"""L2 model invariants: shapes, causality, and — critically — that the
decode path (Pallas kernels + KV cache) agrees with teacher-forcing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    Config, MAX_SEQ, PARAM_ORDER, decode_step, forward_all, init_params,
    ladder, loss_fn, make_exports, prefill, state_size,
)

CFG = Config("test", d_model=32, n_layers=2, n_heads=2, vocab=50, max_seq=32)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0))


def test_param_shapes_cover_order(params):
    assert set(params.keys()) == set(PARAM_ORDER)
    assert CFG.n_params() == sum(int(np.prod(v.shape)) for v in params.values())


def test_forward_shape(params):
    toks = jnp.arange(16) % CFG.vocab
    logits = forward_all(CFG, params, toks)
    assert logits.shape == (16, CFG.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_causality(params):
    """Changing a future token must not affect earlier logits."""
    toks = jnp.arange(20) % CFG.vocab
    base = np.asarray(forward_all(CFG, params, toks))
    mod = toks.at[15].set((toks[15] + 7) % CFG.vocab)
    pert = np.asarray(forward_all(CFG, params, mod))
    np.testing.assert_allclose(base[:15], pert[:15], atol=1e-5)
    assert np.abs(base[15:] - pert[15:]).max() > 1e-6


def test_prefill_matches_forward_last_position(params):
    n = 10
    toks = (jnp.arange(n) * 3 + 1) % CFG.vocab
    padded = jnp.zeros((1, CFG.max_seq), jnp.int32).at[0, :n].set(toks)
    kv, logits_pre = prefill(CFG, params, padded, jnp.array([n], jnp.int32))
    logits_fwd = forward_all(CFG, params, padded[0], jnp.array(n))[n - 1]
    np.testing.assert_allclose(np.asarray(logits_pre), np.asarray(logits_fwd), atol=1e-4)
    assert kv.shape == CFG.kv_shape()


def test_decode_consistent_with_teacher_forcing(params):
    """prefill + step-by-step decode == full forward (same logits)."""
    n = 6
    extra = 4
    toks = (jnp.arange(n + extra) * 5 + 2) % CFG.vocab
    padded = jnp.zeros((1, CFG.max_seq), jnp.int32).at[0, :n].set(toks[:n])
    kv, logits = prefill(CFG, params, padded, jnp.array([n], jnp.int32))
    full = forward_all(CFG, params,
                       jnp.zeros(CFG.max_seq, jnp.int32).at[: n + extra].set(toks),
                       jnp.array(n + extra))
    for i in range(extra):
        pos = n + i
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[pos - 1]), atol=2e-3,
            err_msg=f"logit mismatch feeding position {pos}")
        kv, logits = decode_step(CFG, params, toks[pos][None].astype(jnp.int32),
                                 jnp.array([pos], jnp.int32), kv)


def test_loss_decreases_under_gradient_step(params):
    batch = jnp.ones((2, CFG.max_seq), jnp.int32) * 3
    lens = jnp.array([10, 12], jnp.int32)
    l0, g = jax.value_and_grad(lambda p: loss_fn(CFG, p, batch, lens))(params)
    p2 = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)
    l1 = loss_fn(CFG, p2, batch, lens)
    assert float(l1) < float(l0)


def test_exports_state_roundtrip(params):
    prefill_fn, decode_fn, score_fn = make_exports(CFG)
    plist = [params[k] for k in PARAM_ORDER]
    toks = jnp.zeros((1, CFG.max_seq), jnp.int32).at[0, :5].set(jnp.arange(5))
    state = prefill_fn(toks, jnp.array([5], jnp.int32), *plist)
    assert state.shape == (state_size(CFG),)
    state2 = decode_fn(jnp.array([7], jnp.int32), jnp.array([5], jnp.int32), state, *plist)
    assert state2.shape == state.shape
    logits_all = score_fn(toks, *plist)
    assert logits_all.shape == (CFG.max_seq * CFG.vocab,)


def test_ladder_is_ordered_and_exportable():
    models = ladder(vocab=100)
    assert len(models) == 6
    params_count = [m.n_params() for m in models]
    assert params_count[0] >= params_count[2] >= params_count[3] >= params_count[5]
    for m in models:
        assert m.max_seq == MAX_SEQ
        assert m.d_model % m.n_heads == 0
