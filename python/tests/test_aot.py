"""AOT export smoke: HLO text is emitted, parseable-looking, and the
weights.bin layout matches meta.json. Uses a tiny random-weight variant so
the test is independent of `make artifacts`."""

import json
import pathlib

import numpy as np
import jax
import pytest

from compile.aot import export_variant, to_hlo_text
from compile.model import Config, init_params, make_exports, state_size


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    cfg = Config("tiny-test", d_model=16, n_layers=1, n_heads=2, vocab=30, max_seq=16)
    params = init_params(cfg, jax.random.PRNGKey(1))
    out = tmp_path_factory.mktemp("artifacts")
    meta = export_variant(cfg, params, out, metrics={"eval_accuracy": 0.0})
    return cfg, params, out, meta


def test_hlo_files_written(exported):
    _, _, out, meta = exported
    for name in ("prefill", "decode", "score"):
        text = (out / f"{name}.hlo.txt").read_text()
        assert text.startswith("HloModule"), f"{name} missing HloModule header"
        assert len(text) == meta["hlo_bytes"][name]
        # flat-state interface: root is a plain array, not a tuple
        assert "ROOT" in text


def test_weights_bin_layout(exported):
    cfg, params, out, meta = exported
    blob = (out / "weights.bin").read_bytes()
    total = sum(w["nbytes"] for w in meta["weights"])
    assert len(blob) == total
    for w in meta["weights"]:
        arr = np.frombuffer(blob[w["offset"]:w["offset"] + w["nbytes"]], np.float32)
        expect = np.asarray(params[w["name"]], np.float32).ravel()
        np.testing.assert_array_equal(arr, expect.ravel())


def test_meta_consistency(exported):
    cfg, _, out, meta = exported
    m = json.loads((out / "meta.json").read_text())
    assert m["state_size"] == state_size(cfg)
    assert m["kv_shape"] == list(cfg.kv_shape())
    assert m["param_order"] == [w["name"] for w in m["weights"]]


def test_hlo_text_is_single_array_root(exported):
    """return_tuple=False: the entry computation root must not be a tuple
    (the Rust runtime depends on this to keep state re-feedable)."""
    cfg, params, _, _ = exported
    prefill_fn, _, _ = make_exports(cfg)
    import jax.numpy as jnp
    pspecs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in cfg.param_shapes().values()]
    lowered = jax.jit(prefill_fn).lower(
        jax.ShapeDtypeStruct((1, cfg.max_seq), jnp.int32),
        jax.ShapeDtypeStruct((1,), jnp.int32), *pspecs)
    text = to_hlo_text(lowered)
    root_lines = [l for l in text.splitlines() if l.strip().startswith("ROOT")]
    assert root_lines, "no ROOT found"
    entry_root = root_lines[-1]
    declared_type = entry_root.split("=", 1)[1].strip()
    assert not declared_type.startswith("("), f"root is a tuple: {entry_root}"
