"""Corpus generator invariants: closed vocabulary, sketch/template
consistency, category length ladder, deterministic output."""

from hypothesis import given, settings, strategies as st

from compile import corpus as C


def test_vocab_closed_and_unique():
    vocab = set(C.build_vocab())
    qs = C.generate_corpus(per_category=5)
    for q in qs:
        for t in q.question:
            assert t in vocab, f"question token {t} not in vocab"
        for s in q.sentences:
            for t in s.full + s.sketch:
                assert t in vocab


def test_sketch_is_subsequence_of_full():
    for q in C.generate_corpus(per_category=5):
        for s in q.sentences:
            it = iter(s.full)
            assert all(tok in it for tok in s.sketch), \
                f"sketch {s.sketch} not a subsequence of {s.full}"


def test_templates_distinguishable_by_sketch_shape():
    # the (length, first-class, second-class) signature must identify the
    # template — two leading sketch tokens disambiguate the expansion,
    # which is what makes it a well-posed learning problem
    sigs = set()
    for tid, (_, sk_pat) in enumerate(C.TEMPLATES):
        slots = sk_pat.replace("{", "").replace("}", "").split()
        sig = (len(slots), slots[0], slots[1])
        assert sig not in sigs, f"template {tid} collides: {sig}"
        sigs.add(sig)


def test_category_length_ladder():
    qs = C.generate_corpus(per_category=30)
    def mean_len(cat):
        sel = [q for q in qs if q.category == cat]
        return sum(len(q.answer_tokens) for q in sel) / len(sel)
    assert mean_len("writing") > mean_len("math")
    assert mean_len("roleplay") > mean_len("common-sense")


def test_deterministic():
    a = C.generate_corpus(seed=5, per_category=4)
    b = C.generate_corpus(seed=5, per_category=4)
    assert [q.answer_tokens for q in a] == [q.answer_tokens for q in b]
    c = C.generate_corpus(seed=6, per_category=4)
    assert [q.answer_tokens for q in a] != [q.answer_tokens for q in c]


def test_split_fractions():
    qs = C.generate_corpus(per_category=50, eval_frac=0.3)
    for cat in C.CATEGORIES:
        sel = [q for q in qs if q.category == cat]
        n_eval = sum(1 for q in sel if q.split == "eval")
        assert n_eval == 15


def test_training_sequences_formats():
    qs = C.generate_corpus(per_category=3)
    seqs = C.training_sequences(qs)
    assert all(s[0] == C.Q and s[-1] == C.EOS for s in seqs)
    # the three formats all present
    assert any(C.A in s and C.SK not in s for s in seqs)       # full answer
    assert any(C.SK in s and C.EX not in s and C.A not in s for s in seqs)  # sketch
    assert any(C.EX in s for s in seqs)                        # expansion


def test_sequences_fit_max_seq():
    from compile.model import MAX_SEQ
    qs = C.generate_corpus()
    for s in C.training_sequences(qs):
        assert len(s) <= MAX_SEQ, f"sequence of {len(s)} tokens exceeds {MAX_SEQ}"


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 10_000))
def test_any_seed_produces_valid_corpus(seed):
    qs = C.generate_corpus(seed=seed, per_category=2)
    assert len(qs) == 2 * len(C.CATEGORIES)
    for q in qs:
        assert q.sentences
        assert all(s.sketch for s in q.sentences)
