"""L1 kernel correctness: Pallas vs the pure-jnp oracle (the CORE
correctness signal). Hypothesis sweeps shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attn_decode import attn_decode, _pick_block_k
from compile.kernels.ref import attn_decode_ref, rmsnorm_ref
from compile.kernels.rmsnorm import rmsnorm


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


def _run_attn(h, s, dh, valid, dtype, block_k=None):
    q = _rand(1, (h, dh), dtype)
    k = _rand(2, (h, s, dh), dtype)
    v = _rand(3, (h, s, dh), dtype)
    mask = (jnp.arange(s) < valid).astype(jnp.float32)
    got = attn_decode(q, k, v, mask, block_k=block_k)
    want = attn_decode_ref(q, k, v, mask)
    return np.asarray(got, np.float32), np.asarray(want, np.float32)


class TestAttnDecode:
    def test_basic_f32(self):
        got, want = _run_attn(4, 128, 32, 100, jnp.float32)
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_single_valid_token(self):
        # softmax over one unmasked slot == that slot's V row
        got, want = _run_attn(2, 64, 16, 1, jnp.float32)
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_full_cache(self):
        got, want = _run_attn(4, 128, 32, 128, jnp.float32)
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_bf16_inputs(self):
        got, want = _run_attn(2, 64, 32, 40, jnp.bfloat16)
        np.testing.assert_allclose(got, want, atol=3e-2)

    def test_explicit_block_sizes(self):
        for bk in (8, 16, 32, 64):
            got, want = _run_attn(2, 64, 16, 50, jnp.float32, block_k=bk)
            np.testing.assert_allclose(got, want, atol=1e-5, err_msg=f"bk={bk}")

    def test_block_k_must_divide(self):
        with pytest.raises(AssertionError):
            _run_attn(1, 60, 8, 10, jnp.float32, block_k=32)

    def test_pick_block_k(self):
        assert _pick_block_k(128) == 64
        assert _pick_block_k(96) == 32
        assert _pick_block_k(7) == 1

    @settings(deadline=None, max_examples=25)
    @given(
        h=st.sampled_from([1, 2, 4]),
        s_blocks=st.integers(1, 8),
        dh=st.sampled_from([8, 16, 32]),
        frac=st.floats(0.05, 1.0),
        dtype=st.sampled_from(["f32", "bf16"]),
    )
    def test_hypothesis_sweep(self, h, s_blocks, dh, frac, dtype):
        s = 16 * s_blocks
        valid = max(1, int(s * frac))
        dt = jnp.float32 if dtype == "f32" else jnp.bfloat16
        got, want = _run_attn(h, s, dh, valid, dt)
        atol = 1e-5 if dtype == "f32" else 3e-2
        np.testing.assert_allclose(got, want, atol=atol)

    def test_numerical_stability_large_logits(self):
        # online softmax must survive large score magnitudes
        q = 30.0 * _rand(1, (2, 16), jnp.float32)
        k = 30.0 * _rand(2, (2, 64, 16), jnp.float32)
        v = _rand(3, (2, 64, 16), jnp.float32)
        mask = jnp.ones(64, jnp.float32)
        got = np.asarray(attn_decode(q, k, v, mask))
        want = np.asarray(attn_decode_ref(q, k, v, mask))
        assert np.isfinite(got).all()
        np.testing.assert_allclose(got, want, atol=1e-4)


class TestRmsNorm:
    def test_matches_ref_rows(self):
        x = _rand(5, (8, 64), jnp.float32)
        w = _rand(6, (64,), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(rmsnorm(x, w)), np.asarray(rmsnorm_ref(x, w)), atol=1e-5
        )

    def test_single_row_decode_shape(self):
        x = _rand(7, (1, 48), jnp.float32)
        w = jnp.ones((48,), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(rmsnorm(x, w)), np.asarray(rmsnorm_ref(x, w)), atol=1e-5
        )

    @settings(deadline=None, max_examples=20)
    @given(
        rows=st.integers(1, 17),
        d=st.sampled_from([16, 48, 64, 128]),
        dtype=st.sampled_from(["f32", "bf16"]),
    )
    def test_hypothesis_sweep(self, rows, d, dtype):
        dt = jnp.float32 if dtype == "f32" else jnp.bfloat16
        x = _rand(rows * 31 + d, (rows, d), dt)
        w = _rand(rows * 7 + 1, (d,), dt)
        atol = 1e-5 if dtype == "f32" else 5e-2
        np.testing.assert_allclose(
            np.asarray(rmsnorm(x, w), np.float32),
            np.asarray(rmsnorm_ref(x, w), np.float32),
            atol=atol,
        )

    def test_scale_invariance_property(self):
        # rmsnorm(a*x) == rmsnorm(x) for a > 0 (up to eps effects)
        x = _rand(9, (4, 32), jnp.float32)
        w = jnp.ones((32,), jnp.float32)
        a = 7.5
        np.testing.assert_allclose(
            np.asarray(rmsnorm(a * x, w)), np.asarray(rmsnorm(x, w)), atol=1e-4
        )
