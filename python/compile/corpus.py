"""Synthetic categorized Q/A corpus — the MT-bench / Vicuna-bench substitute.

The paper evaluates PICE on MT-bench and Vicuna-bench with a GPT judge. We
have neither the models nor the judge, so we build a *closed synthetic
language* with the properties the evaluation actually consumes:

  * 12 question categories (the 10 of Table IV + counterfactual and
    common-sense which appear in Figs. 7-11),
  * per-category answer lengths (math/common-sense short, writing/roleplay
    long) driving the scheduler's length heuristics,
  * reference answers built from fixed sentence templates whose *content
    words* form a semantically complete "sketch" and whose filler words are
    a deterministic function of the template — so sketch -> expansion is a
    learnable inverse mapping, and model capacity translates into a real
    quality gap (exactly the gap the ensemble/judge experiments need).

Everything is seeded and deterministic; the corpus is emitted to
``artifacts/corpus.json`` and consumed by the Rust coordinator.
"""

from __future__ import annotations

import json
import random
from dataclasses import dataclass, field

# --------------------------------------------------------------------------
# Vocabulary
# --------------------------------------------------------------------------

# Special tokens. <q>: question start, <a>: answer / expansion output,
# <sk>: sketch, <ex>: the single sketch-sentence to expand, ";" separates
# sketch sentences, "." terminates answer sentences.
PAD, BOS, EOS, Q, A, SK, EX = "<pad>", "<bos>", "<eos>", "<q>", "<a>", "<sk>", "<ex>"
SPECIALS = [PAD, BOS, EOS, Q, A, SK, EX, ".", ";", "?"]

# Filler (grammar) words shared across categories. These are the words a
# sketch drops — the "redundancy phenomenon" of the paper's Observation 1.
FILLERS = [
    "the", "a", "of", "in", "to", "and", "is", "are", "with", "that",
    "on", "for", "it", "as", "by", "can", "will", "because", "into",
    "many", "some", "this", "very", "also", "then", "when", "about",
    "please", "describe", "explain", "how", "what", "why", "write", "tell",
    "me", "story", "question", "answer",
]

CATEGORIES = [
    "generic", "knowledge", "roleplay", "fermi", "coding", "math",
    "writing", "reasoning", "stem", "humanities", "counterfactual",
    "common-sense",
]

# Content-word pools. Classes (noun/verb/adj/adv/place) are globally
# disjoint so a model can infer the word class of every sketch token.
# Each category gets its own nouns; verbs/adjs/advs/places are shared pools
# sliced per category to keep the vocabulary compact but category-flavoured.
_NOUN_POOLS = {
    "generic": ["life", "habit", "plan", "goal", "idea", "choice", "routine", "balance"],
    "knowledge": ["atom", "cell", "planet", "ocean", "climate", "energy", "virus", "genome"],
    "roleplay": ["knight", "wizard", "dragon", "castle", "quest", "sword", "kingdom", "hero"],
    "fermi": ["piano", "raindrop", "hair", "grain", "bulb", "brick", "leaf", "coin"],
    "coding": ["function", "array", "loop", "stack", "pointer", "thread", "cache", "queue"],
    "math": ["number", "fraction", "angle", "matrix", "prime", "vector", "graph", "sum"],
    "writing": ["letter", "essay", "poem", "novel", "chapter", "draft", "plot", "scene"],
    "reasoning": ["clue", "premise", "pattern", "motive", "paradox", "proof", "riddle", "logic"],
    "stem": ["circuit", "enzyme", "rocket", "laser", "magnet", "turbine", "sensor", "alloy"],
    "humanities": ["empire", "treaty", "culture", "myth", "revolution", "dynasty", "temple", "trade"],
    "counterfactual": ["history", "timeline", "outcome", "event", "decision", "war", "invention", "discovery"],
    "common-sense": ["umbrella", "kitchen", "ladder", "mirror", "shadow", "pocket", "window", "bridge"],
}
_VERBS = [
    "moves", "shapes", "guides", "builds", "breaks", "holds", "turns", "links",
    "grows", "drives", "forms", "lifts", "splits", "joins", "maps", "tests",
    "sorts", "binds", "leads", "marks", "bends", "melts", "spins", "flows",
]
_ADJS = [
    "bright", "steady", "hidden", "simple", "complex", "ancient", "modern", "rapid",
    "gentle", "sharp", "quiet", "bold", "narrow", "broad", "dense", "hollow",
    "smooth", "rough", "deep", "light", "heavy", "warm", "cold", "pure",
]
_ADVS = [
    "slowly", "quickly", "carefully", "boldly", "quietly", "firmly",
    "smoothly", "rarely", "often", "easily", "barely", "fully",
]
_PLACES = [
    "garden", "valley", "market", "library", "harbor", "forest",
    "desert", "village", "tower", "meadow", "cavern", "plaza",
]


def build_vocab() -> list[str]:
    """Deterministic token list; index = token id."""
    vocab: list[str] = list(SPECIALS) + list(FILLERS)
    for cat in CATEGORIES:
        vocab.extend(_NOUN_POOLS[cat])
    vocab.extend(_VERBS)
    vocab.extend(_ADJS)
    vocab.extend(_ADVS)
    vocab.extend(_PLACES)
    assert len(vocab) == len(set(vocab)), "vocab has duplicates"
    return vocab


# --------------------------------------------------------------------------
# Sentence templates
# --------------------------------------------------------------------------
# Each template is (full-sentence pattern, sketch pattern). Slots: N=noun,
# N2=second noun, V=verb, V2=second verb, J=adjective, D=adverb, P=place.
# Sketch patterns are distinguishable by length + leading word class, so
# the inverse mapping sketch -> full sentence is well defined (and
# learnable: that is what the SLM "expansion" has to do).
TEMPLATES = [
    # id 0: 5-word sketch starting with adjective
    ("the {J} {N} {V} the {N2} in the {P} .", "{J} {N} {V} {N2} {P}"),
    # id 1: 4-word sketch, second word verb, third adverb
    ("a {N} can {V} {D} with the {N2} .", "{N} {V} {D} {N2}"),
    # id 2: 4-word sketch, second word adjective
    ("the {N} is {J} because it {V} the {N2} .", "{N} {J} {V} {N2}"),
    # id 3: 5-word sketch starting with noun, double verb
    ("many {N} {V} to {V2} the {J} {N2} .", "{N} {V} {V2} {J} {N2}"),
]

# Expected answer length in *sentences* per category — mirrors the paper's
# observation that math/common-sense answers are short while writing/roleplay
# answers are long (Fig. 7, Fig. 10).
SENTENCES_PER_CATEGORY = {
    "generic": 4, "knowledge": 5, "roleplay": 6, "fermi": 3, "coding": 5,
    "math": 2, "writing": 8, "reasoning": 4, "stem": 5, "humanities": 6,
    "counterfactual": 3, "common-sense": 2,
}

QUESTION_TEMPLATES = [
    "please describe the {J} {N} in the {P} ?",
    "explain how the {N} {V} the {N2} ?",
    "why is the {N} {J} and how it {V} ?",
    "tell me about the {N} and the {N2} in the {P} ?",
    "write a story about the {J} {N} that {V} ?",
]


@dataclass
class Sentence:
    """One reference-answer sentence with its sketch."""
    template_id: int
    full: list[str] = field(default_factory=list)
    sketch: list[str] = field(default_factory=list)


@dataclass
class Question:
    qid: int
    category: str
    question: list[str]
    sentences: list[Sentence]
    split: str  # "train" | "eval"

    @property
    def answer_tokens(self) -> list[str]:
        out: list[str] = []
        for s in self.sentences:
            out.extend(s.full)
        return out

    @property
    def sketch_tokens(self) -> list[str]:
        out: list[str] = []
        for i, s in enumerate(self.sentences):
            if i:
                out.append(";")
            out.extend(s.sketch)
        return out


def _fill(pattern: str, rng: random.Random, cat: str) -> dict[str, str]:
    pool = _NOUN_POOLS[cat]
    n = rng.choice(pool)
    n2 = rng.choice([x for x in pool if x != n])
    v = rng.choice(_VERBS)
    slots = {
        "N": n, "N2": n2, "V": v,
        "V2": rng.choice([x for x in _VERBS if x != v]),
        "J": rng.choice(_ADJS), "D": rng.choice(_ADVS),
        "P": rng.choice(_PLACES),
    }
    return {k: v for k, v in slots.items() if "{%s}" % k in pattern}


def make_sentence(rng: random.Random, cat: str) -> Sentence:
    tid = rng.randrange(len(TEMPLATES))
    full_pat, sk_pat = TEMPLATES[tid]
    slots = _fill(full_pat + " " + sk_pat, rng, cat)
    full = full_pat.format(**slots).split()
    sketch = sk_pat.format(**slots).split()
    return Sentence(template_id=tid, full=full, sketch=sketch)


def make_question(qid: int, cat: str, rng: random.Random, split: str) -> Question:
    qpat = QUESTION_TEMPLATES[rng.randrange(len(QUESTION_TEMPLATES))]
    qslots = _fill(qpat, rng, cat)
    qtoks = qpat.format(**qslots).split()
    k = SENTENCES_PER_CATEGORY[cat]
    # +-1 sentence of natural variation
    k = max(1, k + rng.choice([-1, 0, 0, 1]))
    sents = [make_sentence(rng, cat) for _ in range(k)]
    return Question(qid=qid, category=cat, question=qtoks, sentences=sents, split=split)


def generate_corpus(seed: int = 20250710, per_category: int = 150,
                    eval_frac: float = 0.3) -> list[Question]:
    rng = random.Random(seed)
    questions: list[Question] = []
    qid = 0
    for cat in CATEGORIES:
        n_eval = int(per_category * eval_frac)
        for i in range(per_category):
            split = "eval" if i >= per_category - n_eval else "train"
            questions.append(make_question(qid, cat, rng, split))
            qid += 1
    return questions


# --------------------------------------------------------------------------
# Training sequences (consumed by train.py)
# --------------------------------------------------------------------------

def training_sequences(questions: list[Question]) -> list[list[str]]:
    """Three sequence formats per train question:

    1. full answer        <q> q <a> s1 . s2 . ... <eos>
    2. sketch generation  <q> q <sk> sk1 ; sk2 ; ... <eos>
    3. expansion          <q> q <sk> full-sketch <ex> sk_i <a> s_i <eos>
       (one per sentence)
    """
    seqs: list[list[str]] = []
    for qq in questions:
        if qq.split != "train":
            continue
        q = qq.question
        seqs.append([Q, *q, A, *qq.answer_tokens, EOS])
        seqs.append([Q, *q, SK, *qq.sketch_tokens, EOS])
        for s in qq.sentences:
            seqs.append([Q, *q, SK, *qq.sketch_tokens, EX, *s.sketch, A, *s.full, EOS])
    return seqs


def corpus_to_json(questions: list[Question]) -> dict:
    return {
        "categories": CATEGORIES,
        "specials": SPECIALS,
        "sentences_per_category": SENTENCES_PER_CATEGORY,
        "questions": [
            {
                "id": q.qid,
                "category": q.category,
                "split": q.split,
                "question": q.question,
                "sentences": [
                    {"template": s.template_id, "full": s.full, "sketch": s.sketch}
                    for s in q.sentences
                ],
            }
            for q in questions
        ],
    }


def main(out_corpus: str, out_vocab: str) -> None:
    vocab = build_vocab()
    questions = generate_corpus()
    with open(out_vocab, "w") as f:
        json.dump({"tokens": vocab}, f)
    with open(out_corpus, "w") as f:
        json.dump(corpus_to_json(questions), f)
    n_train = sum(1 for q in questions if q.split == "train")
    print(f"vocab={len(vocab)} questions={len(questions)} (train={n_train})")


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "../artifacts/corpus.json",
         sys.argv[2] if len(sys.argv) > 2 else "../artifacts/vocab.json")
