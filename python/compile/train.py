"""Build-time training of the picoLM ladder on the synthetic corpus.

Hand-rolled Adam (no optax in the image). Each variant trains on the same
next-token objective; capacity alone creates the Table-I-style quality
ladder. The two same-size "families" (qwen72b-sim vs llama70b-sim, etc.)
differ by init seed and a 90% data subsample — giving the genuinely
*diverse* errors the ensemble-learning component exploits (paper §IV-C).
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus as corpus_mod
from .model import Config, MAX_SEQ, init_params, loss_fn, forward_all


def encode(seqs: list[list[str]], tok2id: dict[str, int]) -> tuple[np.ndarray, np.ndarray]:
    """Pad/encode token sequences to [N, MAX_SEQ] + lengths [N]."""
    n = len(seqs)
    out = np.zeros((n, MAX_SEQ), np.int32)  # 0 == <pad>
    lens = np.zeros((n,), np.int32)
    for i, s in enumerate(seqs):
        ids = [tok2id[t] for t in s][:MAX_SEQ]
        out[i, : len(ids)] = ids
        lens[i] = len(ids)
    return out, lens


def adam_update(params, grads, m, v, step, lr, b1=0.9, b2=0.99, eps=1e-8):
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    mhat = jax.tree.map(lambda a: a / (1 - b1 ** step), m)
    vhat = jax.tree.map(lambda a: a / (1 - b2 ** step), v)
    params = jax.tree.map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat)
    return params, m, v


def train_variant(cfg: Config, data: np.ndarray, lens: np.ndarray, *,
                  seed: int, steps: int, batch: int = 16,
                  lr: float = 6e-3, subsample: float = 1.0,
                  log_every: int = 100) -> tuple[dict, dict]:
    """Train one variant; returns (params, train report)."""
    rng = np.random.default_rng(seed)
    n = data.shape[0]
    if subsample < 1.0:
        keep = rng.permutation(n)[: int(n * subsample)]
        data, lens = data[keep], lens[keep]
        n = data.shape[0]

    params = init_params(cfg, jax.random.PRNGKey(seed))
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step_fn(params, m, v, batch_toks, batch_lens, step, lr_t):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch_toks, batch_lens))(params)
        params, m, v = adam_update(params, grads, m, v, step, lr_t)
        return params, m, v, loss

    t0 = time.time()
    last_loss = float("nan")
    for it in range(1, steps + 1):
        idx = rng.integers(0, n, size=batch)
        # cosine LR with short warmup
        warm = min(1.0, it / 20)
        lr_t = lr * warm * 0.5 * (1 + np.cos(np.pi * it / steps))
        params, m, v, loss = step_fn(
            params, m, v, jnp.asarray(data[idx]), jnp.asarray(lens[idx]),
            jnp.float32(it), jnp.float32(lr_t))
        if it % log_every == 0 or it == steps:
            last_loss = float(loss)
            print(f"  [{cfg.name}] step {it}/{steps} loss={last_loss:.3f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
    report = {"steps": steps, "final_loss": last_loss,
              "train_seconds": round(time.time() - t0, 1)}
    return params, report


def eval_accuracy(cfg: Config, params: dict, data: np.ndarray,
                  lens: np.ndarray, max_seqs: int = 64) -> float:
    """Held-out next-token accuracy — the MMLU-score stand-in."""

    @jax.jit
    def acc_one(tokens, length):
        logits = forward_all(cfg, params, tokens)
        pred = jnp.argmax(logits, axis=-1)
        tgt = jnp.roll(tokens, -1)
        w = (jnp.arange(tokens.shape[0]) < length - 1).astype(jnp.float32)
        return ((pred == tgt) * w).sum(), w.sum()

    hits = tot = 0.0
    for i in range(min(max_seqs, data.shape[0])):
        h, t = acc_one(jnp.asarray(data[i]), jnp.asarray(lens[i]))
        hits += float(h)
        tot += float(t)
    return hits / max(tot, 1.0)


def build_dataset() -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, list[str]]:
    """Returns (train toks, train lens, eval toks, eval lens, vocab)."""
    vocab = corpus_mod.build_vocab()
    tok2id = {t: i for i, t in enumerate(vocab)}
    questions = corpus_mod.generate_corpus()
    train_seqs = corpus_mod.training_sequences(questions)
    # held-out sequences from eval questions, same formats
    eval_qs = [q for q in questions if q.split == "eval"]
    for q in eval_qs:
        q.split = "train"  # reuse generator
    eval_seqs = corpus_mod.training_sequences(eval_qs)
    for q in eval_qs:
        q.split = "eval"
    tr, trl = encode(train_seqs, tok2id)
    ev, evl = encode(eval_seqs, tok2id)
    return tr, trl, ev, evl, vocab
