"""L2: the picoLM transformer family (JAX, build-time only).

Six variants mirror the paper's Table I model ladder (Qwen2.5-72B ... 1.5B).
The *relative* capability ordering is what PICE's scheduler/ensemble/judge
logic consumes; capacity differences on the synthetic corpus produce a real
quality gap between "cloud LLM" and "edge SLM" (DESIGN.md §2).

Architecture: learned positional embeddings, pre-RMSNorm blocks, MHA with a
causal mask, GELU MLP (4x), tied LM head. Layer weights are stacked on a
leading L axis and consumed with ``lax.scan`` so the lowered HLO stays small.

Three entry points are AOT-exported per variant (aot.py):
  * prefill(tokens[1,S], length[1], *params) -> (kv, logits[V])
  * decode(token[1], pos[1], kv, *params)    -> (kv, logits[V])
  * score(tokens[1,S], *params)              -> logits[S,V]
The decode path runs the L1 Pallas kernels (attn_decode + rmsnorm) so they
lower into the same HLO the Rust runtime executes.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.attn_decode import attn_decode
from .kernels.rmsnorm import rmsnorm
from .kernels.ref import rmsnorm_ref

MAX_SEQ = 128

# Names, in the exact order params are passed to the exported functions and
# laid out in weights.bin. The Rust runtime follows this order.
PARAM_ORDER = ["emb", "pos", "wq", "wk", "wv", "wo", "w1", "w2", "ln1", "ln2", "lnf"]


@dataclass(frozen=True)
class Config:
    """picoLM hyperparameters for one Table-I-ladder variant."""
    name: str          # e.g. "qwen72b-sim"
    d_model: int
    n_layers: int
    n_heads: int
    vocab: int
    max_seq: int = MAX_SEQ

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    def param_shapes(self) -> dict[str, tuple[int, ...]]:
        d, l, v, f = self.d_model, self.n_layers, self.vocab, self.d_ff
        return {
            "emb": (v, d), "pos": (self.max_seq, d),
            "wq": (l, d, d), "wk": (l, d, d), "wv": (l, d, d), "wo": (l, d, d),
            "w1": (l, d, f), "w2": (l, f, d),
            "ln1": (l, d), "ln2": (l, d), "lnf": (d,),
        }

    def n_params(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for s in self.param_shapes().values())

    def kv_shape(self) -> tuple[int, ...]:
        # [L, 2(kv), H, S, Dh] — matches the attn_decode kernel's [H, S, Dh].
        return (self.n_layers, 2, self.n_heads, self.max_seq, self.head_dim)


# The model ladder. Capacity ordering mirrors Table I; the two 70B-class and
# the two 7/8B-class variants differ by init seed (distinct "families"), which
# is what makes the ensemble's diversity argument real.
def ladder(vocab: int) -> list[Config]:
    return [
        Config("qwen72b-sim", d_model=128, n_layers=4, n_heads=4, vocab=vocab),
        Config("llama70b-sim", d_model=128, n_layers=4, n_heads=4, vocab=vocab),
        Config("qwen32b-sim", d_model=112, n_layers=4, n_heads=4, vocab=vocab),
        Config("llama8b-sim", d_model=64, n_layers=2, n_heads=2, vocab=vocab),
        Config("qwen7b-sim", d_model=64, n_layers=2, n_heads=2, vocab=vocab),
        Config("qwen1.5b-sim", d_model=48, n_layers=2, n_heads=2, vocab=vocab),
    ]


def init_params(cfg: Config, key: jax.Array) -> dict[str, jax.Array]:
    shapes = cfg.param_shapes()
    keys = jax.random.split(key, len(shapes))
    params = {}
    for (name, shape), k in zip(shapes.items(), keys):
        if name.startswith("ln"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            params[name] = jax.random.normal(k, shape, jnp.float32) * (fan_in ** -0.5)
    return params


# --------------------------------------------------------------------------
# Forward passes
# --------------------------------------------------------------------------

def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    # [S, d] -> [H, S, Dh]
    s, d = x.shape
    return x.reshape(s, n_heads, d // n_heads).transpose(1, 0, 2)


def forward_all(cfg: Config, params: dict, tokens: jax.Array,
                length: jax.Array | None = None) -> jax.Array:
    """Teacher-forcing forward over a whole [S] token sequence -> [S, V].

    Used for training and the exported ``score`` entry point. Plain jnp
    attention (batched prefill is compute-bound; the Pallas kernel targets
    the bandwidth-bound decode path).
    """
    s = tokens.shape[0]
    x = params["emb"][tokens] + params["pos"][:s]
    causal = jnp.tril(jnp.ones((s, s), jnp.float32))
    if length is not None:
        valid = (jnp.arange(s) < length).astype(jnp.float32)
        causal = causal * valid[None, :]
    neg = (causal - 1.0) * 1e9

    def block(x, layer):
        wq, wk, wv, wo, w1, w2, ln1, ln2 = layer
        h = rmsnorm_ref(x, ln1)
        q = _split_heads(h @ wq, cfg.n_heads)
        k = _split_heads(h @ wk, cfg.n_heads)
        v = _split_heads(h @ wv, cfg.n_heads)
        scores = jnp.einsum("hqd,hkd->hqk", q, k) / (cfg.head_dim ** 0.5) + neg
        att = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("hqk,hkd->hqd", att, v)
        o = o.transpose(1, 0, 2).reshape(s, cfg.d_model)
        x = x + o @ wo
        h2 = rmsnorm_ref(x, ln2)
        x = x + jax.nn.gelu(h2 @ w1) @ w2
        return x, None

    layers = (params["wq"], params["wk"], params["wv"], params["wo"],
              params["w1"], params["w2"], params["ln1"], params["ln2"])
    x, _ = jax.lax.scan(block, x, layers)
    x = rmsnorm_ref(x, params["lnf"])
    return x @ params["emb"].T  # tied head -> [S, V]


def prefill(cfg: Config, params: dict, tokens: jax.Array,
            length: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Process a padded [1, S] prompt; return (kv cache, logits at length-1).

    The KV cache is populated for *all* S slots (padding slots hold garbage
    keys/values); decode masks by position, so the garbage is never read.
    """
    toks = tokens[0]
    s = cfg.max_seq
    x = params["emb"][toks] + params["pos"][:s]
    llen = length[0]
    valid = (jnp.arange(s) < llen).astype(jnp.float32)
    causal = jnp.tril(jnp.ones((s, s), jnp.float32)) * valid[None, :]
    neg = (causal - 1.0) * 1e9

    def block(x, layer):
        wq, wk, wv, wo, w1, w2, ln1, ln2 = layer
        h = rmsnorm_ref(x, ln1)
        q = _split_heads(h @ wq, cfg.n_heads)
        k = _split_heads(h @ wk, cfg.n_heads)   # [H, S, Dh]
        v = _split_heads(h @ wv, cfg.n_heads)
        scores = jnp.einsum("hqd,hkd->hqk", q, k) / (cfg.head_dim ** 0.5) + neg
        att = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("hqk,hkd->hqd", att, v)
        o = o.transpose(1, 0, 2).reshape(s, cfg.d_model)
        x = x + o @ wo
        h2 = rmsnorm_ref(x, ln2)
        x = x + jax.nn.gelu(h2 @ w1) @ w2
        return x, jnp.stack([k, v])             # [2, H, S, Dh]

    layers = (params["wq"], params["wk"], params["wv"], params["wo"],
              params["w1"], params["w2"], params["ln1"], params["ln2"])
    x, kv = jax.lax.scan(block, x, layers)      # kv: [L, 2, H, S, Dh]
    x = rmsnorm_ref(x, params["lnf"])
    logits = x[llen - 1] @ params["emb"].T      # [V]
    return kv, logits


def decode_step(cfg: Config, params: dict, token: jax.Array, pos: jax.Array,
                kv: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One autoregressive step at position ``pos`` (the L1 hot path).

    token: [1] i32 — the token *at* pos; pos: [1] i32.
    kv:    [L, 2, H, S, Dh] cache with positions < pos filled.
    Returns (updated kv, next-token logits [V]).
    """
    p = pos[0]
    x = params["emb"][token[0]] + params["pos"][p]        # [d]
    mask = (jnp.arange(cfg.max_seq) <= p).astype(jnp.float32)

    def block(carry, layer):
        x = carry
        wq, wk, wv, wo, w1, w2, ln1, ln2, kv_l = layer
        h = rmsnorm(x[None, :], ln1)[0]                   # L1 kernel
        q = (h @ wq).reshape(cfg.n_heads, cfg.head_dim)
        k_new = (h @ wk).reshape(cfg.n_heads, 1, cfg.head_dim)
        v_new = (h @ wv).reshape(cfg.n_heads, 1, cfg.head_dim)
        k = jax.lax.dynamic_update_slice(kv_l[0], k_new, (0, p, 0))
        v = jax.lax.dynamic_update_slice(kv_l[1], v_new, (0, p, 0))
        o = attn_decode(q, k, v, mask)                    # L1 kernel
        x = x + o.reshape(cfg.d_model) @ wo
        h2 = rmsnorm(x[None, :], ln2)[0]                  # L1 kernel
        x = x + jax.nn.gelu(h2 @ w1) @ w2
        return x, jnp.stack([k, v])

    layers = (params["wq"], params["wk"], params["wv"], params["wo"],
              params["w1"], params["w2"], params["ln1"], params["ln2"], kv)
    x, kv_new = jax.lax.scan(block, x, layers)
    x = rmsnorm(x[None, :], params["lnf"])[0]
    logits = x @ params["emb"].T
    return kv_new, logits


# --------------------------------------------------------------------------
# Exported (positional-params) wrappers — the AOT interface
# --------------------------------------------------------------------------
# PJRT (via the rust `xla` crate) returns multi-output programs as a single
# *tuple* buffer that cannot be re-fed or partially read. We therefore export
# single-array functions over a flat f32 "state" = concat(kv.ravel(), logits):
# the state buffer stays device-side across decode steps and the Rust side
# reads only the logits tail with an offset copy_raw_to_host_sync.

def _pack(args: tuple) -> dict:
    return dict(zip(PARAM_ORDER, args))


def state_size(cfg: Config) -> int:
    kv_elems = 1
    for d in cfg.kv_shape():
        kv_elems *= d
    return kv_elems + cfg.vocab


def make_exports(cfg: Config):
    """Positional-argument wrappers matching PARAM_ORDER, for jax.jit.lower."""
    kv_shape = cfg.kv_shape()
    kv_elems = state_size(cfg) - cfg.vocab

    def prefill_fn(tokens, length, *params):
        kv, logits = prefill(cfg, _pack(params), tokens, length)
        return jnp.concatenate([kv.reshape(-1), logits])

    def decode_fn(token, pos, state, *params):
        kv = state[:kv_elems].reshape(kv_shape)
        kv, logits = decode_step(cfg, _pack(params), token, pos, kv)
        return jnp.concatenate([kv.reshape(-1), logits])

    def score_fn(tokens, *params):
        return forward_all(cfg, _pack(params), tokens[0]).reshape(-1)

    return prefill_fn, decode_fn, score_fn


def loss_fn(cfg: Config, params: dict, batch: jax.Array,
            lengths: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy over a [B, S] batch (pad-masked)."""

    def one(tokens, length):
        logits = forward_all(cfg, params, tokens)         # [S, V]
        logp = jax.nn.log_softmax(logits, axis=-1)
        tgt = jnp.roll(tokens, -1)
        picked = jnp.take_along_axis(logp, tgt[:, None], axis=-1)[:, 0]
        w = (jnp.arange(tokens.shape[0]) < length - 1).astype(jnp.float32)
        return -(picked * w).sum(), w.sum()

    nll, cnt = jax.vmap(one)(batch, lengths)
    return nll.sum() / jnp.maximum(cnt.sum(), 1.0)
