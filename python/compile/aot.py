"""AOT pipeline: corpus -> train picoLM ladder -> export HLO text + weights.

Outputs (all under artifacts/):
  corpus.json, vocab.json, manifest.json
  models/<name>/{prefill,decode,score}.hlo.txt   — HLO *text* (xla_extension
      0.5.1 rejects jax>=0.5 serialized protos; the text parser reassigns
      instruction ids — see /opt/xla-example/README.md)
  models/<name>/weights.bin                      — f32 LE, PARAM_ORDER layout
  models/<name>/meta.json                        — shapes, arg order, sim
      profile (Table-I/II calibration), measured eval metrics

Runs ONCE at build time (`make artifacts`); Python is never on the request
path. Env knobs: PICE_TRAIN_STEPS (default 300), PICE_SKIP_TRAIN=1 (random
weights — CI smoke only).
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus as corpus_mod
from .model import MAX_SEQ, PARAM_ORDER, Config, ladder, make_exports, state_size
from .train import build_dataset, eval_accuracy, train_variant

# Simulated-testbed calibration, straight from the paper's Table I
# (A100+vLLM speeds, GPU memory, MMLU) plus behavioural notes from §V-B:
# the 32B model "often underestimates" response lengths (length_pred_bias).
SIM_PROFILE = {
    "qwen72b-sim": dict(speed_tps=18.19, memory_gb=134.74, mmlu=86.1,
                        length_pred_bias=1.0, family="qwen"),
    "llama70b-sim": dict(speed_tps=18.82, memory_gb=130.64, mmlu=79.5,
                         length_pred_bias=1.0, family="llama"),
    "qwen32b-sim": dict(speed_tps=22.13, memory_gb=60.11, mmlu=83.3,
                        length_pred_bias=0.55, family="qwen"),
    "llama8b-sim": dict(speed_tps=76.5, memory_gb=15.83, mmlu=66.6,
                        length_pred_bias=1.0, family="llama"),
    "qwen7b-sim": dict(speed_tps=84.28, memory_gb=14.92, mmlu=74.2,
                       length_pred_bias=1.0, family="qwen"),
    "qwen1.5b-sim": dict(speed_tps=183.33, memory_gb=3.44, mmlu=60.9,
                         length_pred_bias=0.9, family="qwen"),
}

TRAIN_SEEDS = {
    "qwen72b-sim": 1, "llama70b-sim": 2, "qwen32b-sim": 3,
    "llama8b-sim": 4, "qwen7b-sim": 5, "qwen1.5b-sim": 6,
}
# same-size families get different data subsets -> diverse errors
SUBSAMPLE = {"llama70b-sim": 0.9, "qwen7b-sim": 0.9}


def to_hlo_text(lowered) -> str:
    # return_tuple=False: every export returns one flat array, so the PJRT
    # result is a plain (re-feedable, offset-readable) buffer — see model.py.
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False)
    return comp.as_hlo_text()


def export_variant(cfg: Config, params: dict, outdir: pathlib.Path,
                   metrics: dict) -> dict:
    outdir.mkdir(parents=True, exist_ok=True)
    prefill_fn, decode_fn, score_fn = make_exports(cfg)

    pspecs = [jax.ShapeDtypeStruct(shape, jnp.float32)
              for shape in cfg.param_shapes().values()]
    state_spec = jax.ShapeDtypeStruct((state_size(cfg),), jnp.float32)
    tok_spec = jax.ShapeDtypeStruct((1, cfg.max_seq), jnp.int32)
    i1 = jax.ShapeDtypeStruct((1,), jnp.int32)

    exports = {
        "prefill": jax.jit(prefill_fn).lower(tok_spec, i1, *pspecs),
        "decode": jax.jit(decode_fn).lower(i1, i1, state_spec, *pspecs),
        "score": jax.jit(score_fn).lower(tok_spec, *pspecs),
    }
    hlo_sizes = {}
    for name, lowered in exports.items():
        text = to_hlo_text(lowered)
        (outdir / f"{name}.hlo.txt").write_text(text)
        hlo_sizes[name] = len(text)

    # weights.bin: f32 LE concatenation in PARAM_ORDER
    offset = 0
    layout = []
    with open(outdir / "weights.bin", "wb") as f:
        for name in PARAM_ORDER:
            arr = np.asarray(params[name], np.float32)
            b = arr.tobytes()
            layout.append({"name": name, "shape": list(arr.shape),
                           "dtype": "f32", "offset": offset, "nbytes": len(b)})
            f.write(b)
            offset += len(b)

    meta = {
        "name": cfg.name,
        "d_model": cfg.d_model, "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads, "head_dim": cfg.head_dim,
        "vocab": cfg.vocab, "max_seq": cfg.max_seq,
        "n_params": int(cfg.n_params()),
        "kv_shape": list(cfg.kv_shape()),
        "state_size": int(state_size(cfg)),
        "param_order": PARAM_ORDER,
        "weights": layout,
        "hlo_bytes": hlo_sizes,
        "sim": SIM_PROFILE.get(cfg.name, dict(
            speed_tps=100.0, memory_gb=1.0, mmlu=50.0,
            length_pred_bias=1.0, family="test")),
        "metrics": metrics,
        # exported arg orders, for the Rust runtime
        "args": {
            "prefill": ["tokens[1,S]i32", "length[1]i32", *PARAM_ORDER],
            "decode": ["token[1]i32", "pos[1]i32", "kv", *PARAM_ORDER],
            "score": ["tokens[1,S]i32", *PARAM_ORDER],
        },
    }
    (outdir / "meta.json").write_text(json.dumps(meta, indent=1))
    return meta


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    ap.add_argument("--steps", type=int,
                    default=int(os.environ.get("PICE_TRAIN_STEPS", "300")))
    ap.add_argument("--only", default=None, help="comma-separated variant names")
    ap.add_argument("--reexport", action="store_true",
                    help="reuse existing weights.bin; re-emit HLO/meta only")
    args = ap.parse_args()

    root = pathlib.Path(args.out)
    root.mkdir(parents=True, exist_ok=True)
    (root / "models").mkdir(exist_ok=True)

    corpus_mod.main(str(root / "corpus.json"), str(root / "vocab.json"))
    tr, trl, ev, evl, vocab = build_dataset()
    print(f"train sequences={tr.shape[0]} eval sequences={ev.shape[0]}")

    skip_train = os.environ.get("PICE_SKIP_TRAIN") == "1"
    only = set(args.only.split(",")) if args.only else None

    manifest = {"max_seq": MAX_SEQ, "vocab": len(vocab), "models": []}
    for cfg in ladder(len(vocab)):
        if only and cfg.name not in only:
            continue
        print(f"=== {cfg.name}: d={cfg.d_model} L={cfg.n_layers} "
              f"H={cfg.n_heads} params={cfg.n_params()/1e6:.2f}M")
        wpath = root / "models" / cfg.name / "weights.bin"
        mpath = root / "models" / cfg.name / "meta.json"
        if args.reexport and wpath.exists() and mpath.exists():
            old = json.loads(mpath.read_text())
            blob = wpath.read_bytes()
            params = {}
            for w in old["weights"]:
                arr = np.frombuffer(
                    blob[w["offset"]:w["offset"] + w["nbytes"]], np.float32)
                params[w["name"]] = jnp.asarray(arr.reshape(w["shape"]))
            report = old.get("metrics", {})
            report.pop("eval_accuracy", None)
        elif skip_train:
            from .model import init_params
            params = init_params(cfg, jax.random.PRNGKey(TRAIN_SEEDS[cfg.name]))
            report = {"steps": 0, "final_loss": None, "train_seconds": 0}
        else:
            params, report = train_variant(
                cfg, tr, trl, seed=TRAIN_SEEDS[cfg.name], steps=args.steps,
                subsample=SUBSAMPLE.get(cfg.name, 1.0))
        acc = eval_accuracy(cfg, params, ev, evl)
        print(f"  eval next-token accuracy = {acc:.3f}")
        metrics = {**report, "eval_accuracy": round(acc, 4)}
        meta = export_variant(cfg, params, root / "models" / cfg.name, metrics)
        manifest["models"].append(cfg.name)
        print(f"  exported: {meta['hlo_bytes']}")

    (root / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print("AOT done.")


if __name__ == "__main__":
    main()
