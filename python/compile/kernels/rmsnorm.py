"""L1 Pallas kernel: fused RMSNorm for the decode path.

Decode processes one token at a time, so every per-layer norm is a [d]
vector op sandwiched between matvecs. Fusing normalize+scale into one VMEM
pass removes two HBM round-trips per layer per token. Rows are blocked so
the same kernel serves prefill ([S, d]) and decode ([1, d]).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)          # [BR, d]
    w = w_ref[...].astype(jnp.float32)          # [d]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * w).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps"))
def rmsnorm(x: jax.Array, w: jax.Array, block_rows: int = 8,
            eps: float = 1e-6) -> jax.Array:
    """RMS-normalize rows of x and scale by w.

    Args:
      x: [R, d] activations (R = 1 for decode, S for prefill).
      w: [d] gain.
    """
    r, d = x.shape
    br = min(block_rows, r)
    while r % br:
        br -= 1
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=True,
    )(x, w)
