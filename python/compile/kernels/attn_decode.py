"""L1 Pallas kernel: fused single-query attention over the KV cache.

This is the decode hot-spot the paper motivates in Sec. II-B: generating one
token requires reading the *entire* KV cache, which dominates decode latency
("more than 50% of the inference latency"). The paper's substrate (vLLM
PagedAttention) tiles the KV cache into GPU pages per threadblock; the TPU
rethink (DESIGN.md §5) expresses the same schedule with a Pallas grid:

  * grid = (heads, kv_blocks): each step streams one (block_k, head_dim)
    KV tile HBM->VMEM via BlockSpec — the analog of a threadblock's page.
  * Q·Kᵀ and P·V are whole-tile contractions (MXU-systolic friendly),
    not per-thread dot products.
  * flash-style *online softmax*: running max m and denominator l are
    carried across grid steps in revisited output blocks (sequential TPU
    grid semantics), replacing CUDA shared-memory reductions.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; real-TPU performance is an analytic estimate (DESIGN.md §6).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _pick_block_k(seq_len: int) -> int:
    """Largest power-of-two KV tile <= 64 that divides seq_len."""
    for bk in (64, 32, 16, 8, 4, 2, 1):
        if seq_len % bk == 0:
            return bk
    return 1


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, *, scale):
    """One (head, kv-block) grid step of online-softmax decode attention.

    q_ref    [1, Dh]   — query for this head (revisited across kv blocks)
    k_ref    [1, BK, Dh], v_ref [1, BK, Dh] — the streamed KV tile
    mask_ref [BK]      — 1.0 for valid cache slots, 0.0 for padding
    o_ref    [1, Dh]   — unnormalized output accumulator (revisited)
    m_ref    [1, 1]    — running max,   l_ref [1, 1] — running denominator
    """
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[0, 0] = NEG_INF
        l_ref[0, 0] = 0.0

    q = q_ref[0, :].astype(jnp.float32)          # [Dh]
    k = k_ref[0, :, :].astype(jnp.float32)       # [BK, Dh]
    v = v_ref[0, :, :].astype(jnp.float32)       # [BK, Dh]
    mask = mask_ref[...].astype(jnp.float32)     # [BK]

    # MXU-shaped contraction: scores for the whole tile at once.
    s = (k @ q) * scale + (mask - 1.0) * 1e9     # [BK]

    m_prev = m_ref[0, 0]
    l_prev = l_ref[0, 0]
    m_cur = jnp.max(s)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                       # [BK]
    corr = jnp.exp(m_prev - m_new)
    l_ref[0, 0] = corr * l_prev + jnp.sum(p)
    o_ref[0, :] = corr * o_ref[0, :] + p @ v
    m_ref[0, 0] = m_new


@functools.partial(jax.jit, static_argnames=("block_k",))
def attn_decode(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
                block_k: int | None = None) -> jax.Array:
    """Single-token decode attention.

    Args:
      q:    [H, Dh]      query at the current position.
      k, v: [H, S, Dh]   the full (padded) KV cache.
      mask: [S]          1.0 where the cache slot is valid (pos <= current).
      block_k: KV tile length; must divide S. Auto-picked when None.

    Returns:
      [H, Dh] attention output, in q's dtype.
    """
    h, dh = q.shape
    _, s, _ = k.shape
    bk = block_k or _pick_block_k(s)
    assert s % bk == 0, f"block_k={bk} must divide seq_len={s}"
    scale = 1.0 / (dh ** 0.5)

    grid = (h, s // bk)
    out, m, l = pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, dh), lambda hh, kb: (hh, 0)),        # q
            pl.BlockSpec((1, bk, dh), lambda hh, kb: (hh, kb, 0)),  # k tile
            pl.BlockSpec((1, bk, dh), lambda hh, kb: (hh, kb, 0)),  # v tile
            pl.BlockSpec((bk,), lambda hh, kb: (kb,)),            # mask tile
        ],
        out_specs=[
            pl.BlockSpec((1, dh), lambda hh, kb: (hh, 0)),        # o (revisited)
            pl.BlockSpec((1, 1), lambda hh, kb: (hh, 0)),         # m
            pl.BlockSpec((1, 1), lambda hh, kb: (hh, 0)),         # l
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, dh), jnp.float32),
            jax.ShapeDtypeStruct((h, 1), jnp.float32),
            jax.ShapeDtypeStruct((h, 1), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, mask)
    return (out / l).astype(q.dtype)
