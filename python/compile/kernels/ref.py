"""Pure-jnp oracles for the L1 Pallas kernels (the correctness reference)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attn_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                    mask: jax.Array) -> jax.Array:
    """Reference single-query attention.

    q: [H, Dh]; k, v: [H, S, Dh]; mask: [S] (1 valid / 0 pad).
    """
    _, dh = q.shape
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = 1.0 / (dh ** 0.5)
    s = jnp.einsum("hd,hsd->hs", qf, kf) * scale          # [H, S]
    s = s + (mask.astype(jnp.float32) - 1.0) * 1e9
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("hs,hsd->hd", p, vf)
    return out.astype(q.dtype)


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * w.astype(jnp.float32)).astype(x.dtype)
