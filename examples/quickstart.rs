//! Quickstart: load one picoLM variant via the PJRT runtime and answer a
//! benchmark question end-to-end (prefill -> KV-cached decode -> text).
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use pice::corpus::Corpus;
use pice::runtime::{Generator, LoadedModel, RuntimeHandle, SamplingParams};
use pice::sketch::Prompts;
use pice::tokenizer::Tokenizer;

fn main() -> Result<()> {
    let art = pice::artifacts_dir();
    let tok = Tokenizer::from_file(&art.join("vocab.json")).map_err(anyhow::Error::msg)?;
    let corpus =
        Corpus::from_file(&art.join("corpus.json"), &tok).map_err(anyhow::Error::msg)?;

    let rt = RuntimeHandle::cpu()?;
    let model = LoadedModel::load(rt, &art.join("models/qwen7b-sim"))?;
    println!(
        "loaded {} — d_model={} layers={} params={}",
        model.art.name, model.art.d_model, model.art.n_layers, model.art.n_params
    );

    let q = corpus.eval_questions()[0];
    println!("\nQ: {}", tok.decode(&q.question));

    let gen = Generator::new(&model, tok.specials.eos);
    let t0 = std::time::Instant::now();
    let out = gen.generate(
        &Prompts::full_answer(&tok, &q.question),
        &SamplingParams { max_tokens: 80, ..Default::default() },
    )?;
    let dt = t0.elapsed();

    println!("A: {}", tok.decode_content(&out.tokens));
    println!(
        "\n{} tokens in {:.0} ms ({:.0} tok/s), mean logp {:.2}",
        out.tokens.len(),
        dt.as_secs_f64() * 1e3,
        out.tokens.len() as f64 / dt.as_secs_f64(),
        out.logps.iter().sum::<f64>() / out.logps.len().max(1) as f64
    );
    println!("reference: {}", tok.decode_content(&q.answer_tokens()));
    Ok(())
}
