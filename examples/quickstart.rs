//! Quickstart: one request end-to-end through the online serving API —
//! submit, stream the progressive response events, read the final trace.
//!
//! Runs against the real PJRT picoLM artifacts when present, otherwise the
//! deterministic surrogate backend (so `PICE_BACKEND=surrogate cargo run
//! --release --example quickstart` works in any environment):
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use pice::baselines;
use pice::scenario::Env;
use pice::serve::{ResponseEventKind, ServeCfg};

fn main() -> Result<(), String> {
    let mut env = Env::load()?;
    println!(
        "backend: {}\n",
        if env.real { "REAL (PJRT picoLM)" } else { "surrogate" }
    );
    let corpus = env.corpus.clone();
    let q = corpus.eval_questions()[0];
    let qid = q.id;
    println!("Q: {}\n", env.tok.decode(&q.question));
    let reference = env.tok.decode_content(&q.answer_tokens());

    // open a serving session: one request, arriving at t=0
    let mut svc = env.service(baselines::pice("llama70b-sim"), ServeCfg::default())
        .map_err(|e| e.to_string())?;
    let h = svc.submit(qid, 0.0).map_err(|e| e.to_string())?;
    svc.pump_all().map_err(|e| e.to_string())?;

    println!("response event stream (simulated time):");
    let mut final_trace = None;
    while let Some(ev) = svc.poll(&h) {
        match ev.kind {
            ResponseEventKind::Admitted { mode } => {
                println!("  [t={:6.2}s] admitted, mode {mode:?}", ev.t)
            }
            ResponseEventKind::SketchReady { text } => {
                println!("  [t={:6.2}s] sketch ready: {text}", ev.t)
            }
            ResponseEventKind::ExpansionChunk { slot, text } => {
                println!("  [t={:6.2}s] expansion #{slot}: {text}", ev.t)
            }
            ResponseEventKind::Final { trace } => {
                println!("  [t={:6.2}s] final answer selected", ev.t);
                final_trace = Some(trace);
            }
            ResponseEventKind::Rejected { reason } => {
                println!("  [t={:6.2}s] rejected: {reason}", ev.t)
            }
        }
    }
    let traces = svc.finish().map_err(|e| e.to_string())?;
    let t = final_trace.or_else(|| traces.into_iter().next()).ok_or("no trace")?;

    println!("\nA: {}", env.tok.decode_content(&t.answer));
    match t.ttfs() {
        Some(ttfs) => println!(
            "\nfirst sketch after {ttfs:.2} sim-s, final after {:.2} sim-s \
             (early response at {:.0}% of e2e latency)",
            t.latency(),
            100.0 * ttfs / t.latency().max(1e-9)
        ),
        None => println!("\nserved as a full answer in {:.2} sim-s", t.latency()),
    }
    println!("winner: {} | cloud {} + edge {} sim tokens",
        if t.winner_model.is_empty() { "cloud".to_string() } else { t.winner_model.clone() },
        t.cloud_tokens,
        t.edge_tokens
    );
    println!("reference: {reference}");
    Ok(())
}
