//! SLO explorer: how the lexicographic objective ordering (paper §IV-A1)
//! changes the operating point — error-led vs throughput-led vs cost-led.
//!
//! ```sh
//! cargo run --release --example slo_explorer        # real backend
//! PICE_BACKEND=surrogate cargo run --release --example slo_explorer
//! ```

use pice::baselines;
use pice::coordinator::slo::Metric;
use pice::quality::judge::Judge;
use pice::scenario::Env;
use pice::util::stats;

fn main() -> Result<(), String> {
    let cloud_model = "llama70b-sim";
    let mut env = Env::load()?;
    let judge = Judge::fit(&env.corpus);
    let rpm = env.paper_rpm(cloud_model);
    let wl = env.workload(rpm, 48, 3);

    let orderings: Vec<(&str, Vec<Metric>)> = vec![
        ("throughput-led", vec![Metric::Throughput, Metric::Error, Metric::Latency, Metric::ServerCost, Metric::EdgeCost]),
        ("error-led", vec![Metric::Error, Metric::Latency, Metric::Throughput, Metric::ServerCost, Metric::EdgeCost]),
        ("server-cost-led", vec![Metric::ServerCost, Metric::Throughput, Metric::Error, Metric::Latency, Metric::EdgeCost]),
        ("latency-led", vec![Metric::Latency, Metric::Throughput, Metric::Error, Metric::ServerCost, Metric::EdgeCost]),
    ];

    println!("cloud={cloud_model} rpm={rpm:.0} (SLA ordering sweep)\n");
    println!("{:<17} {:>10} {:>8} {:>9} {:>12} {:>6}", "ordering", "thpt(q/m)", "lat(s)", "quality", "server-tok", "prog");
    for (name, order) in orderings {
        let mut cfg = baselines::pice(cloud_model);
        cfg.scheduler.policy.order = order;
        let (m, traces) = env.run(cfg, &wl).map_err(|e| e.to_string())?;
        let scores: Vec<f64> = traces
            .iter()
            .filter_map(|t| env.corpus.get(t.question_id).map(|q| judge.score(q, &t.answer).overall))
            .collect();
        println!(
            "{:<17} {:>10.2} {:>8.2} {:>9.2} {:>12} {:>6}",
            name, m.throughput_qpm, m.avg_latency_s, stats::mean(&scores), m.server_tokens, m.n_progressive
        );
    }
    Ok(())
}
