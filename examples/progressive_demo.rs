//! Progressive inference as the client streams it: the cloud LLM's sketch
//! arrives early, edge SLM expansions stream in behind it, the ensemble
//! picks a winner — all observed through the serving API's per-request
//! response events rather than by calling the runtime layers directly.
//!
//! Works on the real PJRT artifacts or the surrogate backend:
//!
//! ```sh
//! cargo run --release --example progressive_demo
//! ```

use pice::baselines;
use pice::metrics::Mode;
use pice::scenario::Env;
use pice::serve::{RequestHandle, ResponseEvent, ResponseEventKind, ServeCfg};

fn main() -> Result<(), String> {
    let mut env = Env::load()?;
    println!(
        "backend: {}\n",
        if env.real { "REAL (PJRT picoLM)" } else { "surrogate" }
    );
    let corpus = env.corpus.clone();
    let questions: Vec<usize> = corpus.eval_questions().iter().map(|q| q.id).take(10).collect();

    // serve a small trickle so the scheduler sees realistic conditions
    let mut svc = env.service(baselines::pice("llama70b-sim"), ServeCfg::default())
        .map_err(|e| e.to_string())?;
    let mut handles: Vec<RequestHandle> = Vec::new();
    for (i, qid) in questions.iter().enumerate() {
        let arrival = i as f64 * 2.0;
        svc.pump_until(arrival).map_err(|e| e.to_string())?;
        handles.push(svc.submit(*qid, arrival).map_err(|e| e.to_string())?);
    }
    svc.pump_all().map_err(|e| e.to_string())?;

    // walk the streams; show the first session that went progressive
    let mut streams: Vec<Vec<ResponseEvent>> = Vec::new();
    for h in &handles {
        streams.push(svc.drain(h));
    }
    let traces = svc.finish().map_err(|e| e.to_string())?;

    let Some(star) = traces.iter().find(|t| t.mode == Mode::Progressive) else {
        println!(
            "(no request went progressive under this workload — \
             {} served, all full answers)",
            traces.len()
        );
        return Ok(());
    };
    let q = corpus.get(star.question_id).ok_or("question")?;
    println!("Q: {}\n", env.tok.decode(&q.question));
    println!("reference: {}\n", env.tok.decode_content(&q.answer_tokens()));

    println!("progressive delivery for request {} (sketch level {}):", star.rid, star.sketch_level);
    for ev in &streams[star.rid] {
        let dt = ev.t - star.arrival;
        match &ev.kind {
            ResponseEventKind::Admitted { mode } => println!(
                "  +{dt:6.2}s admitted ({mode:?}, predicted {} sim tokens)",
                star.predicted_len
            ),
            ResponseEventKind::SketchReady { text } => {
                println!("  +{dt:6.2}s cloud sketch : {text}")
            }
            ResponseEventKind::ExpansionChunk { slot, text } => {
                println!("  +{dt:6.2}s expansion #{slot}: {text}")
            }
            ResponseEventKind::Final { trace } => println!(
                "  +{dt:6.2}s FINAL (winner {}, confidence {:.2}, {} parallel lanes)",
                trace.winner_model,
                trace.confidence,
                trace.parallelism.max(1)
            ),
            ResponseEventKind::Rejected { reason } => println!("  +{dt:6.2}s rejected: {reason}"),
        }
    }
    println!("\nfinal progressive answer: {}", env.tok.decode_content(&star.answer));
    if let (Some(ttfs), latency) = (star.ttfs(), star.latency()) {
        println!(
            "sketch streamed after {ttfs:.2} sim-s of a {latency:.2} sim-s response \
             ({:.0}% early)",
            100.0 * (1.0 - ttfs / latency.max(1e-9))
        );
    }
    println!(
        "\nserved {} requests total, {} progressive",
        traces.len(),
        traces.iter().filter(|t| t.mode == Mode::Progressive).count()
    );
    Ok(())
}
