//! Progressive inference, step by step, on real models: the cloud LLM
//! writes a sketch, three edge SLMs expand each sketch sentence in
//! parallel, the ensemble picks the most confident expansion.
//!
//! ```sh
//! make artifacts && cargo run --release --example progressive_demo
//! ```

use anyhow::Result;
use pice::corpus::Corpus;
use pice::ensemble::{confidence, Candidate, ConfidenceWeights};
use pice::runtime::{Generator, LoadedModel, RuntimeHandle, SamplingParams};
use pice::sketch::{split_sketch, Prompts};
use pice::tokenizer::Tokenizer;

fn main() -> Result<()> {
    let art = pice::artifacts_dir();
    let tok = Tokenizer::from_file(&art.join("vocab.json")).map_err(anyhow::Error::msg)?;
    let corpus =
        Corpus::from_file(&art.join("corpus.json"), &tok).map_err(anyhow::Error::msg)?;
    let rt = RuntimeHandle::cpu()?;

    let cloud = LoadedModel::load(rt.clone(), &art.join("models/llama70b-sim"))?;
    let slm_names = ["llama8b-sim", "qwen7b-sim", "qwen1.5b-sim"];
    let slms: Vec<LoadedModel> = slm_names
        .iter()
        .map(|n| LoadedModel::load(rt.clone(), &art.join("models").join(n)))
        .collect::<Result<_>>()?;

    let q = corpus.eval_questions()[7];
    println!("Q: {}\n", tok.decode(&q.question));
    println!("reference: {}\n", tok.decode_content(&q.answer_tokens()));

    // 1) cloud LLM generates the sketch
    let cloud_gen = Generator::new(&cloud, tok.specials.eos);
    let sk_out = cloud_gen.generate(
        &Prompts::sketch(&tok, &q.question),
        &SamplingParams { max_tokens: 60, ..Default::default() },
    )?;
    let mut sketch = sk_out.tokens.clone();
    sketch.retain(|&t| t != tok.specials.eos);
    println!("cloud sketch ({} tokens): {}\n", sketch.len(), tok.decode(&sketch));

    // 2) edge SLMs expand each sketch sentence independently (parallel lanes
    //    on the testbed; sequential here for clarity)
    let sentences = split_sketch(&sketch, tok.specials.semicolon);
    let w = ConfidenceWeights::default();
    let mut final_answer: Vec<u32> = Vec::new();
    for (si, sent) in sentences.iter().enumerate() {
        println!("sentence {si}: [{}]", tok.decode(sent));
        let mut cands = Vec::new();
        for (name, slm) in slm_names.iter().zip(&slms) {
            let g = Generator::new(slm, tok.specials.eos);
            let out = g.generate(
                &Prompts::expand(&tok, &q.question, &sketch, sent),
                &SamplingParams {
                    max_tokens: 24,
                    stop_token: Some(tok.specials.period),
                    ..Default::default()
                },
            )?;
            let mut toks = out.tokens.clone();
            toks.retain(|&t| t != tok.specials.eos);
            let cand = Candidate { model: name.to_string(), tokens: toks, logps: out.logps };
            let con = confidence(&cand, sent, sent.len() * 2, w);
            println!("  {name:<14} con={con:.3}  {}", tok.decode(&cand.tokens));
            cands.push((con, cand));
        }
        // 3) ensemble selection
        let (con, best) = cands
            .into_iter()
            .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
            .unwrap();
        println!("  -> winner: {} ({con:.3})\n", best.model);
        final_answer.extend(best.tokens);
    }
    println!("final progressive answer: {}", tok.decode_content(&final_answer));
    Ok(())
}
