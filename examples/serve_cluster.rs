//! End-to-end serving driver (the repo's headline validation run).
//!
//! Loads the real picoLM artifacts, serves a batched Poisson workload
//! through the full PICE stack (dynamic scheduler -> sketch on the cloud
//! LLM -> multi-list dispatch -> edge SLM expansion with the execution
//! optimizer -> ensemble selection) and through the three baselines, then
//! reports throughput, latency and judge quality. Results are recorded in
//! EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_cluster [rpm] [n]
//! ```

use pice::metrics::Mode;
use pice::quality::judge::Judge;
use pice::scenario::Env;
use pice::util::stats;

fn main() -> Result<(), String> {
    let rpm: Option<f64> = std::env::args().nth(1).and_then(|x| x.parse().ok());
    let n: usize = std::env::args().nth(2).and_then(|x| x.parse().ok()).unwrap_or(60);
    let cloud_model = "llama70b-sim";

    let mut env = Env::load()?;
    let rpm = rpm.unwrap_or_else(|| env.paper_rpm(cloud_model));
    println!(
        "backend: {} | cloud model: {cloud_model} | RPM {rpm:.0} | {n} requests | 4 edges\n",
        if env.real { "REAL (PJRT picoLM)" } else { "surrogate" }
    );

    let judge = Judge::fit(&env.corpus);
    println!(
        "{:<11} {:>10} {:>9} {:>9} {:>8} {:>12} {:>10} {:>8}",
        "system", "thpt(q/m)", "lat(s)", "p95(s)", "quality", "server-tok", "edge-tok", "prog"
    );
    let wall = std::time::Instant::now();
    for (name, result) in env.run_all_systems(cloud_model, rpm, n, 11) {
        match result {
            Err(e) => println!("{name:<11} {e}"),
            Ok((m, traces)) => {
                let scores: Vec<f64> = traces
                    .iter()
                    .filter_map(|t| {
                        env.corpus.get(t.question_id).map(|q| judge.score(q, &t.answer).overall)
                    })
                    .collect();
                println!(
                    "{:<11} {:>10.2} {:>9.2} {:>9.2} {:>8.2} {:>12} {:>10} {:>8}",
                    name,
                    m.throughput_qpm,
                    m.avg_latency_s,
                    m.p95_latency_s,
                    stats::mean(&scores),
                    m.server_tokens,
                    m.edge_tokens,
                    traces.iter().filter(|t| t.mode == Mode::Progressive).count(),
                );
            }
        }
    }
    println!("\n(real wall-clock for the whole comparison: {:.1}s)", wall.elapsed().as_secs_f64());
    Ok(())
}
