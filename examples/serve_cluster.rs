//! End-to-end serving driver (the repo's headline validation run), on the
//! online serving API: a Poisson workload is submitted *open-loop* through
//! [`PiceService`] — requests arrive while earlier ones are mid-flight — and
//! every request's progressive delivery is logged live (sketch latency vs
//! final latency), followed by the aggregate table for PICE and the three
//! baselines on the same workload.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_cluster [rpm] [n]
//! PICE_BACKEND=surrogate cargo run --release --example serve_cluster
//! ```

use pice::baselines;
use pice::metrics::Mode;
use pice::quality::judge::Judge;
use pice::scenario::Env;
use pice::serve::{ResponseEventKind, ServeCfg};
use pice::util::stats;

fn main() -> Result<(), String> {
    let rpm: Option<f64> = std::env::args().nth(1).and_then(|x| x.parse().ok());
    let n: usize = std::env::args().nth(2).and_then(|x| x.parse().ok()).unwrap_or(60);
    let cloud_model = "llama70b-sim";

    let mut env = Env::load()?;
    let rpm = rpm.unwrap_or_else(|| env.paper_rpm(cloud_model));
    println!(
        "backend: {} | cloud model: {cloud_model} | RPM {rpm:.0} | {n} requests | 4 edges\n",
        if env.real { "REAL (PJRT picoLM)" } else { "surrogate" }
    );
    let corpus = env.corpus.clone();
    let judge = Judge::fit(&corpus);
    let wl = env.workload(rpm, n, 11);

    let wall = std::time::Instant::now();
    let mut svc = env
        .service(baselines::pice(cloud_model), ServeCfg::default())
        .map_err(|e| e.to_string())?;

    // Open-loop submission with a live event log: each iteration pumps the
    // simulated cluster up to the next arrival and prints whatever streamed
    // in the meantime (global emission order via poll_any).
    println!("live per-request event log (sim time):");
    for r in &wl.requests {
        svc.pump_until(r.arrival_s).map_err(|e| e.to_string())?;
        log_pending(&mut svc);
        svc.submit(r.question_id, r.arrival_s).map_err(|e| e.to_string())?;
    }
    svc.pump_all().map_err(|e| e.to_string())?;
    log_pending(&mut svc);
    let traces = svc.finish().map_err(|e| e.to_string())?;

    // streaming percentiles of the open-loop PICE run
    let m = pice::metrics::aggregate(&traces);
    println!(
        "\nfirst sketch p50/p99: {:.2}/{:.2} s | first expansion p50/p99: {:.2}/{:.2} s",
        m.p50_ttfs_s, m.p99_ttfs_s, m.p50_ttfe_s, m.p99_ttfe_s
    );

    // The headline comparison: all four systems on the same workload (the
    // PICE row is bit-identical to the streamed open-loop run above — the
    // closed-loop-driver equivalence guarantee).
    println!(
        "\n{:<11} {:>10} {:>9} {:>9} {:>9} {:>8} {:>12} {:>10} {:>8}",
        "system", "thpt(q/m)", "lat(s)", "p95(s)", "ttfs-p50", "quality", "server-tok",
        "edge-tok", "prog"
    );
    for (name, result) in env.run_all_systems(cloud_model, rpm, n, 11) {
        match result {
            Err(e) => println!("{name:<11} {e}"),
            Ok((m, traces)) => {
                let scores: Vec<f64> = traces
                    .iter()
                    .filter_map(|t| {
                        corpus.get(t.question_id).map(|q| judge.score(q, &t.answer).overall)
                    })
                    .collect();
                println!(
                    "{:<11} {:>10.2} {:>9.2} {:>9.2} {:>9.2} {:>8.2} {:>12} {:>10} {:>8}",
                    name,
                    m.throughput_qpm,
                    m.avg_latency_s,
                    m.p95_latency_s,
                    m.p50_ttfs_s,
                    stats::mean(&scores),
                    m.server_tokens,
                    m.edge_tokens,
                    traces.iter().filter(|t| t.mode == Mode::Progressive).count(),
                );
            }
        }
    }
    println!(
        "\n(real wall-clock for the whole comparison: {:.1}s)",
        wall.elapsed().as_secs_f64()
    );
    Ok(())
}

/// Print the newly streamed events: one compact line per event, showing the
/// progressive-delivery shape (sketch early, final later).
fn log_pending(svc: &mut pice::serve::PiceService<'_>) {
    while let Some(ev) = svc.poll_any() {
        match &ev.kind {
            ResponseEventKind::Admitted { mode } => {
                println!("  [t={:8.2}] req {:>3} admitted ({mode:?})", ev.t, ev.rid)
            }
            ResponseEventKind::SketchReady { .. } => {
                println!("  [t={:8.2}] req {:>3} sketch ready", ev.t, ev.rid)
            }
            ResponseEventKind::ExpansionChunk { slot, .. } => {
                println!("  [t={:8.2}] req {:>3} expansion #{slot}", ev.t, ev.rid)
            }
            ResponseEventKind::Final { trace } => println!(
                "  [t={:8.2}] req {:>3} FINAL: sketch after {} | final after {:.2}s",
                ev.t,
                ev.rid,
                match trace.ttfs() {
                    Some(s) => format!("{s:.2}s"),
                    None => "-".to_string(),
                },
                trace.latency()
            ),
            ResponseEventKind::Rejected { reason } => {
                println!("  [t={:8.2}] req {:>3} REJECTED: {reason}", ev.t, ev.rid)
            }
        }
    }
}
