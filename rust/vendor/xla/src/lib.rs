//! Offline stub of the XLA/PJRT Rust bindings.
//!
//! The build image carries no XLA shared libraries, so this vendored crate
//! provides the exact type/API surface `pice::runtime` compiles against
//! while every runtime entry point returns an "unavailable" error. All
//! real-backend paths in the workspace are gated on `artifacts/manifest.json`
//! existing, so the stub is never executed by the tier-1 tests or the
//! surrogate benches; linking a real PJRT build back in only requires
//! swapping this path dependency for the actual bindings.

use std::fmt;

/// Error type for every stub operation.
pub struct XlaError(pub String);

impl XlaError {
    fn unavailable(what: &str) -> XlaError {
        XlaError(format!("{what}: PJRT unavailable (offline xla stub build)"))
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, XlaError>;

/// Parsed HLO module (stub: never constructible at runtime).
pub struct HloModuleProto {
    _p: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(XlaError::unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation wrapper around an HLO module.
pub struct XlaComputation {
    _p: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _p: () }
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer {
    _p: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Host-side literal (stub: zero elements).
pub struct Literal {
    _p: (),
}

impl Literal {
    pub fn element_count(&self) -> usize {
        0
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(XlaError::unavailable("Literal::to_vec"))
    }

    pub fn copy_raw_to<T>(&self, _dst: &mut [T]) -> Result<()> {
        Err(XlaError::unavailable("Literal::copy_raw_to"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _p: (),
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed argument buffers; returns per-replica outputs.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _p: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(XlaError::unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e:?}").contains("unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
