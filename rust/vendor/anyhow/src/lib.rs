//! Offline shim of the `anyhow` crate: the build image has no crates.io
//! access, so this vendored crate provides the (small) API subset the rest
//! of the workspace uses — `Error`, `Result`, `anyhow!`, `bail!`, `Context`.
//!
//! Semantics match upstream for that subset: `Error` is an opaque,
//! `Display`/`Debug`-printable error value; `Context` prefixes the message.

use std::fmt;

/// Opaque error type carrying a rendered message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct an error from any displayable message (like `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

/// `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Attach context to an error, prefixing its message.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macro_forms() {
        let a: Error = anyhow!("plain");
        let n = 3;
        let b: Error = anyhow!("formatted {n} and {}", 4);
        let c: Error = anyhow!(String::from("from expr"));
        assert_eq!(a.to_string(), "plain");
        assert_eq!(b.to_string(), "formatted 3 and 4");
        assert_eq!(c.to_string(), "from expr");
        assert_eq!(format!("{c:?}"), "from expr");
    }

    #[test]
    fn bail_returns() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("boom {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "boom 7");
    }

    #[test]
    fn context_prefixes() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(e.to_string(), "ctx: inner");
    }
}
