//! Determinism of the scenario-sweep layer: running a grid of scenarios
//! through [`SweepRunner`] must produce byte-identical results to the
//! sequential `for` loop — at 1/2/4 sweep threads, with the shared
//! in-process cache enabled or disabled, and stacked on `PICE_WORKERS`
//! backend parallelism (each scenario's backend itself a worker pool).
//! Each scenario is a pure function of `(cfg, workload, seed)` and the
//! cache is transparent, so no interleaving may change a single byte.

use std::sync::Arc;

use pice::baselines;
use pice::coordinator::backend::{MemoBackend, ParallelBackend, SurrogateBackend, TextBackend};
use pice::coordinator::Engine;
use pice::corpus::synth::{synth_corpus, synth_tokenizer};
use pice::corpus::workload::{Arrival, Workload, WorkloadSpec};
use pice::corpus::Corpus;
use pice::metrics::RequestTrace;
use pice::models::Registry;
use pice::sweep::{ScenarioResult, SharedMemoCache, SweepRunner, SweepScenario};
use pice::tokenizer::Tokenizer;

fn setup() -> (Arc<Corpus>, Tokenizer, Registry) {
    let tok = synth_tokenizer();
    let corpus = Arc::new(synth_corpus(&tok, 20, 42));
    let reg = Registry::builtin();
    (corpus, tok, reg)
}

fn workload(corpus: &Arc<Corpus>, n: usize, seed: u64) -> Arc<Workload> {
    Arc::new(Workload::generate(
        corpus,
        WorkloadSpec {
            rpm: 40.0,
            n_requests: n,
            arrival: Arrival::Poisson,
            categories: vec![],
            seed,
        },
    ))
}

/// A mixed grid: shared workload across policy variants (the cross-variant
/// cache case) plus distinct-seed/workload cells (the disjoint case).
fn grid(corpus: &Arc<Corpus>) -> Vec<SweepScenario> {
    let wl_a = workload(corpus, 30, 5);
    let wl_b = workload(corpus, 24, 9);
    let mut v = vec![
        SweepScenario::new("pice", baselines::pice("llama70b-sim"), wl_a.clone()),
        SweepScenario::new("cloud", baselines::cloud_only("llama70b-sim"), wl_a.clone()),
        SweepScenario::new("routing", baselines::routing("llama70b-sim"), wl_a.clone()),
    ];
    let mut tight = baselines::pice("llama70b-sim");
    tight.queue_cap = 2;
    v.push(SweepScenario::new("pice-q2", tight, wl_a));
    let mut reseeded = baselines::pice("qwen72b-sim");
    reseeded.seed = 1234;
    v.push(SweepScenario::new("pice-reseed", reseeded, wl_b.clone()));
    let mut stat = baselines::pice("llama70b-sim");
    stat.scheduler.static_mode = true;
    v.push(SweepScenario::new("pice-static", stat, wl_b));
    v
}

/// The reference semantics: a plain sequential loop, one fresh backend per
/// scenario, no sweep machinery at all.
fn sequential_loop(
    scenarios: &[SweepScenario],
    corpus: &Arc<Corpus>,
    tok: &Tokenizer,
    reg: &Registry,
    base: &SurrogateBackend,
) -> Vec<ScenarioResult> {
    scenarios
        .iter()
        .map(|sc| {
            let mut backend = base.clone();
            let mut engine =
                Engine::new(sc.cfg.clone(), corpus.clone(), tok, reg, &mut backend)?;
            let traces = engine.run(&sc.workload)?;
            Ok((pice::metrics::aggregate(&traces), traces))
        })
        .collect()
}

fn assert_traces_identical(label: &str, a: &[RequestTrace], b: &[RequestTrace]) {
    assert_eq!(a.len(), b.len(), "{label}: trace count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.rid, y.rid, "{label}: rid");
        assert_eq!(x.mode, y.mode, "{label}: mode rid={}", x.rid);
        assert_eq!(x.answer, y.answer, "{label}: answer rid={}", x.rid);
        assert_eq!(x.winner_model, y.winner_model, "{label}: winner rid={}", x.rid);
        assert_eq!(x.cloud_tokens, y.cloud_tokens, "{label}: cloud tokens rid={}", x.rid);
        assert_eq!(x.edge_tokens, y.edge_tokens, "{label}: edge tokens rid={}", x.rid);
        assert_eq!(x.sketch_level, y.sketch_level, "{label}: level rid={}", x.rid);
        assert_eq!(x.parallelism, y.parallelism, "{label}: parallelism rid={}", x.rid);
        assert!(x.done == y.done, "{label}: done time rid={}", x.rid);
        assert!(x.confidence == y.confidence, "{label}: confidence rid={}", x.rid);
    }
}

fn assert_results_identical(label: &str, a: &[ScenarioResult], b: &[ScenarioResult]) {
    assert_eq!(a.len(), b.len(), "{label}: result count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        match (x, y) {
            (Ok((ma, ta)), Ok((mb, tb))) => {
                assert_traces_identical(&format!("{label} scenario {i}"), ta, tb);
                assert!(ma.throughput_qpm == mb.throughput_qpm, "{label} {i}: thpt");
                assert!(ma.avg_latency_s == mb.avg_latency_s, "{label} {i}: latency");
                assert_eq!(ma.server_tokens, mb.server_tokens, "{label} {i}: server tokens");
                assert_eq!(ma.edge_tokens, mb.edge_tokens, "{label} {i}: edge tokens");
            }
            (Err(ea), Err(eb)) => {
                assert_eq!(ea.to_string(), eb.to_string(), "{label} {i}: error text")
            }
            _ => panic!("{label} {i}: Ok/Err mismatch"),
        }
    }
}

#[test]
fn sweep_bit_identical_to_sequential_loop_at_any_thread_count() {
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let scenarios = grid(&corpus);
    let reference = sequential_loop(&scenarios, &corpus, &tok, &reg, &base);
    assert!(reference.iter().all(|r| r.is_ok()));
    for threads in [1usize, 2, 4] {
        let got = SweepRunner::new(threads).run(&scenarios, &corpus, &tok, &reg, |_| {
            Box::new(base.clone()) as Box<dyn TextBackend>
        });
        assert_results_identical(&format!("{threads} threads, no cache"), &reference, &got);
    }
}

#[test]
fn shared_cache_is_transparent_and_produces_cross_variant_hits() {
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let scenarios = grid(&corpus);
    let reference = sequential_loop(&scenarios, &corpus, &tok, &reg, &base);
    for threads in [1usize, 2, 4] {
        let cache = Arc::new(SharedMemoCache::new(1 << 15));
        let got = SweepRunner::new(threads).run(&scenarios, &corpus, &tok, &reg, |i| {
            Box::new(MemoBackend::shared(base.clone(), cache.clone(), i as u32))
                as Box<dyn TextBackend>
        });
        assert_results_identical(&format!("{threads} threads, shared cache"), &reference, &got);
        let s = cache.stats();
        assert!(s.hits > 0, "{threads} threads: no cache hits at all");
        assert!(
            s.cross_hits > 0,
            "{threads} threads: policy variants over one workload must share generations"
        );
    }
}

#[test]
fn sweep_stacks_on_backend_worker_pools() {
    // each scenario's backend is itself a 2-worker ParallelBackend (the
    // PICE_WORKERS layer), under a shared memo handle — sweep threads on
    // top must still be bit-identical
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let scenarios = grid(&corpus);
    let reference = sequential_loop(&scenarios, &corpus, &tok, &reg, &base);
    let cache = Arc::new(SharedMemoCache::new(1 << 15));
    let got = SweepRunner::new(2).run(&scenarios, &corpus, &tok, &reg, |i| {
        let pool = ParallelBackend::new(2, |_| base.clone());
        Box::new(MemoBackend::shared(pool, cache.clone(), i as u32)) as Box<dyn TextBackend>
    });
    assert_results_identical("sweep x2 over workers x2", &reference, &got);
}

#[test]
fn results_arrive_in_submission_order() {
    // scenarios with distinct workload sizes: slot i must hold scenario
    // i's result regardless of which thread finished first
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let sizes = [6usize, 18, 10, 26, 8, 14];
    let scenarios: Vec<SweepScenario> = sizes
        .iter()
        .enumerate()
        .map(|(i, &n)| {
            SweepScenario::new(
                format!("n{n}"),
                baselines::pice("llama70b-sim"),
                workload(&corpus, n, 100 + i as u64),
            )
        })
        .collect();
    let got = SweepRunner::new(4).run(&scenarios, &corpus, &tok, &reg, |_| {
        Box::new(base.clone()) as Box<dyn TextBackend>
    });
    for (i, (res, &n)) in got.iter().zip(&sizes).enumerate() {
        let (m, traces) = res.as_ref().expect("scenario ok");
        assert_eq!(traces.len(), n, "slot {i} holds the wrong scenario");
        assert_eq!(m.n_requests, n, "slot {i} metrics mismatch");
    }
}

#[test]
fn runner_reports_infeasible_scenarios_in_place() {
    // an OOM cell (cloud model too big for an edge in edge-only mode) must
    // land as Err in its own slot without poisoning the rest
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let wl = workload(&corpus, 8, 3);
    let scenarios = vec![
        SweepScenario::new("ok", baselines::pice("llama70b-sim"), wl.clone()),
        SweepScenario::new("oom", baselines::edge_only("llama70b-sim"), wl.clone()),
        SweepScenario::new("ok2", baselines::cloud_only("llama70b-sim"), wl),
    ];
    let got = SweepRunner::new(2).run(&scenarios, &corpus, &tok, &reg, |_| {
        Box::new(base.clone()) as Box<dyn TextBackend>
    });
    assert!(got[0].is_ok());
    assert!(got[1].is_err(), "edge-only 70B must OOM on a Jetson");
    assert!(got[2].is_ok());
}
