//! The dynamics subsystem's two contracts, end to end:
//!
//! 1. **Determinism** — a churn-heavy scenario (edge crash/recover +
//!    stragglers + a fluctuating WAN) produces bit-identical traces across
//!    the sequential loop, the SweepRunner at 1/2/4 threads, and open-loop
//!    service driving vs the closed-loop `Engine::run`.
//! 2. **No lost requests** — under repeated edge crashes (including every
//!    edge down at once, with and without scheduled recovery) every
//!    submitted request still reaches exactly one terminal state, with
//!    `failovers` accounting for the displaced work.

use std::collections::HashSet;
use std::sync::Arc;

use pice::baselines;
use pice::coordinator::backend::{SurrogateBackend, TextBackend};
use pice::coordinator::{Engine, EngineCfg};
use pice::corpus::synth::{synth_corpus, synth_tokenizer};
use pice::corpus::workload::{Arrival, Workload, WorkloadSpec};
use pice::corpus::Corpus;
use pice::dynamics::{DynamicsSpec, EdgeEvent, EdgeFault, FaultSpec};
use pice::metrics::{aggregate, RequestTrace};
use pice::models::Registry;
use pice::serve::{PiceService, ServeCfg};
use pice::sweep::{SweepRunner, SweepScenario};
use pice::tokenizer::Tokenizer;

fn setup() -> (Arc<Corpus>, Tokenizer, Registry) {
    let tok = synth_tokenizer();
    let corpus = Arc::new(synth_corpus(&tok, 20, 42));
    (corpus, tok, Registry::builtin())
}

fn workload(
    corpus: &Arc<Corpus>,
    rpm: f64,
    n: usize,
    arrival: Arrival,
    seed: u64,
) -> Arc<Workload> {
    Arc::new(Workload::generate(
        corpus,
        WorkloadSpec { rpm, n_requests: n, arrival, categories: vec![], seed },
    ))
}

/// Dense staggered churn: each edge of 4 cycles down-2s/up-14s, covering
/// sim time 1..~240 s — any in-flight expansion in that window dies at
/// least once.
fn dense_churn() -> DynamicsSpec {
    let mut events = Vec::new();
    for k in 0..60usize {
        let t = 1.0 + 4.0 * k as f64;
        events.push(EdgeEvent { t, eid: k % 4, fault: EdgeFault::Crash });
        events.push(EdgeEvent { t: t + 2.0, eid: k % 4, fault: EdgeFault::Recover });
    }
    DynamicsSpec {
        faults: FaultSpec { events, ..Default::default() },
        seed: 7,
        ..Default::default()
    }
}

/// The churn-heavy composite: edge-churn faults + flaky-wan link.
fn churn_heavy() -> DynamicsSpec {
    let churn = DynamicsSpec::preset("edge-churn").unwrap();
    let flaky = DynamicsSpec::preset("flaky-wan").unwrap();
    DynamicsSpec { link: flaky.link, faults: churn.faults, seed: 23 }
}

fn run_closed_loop(
    cfg: &EngineCfg,
    wl: &Workload,
    corpus: &Arc<Corpus>,
    tok: &Tokenizer,
    reg: &Registry,
) -> Vec<RequestTrace> {
    let mut backend = SurrogateBackend::new(corpus.clone(), tok, reg, 9);
    let mut engine =
        Engine::new(cfg.clone(), corpus.clone(), tok, reg, &mut backend).expect("engine");
    engine.run(wl).expect("run")
}

/// Every field, via the Debug form (covers failovers/retried_slots too).
fn assert_identical(label: &str, a: &[RequestTrace], b: &[RequestTrace]) {
    assert_eq!(a.len(), b.len(), "{label}: trace count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(format!("{x:?}"), format!("{y:?}"), "{label}: trace rid={}", x.rid);
    }
}

fn assert_exactly_one_terminal_each(label: &str, traces: &[RequestTrace], n: usize) {
    assert_eq!(traces.len(), n, "{label}: requests lost or duplicated");
    let rids: HashSet<usize> = traces.iter().map(|t| t.rid).collect();
    assert_eq!(rids.len(), n, "{label}: duplicate terminal traces");
    for t in traces {
        assert!(t.done >= t.arrival, "{label}: negative latency rid={}", t.rid);
        assert!(!t.answer.is_empty(), "{label}: empty answer rid={}", t.rid);
    }
}

#[test]
fn no_request_lost_under_repeated_edge_crashes() {
    let (corpus, tok, reg) = setup();
    let cfg = baselines::pice("llama70b-sim").with_dynamics(dense_churn());
    // burst: all 30 requests at t=0, so expansions saturate the edges while
    // the churn schedule kills each edge over and over
    let wl = workload(&corpus, 40.0, 30, Arrival::Burst, 3);
    let traces = run_closed_loop(&cfg, &wl, &corpus, &tok, &reg);
    assert_exactly_one_terminal_each("dense churn", &traces, 30);
    let m = aggregate(&traces);
    assert!(
        m.failovers > 0,
        "240 s of staggered crashes over a saturated burst must displace work"
    );
    assert!(m.p99_degraded_latency_s > 0.0, "failover survivors must feed degraded percentiles");
}

#[test]
fn edge_only_full_answers_survive_crashes() {
    let (corpus, tok, reg) = setup();
    // llama8b fits a Jetson, so the edge-only baseline actually runs
    let cfg = baselines::edge_only("llama8b-sim").with_dynamics(dense_churn());
    let wl = workload(&corpus, 30.0, 20, Arrival::Burst, 5);
    let traces = run_closed_loop(&cfg, &wl, &corpus, &tok, &reg);
    assert_exactly_one_terminal_each("edge-only churn", &traces, 20);
}

#[test]
fn all_edges_down_forever_falls_back_to_cloud() {
    let (corpus, tok, reg) = setup();
    // both edges die at t=1 and never recover: progressive requests must
    // terminate via the cloud instead of stranding in the job queue
    let spec = DynamicsSpec {
        faults: FaultSpec {
            events: vec![
                EdgeEvent { t: 1.0, eid: 0, fault: EdgeFault::Crash },
                EdgeEvent { t: 1.0, eid: 1, fault: EdgeFault::Crash },
            ],
            ..Default::default()
        },
        seed: 1,
        ..Default::default()
    };
    let mut cfg = baselines::pice("llama70b-sim").with_dynamics(spec);
    cfg.n_edges = 2;
    let wl = workload(&corpus, 40.0, 8, Arrival::Burst, 9);
    let traces = run_closed_loop(&cfg, &wl, &corpus, &tok, &reg);
    assert_exactly_one_terminal_each("permanent blackout", &traces, 8);
    // whatever went progressive was rescued by the cloud and marked failed-over
    for t in traces.iter().filter(|t| t.failovers > 0) {
        assert!(
            t.winner_model.contains("llama70b") || t.retried_slots > 0,
            "rescued rid={} should carry a cloud answer or re-queued slots, got winner `{}`",
            t.rid,
            t.winner_model
        );
    }
    let m = aggregate(&traces);
    assert!(m.failovers > 0, "a permanent blackout at t=1 must displace sketched work");
}

#[test]
fn churn_heavy_traces_identical_at_1_2_4_sweep_threads() {
    let (corpus, tok, reg) = setup();
    let wl = workload(&corpus, 40.0, 24, Arrival::Poisson, 5);
    let bursty =
        workload(&corpus, 40.0, 18, Arrival::BurstyPoisson { burst_factor: 4.0, burst_len: 6 }, 7);
    let pice = || baselines::pice("llama70b-sim").with_dynamics(churn_heavy());
    let cloud = baselines::cloud_only("llama70b-sim").with_dynamics(churn_heavy());
    let routing = baselines::routing("llama70b-sim").with_dynamics(churn_heavy());
    let grid = vec![
        SweepScenario::new("pice-churn", pice(), wl.clone()),
        SweepScenario::new("cloud-churn", cloud, wl.clone()),
        SweepScenario::new("routing-churn", routing, wl),
        SweepScenario::new("pice-bursty", pice(), bursty),
    ];
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    // reference: plain sequential loop, no sweep machinery
    let reference: Vec<Vec<RequestTrace>> = grid
        .iter()
        .map(|sc| run_closed_loop(&sc.cfg, &sc.workload, &corpus, &tok, &reg))
        .collect();
    for threads in [1usize, 2, 4] {
        let runner = SweepRunner::new(threads);
        let results = runner.run(&grid, &corpus, &tok, &reg, |_| {
            Box::new(base.clone()) as Box<dyn TextBackend>
        });
        for (i, res) in results.into_iter().enumerate() {
            let (_, traces) = res.expect("scenario");
            let label = format!("{} @{} threads", grid[i].label, threads);
            assert_identical(&label, &reference[i], &traces);
        }
    }
}

#[test]
fn churn_open_loop_service_identical_to_closed_loop() {
    let (corpus, tok, reg) = setup();
    let cfg = baselines::pice("llama70b-sim").with_dynamics(churn_heavy());
    let wl = workload(&corpus, 40.0, 20, Arrival::Poisson, 11);
    let closed = run_closed_loop(&cfg, &wl, &corpus, &tok, &reg);
    let mut backend = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let engine =
        Engine::new(cfg.clone(), corpus.clone(), &tok, &reg, &mut backend).expect("engine");
    let mut svc =
        PiceService::new(engine, ServeCfg { max_inflight: usize::MAX, deadline_s: None });
    for r in &wl.requests {
        svc.pump_until(r.arrival_s).expect("pump");
        svc.submit(r.question_id, r.arrival_s).expect("submit");
    }
    let open = svc.finish().expect("finish");
    assert_identical("open vs closed loop under churn", &closed, &open);
}

#[test]
fn static_default_has_no_failovers_and_matches_stable_preset() {
    let (corpus, tok, reg) = setup();
    let wl = workload(&corpus, 40.0, 20, Arrival::Poisson, 13);
    let plain = run_closed_loop(&baselines::pice("llama70b-sim"), &wl, &corpus, &tok, &reg);
    let stable = run_closed_loop(
        &baselines::pice("llama70b-sim")
            .with_dynamics(DynamicsSpec::preset("stable").unwrap()),
        &wl,
        &corpus,
        &tok,
        &reg,
    );
    assert_identical("stable preset vs default", &plain, &stable);
    for t in &plain {
        assert_eq!(t.failovers, 0, "static world must never fail over");
        assert_eq!(t.retried_slots, 0);
    }
    let m = aggregate(&plain);
    assert_eq!(m.failovers, 0);
    assert_eq!(m.p99_degraded_latency_s, 0.0);
}

#[test]
fn slo_deadline_rejects_infeasible_but_leaves_feasible_untouched() {
    let (corpus, tok, reg) = setup();
    let cfg = baselines::pice("llama70b-sim");
    let wl = workload(&corpus, 40.0, 16, Arrival::Poisson, 17);
    let closed = run_closed_loop(&cfg, &wl, &corpus, &tok, &reg);

    // a generous deadline admits everything: traces identical to no-SLO
    let mut backend = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let engine =
        Engine::new(cfg.clone(), corpus.clone(), &tok, &reg, &mut backend).expect("engine");
    let mut svc = PiceService::new(
        engine,
        ServeCfg { max_inflight: usize::MAX, deadline_s: Some(1e6) },
    );
    for r in &wl.requests {
        svc.pump_until(r.arrival_s).expect("pump");
        svc.submit(r.question_id, r.arrival_s).expect("submit");
    }
    assert_eq!(svc.rejected(), 0, "feasible requests must be unaffected by the SLO gate");
    let open = svc.finish().expect("finish");
    assert_identical("SLO generous deadline", &closed, &open);

    // an impossible deadline (below even one sketch transfer) rejects all
    let mut backend = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let engine =
        Engine::new(cfg.clone(), corpus.clone(), &tok, &reg, &mut backend).expect("engine");
    let mut svc = PiceService::new(
        engine,
        ServeCfg { max_inflight: usize::MAX, deadline_s: Some(1e-9) },
    );
    let h = svc.submit(0, 0.0).expect("submit");
    assert!(svc.is_terminal(&h), "infeasible submission must terminate immediately");
    match svc.poll(&h).expect("terminal event").kind {
        pice::serve::ResponseEventKind::Rejected { reason } => {
            assert!(reason.contains("infeasible"), "reason must say infeasible: {reason}");
        }
        other => panic!("expected Rejected, got {other:?}"),
    }
    assert_eq!(svc.rejected(), 1);
    let traces = svc.finish().expect("finish");
    assert!(traces.is_empty(), "rejected submissions never reach the engine");
}
