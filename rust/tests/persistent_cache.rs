//! Cross-process persistence of the generation cache: replaying a scenario
//! through a *fresh* [`PersistentMemoBackend`] over the same snapshot file
//! must produce bit-identical request traces to the cold-cache run, with a
//! nonzero cross-process hit rate — at every worker-pool size, since the
//! cache and the pool are both pure execution-substrate layers.

use std::path::PathBuf;
use std::sync::Arc;

use pice::baselines;
use pice::coordinator::backend::{
    ParallelBackend, PersistentMemoBackend, SurrogateBackend, TextBackend,
};
use pice::coordinator::Engine;
use pice::corpus::synth::{synth_corpus, synth_tokenizer};
use pice::corpus::workload::{Arrival, Workload, WorkloadSpec};
use pice::corpus::Corpus;
use pice::metrics::RequestTrace;
use pice::models::Registry;
use pice::scenario;
use pice::tokenizer::Tokenizer;

fn setup() -> (Arc<Corpus>, Tokenizer, Registry, Workload) {
    let tok = synth_tokenizer();
    let corpus = Arc::new(synth_corpus(&tok, 20, 42));
    let reg = Registry::builtin();
    let wl = Workload::generate(
        &corpus,
        WorkloadSpec {
            rpm: 40.0,
            n_requests: 40,
            arrival: Arrival::Poisson,
            categories: vec![],
            seed: 5,
        },
    );
    (corpus, tok, reg, wl)
}

fn run_with(
    backend: &mut dyn TextBackend,
    corpus: &Arc<Corpus>,
    tok: &Tokenizer,
    reg: &Registry,
    wl: &Workload,
) -> Vec<RequestTrace> {
    let cfg = baselines::pice("llama70b-sim");
    let mut engine = Engine::new(cfg, corpus.clone(), tok, reg, backend).unwrap();
    engine.run(wl).unwrap()
}

fn assert_traces_identical(label: &str, a: &[RequestTrace], b: &[RequestTrace]) {
    assert_eq!(a.len(), b.len(), "{label}: trace count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.rid, y.rid, "{label}: rid");
        assert_eq!(x.mode, y.mode, "{label}: mode rid={}", x.rid);
        assert_eq!(x.answer, y.answer, "{label}: answer rid={}", x.rid);
        assert_eq!(x.winner_model, y.winner_model, "{label}: winner rid={}", x.rid);
        assert_eq!(x.cloud_tokens, y.cloud_tokens, "{label}: cloud tokens rid={}", x.rid);
        assert_eq!(x.edge_tokens, y.edge_tokens, "{label}: edge tokens rid={}", x.rid);
        assert_eq!(x.sketch_level, y.sketch_level, "{label}: level rid={}", x.rid);
        assert!((x.done - y.done).abs() < 1e-12, "{label}: done time rid={}", x.rid);
        assert!((x.confidence - y.confidence).abs() < 1e-12, "{label}: confidence rid={}", x.rid);
    }
}

fn tmp_cache(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("pice_persist_{}_{name}.json", std::process::id()))
}

#[test]
fn persisted_cache_replay_bit_identical_across_worker_counts() {
    let (corpus, tok, reg, wl) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, scenario::SURROGATE_SEED);
    let mut plain = base.clone();
    let reference = run_with(&mut plain, &corpus, &tok, &reg, &wl);
    assert!(!reference.is_empty());
    let stamp = scenario::surrogate_cache_stamp(&tok, &corpus, &reg, scenario::SURROGATE_SEED);
    let path = tmp_cache("engine_roundtrip");
    let _ = std::fs::remove_file(&path);

    // "process" 1: cold cache — populates and saves the snapshot
    {
        let mut cold = PersistentMemoBackend::load(base.clone(), 4096, &path, &stamp);
        assert_eq!(cold.restored_entries(), 0);
        let t = run_with(&mut cold, &corpus, &tok, &reg, &wl);
        assert_traces_identical("cold", &reference, &t);
        cold.save().unwrap();
    }
    // later "processes": fresh backend instances restore the snapshot and
    // must replay it — identically — over any worker-pool size
    for workers in [1usize, 2, 4] {
        let mut warm = PersistentMemoBackend::load(
            ParallelBackend::new(workers, |_| base.clone()),
            4096,
            &path,
            &stamp,
        );
        assert!(warm.restored_entries() > 0, "x{workers}: nothing restored");
        let t = run_with(&mut warm, &corpus, &tok, &reg, &wl);
        assert_traces_identical(&format!("warm x{workers}"), &reference, &t);
        let (hits, misses) = warm.stats();
        assert!(hits > 0, "x{workers}: no cross-process hits");
        assert_eq!(misses, 0, "x{workers}: deterministic replay must miss nothing");
        assert!(warm.hit_rate() > 0.5, "x{workers}: hit rate {}", warm.hit_rate());
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn auto_workers_in_bounds() {
    let w = scenario::auto_workers();
    assert!((1..=8).contains(&w), "auto_workers() = {w}");
}
