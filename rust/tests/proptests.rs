//! Property tests on coordinator invariants (routing, batching, state) via
//! the in-crate testkit harness.

use pice::cluster::DeviceSpec;
use pice::coordinator::dispatch::{Job, MultiListQueue};
use pice::coordinator::scheduler::{CloudScheduler, Mode, SchedInput};
use pice::coordinator::selection::select_model;
use pice::coordinator::slo::SloPolicy;
use pice::costmodel::Estimates;
use pice::ensemble::{confidence, select, Candidate, ConfidenceWeights};
use pice::models::Registry;
use pice::network::TransferModel;
use pice::parallel::{merge_once, plan_groups, EdgeCostModel, Group};
use pice::profiler::LatencyFit;
use pice::quality::rouge::{lcs_len, lcs_len_trimmed, rouge1_f1, rouge_l_f1};
use pice::sketch::{compress, levels, split_sentences, split_sketch};
use pice::testkit::{forall, Gen};

fn job(rid: usize, len: usize) -> Job {
    Job {
        rid,
        expected_len: len,
        sentences: vec![],
        salvaged: vec![],
        full_sketch: Vec::new().into(),
        question: Vec::new().into(),
        enqueued_at: 0.0,
        replicas_left: 1,
    }
}

#[test]
fn prop_queue_conserves_jobs() {
    forall(200, |rng| {
        let cap = 1 + rng.below(64);
        let mut q = MultiListQueue::standard(cap);
        let n = rng.below(100);
        let mut accepted = 0;
        for rid in 0..n {
            if q.push(job(rid, rng.below(200))) {
                accepted += 1;
            }
        }
        assert!(q.len() <= cap);
        assert_eq!(q.len(), accepted.min(cap));
        // drain fully; every accepted job comes out exactly once
        let mut seen = std::collections::HashSet::new();
        loop {
            let batch = q.pull_batch(1 + rng.below(8));
            if batch.is_empty() {
                break;
            }
            for j in batch {
                assert!(seen.insert(j.rid), "job {} duplicated", j.rid);
            }
        }
        assert_eq!(seen.len(), accepted.min(cap));
    });
}

#[test]
fn prop_pull_batch_is_single_bucket() {
    forall(200, |rng| {
        let mut q = MultiListQueue::standard(256);
        for rid in 0..(1 + rng.below(64)) {
            q.push(job(rid, rng.below(200)));
        }
        let batch = q.pull_batch(1 + rng.below(16));
        if batch.len() > 1 {
            let b0 = q.bucket_of(batch[0].expected_len);
            assert!(batch.iter().all(|j| q.bucket_of(j.expected_len) == b0));
        }
    });
}

#[test]
fn prop_merge_preserves_sentences() {
    forall(300, |rng| {
        let lens = Gen::lens(rng, 24, 1, 40);
        let groups: Vec<Group> = (0..lens.len()).map(|i| vec![i]).collect();
        let merged = merge_once(&groups, &lens);
        assert_eq!(merged.len(), lens.len().div_ceil(2));
        let mut all: Vec<usize> = merged.iter().flatten().copied().collect();
        all.sort();
        assert_eq!(all, (0..lens.len()).collect::<Vec<_>>());
    });
}

#[test]
fn prop_plan_groups_partition_and_cap() {
    forall(300, |rng| {
        let lens = Gen::lens(rng, 16, 1, 30);
        let p_max = 1 + rng.below(8);
        let budget = rng.range(0.01, 10.0);
        let cost = EdgeCostModel {
            token_s: rng.range(0.001, 0.05),
            batch_slowdown: 0.06,
            prompt_tokens: rng.below(200),
            prefill_speedup: 8.0,
        };
        let plan = plan_groups(&lens, p_max, budget, &cost);
        assert!(!plan.is_empty());
        assert!(plan.len() <= p_max.max(1));
        let mut all: Vec<usize> = plan.iter().flatten().copied().collect();
        all.sort();
        assert_eq!(all, (0..lens.len()).collect::<Vec<_>>(), "not a partition");
    });
}

#[test]
fn prop_merging_never_increases_wall_clock_budget_violation() {
    // plan_groups only merges when the merged plan still fits the budget,
    // so: if the fully-parallel plan fits, the final plan fits too.
    forall(200, |rng| {
        let lens = Gen::lens(rng, 12, 1, 25);
        let cost = EdgeCostModel {
            token_s: 0.01,
            batch_slowdown: 0.06,
            prompt_tokens: rng.below(100),
            prefill_speedup: 8.0,
        };
        let full: Vec<Group> = (0..lens.len()).map(|i| vec![i]).collect();
        let full_t = cost.wall_clock(&full, &lens);
        let budget = full_t * rng.range(1.0, 3.0);
        let plan = plan_groups(&lens, 64, budget, &cost);
        assert!(cost.wall_clock(&plan, &lens) <= budget + 1e-9);
    });
}

#[test]
fn prop_sketch_ops_roundtrip() {
    forall(300, |rng| {
        let period = 7u32;
        let semi = 8u32;
        // random token stream without the separators, then insert them
        let mut toks = Gen::tokens(rng, 60, 200);
        toks.retain(|&t| t != period && t != semi);
        if toks.is_empty() {
            return;
        }
        let sents = split_sentences(&toks, period);
        let total: usize = sents.iter().map(Vec::len).sum();
        assert_eq!(total, toks.len());
        let parts = split_sketch(&toks, semi);
        let total2: usize = parts.iter().map(Vec::len).sum();
        assert_eq!(total2, toks.len());
    });
}

#[test]
fn prop_compress_monotone_and_bounded() {
    forall(300, |rng| {
        let sk = Gen::tokens(rng, 12, 150);
        let lv = levels();
        let mut prev = usize::MAX;
        for l in lv.iter().skip(1) {
            let c = compress(&sk, *l);
            assert!(!c.is_empty());
            assert!(c.len() <= sk.len());
            assert!(c.len() <= prev, "compression not monotone in level");
            assert!(sk.starts_with(&c));
            prev = c.len();
        }
    });
}

#[test]
fn prop_rouge_bounds_and_symmetries() {
    forall(400, |rng| {
        let a = Gen::tokens(rng, 30, 60);
        let b = Gen::tokens(rng, 30, 60);
        for v in [rouge1_f1(&a, &b), rouge_l_f1(&a, &b)] {
            assert!((0.0..=1.0 + 1e-12).contains(&v));
        }
        assert!(lcs_len(&a, &b) <= a.len().min(b.len()));
        assert_eq!(lcs_len(&a, &b), lcs_len(&b, &a));
        assert!((rouge1_f1(&a, &a) - 1.0).abs() < 1e-12);
    });
}

#[test]
fn prop_trimmed_rouge_l_equals_naive_dp() {
    // the trimmed LCS fast path must be observationally identical to the
    // naive O(n*m) DP, on random pairs and on the near-identical pairs the
    // trim was built for
    let naive_f1 = |c: &[u32], r: &[u32]| -> f64 {
        if c.is_empty() || r.is_empty() {
            return 0.0;
        }
        let l = lcs_len(c, r) as f64;
        let p = l / c.len() as f64;
        let rr = l / r.len() as f64;
        if p + rr == 0.0 {
            0.0
        } else {
            2.0 * p * rr / (p + rr)
        }
    };
    forall(400, |rng| {
        // fully random pair
        let a = Gen::tokens(rng, 30, 60);
        let b = Gen::tokens(rng, 30, 60);
        assert_eq!(lcs_len_trimmed(&a, &b), lcs_len(&a, &b));
        assert!((rouge_l_f1(&a, &b) - naive_f1(&a, &b)).abs() < 1e-12);
        // near-identical pair: copy with a few point mutations (the common
        // case for high-quality candidates vs their reference)
        let mut c = a.clone();
        for _ in 0..rng.below(4) {
            let i = rng.below(c.len());
            c[i] = 10 + (rng.next_u64() % 50) as u32;
        }
        assert_eq!(lcs_len_trimmed(&a, &c), lcs_len(&a, &c));
        assert!((rouge_l_f1(&a, &c) - naive_f1(&a, &c)).abs() < 1e-12);
        assert!((rouge_l_f1(&a, &a) - 1.0).abs() < 1e-12);
    });
}

#[test]
fn prop_scheduler_respects_hard_constraint() {
    forall(300, |rng| {
        let s = CloudScheduler::default();
        let inp = SchedInput {
            predicted_len: 20 + rng.below(200),
            n_edges: 1 + rng.below(8),
            best_slm_capability: rng.range(40.0, 90.0),
        };
        let est = Estimates {
            f_cloud: LatencyFit { a: rng.range(0.0, 0.5), b: rng.range(0.01, 0.1) },
            cost_coeff: rng.range(0.1, 3.0),
            transfer: TransferModel { base_s: 0.02, per_token_s: 1e-6 },
            backlog_s: rng.range(0.0, 30.0),
            parallel_hint: rng.range(1.0, 8.0),
        };
        let d = s.decide(&inp, &est);
        if d.mode == Mode::Progressive {
            // the chosen level must satisfy Eq. 2
            let budget = est.f_cloud.eval(inp.predicted_len) * s.policy.latency_slack;
            assert!(
                s.e2e_estimate(&inp, &est, d.level) <= budget + 1e-9,
                "picked an infeasible level"
            );
        }
    });
}

#[test]
fn prop_selection_always_returns_candidate() {
    let reg = Registry::builtin();
    let dev = DeviceSpec::jetson_orin("e");
    let c = vec![
        reg.get("qwen1.5b-sim").unwrap(),
        reg.get("qwen7b-sim").unwrap(),
        reg.get("llama8b-sim").unwrap(),
    ];
    forall(300, |rng| {
        let current = c[rng.below(c.len())].name.clone();
        let out = select_model(
            &dev,
            &c,
            &current,
            10 + rng.below(300),
            rng.below(120),
            rng.range(0.001, 60.0),
            rng.below(12),
            8,
        );
        assert!(c.iter().any(|m| m.name == out.model), "unknown model chosen");
        if !out.switched {
            assert_eq!(out.model, current);
            assert_eq!(out.switch_cost_s, 0.0);
        } else {
            assert!(out.switch_cost_s > 0.0);
        }
    });
}

#[test]
fn prop_ensemble_confidence_bounded_and_select_argmax() {
    forall(300, |rng| {
        let w = ConfidenceWeights::default();
        let sketch = Gen::tokens(rng, 10, 80);
        let n = 1 + rng.below(5);
        let cands: Vec<Candidate> = (0..n)
            .map(|i| {
                let toks = Gen::tokens(rng, 20, 80);
                let lp = toks.iter().map(|_| -rng.range(0.0, 4.0)).collect();
                Candidate { model: format!("m{i}"), tokens: toks, logps: lp }
            })
            .collect();
        let expected = 1 + rng.below(40);
        let (idx, best) = select(&cands, &sketch, expected, w).unwrap();
        assert!((0.0..=1.0 + 1e-9).contains(&best));
        for (i, c) in cands.iter().enumerate() {
            let v = confidence(c, &sketch, expected, w);
            assert!(v <= best + 1e-12, "select missed a better candidate {i}");
        }
        assert!(idx < cands.len());
    });
}

#[test]
fn prop_lex_select_pareto_respect() {
    // the lexicographic winner is never strictly dominated on the primary
    // metric beyond the tolerance band
    forall(300, |rng| {
        let policy = SloPolicy::default();
        let n = 1 + rng.below(6);
        let cands: Vec<[f64; 5]> = (0..n)
            .map(|_| {
                [
                    rng.range(0.0, 1.0),
                    -rng.range(0.0, 10.0),
                    rng.range(0.0, 100.0),
                    rng.range(0.0, 500.0),
                    rng.range(0.0, 500.0),
                ]
            })
            .collect();
        let pick = policy.lex_select(&cands).unwrap();
        let mi = policy.metric_index(policy.order[0]);
        let best = cands.iter().map(|c| c[mi]).fold(f64::INFINITY, f64::min);
        let band = best.abs().max(1e-9) * policy.tolerance;
        assert!(cands[pick][mi] <= best + band + 1e-12);
    });
}
