//! Determinism of the batched/parallel execution layer: running the full
//! PICE engine over [`ParallelBackend`] (per-worker surrogate replicas,
//! index-ordered merge) must produce byte-identical request traces to the
//! sequential surrogate for the same seed — the engine's contract that
//! parallelism is a pure execution-substrate change. Same for the memo
//! cache, alone and stacked on top.

use std::sync::Arc;

use pice::baselines;
use pice::coordinator::backend::{
    GenRequest, MemoBackend, ParallelBackend, SurrogateBackend, TextBackend,
};
use pice::coordinator::Engine;
use pice::corpus::synth::{synth_corpus, synth_tokenizer};
use pice::corpus::workload::{Arrival, Workload, WorkloadSpec};
use pice::corpus::Corpus;
use pice::metrics::RequestTrace;
use pice::models::Registry;
use pice::runtime::SamplingParams;
use pice::sketch::Prompts;
use pice::tokenizer::Tokenizer;

fn setup() -> (Arc<Corpus>, Tokenizer, Registry, Workload) {
    let tok = synth_tokenizer();
    let corpus = Arc::new(synth_corpus(&tok, 20, 42));
    let reg = Registry::builtin();
    let wl = Workload::generate(
        &corpus,
        WorkloadSpec {
            rpm: 40.0,
            n_requests: 40,
            arrival: Arrival::Poisson,
            categories: vec![],
            seed: 5,
        },
    );
    (corpus, tok, reg, wl)
}

fn run_with(
    backend: &mut dyn TextBackend,
    corpus: &Arc<Corpus>,
    tok: &Tokenizer,
    reg: &Registry,
    wl: &Workload,
) -> Vec<RequestTrace> {
    let cfg = baselines::pice("llama70b-sim");
    let mut engine = Engine::new(cfg, corpus.clone(), tok, reg, backend).unwrap();
    engine.run(wl).unwrap()
}

fn assert_traces_identical(label: &str, a: &[RequestTrace], b: &[RequestTrace]) {
    assert_eq!(a.len(), b.len(), "{label}: trace count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.rid, y.rid, "{label}: rid");
        assert_eq!(x.mode, y.mode, "{label}: mode rid={}", x.rid);
        assert_eq!(x.answer, y.answer, "{label}: answer rid={}", x.rid);
        assert_eq!(x.winner_model, y.winner_model, "{label}: winner rid={}", x.rid);
        assert_eq!(x.cloud_tokens, y.cloud_tokens, "{label}: cloud tokens rid={}", x.rid);
        assert_eq!(x.edge_tokens, y.edge_tokens, "{label}: edge tokens rid={}", x.rid);
        assert_eq!(x.sketch_level, y.sketch_level, "{label}: level rid={}", x.rid);
        assert_eq!(x.parallelism, y.parallelism, "{label}: parallelism rid={}", x.rid);
        assert!((x.done - y.done).abs() < 1e-12, "{label}: done time rid={}", x.rid);
        assert!((x.confidence - y.confidence).abs() < 1e-12, "{label}: confidence rid={}", x.rid);
    }
}

#[test]
fn parallel_backend_traces_identical_to_sequential() {
    let (corpus, tok, reg, wl) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let mut seq = base.clone();
    let reference = run_with(&mut seq, &corpus, &tok, &reg, &wl);
    assert!(!reference.is_empty());
    for workers in [2usize, 4] {
        let mut par = ParallelBackend::new(workers, |_| base.clone());
        let got = run_with(&mut par, &corpus, &tok, &reg, &wl);
        assert_traces_identical(&format!("{workers} workers"), &reference, &got);
    }
}

#[test]
fn memo_cache_traces_identical_to_sequential() {
    let (corpus, tok, reg, wl) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let mut seq = base.clone();
    let reference = run_with(&mut seq, &corpus, &tok, &reg, &wl);

    let mut memo = MemoBackend::new(base.clone(), 4096);
    let first = run_with(&mut memo, &corpus, &tok, &reg, &wl);
    assert_traces_identical("memo cold", &reference, &first);
    // replaying the same workload must be served largely from cache, with
    // identical traces
    let second = run_with(&mut memo, &corpus, &tok, &reg, &wl);
    assert_traces_identical("memo warm", &reference, &second);
    let (hits, misses) = memo.stats();
    assert!(hits >= misses, "expected a warm replay to hit: {hits} hits / {misses} misses");

    // memo stacked on the parallel pool
    let mut stacked = MemoBackend::new(ParallelBackend::new(4, |_| base.clone()), 4096);
    let got = run_with(&mut stacked, &corpus, &tok, &reg, &wl);
    assert_traces_identical("memo+parallel", &reference, &got);
}

#[test]
fn parallel_batch_results_are_index_aligned() {
    // direct protocol-level check: shuffled-size batches over every prompt
    // kind keep results positionally aligned with requests
    let (corpus, tok, reg, _) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let mut reqs: Vec<GenRequest> = Vec::new();
    for q in corpus.eval_questions().into_iter().take(24) {
        let sketch = q.sketch_tokens(tok.specials.semicolon);
        reqs.push(GenRequest::new(
            "llama70b-sim",
            &Prompts::sketch(&tok, &q.question),
            SamplingParams { max_tokens: 60, seed: q.id as u64, ..Default::default() },
        ));
        for (si, sent) in q.sentences.iter().enumerate() {
            reqs.push(GenRequest::new(
                "qwen7b-sim",
                &Prompts::expand(&tok, &q.question, &sketch, &sent.sketch),
                SamplingParams {
                    max_tokens: 24,
                    stop_token: Some(tok.specials.period),
                    seed: (q.id as u64) << 8 | si as u64,
                    ..Default::default()
                },
            ));
        }
    }
    let mut seq = base.clone();
    let expect = seq.generate_batch(&reqs);
    let mut par = ParallelBackend::new(3, |_| base.clone());
    let got = par.generate_batch(&reqs);
    assert_eq!(expect.len(), got.len());
    for (i, (e, g)) in expect.iter().zip(&got).enumerate() {
        let (e, g) = (e.as_ref().unwrap(), g.as_ref().unwrap());
        assert_eq!(e.tokens, g.tokens, "idx {i}");
        assert_eq!(e.logps, g.logps, "idx {i}");
    }
}
