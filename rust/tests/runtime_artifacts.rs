//! Integration over the *real* runtime: PJRT + AOT picoLM artifacts.
//! Skipped (with a notice) when `make artifacts` hasn't run.

use std::sync::Arc;

use pice::corpus::Corpus;
use pice::runtime::{Generator, LoadedModel, RuntimeHandle, SamplingParams};
use pice::sketch::Prompts;
use pice::tokenizer::Tokenizer;

fn artifacts_ready() -> bool {
    pice::artifacts_dir().join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_ready() {
            eprintln!("SKIP: run `make artifacts` first");
            return;
        }
    };
}

fn load_small() -> (LoadedModel, Tokenizer, Arc<Corpus>) {
    let art = pice::artifacts_dir();
    let rt = RuntimeHandle::cpu().expect("pjrt client");
    let tok = Tokenizer::from_file(&art.join("vocab.json")).expect("vocab");
    let corpus = Arc::new(Corpus::from_file(&art.join("corpus.json"), &tok).expect("corpus"));
    let m = LoadedModel::load(rt, &art.join("models/qwen1.5b-sim")).expect("model");
    (m, tok, corpus)
}

#[test]
fn generate_produces_tokens_and_logps() {
    require_artifacts!();
    let (m, tok, corpus) = load_small();
    let g = Generator::new(&m, tok.specials.eos);
    let q = corpus.eval_questions()[0];
    let out = g
        .generate(
            &Prompts::full_answer(&tok, &q.question),
            &SamplingParams { max_tokens: 32, ..Default::default() },
        )
        .unwrap();
    assert!(!out.tokens.is_empty());
    assert_eq!(out.tokens.len(), out.logps.len());
    assert!(out.logps.iter().all(|&l| l <= 0.0));
    assert!(out.tokens.iter().all(|&t| (t as usize) < m.art.vocab));
}

#[test]
fn greedy_generation_deterministic() {
    require_artifacts!();
    let (m, tok, corpus) = load_small();
    let g = Generator::new(&m, tok.specials.eos);
    let q = corpus.eval_questions()[1];
    let sp = SamplingParams { max_tokens: 24, ..Default::default() };
    let p = Prompts::full_answer(&tok, &q.question);
    let a = g.generate(&p, &sp).unwrap();
    let b = g.generate(&p, &sp).unwrap();
    assert_eq!(a.tokens, b.tokens);
}

#[test]
fn stop_token_respected() {
    require_artifacts!();
    let (m, tok, corpus) = load_small();
    let g = Generator::new(&m, tok.specials.eos);
    let q = corpus.eval_questions()[2];
    let full_sk = q.sketch_tokens(tok.specials.semicolon);
    let p = Prompts::expand(&tok, &q.question, &full_sk, &q.sentences[0].sketch);
    let out = g
        .generate(
            &p,
            &SamplingParams {
                max_tokens: 30,
                stop_token: Some(tok.specials.period),
                ..Default::default()
            },
        )
        .unwrap();
    let last = *out.tokens.last().unwrap();
    assert!(
        last == tok.specials.period || last == tok.specials.eos || out.tokens.len() == 30,
        "bad stop: {last}"
    );
}

#[test]
fn score_matches_generation_confidence_direction() {
    require_artifacts!();
    let (m, tok, corpus) = load_small();
    let g = Generator::new(&m, tok.specials.eos);
    // a corpus-like sequence should score better than a shuffled one
    let q = corpus.eval_questions()[3];
    let mut natural = vec![tok.specials.q];
    natural.extend_from_slice(&q.question);
    natural.push(tok.specials.a);
    natural.extend(q.answer_tokens());
    natural.truncate(m.art.max_seq);
    let mut shuffled = natural.clone();
    shuffled.reverse();
    let lp_nat: f64 = g.score_logps(&natural).unwrap().iter().sum::<f64>()
        / (natural.len() - 1) as f64;
    let lp_shuf: f64 = g.score_logps(&shuffled).unwrap().iter().sum::<f64>()
        / (shuffled.len() - 1) as f64;
    assert!(lp_nat > lp_shuf, "natural {lp_nat} <= shuffled {lp_shuf}");
}

#[test]
fn temperature_sampling_varies_with_seed() {
    require_artifacts!();
    let (m, tok, corpus) = load_small();
    let g = Generator::new(&m, tok.specials.eos);
    let q = corpus.eval_questions()[4];
    let p = Prompts::full_answer(&tok, &q.question);
    let a = g
        .generate(&p, &SamplingParams { max_tokens: 24, temperature: 1.0, seed: 1, ..Default::default() })
        .unwrap();
    let b = g
        .generate(&p, &SamplingParams { max_tokens: 24, temperature: 1.0, seed: 2, ..Default::default() })
        .unwrap();
    assert_ne!(a.tokens, b.tokens, "different seeds gave identical samples");
}

#[test]
fn prompt_too_long_rejected() {
    require_artifacts!();
    let (m, tok, _) = load_small();
    let g = Generator::new(&m, tok.specials.eos);
    let p = vec![tok.specials.q; m.art.max_seq + 1];
    assert!(g.generate(&p, &SamplingParams::default()).is_err());
}
