//! The cost-model layer's contract (see `costmodel/mod.rs`):
//!
//! 1. **Null calibration is the static model.** A `Calibrated` model with
//!    its corrections frozen (`rate_alpha` 0, `min_samples` unreachable)
//!    turns the whole observation machinery on yet decides bit-identically
//!    to the default `StaticFit`, across the fig6 variant grid, the fig13
//!    queue-capacity grid, and the dynamics presets. This is the
//!    refactor's freeze guard: since calibration-off routes through the
//!    same `CostModel` trait, equality here pins the static default.
//! 2. **Calibrated stays deterministic.** The model learns only from its
//!    own engine's event stream, so calibrated runs are bit-identical
//!    across 1/2/4 sweep threads and open vs closed loop — including the
//!    learned state itself.
//! 3. **Warm-start round-trip.** An end-of-run state survives
//!    `CalibStore` save/load bit-exactly (f64s persist as bit patterns),
//!    and a warm run seeded from disk decides identically to one seeded
//!    from the in-memory donor state.
//! 4. **Stale stamps cold-start.** A snapshot written under a different
//!    corpus/registry stamp is never applied, but survives as a foreign
//!    section across saves.
//! 5. **Warm state ages out under drift.** A warm-loaded state whose
//!    predictions stay off-world for `drift_samples` consecutive cloud
//!    observations is discarded and the model re-learns cold — a snapshot
//!    from changed hardware or a changed link cannot steer Eq. 2 forever.
//! 6. **Router == engine.** Fleet least-loaded placement reads the shard
//!    engine's own memoized `backlog_estimate_s`: at every poll point the
//!    router sees exactly the number the shard's admission path uses, the
//!    estimate is stable across repeated polls, and the request lands on
//!    the shard the router quoted.

use std::path::PathBuf;
use std::sync::Arc;

use pice::baselines;
use pice::cluster::DeviceSpec;
use pice::coordinator::backend::{SurrogateBackend, TextBackend};
use pice::coordinator::{Engine, EngineCfg};
use pice::corpus::synth::{synth_corpus, synth_tokenizer};
use pice::corpus::workload::{Arrival, Workload, WorkloadSpec};
use pice::corpus::Corpus;
use pice::costmodel::{CalibMode, CalibState, CalibStore};
use pice::dynamics::DynamicsSpec;
use pice::fleet::{shard_cfg, Fleet, Placement};
use pice::metrics::RequestTrace;
use pice::models::Registry;
use pice::serve::{PiceService, ServeCfg};
use pice::sweep::{SweepRunner, SweepScenario};
use pice::tokenizer::Tokenizer;

const MODEL: &str = "llama70b-sim";

fn setup() -> (Arc<Corpus>, Tokenizer, Registry) {
    let tok = synth_tokenizer();
    let corpus = Arc::new(synth_corpus(&tok, 20, 42));
    (corpus, tok, Registry::builtin())
}

/// §V-B's operating point, same formula as `Env::paper_rpm`.
fn paper_rpm(reg: &Registry) -> f64 {
    let info = reg.get(MODEL).expect("model");
    let cloud = DeviceSpec::a100_cloud("c");
    1.5 * cloud.max_batch(info, 1000) as f64
}

fn workload(
    corpus: &Arc<Corpus>,
    rpm: f64,
    n: usize,
    arrival: Arrival,
    seed: u64,
) -> Arc<Workload> {
    Arc::new(Workload::generate(
        corpus,
        WorkloadSpec { rpm, n_requests: n, arrival, categories: vec![], seed },
    ))
}

/// Same engine shape, calibration learning.
fn calibrated(mut cfg: EngineCfg) -> EngineCfg {
    cfg.calib.mode = CalibMode::On;
    cfg
}

/// The non-tautological freeze shape: the `Calibrated` model (observation
/// machinery fully wired) with every correction frozen at its identity.
fn frozen(mut cfg: EngineCfg) -> EngineCfg {
    cfg.calib.mode = CalibMode::On;
    cfg.calib.rate_alpha = 0.0;
    cfg.calib.min_samples = usize::MAX;
    cfg
}

/// Closed-loop run; returns the traces and the end-of-run calibration
/// state (None for the static model).
fn run_closed(
    cfg: &EngineCfg,
    wl: &Workload,
    corpus: &Arc<Corpus>,
    tok: &Tokenizer,
    reg: &Registry,
) -> (Vec<RequestTrace>, Option<CalibState>) {
    let mut backend = SurrogateBackend::new(corpus.clone(), tok, reg, 9);
    let mut e = Engine::new(cfg.clone(), corpus.clone(), tok, reg, &mut backend).expect("engine");
    let traces = e.run(wl).expect("run");
    let state = e.calib_state();
    (traces, state)
}

fn assert_traces_identical(label: &str, a: &[RequestTrace], b: &[RequestTrace]) {
    assert_eq!(a.len(), b.len(), "{label}: trace count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(format!("{x:?}"), format!("{y:?}"), "{label}: trace rid={}", x.rid);
    }
}

/// The fig6 variant grid (seed 13), the fig13 queue-capacity grid
/// (seed 19, 1.3x load), and the dynamics presets on bursty arrivals
/// (seed 29) — the scenario families every bench freezes on.
fn scenario_families(
    reg: &Registry,
    corpus: &Arc<Corpus>,
) -> Vec<(String, EngineCfg, Arc<Workload>)> {
    let rpm = paper_rpm(reg);
    let mut out = Vec::new();
    let wl6 = workload(corpus, rpm, 36, Arrival::Poisson, 13);
    let mut stat = baselines::pice(MODEL);
    stat.scheduler.static_mode = true;
    out.push(("fig6/Cloud-only".into(), baselines::cloud_only(MODEL), wl6.clone()));
    out.push(("fig6/Routing".into(), baselines::routing(MODEL), wl6.clone()));
    out.push(("fig6/PICE-static".into(), stat, wl6.clone()));
    out.push(("fig6/PICE-dynamic".into(), baselines::pice(MODEL), wl6));
    let wl13 = workload(corpus, rpm * 1.3, 30, Arrival::Poisson, 19);
    for cap in [1usize, 4, 16] {
        let mut cfg = baselines::pice(MODEL);
        cfg.queue_cap = cap;
        out.push((format!("fig13/cap{cap}"), cfg, wl13.clone()));
    }
    let wld = workload(
        corpus,
        rpm,
        30,
        Arrival::BurstyPoisson { burst_factor: 4.0, burst_len: 5 },
        29,
    );
    for p in ["flaky-wan", "edge-churn"] {
        let cfg = baselines::pice(MODEL).with_dynamics(DynamicsSpec::preset(p).expect("preset"));
        out.push((format!("dyn/{p}"), cfg, wld.clone()));
    }
    out
}

#[test]
fn null_calibration_is_bit_identical_to_calibration_off() {
    let (corpus, tok, reg) = setup();
    for (name, cfg, wl) in scenario_families(&reg, &corpus) {
        let (off_traces, off_state) = run_closed(&cfg, &wl, &corpus, &tok, &reg);
        let (nul_traces, nul_state) = run_closed(&frozen(cfg), &wl, &corpus, &tok, &reg);
        // the frozen run really did build the Calibrated model (it has
        // persistable state); the off run really is static
        assert!(off_state.is_none(), "{name}: static model leaked a state");
        assert!(nul_state.is_some(), "{name}: frozen run was not Calibrated");
        assert_traces_identical(&name, &off_traces, &nul_traces);
    }
}

#[test]
fn calibrated_sweep_is_bit_identical_across_thread_counts() {
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let wl = workload(
        &corpus,
        paper_rpm(&reg),
        24,
        Arrival::BurstyPoisson { burst_factor: 3.0, burst_len: 6 },
        5,
    );
    let flaky = DynamicsSpec::preset("flaky-wan").expect("preset");
    let churn = DynamicsSpec::preset("edge-churn").expect("preset");
    let scenarios = vec![
        SweepScenario::new("calib", calibrated(baselines::pice(MODEL)), wl.clone()),
        SweepScenario::new(
            "calib-flaky",
            calibrated(baselines::pice(MODEL).with_dynamics(flaky)),
            wl.clone(),
        ),
        SweepScenario::new(
            "calib-churn",
            calibrated(baselines::pice(MODEL).with_dynamics(churn)),
            wl.clone(),
        ),
        SweepScenario::new("calib-routing", calibrated(baselines::routing(MODEL)), wl),
    ];
    let reference: Vec<Vec<RequestTrace>> = scenarios
        .iter()
        .map(|sc| run_closed(&sc.cfg, &sc.workload, &corpus, &tok, &reg).0)
        .collect();
    for threads in [1usize, 2, 4] {
        let got = SweepRunner::new(threads).run(&scenarios, &corpus, &tok, &reg, |_| {
            Box::new(base.clone()) as Box<dyn TextBackend>
        });
        for ((sc, want), res) in scenarios.iter().zip(&reference).zip(got) {
            let (_, traces) = res.expect("scenario ok");
            assert_traces_identical(&format!("{} @ {threads} threads", sc.label), want, &traces);
        }
    }
}

#[test]
fn calibrated_open_loop_matches_closed_loop() {
    let (corpus, tok, reg) = setup();
    let cfg = calibrated(
        baselines::pice(MODEL).with_dynamics(DynamicsSpec::preset("flaky-wan").expect("preset")),
    );
    let wl = workload(
        &corpus,
        paper_rpm(&reg),
        30,
        Arrival::BurstyPoisson { burst_factor: 4.0, burst_len: 5 },
        29,
    );
    let (closed_traces, closed_state) = run_closed(&cfg, &wl, &corpus, &tok, &reg);
    let mut backend = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let engine =
        Engine::new(cfg.clone(), corpus.clone(), &tok, &reg, &mut backend).expect("engine");
    let mut svc =
        PiceService::new(engine, ServeCfg { max_inflight: usize::MAX, deadline_s: None });
    for r in &wl.requests {
        svc.pump_until(r.arrival_s).expect("pump");
        svc.submit(r.question_id, r.arrival_s).expect("submit");
    }
    svc.pump_all().expect("pump_all");
    let open_state = svc.calib_states().remove(0).1;
    let open_traces = svc.finish().expect("finish");
    assert_traces_identical("open vs closed", &closed_traces, &open_traces);
    // the learned state itself is part of the determinism contract
    assert_eq!(closed_state, open_state, "open and closed loop learned different states");
    assert!(closed_state.expect("calibrated state").cloud_samples > 0, "nothing was learned");
}

fn tmp_store(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("pice_calib_{}_{name}.json", std::process::id()))
}

#[test]
fn warm_state_round_trips_through_the_store() {
    let (corpus, tok, reg) = setup();
    let base_cfg =
        baselines::pice(MODEL).with_dynamics(DynamicsSpec::preset("flaky-wan").expect("preset"));
    let key = base_cfg.calib_key();
    let wl = workload(
        &corpus,
        paper_rpm(&reg),
        30,
        Arrival::BurstyPoisson { burst_factor: 4.0, burst_len: 5 },
        29,
    );
    let (_, donor) = run_closed(&calibrated(base_cfg.clone()), &wl, &corpus, &tok, &reg);
    let donor = donor.expect("calibrated state");
    assert!(donor.cloud_samples > 0, "donor learned nothing — the round trip proves nothing");

    let path = tmp_store("warm_roundtrip");
    let _ = std::fs::remove_file(&path);
    let mut store = CalibStore::load(&path, "stamp-a");
    assert_eq!(store.restored_entries(), 0, "cold start restored something");
    store.put(&key, donor.clone());
    assert!(store.dirty());
    store.save().expect("save");
    let reloaded = CalibStore::load(&path, "stamp-a");
    assert_eq!(reloaded.restored_entries(), 1);
    let restored = reloaded.get(&key).expect("state under same stamp");
    assert_eq!(restored, donor, "state drifted across save/load");

    // a warm run seeded from disk == one seeded from the in-memory donor
    let warm = |st: &CalibState| {
        let mut cfg = base_cfg.clone();
        cfg.calib.mode = CalibMode::Warm;
        cfg.calib.warm = Some(st.clone());
        cfg
    };
    let (mem_traces, _) = run_closed(&warm(&donor), &wl, &corpus, &tok, &reg);
    let (disk_traces, _) = run_closed(&warm(&restored), &wl, &corpus, &tok, &reg);
    assert_traces_identical("warm mem vs disk", &mem_traces, &disk_traces);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn stale_stamps_cold_start_but_are_preserved() {
    let state = CalibState {
        n: 3.0,
        sx: 210.0,
        sy: 14.0,
        sxx: 16900.0,
        sxy: 1120.0,
        edge_corr: 1.25,
        transfer_corr: 0.8,
        parallelism: 2.5,
        resid_s: 0.4,
        cloud_samples: 5,
        edge_samples: 7,
        transfer_samples: 3,
    };
    let path = tmp_store("stale_stamp");
    let _ = std::fs::remove_file(&path);
    let mut store = CalibStore::load(&path, "stamp-a");
    store.put("pice/e4/pice", state.clone());
    store.save().expect("save");

    // a different stamp never applies the snapshot...
    let mut other = CalibStore::load(&path, "stamp-b");
    assert_eq!(other.restored_entries(), 0, "stale stamp was applied");
    assert!(other.get("pice/e4/pice").is_none());
    // ...and saving under it keeps stamp-a's section intact on disk
    let mut newer = state.clone();
    newer.cloud_samples = 99;
    other.put("pice/e4/pice", newer);
    other.save().expect("save under new stamp");
    let back = CalibStore::load(&path, "stamp-a");
    assert_eq!(back.get("pice/e4/pice"), Some(state), "foreign section was dropped");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn warm_start_under_a_changed_world_ages_out() {
    let (corpus, tok, reg) = setup();
    // a donor state from an alien world: regression points (100, 900),
    // (200, 1700), (300, 2500) give f(l) = 8 l + 100 — minutes of claimed
    // cloud service where this world takes seconds (and positive at every
    // length, so each observation votes off-world), far beyond any sane
    // drift ratio
    let alien = CalibState {
        n: 3.0,
        sx: 600.0,
        sy: 5100.0,
        sxx: 140_000.0,
        sxy: 1_180_000.0,
        edge_corr: 1.0,
        transfer_corr: 1.0,
        parallelism: 2.0,
        resid_s: 0.5,
        cloud_samples: 200,
        edge_samples: 0,
        transfer_samples: 0,
    };
    let wl = workload(&corpus, paper_rpm(&reg), 30, Arrival::Poisson, 29);
    let warm = |drift_ratio: f64| {
        let mut cfg = baselines::pice(MODEL);
        cfg.calib.mode = CalibMode::Warm;
        cfg.calib.warm = Some(alien.clone());
        cfg.calib.drift_ratio = drift_ratio;
        cfg.calib.drift_samples = 3;
        cfg
    };
    // age-out disarmed (an unreachable ratio): the alien accumulators
    // survive the whole run and every new sample stacks on top of them
    let (_, keep) = run_closed(&warm(1e6), &wl, &corpus, &tok, &reg);
    let keep = keep.expect("calibrated state");
    assert!(keep.cloud_samples > alien.cloud_samples, "warm run observed nothing");
    // age-out armed: three consecutive off-world residuals discard the
    // warm state and re-learn cold, so the alien samples are gone from
    // the end-of-run state (if no reset ever fired the two runs would be
    // identical, alien samples included)
    let (traces, aged) = run_closed(&warm(1.5), &wl, &corpus, &tok, &reg);
    let aged = aged.expect("calibrated state");
    assert_eq!(traces.len(), wl.requests.len(), "age-out lost requests");
    assert!(
        aged.cloud_samples < alien.cloud_samples.min(keep.cloud_samples),
        "drift age-out never fired: {} cloud samples vs donor {} / kept {}",
        aged.cloud_samples,
        alien.cloud_samples,
        keep.cloud_samples
    );
}

#[test]
fn least_loaded_router_reads_the_shards_own_estimate() {
    let (corpus, tok, reg) = setup();
    let base = calibrated(baselines::pice(MODEL));
    let wl = workload(&corpus, paper_rpm(&reg), 32, Arrival::Poisson, 7);
    let backend = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let shards = (0..4)
        .map(|i| {
            Engine::new_owned(
                shard_cfg(&base, i),
                corpus.clone(),
                &tok,
                &reg,
                Box::new(backend.clone()),
            )
            .expect("shard")
        })
        .collect();
    let mut fleet = Fleet::new(shards, Placement::LeastLoaded);
    for r in &wl.requests {
        fleet.pump_until(r.arrival_s).expect("pump");
        let key = r.rid as u64;
        let quoted = fleet.backlog_estimate_for(key);
        let s = fleet.shard_for(key);
        let engine_est = fleet.shard_mut(s).backlog_estimate_s();
        assert_eq!(
            quoted.to_bits(),
            engine_est.to_bits(),
            "rid {}: router quoted {quoted} but shard {s} computes {engine_est}",
            r.rid
        );
        // memoized: polling again without pumping is bit-stable
        assert_eq!(quoted.to_bits(), fleet.backlog_estimate_for(key).to_bits());
        let global = fleet.submit(r.question_id, r.arrival_s, key).expect("submit");
        assert_eq!(fleet.route_of(global), s, "request landed off the quoted shard");
    }
    fleet.pump_all().expect("drain");
    assert_eq!(fleet.take_traces().len(), wl.requests.len());
    // every shard owns an independent calibrated model
    assert_eq!(fleet.calib_summaries().len(), 4);
}
