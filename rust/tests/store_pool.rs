//! Integration tests for the paged buffer-pool generation store
//! (`pice::store`): pin-while-reading under concurrent evictors, bit-exact
//! spill round trips, stale-stamp / torn-page cold starts, and the one-time
//! v1 monolithic-snapshot migration.

use std::path::PathBuf;
use std::sync::Arc;

use pice::runtime::{GenOutput, SamplingParams};
use pice::store::{page, BufferPool, MemoKey, PoolCfg};
use pice::util::json::{self, Json};

fn tmp_root(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("pice_store_pool_{}_{name}", std::process::id()))
}

fn key(seed: u64) -> MemoKey {
    MemoKey::new(
        "qwen7b-sim",
        &[seed as u32, (seed >> 8) as u32, 7],
        &SamplingParams { max_tokens: 16, seed, ..Default::default() },
    )
}

/// Per-key output so a cross-contaminated read (wrong page, torn write,
/// racing evictor) is detectable, not just a hit-rate blip.
fn out(seed: u64) -> GenOutput {
    GenOutput {
        tokens: vec![seed as u32, (seed as u32).wrapping_mul(31)],
        logps: vec![-0.25 - seed as f64 * 1e-3, -1.5],
        finished: true,
    }
}

#[test]
fn pinned_reads_survive_concurrent_evictors() {
    let root = tmp_root("pins");
    let _ = std::fs::remove_dir_all(&root);
    // tiny budget + small pages: every reader get() faults pages back in
    // while the writers' inserts drive the clock evictor over them
    let cfg = PoolCfg { max_entries: usize::MAX, byte_budget: 2 * 1024, page_entries: 4 };
    let pool = Arc::new(BufferPool::new(cfg));
    pool.attach_store(&root, "st");
    const N: u64 = 160;
    for i in 0..N {
        pool.insert(key(i), out(i), 0);
    }
    let mut handles = Vec::new();
    for _ in 0..4 {
        let p = pool.clone();
        handles.push(std::thread::spawn(move || {
            for _ in 0..3 {
                for i in 0..N {
                    if let Some(o) = p.get(&key(i), 0) {
                        let want = out(i);
                        assert_eq!(o.tokens, want.tokens, "corrupted read for key {i}");
                        assert_eq!(
                            o.logps[0].to_bits(),
                            want.logps[0].to_bits(),
                            "corrupted logp for key {i}"
                        );
                    }
                }
            }
        }));
    }
    for t in 0..2u64 {
        let p = pool.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..N {
                p.insert(key(1000 + t * N + i), out(1000 + t * N + i), 1);
            }
        }));
    }
    for h in handles {
        h.join().expect("reader/writer thread panicked");
    }
    // the store was attached before any insert, so every eviction spilled:
    // after the dust settles every key is still servable, bit-exactly
    for i in 0..N {
        let o = pool.get(&key(i), 0).unwrap_or_else(|| panic!("key {i} lost"));
        assert_eq!(o.tokens, out(i).tokens);
    }
    let c = pool.counters();
    assert!(c.evictions > 0 && c.spilled_pages > 0 && c.faulted_pages > 0, "vacuous stress: {c:?}");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn spill_round_trip_is_bit_exact() {
    let root = tmp_root("bits");
    let _ = std::fs::remove_dir_all(&root);
    // adversarial f64 bit patterns (subnormals, extremes, repeating binary
    // fractions) and u64 key fields beyond 2^53
    let nasty: [f64; 8] = [
        5e-324,                  // smallest subnormal
        -5e-324,
        f64::MIN_POSITIVE,
        -f64::MIN_POSITIVE,
        -0.1,                    // repeating binary fraction
        -1.0 / 3.0,
        -1e300,
        f64::MAX,
    ];
    let mk_key = |i: u64| {
        MemoKey {
            model: "m".into(),
            prompt: vec![i as u32],
            temperature_bits: 0.7f64.to_bits(),
            max_tokens: 8,
            stop_token: None,
            seed: u64::MAX - 12345 - i,
        }
    };
    // one entry per page (entry cap 1), so inserting the next entry spills
    // the previous one — every lookup below is a disk round trip
    let pool = BufferPool::new(PoolCfg::entry_capped(1));
    pool.attach_store(&root, "st");
    for (i, &lp) in nasty.iter().enumerate() {
        let o = GenOutput { tokens: vec![i as u32], logps: vec![lp, lp / 2.0], finished: true };
        pool.insert(mk_key(i as u64), o, 0);
    }
    for (i, &lp) in nasty.iter().enumerate() {
        let o = pool.get(&mk_key(i as u64), 0).unwrap_or_else(|| panic!("entry {i} lost"));
        assert_eq!(o.logps[0].to_bits(), lp.to_bits(), "logp bits for {lp:?}");
        assert_eq!(o.logps[1].to_bits(), (lp / 2.0).to_bits(), "half logp bits for {lp:?}");
        assert_eq!(o.tokens, vec![i as u32]);
    }
    let c = pool.counters();
    assert!(c.faulted_pages >= nasty.len() as u64 - 1, "reads were not disk round trips: {c:?}");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn stale_stamp_is_cold_start_and_preserves_the_store() {
    let root = tmp_root("stale");
    let _ = std::fs::remove_dir_all(&root);
    {
        let pool = BufferPool::new(PoolCfg::entry_capped(64));
        pool.attach_store(&root, "stamp-a");
        for i in 0..5u64 {
            pool.insert(key(i), out(i), 0);
        }
        pool.flush().unwrap();
    }
    // a different stamp sees nothing — and must not disturb stamp-a's pages
    let pool_b = BufferPool::new(PoolCfg::entry_capped(64));
    assert_eq!(pool_b.attach_store(&root, "stamp-b"), 0);
    assert!(pool_b.get(&key(0), 0).is_none());
    assert!(root.join("stamp-a").join("manifest.json").exists());
    // re-attaching under the original stamp still restores everything
    let pool_a = BufferPool::new(PoolCfg::entry_capped(64));
    assert_eq!(pool_a.attach_store(&root, "stamp-a"), 5);
    assert_eq!(pool_a.get(&key(3), 0).unwrap().tokens, out(3).tokens);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn torn_page_is_a_cold_page_never_an_error() {
    let root = tmp_root("torn");
    let _ = std::fs::remove_dir_all(&root);
    {
        // three entries per page -> keys 0-2, 3-5, 6-8 on pages 0, 1, 2
        let cfg = PoolCfg { max_entries: usize::MAX, byte_budget: usize::MAX, page_entries: 3 };
        let pool = BufferPool::new(cfg);
        pool.attach_store(&root, "st");
        for i in 0..9u64 {
            pool.insert(key(i), out(i), 0);
        }
        pool.flush().unwrap();
    }
    // tear the middle page: a crash mid-write never leaves this (writes are
    // temp+rename), but disk corruption can
    std::fs::write(root.join("st").join("page-000001.json"), "torn{").unwrap();
    let pool = BufferPool::new(PoolCfg::entry_capped(64));
    assert_eq!(pool.attach_store(&root, "st"), 9, "attach reads only the manifest");
    assert_eq!(pool.get(&key(0), 0).unwrap().tokens, out(0).tokens);
    assert!(pool.get(&key(4), 0).is_none(), "torn page must read as a miss");
    assert_eq!(pool.get(&key(7), 0).unwrap().tokens, out(7).tokens);
    assert_eq!(pool.len(), 6, "the torn page's entries are gone, the rest intact");

    // a torn manifest is a whole-store cold start, same contract
    std::fs::write(root.join("st").join("manifest.json"), "{not json").unwrap();
    let pool2 = BufferPool::new(PoolCfg::entry_capped(64));
    assert_eq!(pool2.attach_store(&root, "st"), 0);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn v1_snapshot_migrates_once_and_in_place() {
    let root = tmp_root("v1");
    let _ = std::fs::remove_dir_all(&root);
    // build a faithful v1 monolithic snapshot: {version:1, caches:{stamp:
    // [entries...]}} with no per-entry owner field
    let v1_entry = |k: &MemoKey, o: &GenOutput| {
        let mut e = page::entry_json(k, o, 0);
        if let Json::Obj(m) = &mut e {
            m.remove("owner");
        }
        e
    };
    let mine: Vec<Json> = (0..5u64).map(|i| v1_entry(&key(i), &out(i))).collect();
    let other: Vec<Json> = (0..2u64).map(|i| v1_entry(&key(100 + i), &out(100 + i))).collect();
    let snap = json::obj(vec![
        ("version", json::num(1.0)),
        (
            "caches",
            json::obj(vec![("st", Json::Arr(mine)), ("other-stamp", Json::Arr(other))]),
        ),
    ]);
    std::fs::write(&root, snap.to_string()).unwrap();

    let pool = BufferPool::new(PoolCfg::entry_capped(64));
    assert_eq!(pool.attach_store(&root, "st"), 5);
    // the monolithic file is gone, replaced by the paged layout — for BOTH
    // stamps (the foreign section became its own store directory)
    assert!(root.is_dir(), "v1 file must be converted to the directory layout");
    assert!(root.join("st").join("manifest.json").exists());
    assert!(root.join("other-stamp").join("manifest.json").exists());
    // imported entries carry the snapshot owner: any scenario's hit on them
    // is a cross hit
    assert_eq!(pool.get(&key(2), 7).unwrap().tokens, out(2).tokens);
    assert_eq!(pool.counters().cross_hits, 1);

    // second process: reads the paged store, not the (gone) v1 file
    let pool2 = BufferPool::new(PoolCfg::entry_capped(64));
    assert_eq!(pool2.attach_store(&root, "st"), 5);
    // the foreign stamp's converted store is directly attachable too
    let pool3 = BufferPool::new(PoolCfg::entry_capped(64));
    assert_eq!(pool3.attach_store(&root, "other-stamp"), 2);
    assert_eq!(pool3.get(&key(101), 0).unwrap().tokens, out(101).tokens);
    let _ = std::fs::remove_dir_all(&root);

    // an unparsable v1 snapshot is a cold start, never an error
    std::fs::write(&root, "{\"version\":1,\"caches\":7}").unwrap();
    let pool4 = BufferPool::new(PoolCfg::entry_capped(64));
    assert_eq!(pool4.attach_store(&root, "st"), 0);
    let _ = std::fs::remove_file(&root);
    let _ = std::fs::remove_dir_all(&root);
}
