//! The tail-tolerance layer's contract (see `coordinator/engine.rs` and
//! PERF.md §Tail tolerance):
//!
//! 1. **Inert-machinery identity** — the tail path armed but unable to fire
//!    (an unreachably large slot-timeout-mult) is bit-identical to hedging
//!    off, across the static world, a flaky WAN, a straggler grid and
//!    staggered single-edge churn. Hedging off (the default) therefore
//!    keeps every pre-existing trace byte-for-byte.
//! 2. **Determinism** — hedged traces are bit-identical across 1/2/4 sweep
//!    threads and across open-loop (pump-between-arrivals) vs closed-loop
//!    (submit-all-then-drain) driving.
//! 3. **Hedging fires** — under a straggler-heavy grid the quantile
//!    watchdog actually re-dispatches work, the per-request hedge budget
//!    caps it, and no request is ever lost or left with an empty answer.
//! 4. **Salvage x hedging** — expansion slots salvaged from a straggler or
//!    a crash are never regenerated, and salvage appears only alongside a
//!    failover or a hedge (the two paths that can strand a pull).
//! 5. **Blackout tolerance** — under whole-cluster blackout windows
//!    (`shard-blackout`) every submission still reaches exactly one
//!    terminal trace: in-flight work backs off with capped exponential
//!    retries and ultimately completes on a recovered edge or the cloud.
//! 6. **Queue-pressure starvation** — a saturating burst against a tiny
//!    admission queue defers re-queues (surfaced as `requeue_retries`) but
//!    never silently drops a request.

use std::collections::HashSet;
use std::sync::Arc;

use pice::baselines;
use pice::cluster::DeviceSpec;
use pice::coordinator::backend::{SurrogateBackend, TextBackend};
use pice::coordinator::{Engine, EngineCfg};
use pice::corpus::synth::{synth_corpus, synth_tokenizer};
use pice::corpus::workload::{Arrival, Workload, WorkloadSpec};
use pice::corpus::Corpus;
use pice::dynamics::{DynamicsSpec, EdgeEvent, EdgeFault, FaultSpec, SlowdownSpec};
use pice::metrics::{aggregate, RequestTrace};
use pice::models::Registry;
use pice::sweep::{SweepRunner, SweepScenario};
use pice::tokenizer::Tokenizer;

const MODEL: &str = "llama70b-sim";

fn setup() -> (Arc<Corpus>, Tokenizer, Registry) {
    let tok = synth_tokenizer();
    let corpus = Arc::new(synth_corpus(&tok, 20, 42));
    (corpus, tok, Registry::builtin())
}

fn paper_rpm(reg: &Registry) -> f64 {
    let info = reg.get(MODEL).expect("model");
    let cloud = DeviceSpec::a100_cloud("c");
    1.5 * cloud.max_batch(info, 1000) as f64
}

fn workload(corpus: &Arc<Corpus>, rpm: f64, n: usize, arrival: Arrival, seed: u64) -> Workload {
    Workload::generate(
        corpus,
        WorkloadSpec { rpm, n_requests: n, arrival, categories: vec![], seed },
    )
}

/// Straggler-heavy crash-free world: 6x slowdown windows on a flaky WAN.
fn stragglers() -> DynamicsSpec {
    let mut d = DynamicsSpec::preset("flaky-wan").expect("preset");
    d.faults = FaultSpec {
        slowdown: Some(SlowdownSpec { mtbs_s: 45.0, mean_dur_s: 30.0, mult: 6.0 }),
        horizon_s: 1800.0,
        ..Default::default()
    };
    d
}

/// Staggered single-edge churn: at most one edge down at any instant, so
/// the full-outage park/backoff fork never runs.
fn staggered_churn() -> DynamicsSpec {
    let mut events = Vec::new();
    for k in 0..30usize {
        let t = 1.0 + 4.0 * k as f64;
        events.push(EdgeEvent { t, eid: k % 4, fault: EdgeFault::Crash });
        events.push(EdgeEvent { t: t + 2.0, eid: k % 4, fault: EdgeFault::Recover });
    }
    DynamicsSpec {
        faults: FaultSpec { events, ..Default::default() },
        seed: 7,
        ..Default::default()
    }
}

fn hedged(base: &EngineCfg, q: f64, mult: f64) -> EngineCfg {
    let mut cfg = base.clone();
    cfg.tail.hedge_quantile = Some(q);
    cfg.tail.slot_timeout_mult = mult;
    cfg
}

fn run_closed(
    cfg: &EngineCfg,
    corpus: &Arc<Corpus>,
    tok: &Tokenizer,
    reg: &Registry,
    backend: &SurrogateBackend,
    wl: &Workload,
) -> Vec<RequestTrace> {
    let mut b = backend.clone();
    let mut eng = Engine::new(cfg.clone(), corpus.clone(), tok, reg, &mut b).expect("engine");
    eng.run(wl).expect("run")
}

fn assert_identical(label: &str, a: &[RequestTrace], b: &[RequestTrace]) {
    assert_eq!(a.len(), b.len(), "{label}: trace count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(format!("{x:?}"), format!("{y:?}"), "{label}: trace rid={}", x.rid);
    }
}

/// Salvage can only come from a stranded pull: a crash failover or a hedge.
fn assert_salvage_provenance(label: &str, traces: &[RequestTrace]) {
    for t in traces {
        assert!(
            t.salvaged_slots == 0 || t.failovers > 0 || t.hedges > 0,
            "{label}: rid {} salvaged {} slots with no failover and no hedge",
            t.rid,
            t.salvaged_slots
        );
    }
}

#[test]
fn inert_tail_machinery_is_bit_identical_to_hedging_off() {
    let (corpus, tok, reg) = setup();
    let backend = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let worlds = [
        ("static", DynamicsSpec::default()),
        ("flaky-wan", DynamicsSpec::preset("flaky-wan").expect("preset")),
        ("stragglers", stragglers()),
        ("staggered-churn", staggered_churn()),
    ];
    for (name, world) in worlds {
        let wl = workload(&corpus, paper_rpm(&reg), 16, Arrival::Poisson, 13);
        let off = baselines::pice(MODEL).with_dynamics(world);
        // timeout = 1e12 x the quantile factor x the Eq. 2 estimate: no
        // pull can overrun it, so the watchdog arms nothing — yet tail_on
        // is true and the inflight bookkeeping runs on every pull
        let inert = hedged(&off, 0.95, 1e12);
        let a = run_closed(&off, &corpus, &tok, &reg, &backend, &wl);
        let b = run_closed(&inert, &corpus, &tok, &reg, &backend, &wl);
        assert_identical(&format!("{name}: off vs inert"), &a, &b);
    }
}

#[test]
fn hedged_traces_are_identical_across_sweep_threads() {
    let (corpus, tok, reg) = setup();
    let backend = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let wl = Arc::new(workload(&corpus, paper_rpm(&reg), 16, Arrival::Poisson, 17));
    let base = baselines::pice(MODEL).with_dynamics(stragglers());
    let mut budget1 = hedged(&base, 0.9, 0.25);
    budget1.tail.hedge_budget = 1;
    let grid = vec![
        SweepScenario::new("unhedged", base.clone(), wl.clone()),
        SweepScenario::new("aggressive", hedged(&base, 0.9, 0.25), wl.clone()),
        SweepScenario::new("moderate", hedged(&base, 0.95, 1.0), wl.clone()),
        SweepScenario::new("budget-1", budget1, wl.clone()),
    ];
    let mut reference: Option<Vec<Vec<RequestTrace>>> = None;
    for threads in [1usize, 2, 4] {
        let runner = SweepRunner::new(threads);
        let results = runner.run(&grid, &corpus, &tok, &reg, |_| {
            Box::new(backend.clone()) as Box<dyn TextBackend>
        });
        let traces: Vec<Vec<RequestTrace>> = results
            .into_iter()
            .map(|r| r.expect("scenario").1)
            .collect();
        match &reference {
            None => reference = Some(traces),
            Some(r) => {
                for (i, (a, b)) in r.iter().zip(&traces).enumerate() {
                    assert_identical(&format!("{threads} threads, scenario {i}"), a, b);
                }
            }
        }
    }
}

#[test]
fn open_and_closed_loop_hedged_traces_match() {
    let (corpus, tok, reg) = setup();
    let backend = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let wl = workload(&corpus, paper_rpm(&reg), 16, Arrival::Poisson, 19);
    let cfg = hedged(&baselines::pice(MODEL).with_dynamics(stragglers()), 0.9, 0.5);
    let closed = run_closed(&cfg, &corpus, &tok, &reg, &backend, &wl);
    let mut b = backend.clone();
    let mut eng = Engine::new(cfg, corpus.clone(), &tok, &reg, &mut b).expect("engine");
    for r in &wl.requests {
        eng.pump_until(r.arrival_s).expect("pump");
        eng.submit(r.question_id, r.arrival_s).expect("submit");
    }
    eng.pump_all().expect("pump_all");
    let open = eng.take_traces();
    assert_identical("open vs closed loop", &closed, &open);
}

#[test]
fn watchdog_hedges_under_stragglers_within_budget() {
    let (corpus, tok, reg) = setup();
    let backend = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let n = 16;
    let wl = workload(&corpus, paper_rpm(&reg), n, Arrival::Poisson, 17);
    let base = baselines::pice(MODEL).with_dynamics(stragglers());
    // ladder from hair-trigger to conservative: the aggressive end is
    // near-certain to overrun (timeout ~0.12x the estimate), so the grid
    // as a whole must observe hedges even if the cost model's estimate
    // and the simulated wall disagree by a factor
    let mut total_hedges = 0usize;
    for mult in [0.05, 0.25, 1.0] {
        for budget in [1usize, 2] {
            let mut cfg = hedged(&base, 0.9, mult);
            cfg.tail.hedge_budget = budget;
            let traces = run_closed(&cfg, &corpus, &tok, &reg, &backend, &wl);
            assert_eq!(traces.len(), n, "mult {mult} budget {budget}: requests lost");
            assert!(
                traces.iter().all(|t| !t.answer.is_empty()),
                "mult {mult} budget {budget}: empty answer"
            );
            for t in &traces {
                assert!(
                    t.hedges <= budget,
                    "mult {mult}: rid {} hedged {} times past budget {budget}",
                    t.rid,
                    t.hedges
                );
            }
            assert_salvage_provenance(&format!("mult {mult} budget {budget}"), &traces);
            total_hedges += aggregate(&traces).hedges;
        }
    }
    assert!(
        total_hedges > 0,
        "a hair-trigger watchdog ladder under 6x stragglers never hedged once"
    );
}

#[test]
fn salvage_with_hedging_never_loses_requests() {
    let (corpus, tok, reg) = setup();
    let backend = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let cfg = hedged(&baselines::pice(MODEL), 0.9, 0.25);
    let wl = workload(&corpus, 40.0, 10, Arrival::Burst, 3);
    // clean run bounds the window where edge expansions are in flight
    let clean = run_closed(&cfg, &corpus, &tok, &reg, &backend, &wl);
    let starts: Vec<f64> = clean.iter().map(|t| t.edge_start).filter(|&s| s > 0.0).collect();
    assert!(!starts.is_empty(), "burst must reach the edges");
    let t0 = starts.iter().fold(f64::INFINITY, |a, &b| a.min(b)) + 0.25;
    let t1 = clean.iter().map(|t| t.done).fold(0.0f64, f64::max);
    assert!(t1 > t0, "degenerate work window");
    // crash edge 0 at each grid instant with hedging armed: the crash
    // salvage path and the hedge path share the per-slot salvage marks,
    // and a slot once salvaged must never be regenerated or recounted
    let steps = 12;
    for k in 0..steps {
        let t = t0 + (t1 - t0) * k as f64 / steps as f64;
        let dynamics = DynamicsSpec {
            faults: FaultSpec {
                events: vec![
                    EdgeEvent { t, eid: 0, fault: EdgeFault::Crash },
                    EdgeEvent { t: t + 5.0, eid: 0, fault: EdgeFault::Recover },
                ],
                ..Default::default()
            },
            ..Default::default()
        };
        let traces = run_closed(
            &cfg.clone().with_dynamics(dynamics),
            &corpus,
            &tok,
            &reg,
            &backend,
            &wl,
        );
        assert_eq!(traces.len(), 10, "crash at t={t:.2}: requests lost");
        assert!(
            traces.iter().all(|t| !t.answer.is_empty()),
            "crash at t={t:.2}: empty answer"
        );
        assert_salvage_provenance(&format!("crash at t={t:.2}"), &traces);
    }
}

#[test]
fn blackout_windows_back_off_and_reach_exactly_one_terminal() {
    let (corpus, tok, reg) = setup();
    let backend = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let cfg = hedged(
        &baselines::pice(MODEL)
            .with_dynamics(DynamicsSpec::preset("shard-blackout").expect("preset")),
        0.95,
        1.0,
    );
    // place the load around the first blackout window, read off the pure
    // fault timeline: a burst just before it (in-flight work displaced),
    // arrivals inside it (the all-edges-down park/backoff fork) and
    // arrivals after recovery
    let tl = cfg.dynamics.faults.timeline(cfg.n_edges, cfg.dynamics.seed);
    let t_first = tl
        .iter()
        .find(|e| e.fault == EdgeFault::Crash)
        .map(|e| e.t)
        .expect("blackout preset must crash");
    let qid = corpus.eval_questions()[0].id;
    let mut subs: Vec<f64> = Vec::new();
    subs.extend(vec![t_first - 3.0; 10]);
    subs.extend([t_first + 2.0, t_first + 5.0, t_first + 9.0, t_first + 14.0]);
    subs.extend([t_first + 30.0, t_first + 45.0, t_first + 60.0, t_first + 75.0]);
    let drive = || {
        let mut b = backend.clone();
        let mut eng =
            Engine::new(cfg.clone(), corpus.clone(), &tok, &reg, &mut b).expect("engine");
        for &at in &subs {
            eng.pump_until(at).expect("pump");
            eng.submit(qid, at).expect("submit");
        }
        eng.pump_all().expect("pump_all");
        eng.take_traces()
    };
    let traces = drive();
    assert_eq!(traces.len(), subs.len(), "blackout lost requests");
    let rids: HashSet<usize> = traces.iter().map(|t| t.rid).collect();
    assert_eq!(rids.len(), subs.len(), "duplicate terminal traces");
    assert!(traces.iter().all(|t| !t.answer.is_empty()), "empty answer under blackout");
    // a 10-deep burst 3 s ahead of the window plus arrivals inside it: at
    // least some work must be in flight or arriving while every edge is
    // down, and each displaced request is counted (backoff/park fork or
    // crash re-dispatch — both bump `failovers`)
    let m = aggregate(&traces);
    assert!(m.failovers > 0, "blackout displaced no request: failovers = 0");
    // the whole drill is pure in (cfg, subs): a replay is bit-identical
    assert_identical("blackout replay", &traces, &drive());
}

#[test]
fn saturating_burst_requeues_but_never_drops() {
    let (corpus, tok, reg) = setup();
    let backend = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let mut cfg = baselines::pice(MODEL);
    // a two-deep admission queue against a 40-request burst: the re-queue
    // path must defer (bounded) and degrade, never drop
    cfg.queue_cap = 2;
    let n = 40;
    let wl = workload(&corpus, 40.0, n, Arrival::Burst, 3);
    let traces = run_closed(&cfg, &corpus, &tok, &reg, &backend, &wl);
    assert_eq!(traces.len(), n, "saturation dropped requests");
    let rids: HashSet<usize> = traces.iter().map(|t| t.rid).collect();
    assert_eq!(rids.len(), n, "duplicate terminal traces");
    assert!(traces.iter().all(|t| !t.answer.is_empty()), "empty answer under saturation");
    let m = aggregate(&traces);
    assert!(m.requeue_retries > 0, "a 40-burst against queue_cap=2 never deferred a re-queue");
}
