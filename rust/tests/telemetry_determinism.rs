//! The telemetry subsystem's house rules (see `telemetry/mod.rs`), end to
//! end:
//!
//! 1. **Zero-cost when off** — an engine that never enables telemetry has
//!    no span log and no registry, and a run with telemetry ON produces
//!    traces bit-identical to one with it off: observing never perturbs
//!    scheduling.
//! 2. **Pure when on** — the span log is a pure function of
//!    `(cfg, workload, seed)`: bit-identical across the SweepRunner at
//!    1/2/4 threads and across open-loop service driving vs the
//!    closed-loop `Engine::run`, under churn-heavy dynamics.
//! 3. **Exactly one root span per request** — even when a shard blackout
//!    forces cross-shard re-dispatch (donor evicts without finalizing, the
//!    adopter completes), every request keeps exactly one `Request` span.
//! 4. **Timestamp attribution** — a cloud rescue must not overwrite the
//!    sketch phase's trace timestamps: `sketch_ready == cloud_done` stays
//!    invariant (regression test for the rescue-overwrite bug).

use std::collections::HashMap;
use std::sync::Arc;

use pice::baselines;
use pice::coordinator::backend::{SurrogateBackend, TextBackend};
use pice::coordinator::{Engine, EngineCfg};
use pice::corpus::synth::{synth_corpus, synth_tokenizer};
use pice::corpus::workload::{Arrival, Workload, WorkloadSpec};
use pice::corpus::Corpus;
use pice::dynamics::{DynamicsSpec, EdgeEvent, EdgeFault, FaultSpec};
use pice::fleet::{session_shard, shard_cfg, Fleet, Placement};
use pice::metrics::RequestTrace;
use pice::models::Registry;
use pice::serve::{PiceService, ServeCfg};
use pice::sweep::{SweepRunner, SweepScenario};
use pice::telemetry::{phase_breakdown, Span, SpanKind};
use pice::tokenizer::Tokenizer;

const MODEL: &str = "llama70b-sim";

fn setup() -> (Arc<Corpus>, Tokenizer, Registry) {
    let tok = synth_tokenizer();
    let corpus = Arc::new(synth_corpus(&tok, 20, 42));
    (corpus, tok, Registry::builtin())
}

fn workload(
    corpus: &Arc<Corpus>,
    rpm: f64,
    n: usize,
    arrival: Arrival,
    seed: u64,
) -> Arc<Workload> {
    Arc::new(Workload::generate(
        corpus,
        WorkloadSpec { rpm, n_requests: n, arrival, categories: vec![], seed },
    ))
}

/// The churn-heavy composite from the dynamics suite: edge-churn faults +
/// flaky-wan link.
fn churn_heavy() -> DynamicsSpec {
    let churn = DynamicsSpec::preset("edge-churn").unwrap();
    let flaky = DynamicsSpec::preset("flaky-wan").unwrap();
    DynamicsSpec { link: flaky.link, faults: churn.faults, seed: 23 }
}

fn run_closed_loop(
    cfg: &EngineCfg,
    wl: &Workload,
    corpus: &Arc<Corpus>,
    tok: &Tokenizer,
    reg: &Registry,
    telemetry: bool,
) -> (Vec<RequestTrace>, Vec<Span>) {
    let mut backend = SurrogateBackend::new(corpus.clone(), tok, reg, 9);
    let mut engine =
        Engine::new(cfg.clone(), corpus.clone(), tok, reg, &mut backend).expect("engine");
    if telemetry {
        engine.enable_telemetry(0);
    }
    let traces = engine.run(wl).expect("run");
    let spans = engine.take_spans();
    (traces, spans)
}

fn assert_traces_identical(label: &str, a: &[RequestTrace], b: &[RequestTrace]) {
    assert_eq!(a.len(), b.len(), "{label}: trace count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(format!("{x:?}"), format!("{y:?}"), "{label}: trace rid={}", x.rid);
    }
}

fn assert_spans_identical(label: &str, a: &[Span], b: &[Span]) {
    assert_eq!(a.len(), b.len(), "{label}: span count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(format!("{x:?}"), format!("{y:?}"), "{label}: span #{i}");
    }
}

/// rid -> number of `Request` root spans.
fn root_counts(spans: &[Span]) -> HashMap<usize, usize> {
    let mut counts: HashMap<usize, usize> = HashMap::new();
    for sp in spans.iter().filter(|sp| matches!(sp.kind, SpanKind::Request)) {
        *counts.entry(sp.rid).or_insert(0) += 1;
    }
    counts
}

#[test]
fn telemetry_off_is_inert_and_on_changes_no_traces() {
    let (corpus, tok, reg) = setup();
    let cfg = baselines::pice(MODEL).with_dynamics(churn_heavy());
    let wl = workload(&corpus, 40.0, 20, Arrival::Poisson, 11);

    // off: no sink exists at all
    let mut backend = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let mut off_engine =
        Engine::new(cfg.clone(), corpus.clone(), &tok, &reg, &mut backend).expect("engine");
    assert!(!off_engine.telemetry_on());
    assert!(off_engine.metrics_registry().is_none());
    let off_traces = off_engine.run(&wl).expect("run");
    assert!(off_engine.take_spans().is_empty(), "spans recorded with telemetry off");
    assert!(off_engine.metrics_registry().is_none());

    // on: same traces to the bit — observing never perturbs scheduling
    let (on_traces, spans) = run_closed_loop(&cfg, &wl, &corpus, &tok, &reg, true);
    assert_traces_identical("telemetry on vs off", &off_traces, &on_traces);
    assert!(!spans.is_empty(), "telemetry on must record spans");
    let roots = root_counts(&spans);
    assert_eq!(roots.len(), on_traces.len(), "one root span per completed request");
    assert!(roots.values().all(|&c| c == 1), "duplicate root spans: {roots:?}");
    for sp in &spans {
        assert!(sp.end >= sp.start, "negative span {sp:?}");
    }
    // the breakdown sees every completed request and attributes real time
    let pb = phase_breakdown(&spans).expect("breakdown");
    assert_eq!(pb.n_requests, on_traces.len());
    assert!(pb.cloud.p50_s > 0.0, "cloud phase must carry time: {pb:?}");
}

#[test]
fn span_log_identical_across_1_2_4_sweep_threads() {
    let (corpus, tok, reg) = setup();
    let wl = workload(&corpus, 40.0, 24, Arrival::Poisson, 5);
    let bursty =
        workload(&corpus, 40.0, 18, Arrival::BurstyPoisson { burst_factor: 4.0, burst_len: 6 }, 7);
    let pice = || baselines::pice(MODEL).with_dynamics(churn_heavy());
    let cloud = baselines::cloud_only(MODEL).with_dynamics(churn_heavy());
    let grid = vec![
        SweepScenario::new("pice-churn", pice(), wl.clone()).with_telemetry(),
        SweepScenario::new("cloud-churn", cloud, wl).with_telemetry(),
        SweepScenario::new("pice-bursty", pice(), bursty).with_telemetry(),
    ];
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    // reference: plain sequential engines, no sweep machinery
    let reference: Vec<(Vec<RequestTrace>, Vec<Span>)> = grid
        .iter()
        .map(|sc| run_closed_loop(&sc.cfg, &sc.workload, &corpus, &tok, &reg, true))
        .collect();
    for threads in [1usize, 2, 4] {
        let runner = SweepRunner::new(threads);
        let results = runner.run_traced(&grid, &corpus, &tok, &reg, |_| {
            Box::new(base.clone()) as Box<dyn TextBackend>
        });
        for (i, res) in results.into_iter().enumerate() {
            let (m, traces, spans) = res.expect("scenario");
            let label = format!("{} @{} threads", grid[i].label, threads);
            assert_traces_identical(&label, &reference[i].0, &traces);
            assert_spans_identical(&label, &reference[i].1, &spans);
            assert!(m.phases.is_some(), "{label}: traced cells must carry a phase breakdown");
        }
    }
}

#[test]
fn open_loop_span_log_identical_to_closed_loop() {
    let (corpus, tok, reg) = setup();
    let cfg = baselines::pice(MODEL).with_dynamics(churn_heavy());
    let wl = workload(&corpus, 40.0, 20, Arrival::Poisson, 11);
    let (closed_traces, closed_spans) = run_closed_loop(&cfg, &wl, &corpus, &tok, &reg, true);
    let mut backend = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let engine =
        Engine::new(cfg.clone(), corpus.clone(), &tok, &reg, &mut backend).expect("engine");
    let mut svc =
        PiceService::new(engine, ServeCfg { max_inflight: usize::MAX, deadline_s: None });
    svc.enable_telemetry();
    for r in &wl.requests {
        svc.pump_until(r.arrival_s).expect("pump");
        svc.submit(r.question_id, r.arrival_s).expect("submit");
    }
    svc.pump_all().expect("pump_all");
    let open_spans = svc.take_spans();
    let open_traces = svc.finish().expect("finish");
    assert_traces_identical("open vs closed traces", &closed_traces, &open_traces);
    assert_spans_identical("open vs closed span log", &closed_spans, &open_spans);
}

#[test]
fn exactly_one_root_span_per_request_under_churn_and_blackout() {
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    // shard 0: crash/recover churn; shard 1: every edge dies at t=0.5 and
    // never recovers, so its displaced sessions must be re-homed by the
    // fleet's rebalance sweep (donor evicts WITHOUT finalizing)
    let healthy = baselines::pice(MODEL).with_dynamics({
        let mut events = Vec::new();
        for k in 0..20usize {
            let t = 1.0 + 4.0 * k as f64;
            events.push(EdgeEvent { t, eid: k % 4, fault: EdgeFault::Crash });
            events.push(EdgeEvent { t: t + 2.0, eid: k % 4, fault: EdgeFault::Recover });
        }
        DynamicsSpec { faults: FaultSpec { events, ..Default::default() }, ..Default::default() }
    });
    let dead_events: Vec<EdgeEvent> = (0..healthy.n_edges)
        .map(|eid| EdgeEvent { t: 0.5 + 0.01 * eid as f64, eid, fault: EdgeFault::Crash })
        .collect();
    let dead = baselines::pice(MODEL).with_dynamics(DynamicsSpec {
        faults: FaultSpec { events: dead_events, ..Default::default() },
        ..Default::default()
    });
    let drive = || {
        let e0 = Engine::new_owned(
            shard_cfg(&healthy, 0),
            corpus.clone(),
            &tok,
            &reg,
            Box::new(base.clone()),
        )
        .expect("healthy shard");
        let e1 =
            Engine::new_owned(shard_cfg(&dead, 1), corpus.clone(), &tok, &reg, Box::new(base.clone()))
                .expect("dead shard");
        let mut fleet = Fleet::new(vec![e0, e1], Placement::Hash);
        fleet.enable_rebalance();
        fleet.enable_telemetry();
        // aim half the sessions at each shard, with arrivals straddling the
        // t=0.5 blackout so the dead shard holds both in-flight and queued
        // work when it dies
        let qid = corpus.eval_questions()[0].id;
        let key = |s: usize| (0u64..).find(|&k| session_shard(k, 2) == s).unwrap();
        let mut subs: Vec<(f64, u64)> = Vec::new();
        for j in 0..6usize {
            subs.push((0.1 * j as f64, key(0)));
            subs.push((0.1 * j as f64, key(1)));
        }
        for j in 0..3usize {
            subs.push((1.0 + j as f64, key(1)));
        }
        subs.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        for &(at, k) in &subs {
            fleet.pump_until(at).expect("pump");
            fleet.submit(qid, at, k).expect("submit");
        }
        fleet.pump_all().expect("drain");
        let spans = fleet.take_spans();
        let traces = fleet.take_traces();
        (subs.len(), traces, spans)
    };
    let (n, traces, spans) = drive();
    assert_eq!(traces.len(), n, "blackout lost requests");
    // exactly one Request root per global rid, even for re-homed sessions
    let roots = root_counts(&spans);
    assert_eq!(roots.len(), n, "root span per request: {roots:?}");
    assert!(roots.values().all(|&c| c == 1), "duplicate root spans: {roots:?}");
    for t in &traces {
        assert!(roots.contains_key(&t.rid), "trace rid {} has no root span", t.rid);
    }
    // the drill actually displaced work across shards
    assert!(
        traces.iter().any(|t| t.failovers > 0),
        "blackout drill displaced no request"
    );
    assert!(
        spans.iter().any(|sp| matches!(sp.kind, SpanKind::Failover)),
        "no failover marks recorded"
    );
    // the whole drill (span log included) is pure in (cfg, subs)
    let (_, traces2, spans2) = drive();
    assert_traces_identical("blackout replay traces", &traces, &traces2);
    assert_spans_identical("blackout replay span log", &spans, &spans2);
}

#[test]
fn cloud_rescue_preserves_sketch_phase_timestamps() {
    let (corpus, tok, reg) = setup();
    // both edges die at t=1 and never recover: progressive requests are
    // rescued by the cloud. Before the attribution fix, the rescue job's
    // admit/done events overwrote cloud_start/cloud_done, detaching them
    // from the sketch phase the trace claims to describe.
    let spec = DynamicsSpec {
        faults: FaultSpec {
            events: vec![
                EdgeEvent { t: 1.0, eid: 0, fault: EdgeFault::Crash },
                EdgeEvent { t: 1.0, eid: 1, fault: EdgeFault::Crash },
            ],
            ..Default::default()
        },
        seed: 1,
        ..Default::default()
    };
    let mut cfg = baselines::pice(MODEL).with_dynamics(spec);
    cfg.n_edges = 2;
    let wl = workload(&corpus, 40.0, 8, Arrival::Burst, 9);
    let mut backend = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let mut engine =
        Engine::new(cfg.clone(), corpus.clone(), &tok, &reg, &mut backend).expect("engine");
    engine.enable_telemetry(0);
    let traces = engine.run(&wl).expect("run");
    let spans = engine.take_spans();
    let reg_counters = engine.metrics_registry().expect("registry").clone();
    assert!(
        reg_counters.counter("cloud_rescues") > 0,
        "a permanent blackout at t=1 must trigger cloud rescues"
    );
    assert!(
        spans.iter().any(|sp| matches!(sp.kind, SpanKind::CloudRescue)),
        "no cloud-rescue marks recorded"
    );
    for t in &traces {
        if let Some(sr) = t.sketch_ready {
            // the sketch phase's completion instant IS cloud_done; a rescue
            // regeneration must not move it
            assert_eq!(
                sr, t.cloud_done,
                "rid {}: rescue overwrote the sketch-phase cloud_done",
                t.rid
            );
            assert!(
                t.cloud_start <= t.cloud_done,
                "rid {}: cloud_start after cloud_done",
                t.rid
            );
            assert!(t.cloud_done <= t.done, "rid {}: cloud_done after completion", t.rid);
        }
    }
    assert!(
        traces.iter().any(|t| t.sketch_ready.is_some()),
        "scenario produced no progressive sketches to check"
    );
}
