//! The buffer-pool house rule, enforced end to end: cache budgets change
//! hit rates and load times, **never traces**. Engine traces must be
//! bit-identical across cache budgets (off / tiny / tiny+spill / huge),
//! 1/2/4 sweep threads, and open- vs closed-loop arrivals — eviction,
//! spill, and fault-in are invisible to results because every entry is
//! keyed by the full generation request and the backends are pure in it.

use std::path::PathBuf;
use std::sync::Arc;

use pice::baselines;
use pice::coordinator::backend::{MemoBackend, SurrogateBackend, TextBackend};
use pice::corpus::synth::{synth_corpus, synth_tokenizer};
use pice::corpus::workload::{Arrival, Workload, WorkloadSpec};
use pice::corpus::Corpus;
use pice::models::Registry;
use pice::store::PoolCfg;
use pice::sweep::cache::load_snapshot;
use pice::sweep::{ScenarioResult, SharedMemoCache, SweepRunner, SweepScenario};
use pice::tokenizer::Tokenizer;

fn setup() -> (Arc<Corpus>, Tokenizer, Registry) {
    let tok = synth_tokenizer();
    let corpus = Arc::new(synth_corpus(&tok, 20, 42));
    let reg = Registry::builtin();
    (corpus, tok, reg)
}

fn grid(corpus: &Arc<Corpus>, arrival: Arrival) -> Vec<SweepScenario> {
    let wl = Arc::new(Workload::generate(
        corpus,
        WorkloadSpec { rpm: 40.0, n_requests: 16, arrival, categories: vec![], seed: 5 },
    ));
    vec![
        SweepScenario::new("pice", baselines::pice("llama70b-sim"), wl.clone()),
        SweepScenario::new("cloud", baselines::cloud_only("llama70b-sim"), wl.clone()),
        SweepScenario::new("routing", baselines::routing("llama70b-sim"), wl),
    ]
}

fn assert_identical(label: &str, a: &[ScenarioResult], b: &[ScenarioResult]) {
    assert_eq!(a.len(), b.len(), "{label}: result count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        match (x, y) {
            (Ok((_, ta)), Ok((_, tb))) => {
                assert_eq!(ta.len(), tb.len(), "{label} scenario {i}: trace count");
                for (u, v) in ta.iter().zip(tb) {
                    assert_eq!(u.rid, v.rid, "{label} {i}: rid");
                    assert_eq!(u.answer, v.answer, "{label} {i}: answer rid={}", u.rid);
                    assert_eq!(u.mode, v.mode, "{label} {i}: mode rid={}", u.rid);
                    assert_eq!(
                        u.winner_model, v.winner_model,
                        "{label} {i}: winner rid={}",
                        u.rid
                    );
                    assert!(u.done == v.done, "{label} {i}: done time rid={}", u.rid);
                    assert!(
                        u.confidence == v.confidence,
                        "{label} {i}: confidence rid={}",
                        u.rid
                    );
                }
            }
            (Err(ea), Err(eb)) => {
                assert_eq!(ea.to_string(), eb.to_string(), "{label} {i}: error text")
            }
            _ => panic!("{label} {i}: Ok/Err mismatch"),
        }
    }
}

fn tmp_root() -> PathBuf {
    std::env::temp_dir().join(format!("pice_budget_det_{}", std::process::id()))
}

/// Small pages + a tiny byte budget: pages seal and evict constantly under
/// an engine workload, so the matrix actually exercises eviction (and, with
/// a store attached, spill + fault-in), not just a big cache that never
/// fills.
fn tiny_cfg() -> PoolCfg {
    PoolCfg { max_entries: usize::MAX, byte_budget: 2048, page_entries: 8 }
}

#[test]
fn traces_identical_across_budgets_threads_and_arrivals() {
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, pice::scenario::SURROGATE_SEED);
    let spill_root = tmp_root();
    for arrival in [Arrival::Poisson, Arrival::Burst] {
        let arr_name = match arrival {
            Arrival::Poisson => "open",
            _ => "closed",
        };
        let grid = grid(&corpus, arrival);
        // the reference semantics: no cache layer at all, one thread
        let reference = SweepRunner::new(1).run(&grid, &corpus, &tok, &reg, |_| {
            Box::new(base.clone()) as Box<dyn TextBackend>
        });
        for budget in ["off", "tiny", "tiny-spill", "huge"] {
            for threads in [1usize, 2, 4] {
                let label = format!("budget={budget} threads={threads} loop={arr_name}");
                let cache = match budget {
                    "off" => None,
                    "tiny" => Some(Arc::new(SharedMemoCache::with_cfg(tiny_cfg()))),
                    "tiny-spill" => {
                        let _ = std::fs::remove_dir_all(&spill_root);
                        let c = Arc::new(SharedMemoCache::with_cfg(tiny_cfg()));
                        load_snapshot(&c, &spill_root, "det-stamp");
                        Some(c)
                    }
                    _ => Some(Arc::new(SharedMemoCache::with_cfg(PoolCfg::byte_budget(
                        usize::MAX,
                    )))),
                };
                let got = SweepRunner::new(threads).run(&grid, &corpus, &tok, &reg, |i| {
                    match &cache {
                        Some(c) => Box::new(MemoBackend::shared(base.clone(), c.clone(), i as u32))
                            as Box<dyn TextBackend>,
                        None => Box::new(base.clone()) as Box<dyn TextBackend>,
                    }
                });
                assert_identical(&label, &reference, &got);
                if let Some(c) = &cache {
                    let s = c.stats();
                    if budget == "tiny" || budget == "tiny-spill" {
                        assert!(s.evictions > 0, "{label}: matrix is vacuous, nothing evicted");
                    }
                    if budget == "tiny-spill" {
                        assert!(s.spilled_pages > 0, "{label}: store attached but nothing spilled");
                    }
                }
            }
        }
    }
    let _ = std::fs::remove_dir_all(&spill_root);
}
