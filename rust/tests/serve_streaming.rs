//! The online serving API's contract, on the fig6/fig13 seed scenarios:
//!
//! 1. **Determinism** — traces produced by driving a workload *open-loop*
//!    through [`PiceService`] (submit each request at its arrival instant,
//!    pumping simulated time between submissions) are bit-identical to the
//!    closed-loop [`Engine::run`] driver (the pre-refactor monolithic loop's
//!    semantics), and to the same scenarios executed through the
//!    [`SweepRunner`] at 1/2/4 threads.
//! 2. **Streaming invariants** — per request: event timestamps are monotone
//!    in sim time, `SketchReady` precedes every `ExpansionChunk`, and
//!    exactly one terminal event (`Final` or `Rejected`) is delivered.
//! 3. **Backpressure** — submissions over `max_inflight` are rejected as a
//!    terminal event on the handle, never silently dropped, and never touch
//!    the engine.

use std::sync::Arc;

use pice::baselines;
use pice::cluster::DeviceSpec;
use pice::coordinator::backend::{SurrogateBackend, TextBackend};
use pice::coordinator::{Engine, EngineCfg};
use pice::corpus::synth::{synth_corpus, synth_tokenizer};
use pice::corpus::workload::{Arrival, Request, Workload, WorkloadSpec};
use pice::corpus::Corpus;
use pice::metrics::{Mode, RequestTrace};
use pice::models::Registry;
use pice::serve::{PiceService, RequestHandle, ResponseEvent, ResponseEventKind, ServeCfg};
use pice::sweep::{SweepRunner, SweepScenario};
use pice::tokenizer::Tokenizer;

fn setup() -> (Arc<Corpus>, Tokenizer, Registry) {
    let tok = synth_tokenizer();
    let corpus = Arc::new(synth_corpus(&tok, 20, 42));
    (corpus, tok, Registry::builtin())
}

/// §V-B's operating point, same formula as `Env::paper_rpm`.
fn paper_rpm(reg: &Registry, model: &str) -> f64 {
    let info = reg.get(model).expect("model");
    let cloud = DeviceSpec::a100_cloud("c");
    1.5 * cloud.max_batch(info, 1000) as f64
}

fn workload(corpus: &Arc<Corpus>, rpm: f64, n: usize, seed: u64) -> Arc<Workload> {
    Arc::new(Workload::generate(
        corpus,
        WorkloadSpec { rpm, n_requests: n, arrival: Arrival::Poisson, categories: vec![], seed },
    ))
}

/// The Fig. 6 variant grid (dynamic-vs-static scheduling comparison),
/// seed 13 — the bench's exact scenario structure.
fn fig6_grid(reg: &Registry, corpus: &Arc<Corpus>) -> Vec<SweepScenario> {
    let model = "llama70b-sim";
    let wl = workload(corpus, paper_rpm(reg, model), 36, 13);
    let mut stat = baselines::pice(model);
    stat.scheduler.static_mode = true;
    vec![
        SweepScenario::new("Cloud-only", baselines::cloud_only(model), wl.clone()),
        SweepScenario::new("Routing", baselines::routing(model), wl.clone()),
        SweepScenario::new("PICE-static", stat, wl.clone()),
        SweepScenario::new("PICE-dynamic", baselines::pice(model), wl),
    ]
}

/// The Fig. 13 queue-capacity grid, seed 19 at 1.3x load.
fn fig13_grid(reg: &Registry, corpus: &Arc<Corpus>) -> Vec<SweepScenario> {
    let model = "llama70b-sim";
    let wl = workload(corpus, paper_rpm(reg, model) * 1.3, 30, 19);
    [1usize, 2, 4, 8, 12, 16]
        .iter()
        .map(|&cap| {
            let mut cfg = baselines::pice(model);
            cfg.queue_cap = cap;
            SweepScenario::new(format!("cap{cap}"), cfg, wl.clone())
        })
        .collect()
}

/// Open-loop driver: a fresh service per scenario; submit each arrival at
/// its instant, pump strictly up to the next arrival in between. Returns
/// (traces, per-session event streams).
fn run_via_service(
    cfg: &EngineCfg,
    wl: &Workload,
    corpus: &Arc<Corpus>,
    tok: &Tokenizer,
    reg: &Registry,
    base: &SurrogateBackend,
) -> (Vec<RequestTrace>, Vec<Vec<ResponseEvent>>) {
    let mut backend = base.clone();
    let engine =
        Engine::new(cfg.clone(), corpus.clone(), tok, reg, &mut backend).expect("engine");
    let mut svc =
        PiceService::new(engine, ServeCfg { max_inflight: usize::MAX, deadline_s: None });
    let mut handles: Vec<RequestHandle> = Vec::with_capacity(wl.requests.len());
    for r in &wl.requests {
        svc.pump_until(r.arrival_s).expect("pump");
        handles.push(svc.submit(r.question_id, r.arrival_s).expect("submit"));
    }
    svc.pump_all().expect("pump_all");
    let streams: Vec<Vec<ResponseEvent>> = handles.iter().map(|h| svc.drain(h)).collect();
    let traces = svc.finish().expect("finish");
    (traces, streams)
}

/// Closed-loop reference: `Engine::run` on a fresh backend clone.
fn run_closed_loop(
    cfg: &EngineCfg,
    wl: &Workload,
    corpus: &Arc<Corpus>,
    tok: &Tokenizer,
    reg: &Registry,
    base: &SurrogateBackend,
) -> Vec<RequestTrace> {
    let mut backend = base.clone();
    let mut engine =
        Engine::new(cfg.clone(), corpus.clone(), tok, reg, &mut backend).expect("engine");
    engine.run(wl).expect("run")
}

fn assert_traces_identical(label: &str, a: &[RequestTrace], b: &[RequestTrace]) {
    assert_eq!(a.len(), b.len(), "{label}: trace count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.rid, y.rid, "{label}: rid");
        assert_eq!(x.mode, y.mode, "{label}: mode rid={}", x.rid);
        assert_eq!(x.answer, y.answer, "{label}: answer rid={}", x.rid);
        assert_eq!(x.winner_model, y.winner_model, "{label}: winner rid={}", x.rid);
        assert_eq!(x.cloud_tokens, y.cloud_tokens, "{label}: cloud tokens rid={}", x.rid);
        assert_eq!(x.edge_tokens, y.edge_tokens, "{label}: edge tokens rid={}", x.rid);
        assert_eq!(x.sketch_level, y.sketch_level, "{label}: level rid={}", x.rid);
        assert_eq!(x.parallelism, y.parallelism, "{label}: parallelism rid={}", x.rid);
        assert!(x.arrival == y.arrival, "{label}: arrival rid={}", x.rid);
        assert!(x.cloud_start == y.cloud_start, "{label}: cloud_start rid={}", x.rid);
        assert!(x.cloud_done == y.cloud_done, "{label}: cloud_done rid={}", x.rid);
        assert!(x.edge_start == y.edge_start, "{label}: edge_start rid={}", x.rid);
        assert!(x.sketch_ready == y.sketch_ready, "{label}: sketch_ready rid={}", x.rid);
        assert!(
            x.first_expansion == y.first_expansion,
            "{label}: first_expansion rid={}",
            x.rid
        );
        assert!(x.done == y.done, "{label}: done time rid={}", x.rid);
        assert!(x.confidence == y.confidence, "{label}: confidence rid={}", x.rid);
    }
}

#[test]
fn service_open_loop_bit_identical_to_closed_loop_on_fig6_fig13() {
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    for (grid_name, grid) in
        [("fig6", fig6_grid(&reg, &corpus)), ("fig13", fig13_grid(&reg, &corpus))]
    {
        for sc in &grid {
            let closed = run_closed_loop(&sc.cfg, &sc.workload, &corpus, &tok, &reg, &base);
            let (open, _) = run_via_service(&sc.cfg, &sc.workload, &corpus, &tok, &reg, &base);
            assert_traces_identical(&format!("{grid_name}/{}", sc.label), &closed, &open);
        }
    }
}

#[test]
fn service_reference_matches_sweep_runner_at_1_2_4_threads() {
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    for (grid_name, grid) in
        [("fig6", fig6_grid(&reg, &corpus)), ("fig13", fig13_grid(&reg, &corpus))]
    {
        // the service-driven per-scenario traces are THE reference
        let reference: Vec<Vec<RequestTrace>> = grid
            .iter()
            .map(|sc| run_via_service(&sc.cfg, &sc.workload, &corpus, &tok, &reg, &base).0)
            .collect();
        for threads in [1usize, 2, 4] {
            let got = SweepRunner::new(threads).run(&grid, &corpus, &tok, &reg, |_| {
                Box::new(base.clone()) as Box<dyn TextBackend>
            });
            for ((sc, reference), got) in grid.iter().zip(&reference).zip(got) {
                let (_, traces) = got.expect("scenario ok");
                assert_traces_identical(
                    &format!("{grid_name}/{} @ {threads} threads", sc.label),
                    reference,
                    &traces,
                );
            }
        }
    }
}

#[test]
fn per_request_streams_are_monotone_sketch_first_one_terminal() {
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let cfg = baselines::pice("llama70b-sim");
    let wl = workload(&corpus, paper_rpm(&reg, "llama70b-sim"), 40, 13);
    let (traces, streams) = run_via_service(&cfg, &wl, &corpus, &tok, &reg, &base);
    assert_eq!(traces.len(), wl.requests.len());
    assert!(
        traces.iter().any(|t| t.mode == Mode::Progressive),
        "workload must exercise the progressive path"
    );
    for (sid, stream) in streams.iter().enumerate() {
        assert!(!stream.is_empty(), "request {sid}: empty event stream");
        // every event belongs to this session
        assert!(stream.iter().all(|e| e.rid == sid), "request {sid}: foreign event");
        // first event is the admission decision
        assert!(
            matches!(stream[0].kind, ResponseEventKind::Admitted { .. }),
            "request {sid}: stream must open with Admitted"
        );
        // timestamps monotone in sim time
        for w in stream.windows(2) {
            assert!(
                w[0].t <= w[1].t,
                "request {sid}: event time went backwards ({} > {})",
                w[0].t,
                w[1].t
            );
        }
        // exactly one terminal event, and it is last
        let terminals = stream.iter().filter(|e| e.kind.is_terminal()).count();
        assert_eq!(terminals, 1, "request {sid}: {terminals} terminal events");
        assert!(
            stream.last().unwrap().kind.is_terminal(),
            "request {sid}: terminal event not last"
        );
        // SketchReady precedes every ExpansionChunk
        let sketch_at =
            stream.iter().position(|e| matches!(e.kind, ResponseEventKind::SketchReady { .. }));
        let chunk_positions: Vec<usize> = stream
            .iter()
            .enumerate()
            .filter(|(_, e)| matches!(e.kind, ResponseEventKind::ExpansionChunk { .. }))
            .map(|(i, _)| i)
            .collect();
        if let Some(first_chunk) = chunk_positions.first() {
            let s = sketch_at.expect("expansion chunks require a sketch");
            assert!(s < *first_chunk, "request {sid}: chunk before sketch");
        }
        // per-mode stream shape
        let mode = traces.iter().find(|t| t.rid == sid).map(|t| t.mode).unwrap();
        match mode {
            Mode::Progressive => {
                assert_eq!(
                    stream
                        .iter()
                        .filter(|e| matches!(e.kind, ResponseEventKind::SketchReady { .. }))
                        .count(),
                    1,
                    "request {sid}: progressive requests stream exactly one sketch"
                );
            }
            Mode::CloudFull | Mode::EdgeFull => {
                assert!(sketch_at.is_none(), "request {sid}: non-progressive sketch");
                assert!(chunk_positions.is_empty(), "request {sid}: non-progressive chunk");
            }
        }
        // expansion slots ascend from 0 in delivery order
        let slots: Vec<usize> = stream
            .iter()
            .filter_map(|e| match e.kind {
                ResponseEventKind::ExpansionChunk { slot, .. } => Some(slot),
                _ => None,
            })
            .collect();
        for (i, s) in slots.iter().enumerate() {
            assert_eq!(*s, i, "request {sid}: slot order");
        }
    }
}

#[test]
fn streamed_timestamps_feed_ttfs_ttfe_metrics() {
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let cfg = baselines::pice("llama70b-sim");
    let wl = workload(&corpus, paper_rpm(&reg, "llama70b-sim"), 40, 13);
    let (traces, streams) = run_via_service(&cfg, &wl, &corpus, &tok, &reg, &base);
    let mut progressive = 0;
    for t in &traces {
        match t.mode {
            Mode::Progressive => {
                progressive += 1;
                let sk = t.sketch_ready.expect("progressive trace records sketch instant");
                assert!(sk >= t.arrival && sk <= t.done, "rid {}", t.rid);
                assert!(t.ttfs().unwrap() >= 0.0);
                // the trace timestamp IS the streamed event timestamp
                let ev_t = streams[t.rid]
                    .iter()
                    .find_map(|e| match e.kind {
                        ResponseEventKind::SketchReady { .. } => Some(e.t),
                        _ => None,
                    })
                    .expect("sketch event");
                assert!(ev_t == sk, "rid {}: trace vs event sketch time", t.rid);
                if let Some(fe) = t.first_expansion {
                    assert!(fe >= sk, "rid {}: expansion before sketch", t.rid);
                }
            }
            _ => {
                assert!(t.sketch_ready.is_none() && t.first_expansion.is_none(), "rid {}", t.rid)
            }
        }
    }
    assert!(progressive > 0);
    let m = pice::metrics::aggregate(&traces);
    assert!(m.p50_ttfs_s > 0.0, "p50 TTFS");
    assert!(m.p99_ttfs_s >= m.p50_ttfs_s, "TTFS percentile order");
    assert!(m.p99_ttfe_s >= m.p50_ttfe_s, "TTFE percentile order");
    // the whole point of progressive delivery: every progressive request's
    // sketch lands strictly before its final answer
    assert!(
        traces
            .iter()
            .filter(|t| t.mode == Mode::Progressive)
            .all(|t| t.ttfs().unwrap() < t.latency()),
        "sketch must precede the final answer"
    );
}

#[test]
fn poll_any_yields_global_emission_order() {
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let mut backend = base.clone();
    let engine = Engine::new(
        baselines::pice("llama70b-sim"),
        corpus.clone(),
        &tok,
        &reg,
        &mut backend,
    )
    .expect("engine");
    let mut svc = PiceService::new(engine, ServeCfg::default());
    let wl = workload(&corpus, 30.0, 16, 7);
    for r in &wl.requests {
        svc.pump_until(r.arrival_s).expect("pump");
        svc.submit(r.question_id, r.arrival_s).expect("submit");
    }
    svc.pump_all().expect("pump_all");
    let mut events = Vec::new();
    while let Some(ev) = svc.poll_any() {
        events.push(ev);
    }
    assert!(!events.is_empty());
    // the global drain preserves emission order: sim time never rewinds
    for w in events.windows(2) {
        assert!(w[0].t <= w[1].t, "global order broken: {} > {}", w[0].t, w[1].t);
    }
    let terminals = events.iter().filter(|e| e.kind.is_terminal()).count();
    assert_eq!(terminals, wl.requests.len(), "one terminal per request");
    // fully drained — per-session streams are empty too
    assert!(svc.poll_any().is_none());
}

#[test]
fn backpressure_rejects_as_terminal_events_not_drops() {
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let mut backend = base.clone();
    let engine = Engine::new(
        baselines::pice("llama70b-sim"),
        corpus.clone(),
        &tok,
        &reg,
        &mut backend,
    )
    .expect("engine");
    let mut svc = PiceService::new(engine, ServeCfg { max_inflight: 2, deadline_s: None });
    let qid = corpus.eval_questions()[0].id;
    // a burst of 12 with no pumping in between: 2 admitted, 10 rejected
    let handles: Vec<RequestHandle> =
        (0..12).map(|_| svc.submit(qid, 0.0).expect("submit")).collect();
    assert_eq!(svc.rejected(), 10);
    assert_eq!(svc.inflight(), 2);
    svc.pump_all().expect("pump");
    assert_eq!(svc.inflight(), 0);
    let mut finals = 0;
    let mut rejects = 0;
    for h in &handles {
        let stream = svc.drain(h);
        assert!(svc.is_terminal(h));
        let terminals: Vec<&ResponseEvent> =
            stream.iter().filter(|e| e.kind.is_terminal()).collect();
        assert_eq!(terminals.len(), 1, "session {}: one terminal event", h.id());
        match &terminals[0].kind {
            ResponseEventKind::Final { trace } => {
                finals += 1;
                assert!(!trace.answer.is_empty());
            }
            ResponseEventKind::Rejected { reason } => {
                rejects += 1;
                assert!(reason.contains("max_inflight"), "{reason}");
            }
            _ => unreachable!(),
        }
    }
    assert_eq!(finals, 2);
    assert_eq!(rejects, 10);
    // only admitted requests ever reached the engine
    let traces = svc.finish().expect("finish");
    assert_eq!(traces.len(), 2);
}

#[test]
fn submissions_between_pumps_interleave_with_inflight_work() {
    // genuinely open-loop: a request submitted while earlier ones are mid
    // flight still lands correctly (the re-entrancy the old monolithic
    // Engine::run could not express)
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let mut backend = base.clone();
    let engine = Engine::new(
        baselines::pice("llama70b-sim"),
        corpus.clone(),
        &tok,
        &reg,
        &mut backend,
    )
    .expect("engine");
    let mut svc = PiceService::new(engine, ServeCfg::default());
    let qids: Vec<usize> = corpus.eval_questions().iter().map(|q| q.id).take(6).collect();
    let mut handles = Vec::new();
    let mut t = 0.0;
    for (i, qid) in qids.iter().enumerate() {
        handles.push(svc.submit(*qid, t).expect("submit"));
        // pump partway into the future before the next arrival
        t += 3.0 * (i + 1) as f64;
        svc.pump_until(t).expect("pump");
    }
    svc.pump_all().expect("pump_all");
    assert!(svc.idle());
    for h in &handles {
        assert!(svc.is_terminal(h), "session {} unterminated", h.id());
    }
    let traces = svc.finish().expect("finish");
    assert_eq!(traces.len(), qids.len());
    // the closed-loop equivalent over the same arrival schedule agrees
    let wl = Workload {
        spec: WorkloadSpec {
            rpm: 1.0,
            n_requests: qids.len(),
            arrival: Arrival::Uniform,
            categories: vec![],
            seed: 0,
        },
        requests: qids
            .iter()
            .enumerate()
            .map(|(rid, qid)| {
                // same arrival schedule as the open-loop submissions above
                let arrival_s: f64 = (0..rid).map(|i| 3.0 * (i + 1) as f64).sum();
                Request { rid, question_id: *qid, arrival_s }
            })
            .collect(),
    };
    let closed = run_closed_loop(
        &baselines::pice("llama70b-sim"),
        &wl,
        &corpus,
        &tok,
        &reg,
        &base,
    );
    assert_traces_identical("interleaved open-loop", &closed, &traces);
}
