//! The sharded serving fleet's contract (see `fleet/mod.rs`):
//!
//! 1. **Shard isolation + pump-order independence** — under hash placement
//!    a fleet run is bit-identical to N independent single-engine runs over
//!    the hash partition of the workload, for any shard count and any pump
//!    interleaving (chunked per-arrival pumping vs submit-all-then-drain) —
//!    in a static world AND under explicit edge churn.
//! 2. **Shard-count transparency** — sessions pinned to one hash class
//!    produce bit-identical traces at 1/2/4 shards (power-of-two nesting).
//! 3. **Least-loaded routing** — never places a session on a
//!    crashed-and-unrecovering shard while a healthy one exists, and spreads
//!    an unpumped burst via the in-flight tiebreak under memoized backlog.
//! 4. **Cross-shard memo-cache accounting** — one shard's generations serve
//!    another's as `cross_hits` (distinct owner ids over one shared cache).
//! 5. **Serving invariants under churn** — through `PiceService::over_fleet`
//!    every submission still reaches exactly one terminal event, with the
//!    merged event stream monotone in sim time.
//! 6. **Partial-result salvage** — an edge crash mid-expansion keeps the
//!    sentence slots whose estimated completion had passed, counts them in
//!    `RequestTrace::salvaged_slots`, and re-queues only the unfinished rest.

use std::collections::HashSet;
use std::sync::Arc;

use pice::baselines;
use pice::cluster::DeviceSpec;
use pice::coordinator::backend::{MemoBackend, SurrogateBackend};
use pice::coordinator::{Engine, EngineCfg};
use pice::corpus::synth::{synth_corpus, synth_tokenizer};
use pice::corpus::workload::{Arrival, Workload, WorkloadSpec};
use pice::corpus::Corpus;
use pice::dynamics::{DynamicsSpec, EdgeEvent, EdgeFault, FaultSpec};
use pice::fleet::{session_shard, shard_cfg, Fleet, Placement};
use pice::metrics::{aggregate, RequestTrace};
use pice::models::Registry;
use pice::serve::{PiceService, ServeCfg};
use pice::sweep::cache::SharedMemoCache;
use pice::tokenizer::Tokenizer;

const MODEL: &str = "llama70b-sim";

fn setup() -> (Arc<Corpus>, Tokenizer, Registry) {
    let tok = synth_tokenizer();
    let corpus = Arc::new(synth_corpus(&tok, 20, 42));
    (corpus, tok, Registry::builtin())
}

fn paper_rpm(reg: &Registry) -> f64 {
    let info = reg.get(MODEL).expect("model");
    let cloud = DeviceSpec::a100_cloud("c");
    1.5 * cloud.max_batch(info, 1000) as f64
}

fn workload(corpus: &Arc<Corpus>, rpm: f64, n: usize, arrival: Arrival, seed: u64) -> Workload {
    Workload::generate(
        corpus,
        WorkloadSpec { rpm, n_requests: n, arrival, categories: vec![], seed },
    )
}

/// (question_id, arrival, session_key) triples — the fleet submission list.
fn subs_of(wl: &Workload, key_of: impl Fn(usize) -> u64) -> Vec<(usize, f64, u64)> {
    wl.requests.iter().map(|r| (r.question_id, r.arrival_s, key_of(r.rid))).collect()
}

fn build_fleet<'a>(
    base_cfg: &EngineCfg,
    n: usize,
    placement: Placement,
    corpus: &Arc<Corpus>,
    tok: &'a Tokenizer,
    reg: &'a Registry,
    backend: &SurrogateBackend,
) -> Fleet<'a> {
    let shards = (0..n)
        .map(|i| {
            Engine::new_owned(
                shard_cfg(base_cfg, i),
                corpus.clone(),
                tok,
                reg,
                Box::new(backend.clone()),
            )
            .expect("shard engine")
        })
        .collect();
    Fleet::new(shards, placement)
}

/// Open-loop chunked driver: pump every shard to each arrival, then submit.
fn drive_chunked(fleet: &mut Fleet<'_>, subs: &[(usize, f64, u64)]) -> Vec<RequestTrace> {
    for &(qid, at, key) in subs {
        fleet.pump_until(at).expect("pump");
        fleet.submit(qid, at, key).expect("submit");
    }
    fleet.pump_all().expect("pump_all");
    fleet.take_traces()
}

/// Closed-loop-style driver: schedule every arrival up-front, drain once.
fn drive_upfront(fleet: &mut Fleet<'_>, subs: &[(usize, f64, u64)]) -> Vec<RequestTrace> {
    for &(qid, at, key) in subs {
        fleet.submit(qid, at, key).expect("submit");
    }
    fleet.pump_all().expect("pump_all");
    fleet.take_traces()
}

/// Every field via the Debug form, rids included.
fn assert_identical(label: &str, a: &[RequestTrace], b: &[RequestTrace]) {
    assert_eq!(a.len(), b.len(), "{label}: trace count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(format!("{x:?}"), format!("{y:?}"), "{label}: trace rid={}", x.rid);
    }
}

/// Field equality modulo the request id (global fleet id vs shard-local id).
fn assert_same_modulo_rid(label: &str, a: &RequestTrace, b: &RequestTrace) {
    let mut x = a.clone();
    let mut y = b.clone();
    x.rid = 0;
    y.rid = 0;
    assert_eq!(format!("{x:?}"), format!("{y:?}"), "{label}");
}

/// Staggered explicit churn over 4 edges: down 2 s, up 14 s, covering the
/// first ~120 s of sim time.
fn churn() -> DynamicsSpec {
    let mut events = Vec::new();
    for k in 0..30usize {
        let t = 1.0 + 4.0 * k as f64;
        events.push(EdgeEvent { t, eid: k % 4, fault: EdgeFault::Crash });
        events.push(EdgeEvent { t: t + 2.0, eid: k % 4, fault: EdgeFault::Recover });
    }
    DynamicsSpec {
        faults: FaultSpec { events, ..Default::default() },
        seed: 7,
        ..Default::default()
    }
}

#[test]
fn hash_fleet_equals_independent_shard_partition_at_any_pump_interleaving() {
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    for (world, dynamics) in [("static", DynamicsSpec::default()), ("churn", churn())] {
        let cfg = baselines::pice(MODEL).with_dynamics(dynamics);
        let wl = workload(&corpus, paper_rpm(&reg), 18, Arrival::Poisson, 13);
        let subs = subs_of(&wl, |rid| rid as u64);
        for n in [1usize, 2, 4] {
            let label = format!("{world}/{n} shards");
            let mut f1 = build_fleet(&cfg, n, Placement::Hash, &corpus, &tok, &reg, &base);
            let chunked = drive_chunked(&mut f1, &subs);
            let mut f2 = build_fleet(&cfg, n, Placement::Hash, &corpus, &tok, &reg, &base);
            let upfront = drive_upfront(&mut f2, &subs);
            // pump-order independence: global ids and every field agree
            assert_identical(&format!("{label}: chunked vs upfront"), &chunked, &upfront);
            assert_eq!(chunked.len(), subs.len(), "{label}: requests lost");

            // shard isolation: reference = independent single-engine runs
            // over the hash partition, with the same per-shard cfg
            let mut counts = vec![0usize; n];
            let route: Vec<(usize, usize)> = subs
                .iter()
                .map(|&(_, _, key)| {
                    let s = session_shard(key, n);
                    counts[s] += 1;
                    (s, counts[s] - 1)
                })
                .collect();
            let refs: Vec<Vec<RequestTrace>> = (0..n)
                .map(|s| {
                    let mut backend = base.clone();
                    let mut eng = Engine::new(
                        shard_cfg(&cfg, s),
                        corpus.clone(),
                        &tok,
                        &reg,
                        &mut backend,
                    )
                    .expect("ref engine");
                    for &(qid, at, key) in &subs {
                        if session_shard(key, n) == s {
                            eng.submit(qid, at).expect("submit");
                        }
                    }
                    eng.pump_all().expect("pump_all");
                    eng.take_traces()
                })
                .collect();
            for (g, t) in chunked.iter().enumerate() {
                assert_eq!(t.rid, g, "{label}: global ids are submission order");
                let (s, local) = route[g];
                assert_eq!(f1.route_of(g), s, "{label}: routed shard");
                assert_same_modulo_rid(
                    &format!("{label}: global {g} vs shard {s} local {local}"),
                    t,
                    &refs[s][local],
                );
            }
        }
    }
}

#[test]
fn pinned_sessions_bit_identical_across_shard_counts() {
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let cfg = baselines::pice(MODEL);
    let wl = workload(&corpus, paper_rpm(&reg), 16, Arrival::Poisson, 5);
    // keys all in hash class 3 (mod 4): power-of-two nesting pins the whole
    // cohort to shard 3 % n for every fleet width n in {1, 2, 4}
    let pinned: Vec<u64> = (0u64..).filter(|&k| session_shard(k, 4) == 3).take(16).collect();
    let subs = subs_of(&wl, |rid| pinned[rid]);
    let mut reference: Option<Vec<RequestTrace>> = None;
    for n in [1usize, 2, 4] {
        let mut fleet = build_fleet(&cfg, n, Placement::Hash, &corpus, &tok, &reg, &base);
        let traces = drive_upfront(&mut fleet, &subs);
        for g in 0..subs.len() {
            assert_eq!(fleet.route_of(g), 3 % n, "{n} shards: pinned cohort moved");
        }
        match &reference {
            None => reference = Some(traces),
            Some(r) => assert_identical(&format!("{n} shards vs 1 shard"), r, &traces),
        }
    }
}

#[test]
fn least_loaded_avoids_crashed_and_unrecovering_shard() {
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let healthy_cfg = baselines::pice(MODEL);
    // shard 1: every edge crashes just after t=0.5 and none ever recovers
    let events: Vec<EdgeEvent> = (0..healthy_cfg.n_edges)
        .map(|eid| EdgeEvent { t: 0.5 + 0.01 * eid as f64, eid, fault: EdgeFault::Crash })
        .collect();
    let dead_cfg = healthy_cfg.clone().with_dynamics(DynamicsSpec {
        faults: FaultSpec { events, ..Default::default() },
        ..Default::default()
    });
    let e0 = Engine::new_owned(healthy_cfg, corpus.clone(), &tok, &reg, Box::new(base.clone()))
        .expect("healthy shard");
    let e1 = Engine::new_owned(dead_cfg, corpus.clone(), &tok, &reg, Box::new(base.clone()))
        .expect("dead shard");
    let mut fleet = Fleet::new(vec![e0, e1], Placement::LeastLoaded);
    fleet.pump_until(2.0).expect("process the crash timeline");
    let qid = corpus.eval_questions()[0].id;
    for i in 0..6u64 {
        let rid = fleet.submit(qid, 2.0, i).expect("submit");
        assert_eq!(fleet.route_of(rid), 0, "request {rid} routed to the dead shard");
    }
    fleet.pump_all().expect("drain");
    assert_eq!(fleet.take_traces().len(), 6, "requests lost");
}

#[test]
fn least_loaded_burst_spreads_by_inflight_tiebreak() {
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let cfg = baselines::pice(MODEL);
    let mut fleet = build_fleet(&cfg, 2, Placement::LeastLoaded, &corpus, &tok, &reg, &base);
    let qid = corpus.eval_questions()[0].id;
    // a burst with no pumping in between: backlog estimates are memoized
    // (the event loops never move), so the in-flight tiebreak alone must
    // keep the placement from collapsing onto shard 0
    let mut per_shard = [0usize; 2];
    for i in 0..8u64 {
        let rid = fleet.submit(qid, 0.0, i).expect("submit");
        per_shard[fleet.route_of(rid)] += 1;
    }
    assert!(
        per_shard.iter().all(|&c| c >= 3),
        "burst collapsed onto one shard: {per_shard:?}"
    );
    fleet.pump_all().expect("drain");
    assert_eq!(fleet.take_traces().len(), 8);
}

#[test]
fn cross_shard_cache_hits_are_counted_and_transparent() {
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let cfg = baselines::pice(MODEL);
    let cache = Arc::new(SharedMemoCache::new(4096));
    let shards = (0..2usize)
        .map(|i| {
            // distinct owner ids over ONE shared store — the cross-shard
            // attribution the fleet's Env wiring uses
            let memo = MemoBackend::shared(base.clone(), cache.clone(), i as u32 + 1);
            Engine::new_owned(shard_cfg(&cfg, i), corpus.clone(), &tok, &reg, Box::new(memo))
                .expect("shard engine")
        })
        .collect();
    let mut fleet = Fleet::new(shards, Placement::Hash);
    let qid = corpus.eval_questions()[0].id;
    let key_on = |shard: usize| (0u64..).find(|&k| session_shard(k, 2) == shard).unwrap();
    // the same question lands on BOTH shards as each shard's local rid 0:
    // identical derived sampling seeds, identical memo keys
    let r0 = fleet.submit(qid, 0.0, key_on(0)).expect("submit");
    let r1 = fleet.submit(qid, 0.0, key_on(1)).expect("submit");
    assert_ne!(fleet.route_of(r0), fleet.route_of(r1));
    fleet.pump_all().expect("drain");
    let stats = cache.stats();
    assert!(
        stats.cross_hits > 0,
        "second shard must replay the first shard's generations: {stats:?}"
    );
    // the shared cache is semantically transparent: both shards produce the
    // same answer for the same question in the same (static) world
    let traces = fleet.take_traces();
    assert_eq!(traces.len(), 2);
    assert_same_modulo_rid("cache transparency", &traces[0], &traces[1]);
}

#[test]
fn fleet_service_one_terminal_per_request_under_churn() {
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let cfg = baselines::pice(MODEL).with_dynamics(churn());
    let fleet = build_fleet(&cfg, 2, Placement::Hash, &corpus, &tok, &reg, &base);
    let mut svc =
        PiceService::over_fleet(fleet, ServeCfg { max_inflight: usize::MAX, deadline_s: None });
    // saturating burst: expansions are in flight on both shards while the
    // churn schedule kills edges over and over
    let wl = workload(&corpus, 40.0, 20, Arrival::Burst, 3);
    for r in &wl.requests {
        svc.pump_until(r.arrival_s).expect("pump");
        svc.submit(r.question_id, r.arrival_s).expect("submit");
    }
    svc.pump_all().expect("pump_all");
    let mut events = Vec::new();
    while let Some(ev) = svc.poll_any() {
        events.push(ev);
    }
    // the k-way merged stream is globally time-ordered
    for w in events.windows(2) {
        assert!(w[0].t <= w[1].t, "merged stream rewound: {} > {}", w[0].t, w[1].t);
    }
    let terminals = events.iter().filter(|e| e.kind.is_terminal()).count();
    assert_eq!(terminals, 20, "exactly one terminal event per submission");
    let traces = svc.finish().expect("finish");
    assert_eq!(traces.len(), 20);
    let rids: HashSet<usize> = traces.iter().map(|t| t.rid).collect();
    assert_eq!(rids.len(), 20, "duplicate terminal traces");
    assert!(traces.iter().all(|t| !t.answer.is_empty()), "empty answer under churn");
}

#[test]
fn edge_crash_salvages_completed_expansion_slots() {
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let cfg = baselines::pice(MODEL);
    let wl = workload(&corpus, 40.0, 10, Arrival::Burst, 3);
    // clean run: find the window where edge expansions are actually in
    // flight, so the crash grid below lands inside real work
    let clean = {
        let mut backend = base.clone();
        let mut eng = Engine::new(cfg.clone(), corpus.clone(), &tok, &reg, &mut backend)
            .expect("engine");
        eng.run(&wl).expect("run")
    };
    // edge_start == 0.0 marks requests that never reached an edge
    let starts: Vec<f64> = clean.iter().map(|t| t.edge_start).filter(|&s| s > 0.0).collect();
    assert!(!starts.is_empty(), "burst must reach the edges");
    let t0 = starts.iter().fold(f64::INFINITY, |a, &b| a.min(b)) + 0.25;
    let t1 = clean.iter().map(|t| t.done).fold(0.0f64, f64::max);
    assert!(t1 > t0, "degenerate work window");

    // deterministic grid scan: crash edge 0 at each instant (with a later
    // recover), keep the other edges alive so re-dispatch salvage actually
    // rides along into a fresh pull
    let steps = 24;
    let mut total_salvaged = 0usize;
    for k in 0..steps {
        let t = t0 + (t1 - t0) * k as f64 / steps as f64;
        let dynamics = DynamicsSpec {
            faults: FaultSpec {
                events: vec![
                    EdgeEvent { t, eid: 0, fault: EdgeFault::Crash },
                    EdgeEvent { t: t + 5.0, eid: 0, fault: EdgeFault::Recover },
                ],
                ..Default::default()
            },
            ..Default::default()
        };
        let mut backend = base.clone();
        let mut eng = Engine::new(
            cfg.clone().with_dynamics(dynamics),
            corpus.clone(),
            &tok,
            &reg,
            &mut backend,
        )
        .expect("engine");
        let traces = eng.run(&wl).expect("run");
        // salvage never loses a request, whenever the crash lands
        assert_eq!(traces.len(), 10, "crash at t={t:.2}: requests lost");
        assert!(
            traces.iter().all(|t| !t.answer.is_empty()),
            "crash at t={t:.2}: empty answer"
        );
        let m = aggregate(&traces);
        total_salvaged += m.salvaged_slots;
        // a salvaged slot is one that does NOT get re-queued: the two
        // tallies partition a killed job's sentences
        for tr in &traces {
            assert!(
                tr.salvaged_slots == 0 || tr.failovers > 0,
                "crash at t={t:.2}: salvage without a failover (rid {})",
                tr.rid
            );
        }
    }
    assert!(
        total_salvaged > 0,
        "a 24-point crash grid across the active edge window must salvage \
         at least one completed expansion slot"
    );
}

#[test]
fn shard_blackout_rebalance_loses_no_requests() {
    let (corpus, tok, reg) = setup();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let mut cfg = baselines::pice(MODEL)
        .with_dynamics(DynamicsSpec::preset("shard-blackout").expect("preset"));
    // hedging on enables the tail-tolerance tier, which includes the
    // fleet's cross-shard re-dispatch of a dead shard's displaced queue
    cfg.tail.hedge_quantile = Some(0.95);
    let n_shards = 4usize;
    // each shard's blackout windows are pure in (n_edges, seed + shard) —
    // aim a burst of sessions at every shard just ahead of its own first
    // window, plus arrivals inside it, so displaced queues must be
    // re-homed onto live peers (or ride the backoff/cloud path) and the
    // drill is guaranteed to engage whatever the sketch-phase latency
    let qid = corpus.eval_questions()[0].id;
    let mut subs: Vec<(usize, f64, u64)> = Vec::new();
    for s in 0..n_shards {
        let tl = cfg
            .dynamics
            .faults
            .timeline(cfg.n_edges, cfg.dynamics.seed.wrapping_add(s as u64));
        let t_first = tl
            .iter()
            .find(|e| e.fault == EdgeFault::Crash)
            .map(|e| e.t)
            .expect("blackout preset must crash");
        let key = (0u64..).find(|&k| session_shard(k, n_shards) == s).unwrap();
        for j in 0..5usize {
            subs.push((qid, (t_first - 2.0) + 0.1 * j as f64, key));
        }
        subs.push((qid, t_first + 3.0, key));
        subs.push((qid, t_first + 8.0, key));
    }
    subs.sort_by(|a, b| a.1.total_cmp(&b.1));
    let drive = |subs: &[(usize, f64, u64)]| {
        let mut fleet = build_fleet(&cfg, n_shards, Placement::Hash, &corpus, &tok, &reg, &base);
        fleet.enable_rebalance();
        drive_chunked(&mut fleet, subs)
    };
    let traces = drive(&subs);
    assert_eq!(traces.len(), subs.len(), "shard blackout lost requests");
    let rids: HashSet<usize> = traces.iter().map(|t| t.rid).collect();
    assert_eq!(rids.len(), subs.len(), "duplicate terminal traces");
    assert!(traces.iter().all(|t| !t.answer.is_empty()), "empty answer under blackout");
    // pre-window bursts are in flight when their shard dies: the drill
    // must displace at least one request (crash re-dispatch, the
    // backoff/park fork, or a cross-shard eviction — all bump failovers)
    let m = aggregate(&traces);
    assert!(m.failovers > 0, "blackout drill displaced no request");
    // the whole drill is pure in (cfg, subs): a replay is bit-identical
    assert_identical("blackout fleet replay", &traces, &drive(&subs));
}
