//! Integration: the full PICE coordinator (scheduler → dispatch → selection
//! → execution optimizer → ensemble) over the simulated testbed with the
//! surrogate backend. Asserts the paper's headline *shapes*, not absolute
//! numbers.

use std::sync::Arc;

use pice::baselines;
use pice::coordinator::backend::SurrogateBackend;
use pice::coordinator::{Engine, EngineCfg, RunError};
use pice::corpus::synth::{synth_corpus, synth_tokenizer};
use pice::corpus::workload::{Arrival, Request, Workload, WorkloadSpec};
use pice::corpus::Corpus;
use pice::metrics::{aggregate, Mode, RunMetrics};
use pice::models::Registry;
use pice::tokenizer::Tokenizer;

fn setup() -> (Arc<Corpus>, Tokenizer, Registry) {
    let tok = synth_tokenizer();
    let corpus = Arc::new(synth_corpus(&tok, 20, 42));
    (corpus, tok, Registry::builtin())
}

fn run(
    cfg: EngineCfg,
    corpus: &Arc<Corpus>,
    tok: &Tokenizer,
    reg: &Registry,
    rpm: f64,
    n: usize,
) -> Result<(RunMetrics, Vec<pice::metrics::RequestTrace>), RunError> {
    let mut backend = SurrogateBackend::new(corpus.clone(), tok, reg, 9);
    let mut engine = Engine::new(cfg, corpus.clone(), tok, reg, &mut backend)?;
    let wl = Workload::generate(
        corpus,
        WorkloadSpec { rpm, n_requests: n, arrival: Arrival::Poisson, categories: vec![], seed: 5 },
    );
    let traces = engine.run(&wl)?;
    Ok((aggregate(&traces), traces))
}

#[test]
fn all_requests_complete_under_every_policy() {
    let (corpus, tok, reg) = setup();
    for (name, cfg) in baselines::all("llama70b-sim") {
        if name == "Edge-only" {
            continue; // OOM by design for 70B
        }
        let (m, traces) = run(cfg, &corpus, &tok, &reg, 30.0, 40).unwrap();
        assert_eq!(m.n_requests, 40, "{name} dropped requests");
        for t in &traces {
            assert!(t.done >= t.arrival, "{name}: negative latency");
            assert!(!t.answer.is_empty(), "{name}: empty answer rid={}", t.rid);
        }
    }
}

#[test]
fn cloud_admission_batch_members_share_final_batch_size() {
    // regression: jobs admitted in one Ev::CloudAdmit batch used to be
    // charged ascending batch sizes (inflight+1 inside the admission loop),
    // pricing the first member of a burst as if it ran alone; every member
    // must be charged the final concurrent batch size
    let (corpus, tok, reg) = setup();
    let qid = corpus.eval_questions()[0].id;
    let n = 6;
    let wl = Workload {
        spec: WorkloadSpec {
            rpm: 60.0,
            n_requests: n,
            arrival: Arrival::Burst,
            categories: vec![],
            seed: 1,
        },
        requests: (0..n).map(|rid| Request { rid, question_id: qid, arrival_s: 0.0 }).collect(),
    };
    let mut backend = SurrogateBackend::new(corpus.clone(), &tok, &reg, 9);
    let mut engine = Engine::new(
        baselines::cloud_only("llama70b-sim"),
        corpus.clone(),
        &tok,
        &reg,
        &mut backend,
    )
    .unwrap();
    let traces = engine.run(&wl).unwrap();
    assert_eq!(traces.len(), n);
    // same question + deterministic decode => same token count for every
    // member, so equal cloud durations iff they share one batch size
    let dur0 = traces[0].cloud_done - traces[0].cloud_start;
    assert!(dur0 > 0.0);
    for t in &traces {
        assert_eq!(t.cloud_tokens, traces[0].cloud_tokens, "rid {}", t.rid);
        let dur = t.cloud_done - t.cloud_start;
        assert!(
            (dur - dur0).abs() < 1e-9,
            "rid {} priced at a different batch size: {dur} vs {dur0}",
            t.rid
        );
    }
}

#[test]
fn pice_beats_cloud_only_throughput_for_large_models() {
    // Table III headline: 1.5-2x throughput for the 70B/72B class at
    // RPM = 1.5 x cloud max batch.
    let (corpus, tok, reg) = setup();
    let (cloud, _) = run(baselines::cloud_only("llama70b-sim"), &corpus, &tok, &reg, 30.0, 60).unwrap();
    let (pice, _) = run(baselines::pice("llama70b-sim"), &corpus, &tok, &reg, 30.0, 60).unwrap();
    assert!(
        pice.throughput_qpm > cloud.throughput_qpm * 1.2,
        "PICE {:.1} qpm vs cloud-only {:.1} qpm",
        pice.throughput_qpm,
        cloud.throughput_qpm
    );
    assert!(
        pice.avg_latency_s < cloud.avg_latency_s,
        "PICE latency {:.1}s vs cloud-only {:.1}s",
        pice.avg_latency_s,
        cloud.avg_latency_s
    );
}

#[test]
fn edge_only_oom_for_big_models_runs_for_small() {
    let (corpus, tok, reg) = setup();
    match run(baselines::edge_only("qwen72b-sim"), &corpus, &tok, &reg, 20.0, 10) {
        Err(RunError::Oom(_)) => {}
        other => panic!("expected OOM, got {other:?}"),
    }
    let (m, _) = run(baselines::edge_only("llama8b-sim"), &corpus, &tok, &reg, 20.0, 10).unwrap();
    assert_eq!(m.n_requests, 10);
}

#[test]
fn pice_offloads_server_tokens() {
    // progressive inference reduces cloud token generation (the semantic-
    // level motivation: Fig. 3)
    let (corpus, tok, reg) = setup();
    let (cloud, _) = run(baselines::cloud_only("llama70b-sim"), &corpus, &tok, &reg, 30.0, 50).unwrap();
    let (pice, traces) = run(baselines::pice("llama70b-sim"), &corpus, &tok, &reg, 30.0, 50).unwrap();
    assert!(
        pice.server_tokens < cloud.server_tokens,
        "server tokens: pice {} vs cloud {}",
        pice.server_tokens,
        cloud.server_tokens
    );
    assert!(pice.n_progressive >= 20, "only {} progressive", pice.n_progressive);
    // progressive requests actually used sketches + edge expansion
    let prog = traces.iter().find(|t| t.mode == Mode::Progressive).unwrap();
    assert!(prog.edge_tokens > 0);
    assert!(!prog.winner_model.is_empty());
}

#[test]
fn small_cloud_models_prefer_full_answers() {
    // §V-B: for Llama3-8B-class cloud models the SLM/LLM gap is too small;
    // PICE should mostly not engage progressive mode (c too high).
    let (corpus, tok, reg) = setup();
    let (m, _) = run(baselines::pice("qwen7b-sim"), &corpus, &tok, &reg, 60.0, 40).unwrap();
    assert!(
        (m.n_progressive as f64) < 0.5 * m.n_requests as f64,
        "{} of {} went progressive",
        m.n_progressive,
        m.n_requests
    );
}

#[test]
fn routing_sends_easy_queries_to_edge() {
    let (corpus, tok, reg) = setup();
    let (_, traces) = run(baselines::routing("llama70b-sim"), &corpus, &tok, &reg, 30.0, 60).unwrap();
    let edge = traces.iter().filter(|t| t.mode == Mode::EdgeFull).count();
    let cloud = traces.iter().filter(|t| t.mode == Mode::CloudFull).count();
    assert!(edge > 0, "router never used the edge");
    assert!(cloud > 0, "router never used the cloud");
    // short-answer categories (math/common-sense) should dominate edge traffic
    let edge_short = traces
        .iter()
        .filter(|t| t.mode == Mode::EdgeFull)
        .filter(|t| t.category == "math" || t.category == "common-sense" || t.category == "counterfactual" || t.category == "fermi")
        .count();
    assert!(edge_short * 2 >= edge, "edge traffic not length-biased");
}

#[test]
fn ensemble_produces_multiple_candidates() {
    let (corpus, tok, reg) = setup();
    let cfg = EngineCfg { ensemble_k: 3, ..baselines::pice("llama70b-sim") };
    let (_, traces) = run(cfg, &corpus, &tok, &reg, 10.0, 20).unwrap();
    let with_conf = traces
        .iter()
        .filter(|t| t.mode == Mode::Progressive && t.confidence > 0.0 && t.confidence < 1.0)
        .count();
    assert!(with_conf > 0, "no ensemble selections recorded");
}

#[test]
fn queue_cap_limits_progressive_admissions() {
    let (corpus, tok, reg) = setup();
    let tight = EngineCfg { queue_cap: 1, ..baselines::pice("llama70b-sim") };
    let loose = EngineCfg { queue_cap: 16, ..baselines::pice("llama70b-sim") };
    let (_, tt) = run(tight, &corpus, &tok, &reg, 60.0, 40).unwrap();
    let (_, tl) = run(loose, &corpus, &tok, &reg, 60.0, 40).unwrap();
    // a tight queue produces fewer *edge-expanded* requests (rejected jobs
    // fall back to sketch-only answers)
    let expanded = |ts: &[pice::metrics::RequestTrace]| ts.iter().filter(|t| t.edge_tokens > 0).count();
    assert!(expanded(&tt) <= expanded(&tl), "{} > {}", expanded(&tt), expanded(&tl));
}

#[test]
fn deterministic_across_runs() {
    let (corpus, tok, reg) = setup();
    let (a, ta) = run(baselines::pice("llama70b-sim"), &corpus, &tok, &reg, 30.0, 30).unwrap();
    let (b, tb) = run(baselines::pice("llama70b-sim"), &corpus, &tok, &reg, 30.0, 30).unwrap();
    assert_eq!(a.n_requests, b.n_requests);
    assert!((a.avg_latency_s - b.avg_latency_s).abs() < 1e-9);
    for (x, y) in ta.iter().zip(&tb) {
        assert_eq!(x.answer, y.answer);
    }
}

#[test]
fn rpm_saturation_shape() {
    // Fig. 12: below the cloud batch cap PICE ~ cloud-only; above it,
    // cloud-only latency blows up while PICE keeps climbing.
    let (corpus, tok, reg) = setup();
    let (cloud_lo, _) = run(baselines::cloud_only("llama70b-sim"), &corpus, &tok, &reg, 10.0, 30).unwrap();
    let (cloud_hi, _) = run(baselines::cloud_only("llama70b-sim"), &corpus, &tok, &reg, 60.0, 60).unwrap();
    let (pice_hi, _) = run(baselines::pice("llama70b-sim"), &corpus, &tok, &reg, 60.0, 60).unwrap();
    assert!(cloud_hi.avg_latency_s > cloud_lo.avg_latency_s * 1.5, "no saturation");
    assert!(pice_hi.throughput_qpm > cloud_hi.throughput_qpm);
}

#[test]
fn more_edges_never_hurt_throughput_much() {
    let (corpus, tok, reg) = setup();
    let mut one = baselines::pice("llama70b-sim");
    one.n_edges = 1;
    let mut four = baselines::pice("llama70b-sim");
    four.n_edges = 4;
    let (m1, _) = run(one, &corpus, &tok, &reg, 40.0, 40).unwrap();
    let (m4, _) = run(four, &corpus, &tok, &reg, 40.0, 40).unwrap();
    assert!(
        m4.throughput_qpm >= m1.throughput_qpm * 0.95,
        "4 edges {:.1} < 1 edge {:.1}",
        m4.throughput_qpm,
        m1.throughput_qpm
    );
}

#[test]
fn progressive_latency_bounded_by_constraint_scale() {
    // Eq. 2 is enforced at admission: progressive requests should not be
    // catastrophically slower than the cloud-only estimate f(l).
    let (corpus, tok, reg) = setup();
    let (_, traces) = run(baselines::pice("llama70b-sim"), &corpus, &tok, &reg, 20.0, 40).unwrap();
    for t in traces.iter().filter(|t| t.mode == Mode::Progressive) {
        // generous bound: 4x the per-request cloud estimate at ~0.11 s/tok
        let f_l = 0.11 * t.predicted_len as f64 + 1.0;
        assert!(
            t.latency() < 4.0 * f_l + 30.0,
            "rid {} latency {:.1}s vs f(l) {:.1}s",
            t.rid,
            t.latency(),
            f_l
        );
    }
}

#[test]
fn trace_timestamps_ordered() {
    let (corpus, tok, reg) = setup();
    let (_, traces) = run(baselines::pice("llama70b-sim"), &corpus, &tok, &reg, 30.0, 40).unwrap();
    for t in &traces {
        assert!(t.arrival <= t.cloud_start + 1e-9, "rid {}", t.rid);
        assert!(t.cloud_start <= t.cloud_done + 1e-9, "rid {}", t.rid);
        if t.mode == Mode::Progressive && t.edge_tokens > 0 {
            assert!(t.cloud_done <= t.edge_start + 1e-9, "rid {}", t.rid);
            assert!(t.edge_start <= t.done + 1e-9, "rid {}", t.rid);
            assert!(t.parallelism >= 1, "rid {}", t.rid);
        }
    }
}

#[test]
fn edge_cost_only_charged_to_progressive_and_edgefull() {
    let (corpus, tok, reg) = setup();
    let (_, traces) = run(baselines::cloud_only("llama70b-sim"), &corpus, &tok, &reg, 30.0, 30).unwrap();
    assert!(traces.iter().all(|t| t.edge_tokens == 0));
    let (_, traces) = run(baselines::pice("llama70b-sim"), &corpus, &tok, &reg, 30.0, 30).unwrap();
    for t in &traces {
        if t.mode == Mode::CloudFull {
            assert_eq!(t.edge_tokens, 0, "rid {} cloud-full charged edge cost", t.rid);
        }
    }
}

#[test]
fn bandwidth_has_minimal_effect() {
    // Fig. 14's conclusion as an invariant: 10 Mbps vs 1000 Mbps changes
    // PICE latency by well under 10%.
    let (corpus, tok, reg) = setup();
    let mut slow = baselines::pice("llama70b-sim");
    slow.link = pice::network::Link::new(10.0, 20.0);
    let mut fast = baselines::pice("llama70b-sim");
    fast.link = pice::network::Link::new(1000.0, 20.0);
    let (ms, _) = run(slow, &corpus, &tok, &reg, 30.0, 40).unwrap();
    let (mf, _) = run(fast, &corpus, &tok, &reg, 30.0, 40).unwrap();
    let rel = (ms.avg_latency_s - mf.avg_latency_s).abs() / mf.avg_latency_s;
    assert!(rel < 0.10, "bandwidth changed latency by {:.0}%", rel * 100.0);
}
