//! Fig. 11 — response quality per category before vs after applying the
//! RLAIF-fine-tuned sketch policy in the serving engine.

mod common;

use pice::baselines;
use pice::finetune::{Trainer, TrainerCfg};
use pice::quality::judge::Judge;
use pice::scenario::{bench_n, Env};
use pice::util::json::{num, obj, s, Json};

fn main() -> Result<(), String> {
    let mut env = Env::load()?;
    let judge = Judge::fit(&env.corpus);
    let model = "llama70b-sim";
    common::banner("Fig 11", "fine-tuning impact on response quality by category");

    let trainer = Trainer {
        cfg: TrainerCfg::default(),
        corpus: env.corpus.clone(),
        tok: &env.tok,
    };
    let out = trainer.run(env.backend.as_mut())?;

    let rpm = env.paper_rpm(model);
    let n = bench_n();
    let wl = env.workload(rpm, n, 31);
    let base_cfg = baselines::pice(model);
    let mut ft_cfg = baselines::pice(model);
    ft_cfg.sketch_keep_frac_override = Some(out.policy.keep_frac.clone());

    let (_, t_base) = env.run(base_cfg, &wl).map_err(|e| e.to_string())?;
    let (_, t_ft) = env.run(ft_cfg, &wl).map_err(|e| e.to_string())?;
    let q_base = common::quality_by_category(&env, &judge, &t_base);
    let q_ft = common::quality_by_category(&env, &judge, &t_ft);

    println!("{:<16} {:>10} {:>12}", "category", "base", "fine-tuned");
    let mut rows = Vec::new();
    for cat in env.corpus.categories.clone() {
        let b = q_base.get(&cat).copied().unwrap_or(f64::NAN);
        let a = q_ft.get(&cat).copied().unwrap_or(f64::NAN);
        println!("{cat:<16} {b:>10.2} {a:>12.2}");
        rows.push(obj(vec![("category", s(&cat)), ("base", num(b)), ("finetuned", num(a))]));
    }
    println!(
        "\noverall: base {:.2} vs fine-tuned {:.2}",
        common::mean_quality(&env, &judge, &t_base),
        common::mean_quality(&env, &judge, &t_ft)
    );
    common::dump("fig11_ftquality", Json::Arr(rows));
    println!(
        "paper shape: gains in most categories; slight losses where aggressive\n\
         compression drops semantic detail (knowledge/writing-like)."
    );
    Ok(())
}
