//! Fig. dynamics — resilience under a moving world: failure rate x
//! bandwidth degradation, PICE vs the cloud-only and edge-only baselines.
//!
//! The paper's pitch is that progressive inference *adapts* (Eq. 2 routing
//! under changing Δ(r)); this bench is where that claim meets churn. The
//! grid injects stochastic edge crashes (MTBF axis) and WAN degradation
//! (bandwidth-fraction axis) into every system and reports p99 latency,
//! failover counts and the degradation ratio vs each system's own calm
//! cell. Two guard rows feed CI:
//! * `stable_identical` — the `stable` dynamics preset must be
//!   bit-identical to a plain static run (dynamics is strictly opt-in);
//! * `churn_failovers` — the `edge-churn` preset must actually activate
//!   the failover path (failovers > 0).

mod common;

use std::sync::Arc;

use pice::baselines;
use pice::corpus::workload::{Arrival, WorkloadSpec};
use pice::dynamics::{DynamicsSpec, FaultSpec, LinkDynamics, LinkPhase};
use pice::metrics::RunMetrics;
use pice::scenario::{bench_n, Env};
use pice::sweep::SweepScenario;
use pice::util::json::{num, obj, s, Json};

/// Crash process for one grid cell; None = immortal edges.
fn faults(mtbf_s: Option<f64>) -> FaultSpec {
    FaultSpec { mtbf_s, mttr_s: 15.0, horizon_s: 1800.0, ..Default::default() }
}

/// WAN degradation for one grid cell: a single phase pinning the link to
/// `frac` of the default 100 Mbps. The calm cell (frac = 1.0) is ALSO
/// expressed as a phase, so every grid cell routes with the same live-link
/// transfer calibration — the calm-vs-degraded ratios then isolate the
/// injected degradation instead of mixing the static world's pinned Eq. 2
/// constants with the live model.
fn degraded_link(frac: f64) -> LinkDynamics {
    LinkDynamics {
        phases: vec![LinkPhase { start_s: 0.0, bandwidth_mbps: 100.0 * frac, rtt_ms: 20.0 }],
        ..Default::default()
    }
}

fn main() -> Result<(), String> {
    common::default_memo_path();
    let env = Env::load()?;
    // PICE and Cloud-only run the paper's 70B regime; Edge-only runs the
    // largest Jetson-feasible model (Table III: the 70B class OOMs on
    // edges). Degradation is measured per system against its OWN calm cell,
    // so the cross-model comparison stays a ratio, not an absolute race.
    // Driven below the edge-only capacity (~6 q/min on 4 Orins) so churn,
    // not queueing overload, dominates every system's tail.
    let model = "llama70b-sim";
    let edge_model = "llama8b-sim";
    let rpm = 4.0;
    let n = bench_n();
    let smoke = std::env::var("PICE_BENCH_SMOKE").as_deref() == Ok("1");
    // bursty load: spikes coincide with degradation windows, the worst case
    let wl = Arc::new(env.workload_with(WorkloadSpec {
        rpm,
        n_requests: n,
        arrival: Arrival::BurstyPoisson { burst_factor: 3.0, burst_len: 8 },
        categories: vec![],
        seed: 31,
    }));
    common::banner("Fig dynamics", "failure rate x bandwidth degradation — resilience");

    // MTBF axis calibration: a PICE expansion slot migrates and re-queues
    // in seconds, while an edge-only full answer needs ~100 s uninterrupted
    // — MTBF 90 s interrupts the latter most attempts but lets re-dispatched
    // slots finish between crashes, which is exactly the contrast the
    // figure measures.
    let fault_axis: &[(&str, Option<f64>)] = if smoke {
        &[("none", None), ("heavy", Some(90.0))]
    } else {
        &[("none", None), ("light", Some(180.0)), ("heavy", Some(90.0))]
    };
    let bw_axis: &[f64] = if smoke { &[1.0, 0.3] } else { &[1.0, 0.5, 0.3] };
    let systems = [
        ("PICE", baselines::pice(model)),
        ("Cloud-only", baselines::cloud_only(model)),
        ("Edge-only", baselines::edge_only(edge_model)),
    ];

    let mut cells: Vec<(String, f64, &str, SweepScenario)> = Vec::new();
    for (fname, mtbf) in fault_axis {
        for &frac in bw_axis {
            for (sname, cfg) in &systems {
                let spec = DynamicsSpec {
                    link: degraded_link(frac),
                    faults: faults(*mtbf),
                    seed: 23,
                };
                let cfg = cfg.clone().with_dynamics(spec);
                let label = format!("{sname} f={fname} bw={frac:.1}");
                let sc = SweepScenario::new(label, cfg, wl.clone());
                cells.push((fname.to_string(), frac, *sname, sc));
            }
        }
    }
    let grid: Vec<SweepScenario> = cells.iter().map(|(_, _, _, sc)| sc.clone()).collect();
    let outcomes = env.run_sweep(&grid);

    println!(
        "{:<11} {:>6} {:>5} | {:>10} {:>8} {:>8} {:>9} {:>6}",
        "system", "faults", "bw", "thpt(q/m)", "lat(s)", "p99(s)", "failover", "slots"
    );
    let mut rows = Vec::new();
    let mut metrics: Vec<(String, f64, String, RunMetrics)> = Vec::new();
    for ((fname, frac, sname, _), outcome) in cells.iter().zip(outcomes) {
        let (m, _) = outcome.map_err(|e| e.to_string())?;
        println!(
            "{sname:<11} {fname:>6} {frac:>5.1} | {:>10.2} {:>8.2} {:>8.2} {:>9} {:>6}",
            m.throughput_qpm, m.avg_latency_s, m.p99_latency_s, m.failovers, m.retried_slots
        );
        rows.push(obj(vec![
            ("system", s(sname)),
            ("faults", s(fname)),
            ("bw_frac", num(*frac)),
            ("throughput_qpm", num(m.throughput_qpm)),
            ("latency_s", num(m.avg_latency_s)),
            ("p99_s", num(m.p99_latency_s)),
            ("p99_degraded_s", num(m.p99_degraded_latency_s)),
            ("failovers", num(m.failovers as f64)),
            ("retried_slots", num(m.retried_slots as f64)),
        ]));
        metrics.push((fname.clone(), *frac, sname.to_string(), m));
    }

    // degradation ratio: worst cell p99 / calm cell p99, per system
    let calm = |sys: &str| -> f64 {
        metrics
            .iter()
            .find(|(f, b, name, _)| f == "none" && *b >= 1.0 && name == sys)
            .map(|(_, _, _, m)| m.p99_latency_s)
            .unwrap_or(f64::NAN)
    };
    let worst_bw = bw_axis.iter().cloned().fold(f64::INFINITY, f64::min);
    let worst = |sys: &str| -> f64 {
        metrics
            .iter()
            .find(|(f, b, name, _)| f == "heavy" && *b <= worst_bw && name == sys)
            .map(|(_, _, _, m)| m.p99_latency_s)
            .unwrap_or(f64::NAN)
    };
    let pice_ratio = worst("PICE") / calm("PICE");
    let edge_ratio = worst("Edge-only") / calm("Edge-only");
    let cloud_ratio = worst("Cloud-only") / calm("Cloud-only");
    println!(
        "\np99 degradation (heavy churn + {worst_bw:.1}x bw vs calm): \
         PICE {pice_ratio:.2}x, Edge-only {edge_ratio:.2}x, Cloud-only {cloud_ratio:.2}x"
    );
    rows.push(obj(vec![
        ("bench", s("resilience")),
        ("pice_p99_ratio", num(pice_ratio)),
        ("edge_p99_ratio", num(edge_ratio)),
        ("cloud_p99_ratio", num(cloud_ratio)),
    ]));

    // guard 1: dynamics is strictly opt-in and bit-neutral when inert.
    // Three configs must produce identical traces: the plain static world,
    // the `stable` preset, and a NULL-dynamics spec — a neutral
    // Slowdown{mult: 1.0} event that turns the whole failover machinery ON
    // (in-flight tracking, epochs, fault-event processing, duration
    // multipliers, cached link reads) while perturbing nothing. The last
    // comparison is the non-tautological one: it proves the machinery
    // itself, not just config plumbing, is zero-impact when inert.
    let calm_cfg = baselines::pice(model);
    let stable_cfg =
        calm_cfg.clone().with_dynamics(DynamicsSpec::preset("stable").expect("preset"));
    let null_spec = DynamicsSpec {
        faults: FaultSpec {
            events: vec![pice::dynamics::EdgeEvent {
                t: 0.0,
                eid: 0,
                fault: pice::dynamics::EdgeFault::Slowdown { mult: 1.0 },
            }],
            ..Default::default()
        },
        ..Default::default()
    };
    let null_cfg = calm_cfg.clone().with_dynamics(null_spec);
    let ab = env.run_sweep(&[
        SweepScenario::new("plain", calm_cfg, wl.clone()),
        SweepScenario::new("stable", stable_cfg, wl.clone()),
        SweepScenario::new("null-dynamics", null_cfg, wl.clone()),
    ]);
    let mut ab = ab.into_iter();
    let (_, plain_traces) = ab.next().unwrap().map_err(|e| e.to_string())?;
    let (_, stable_traces) = ab.next().unwrap().map_err(|e| e.to_string())?;
    let (_, null_traces) = ab.next().unwrap().map_err(|e| e.to_string())?;
    let same = |a: &[pice::metrics::RequestTrace], b: &[pice::metrics::RequestTrace]| {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| format!("{x:?}") == format!("{y:?}"))
    };
    let identical = same(&plain_traces, &stable_traces) && same(&plain_traces, &null_traces);
    assert!(identical, "inert dynamics diverged from the static world");
    println!("stable preset + null-dynamics machinery: bit-identical to the static run OK");
    rows.push(obj(vec![
        ("bench", s("stable_identical")),
        ("identical", num(identical as i32 as f64)),
    ]));

    // guard 2: the `edge-churn` preset activates the failover path
    let churn_cfg =
        baselines::pice(model).with_dynamics(DynamicsSpec::preset("edge-churn").expect("preset"));
    let churn = env.run_sweep(&[SweepScenario::new("edge-churn", churn_cfg, wl.clone())]);
    let (cm, _) = churn.into_iter().next().unwrap().map_err(|e| e.to_string())?;
    println!(
        "edge-churn preset: {} failovers, {} slots re-queued, degraded p99 {:.2}s",
        cm.failovers, cm.retried_slots, cm.p99_degraded_latency_s
    );
    assert!(cm.failovers > 0, "edge-churn preset never exercised the failover path");
    rows.push(obj(vec![
        ("bench", s("churn_failovers")),
        ("failovers", num(cm.failovers as f64)),
        ("retried_slots", num(cm.retried_slots as f64)),
    ]));

    common::dump("fig_dynamics", Json::Arr(rows));
    println!(
        "\npaper shape: the edge-only baseline's tail latency blows up with churn\n\
         (whole answers restart from scratch); PICE degrades gracefully — lost\n\
         expansion slots re-queue against surviving edges or fall back to the\n\
         cloud, and the sketch already reached the client."
    );
    common::report_sweep_stats(&env);
    Ok(())
}
