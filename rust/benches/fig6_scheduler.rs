//! Fig. 6 — dynamic vs static scheduling: (a) throughput + latency,
//! (b) overall response quality, (c) per-category net win rate of the
//! dynamic scheduler over the static one.
//!
//! The four variants run concurrently through the scenario-sweep runner
//! over one shared generation cache — same numbers as the old sequential
//! loop (the sweep is bit-identical by construction), but the grid runs in
//! parallel and the variants serve each other's repeated generations.

mod common;

use std::collections::BTreeMap;
use std::sync::Arc;

use pice::baselines;
use pice::quality::judge::Judge;
use pice::scenario::{bench_n, Env};
use pice::sweep::SweepScenario;
use pice::util::json::{num, obj, s, Json};

fn main() -> Result<(), String> {
    common::default_memo_path();
    let env = Env::load()?;
    let judge = Judge::fit(&env.corpus);
    let model = "llama70b-sim";
    let rpm = env.paper_rpm(model);
    let n = bench_n();
    let wl = Arc::new(env.workload(rpm, n, 13));
    common::banner("Fig 6", "efficiency + quality impact of the dynamic scheduler");

    let variants: Vec<(&str, pice::coordinator::EngineCfg)> = vec![
        ("Cloud-only", baselines::cloud_only(model)),
        ("Routing", baselines::routing(model)),
        ("PICE-static", {
            let mut c = baselines::pice(model);
            c.scheduler.static_mode = true;
            c
        }),
        ("PICE-dynamic", baselines::pice(model)),
    ];
    let scenarios: Vec<SweepScenario> = variants
        .iter()
        .map(|(name, cfg)| SweepScenario::new(*name, cfg.clone(), wl.clone()))
        .collect();
    let outcomes = env.run_sweep(&scenarios);

    let mut results = Vec::new();
    println!("(a,b) {:<13} {:>10} {:>8} {:>9}", "system", "thpt(q/m)", "lat(s)", "quality");
    let mut json_rows = Vec::new();
    for (sc, outcome) in scenarios.iter().zip(outcomes) {
        let (m, traces) = outcome.map_err(|e| e.to_string())?;
        let name = sc.label.as_str();
        let q = common::mean_quality(&env, &judge, &traces);
        println!("      {name:<13} {:>10.2} {:>8.2} {:>9.2}", m.throughput_qpm, m.avg_latency_s, q);
        json_rows.push(obj(vec![
            ("system", s(name)),
            ("throughput_qpm", num(m.throughput_qpm)),
            ("latency_s", num(m.avg_latency_s)),
            ("quality", num(q)),
        ]));
        results.push(traces);
    }

    // (c) net win rate per category: dynamic vs static judge scores per rid
    let stat = &results[2];
    let dynm = &results[3];
    let by_rid: BTreeMap<usize, &pice::metrics::RequestTrace> =
        stat.iter().map(|t| (t.rid, t)).collect();
    let mut win: BTreeMap<String, (usize, usize, usize)> = BTreeMap::new();
    for t in dynm {
        let Some(st) = by_rid.get(&t.rid) else { continue };
        let Some(q) = env.corpus.get(t.question_id) else { continue };
        let sd = judge.score(q, &t.answer).overall;
        let ss = judge.score(q, &st.answer).overall;
        let e = win.entry(t.category.clone()).or_insert((0, 0, 0));
        if sd > ss + 0.05 {
            e.0 += 1;
        } else if ss > sd + 0.05 {
            e.1 += 1;
        } else {
            e.2 += 1;
        }
    }
    println!("\n(c) net win rate (dynamic - static), by category:");
    let mut improved = 0;
    let mut total_cats = 0;
    for (cat, (w, l, t)) in &win {
        let nn = (w + l + t).max(1);
        let net = (*w as f64 - *l as f64) / nn as f64 * 100.0;
        println!("      {cat:<16} {net:>7.1}%  (win {w} / lose {l} / tie {t})");
        total_cats += 1;
        if net > 0.0 {
            improved += 1;
        }
    }
    println!(
        "\npaper shape: dynamic adds ~+50% throughput over static, improves quality in\n\
         most categories (paper: 69%) — here {improved}/{total_cats} categories improved."
    );
    common::dump("fig6_scheduler", Json::Arr(json_rows));
    common::report_sweep_stats(&env);
    Ok(())
}
