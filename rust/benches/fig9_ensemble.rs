//! Fig. 9 — ensemble learning's effect on response quality, per category:
//! PICE with ensemble_k=3 vs ensemble off (k=1).

mod common;

use pice::baselines;
use pice::quality::judge::Judge;
use pice::scenario::{bench_n, Env};
use pice::util::json::{num, obj, s, Json};

fn main() -> Result<(), String> {
    let mut env = Env::load()?;
    let judge = Judge::fit(&env.corpus);
    let model = "llama70b-sim";
    // moderate load so idle edges exist for replicas (the ensemble's budget)
    let rpm = env.paper_rpm(model) * 0.6;
    let n = bench_n();
    let wl = env.workload(rpm, n, 21);
    common::banner("Fig 9", "impact of ensemble learning on response quality");

    let mut on = baselines::pice(model);
    on.ensemble_k = 3;
    let mut off = baselines::pice(model);
    off.ensemble_k = 1;
    let (_, t_on) = env.run(on, &wl).map_err(|e| e.to_string())?;
    let (_, t_off) = env.run(off, &wl).map_err(|e| e.to_string())?;

    let q_on = common::quality_by_category(&env, &judge, &t_on);
    let q_off = common::quality_by_category(&env, &judge, &t_off);
    println!("{:<16} {:>10} {:>10} {:>9}", "category", "ensemble", "single", "delta%");
    let mut rows = Vec::new();
    let mut better = 0;
    let mut total = 0;
    for cat in env.corpus.categories.clone() {
        let a = q_on.get(&cat).copied().unwrap_or(f64::NAN);
        let b = q_off.get(&cat).copied().unwrap_or(f64::NAN);
        let d = (a - b) / b * 100.0;
        println!("{cat:<16} {a:>10.2} {b:>10.2} {d:>8.1}%");
        rows.push(obj(vec![
            ("category", s(&cat)),
            ("ensemble", num(a)),
            ("single", num(b)),
            ("delta_pct", num(d)),
        ]));
        if d > 0.0 {
            better += 1;
        }
        total += 1;
    }
    let o_on = common::mean_quality(&env, &judge, &t_on);
    let o_off = common::mean_quality(&env, &judge, &t_off);
    println!(
        "\noverall: ensemble {o_on:.2} vs single {o_off:.2} ({:+.1}%) — improved {better}/{total} categories",
        (o_on - o_off) / o_off * 100.0
    );
    common::dump("fig9_ensemble", Json::Arr(rows));
    println!("paper shape: ensemble helps nearly all categories (~+2.8% overall).");
    Ok(())
}
