//! §Fleet — open-loop saturation ramp over the sharded serving fleet.
//!
//! For each (placement, shard count) the bench drives an ascending
//! request-rate ladder through `Env::fleet_service` open-loop (submit each
//! arrival at its instant, pump between) until the run violates the SLO —
//! p99 end-to-end latency or p99 time-to-first-sketch beyond 3x an
//! unsaturated single-engine anchor. The last passing rung is the fleet's
//! max sustainable rpm; scaling it against the 1-shard fleet is the PR's
//! perf claim (CI guards 4-shard > 2x 1-shard at the same SLO).
//!
//! A second pass pins a session cohort to one hash class (mod 8) and
//! replays it at 1/2/4/8 shards: power-of-two hash nesting must keep the
//! traces bit-identical across shard counts (`hash_identity` in the JSON).
//!
//! Results print paper-style rows and dump machine-readable JSON to both
//! `bench_results/fig_saturation.json` and `BENCH_fig_saturation.json`
//! (repo root) so the scaling trajectory is tracked across PRs.

mod common;

use std::collections::BTreeMap;

use pice::baselines;
use pice::coordinator::EngineCfg;
use pice::corpus::workload::Workload;
use pice::fleet::{session_shard, FleetCfg, Placement};
use pice::metrics::{aggregate, aggregate_shards, RequestTrace};
use pice::scenario::{self, Env};
use pice::serve::ServeCfg;
use pice::util::json::{arr, num, obj, s, Json};

const MODEL: &str = "llama70b-sim";

/// Open-loop fleet driver: submit each arrival at its instant, pumping
/// every shard between. Returns (traces, session-id -> shard routes).
fn drive(
    env: &Env,
    cfg: &EngineCfg,
    fleet: FleetCfg,
    wl: &Workload,
    keys: Option<&[u64]>,
) -> (Vec<RequestTrace>, Vec<Option<usize>>) {
    let mut svc = env
        .fleet_service(
            cfg.clone(),
            ServeCfg { max_inflight: usize::MAX, deadline_s: None },
            fleet,
        )
        .expect("fleet service");
    for r in &wl.requests {
        svc.pump_until(r.arrival_s).expect("pump");
        match keys {
            Some(ks) => {
                svc.submit_with_key(r.question_id, r.arrival_s, ks[r.rid]).expect("submit")
            }
            None => svc.submit(r.question_id, r.arrival_s).expect("submit"),
        };
    }
    svc.pump_all().expect("pump_all");
    let routes = svc.shard_routes().to_vec();
    let traces = svc.finish().expect("finish");
    (traces, routes)
}

/// Group fleet traces by the shard each session was placed on.
fn group_by_shard(
    traces: &[RequestTrace],
    routes: &[Option<usize>],
    shards: usize,
) -> Vec<Vec<RequestTrace>> {
    let mut by_shard: Vec<Vec<RequestTrace>> = vec![Vec::new(); shards];
    for t in traces {
        if let Some(sh) = routes.get(t.rid).copied().flatten() {
            by_shard[sh].push(t.clone());
        }
    }
    by_shard
}

fn main() -> Result<(), String> {
    common::banner("fig_saturation", "open-loop saturation ramp over the serving fleet");
    common::default_memo_path();
    let smoke = std::env::var("PICE_BENCH_SMOKE").as_deref() == Ok("1");
    let env = Env::load()?;
    let cfg = baselines::pice(MODEL);
    let paper = env.paper_rpm(MODEL);
    let per_shard_n = if smoke { 10 } else { (scenario::bench_n() / 2).max(16) };
    let shard_counts: &[usize] = if smoke { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let rungs: u32 = if smoke { 8 } else { 10 };

    // SLO anchor: an unsaturated single engine at 0.3x the paper operating
    // point. The ladder below fails a rung when p99 latency or p99 TTFS
    // exceeds 3x this anchor — "the same answer quality, three times the
    // tail" marks saturation.
    let anchor_wl = env.workload(0.3 * paper, per_shard_n, 11);
    let (anchor_traces, _) = drive(
        &env,
        &cfg,
        FleetCfg { shards: 1, placement: Placement::Hash },
        &anchor_wl,
        None,
    );
    let am = aggregate(&anchor_traces);
    let slo_lat = am.p99_latency_s * 3.0;
    let slo_ttfs = am.p99_ttfs_s * 3.0;
    println!(
        "SLO anchor @ {:.0} rpm: p99 latency {:.2}s, p99 TTFS {:.2}s -> SLO {:.2}s / {:.2}s\n",
        0.3 * paper,
        am.p99_latency_s,
        am.p99_ttfs_s,
        slo_lat,
        slo_ttfs
    );

    let mut rung_rows: Vec<Json> = Vec::new();
    let mut max_rows: Vec<Json> = Vec::new();
    let mut max_rpm: BTreeMap<(&'static str, usize), f64> = BTreeMap::new();
    println!(
        "{:<14} {:>6} {:>9} {:>9} {:>9} {:>9} {:>11} {:>5}",
        "placement", "shards", "rpm", "thpt q/m", "p99 lat", "p99 TTFS", "load/shard", "SLO"
    );
    for placement in [Placement::Hash, Placement::LeastLoaded] {
        for &shards in shard_counts {
            let n = per_shard_n * shards;
            let mut sustained = 0.0f64;
            for k in 0..rungs {
                let rpm = 0.5 * paper * 1.5f64.powi(k as i32);
                let wl = env.workload(rpm, n, 11);
                let fleet = FleetCfg { shards, placement };
                let (traces, routes) = drive(&env, &cfg, fleet, &wl, None);
                let fm = aggregate_shards(&group_by_shard(&traces, &routes, shards));
                let m = &fm.fleet;
                let load_min =
                    fm.per_shard.iter().map(|sm| sm.n_requests).min().unwrap_or(0);
                let load_max =
                    fm.per_shard.iter().map(|sm| sm.n_requests).max().unwrap_or(0);
                let ttfs_ok = am.p99_ttfs_s <= 0.0 || m.p99_ttfs_s <= slo_ttfs;
                let pass = m.p99_latency_s <= slo_lat && ttfs_ok;
                println!(
                    "{:<14} {:>6} {:>9.0} {:>9.2} {:>8.2}s {:>8.2}s {:>7}..{:<3} {:>5}",
                    placement.name(),
                    shards,
                    rpm,
                    m.throughput_qpm,
                    m.p99_latency_s,
                    m.p99_ttfs_s,
                    load_min,
                    load_max,
                    if pass { "ok" } else { "FAIL" }
                );
                rung_rows.push(obj(vec![
                    ("placement", s(placement.name())),
                    ("shards", num(shards as f64)),
                    ("rpm", num(rpm)),
                    ("throughput_qpm", num(m.throughput_qpm)),
                    ("p99_latency_s", num(m.p99_latency_s)),
                    ("p99_ttfs_s", num(m.p99_ttfs_s)),
                    ("salvaged_slots", num(m.salvaged_slots as f64)),
                    ("pass", num(if pass { 1.0 } else { 0.0 })),
                ]));
                if pass {
                    sustained = rpm;
                } else {
                    break;
                }
            }
            println!(
                "  -> {} x{shards}: max sustainable {:.0} rpm ({:.0} per shard)\n",
                placement.name(),
                sustained,
                sustained / shards as f64
            );
            max_rpm.insert((placement.name(), shards), sustained);
            max_rows.push(obj(vec![
                ("placement", s(placement.name())),
                ("shards", num(shards as f64)),
                ("max_rpm", num(sustained)),
                ("max_rpm_per_shard", num(sustained / shards as f64)),
            ]));
        }
    }

    // The PR's perf claim: a 4-shard hash fleet sustains > 2x the rpm of a
    // single engine at the same SLO (CI asserts ratio > 2.0).
    let rpm1 = max_rpm.get(&("hash", 1)).copied().unwrap_or(0.0);
    let rpm4 = max_rpm.get(&("hash", 4)).copied().unwrap_or(0.0);
    let ratio = if rpm1 > 0.0 { rpm4 / rpm1 } else { 0.0 };
    println!("scaling guard: hash x4 {rpm4:.0} rpm vs x1 {rpm1:.0} rpm -> {ratio:.2}x");

    // Determinism guard: a session cohort pinned to one hash class (mod 8)
    // must replay bit-identically at every power-of-two fleet width.
    let pinned: Vec<u64> = (0u64..).filter(|&k| session_shard(k, 8) == 5).take(12).collect();
    let pin_wl = env.workload(0.5 * paper, pinned.len(), 23);
    let mut identity = true;
    let mut reference: Option<Vec<String>> = None;
    for &shards in shard_counts {
        let fleet = FleetCfg { shards, placement: Placement::Hash };
        let (traces, _) = drive(&env, &cfg, fleet, &pin_wl, Some(&pinned));
        let repr: Vec<String> = traces.iter().map(|t| format!("{t:?}")).collect();
        match &reference {
            None => reference = Some(repr),
            Some(r) => {
                if *r != repr {
                    identity = false;
                    println!("hash identity BROKEN at {shards} shards");
                }
            }
        }
    }
    println!(
        "hash identity: pinned cohort bit-identical across shard counts: {}",
        if identity { "yes" } else { "NO" }
    );
    common::report_sweep_stats(&env);

    let json = obj(vec![
        ("slo_p99_latency_s", num(slo_lat)),
        ("slo_p99_ttfs_s", num(slo_ttfs)),
        ("rungs", arr(rung_rows)),
        ("max_sustainable", arr(max_rows)),
        (
            "scaling_guard",
            obj(vec![
                ("placement", s("hash")),
                ("rpm_1shard", num(rpm1)),
                ("rpm_4shard", num(rpm4)),
                ("ratio", num(ratio)),
            ]),
        ),
        ("hash_identity", num(if identity { 1.0 } else { 0.0 })),
    ]);
    common::dump("fig_saturation", json.clone());
    // cross-PR scaling trajectory file at the repo root (see PERF.md); bench
    // executables run with CWD = rust/, so resolve the root via the manifest
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(std::path::Path::to_path_buf)
        .unwrap_or_default();
    let path = root.join("BENCH_fig_saturation.json");
    if std::fs::write(&path, json.to_string()).is_ok() {
        println!("[saved {}]", path.display());
    }
    Ok(())
}
