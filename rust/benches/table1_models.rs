//! Table I — the model ladder: serving speed, memory, MMLU, plus the
//! picoLM reality behind each simulated identity (measured decode tok/s on
//! this host and held-out next-token accuracy as the MMLU stand-in).

mod common;

use pice::runtime::{Generator, LoadedModel, RuntimeHandle, SamplingParams};
use pice::scenario::Env;
use pice::sketch::Prompts;
use pice::util::json::{arr, num, obj, s, Json};

fn main() -> Result<(), String> {
    common::default_memo_path();
    let env = Env::load()?;
    common::banner("Table I", "model performance comparison (paper calibration + measured)");
    println!(
        "{:<15} | {:>10} {:>11} {:>6} | {:>12} {:>10}",
        "Model (sim)", "Speed(t/s)", "Memory(GB)", "MMLU", "real tok/s", "eval acc"
    );

    let rt = if env.real { RuntimeHandle::cpu().ok() } else { None };
    let mut rows = Vec::new();
    for m in &env.registry.models {
        let mut real_tps = f64::NAN;
        if let (Some(rt), Some(dir)) = (&rt, &m.artifact_dir) {
            if let Ok(lm) = LoadedModel::load(rt.clone(), dir) {
                let g = Generator::new(&lm, env.tok.specials.eos);
                let q = env.corpus.eval_questions()[0];
                let prompt = Prompts::full_answer(&env.tok, &q.question);
                let sp = SamplingParams { max_tokens: 48, ..Default::default() };
                let _ = g.generate(&prompt, &sp); // warm
                let t0 = std::time::Instant::now();
                if let Ok(out) = g.generate(&prompt, &sp) {
                    real_tps = out.tokens.len() as f64 / t0.elapsed().as_secs_f64();
                }
            }
        }
        println!(
            "{:<15} | {:>10.2} {:>11.2} {:>6.1} | {:>12.0} {:>10.3}",
            m.name, m.speed_tps, m.memory_gb, m.mmlu, real_tps, m.eval_accuracy
        );
        rows.push(obj(vec![
            ("model", s(&m.name)),
            ("speed_tps", num(m.speed_tps)),
            ("memory_gb", num(m.memory_gb)),
            ("mmlu", num(m.mmlu)),
            ("real_tps", num(if real_tps.is_nan() { -1.0 } else { real_tps })),
            ("eval_accuracy", num(m.eval_accuracy)),
        ]));
    }
    common::dump("table1_models", Json::Arr(rows));
    println!("\npaper shape check: speed and memory are inversely ordered; MMLU rises with size.");
    common::report_memo_stats(&env);
    let _ = arr(vec![]);
    Ok(())
}
