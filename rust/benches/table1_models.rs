//! Table I — the model ladder: serving speed, memory, MMLU, plus the
//! picoLM reality behind each simulated identity (measured decode tok/s on
//! this host and held-out next-token accuracy as the MMLU stand-in), and a
//! per-model serving sanity sweep: one small PICE scenario per registry
//! model as the cloud LLM, executed concurrently by the scenario-sweep
//! runner over the shared generation cache.

mod common;

use std::sync::Arc;

use pice::baselines;
use pice::runtime::{Generator, LoadedModel, RuntimeHandle, SamplingParams};
use pice::scenario::{bench_n, Env};
use pice::sketch::Prompts;
use pice::sweep::SweepScenario;
use pice::util::json::{arr, num, obj, s, Json};

fn main() -> Result<(), String> {
    common::default_memo_path();
    let env = Env::load()?;
    common::banner("Table I", "model performance comparison (paper calibration + measured)");
    println!(
        "{:<15} | {:>10} {:>11} {:>6} | {:>12} {:>10}",
        "Model (sim)", "Speed(t/s)", "Memory(GB)", "MMLU", "real tok/s", "eval acc"
    );

    let rt = if env.real { RuntimeHandle::cpu().ok() } else { None };
    let mut rows = Vec::new();
    for m in &env.registry.models {
        let mut real_tps = f64::NAN;
        if let (Some(rt), Some(dir)) = (&rt, &m.artifact_dir) {
            if let Ok(lm) = LoadedModel::load(rt.clone(), dir) {
                let g = Generator::new(&lm, env.tok.specials.eos);
                let q = env.corpus.eval_questions()[0];
                let prompt = Prompts::full_answer(&env.tok, &q.question);
                let sp = SamplingParams { max_tokens: 48, ..Default::default() };
                let _ = g.generate(&prompt, &sp); // warm
                let t0 = std::time::Instant::now();
                if let Ok(out) = g.generate(&prompt, &sp) {
                    real_tps = out.tokens.len() as f64 / t0.elapsed().as_secs_f64();
                }
            }
        }
        println!(
            "{:<15} | {:>10.2} {:>11.2} {:>6.1} | {:>12.0} {:>10.3}",
            m.name, m.speed_tps, m.memory_gb, m.mmlu, real_tps, m.eval_accuracy
        );
        rows.push(obj(vec![
            ("model", s(&m.name)),
            ("speed_tps", num(m.speed_tps)),
            ("memory_gb", num(m.memory_gb)),
            ("mmlu", num(m.mmlu)),
            ("real_tps", num(if real_tps.is_nan() { -1.0 } else { real_tps })),
            ("eval_accuracy", num(m.eval_accuracy)),
        ]));
    }
    common::dump("table1_models", Json::Arr(rows));
    println!("\npaper shape check: speed and memory are inversely ordered; MMLU rises with size.");

    // Per-model serving sweep: every registry model takes the cloud-LLM
    // role in a small PICE scenario; the grid runs concurrently via the
    // sweep runner (one cache-owner per model, shared generation cache).
    let n = (bench_n() / 2).max(6);
    let scenarios: Vec<SweepScenario> = env
        .registry
        .models
        .iter()
        .map(|m| {
            let rpm = env.paper_rpm(&m.name);
            let wl = Arc::new(env.workload(rpm, n, 23));
            SweepScenario::new(m.name.clone(), baselines::pice(&m.name), wl)
        })
        .collect();
    let outcomes = env.run_sweep(&scenarios);
    println!("\nserving sweep ({} reqs each, PICE policy, concurrent grid):", n);
    println!("{:<15} | {:>10} {:>8} {:>8}", "cloud model", "thpt(q/m)", "lat(s)", "p95(s)");
    let mut serve_rows = Vec::new();
    for (sc, outcome) in scenarios.iter().zip(outcomes) {
        match outcome {
            Ok((m, _)) => {
                println!(
                    "{:<15} | {:>10.2} {:>8.2} {:>8.2}",
                    sc.label, m.throughput_qpm, m.avg_latency_s, m.p95_latency_s
                );
                serve_rows.push(obj(vec![
                    ("model", s(&sc.label)),
                    ("throughput_qpm", num(m.throughput_qpm)),
                    ("latency_s", num(m.avg_latency_s)),
                    ("p95_s", num(m.p95_latency_s)),
                ]));
            }
            Err(e) => {
                // Table-III-style infeasible cells (e.g. a model too big
                // for the simulated cloud node) — report, don't abort
                println!("{:<15} | {e}", sc.label);
                serve_rows.push(obj(vec![("model", s(&sc.label)), ("error", s(&e.to_string()))]));
            }
        }
    }
    common::dump("table1_serving", Json::Arr(serve_rows));
    common::report_sweep_stats(&env);
    let _ = arr(vec![]);
    Ok(())
}
