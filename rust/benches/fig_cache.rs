//! §Buffer-pool store — the paged generation store under memory pressure
//! (PERF.md §Buffer-pool store): warm hit rate and lookup latency for a
//! working set larger than the byte budget, with and without disk spill,
//! plus the cross-run replay the capped in-memory cache cannot serve.
//!
//! Guard rows consumed by CI:
//! * `spill_guard` — the spill-enabled pool's warm hit rate must be >= the
//!   budget-capped in-memory pool's (spill turns evictions into faults
//!   instead of misses).
//! * `trace_identity` — engine traces are bit-identical across cache
//!   budgets (off / tiny / tiny+spill / huge) and 1/2/4 sweep threads;
//!   eviction and spill may change hit rates, never traces.
//!
//! Results dump to `bench_results/fig_cache.json` and the cross-PR
//! trajectory file `BENCH_fig_cache.json` at the repo root.

mod common;

use std::sync::Arc;
use std::time::Instant;

use pice::baselines;
use pice::coordinator::backend::{MemoBackend, SurrogateBackend, TextBackend};
use pice::corpus::synth::{synth_corpus, synth_tokenizer};
use pice::corpus::workload::{Arrival, Workload, WorkloadSpec};
use pice::runtime::{GenOutput, SamplingParams};
use pice::store::PoolCfg;
use pice::sweep::{
    cache::load_snapshot, ScenarioResult, SharedMemoCache, SweepRunner, SweepScenario,
};
use pice::util::json::{num, obj, s, Json};
use pice::util::stats;

/// Synthetic working set: `n` distinct generation entries of ~650 bytes
/// each (64-token prompt, 24-token output), so budgets are easy to reason
/// about as fractions of `n * ~650`.
fn working_set(n: usize) -> Vec<(pice::sweep::cache::MemoKey, GenOutput)> {
    (0..n as u64)
        .map(|i| {
            let prompt: Vec<u32> =
                (0..64).map(|j| (i as u32).wrapping_mul(2654435761).wrapping_add(j)).collect();
            let key = pice::sweep::cache::MemoKey::new(
                "qwen7b-sim",
                &prompt,
                &SamplingParams { max_tokens: 24, seed: i, ..Default::default() },
            );
            let out = GenOutput {
                tokens: (0..24).map(|j| (i as u32).wrapping_add(j)).collect(),
                logps: (0..24).map(|j| -0.01 * (i as f64 + j as f64 + 1.0)).collect(),
                finished: true,
            };
            (key, out)
        })
        .collect()
}

/// Fill the cache from the working set (the cold pass), then replay every
/// key once (the warm pass), timing each warm lookup. Returns
/// (warm_hit_rate, p50_us, p99_us).
fn fill_and_replay(
    cache: &SharedMemoCache,
    set: &[(pice::sweep::cache::MemoKey, GenOutput)],
) -> (f64, f64, f64) {
    for (k, v) in set {
        if cache.get(k, 0).is_none() {
            cache.insert(k.clone(), v.clone(), 0);
        }
    }
    let before = cache.stats();
    let mut lat_us = Vec::with_capacity(set.len());
    for (k, _) in set {
        let t0 = Instant::now();
        std::hint::black_box(cache.get(k, 0));
        lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let after = cache.stats();
    let warm_hits = after.hits - before.hits;
    let rate = warm_hits as f64 / set.len() as f64;
    (rate, stats::percentile(&lat_us, 50.0), stats::percentile(&lat_us, 99.0))
}

fn variant_row(
    rows: &mut Vec<Json>,
    name: &str,
    cache: &SharedMemoCache,
    rate: f64,
    p50: f64,
    p99: f64,
) {
    let cs = cache.stats();
    println!(
        "{name:<26} {:>6.1}% warm hits   p50 {p50:>7.2} µs   p99 {p99:>8.2} µs   ({} evictions, {} spilled, {} faulted, {:.0} KiB resident)",
        rate * 100.0,
        cs.evictions,
        cs.spilled_pages,
        cs.faulted_pages,
        cs.resident_bytes as f64 / 1024.0,
    );
    rows.push(obj(vec![
        ("bench", s(&format!("warm_{name}"))),
        ("warm_hit_rate", num(rate)),
        ("p50_us", num(p50)),
        ("p99_us", num(p99)),
        ("evictions", num(cs.evictions as f64)),
        ("spilled_pages", num(cs.spilled_pages as f64)),
        ("faulted_pages", num(cs.faulted_pages as f64)),
        ("resident_bytes", num(cs.resident_bytes as f64)),
    ]));
}

fn main() -> Result<(), String> {
    common::banner("§Buffer-pool store", "budgeted residency, disk spill, cross-run warm starts");
    let smoke = std::env::var("PICE_BENCH_SMOKE").as_deref() == Ok("1");
    let mut rows = Vec::new();

    let n = if smoke { 1024 } else { 4096 };
    let set = working_set(n);
    // ~650 B/entry -> a budget holding roughly 10% of the working set
    let budget = n * 65;
    println!("working set: {n} entries, byte budget {budget} B (~10% resident)");

    // --- in-process variants: capped, capped+spill, unbounded ---------------
    let store_dir = std::path::Path::new("bench_results").join("fig_cache_store");
    let _ = std::fs::remove_dir_all(&store_dir);

    let capped = SharedMemoCache::with_cfg(PoolCfg::byte_budget(budget));
    let (rate_capped, p50, p99) = fill_and_replay(&capped, &set);
    variant_row(&mut rows, "inmem-capped", &capped, rate_capped, p50, p99);

    let spill = SharedMemoCache::with_cfg(PoolCfg::byte_budget(budget));
    let mut snap = load_snapshot(&spill, &store_dir, "fig-cache-stamp");
    let (rate_spill, p50, p99) = fill_and_replay(&spill, &set);
    variant_row(&mut rows, "spill", &spill, rate_spill, p50, p99);
    snap.save(&spill)?;

    let unbounded = SharedMemoCache::new(usize::MAX);
    let (rate_unb, p50, p99) = fill_and_replay(&unbounded, &set);
    variant_row(&mut rows, "unbounded", &unbounded, rate_unb, p50, p99);

    // Guard: spill converts budget evictions into page faults, so its warm
    // hit rate must dominate the capped in-memory pool's.
    let spill_ok = rate_spill >= rate_capped;
    println!(
        "spill_guard: spill warm {:.1}% >= capped warm {:.1}%  -> {}",
        rate_spill * 100.0,
        rate_capped * 100.0,
        if spill_ok { "ok" } else { "VIOLATED" }
    );
    rows.push(obj(vec![
        ("bench", s("spill_guard")),
        ("spill_warm_hit_rate", num(rate_spill)),
        ("capped_warm_hit_rate", num(rate_capped)),
        ("ok", num(spill_ok as usize as f64)),
    ]));

    // --- cross-run replay: a fresh process against the same store dir -------
    // The capped cache without a store starts cold every run; the spill
    // store sustains the warm hit rate across processes from the manifest
    // alone (pages fault in on demand).
    let cold = SharedMemoCache::with_cfg(PoolCfg::byte_budget(budget));
    let (rate_cold, _, _) = {
        let mut lat = Vec::new();
        let before = cold.stats();
        for (k, _) in &set {
            let t0 = Instant::now();
            std::hint::black_box(cold.get(k, 0));
            lat.push(t0.elapsed().as_secs_f64() * 1e6);
        }
        let after = cold.stats();
        ((after.hits - before.hits) as f64 / set.len() as f64, 0.0, 0.0)
    };
    let warm = SharedMemoCache::with_cfg(PoolCfg::byte_budget(budget));
    let snap2 = load_snapshot(&warm, &store_dir, "fig-cache-stamp");
    let restored = snap2.restored_entries();
    let mut lat_us = Vec::with_capacity(set.len());
    let before = warm.stats();
    for (k, _) in &set {
        let t0 = Instant::now();
        std::hint::black_box(warm.get(k, 0));
        lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let after = warm.stats();
    let rate_replay = (after.hits - before.hits) as f64 / set.len() as f64;
    let (rp50, rp99) = (stats::percentile(&lat_us, 50.0), stats::percentile(&lat_us, 99.0));
    println!(
        "cross-run replay: {restored} entries attached, {:.1}% warm hits (fresh capped cache: {:.1}%)   p50 {rp50:.2} µs   p99 {rp99:.2} µs   ({} pages faulted)",
        rate_replay * 100.0,
        rate_cold * 100.0,
        warm.stats().faulted_pages,
    );
    rows.push(obj(vec![
        ("bench", s("cross_run_replay")),
        ("restored_entries", num(restored as f64)),
        ("warm_hit_rate", num(rate_replay)),
        ("fresh_capped_hit_rate", num(rate_cold)),
        ("p50_us", num(rp50)),
        ("p99_us", num(rp99)),
        ("faulted_pages", num(warm.stats().faulted_pages as f64)),
    ]));

    // --- trace-identity guard: budgets x threads x arrival ------------------
    // Engine traces must not depend on the cache budget, on spill/fault
    // activity, or on sweep-thread interleaving. Reference: no cache at all.
    let tok = synth_tokenizer();
    let corpus = Arc::new(synth_corpus(&tok, 20, 42));
    let reg = pice::models::Registry::builtin();
    let base = SurrogateBackend::new(corpus.clone(), &tok, &reg, pice::scenario::SURROGATE_SEED);
    let nreq = if smoke { 12 } else { 24 };
    let grid_for = |arrival: Arrival| -> Vec<SweepScenario> {
        let wl = Arc::new(Workload::generate(
            &corpus,
            WorkloadSpec { rpm: 40.0, n_requests: nreq, arrival, categories: vec![], seed: 5 },
        ));
        vec![
            SweepScenario::new("pice", baselines::pice("llama70b-sim"), wl.clone()),
            SweepScenario::new("cloud", baselines::cloud_only("llama70b-sim"), wl.clone()),
            SweepScenario::new("routing", baselines::routing("llama70b-sim"), wl),
        ]
    };
    let same = |a: &[ScenarioResult], b: &[ScenarioResult]| {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| match (x, y) {
                (Ok((_, ta)), Ok((_, tb))) => {
                    ta.len() == tb.len()
                        && ta.iter().zip(tb).all(|(u, v)| {
                            u.answer == v.answer && u.done == v.done && u.mode == v.mode
                        })
                }
                _ => false,
            })
    };
    let spill_dir = std::path::Path::new("bench_results").join("fig_cache_trace_store");
    let mut all_identical = true;
    let mut cells = 0usize;
    for (arr_name, arrival) in [("open", Arrival::Poisson), ("closed", Arrival::Burst)] {
        let grid = grid_for(arrival);
        let reference = SweepRunner::new(1).run(&grid, &corpus, &tok, &reg, |_| {
            Box::new(base.clone()) as Box<dyn TextBackend>
        });
        for (budget_name, cfg) in [
            ("off", None),
            ("tiny", Some(PoolCfg::byte_budget(2048))),
            ("tiny-spill", Some(PoolCfg::byte_budget(2048))),
            ("huge", Some(PoolCfg::byte_budget(usize::MAX))),
        ] {
            for threads in [1usize, 2, 4] {
                let cache = cfg.map(|c| Arc::new(SharedMemoCache::with_cfg(c)));
                if budget_name == "tiny-spill" {
                    let _ = std::fs::remove_dir_all(&spill_dir);
                    if let Some(c) = &cache {
                        load_snapshot(c, &spill_dir, "trace-stamp");
                    }
                }
                let got = SweepRunner::new(threads).run(&grid, &corpus, &tok, &reg, |i| {
                    match &cache {
                        Some(c) => Box::new(MemoBackend::shared(base.clone(), c.clone(), i as u32))
                            as Box<dyn TextBackend>,
                        None => Box::new(base.clone()) as Box<dyn TextBackend>,
                    }
                });
                let ok = same(&reference, &got);
                all_identical &= ok;
                cells += 1;
                if !ok {
                    println!(
                        "trace MISMATCH: budget={budget_name} threads={threads} loop={arr_name}"
                    );
                }
            }
        }
    }
    let _ = std::fs::remove_dir_all(&spill_dir);
    println!(
        "trace_identity: {cells} cells (budget off/tiny/tiny-spill/huge x 1/2/4 threads x open/closed) -> {}",
        if all_identical { "all identical" } else { "MISMATCH (BUG)" }
    );
    rows.push(obj(vec![
        ("bench", s("trace_identity")),
        ("cells", num(cells as f64)),
        ("identical", num(all_identical as usize as f64)),
    ]));

    let json = Json::Arr(rows);
    common::dump("fig_cache", json.clone());
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(std::path::Path::to_path_buf)
        .unwrap_or_default();
    let path = root.join("BENCH_fig_cache.json");
    if std::fs::write(&path, json.to_string()).is_ok() {
        println!("[saved {}]", path.display());
    }
    Ok(())
}
