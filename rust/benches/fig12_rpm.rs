//! Fig. 12 — sensitivity to load: throughput and latency vs requests per
//! minute for PICE / Cloud-only / Routing.

mod common;

use pice::baselines;
use pice::scenario::{bench_n, Env};
use pice::util::json::{num, obj, s, Json};

fn main() -> Result<(), String> {
    let mut env = Env::load()?;
    let model = "llama70b-sim";
    let n = bench_n();
    common::banner("Fig 12", "impact of RPM (requests per minute)");
    println!(
        "{:>5} | {:>10} {:>8} | {:>10} {:>8} | {:>10} {:>8}",
        "RPM", "cloud q/m", "lat", "routing", "lat", "PICE", "lat"
    );
    let mut rows = Vec::new();
    for rpm in [10.0, 20.0, 30.0, 40.0, 50.0, 60.0] {
        let wl = env.workload(rpm, n, 5);
        let mut cells = Vec::new();
        for (name, cfg) in [
            ("Cloud-only", baselines::cloud_only(model)),
            ("Routing", baselines::routing(model)),
            ("PICE", baselines::pice(model)),
        ] {
            let (m, _) = env.run(cfg, &wl).map_err(|e| e.to_string())?;
            rows.push(obj(vec![
                ("rpm", num(rpm)),
                ("system", s(name)),
                ("throughput_qpm", num(m.throughput_qpm)),
                ("latency_s", num(m.avg_latency_s)),
            ]));
            cells.push((m.throughput_qpm, m.avg_latency_s));
        }
        println!(
            "{rpm:>5.0} | {:>10.1} {:>8.1} | {:>10.1} {:>8.1} | {:>10.1} {:>8.1}",
            cells[0].0, cells[0].1, cells[1].0, cells[1].1, cells[2].0, cells[2].1
        );
    }
    common::dump("fig12_rpm", Json::Arr(rows));
    println!(
        "\npaper shape: below the cloud batch cap all systems track the offered load;\n\
         beyond it Cloud-only flat-lines with exploding latency while PICE keeps scaling."
    );
    Ok(())
}
