//! §Perf — hot-path microbenches for the optimization pass (EXPERIMENTS.md
//! §Perf): L3 coordinator primitives, the batched/parallel backend layer,
//! the memo-cache, the end-to-end event loop (sequential vs parallel
//! substrate), and the real PJRT decode step per model variant.
//!
//! Results print paper-style rows and dump machine-readable JSON to both
//! `bench_results/perf_hotpath.json` and `BENCH_perf_hotpath.json` (repo
//! root) so the perf trajectory is tracked across PRs — see PERF.md.

mod common;

use std::sync::Arc;
use std::time::Instant;

use pice::baselines;
use pice::coordinator::backend::{
    GenRequest, MemoBackend, ParallelBackend, PersistentMemoBackend, SurrogateBackend, TextBackend,
};
use pice::coordinator::dispatch::{Job, MultiListQueue};
use pice::coordinator::scheduler::{CloudScheduler, SchedInput};
use pice::coordinator::Engine;
use pice::costmodel::Estimates;
use pice::corpus::synth::{synth_corpus, synth_tokenizer};
use pice::corpus::workload::{Arrival, Workload, WorkloadSpec};
use pice::models::Registry;
use pice::network::TransferModel;
use pice::parallel::{plan_batch, EdgeCostModel};
use pice::profiler::LatencyFit;
use pice::quality::rouge::{rouge1_f1, rouge_l_f1};
use pice::runtime::{Generator, LoadedModel, RuntimeHandle, SamplingParams};
use pice::scenario::Env;
use pice::sketch::Prompts;
use pice::sweep::{SharedMemoCache, SweepRunner, SweepScenario};
use pice::util::json::{num, obj, s, Json};
use pice::util::rng::Rng;

fn time_it<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

/// Expansion-shaped request batch over the synth eval split — the same
/// (model, prompt, per-request seed) stream the engine's edge pulls emit.
fn expansion_requests(
    tok: &pice::tokenizer::Tokenizer,
    corpus: &pice::corpus::Corpus,
) -> Vec<GenRequest> {
    let mut reqs = Vec::new();
    for q in corpus.eval_questions() {
        let sketch = q.sketch_tokens(tok.specials.semicolon);
        for (si, sent) in q.sentences.iter().enumerate() {
            reqs.push(GenRequest::new(
                "qwen7b-sim",
                &Prompts::expand(tok, &q.question, &sketch, &sent.sketch),
                SamplingParams {
                    max_tokens: 24,
                    stop_token: Some(tok.specials.period),
                    seed: (q.id as u64) << 8 ^ si as u64,
                    ..Default::default()
                },
            ));
        }
    }
    reqs
}

fn report(rows: &mut Vec<Json>, name: &str, secs: f64, unit: &str) {
    let v = if secs < 1e-3 { format!("{:.2} µs", secs * 1e6) } else { format!("{:.3} ms", secs * 1e3) };
    println!("{name:<44} {v:>12}  ({unit})");
    rows.push(obj(vec![("bench", s(name)), ("seconds", num(secs))]));
}

fn main() -> Result<(), String> {
    common::banner("§Perf", "hot-path microbenchmarks");
    common::default_memo_path();
    let smoke = std::env::var("PICE_BENCH_SMOKE").as_deref() == Ok("1");
    let mut rows = Vec::new();

    // --- L3 primitives -----------------------------------------------------
    let mut rng = Rng::new(1);
    let sched = CloudScheduler::default();
    let inp = SchedInput { predicted_len: 480, n_edges: 4, best_slm_capability: 74.0 };
    let est = Estimates {
        f_cloud: LatencyFit { a: 0.4, b: 0.1 },
        cost_coeff: 0.6,
        transfer: TransferModel { base_s: 0.02, per_token_s: 5e-7 },
        backlog_s: 12.0,
        parallel_hint: 4.0,
    };
    report(&mut rows, "scheduler.decide (Eq. 2 over 4 levels)", time_it(20_000, || {
        std::hint::black_box(sched.decide(&inp, &est));
    }), "per request");

    let mk_job = |rid: usize, len: usize| Job {
        rid,
        expected_len: len,
        sentences: vec![],
        salvaged: vec![],
        full_sketch: Vec::new().into(),
        question: Vec::new().into(),
        enqueued_at: 0.0,
        replicas_left: 1,
    };
    report(&mut rows, "multi-list queue push+pull_batch(4)", time_it(20_000, || {
        let mut q = MultiListQueue::standard(64);
        for rid in 0..16 {
            q.push(mk_job(rid, (rid * 37) % 200));
        }
        while !q.is_empty() {
            std::hint::black_box(q.pull_batch(4));
        }
    }), "16 jobs");

    let lens: Vec<usize> = (0..8).map(|i| 80 + i * 20).collect();
    let cost = EdgeCostModel { token_s: 0.01, batch_slowdown: 0.06, prompt_tokens: 300, prefill_speedup: 8.0 };
    report(&mut rows, "plan_batch (8 sentences, 1 job)", time_it(20_000, || {
        let refs: Vec<&[usize]> = vec![&lens];
        std::hint::black_box(plan_batch(&refs, 16, &cost));
    }), "per job");

    let a: Vec<u32> = (0..120).map(|_| rng.next_u64() as u32 % 200).collect();
    let b: Vec<u32> = (0..120).map(|_| rng.next_u64() as u32 % 200).collect();
    report(&mut rows, "rouge-1 (120x120 tokens)", time_it(20_000, || {
        std::hint::black_box(rouge1_f1(&a, &b));
    }), "per pair");
    report(&mut rows, "rouge-L LCS (120x120 random)", time_it(2_000, || {
        std::hint::black_box(rouge_l_f1(&a, &b));
    }), "per pair");
    // near-identical pair: the prefix/suffix trim collapses the DP
    let mut a2 = a.clone();
    a2[60] = a2[60].wrapping_add(1) % 200;
    report(&mut rows, "rouge-L LCS (120x120 near-identical)", time_it(20_000, || {
        std::hint::black_box(rouge_l_f1(&a, &a2));
    }), "per pair");

    // --- batched parallel backend (tentpole) --------------------------------
    let tok = synth_tokenizer();
    let corpus = Arc::new(synth_corpus(&tok, 30, 42));
    let reg = Registry::builtin();
    // same seed as Env::load's surrogate, so the persistent-cache section
    // below shares entries (and a stamp) with Env-driven bench runs
    let base =
        SurrogateBackend::new(corpus.clone(), &tok, &reg, pice::scenario::SURROGATE_SEED);
    let reqs = expansion_requests(&tok, &corpus);
    let iters = if smoke { 5 } else { 40 };
    println!("-- batched expansion: {} requests per batch --", reqs.len());
    let mut seq = base.clone();
    let t_seq = time_it(iters, || {
        std::hint::black_box(seq.generate_batch(&reqs));
    });
    report(&mut rows, "expansion batch, sequential", t_seq, "per batch");
    let mut speedup4 = 0.0;
    for workers in [1usize, 2, 4] {
        let mut par = ParallelBackend::new(workers, |_| base.clone());
        // warm the pool once so thread startup isn't timed
        std::hint::black_box(par.generate_batch(&reqs));
        let t = time_it(iters, || {
            std::hint::black_box(par.generate_batch(&reqs));
        });
        report(&mut rows, &format!("expansion batch, parallel x{workers}"), t, "per batch");
        let sp = t_seq / t;
        println!("{:<44} {sp:>11.2}x", format!("  speedup vs sequential (x{workers})"));
        rows.push(obj(vec![
            ("bench", s(&format!("expansion_speedup_x{workers}"))),
            ("speedup", num(sp)),
        ]));
        if workers == 4 {
            speedup4 = sp;
        }
    }

    // --- memo-cache hit rate -------------------------------------------------
    {
        let mut memo = MemoBackend::new(base.clone(), 8192);
        std::hint::black_box(memo.generate_batch(&reqs)); // cold pass: misses
        let t_warm = time_it(iters, || {
            std::hint::black_box(memo.generate_batch(&reqs)); // replays: hits
        });
        report(&mut rows, "expansion batch, memo-cached replay", t_warm, "per batch");
        let (hits, misses) = memo.stats();
        println!(
            "{:<44} {:>10.1}%  ({hits} hits / {misses} misses)",
            "  memo hit rate (bench replay)",
            memo.hit_rate() * 100.0
        );
        rows.push(obj(vec![
            ("bench", s("memo_hit_rate")),
            ("hit_rate", num(memo.hit_rate())),
            ("hits", num(hits as f64)),
            ("misses", num(misses as f64)),
        ]));
    }

    // --- scenario-sweep runner (tentpole) -----------------------------------
    {
        let n = if smoke { 16 } else { 40 };
        let wl = Arc::new(Workload::generate(
            &corpus,
            WorkloadSpec {
                rpm: 40.0,
                n_requests: n,
                arrival: Arrival::Poisson,
                categories: vec![],
                seed: 7,
            },
        ));
        // distinct engine seeds -> disjoint generation keys, so the speedup
        // rows isolate the thread-pool win from cache effects
        let grid: Vec<SweepScenario> = (0..8)
            .map(|i| {
                let mut cfg = baselines::pice("llama70b-sim");
                cfg.seed = 1_000 + 7 * i as u64;
                SweepScenario::new(format!("s{i}"), cfg, wl.clone())
            })
            .collect();
        println!("-- scenario sweep: {} scenarios x {n} requests --", grid.len());
        let run_grid = |threads: usize| {
            SweepRunner::new(threads).run(&grid, &corpus, &tok, &reg, |_| {
                Box::new(base.clone()) as Box<dyn TextBackend>
            })
        };
        let iters = if smoke { 1 } else { 3 };
        let reference = run_grid(1); // warm + determinism reference
        let t_seq = time_it(iters, || {
            std::hint::black_box(run_grid(1));
        });
        report(&mut rows, "scenario sweep, sequential (1 thread)", t_seq, "per sweep");
        let same_traces = |a: &[pice::sweep::ScenarioResult], b: &[pice::sweep::ScenarioResult]| {
            a.len() == b.len()
                && a.iter().zip(b).all(|(x, y)| match (x, y) {
                    (Ok((_, ta)), Ok((_, tb))) => {
                        ta.len() == tb.len()
                            && ta
                                .iter()
                                .zip(tb)
                                .all(|(u, v)| u.answer == v.answer && u.done == v.done)
                    }
                    _ => false,
                })
        };
        for threads in [2usize, 4] {
            let t = time_it(iters, || {
                std::hint::black_box(run_grid(threads));
            });
            report(&mut rows, &format!("scenario sweep, {threads} threads"), t, "per sweep");
            let identical = same_traces(&reference, &run_grid(threads));
            let sp = t_seq / t.max(1e-12);
            println!(
                "{:<44} {sp:>11.2}x  (identical: {})",
                format!("  sweep speedup vs sequential (x{threads})"),
                if identical { "yes" } else { "NO (BUG)" }
            );
            rows.push(obj(vec![
                ("bench", s(&format!("sweep_speedup_x{threads}"))),
                ("speedup", num(sp)),
                ("traces_identical", num(identical as usize as f64)),
            ]));
        }

        // cross-variant shared cache: the Fig. 6 variant grid over ONE
        // SharedMemoCache — the four systems replay the same questions with
        // the same derived seeds, so they serve each other's generations
        let variants: Vec<SweepScenario> = vec![
            SweepScenario::new("Cloud-only", baselines::cloud_only("llama70b-sim"), wl.clone()),
            SweepScenario::new("Routing", baselines::routing("llama70b-sim"), wl.clone()),
            SweepScenario::new(
                "PICE-static",
                {
                    let mut c = baselines::pice("llama70b-sim");
                    c.scheduler.static_mode = true;
                    c
                },
                wl.clone(),
            ),
            SweepScenario::new("PICE-dynamic", baselines::pice("llama70b-sim"), wl.clone()),
        ];
        let plain = SweepRunner::new(1).run(&variants, &corpus, &tok, &reg, |_| {
            Box::new(base.clone()) as Box<dyn TextBackend>
        });
        let cache = Arc::new(SharedMemoCache::new(1 << 15));
        let shared = SweepRunner::new(4).run(&variants, &corpus, &tok, &reg, |i| {
            Box::new(MemoBackend::shared(base.clone(), cache.clone(), i as u32))
                as Box<dyn TextBackend>
        });
        let identical = same_traces(&plain, &shared);
        let cs = cache.stats();
        println!(
            "{:<44} {:>10.1}%  ({} cross / {} lookups, identical: {})",
            "  cross-variant shared-cache hit rate",
            cs.cross_hit_rate() * 100.0,
            cs.cross_hits,
            cs.lookups(),
            if identical { "yes" } else { "NO (BUG)" }
        );
        rows.push(obj(vec![
            ("bench", s("cross_variant_hit_rate")),
            ("hit_rate", num(cs.cross_hit_rate())),
            ("cross_hits", num(cs.cross_hits as f64)),
            ("lookups", num(cs.lookups() as f64)),
            ("traces_identical", num(identical as usize as f64)),
        ]));
    }

    // --- persistent cross-run memo cache ------------------------------------
    // One pass of the expansion batch against the snapshot-backed cache:
    // the first process reports 0% and writes the snapshot, every later
    // process replays it at ~100% — the CI warm-cache step asserts this.
    // default_memo_path() above guarantees PICE_MEMO_PATH is set unless the
    // user exported it empty to disable persistence.
    if let Some(cache_path) = std::env::var("PICE_MEMO_PATH").ok().filter(|p| !p.is_empty()) {
        let stamp = pice::scenario::surrogate_cache_stamp(
            &tok,
            &corpus,
            &reg,
            pice::scenario::SURROGATE_SEED,
        );
        let mut pmemo = PersistentMemoBackend::load(base.clone(), 8192, &cache_path, &stamp);
        let restored = pmemo.restored_entries();
        let t_run = time_it(1, || {
            std::hint::black_box(pmemo.generate_batch(&reqs));
        });
        report(&mut rows, "expansion batch, persistent cache", t_run, "per batch");
        let (hits, misses) = pmemo.stats();
        println!(
            "{:<44} {:>10.1}%  ({hits} hits / {misses} misses, {restored} restored)",
            "  persistent memo hit rate (vs prior run)",
            pmemo.hit_rate() * 100.0
        );
        rows.push(obj(vec![
            ("bench", s("persistent_memo_hit_rate")),
            ("hit_rate", num(pmemo.hit_rate())),
            ("hits", num(hits as f64)),
            ("misses", num(misses as f64)),
            ("restored_entries", num(restored as f64)),
        ]));
        pmemo.save().map_err(|e| format!("persist memo cache: {e}"))?;
        let cs = pmemo.cache_stats();
        println!(
            "{:<44} {:>12}  ({} pages spilled, {} faulted, {:.1} KiB resident, {} non-finite skipped)",
            "  buffer-pool evictions",
            cs.evictions,
            cs.spilled_pages,
            cs.faulted_pages,
            cs.resident_bytes as f64 / 1024.0,
            cs.skipped_nonfinite,
        );
        rows.push(obj(vec![
            ("bench", s("cache_pool")),
            ("evictions", num(cs.evictions as f64)),
            ("spilled_pages", num(cs.spilled_pages as f64)),
            ("faulted_pages", num(cs.faulted_pages as f64)),
            ("resident_bytes", num(cs.resident_bytes as f64)),
            ("resident_entries", num(cs.resident_entries as f64)),
            ("skipped_nonfinite", num(cs.skipped_nonfinite as f64)),
        ]));
        println!("[persistent cache at {cache_path}]");
    } else {
        println!("(PICE_MEMO_PATH exported empty — skipping persistent-cache bench)");
    }

    // --- end-to-end event loop: sequential vs parallel substrate ------------
    {
        let n = if smoke { 20 } else { 60 };
        let wl = Workload::generate(
            &corpus,
            WorkloadSpec {
                rpm: 40.0,
                n_requests: n,
                arrival: Arrival::Poisson,
                categories: vec![],
                seed: 3,
            },
        );
        let mut seq_backend = base.clone();
        let t0 = Instant::now();
        let mut engine =
            Engine::new(baselines::pice("llama70b-sim"), corpus.clone(), &tok, &reg, &mut seq_backend)
                .map_err(|e| e.to_string())?;
        let traces_seq = engine.run(&wl).map_err(|e| e.to_string())?;
        let dt_seq = t0.elapsed().as_secs_f64();
        report(&mut rows, &format!("engine.run {n} reqs (surrogate, seq)"), dt_seq / n as f64, "per request");

        let mut par_backend = ParallelBackend::new(4, |_| base.clone());
        let t0 = Instant::now();
        let mut engine =
            Engine::new(baselines::pice("llama70b-sim"), corpus.clone(), &tok, &reg, &mut par_backend)
                .map_err(|e| e.to_string())?;
        let traces_par = engine.run(&wl).map_err(|e| e.to_string())?;
        let dt_par = t0.elapsed().as_secs_f64();
        report(&mut rows, &format!("engine.run {n} reqs (surrogate, par x4)"), dt_par / n as f64, "per request");
        let identical = traces_seq.len() == traces_par.len()
            && traces_seq.iter().zip(&traces_par).all(|(x, y)| x.answer == y.answer);
        println!(
            "{:<44} {:>12}",
            "  par traces identical to seq",
            if identical { "yes" } else { "NO (BUG)" }
        );
        println!(
            "{:<44} {:>11.2}x",
            "  engine speedup (seq/par wall)",
            dt_seq / dt_par.max(1e-12)
        );
        rows.push(obj(vec![
            ("bench", s("engine_run_speedup_x4")),
            ("speedup", num(dt_seq / dt_par.max(1e-12))),
            ("traces_identical", num(identical as usize as f64)),
        ]));
    }

    // --- telemetry overhead (PERF.md §Telemetry) ----------------------------
    // Off must be free: telemetry lives behind one Option and an untouched
    // f64 store, so off-traces are bit-identical to the pre-telemetry
    // engine (CI asserts traces_identical == 1). On is bounded: spans are
    // plain pushes of already-computed sim times, no extra events.
    {
        let n = if smoke { 20 } else { 60 };
        let wl = Workload::generate(
            &corpus,
            WorkloadSpec {
                rpm: 40.0,
                n_requests: n,
                arrival: Arrival::Poisson,
                categories: vec![],
                seed: 3,
            },
        );
        let run = |telemetry: bool| {
            let mut backend = base.clone();
            let mut engine = Engine::new(
                baselines::pice("llama70b-sim"),
                corpus.clone(),
                &tok,
                &reg,
                &mut backend,
            )
            .expect("engine");
            if telemetry {
                engine.enable_telemetry(0);
            }
            let traces = engine.run(&wl).expect("run");
            let spans = engine.take_spans();
            (traces, spans)
        };
        let iters = if smoke { 1 } else { 3 };
        let (ref_off, _) = run(false); // warm the backend path
        let t_off = time_it(iters, || {
            std::hint::black_box(run(false));
        });
        let t_on = time_it(iters, || {
            std::hint::black_box(run(true));
        });
        let (on_traces, spans) = run(true);
        let identical = ref_off.len() == on_traces.len()
            && ref_off
                .iter()
                .zip(&on_traces)
                .all(|(x, y)| x.answer == y.answer && x.done == y.done);
        let ratio = t_on / t_off.max(1e-12);
        report(&mut rows, &format!("engine.run {n} reqs, telemetry off"), t_off / n as f64, "per request");
        report(&mut rows, &format!("engine.run {n} reqs, telemetry on"), t_on / n as f64, "per request");
        println!(
            "{:<44} {ratio:>11.2}x  ({} spans, identical: {})",
            "  telemetry on/off wall ratio",
            spans.len(),
            if identical { "yes" } else { "NO (BUG)" }
        );
        rows.push(obj(vec![
            ("bench", s("telemetry_overhead")),
            ("off_s_per_req", num(t_off / n as f64)),
            ("on_s_per_req", num(t_on / n as f64)),
            ("overhead_ratio", num(ratio)),
            ("spans", num(spans.len() as f64)),
            ("traces_identical", num(identical as usize as f64)),
        ]));
    }

    println!("batched expansion 4-worker speedup: {speedup4:.2}x (target >= 1.5x)");

    // --- legacy Env-driven event loop (coordinator cost only) ---------------
    {
        std::env::set_var("PICE_BACKEND", "surrogate");
        let mut env = Env::load()?;
        std::env::remove_var("PICE_BACKEND");
        let wl = env.workload(40.0, 60, 3);
        let t0 = Instant::now();
        let (m, _) = env.run(baselines::pice("llama70b-sim"), &wl).map_err(|e| e.to_string())?;
        let dt = t0.elapsed().as_secs_f64();
        report(&mut rows, "engine.run 60 reqs (surrogate, L3-only)", dt / 60.0, "per request");
        println!("{:<44} {:>9.0} sim-s in {:.2} real-s", "  (simulated makespan vs real wall)", m.makespan_s, dt);
    }

    // --- real PJRT decode hot path ------------------------------------------
    let art = pice::artifacts_dir();
    if art.join("manifest.json").exists() {
        let rt = RuntimeHandle::cpu().map_err(|e| e.to_string())?;
        let env = Env::load()?;
        for name in ["qwen1.5b-sim", "qwen7b-sim", "llama70b-sim"] {
            let lm = LoadedModel::load(rt.clone(), &art.join("models").join(name))
                .map_err(|e| e.to_string())?;
            let g = Generator::new(&lm, env.tok.specials.eos);
            let q = env.corpus.eval_questions()[0];
            let prompt = Prompts::full_answer(&env.tok, &q.question);
            let sp = SamplingParams { max_tokens: 32, ..Default::default() };
            let mut scratch = pice::runtime::GenScratch::default();
            let _ = g.generate_with(&prompt, &sp, &mut scratch);
            let t0 = Instant::now();
            let mut toks = 0usize;
            for _ in 0..3 {
                toks += g
                    .generate_with(&prompt, &sp, &mut scratch)
                    .map_err(|e| e.to_string())?
                    .tokens
                    .len();
            }
            let per_tok = t0.elapsed().as_secs_f64() / toks as f64;
            report(&mut rows, &format!("PJRT decode step [{name}]"), per_tok, "per token");
        }
    } else {
        println!("(artifacts missing — skipping real PJRT decode benches)");
    }

    let json = Json::Arr(rows);
    common::dump("perf_hotpath", json.clone());
    // cross-PR perf trajectory file at the repo root (see PERF.md). Bench
    // executables run with CWD = the package root (rust/), so resolve the
    // repo root from the manifest dir instead of relying on the CWD.
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(std::path::Path::to_path_buf)
        .unwrap_or_default();
    let path = root.join("BENCH_perf_hotpath.json");
    if std::fs::write(&path, json.to_string()).is_ok() {
        println!("[saved {}]", path.display());
    }
    Ok(())
}
