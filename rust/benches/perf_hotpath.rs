//! §Perf — hot-path microbenches for the optimization pass (EXPERIMENTS.md
//! §Perf): L3 coordinator primitives, the end-to-end event loop, and the
//! real PJRT decode step per model variant.

mod common;

use std::time::Instant;

use pice::coordinator::dispatch::{Job, MultiListQueue};
use pice::coordinator::scheduler::{CloudScheduler, SchedInput};
use pice::parallel::{plan_batch, EdgeCostModel};
use pice::profiler::LatencyFit;
use pice::quality::rouge::{rouge1_f1, rouge_l_f1};
use pice::runtime::{Generator, LoadedModel, RuntimeHandle, SamplingParams};
use pice::scenario::Env;
use pice::sketch::Prompts;
use pice::util::json::{num, obj, s, Json};
use pice::util::rng::Rng;

fn time_it<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() -> Result<(), String> {
    common::banner("§Perf", "hot-path microbenchmarks");
    let mut rows = Vec::new();
    let mut report = |name: &str, secs: f64, unit: &str| {
        let v = if secs < 1e-3 { format!("{:.2} µs", secs * 1e6) } else { format!("{:.3} ms", secs * 1e3) };
        println!("{name:<44} {v:>12}  ({unit})");
        rows.push(obj(vec![("bench", s(name)), ("seconds", num(secs))]));
    };

    // --- L3 primitives -----------------------------------------------------
    let mut rng = Rng::new(1);
    let sched = CloudScheduler::default();
    let inp = SchedInput {
        predicted_len: 480,
        f_cloud: LatencyFit { a: 0.4, b: 0.1 },
        cost_coeff: 0.6,
        transfer_s: |n| 0.02 + n as f64 * 5e-7,
        backlog_s: 12.0,
        n_edges: 4,
        best_slm_capability: 74.0,
        parallel_hint: 4.0,
    };
    report("scheduler.decide (Eq. 2 over 4 levels)", time_it(20_000, || {
        std::hint::black_box(sched.decide(&inp));
    }), "per request");

    let mk_job = |rid: usize, len: usize| Job {
        rid,
        expected_len: len,
        sentences: vec![],
        full_sketch: vec![],
        question: vec![],
        enqueued_at: 0.0,
        replicas_left: 1,
    };
    report("multi-list queue push+pull_batch(4)", time_it(20_000, || {
        let mut q = MultiListQueue::standard(64);
        for rid in 0..16 {
            q.push(mk_job(rid, (rid * 37) % 200));
        }
        while !q.is_empty() {
            std::hint::black_box(q.pull_batch(4));
        }
    }), "16 jobs");

    let lens: Vec<usize> = (0..8).map(|i| 80 + i * 20).collect();
    let cost = EdgeCostModel { token_s: 0.01, batch_slowdown: 0.06, prompt_tokens: 300, prefill_speedup: 8.0 };
    report("plan_batch (8 sentences, 1 job)", time_it(20_000, || {
        let refs: Vec<&[usize]> = vec![&lens];
        std::hint::black_box(plan_batch(&refs, 16, &cost));
    }), "per job");

    let a: Vec<u32> = (0..120).map(|_| rng.next_u64() as u32 % 200).collect();
    let b: Vec<u32> = (0..120).map(|_| rng.next_u64() as u32 % 200).collect();
    report("rouge-1 (120x120 tokens)", time_it(20_000, || {
        std::hint::black_box(rouge1_f1(&a, &b));
    }), "per pair");
    report("rouge-L LCS (120x120 tokens)", time_it(2_000, || {
        std::hint::black_box(rouge_l_f1(&a, &b));
    }), "per pair");

    // --- end-to-end event loop (surrogate: coordinator cost only) ----------
    {
        std::env::set_var("PICE_BACKEND", "surrogate");
        let mut env = Env::load()?;
        std::env::remove_var("PICE_BACKEND");
        let wl = env.workload(40.0, 60, 3);
        let t0 = Instant::now();
        let (m, _) = env.run(pice::baselines::pice("llama70b-sim"), &wl).map_err(|e| e.to_string())?;
        let dt = t0.elapsed().as_secs_f64();
        report("engine.run 60 reqs (surrogate, L3-only)", dt / 60.0, "per request");
        println!("{:<44} {:>9.0} sim-s in {:.2} real-s", "  (simulated makespan vs real wall)", m.makespan_s, dt);
    }

    // --- real PJRT decode hot path ------------------------------------------
    let art = pice::artifacts_dir();
    if art.join("manifest.json").exists() {
        let rt = RuntimeHandle::cpu().map_err(|e| e.to_string())?;
        let env = Env::load()?;
        for name in ["qwen1.5b-sim", "qwen7b-sim", "llama70b-sim"] {
            let lm = LoadedModel::load(rt.clone(), &art.join("models").join(name))
                .map_err(|e| e.to_string())?;
            let g = Generator::new(&lm, env.tok.specials.eos);
            let q = env.corpus.eval_questions()[0];
            let prompt = Prompts::full_answer(&env.tok, &q.question);
            let sp = SamplingParams { max_tokens: 32, ..Default::default() };
            let _ = g.generate(&prompt, &sp);
            let t0 = Instant::now();
            let mut toks = 0usize;
            for _ in 0..3 {
                toks += g.generate(&prompt, &sp).map_err(|e| e.to_string())?.tokens.len();
            }
            let per_tok = t0.elapsed().as_secs_f64() / toks as f64;
            report(&format!("PJRT decode step [{name}]"), per_tok, "per token");
        }
    } else {
        println!("(artifacts missing — skipping real PJRT decode benches)");
    }

    common::dump("perf_hotpath", Json::Arr(rows));
    Ok(())
}
