//! Fig. 10 — mean sketch length per category before vs after the RLAIF
//! fine-tuning component (Fig. 5 pipeline: preference labeling -> reward
//! model -> policy-gradient with KL leash).

mod common;

use pice::finetune::{SketchPolicy, Trainer, TrainerCfg};
use pice::scenario::Env;
use pice::util::json::{num, obj, s, Json};

fn main() -> Result<(), String> {
    let mut env = Env::load()?;
    common::banner("Fig 10", "sketch length by category, base vs fine-tuned");

    let trainer = Trainer {
        cfg: TrainerCfg::default(),
        corpus: env.corpus.clone(),
        tok: &env.tok,
    };
    let out = trainer.run(env.backend.as_mut())?;
    println!(
        "reward model: {} preference pairs, train loss {:.3}, holdout acc {:.2}\n",
        out.n_pairs, out.rm_train_loss, out.rm_holdout_acc
    );

    let semicolon = env.tok.specials.semicolon;
    let base = SketchPolicy::sft(&env.corpus.categories);
    let before = base.mean_lengths(&env.corpus, semicolon);
    let after = out.policy.mean_lengths(&env.corpus, semicolon);

    println!("{:<16} {:>10} {:>12} {:>10}", "category", "base", "fine-tuned", "keep-frac");
    let mut rows = Vec::new();
    for cat in env.corpus.categories.clone() {
        let b = before.get(&cat).copied().unwrap_or(f64::NAN);
        let a = after.get(&cat).copied().unwrap_or(f64::NAN);
        println!("{cat:<16} {b:>10.1} {a:>12.1} {:>10.2}", out.policy.frac(&cat));
        rows.push(obj(vec![
            ("category", s(&cat)),
            ("base_len", num(b)),
            ("finetuned_len", num(a)),
            ("keep_frac", num(out.policy.frac(&cat))),
        ]));
    }
    common::dump("fig10_sketchlen", Json::Arr(rows));
    println!(
        "\npaper shape: most categories compress (writing/knowledge most); a few\n\
         (counterfactual/generic-like) stay flat or grow slightly to keep semantics."
    );
    Ok(())
}
