//! Fig. 8 — per-category ensemble confidence of the three edge SLMs:
//! different models are confident in different categories (the diversity
//! the ensemble exploits).

mod common;

use std::collections::BTreeMap;

use pice::ensemble::{confidence, Candidate, ConfidenceWeights};
use pice::runtime::SamplingParams;
use pice::scenario::Env;
use pice::sketch::Prompts;
use pice::util::json::{num, obj, s, Json};

fn main() -> Result<(), String> {
    let mut env = Env::load()?;
    common::banner("Fig 8", "SLM confidence by question category");
    let slms = ["llama8b-sim", "qwen7b-sim", "qwen1.5b-sim"];
    let w = ConfidenceWeights::default();

    // mean confidence per (model, category) over eval sketch expansions
    let mut acc: BTreeMap<(String, String), (f64, usize)> = BTreeMap::new();
    let qs: Vec<usize> = env.corpus.eval_questions().iter().map(|q| q.id).collect();
    for qid in qs {
        let q = env.corpus.get(qid).unwrap().clone();
        let sketch = q.sketch_tokens(env.tok.specials.semicolon);
        for (si, sent) in q.sentences.iter().enumerate().take(2) {
            let prompt = Prompts::expand(&env.tok, &q.question, &sketch, &sent.sketch);
            for m in &slms {
                let out = env.backend.generate(
                    m,
                    &prompt,
                    &SamplingParams {
                        max_tokens: 24,
                        stop_token: Some(env.tok.specials.period),
                        seed: (qid * 7 + si) as u64,
                        ..Default::default()
                    },
                )?;
                let cand = Candidate { model: (*m).into(), tokens: out.tokens, logps: out.logps };
                let con = confidence(&cand, &sent.sketch, sent.full.len(), w);
                let e = acc.entry((m.to_string(), q.category.clone())).or_insert((0.0, 0));
                e.0 += con;
                e.1 += 1;
            }
        }
    }

    print!("{:<16}", "category");
    for m in &slms {
        print!(" {:>14}", m);
    }
    println!();
    let mut rows = Vec::new();
    for cat in env.corpus.categories.clone() {
        print!("{cat:<16}");
        for m in &slms {
            let (sum, n) = acc.get(&(m.to_string(), cat.clone())).copied().unwrap_or((0.0, 0));
            let v = sum / n.max(1) as f64;
            print!(" {v:>14.3}");
            rows.push(obj(vec![("model", s(m)), ("category", s(&cat)), ("confidence", num(v))]));
        }
        println!();
    }
    common::dump("fig8_confidence", Json::Arr(rows));
    println!("\npaper shape: confidence rankings differ across categories (no single SLM dominates).");
    Ok(())
}
