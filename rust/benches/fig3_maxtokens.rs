//! Fig. 3 (motivation) — serving throughput vs the LLM's max response
//! tokens: shortening LLM outputs is the headroom progressive inference
//! exploits (500 -> 200 tokens gives the paper's 1.5-2x).

mod common;

use pice::baselines;
use pice::scenario::{bench_n, Env};
use pice::util::json::{num, obj, Json};

fn main() -> Result<(), String> {
    let mut env = Env::load()?;
    let model = "llama70b-sim";
    let rpm = env.paper_rpm(model) * 2.0; // saturating load isolates capacity
    let n = bench_n();
    common::banner("Fig 3", "throughput vs max tokens of the LLM response");
    println!("{:>10} {:>14} {:>10}", "max tokens", "thpt(q/m)", "lat(s)");
    let mut rows = Vec::new();
    for max_tokens in [100usize, 200, 300, 400, 500, 600, 700] {
        let mut cfg = baselines::cloud_only(model);
        cfg.cloud_max_tokens = max_tokens;
        let wl = env.workload(rpm, n, 7);
        let (m, _) = env.run(cfg, &wl).map_err(|e| e.to_string())?;
        println!("{max_tokens:>10} {:>14.2} {:>10.2}", m.throughput_qpm, m.avg_latency_s);
        rows.push(obj(vec![
            ("max_tokens", num(max_tokens as f64)),
            ("throughput_qpm", num(m.throughput_qpm)),
            ("latency_s", num(m.avg_latency_s)),
        ]));
    }
    common::dump("fig3_maxtokens", Json::Arr(rows));
    println!("\npaper shape: throughput rises steeply as max tokens shrinks (~1.5-2x from 500->200).");
    Ok(())
}
