//! Ablations beyond the paper's figures — the design choices DESIGN.md
//! calls out: (a) ensemble confidence weights (Eq. 3's α1/α2), (b) edge
//! fleet size, (c) fixed sketch level vs the dynamic lexicographic choice,
//! (d) multi-list vs single-FIFO dispatch (bucket ablation).

mod common;

use pice::baselines;
use pice::ensemble::ConfidenceWeights;
use pice::quality::judge::Judge;
use pice::scenario::{bench_n, Env};
use pice::sketch::SketchLevel;
use pice::util::json::{num, obj, s, Json};

fn main() -> Result<(), String> {
    let mut env = Env::load()?;
    let judge = Judge::fit(&env.corpus);
    let model = "llama70b-sim";
    let rpm = env.paper_rpm(model);
    let n = bench_n();
    let wl = env.workload(rpm, n, 41);
    let mut rows = Vec::new();

    common::banner("Ablation A", "ensemble confidence weights (Eq. 3)");
    println!("{:>6} {:>6} {:>9} {:>10}", "α1", "α2", "quality", "thpt(q/m)");
    for (a1, a2) in [(0.0, 0.0), (1.0, 0.0), (0.4, 0.2), (0.2, 0.2), (0.0, 0.5)] {
        let mut cfg = baselines::pice(model);
        cfg.confidence = ConfidenceWeights { alpha1: a1, alpha2: a2 };
        let (m, traces) = env.run(cfg, &wl).map_err(|e| e.to_string())?;
        let q = common::mean_quality(&env, &judge, &traces);
        println!("{a1:>6.1} {a2:>6.1} {q:>9.2} {:>10.2}", m.throughput_qpm);
        rows.push(obj(vec![
            ("ablation", s("confidence_weights")),
            ("alpha1", num(a1)),
            ("alpha2", num(a2)),
            ("quality", num(q)),
        ]));
    }
    println!("(α1=1: perplexity-only — the failure mode §IV-C motivates against)");

    common::banner("Ablation B", "edge fleet size");
    println!("{:>7} {:>10} {:>8} {:>6}", "#edges", "thpt(q/m)", "lat(s)", "prog");
    for edges in [1usize, 2, 4, 6, 8] {
        let mut cfg = baselines::pice(model);
        cfg.n_edges = edges;
        let (m, _) = env.run(cfg, &wl).map_err(|e| e.to_string())?;
        println!("{edges:>7} {:>10.2} {:>8.2} {:>6}", m.throughput_qpm, m.avg_latency_s, m.n_progressive);
        rows.push(obj(vec![
            ("ablation", s("edge_fleet")),
            ("edges", num(edges as f64)),
            ("throughput_qpm", num(m.throughput_qpm)),
            ("latency_s", num(m.avg_latency_s)),
        ]));
    }

    common::banner("Ablation C", "fixed sketch level vs dynamic selection");
    println!("{:<22} {:>10} {:>8} {:>9}", "level policy", "thpt(q/m)", "lat(s)", "quality");
    let fixed_levels = [
        ("dynamic (lex policy)", None),
        ("fixed level 1 (full)", Some(SketchLevel { level: 1, keep_frac: 1.0 })),
        ("fixed level 3 (0.6)", Some(SketchLevel { level: 3, keep_frac: 0.6 })),
    ];
    for (name, lv) in fixed_levels {
        let mut cfg = baselines::pice(model);
        if let Some(lv) = lv {
            cfg.scheduler.levels = vec![SketchLevel { level: 0, keep_frac: 0.0 }, lv];
        }
        let (m, traces) = env.run(cfg, &wl).map_err(|e| e.to_string())?;
        let q = common::mean_quality(&env, &judge, &traces);
        println!("{name:<22} {:>10.2} {:>8.2} {q:>9.2}", m.throughput_qpm, m.avg_latency_s);
        rows.push(obj(vec![
            ("ablation", s("sketch_level")),
            ("policy", s(name)),
            ("throughput_qpm", num(m.throughput_qpm)),
            ("quality", num(q)),
        ]));
    }

    common::banner("Ablation D", "multi-list vs single-FIFO dispatch");
    println!("{:<22} {:>10} {:>8} {:>9}", "dispatch", "thpt(q/m)", "lat(s)", "p95(s)");
    for (name, single) in [("multi-list (Alg. 1)", false), ("single FIFO", true)] {
        let mut cfg = baselines::pice(model);
        if single {
            // one bucket == plain FIFO (Algorithm 1 ablated away)
            cfg.queue_cap = 8;
            cfg.scheduler.levels = pice::sketch::levels();
            cfg.seed = 41;
            cfg.sketch_keep_frac_override = None;
            // the engine constructs buckets from fixed bounds; a huge first
            // bound folds everything into one list
            std::env::set_var("PICE_SINGLE_FIFO", "1");
        }
        let (m, _) = env.run(cfg, &wl).map_err(|e| e.to_string())?;
        if single {
            std::env::remove_var("PICE_SINGLE_FIFO");
        }
        println!("{name:<22} {:>10.2} {:>8.2} {:>9.2}", m.throughput_qpm, m.avg_latency_s, m.p95_latency_s);
        rows.push(obj(vec![
            ("ablation", s("dispatch")),
            ("policy", s(name)),
            ("throughput_qpm", num(m.throughput_qpm)),
            ("p95_s", num(m.p95_latency_s)),
        ]));
    }

    common::dump("ablations", Json::Arr(rows));
    Ok(())
}
