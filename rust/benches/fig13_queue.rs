//! Fig. 13 — sensitivity to the job-queue length: throughput and latency
//! vs the expansion queue capacity. The six capacity variants run as one
//! concurrent sweep over a shared workload + generation cache.

mod common;

use std::sync::Arc;

use pice::baselines;
use pice::scenario::{bench_n, Env};
use pice::sweep::SweepScenario;
use pice::util::json::{num, obj, Json};

fn main() -> Result<(), String> {
    common::default_memo_path();
    let env = Env::load()?;
    let model = "llama70b-sim";
    let rpm = env.paper_rpm(model) * 1.3; // pressure so the queue matters
    let n = bench_n();
    let wl = Arc::new(env.workload(rpm, n, 19));
    common::banner("Fig 13", "impact of the job queue length");
    println!("{:>9} {:>12} {:>9} {:>9}", "queue cap", "thpt(q/m)", "lat(s)", "p95(s)");

    let caps = [1usize, 2, 4, 8, 12, 16];
    let scenarios: Vec<SweepScenario> = caps
        .iter()
        .map(|&cap| {
            let mut cfg = baselines::pice(model);
            cfg.queue_cap = cap;
            SweepScenario::new(format!("cap{cap}"), cfg, wl.clone())
        })
        .collect();
    let outcomes = env.run_sweep(&scenarios);

    let mut rows = Vec::new();
    for (&cap, outcome) in caps.iter().zip(outcomes) {
        let (m, _) = outcome.map_err(|e| e.to_string())?;
        println!(
            "{cap:>9} {:>12.2} {:>9.2} {:>9.2}",
            m.throughput_qpm, m.avg_latency_s, m.p95_latency_s
        );
        rows.push(obj(vec![
            ("queue_cap", num(cap as f64)),
            ("throughput_qpm", num(m.throughput_qpm)),
            ("latency_s", num(m.avg_latency_s)),
            ("p95_s", num(m.p95_latency_s)),
        ]));
    }
    common::dump("fig13_queue", Json::Arr(rows));
    println!(
        "\npaper shape: best throughput near cap = #edges (4); beyond ~8 the waiting\n\
         time inflates latency with no throughput gain."
    );
    common::report_sweep_stats(&env);
    Ok(())
}
