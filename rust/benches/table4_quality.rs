//! Table IV — response quality: overall judge score (1-10) and the five
//! LLMZoo-style rank dimensions (1-4, lower better), per category, for the
//! four systems. Rankings are computed per question across the systems,
//! exactly as LLMZoo ranks competing answers to the same prompt.

mod common;

use std::collections::BTreeMap;

use pice::quality::judge::{rank_dims, Judge, Scores, DIM_NAMES};
use pice::scenario::{bench_n, Env};
use pice::util::json::{num, obj, s, Json};

fn main() -> Result<(), String> {
    let mut env = Env::load()?;
    let judge = Judge::fit(&env.corpus);
    let n = bench_n().max(48);
    let model = "llama70b-sim";
    let rpm = env.paper_rpm(model);
    common::banner("Table IV", "response quality comparison (4 systems x 5 rank dims)");

    // run the four systems over the SAME workload; Edge-only OOMs for the
    // 70B scenario, so (as a quality comparator) it serves with its largest
    // deployable model — noted in the output.
    let systems = ["Cloud-only", "Edge-only", "Routing", "PICE"];
    let mut per_system_traces = Vec::new();
    for (name, result) in env.run_all_systems(model, rpm, n, 11) {
        match result {
            Ok((_, traces)) => per_system_traces.push((name, traces)),
            Err(_) if name == "Edge-only" => {
                let cfg = pice::baselines::edge_only("llama8b-sim");
                let wl = env.workload(rpm, n, 11);
                let (_, traces) = env.run(cfg, &wl).map_err(|e| e.to_string())?;
                println!("(Edge-only OOMs with the 70B model; quality row uses llama8b on edges)");
                per_system_traces.push((name, traces));
            }
            Err(e) => return Err(e.to_string()),
        }
    }

    // score + rank per question
    type Acc = BTreeMap<String, Vec<f64>>; // category -> values
    let mut overall: Vec<Acc> = vec![Acc::new(); 4];
    let mut ranks: Vec<Vec<Acc>> = vec![vec![Acc::new(); 5]; 4];
    let by_q = |traces: &[pice::metrics::RequestTrace]| -> BTreeMap<usize, Vec<u32>> {
        traces.iter().map(|t| (t.rid, t.answer.clone())).collect()
    };
    let answer_maps: Vec<BTreeMap<usize, Vec<u32>>> =
        per_system_traces.iter().map(|(_, t)| by_q(t)).collect();
    let base = &per_system_traces[0].1;
    for t in base {
        let Some(q) = env.corpus.get(t.question_id) else { continue };
        let mut scores: Vec<Scores> = Vec::with_capacity(4);
        for am in &answer_maps {
            let ans = am.get(&t.rid).cloned().unwrap_or_default();
            scores.push(judge.score(q, &ans));
        }
        let rk = rank_dims(&scores);
        for sys in 0..4 {
            overall[sys].entry(q.category.clone()).or_default().push(scores[sys].overall);
            for d in 0..5 {
                ranks[sys][d].entry(q.category.clone()).or_default().push(rk[sys][d]);
            }
        }
    }

    let mean = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len().max(1) as f64;
    let all_mean = |acc: &Acc| {
        let v: Vec<f64> = acc.values().flatten().copied().collect();
        v.iter().sum::<f64>() / v.len().max(1) as f64
    };
    let categories: Vec<String> = env.corpus.categories.clone();

    let mut json_rows = Vec::new();
    for sys in 0..4 {
        println!("\n=== {} ===", systems[sys]);
        print!("{:<16} {:>8}", "metric", "Overall");
        for c in &categories {
            print!(" {:>9.9}", c);
        }
        println!();
        print!("{:<16} {:>8.2}", "Overall score", all_mean(&overall[sys]));
        for c in &categories {
            print!(" {:>9.2}", overall[sys].get(c).map(mean).unwrap_or(f64::NAN));
        }
        println!();
        for d in 0..5 {
            print!("{:<16} {:>8.2}", format!("{} rank", DIM_NAMES[d]), all_mean(&ranks[sys][d]));
            for c in &categories {
                print!(" {:>9.2}", ranks[sys][d].get(c).map(mean).unwrap_or(f64::NAN));
            }
            println!();
        }
        json_rows.push(obj(vec![
            ("system", s(systems[sys])),
            ("overall", num(all_mean(&overall[sys]))),
            ("integrity_rank", num(all_mean(&ranks[sys][4]))),
            ("relevance_rank", num(all_mean(&ranks[sys][1]))),
        ]));
    }
    common::dump("table4_quality", Json::Arr(json_rows));
    println!(
        "\npaper shape: PICE best overall + best integrity; Edge-only worst;\n\
         PICE weaker than Cloud-only on math/coding."
    );
    Ok(())
}
