//! Fig. tail — hedged expansion dispatch vs plain dispatch under a
//! straggler-heavy WAN, plus the shard-blackout failover drill.
//!
//! The tail-tolerance pitch (PERF.md §Tail tolerance): a single straggling
//! expansion pull holds the whole request hostage — p99/p99.9 latency is
//! set by the slowest edge, not the average one. The per-slot watchdog
//! arms a timer at a configured quantile of Eq. 2's edge-term estimate;
//! when a pull overruns it, the still-pending slots are speculatively
//! re-dispatched to another up edge (or the cloud), first completion wins
//! per slot, and the straggler's late answer is discarded by the epoch
//! machinery. This bench measures the tail win and feeds three CI guards:
//! * `tail_win` — the best hedged p99 must not exceed the unhedged p99
//!   under the straggler grid (a conservative slot-timeout-mult variant
//!   degenerates to the unhedged schedule, so the best-of can only tie or
//!   win);
//! * `null_hedge_identical` — the tail *machinery* armed but inert (an
//!   unreachably large slot-timeout-mult) must be bit-identical to hedging
//!   off: watching for stragglers costs nothing when none can fire;
//! * `blackout_no_lost` — a 4-shard fleet under the `shard-blackout`
//!   preset with hedging on (which enables cross-shard re-dispatch) must
//!   finish exactly one trace per submitted request.

mod common;

use std::collections::HashSet;
use std::sync::Arc;

use pice::baselines;
use pice::coordinator::EngineCfg;
use pice::dynamics::{DynamicsSpec, FaultSpec, SlowdownSpec};
use pice::fleet::{FleetCfg, Placement};
use pice::metrics::RequestTrace;
use pice::scenario::{bench_n, Env};
use pice::serve::ServeCfg;
use pice::sweep::SweepScenario;
use pice::util::json::{num, obj, s, Json};

const MODEL: &str = "llama70b-sim";

/// Straggler-heavy grid: the flaky-wan link plus aggressive slowdown
/// windows (6x compute, ~40% duty per edge). No crashes — stragglers are
/// the tail-latency failure mode hedging addresses; crash recovery is the
/// failover/backoff machinery's job and is drilled in the blackout lane.
fn straggler_world() -> DynamicsSpec {
    let mut d = DynamicsSpec::preset("flaky-wan").expect("preset");
    d.faults = FaultSpec {
        slowdown: Some(SlowdownSpec { mtbs_s: 45.0, mean_dur_s: 30.0, mult: 6.0 }),
        horizon_s: 1800.0,
        ..Default::default()
    };
    d
}

fn hedged(base: &EngineCfg, q: f64, mult: f64) -> EngineCfg {
    let mut cfg = base.clone();
    cfg.tail.hedge_quantile = Some(q);
    cfg.tail.slot_timeout_mult = mult;
    cfg
}

fn main() -> Result<(), String> {
    common::default_memo_path();
    let smoke = std::env::var("PICE_BENCH_SMOKE").as_deref() == Ok("1");
    let mut env = Env::load()?;
    let n = bench_n();
    // moderate load: idle capacity must exist for a hedge to land on, or
    // the re-dispatch just queues behind the same stragglers
    let rpm = 0.6 * env.paper_rpm(MODEL);
    let wl = Arc::new(env.workload(rpm, n, 41));
    common::banner("Fig tail", "hedged expansion dispatch vs plain under stragglers");

    let base = baselines::pice(MODEL).with_dynamics(straggler_world());
    // variant ladder: aggressive -> conservative watchdogs. The x4.0 rung
    // hedges only pulls overrunning ~9x the estimate, which the 6x
    // straggler cap makes unreachable — it reproduces the unhedged
    // schedule and anchors the best-of guard.
    let variants = [
        ("unhedged", None),
        ("hedge-q90-x0.5", Some((0.9, 0.5))),
        ("hedge-q90-x1.0", Some((0.9, 1.0))),
        ("hedge-q95-x1.0", Some((0.95, 1.0))),
        ("hedge-q95-x4.0", Some((0.95, 4.0))),
    ];
    let grid: Vec<SweepScenario> = variants
        .iter()
        .map(|(name, knobs)| {
            let cfg = match knobs {
                Some((q, mult)) => hedged(&base, *q, *mult),
                None => base.clone(),
            };
            SweepScenario::new(name, cfg, wl.clone())
        })
        .collect();
    let outcomes = env.run_sweep(&grid);

    println!(
        "{:<16} | {:>8} {:>8} {:>9} {:>8} {:>9} {:>7}",
        "system", "p95(s)", "p99(s)", "p99.9(s)", "ttfe99", "ttfe99.9", "hedges"
    );
    let mut rows = Vec::new();
    let mut p99 = Vec::new();
    for ((name, _), outcome) in variants.iter().zip(outcomes) {
        let (m, _) = outcome.map_err(|e| e.to_string())?;
        println!(
            "{name:<16} | {:>8.2} {:>8.2} {:>9.2} {:>8.2} {:>9.2} {:>7}",
            m.p95_latency_s,
            m.p99_latency_s,
            m.p999_latency_s,
            m.p99_ttfe_s,
            m.p999_ttfe_s,
            m.hedges
        );
        rows.push(obj(vec![
            ("system", s(name)),
            ("p95_s", num(m.p95_latency_s)),
            ("p99_s", num(m.p99_latency_s)),
            ("p999_s", num(m.p999_latency_s)),
            ("p99_ttfe_s", num(m.p99_ttfe_s)),
            ("p999_ttfe_s", num(m.p999_ttfe_s)),
            ("hedges", num(m.hedges as f64)),
            ("hedged_slots", num(m.hedged_slots as f64)),
        ]));
        p99.push(m.p99_latency_s);
    }
    let unhedged_p99 = p99[0];
    let best_hedged_p99 = p99[1..].iter().copied().fold(f64::INFINITY, f64::min);
    let win = best_hedged_p99 <= unhedged_p99 + 1e-9;
    println!(
        "\np99 under stragglers: unhedged {unhedged_p99:.2}s, best hedged \
         {best_hedged_p99:.2}s -> hedging {}",
        if win { "holds (<= unhedged)" } else { "LOSES (BUG?)" }
    );
    rows.push(obj(vec![
        ("bench", s("tail_win")),
        ("unhedged_p99_s", num(unhedged_p99)),
        ("hedged_p99_s", num(best_hedged_p99)),
        ("win", num(win as i32 as f64)),
    ]));
    assert!(
        win,
        "best hedged p99 ({best_hedged_p99:.3}s) exceeds unhedged p99 ({unhedged_p99:.3}s)"
    );

    // --- guard: inert tail machinery is bit-identical to hedging off ------
    // Same trick as fig_adaptive's frozen-calibration guard: turn the whole
    // tail path ON (tail_on true, inflight tracked, the watchdog condition
    // evaluated on every expansion pull) but make the timeout unreachable.
    // Run it in the straggler world — crash-free on purpose: under crashes
    // the backoff-retry path legitimately replaces park-or-cloud fallback,
    // so only a crash-free world isolates "armed but never firing".
    let off_cfg = base.clone();
    let inert_cfg = hedged(&base, 0.95, 1e12);
    let ab = env.run_sweep(&[
        SweepScenario::new("hedge-off", off_cfg, wl.clone()),
        SweepScenario::new("hedge-inert", inert_cfg, wl.clone()),
    ]);
    let mut ab = ab.into_iter();
    let (_, off_traces) = ab.next().unwrap().map_err(|e| e.to_string())?;
    let (_, inert_traces) = ab.next().unwrap().map_err(|e| e.to_string())?;
    let identical = off_traces.len() == inert_traces.len()
        && off_traces
            .iter()
            .zip(&inert_traces)
            .all(|(x, y)| format!("{x:?}") == format!("{y:?}"));
    assert!(identical, "inert tail machinery diverged from hedging off");
    println!("inert tail machinery: bit-identical to hedging off OK");
    rows.push(obj(vec![
        ("bench", s("null_hedge_identical")),
        ("identical", num(identical as i32 as f64)),
    ]));

    // --- blackout lane: fleet failover re-dispatch loses no request -------
    // 4 hash shards under the shard-blackout preset; hedging on enables the
    // cross-shard re-dispatch of a dead shard's queued sessions. Every
    // submitted request must finish with exactly one trace.
    let shards = 4;
    let bn = if smoke { 24 } else { (2 * n).max(48) };
    let bwl = env.workload(rpm, bn, 43);
    let mut cfg = hedged(&baselines::pice(MODEL), 0.95, 1.0);
    cfg.dynamics = DynamicsSpec::preset("shard-blackout").expect("preset");
    let mut svc = env
        .fleet_service(
            cfg,
            ServeCfg { max_inflight: usize::MAX, deadline_s: None },
            FleetCfg { shards, placement: Placement::Hash },
        )
        .map_err(|e| e.to_string())?;
    for r in &bwl.requests {
        svc.pump_until(r.arrival_s).map_err(|e| e.to_string())?;
        svc.submit(r.question_id, r.arrival_s).map_err(|e| e.to_string())?;
    }
    svc.pump_all().map_err(|e| e.to_string())?;
    let traces: Vec<RequestTrace> = svc.finish().map_err(|e| e.to_string())?;
    let rids: HashSet<usize> = traces.iter().map(|t| t.rid).collect();
    let no_lost = traces.len() == bn && rids.len() == bn;
    let failovers: usize = traces.iter().map(|t| t.failovers).sum();
    println!(
        "\nshard-blackout x{shards}: {} / {bn} traces, {} distinct sessions, \
         {failovers} failover moves -> {}",
        traces.len(),
        rids.len(),
        if no_lost { "no request lost" } else { "REQUESTS LOST (BUG?)" }
    );
    rows.push(obj(vec![
        ("bench", s("blackout_no_lost")),
        ("submitted", num(bn as f64)),
        ("traces", num(traces.len() as f64)),
        ("distinct", num(rids.len() as f64)),
        ("failover_moves", num(failovers as f64)),
        ("no_lost", num(no_lost as i32 as f64)),
    ]));
    assert!(no_lost, "shard-blackout fleet lost requests: {} of {bn} finished", traces.len());

    let json = Json::Arr(rows);
    common::dump("fig_tail", json.clone());
    // cross-PR trajectory file at the repo root (benches run with CWD =
    // rust/, so resolve the root from the manifest dir)
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(std::path::Path::to_path_buf)
        .unwrap_or_default();
    let path = root.join("BENCH_fig_tail.json");
    if std::fs::write(&path, json.to_string()).is_ok() {
        println!("[saved {}]", path.display());
    }
    println!(
        "\npaper shape: tail latency is set by the slowest expansion pull, not\n\
         the average one; the quantile watchdog re-dispatches a straggler's\n\
         pending slots to healthy capacity, trading bounded duplicate compute\n\
         for the p99/p99.9 win, and the same machinery re-homes a blacked-out\n\
         shard's queue so no session is ever lost."
    );
    common::report_sweep_stats(&env);
    Ok(())
}
