//! Load-sweep scenario grid — rpm × edge count × policy × dynamics, the
//! whole-tradeoff-surface characterization that Edge-First-style cloud-edge
//! studies call for and that was previously too slow to run as a
//! sequential loop. The grid executes concurrently on the scenario-sweep
//! runner (`PICE_SWEEP_THREADS`) over one shared generation cache, so the
//! scenarios that replay each workload serve each other's generations
//! instead of recomputing them. The dynamics axis replays each cell in a
//! static world and under the named environment presets (PERF.md
//! §Dynamics subsystem) — deterministic per cell, so the grid stays
//! bit-identical at any thread count.

mod common;

use std::sync::Arc;
use std::time::Instant;

use pice::baselines;
use pice::coordinator::EngineCfg;
use pice::dynamics::DynamicsSpec;
use pice::quality::judge::Judge;
use pice::scenario::{bench_n, Env};
use pice::sweep::{sweep_threads, SweepScenario};
use pice::util::json::{num, obj, s, Json};

fn main() -> Result<(), String> {
    common::default_memo_path();
    let env = Env::load()?;
    let judge = Judge::fit(&env.corpus);
    let model = "llama70b-sim";
    let base_rpm = env.paper_rpm(model);
    let smoke = std::env::var("PICE_BENCH_SMOKE").as_deref() == Ok("1");
    let n = bench_n();

    let rpm_mults: &[f64] = if smoke { &[1.0] } else { &[0.75, 1.0, 1.5] };
    let edge_counts: &[usize] = if smoke { &[2, 4] } else { &[2, 4, 8] };
    let dyn_axis: &[&str] = if smoke { &["stable", "edge-churn"] } else {
        &["stable", "flaky-wan", "edge-churn"]
    };
    type MkCfg = fn(&str) -> EngineCfg;
    let policies: [(&str, MkCfg); 3] = [
        ("PICE", baselines::pice),
        ("Cloud-only", baselines::cloud_only),
        ("Routing", baselines::routing),
    ];

    // one workload per load level, shared by every (edges, policy, dynamics)
    // variant at that level — the cross-variant cache case
    let mut scenarios: Vec<(f64, usize, &str, &str, SweepScenario)> = Vec::new();
    for &mult in rpm_mults {
        let wl = Arc::new(env.workload(base_rpm * mult, n, 29));
        for &ne in edge_counts {
            for (pname, mk) in &policies {
                for &dname in dyn_axis {
                    let mut cfg = mk(model);
                    cfg.n_edges = ne;
                    cfg.dynamics = DynamicsSpec::preset(dname).expect("known preset");
                    let label = format!("{pname} x{mult:.2} e{ne} {dname}");
                    let sc = SweepScenario::new(label, cfg, wl.clone());
                    scenarios.push((mult, ne, pname, dname, sc));
                }
            }
        }
    }
    let grid: Vec<SweepScenario> = scenarios.iter().map(|(_, _, _, _, sc)| sc.clone()).collect();

    common::banner(
        "Sweep grid",
        "load (rpm) x edge count x policy — concurrent scenario sweep",
    );
    println!(
        "{} scenarios x {} reqs, {} sweep threads",
        grid.len(),
        n,
        sweep_threads()
    );
    let t0 = Instant::now();
    let outcomes = env.run_sweep(&grid);
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "{:<12} {:>6} {:>6} {:>10} | {:>10} {:>8} {:>8} {:>8} {:>8}",
        "policy", "rpm x", "edges", "dynamics", "thpt(q/m)", "lat(s)", "p95(s)", "quality",
        "failover"
    );
    let mut rows = Vec::new();
    for ((mult, ne, pname, dname, _), outcome) in scenarios.iter().zip(outcomes) {
        let (m, traces) = outcome.map_err(|e| e.to_string())?;
        let q = common::mean_quality(&env, &judge, &traces);
        println!(
            "{pname:<12} {mult:>6.2} {ne:>6} {dname:>10} | {:>10.2} {:>8.2} {:>8.2} {:>8.2} {:>8}",
            m.throughput_qpm, m.avg_latency_s, m.p95_latency_s, q, m.failovers
        );
        rows.push(obj(vec![
            ("policy", s(pname)),
            ("rpm_mult", num(*mult)),
            ("rpm", num(base_rpm * mult)),
            ("edges", num(*ne as f64)),
            ("dynamics", s(dname)),
            ("throughput_qpm", num(m.throughput_qpm)),
            ("latency_s", num(m.avg_latency_s)),
            ("p95_s", num(m.p95_latency_s)),
            ("quality", num(q)),
            ("failovers", num(m.failovers as f64)),
        ]));
    }
    common::dump("sweep_grid", Json::Arr(rows));
    println!("\ngrid wall time: {wall:.2}s ({} scenarios)", grid.len());
    println!(
        "paper shape: PICE's throughput lead over Cloud-only widens with load and\n\
         with edge count; Routing sits between, degrading as misroutes pile up."
    );
    common::report_sweep_stats(&env);
    Ok(())
}
