//! Shared plumbing for the paper-reproduction benches (one bench per table
//! and figure of the evaluation section — see DESIGN.md §4).
//!
//! Benches print the paper-style rows/series and also dump machine-readable
//! JSON into `bench_results/` for EXPERIMENTS.md.

#![allow(dead_code)]

use pice::quality::judge::Judge;
use pice::scenario::Env;
use pice::util::json::Json;
use pice::util::stats;

pub fn banner(name: &str, what: &str) {
    println!("\n================================================================");
    println!("{name} — {what}");
    println!("================================================================");
}

/// Mean judge score of a run's answers.
pub fn mean_quality(env: &Env, judge: &Judge, traces: &[pice::metrics::RequestTrace]) -> f64 {
    let scores: Vec<f64> = traces
        .iter()
        .filter_map(|t| env.corpus.get(t.question_id).map(|q| judge.score(q, &t.answer).overall))
        .collect();
    stats::mean(&scores)
}

/// Write a bench result JSON under bench_results/.
pub fn dump(name: &str, value: Json) {
    let dir = std::path::Path::new("bench_results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(format!("{name}.json"));
    if std::fs::write(&path, value.to_string()).is_ok() {
        println!("[saved {}]", path.display());
    }
}

/// Quality scoring per category; returns (category -> mean overall).
pub fn quality_by_category(
    env: &Env,
    judge: &Judge,
    traces: &[pice::metrics::RequestTrace],
) -> std::collections::BTreeMap<String, f64> {
    let mut acc: std::collections::BTreeMap<String, (f64, usize)> = Default::default();
    for t in traces {
        if let Some(q) = env.corpus.get(t.question_id) {
            let s = judge.score(q, &t.answer).overall;
            let e = acc.entry(t.category.clone()).or_insert((0.0, 0));
            e.0 += s;
            e.1 += 1;
        }
    }
    acc.into_iter().map(|(c, (s, n))| (c, s / n.max(1) as f64)).collect()
}
