//! Shared plumbing for the paper-reproduction benches (one bench per table
//! and figure of the evaluation section — see DESIGN.md §4).
//!
//! Benches print the paper-style rows/series and also dump machine-readable
//! JSON into `bench_results/` for EXPERIMENTS.md.

#![allow(dead_code)]

use pice::quality::judge::Judge;
use pice::scenario::Env;
use pice::util::json::Json;
use pice::util::stats;

pub fn banner(name: &str, what: &str) {
    println!("\n================================================================");
    println!("{name} — {what}");
    println!("================================================================");
}

/// Mean judge score of a run's answers.
pub fn mean_quality(env: &Env, judge: &Judge, traces: &[pice::metrics::RequestTrace]) -> f64 {
    let scores: Vec<f64> = traces
        .iter()
        .filter_map(|t| env.corpus.get(t.question_id).map(|q| judge.score(q, &t.answer).overall))
        .collect();
    stats::mean(&scores)
}

/// Write a bench result JSON under bench_results/.
pub fn dump(name: &str, value: Json) {
    let dir = std::path::Path::new("bench_results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(format!("{name}.json"));
    if std::fs::write(&path, value.to_string()).is_ok() {
        println!("[saved {}]", path.display());
    }
}

/// Default the persistent-cache path for figure benches: if `PICE_MEMO_PATH`
/// is unset, point it at the shared `bench_results/memo_store` paged
/// directory so the figure benches warm each other's caches across
/// processes (the store is stamp-guarded and semantically transparent, so
/// this never changes results). Export `PICE_MEMO_PATH=` (empty) to
/// disable persistence.
pub fn default_memo_path() {
    if std::env::var_os("PICE_MEMO_PATH").is_none() {
        std::env::set_var("PICE_MEMO_PATH", "bench_results/memo_store");
    }
}

/// Print the memo-cache hit/miss line for a bench's env, if a cache layer
/// is active and saw traffic. With `PICE_MEMO_PATH` set, the hits include
/// entries restored from a previous process — the cross-run cache the
/// figure benches share (PERF.md §Persistent cache).
pub fn report_memo_stats(env: &Env) {
    if let Some((hits, misses)) = env.memo_stats() {
        let total = hits + misses;
        if total > 0 {
            println!(
                "memo cache: {hits} hits / {misses} misses ({:.1}% hit rate)",
                hits as f64 / total as f64 * 100.0
            );
        }
    }
}

/// Print the shared-cache line for a sweep-driven bench: hit rate plus the
/// cross-variant fraction (lookups served by an entry another scenario —
/// or a restored snapshot — inserted). Superset of [`report_memo_stats`];
/// use it for benches that ran through `Env::run_sweep`.
pub fn report_sweep_stats(env: &Env) {
    if let Some(s) = env.cache_stats() {
        if s.lookups() > 0 {
            println!(
                "shared cache: {} hits / {} misses ({:.1}% hit rate, {:.1}% cross-variant)",
                s.hits,
                s.misses,
                s.hit_rate() * 100.0,
                s.cross_hit_rate() * 100.0
            );
        }
    }
    if let Some(restored) = env.restored_entries() {
        if restored > 0 {
            println!("  ({restored} entries restored from the persistent snapshot)");
        }
    }
}

/// Quality scoring per category; returns (category -> mean overall).
pub fn quality_by_category(
    env: &Env,
    judge: &Judge,
    traces: &[pice::metrics::RequestTrace],
) -> std::collections::BTreeMap<String, f64> {
    let mut acc: std::collections::BTreeMap<String, (f64, usize)> = Default::default();
    for t in traces {
        if let Some(q) = env.corpus.get(t.question_id) {
            let s = judge.score(q, &t.answer).overall;
            let e = acc.entry(t.category.clone()).or_insert((0.0, 0));
            e.0 += s;
            e.1 += 1;
        }
    }
    acc.into_iter().map(|(c, (s, n))| (c, s / n.max(1) as f64)).collect()
}
