//! Fig. adaptive — online calibration vs the static offline fit under a
//! degraded, drifting WAN (the `flaky-wan` dynamics preset).
//!
//! The cost-model layer's pitch: Eq. 2 is only as good as its estimates.
//! The offline profile is fit at a nominal batch on a calm link; under
//! saturating bursty load the cloud serves slower than the fit claims, so
//! the static scheduler under-budgets, forgoes progressive inference
//! exactly when offloading would help, and piles work onto the congested
//! cloud. A [`pice::costmodel::Calibrated`] model re-fits f(l) from the
//! run's own observed service times and corrects the transfer/edge-rate
//! estimates, recovering those admissions. This bench measures that win
//! (p99 latency, calibrated vs static) and feeds two CI guards:
//! * `adaptive_win` — best calibrated p99 (cold or warm-started) must not
//!   exceed the static p99 under flaky-wan;
//! * `null_calib_identical` — the calibration *machinery* with frozen
//!   knobs (rate_alpha 0, unreachable min_samples) must be bit-identical
//!   to calibration off: observing costs nothing when learning is inert.

mod common;

use std::sync::Arc;

use pice::baselines;
use pice::corpus::workload::{Arrival, WorkloadSpec};
use pice::costmodel::{CalibMode, CalibState};
use pice::dynamics::DynamicsSpec;
use pice::scenario::{bench_n, Env};
use pice::serve::ServeCfg;
use pice::sweep::SweepScenario;
use pice::util::json::{num, obj, s, Json};

fn main() -> Result<(), String> {
    common::default_memo_path();
    let mut env = Env::load()?;
    let model = "llama70b-sim";
    // saturating regime: the offline cloud fit is most wrong exactly when
    // the cloud is loaded, which is where calibration has something to say
    let rpm = env.paper_rpm(model);
    let n = bench_n();
    let wl = Arc::new(env.workload_with(WorkloadSpec {
        rpm,
        n_requests: n,
        arrival: Arrival::BurstyPoisson { burst_factor: 3.0, burst_len: 6 },
        categories: vec![],
        seed: 37,
    }));
    common::banner("Fig adaptive", "online calibration vs static fit under flaky-wan");
    let flaky = DynamicsSpec::preset("flaky-wan").expect("preset");

    // --- learn pass: run calibrated open-loop, keep the learned state -----
    // (the service path is the one surface that exposes calibration state;
    // its traces are bit-identical to the closed-loop driver)
    // engage the cloud re-fit a third of the way through the run so the
    // smoke sizing (n = 12) exercises the same adaptation as the full run
    let min_samples = (n / 3).max(4);
    let mut learn_cfg = baselines::pice(model).with_dynamics(flaky.clone());
    learn_cfg.calib.mode = CalibMode::On;
    learn_cfg.calib.min_samples = min_samples;
    let mut svc = env.service(learn_cfg, ServeCfg::default()).map_err(|e| e.to_string())?;
    for r in &wl.requests {
        svc.pump_until(r.arrival_s).map_err(|e| e.to_string())?;
        svc.submit(r.question_id, r.arrival_s).map_err(|e| e.to_string())?;
    }
    svc.pump_all().map_err(|e| e.to_string())?;
    let summary = svc.calib_summaries().remove(0);
    let learned: Option<CalibState> = svc.calib_states().remove(0).1;
    svc.finish().map_err(|e| e.to_string())?;
    println!("learn pass: {summary}");

    // --- compare pass: static vs cold-calibrated vs warm-started ----------
    let variant = |mode: CalibMode, warm: &Option<CalibState>| {
        let mut cfg = baselines::pice(model).with_dynamics(flaky.clone());
        cfg.calib.mode = mode;
        cfg.calib.min_samples = min_samples;
        cfg.calib.warm = warm.clone();
        cfg
    };
    let names = ["PICE-static", "PICE-calibrated", "PICE-warm"];
    let grid = vec![
        SweepScenario::new(names[0], variant(CalibMode::Off, &None), wl.clone()),
        SweepScenario::new(names[1], variant(CalibMode::On, &None), wl.clone()),
        SweepScenario::new(names[2], variant(CalibMode::Warm, &learned), wl.clone()),
    ];
    let outcomes = env.run_sweep(&grid);

    println!(
        "{:<16} | {:>10} {:>8} {:>8} {:>8} {:>12}",
        "system", "thpt(q/m)", "lat(s)", "p95(s)", "p99(s)", "progressive"
    );
    let mut rows = Vec::new();
    let mut p99 = Vec::new();
    for (name, outcome) in names.iter().zip(outcomes) {
        let (m, traces) = outcome.map_err(|e| e.to_string())?;
        let progressive =
            traces.iter().filter(|t| t.mode == pice::metrics::Mode::Progressive).count();
        println!(
            "{name:<16} | {:>10.2} {:>8.2} {:>8.2} {:>8.2} {:>9}/{:<2}",
            m.throughput_qpm, m.avg_latency_s, m.p95_latency_s, m.p99_latency_s, progressive, n
        );
        rows.push(obj(vec![
            ("system", s(name)),
            ("throughput_qpm", num(m.throughput_qpm)),
            ("latency_s", num(m.avg_latency_s)),
            ("p95_s", num(m.p95_latency_s)),
            ("p99_s", num(m.p99_latency_s)),
            ("progressive", num(progressive as f64)),
        ]));
        p99.push(m.p99_latency_s);
    }
    let (static_p99, cold_p99, warm_p99) = (p99[0], p99[1], p99[2]);
    let calib_p99 = cold_p99.min(warm_p99);
    let win = calib_p99 <= static_p99 + 1e-9;
    println!(
        "\np99 under flaky-wan: static {static_p99:.2}s, calibrated cold {cold_p99:.2}s, \
         warm {warm_p99:.2}s -> calibrated {}",
        if win { "holds (<= static)" } else { "LOSES (BUG?)" }
    );
    rows.push(obj(vec![
        ("bench", s("adaptive_win")),
        ("static_p99_s", num(static_p99)),
        ("calibrated_p99_s", num(cold_p99)),
        ("warm_p99_s", num(warm_p99)),
        ("win", num(win as i32 as f64)),
    ]));
    assert!(
        win,
        "calibrated p99 ({calib_p99:.3}s) exceeds static p99 ({static_p99:.3}s) under flaky-wan"
    );

    // --- guard: frozen calibration is bit-identical to calibration off ----
    // Same trick as fig_dynamics' null-dynamics guard: turn the whole
    // observation machinery ON (learning() true, every event feeds the
    // model) but freeze the corrections (rate_alpha 0, min_samples
    // unreachable), in the calm static world. Traces must match the
    // default-off run bit for bit — proving the machinery, not just the
    // mode flag, is zero-impact when inert.
    let off_cfg = baselines::pice(model);
    let mut frozen_cfg = off_cfg.clone();
    frozen_cfg.calib.mode = CalibMode::On;
    frozen_cfg.calib.rate_alpha = 0.0;
    frozen_cfg.calib.min_samples = usize::MAX;
    let ab = env.run_sweep(&[
        SweepScenario::new("calib-off", off_cfg, wl.clone()),
        SweepScenario::new("calib-frozen", frozen_cfg, wl.clone()),
    ]);
    let mut ab = ab.into_iter();
    let (_, off_traces) = ab.next().unwrap().map_err(|e| e.to_string())?;
    let (_, frozen_traces) = ab.next().unwrap().map_err(|e| e.to_string())?;
    let same = |a: &[pice::metrics::RequestTrace], b: &[pice::metrics::RequestTrace]| {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| format!("{x:?}") == format!("{y:?}"))
    };
    let identical = same(&off_traces, &frozen_traces);
    assert!(identical, "frozen calibration diverged from calibration off");
    println!("frozen calibration machinery: bit-identical to calibration off OK");
    rows.push(obj(vec![
        ("bench", s("null_calib_identical")),
        ("identical", num(identical as i32 as f64)),
    ]));

    let json = Json::Arr(rows);
    common::dump("fig_adaptive", json.clone());
    // cross-PR trajectory file at the repo root, like perf_hotpath (benches
    // run with CWD = rust/, so resolve the root from the manifest dir)
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(std::path::Path::to_path_buf)
        .unwrap_or_default();
    let path = root.join("BENCH_fig_adaptive.json");
    if std::fs::write(&path, json.to_string()).is_ok() {
        println!("[saved {}]", path.display());
    }
    println!(
        "\npaper shape: the offline fit under-estimates a loaded cloud, so the\n\
         static scheduler forgoes progressive inference exactly when the WAN\n\
         and the cloud are both stressed; the calibrated model re-fits f(l)\n\
         from observed service times and keeps admitting, holding the tail."
    );
    common::report_sweep_stats(&env);
    Ok(())
}
