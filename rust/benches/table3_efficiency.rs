//! Table III — inference efficiency: throughput (queries/min) and average
//! end-to-end latency (s) of Cloud-only / Edge-only / Routing / PICE, for
//! each cloud model of the ladder, at RPM = 1.5x the cloud max batch.

mod common;

use pice::scenario::{bench_n, Env};
use pice::util::json::{num, obj, s, Json};

// Paper Table III reference values: (model, method) -> (thpt, latency)
const PAPER: &[(&str, [(f64, f64); 4])] = &[
    ("qwen72b-sim", [(14.89, 138.62), (-1.0, -1.0), (14.86, 145.04), (21.24, 97.34)]),
    ("llama70b-sim", [(16.33, 121.54), (-1.0, -1.0), (13.79, 143.94), (25.98, 75.15)]),
    ("qwen32b-sim", [(32.13, 72.32), (-1.0, -1.0), (30.04, 88.57), (34.81, 61.22)]),
    ("llama8b-sim", [(75.51, 28.57), (6.03, 804.21), (69.55, 74.75), (70.48, 30.21)]),
    ("qwen7b-sim", [(88.33, 30.88), (6.68, 801.23), (69.55, 68.66), (84.98, 31.78)]),
    ("qwen1.5b-sim", [(148.12, 23.71), (21.20, 210.38), (133.31, 41.28), (140.86, 26.19)]),
];

fn main() -> Result<(), String> {
    let mut env = Env::load()?;
    let n = bench_n();
    common::banner("Table III", "inference efficiency comparison (ours vs paper)");
    let mut out_rows = Vec::new();
    for (model, paper_rows) in PAPER {
        let rpm = env.paper_rpm(model);
        println!("\n--- cloud model {model} (RPM {rpm:.0}, {n} requests) ---");
        println!(
            "{:<11} {:>12} {:>10}   {:>14} {:>12}",
            "method", "thpt(q/m)", "lat(s)", "paper thpt", "paper lat"
        );
        for (i, (name, result)) in env.run_all_systems(model, rpm, n, 11).into_iter().enumerate() {
            let (pt, pl) = paper_rows[i];
            let paper_t = if pt < 0.0 { "OOM".to_string() } else { format!("{pt:.2}") };
            let paper_l = if pl < 0.0 { "OOM".to_string() } else { format!("{pl:.2}") };
            match result {
                Err(_) => {
                    println!("{name:<11} {:>12} {:>10}   {paper_t:>14} {paper_l:>12}", "OOM", "OOM");
                    out_rows.push(obj(vec![
                        ("model", s(model)),
                        ("method", s(name)),
                        ("oom", Json::Bool(true)),
                    ]));
                }
                Ok((m, _)) => {
                    println!(
                        "{name:<11} {:>12.2} {:>10.2}   {paper_t:>14} {paper_l:>12}",
                        m.throughput_qpm, m.avg_latency_s
                    );
                    out_rows.push(obj(vec![
                        ("model", s(model)),
                        ("method", s(name)),
                        ("throughput_qpm", num(m.throughput_qpm)),
                        ("latency_s", num(m.avg_latency_s)),
                        ("paper_throughput", num(pt)),
                        ("paper_latency", num(pl)),
                    ]));
                }
            }
        }
    }
    common::dump("table3_efficiency", Json::Arr(out_rows));
    println!(
        "\nshape checks: PICE > Cloud-only for 70B/72B-class; ~parity at 32B-class;\n\
         slightly behind at 7/8B-class; Edge-only OOM above 8B; Routing trails Cloud-only."
    );
    Ok(())
}
