//! Fig. 14 — sensitivity to cloud<->edge bandwidth: throughput and latency
//! across bandwidths for PICE / Cloud-only / Routing.

mod common;

use pice::baselines;
use pice::network::Link;
use pice::scenario::{bench_n, Env};
use pice::util::json::{num, obj, s, Json};

fn main() -> Result<(), String> {
    let mut env = Env::load()?;
    let model = "llama70b-sim";
    let rpm = env.paper_rpm(model);
    let n = bench_n();
    let wl = env.workload(rpm, n, 23);
    common::banner("Fig 14", "impact of bandwidth on inference efficiency");
    println!(
        "{:>10} | {:>10} {:>8} | {:>10} {:>8} | {:>10} {:>8}",
        "Mbps", "cloud q/m", "lat", "routing", "lat", "PICE", "lat"
    );
    let mut rows = Vec::new();
    for bw in [1.0, 10.0, 50.0, 100.0, 500.0, 1000.0] {
        let mut cells = Vec::new();
        for (name, mut cfg) in [
            ("Cloud-only", baselines::cloud_only(model)),
            ("Routing", baselines::routing(model)),
            ("PICE", baselines::pice(model)),
        ] {
            cfg.link = Link::new(bw, 20.0);
            let (m, _) = env.run(cfg, &wl).map_err(|e| e.to_string())?;
            rows.push(obj(vec![
                ("bandwidth_mbps", num(bw)),
                ("system", s(name)),
                ("throughput_qpm", num(m.throughput_qpm)),
                ("latency_s", num(m.avg_latency_s)),
            ]));
            cells.push((m.throughput_qpm, m.avg_latency_s));
        }
        println!(
            "{bw:>10.0} | {:>10.1} {:>8.1} | {:>10.1} {:>8.1} | {:>10.1} {:>8.1}",
            cells[0].0, cells[0].1, cells[1].0, cells[1].1, cells[2].0, cells[2].1
        );
    }
    common::dump("fig14_bandwidth", Json::Arr(rows));
    println!(
        "\npaper shape: PICE leads at every bandwidth; latency barely moves with\n\
         bandwidth (text transfers are tens of ms — inference dominates)."
    );
    Ok(())
}
