//! Fig. 7 — the execution optimizer's semantic-level parallelism:
//! (a) optimal parallelism vs sketch length per task type,
//! (b) edge inference latency vs sketch length, parallel vs serial.
//!
//! Driven directly through the batch planner against the Jetson memory
//! model (the 7B-class SLM, whose KV footprint makes the ceiling bind —
//! the paper's "limited by edge device memory" regime).

mod common;

use pice::cluster::DeviceSpec;
use pice::models::Registry;
use pice::parallel::{batch_wall, plan_batch, EdgeCostModel, Group};
use pice::util::json::{num, obj, s, Json};

fn main() -> Result<(), String> {
    let reg = Registry::builtin();
    let edge = DeviceSpec::jetson_orin("edge-0");
    let slm = reg.get("qwen7b-sim").unwrap();
    common::banner("Fig 7", "optimal parallelism & latency vs sketch length");

    // task types: (label, words per sketch sentence) — longer per-sentence
    // sketches (math/common-sense) yield fewer, longer sentences.
    let task_types: [(&str, usize); 4] =
        [("generic", 50), ("roleplay", 55), ("common-sense", 110), ("math", 130)];

    println!("(a) optimal parallelism");
    print!("{:>14}", "sketch tokens");
    for (t, _) in &task_types {
        print!(" {:>13}", t);
    }
    println!();
    let mut rows = Vec::new();
    let sweep = [100usize, 200, 300, 400, 500, 600, 700];
    for &sk in &sweep {
        print!("{sk:>14}");
        for (label, per_sent) in &task_types {
            let k = (sk / per_sent).max(1);
            // expansion is ~2.2x the sketch; split across k sentences
            let exp: Vec<usize> = (0..k).map(|_| (sk as f64 * 2.2 / k as f64) as usize).collect();
            let context = sk + exp.iter().sum::<usize>() / k + 60;
            let p_mem = edge.max_batch(slm, context).max(1);
            let cost = EdgeCostModel {
                token_s: edge.token_latency_s(slm, 1),
                batch_slowdown: pice::cluster::BATCH_TOKEN_SLOWDOWN,
                prompt_tokens: sk + 60,
                prefill_speedup: 8.0,
            };
            let refs: Vec<&[usize]> = vec![&exp];
            let (plans, wall) = plan_batch(&refs, p_mem, &cost);
            let p = plans[0].len();
            print!(" {:>13}", p);
            rows.push(obj(vec![
                ("task", s(label)),
                ("sketch_tokens", num(sk as f64)),
                ("parallelism", num(p as f64)),
                ("latency_s", num(wall)),
                ("p_mem", num(p_mem as f64)),
            ]));
        }
        println!();
    }

    println!("\n(b) edge latency: parallel (PICE) vs serial expansion");
    println!("{:>14} {:>14} {:>14} {:>10}", "sketch tokens", "parallel(s)", "serial(s)", "saved(s)");
    for &sk in &sweep {
        let k = (sk / 50).max(1);
        let exp: Vec<usize> = (0..k).map(|_| (sk as f64 * 2.2 / k as f64) as usize).collect();
        let p_mem = edge.max_batch(slm, sk + 150).max(1);
        let cost = EdgeCostModel {
            token_s: edge.token_latency_s(slm, 1),
            batch_slowdown: pice::cluster::BATCH_TOKEN_SLOWDOWN,
            prompt_tokens: sk + 60,
            prefill_speedup: 8.0,
        };
        let refs: Vec<&[usize]> = vec![&exp];
        let (_, par) = plan_batch(&refs, p_mem, &cost);
        let serial_plan: Vec<Vec<Group>> = vec![vec![(0..k).collect()]];
        let ser = batch_wall(&serial_plan, &refs, &cost);
        println!("{sk:>14} {par:>14.1} {ser:>14.1} {:>10.1}", ser - par);
    }
    common::dump("fig7_parallelism", Json::Arr(rows));
    println!(
        "\npaper shape: parallelism grows with sketch length then flattens/declines at the\n\
         memory ceiling (~500 tokens); short-answer tasks (math/common-sense) stay low;\n\
         parallel expansion saves tens of seconds at 500+ tokens."
    );
    Ok(())
}
