//! Simulated cloud-edge cluster (the Table II testbed substitute).
//!
//! Device latency/memory models are calibrated so that (a) Table I speeds
//! hold on the cloud, (b) the Jetson/A100 compute ratio scales edge speeds,
//! (c) memory limits reproduce the paper's OOM entries (Table III) and the
//! parallelism ceiling of Fig. 7. All constants live here, documented.

use crate::models::ModelInfo;
use crate::simclock::SimTime;

/// Table II: per-device physical specs.
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: String,
    pub kind: DeviceKind,
    pub memory_gb: f64,
    pub mem_bw_gbs: f64,
    pub tflops: f64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceKind {
    Cloud,
    Edge,
}

/// Table I speeds were measured on 2x A100 with vLLM — the compute basis.
pub const CLOUD_BASIS_TFLOPS: f64 = 2.0 * 624.0;
/// Weight-loading bandwidth for model switching (NVMe-class), GB/s.
pub const MODEL_LOAD_GBS: f64 = 2.0;
/// Runtime (KV + activations) per simulated sequence, as a fraction of model
/// weight memory per 1k generated tokens. Calibrated so the 72B cloud model
/// supports max batch 20 (§V-B) on 4xA100 (320 GB).
pub const SEQ_MEM_FRAC_PER_1K: f64 = 0.069;
/// Edge inference (PyTorch+Transformers, no paged KV) wastes activation
/// memory vs vLLM; this multiplier reproduces Fig. 7's parallelism ceiling.
pub const EDGE_MEM_OVERHEAD: f64 = 4.0;
/// Weight-memory headroom factor for "does the model fit at all".
pub const WEIGHT_HEADROOM: f64 = 1.1;
/// Batching efficiency: marginal per-token slowdown per extra sequence in a
/// batch (weights are re-streamed once per step regardless of batch size, so
/// larger batches raise per-step time mildly while raising throughput).
pub const BATCH_TOKEN_SLOWDOWN: f64 = 0.06;

impl DeviceSpec {
    pub fn a100_cloud(name: &str) -> Self {
        // 4x NVIDIA A100 80GB node (Table II)
        DeviceSpec {
            name: name.to_string(),
            kind: DeviceKind::Cloud,
            memory_gb: 4.0 * 80.0,
            mem_bw_gbs: 1935.0,
            tflops: 4.0 * 624.0,
        }
    }

    pub fn jetson_orin(name: &str) -> Self {
        // Jetson AGX Orin 64GB (Table II)
        DeviceSpec {
            name: name.to_string(),
            kind: DeviceKind::Edge,
            memory_gb: 64.0,
            mem_bw_gbs: 204.8,
            tflops: 137.5,
        }
    }

    /// Throughput scale vs the Table-I measurement basis.
    pub fn compute_scale(&self) -> f64 {
        match self.kind {
            // vLLM on the cloud reaches the Table-I numbers directly.
            DeviceKind::Cloud => 1.0,
            // Edge runs PyTorch (no CUDA-graph/vLLM tricks): effective
            // utilisation is lower; 0.75 matches the paper's edge-only
            // latency scale (Table III: Llama3-8B ~6 queries/min on 4 Orins).
            DeviceKind::Edge => 0.75 * self.tflops / CLOUD_BASIS_TFLOPS,
        }
    }

    /// Does this model fit (weights only)?
    pub fn fits(&self, model: &ModelInfo) -> bool {
        model.memory_gb * WEIGHT_HEADROOM <= self.memory_gb
    }

    /// Free memory after loading a model's weights.
    pub fn free_gb(&self, model: &ModelInfo) -> f64 {
        (self.memory_gb - model.memory_gb * WEIGHT_HEADROOM).max(0.0)
    }

    /// Per-sequence runtime memory for `tokens` context length, GB.
    pub fn seq_mem_gb(&self, model: &ModelInfo, tokens: usize) -> f64 {
        let base = model.memory_gb * SEQ_MEM_FRAC_PER_1K * (tokens as f64 / 1000.0);
        match self.kind {
            DeviceKind::Cloud => base,
            DeviceKind::Edge => base * EDGE_MEM_OVERHEAD,
        }
    }

    /// Max concurrent sequences at `tokens` context (the paper's batch /
    /// parallelism ceiling). Returns 0 if the model itself doesn't fit.
    pub fn max_batch(&self, model: &ModelInfo, tokens: usize) -> usize {
        if !self.fits(model) {
            return 0;
        }
        let per_seq = self.seq_mem_gb(model, tokens.max(64));
        if per_seq <= 0.0 {
            return 64;
        }
        (self.free_gb(model) / per_seq).floor().min(64.0) as usize
    }

    /// Per-token decode latency for one sequence inside a batch of `b`.
    pub fn token_latency_s(&self, model: &ModelInfo, b: usize) -> SimTime {
        let scale = self.compute_scale();
        let base = 1.0 / (model.speed_tps * scale);
        base * (1.0 + BATCH_TOKEN_SLOWDOWN * (b.saturating_sub(1)) as f64)
    }

    /// Time to generate `tokens` for each member of a batch of `b`.
    pub fn gen_time_s(&self, model: &ModelInfo, tokens: usize, b: usize) -> SimTime {
        tokens as f64 * self.token_latency_s(model, b)
    }

    /// Prefill cost: processing the prompt is compute-bound and much faster
    /// than decode; model it as `prompt_tokens` at 8x decode speed.
    pub fn prefill_time_s(&self, model: &ModelInfo, prompt_tokens: usize, b: usize) -> SimTime {
        self.gen_time_s(model, prompt_tokens, b) / 8.0
    }

    /// Time to (re)load a model's weights — the model-switching overhead
    /// Algorithm 2 avoids.
    pub fn model_load_s(&self, model: &ModelInfo) -> SimTime {
        model.memory_gb / MODEL_LOAD_GBS
    }
}

/// The paper's testbed: one cloud node + N Jetson edges.
#[derive(Clone, Debug)]
pub struct Cluster {
    pub cloud: DeviceSpec,
    pub edges: Vec<DeviceSpec>,
}

impl Cluster {
    pub fn testbed(n_edges: usize) -> Self {
        Cluster {
            cloud: DeviceSpec::a100_cloud("cloud-0"),
            edges: (0..n_edges).map(|i| DeviceSpec::jetson_orin(&format!("edge-{i}"))).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Registry;

    #[test]
    fn cloud_batch_calibration() {
        // §V-B: max batch for the 72B model on the cloud is ~20 at the
        // serving context (~1k tokens).
        let r = Registry::builtin();
        let cloud = DeviceSpec::a100_cloud("c");
        let b = cloud.max_batch(r.get("qwen72b-sim").unwrap(), 1000);
        assert!((17..=23).contains(&b), "72B cloud max batch = {b}");
    }

    #[test]
    fn oom_rules_match_table3() {
        // Table III: edge-only OOMs for the 72B/70B/32B models, works for <=8B
        let r = Registry::builtin();
        let edge = DeviceSpec::jetson_orin("e");
        assert!(!edge.fits(r.get("qwen72b-sim").unwrap()));
        assert!(!edge.fits(r.get("qwen32b-sim").unwrap()));
        assert!(edge.fits(r.get("llama8b-sim").unwrap()));
        assert!(edge.fits(r.get("qwen1.5b-sim").unwrap()));
    }

    #[test]
    fn edge_slower_than_cloud() {
        let r = Registry::builtin();
        let m = r.get("llama8b-sim").unwrap();
        let cloud = DeviceSpec::a100_cloud("c");
        let edge = DeviceSpec::jetson_orin("e");
        let c = edge.token_latency_s(m, 1) / cloud.token_latency_s(m, 1);
        // cost coefficient c should be > 5 (Jetson much slower than 2xA100)
        assert!(c > 5.0, "cost coefficient {c}");
    }

    #[test]
    fn edge_parallelism_ceiling() {
        // Fig. 7: edge parallelism for a 7B model at ~1k-token context peaks
        // around 8-12 before memory runs out.
        let r = Registry::builtin();
        let m = r.get("qwen7b-sim").unwrap();
        let edge = DeviceSpec::jetson_orin("e");
        let p = edge.max_batch(m, 1000);
        assert!((6..=16).contains(&p), "edge parallelism = {p}");
    }

    #[test]
    fn batch_slows_tokens_but_helps_throughput() {
        let r = Registry::builtin();
        let m = r.get("qwen72b-sim").unwrap();
        let cloud = DeviceSpec::a100_cloud("c");
        let t1 = cloud.token_latency_s(m, 1);
        let t8 = cloud.token_latency_s(m, 8);
        assert!(t8 > t1);
        // throughput = b / t_tok(b) must still increase
        assert!(8.0 / t8 > 1.0 / t1);
    }
}
