//! Baseline configurations (paper §V-A).
//!
//! The baselines reuse the PICE engine's event loop with different admission
//! policies — the same methodology as the paper, which runs all four systems
//! on one testbed:
//!
//! * **Cloud-only** — every query served by the cloud vLLM-like engine.
//! * **Edge-only** — queries load-balanced over edge devices hosting the
//!   same model as the cloud scenario (OOM when it doesn't fit a Jetson).
//! * **Routing** — Hybrid-LLM-style difficulty router: predicted-difficulty
//!   thresholding between edge SLM and cloud LLM.

use crate::coordinator::{EngineCfg, Policy};

/// Difficulty threshold in SIM tokens (engine units; /10 for real picoLM
/// tokens): queries with predicted answers under ~40 real words go to edge.
pub const ROUTER_THRESHOLD: f64 = 400.0;

pub fn cloud_only(cloud_model: &str) -> EngineCfg {
    EngineCfg::pice(cloud_model).with_policy(Policy::CloudOnly)
}

pub fn edge_only(cloud_model: &str) -> EngineCfg {
    EngineCfg::pice(cloud_model).with_policy(Policy::EdgeOnly)
}

pub fn routing(cloud_model: &str) -> EngineCfg {
    EngineCfg::pice(cloud_model)
        .with_policy(Policy::Routing { difficulty_threshold: ROUTER_THRESHOLD })
}

pub fn pice(cloud_model: &str) -> EngineCfg {
    EngineCfg::pice(cloud_model)
}

/// All four systems in Table-III/IV order.
pub fn all(cloud_model: &str) -> Vec<(&'static str, EngineCfg)> {
    vec![
        ("Cloud-only", cloud_only(cloud_model)),
        ("Edge-only", edge_only(cloud_model)),
        ("Routing", routing(cloud_model)),
        ("PICE", pice(cloud_model)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Policy;

    #[test]
    fn four_systems() {
        let v = all("qwen72b-sim");
        assert_eq!(v.len(), 4);
        assert!(matches!(v[0].1.policy, Policy::CloudOnly));
        assert!(matches!(v[3].1.policy, Policy::Pice));
    }
}
