//! Word-level tokenizer over the build-time vocabulary (`artifacts/vocab.json`).
//!
//! The Python compile path owns vocabulary construction; this module is the
//! runtime mirror used by the Rust coordinator for every encode/decode on the
//! request path.

use std::collections::HashMap;
use std::path::Path;

use crate::util::json::Json;

/// Token ids for the special markers (fixed positions in SPECIALS).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Specials {
    pub pad: u32,
    pub bos: u32,
    pub eos: u32,
    pub q: u32,
    pub a: u32,
    pub sk: u32,
    pub ex: u32,
    pub period: u32,
    pub semicolon: u32,
    pub question_mark: u32,
}

#[derive(Clone, Debug)]
pub struct Tokenizer {
    tokens: Vec<String>,
    ids: HashMap<String, u32>,
    pub specials: Specials,
}

impl Tokenizer {
    pub fn from_file(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let json = Json::parse(&text)?;
        let tokens = json
            .req("tokens")?
            .str_vec()
            .ok_or("vocab.json: 'tokens' must be an array of strings")?;
        Self::from_tokens(tokens)
    }

    pub fn from_tokens(tokens: Vec<String>) -> Result<Self, String> {
        let mut ids = HashMap::with_capacity(tokens.len());
        for (i, t) in tokens.iter().enumerate() {
            if ids.insert(t.clone(), i as u32).is_some() {
                return Err(format!("duplicate token '{t}'"));
            }
        }
        let need = |s: &str| -> Result<u32, String> {
            ids.get(s).copied().ok_or(format!("vocab missing special '{s}'"))
        };
        let specials = Specials {
            pad: need("<pad>")?,
            bos: need("<bos>")?,
            eos: need("<eos>")?,
            q: need("<q>")?,
            a: need("<a>")?,
            sk: need("<sk>")?,
            ex: need("<ex>")?,
            period: need(".")?,
            semicolon: need(";")?,
            question_mark: need("?")?,
        };
        Ok(Tokenizer { tokens, ids, specials })
    }

    pub fn vocab_size(&self) -> usize {
        self.tokens.len()
    }

    pub fn id(&self, tok: &str) -> Option<u32> {
        self.ids.get(tok).copied()
    }

    pub fn token(&self, id: u32) -> &str {
        self.tokens.get(id as usize).map(String::as_str).unwrap_or("<unk>")
    }

    /// Encode whitespace-separated text; unknown words are skipped (the
    /// synthetic language is closed, so this only matters for user input).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.split_whitespace().filter_map(|w| self.id(w)).collect()
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter().map(|&i| self.token(i)).collect::<Vec<_>>().join(" ")
    }

    /// Decode dropping special markers — for judge/rouge scoring.
    pub fn decode_content(&self, ids: &[u32]) -> String {
        let sp = &self.specials;
        ids.iter()
            .filter(|&&i| ![sp.pad, sp.bos, sp.eos, sp.q, sp.a, sp.sk, sp.ex].contains(&i))
            .map(|&i| self.token(i))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Tokenizer {
        let toks = ["<pad>", "<bos>", "<eos>", "<q>", "<a>", "<sk>", "<ex>", ".", ";", "?",
            "the", "cat", "sat"];
        Tokenizer::from_tokens(toks.iter().map(|s| s.to_string()).collect()).unwrap()
    }

    #[test]
    fn roundtrip() {
        let t = toy();
        let ids = t.encode("the cat sat .");
        assert_eq!(t.decode(&ids), "the cat sat .");
    }

    #[test]
    fn unknown_skipped() {
        let t = toy();
        assert_eq!(t.encode("the dog sat"), vec![10, 12]);
    }

    #[test]
    fn specials_resolved() {
        let t = toy();
        assert_eq!(t.specials.pad, 0);
        assert_eq!(t.specials.eos, 2);
        assert_eq!(t.specials.period, 7);
    }

    #[test]
    fn duplicate_rejected() {
        let toks: Vec<String> = ["<pad>", "<pad>"].iter().map(|s| s.to_string()).collect();
        assert!(Tokenizer::from_tokens(toks).is_err());
    }
}
