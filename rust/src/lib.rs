//! PICE: a semantic-driven progressive inference system for LLM serving in
//! cloud-edge networks — full-system reproduction (see DESIGN.md).
//!
//! Layering:
//! * substrates: [`util`], [`tokenizer`], [`corpus`], [`simclock`],
//!   [`network`], [`cluster`], [`models`], [`profiler`], [`quality`],
//!   [`sketch`]
//! * runtime: [`runtime`] (PJRT; loads the AOT picoLM artifacts)
//! * the paper's contribution: [`coordinator`] (dynamic scheduler, job
//!   dispatching, model selection), [`costmodel`] (Eq. 2 estimation behind
//!   one trait: the static offline fit and the online-calibrated model,
//!   with persisted warm-start state), [`parallel`] (execution optimizer),
//!   [`ensemble`], [`finetune`] (RLAIF sketch policy), [`baselines`]
//! * environment dynamics: [`dynamics`] (time-varying links, edge churn /
//!   failure injection; the engine's failover re-dispatch rides on it)
//! * online serving: [`serve`] (streaming progressive-response sessions
//!   over the step-driven engine core, with admission control), [`fleet`]
//!   (N engine shards behind a hash / least-loaded placement router)
//! * storage: [`store`] (paged buffer-pool generation store — budgeted
//!   residency, clock eviction, disk spill; [`sweep::cache`] is its façade)
//! * evaluation scale-out: [`sweep`] (shared generation cache + the
//!   concurrent scenario-sweep runner), [`scenario`] (env wiring)
//! * observability: [`telemetry`] (deterministic request spans, metrics
//!   registry, Chrome-trace / snapshot exporters — zero-cost when off)

pub mod baselines;
pub mod cli;
pub mod cluster;
pub mod coordinator;
pub mod costmodel;
pub mod dynamics;
pub mod finetune;
pub mod corpus;
pub mod ensemble;
pub mod fleet;
pub mod metrics;
pub mod parallel;
pub mod models;
pub mod network;
pub mod profiler;
pub mod quality;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod simclock;
pub mod sketch;
pub mod store;
pub mod sweep;
pub mod telemetry;
pub mod testkit;
pub mod tokenizer;
pub mod util;

use std::path::PathBuf;

/// Default artifacts directory (relative to the repo root / cwd).
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("PICE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
