//! The online serving API: streaming progressive-response sessions over the
//! step-driven engine core.
//!
//! PICE's product is not a batch of traces — it is a *sketch that arrives
//! early* and *expansions that stream in behind it* (PAPER §IV). This module
//! exposes that contract:
//!
//! * [`PiceService`] — a session façade over [`Engine`]: `submit()` returns a
//!   [`RequestHandle`]; pumping the service advances simulated time and
//!   routes per-request [`ResponseEvent`]s (`Admitted`, `SketchReady`,
//!   `ExpansionChunk`, `Final`, `Rejected`) into each session's stream.
//! * **Admission control / backpressure** — [`ServeCfg::max_inflight`] bounds
//!   concurrently admitted requests; a submission over the bound is not
//!   silently dropped: its handle immediately carries a terminal
//!   [`ResponseEventKind::Rejected`] and the engine never sees it.
//! * **SLO-aware admission** — an optional [`ServeCfg::deadline_s`] rejects
//!   up-front (`Rejected{reason: "infeasible: …"}`) when the engine's
//!   current backlog estimate already exceeds the request's deadline, so
//!   doomed work never occupies the cluster.
//!
//! Every request stream satisfies three invariants (enforced by
//! `rust/tests/serve_streaming.rs`): event timestamps are monotone in sim
//! time, `SketchReady` precedes every `ExpansionChunk`, and exactly one
//! terminal event (`Final` or `Rejected`) is delivered per submission.
//!
//! Determinism: driving a workload open-loop through the service (submit
//! each request at its arrival time, pumping between submissions) produces
//! traces **bit-identical** to the closed-loop [`Engine::run`] driver —
//! external arrivals are injected ahead of same-instant internal events
//! (see [`crate::simclock::FIRST_CLASS`]), so the event interleaving is
//! exactly what scheduling every arrival up-front would have produced.

use std::collections::VecDeque;

use crate::coordinator::{Engine, RunError};
use crate::fleet::Fleet;
use crate::metrics::{Mode, RequestTrace};
use crate::simclock::SimTime;
use crate::telemetry::{MetricsRegistry, Span};

/// One streamed serving event for a request session.
#[derive(Clone, Debug)]
pub struct ResponseEvent {
    /// session id of the request this event belongs to (engine rid while
    /// inside the engine; rewritten to the session id by [`PiceService`])
    pub rid: usize,
    /// simulated timestamp the event became visible to the client
    pub t: SimTime,
    pub kind: ResponseEventKind,
}

#[derive(Clone, Debug)]
pub enum ResponseEventKind {
    /// the request passed admission and the scheduler chose its serving mode
    Admitted { mode: Mode },
    /// the cloud sketch is ready — the early, low-latency partial response
    SketchReady { text: String },
    /// one edge expansion (ensemble candidate) arrived behind the sketch
    ExpansionChunk { slot: usize, text: String },
    /// terminal: the request finished; the full trace is attached
    Final { trace: RequestTrace },
    /// terminal: admission control turned the request away (backpressure)
    Rejected { reason: String },
}

impl ResponseEventKind {
    /// Terminal events end a session's stream (exactly one per request).
    pub fn is_terminal(&self) -> bool {
        matches!(self, ResponseEventKind::Final { .. } | ResponseEventKind::Rejected { .. })
    }
}

/// Service-level admission knobs (the engine's own queue policy is part of
/// [`crate::coordinator::EngineCfg`]; this bounds what enters the engine).
#[derive(Clone, Debug)]
pub struct ServeCfg {
    /// max requests concurrently admitted (submitted, not yet terminal);
    /// submissions past the bound are rejected with a terminal
    /// [`ResponseEventKind::Rejected`] instead of queuing unboundedly.
    pub max_inflight: usize,
    /// optional per-request SLO deadline (seconds, end-to-end): a
    /// submission is rejected up-front with a terminal
    /// `Rejected{reason: "infeasible: …"}` when the engine's current
    /// backlog estimate ([`Engine::backlog_estimate_s`] — the cost model's
    /// Eq. 2 backlog over the queued expansion jobs plus one sketch
    /// transfer on the live link, memoized per engine event) already
    /// exceeds it. `None` (the default) admits purely by `max_inflight`,
    /// exactly the pre-SLO behavior.
    pub deadline_s: Option<SimTime>,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg { max_inflight: 256, deadline_s: None }
    }
}

/// Opaque per-request session handle returned by [`PiceService::submit`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RequestHandle {
    sid: usize,
}

impl RequestHandle {
    /// Session id — also the `rid` stamped on this session's events.
    pub fn id(&self) -> usize {
        self.sid
    }
}

struct Session {
    /// events routed to this session, FIFO
    queue: VecDeque<ResponseEvent>,
    terminal: bool,
}

/// What the service fronts: one engine (the original contract) or a
/// [`Fleet`] of engine shards. Both expose the same step-driven surface —
/// sequential rids, time-ordered event stream, pump/trace drains — so every
/// session/admission/streaming invariant above holds unchanged over N
/// shards.
enum ServeCore<'a> {
    Engine(Engine<'a>),
    Fleet(Fleet<'a>),
}

impl<'a> ServeCore<'a> {
    fn now(&self) -> SimTime {
        match self {
            ServeCore::Engine(e) => e.now(),
            ServeCore::Fleet(f) => f.now(),
        }
    }

    fn is_idle(&self) -> bool {
        match self {
            ServeCore::Engine(e) => e.is_idle(),
            ServeCore::Fleet(f) => f.is_idle(),
        }
    }

    /// Submit; returns `(rid, shard)` — shard is `None` on the
    /// single-engine core. Rids are sequential in submission order on both
    /// cores (the fleet allocates global ids at its router).
    fn submit(
        &mut self,
        question_id: usize,
        arrival: SimTime,
        session_key: u64,
    ) -> Result<(usize, Option<usize>), RunError> {
        match self {
            ServeCore::Engine(e) => Ok((e.submit(question_id, arrival)?, None)),
            ServeCore::Fleet(f) => {
                let rid = f.submit(question_id, arrival, session_key)?;
                Ok((rid, Some(f.route_of(rid))))
            }
        }
    }

    /// Backlog the request behind this session key would inherit — on a
    /// fleet, the estimate of the shard placement would actually choose.
    fn backlog_estimate_s(&mut self, session_key: u64) -> SimTime {
        match self {
            ServeCore::Engine(e) => e.backlog_estimate_s(),
            ServeCore::Fleet(f) => f.backlog_estimate_for(session_key),
        }
    }

    fn pump_until(&mut self, horizon: SimTime) -> Result<(), RunError> {
        match self {
            ServeCore::Engine(e) => e.pump_until(horizon),
            ServeCore::Fleet(f) => f.pump_until(horizon),
        }
    }

    fn pump_all(&mut self) -> Result<(), RunError> {
        match self {
            ServeCore::Engine(e) => e.pump_all(),
            ServeCore::Fleet(f) => f.pump_all(),
        }
    }

    fn take_events(&mut self) -> Vec<ResponseEvent> {
        match self {
            ServeCore::Engine(e) => e.take_events(),
            ServeCore::Fleet(f) => f.take_events(),
        }
    }

    fn take_traces(&mut self) -> Vec<RequestTrace> {
        match self {
            ServeCore::Engine(e) => e.take_traces(),
            ServeCore::Fleet(f) => f.take_traces(),
        }
    }

    fn calib_summaries(&self) -> Vec<crate::costmodel::CalibSummary> {
        match self {
            ServeCore::Engine(e) => vec![e.calib_summary()],
            ServeCore::Fleet(f) => f.calib_summaries(),
        }
    }

    fn calib_states(&self) -> Vec<(String, Option<crate::costmodel::CalibState>)> {
        match self {
            ServeCore::Engine(e) => vec![(e.calib_key(), e.calib_state())],
            ServeCore::Fleet(f) => (0..f.n_shards())
                .map(|s| (f.shard(s).calib_key(), f.shard(s).calib_state()))
                .collect(),
        }
    }

    fn enable_telemetry(&mut self) {
        match self {
            ServeCore::Engine(e) => e.enable_telemetry(0),
            ServeCore::Fleet(f) => f.enable_telemetry(),
        }
    }

    fn take_spans(&mut self) -> Vec<Span> {
        match self {
            ServeCore::Engine(e) => e.take_spans(),
            ServeCore::Fleet(f) => f.take_spans(),
        }
    }

    fn metrics_registries(&self) -> Option<(MetricsRegistry, Vec<MetricsRegistry>)> {
        match self {
            ServeCore::Engine(e) => {
                let r = e.metrics_registry()?.clone();
                Some((r.clone(), vec![r]))
            }
            ServeCore::Fleet(f) => f.metrics_registries(),
        }
    }

    /// Per-shard `(backlog estimate, live edges)` at this instant — the
    /// snapshot exporter's gauges (one entry over an engine core).
    fn shard_gauges(&mut self) -> Vec<(SimTime, usize)> {
        match self {
            ServeCore::Engine(e) => vec![(e.backlog_estimate_s(), e.up_edges())],
            ServeCore::Fleet(f) => (0..f.n_shards())
                .map(|s| {
                    let e = f.shard_mut(s);
                    (e.backlog_estimate_s(), e.up_edges())
                })
                .collect(),
        }
    }
}

/// Streaming serving façade over the step-driven [`Engine`] core.
///
/// ```ignore
/// let mut svc = PiceService::new(engine, ServeCfg::default());
/// let h = svc.submit(question_id, arrival_s)?;
/// svc.pump_all()?;                      // or pump_until(horizon) open-loop
/// while let Some(ev) = svc.poll(&h) { /* stream to the client */ }
/// ```
pub struct PiceService<'a> {
    core: ServeCore<'a>,
    cfg: ServeCfg,
    sessions: Vec<Session>,
    /// core rid -> session id (admitted submissions only)
    rid_to_sid: Vec<usize>,
    /// session id -> fleet shard (None for rejected submissions and on the
    /// single-engine core) — the per-shard metrics breakdown key
    sid_shard: Vec<Option<usize>>,
    /// one session-id marker per routed event, in global emission order —
    /// backs [`PiceService::poll_any`] without cloning events
    order: VecDeque<usize>,
    inflight: usize,
    rejected: usize,
}

impl<'a> PiceService<'a> {
    /// Wrap an engine; enables its streaming event sink.
    pub fn new(mut engine: Engine<'a>, cfg: ServeCfg) -> Self {
        engine.enable_events();
        PiceService::over(ServeCore::Engine(engine), cfg)
    }

    /// Wrap a [`Fleet`] of engine shards; enables streaming on every shard.
    /// Sessions, admission control (`max_inflight`, `deadline_s`) and the
    /// streaming invariants work unchanged — `deadline_s` tests against
    /// the backlog of the shard placement would choose for the session.
    pub fn over_fleet(mut fleet: Fleet<'a>, cfg: ServeCfg) -> Self {
        fleet.enable_events();
        PiceService::over(ServeCore::Fleet(fleet), cfg)
    }

    fn over(core: ServeCore<'a>, cfg: ServeCfg) -> Self {
        PiceService {
            core,
            cfg,
            sessions: Vec::new(),
            rid_to_sid: Vec::new(),
            sid_shard: Vec::new(),
            order: VecDeque::new(),
            inflight: 0,
            rejected: 0,
        }
    }

    /// Current simulated time of the underlying core (on a fleet, the
    /// furthest shard clock).
    pub fn now(&self) -> SimTime {
        self.core.now()
    }

    /// Requests admitted and not yet terminal.
    pub fn inflight(&self) -> usize {
        self.inflight
    }

    /// Submissions turned away by admission control so far.
    pub fn rejected(&self) -> usize {
        self.rejected
    }

    /// Submit a request arriving at simulated time `arrival` (>= `now()`;
    /// earlier values clamp to now). Backpressure is an API outcome, not a
    /// drop: over [`ServeCfg::max_inflight`], the returned handle's stream
    /// carries a terminal [`ResponseEventKind::Rejected`] immediately and
    /// the engine is never touched. `Err` is reserved for hard failures
    /// (infeasible placement, backend errors).
    pub fn submit(
        &mut self,
        question_id: usize,
        arrival: SimTime,
    ) -> Result<RequestHandle, RunError> {
        let key = self.sessions.len() as u64;
        self.submit_with_key(question_id, arrival, key)
    }

    /// [`PiceService::submit`] with an explicit session key. On a fleet the
    /// key drives placement — callers with client affinity (one user, many
    /// requests) pass a stable key so hash placement co-locates the
    /// session. On a single engine the key is ignored. The default
    /// [`PiceService::submit`] uses the session id as key.
    pub fn submit_with_key(
        &mut self,
        question_id: usize,
        arrival: SimTime,
        session_key: u64,
    ) -> Result<RequestHandle, RunError> {
        let sid = self.sessions.len();
        if self.inflight >= self.cfg.max_inflight {
            let reason = format!(
                "admission: {} requests in flight (max_inflight {})",
                self.inflight, self.cfg.max_inflight
            );
            return Ok(self.reject(sid, arrival, reason));
        }
        // SLO-aware admission: reject-on-infeasible instead of letting a
        // doomed request queue (the client can retry elsewhere/later)
        if let Some(deadline) = self.cfg.deadline_s {
            let est = self.core.backlog_estimate_s(session_key);
            if est > deadline {
                let reason = format!(
                    "infeasible: backlog estimate {est:.2}s exceeds deadline {deadline:.2}s"
                );
                return Ok(self.reject(sid, arrival, reason));
            }
        }
        let (rid, shard) = self.core.submit(question_id, arrival, session_key)?;
        debug_assert_eq!(rid, self.rid_to_sid.len(), "core rids are sequential");
        self.rid_to_sid.push(sid);
        self.sid_shard.push(shard);
        self.sessions.push(Session { queue: VecDeque::new(), terminal: false });
        self.inflight += 1;
        Ok(RequestHandle { sid })
    }

    /// Process every event strictly before `horizon`, routing response
    /// events to their sessions. Submit arrivals at `horizon` *before*
    /// pumping past it to keep the open-loop run bit-identical to the
    /// closed-loop driver.
    pub fn pump_until(&mut self, horizon: SimTime) -> Result<(), RunError> {
        let res = self.core.pump_until(horizon);
        self.route();
        res
    }

    /// Drain the engine to quiescence (all submitted work finished).
    pub fn pump_all(&mut self) -> Result<(), RunError> {
        let res = self.core.pump_all();
        self.route();
        res
    }

    /// Close a session before the engine ever sees it: an immediate
    /// terminal [`ResponseEventKind::Rejected`] (backpressure or an
    /// infeasible SLO), never a silent drop.
    fn reject(&mut self, sid: usize, arrival: SimTime, reason: String) -> RequestHandle {
        let t = arrival.max(self.core.now());
        let mut queue = VecDeque::new();
        let kind = ResponseEventKind::Rejected { reason };
        queue.push_back(ResponseEvent { rid: sid, t, kind });
        self.sessions.push(Session { queue, terminal: true });
        self.sid_shard.push(None);
        self.order.push_back(sid);
        self.rejected += 1;
        RequestHandle { sid }
    }

    fn route(&mut self) {
        for mut ev in self.core.take_events() {
            let sid = self.rid_to_sid[ev.rid];
            // the session id is the client-facing request id — on the event
            // AND on the embedded terminal trace, so a client keying state
            // by either sees one id even when rejections made session ids
            // diverge from engine rids
            ev.rid = sid;
            if let ResponseEventKind::Final { trace } = &mut ev.kind {
                trace.rid = sid;
            }
            if ev.kind.is_terminal() {
                self.sessions[sid].terminal = true;
                self.inflight = self.inflight.saturating_sub(1);
            }
            self.sessions[sid].queue.push_back(ev);
            self.order.push_back(sid);
        }
    }

    /// Next pending event of this session, if any.
    pub fn poll(&mut self, h: &RequestHandle) -> Option<ResponseEvent> {
        self.sessions[h.sid].queue.pop_front()
    }

    /// Next pending event across *all* sessions, in global emission order —
    /// the live-log drain (O(events), no per-session sweep). Mixing with
    /// per-session [`PiceService::poll`]/[`PiceService::drain`] is allowed:
    /// markers whose event was already taken are skipped.
    pub fn poll_any(&mut self) -> Option<ResponseEvent> {
        while let Some(sid) = self.order.pop_front() {
            if let Some(ev) = self.sessions[sid].queue.pop_front() {
                return Some(ev);
            }
        }
        None
    }

    /// Drain every pending event of this session.
    pub fn drain(&mut self, h: &RequestHandle) -> Vec<ResponseEvent> {
        self.sessions[h.sid].queue.drain(..).collect()
    }

    /// True once the session's terminal event has been *routed* (it may
    /// still be waiting in the stream until polled).
    pub fn is_terminal(&self, h: &RequestHandle) -> bool {
        self.sessions[h.sid].terminal
    }

    /// The fleet shard this session was placed on (`None` for rejected
    /// submissions and on the single-engine core).
    pub fn shard_of(&self, h: &RequestHandle) -> Option<usize> {
        self.sid_shard.get(h.sid).copied().flatten()
    }

    /// Session-id-indexed shard placements — group
    /// [`PiceService::finish`]'s traces by `shard_routes()[trace.rid]` for
    /// the per-shard [`crate::metrics::aggregate_shards`] breakdown.
    pub fn shard_routes(&self) -> &[Option<usize>] {
        &self.sid_shard
    }

    /// True when the engine has no scheduled work left.
    pub fn idle(&self) -> bool {
        self.core.is_idle()
    }

    /// One cost-model calibration summary per underlying engine — a single
    /// entry over an engine core, one per shard (shard order) over a fleet.
    pub fn calib_summaries(&self) -> Vec<crate::costmodel::CalibSummary> {
        self.core.calib_summaries()
    }

    /// Per-engine `(calibration key, learned state)` pairs — what a warm
    /// shutdown persists. `None` states (static model / nothing learned)
    /// are for the caller to skip.
    pub fn calib_states(&self) -> Vec<(String, Option<crate::costmodel::CalibState>)> {
        self.core.calib_states()
    }

    /// Turn on deterministic request-span tracing and the metrics registry
    /// on every underlying engine shard. Off by default; enabling changes
    /// nothing about scheduling — see [`crate::telemetry`].
    pub fn enable_telemetry(&mut self) {
        self.core.enable_telemetry();
    }

    /// Drain the telemetry spans recorded so far, with each span's `rid`
    /// remapped to its session id (the same remap [`PiceService::finish`]
    /// applies to traces).
    pub fn take_spans(&mut self) -> Vec<Span> {
        let mut spans = self.core.take_spans();
        for sp in &mut spans {
            sp.rid = self.rid_to_sid[sp.rid];
        }
        spans
    }

    /// `(merged, per-shard)` metrics registries, or `None` until
    /// [`PiceService::enable_telemetry`] has been called.
    pub fn metrics_registries(&self) -> Option<(MetricsRegistry, Vec<MetricsRegistry>)> {
        self.core.metrics_registries()
    }

    /// Per-shard `(backlog estimate in seconds, live edges)` at this
    /// instant — the snapshot exporter's gauges.
    pub fn shard_gauges(&mut self) -> Vec<(SimTime, usize)> {
        self.core.shard_gauges()
    }

    /// Finish serving: drain the engine and return the completed traces,
    /// with each trace's `rid` remapped to its session id (the same id its
    /// handle and events carry — rejected submissions have no trace).
    pub fn finish(mut self) -> Result<Vec<RequestTrace>, RunError> {
        self.core.pump_all()?;
        self.route();
        let mut traces = self.core.take_traces();
        for t in &mut traces {
            t.rid = self.rid_to_sid[t.rid];
        }
        Ok(traces)
    }
}
