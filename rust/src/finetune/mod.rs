//! Model fine-tuning component (paper §IV-D, Fig. 5): RLAIF for concise,
//! semantically complete sketches.
//!
//! The paper fine-tunes the cloud LLM with (1) SFT, (2) a reward model
//! trained on AI-labeled sketch preference pairs, (3) RL with a KL leash.
//! Fine-tuning transformer weights needs GPUs we don't have; per the
//! substitution rule the *sketch policy* — the thing the pipeline actually
//! optimizes, and the thing Figs. 10/11 measure — is reproduced exactly:
//!
//! * policy: per-category keep-fraction θ_c of sketch content words;
//! * preference labeling: score(r) = β1·(1/l_r) + β2·RougeL(expand(r), y)
//!   where the expansion runs on the *real* backend (AI feedback);
//! * reward model: linear pairwise-logistic on sketch features (Eq. L_R);
//! * RL: policy-gradient ascent on R_φ − γ·KL(θ‖θ_SFT).

pub mod policy;
pub mod reward;

pub use policy::{FinetuneOutcome, SketchPolicy, Trainer, TrainerCfg};
pub use reward::{label_preference, PreferencePair, RewardModel, SketchFeatures};
