//! Sketch policy + the three-step RLAIF pipeline (paper Fig. 5).

use std::collections::BTreeMap;
use std::sync::Arc;

use super::reward::{label_preference, PreferencePair, RewardModel, SketchFeatures};
use crate::coordinator::backend::TextBackend;
use crate::corpus::{Corpus, Question};
use crate::runtime::SamplingParams;
use crate::sketch::{compress, Prompts, SketchLevel};
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

/// Per-category sketch compression policy: keep-fraction θ_c of each
/// sentence-sketch's content words (the knob the RLAIF loop tunes).
#[derive(Clone, Debug)]
pub struct SketchPolicy {
    pub keep_frac: BTreeMap<String, f64>,
    pub default_frac: f64,
}

impl SketchPolicy {
    /// The SFT starting point: uniform full sketches.
    pub fn sft(categories: &[String]) -> Self {
        SketchPolicy {
            keep_frac: categories.iter().map(|c| (c.clone(), 1.0)).collect(),
            default_frac: 1.0,
        }
    }

    pub fn frac(&self, category: &str) -> f64 {
        *self.keep_frac.get(category).unwrap_or(&self.default_frac)
    }

    /// Produce the policy's sketch of a question's reference sentences.
    pub fn sketch(&self, q: &Question, semicolon: u32) -> Vec<u32> {
        let lv = SketchLevel { level: 1, keep_frac: self.frac(&q.category).min(1.0) };
        let mut out = Vec::new();
        for (i, s) in q.sentences.iter().enumerate() {
            if i > 0 {
                out.push(semicolon);
            }
            out.extend(compress(&s.sketch, lv));
        }
        out
    }

    /// Mean sketch length per category over a corpus (Fig. 10's metric).
    pub fn mean_lengths(&self, corpus: &Corpus, semicolon: u32) -> BTreeMap<String, f64> {
        let mut sums: BTreeMap<String, (f64, usize)> = BTreeMap::new();
        for q in corpus.eval_questions() {
            let len = self.sketch(q, semicolon).len() as f64;
            let e = sums.entry(q.category.clone()).or_insert((0.0, 0));
            e.0 += len;
            e.1 += 1;
        }
        sums.into_iter().map(|(c, (s, n))| (c, s / n.max(1) as f64)).collect()
    }
}

#[derive(Clone, Debug)]
pub struct TrainerCfg {
    /// expansion model used as "AI feedback" (the base LLM of §IV-D)
    pub expander_model: String,
    /// preference pairs per category
    pub pairs_per_category: usize,
    /// RL iterations
    pub rl_steps: usize,
    /// exploration stddev for candidate keep-fractions
    pub sigma: f64,
    /// KL leash weight γ
    pub gamma: f64,
    pub lr: f64,
    pub seed: u64,
}

impl Default for TrainerCfg {
    fn default() -> Self {
        TrainerCfg {
            expander_model: "qwen72b-sim".into(),
            pairs_per_category: 12,
            rl_steps: 40,
            sigma: 0.18,
            gamma: 0.25,
            lr: 0.35,
            seed: 23,
        }
    }
}

#[derive(Clone, Debug)]
pub struct FinetuneOutcome {
    pub policy: SketchPolicy,
    pub reward_model: RewardModel,
    pub rm_train_loss: f64,
    pub rm_holdout_acc: f64,
    pub n_pairs: usize,
}

pub struct Trainer<'a> {
    pub cfg: TrainerCfg,
    pub corpus: Arc<Corpus>,
    pub tok: &'a Tokenizer,
}

impl<'a> Trainer<'a> {
    /// Expand a sketch back to a full answer with the base LLM (AI feedback).
    fn expand(
        &self,
        backend: &mut dyn TextBackend,
        q: &Question,
        sketch: &[u32],
    ) -> Result<Vec<u32>, String> {
        let semicolon = self.tok.specials.semicolon;
        let sents = crate::sketch::split_sketch(sketch, semicolon);
        let mut out = Vec::new();
        for s in &sents {
            let prompt = Prompts::expand(self.tok, &q.question, sketch, s);
            let g = backend.generate(
                &self.cfg.expander_model,
                &prompt,
                &SamplingParams {
                    max_tokens: 24,
                    stop_token: Some(self.tok.specials.period),
                    seed: self.cfg.seed,
                    ..Default::default()
                },
            )?;
            out.extend(g.tokens.iter().copied().filter(|&t| t != self.tok.specials.eos));
        }
        Ok(out)
    }

    fn features(&self, q: &Question, sketch: &[u32]) -> SketchFeatures {
        let content: std::collections::HashSet<u32> =
            q.sentences.iter().flat_map(|s| s.sketch.iter().copied()).collect();
        let kept = sketch.iter().filter(|t| content.contains(t)).count();
        SketchFeatures::compute(
            sketch.len(),
            kept as f64 / content.len().max(1) as f64,
            q.answer_len(),
        )
    }

    /// Step 2 of Fig. 5: generate sketch pairs, expand both with the base
    /// LLM, label by the β-criterion, and fit the reward model.
    pub fn collect_and_train_rm(
        &self,
        backend: &mut dyn TextBackend,
    ) -> Result<(RewardModel, Vec<PreferencePair>, f64, f64), String> {
        let mut rng = Rng::new(self.cfg.seed);
        let semicolon = self.tok.specials.semicolon;
        let mut pairs = Vec::new();
        for cat in &self.corpus.categories {
            let qs: Vec<&Question> = self
                .corpus
                .eval_questions()
                .into_iter()
                .filter(|q| &q.category == cat)
                .collect();
            for i in 0..self.cfg.pairs_per_category.min(qs.len()) {
                let q = qs[i];
                let f1 = rng.range(0.35, 1.0);
                let f2 = rng.range(0.35, 1.0);
                let p1 = SketchPolicy {
                    keep_frac: BTreeMap::new(),
                    default_frac: f1,
                };
                let p2 = SketchPolicy {
                    keep_frac: BTreeMap::new(),
                    default_frac: f2,
                };
                let r1 = p1.sketch(q, semicolon);
                let r2 = p2.sketch(q, semicolon);
                let y1 = self.expand(backend, q, &r1)?;
                let y2 = self.expand(backend, q, &r2)?;
                let reference = q.answer_tokens();
                let first_wins = label_preference(r1.len(), &y1, r2.len(), &y2, &reference);
                let (w, l) = if first_wins { (&r1, &r2) } else { (&r2, &r1) };
                pairs.push(PreferencePair {
                    winner: self.features(q, w),
                    loser: self.features(q, l),
                });
            }
        }
        let split = (pairs.len() * 4) / 5;
        let mut rm = RewardModel::default();
        let loss = rm.train(&pairs[..split.max(1)], 60, 0.4, self.cfg.seed);
        let acc = rm.accuracy(&pairs[split..]);
        Ok((rm, pairs, loss, acc))
    }

    /// Step 3 of Fig. 5: policy-gradient ascent on R_φ − γ·KL(θ‖θ_SFT),
    /// per category (REINFORCE with two-point baseline).
    pub fn rl_finetune(
        &self,
        rm: &RewardModel,
    ) -> SketchPolicy {
        let mut rng = Rng::new(self.cfg.seed ^ 0xF17E);
        let semicolon = self.tok.specials.semicolon;
        let sft = SketchPolicy::sft(&self.corpus.categories);
        let mut policy = sft.clone();
        for cat in &self.corpus.categories {
            let qs: Vec<&Question> = self
                .corpus
                .eval_questions()
                .into_iter()
                .filter(|q| &q.category == cat)
                .collect();
            if qs.is_empty() {
                continue;
            }
            let theta0 = sft.frac(cat);
            let mut theta = theta0;
            for step in 0..self.cfg.rl_steps {
                let q = qs[step % qs.len()];
                // antithetic exploration pair
                let eps = rng.normal() * self.cfg.sigma;
                let objective = |th: f64| -> f64 {
                    let p = SketchPolicy {
                        keep_frac: BTreeMap::new(),
                        default_frac: th.clamp(0.3, 1.25),
                    };
                    let sk = p.sketch(q, semicolon);
                    let r = rm.reward(&self.features(q, &sk));
                    // KL leash: Gaussian-policy KL reduces to a quadratic
                    let kl = (th - theta0) * (th - theta0) / (2.0 * self.cfg.sigma * self.cfg.sigma);
                    (1.0 - self.cfg.gamma) * r - self.cfg.gamma * kl * 0.05
                };
                let up = objective(theta + eps);
                let dn = objective(theta - eps);
                // REINFORCE gradient estimate with antithetic baseline
                let grad = (up - dn) / (2.0 * eps.abs().max(1e-6)) * eps.signum();
                theta = (theta + self.cfg.lr * grad / (1.0 + step as f64 * 0.1))
                    .clamp(0.3, 1.25);
            }
            policy.keep_frac.insert(cat.clone(), theta);
        }
        policy
    }

    /// The full pipeline (Fig. 5): SFT policy -> RM -> RL.
    pub fn run(&self, backend: &mut dyn TextBackend) -> Result<FinetuneOutcome, String> {
        let (rm, pairs, loss, acc) = self.collect_and_train_rm(backend)?;
        let policy = self.rl_finetune(&rm);
        Ok(FinetuneOutcome {
            policy,
            reward_model: rm,
            rm_train_loss: loss,
            rm_holdout_acc: acc,
            n_pairs: pairs.len(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SurrogateBackend;
    use crate::corpus::tests_support::toy_corpus;
    use crate::models::Registry;

    #[test]
    fn sft_policy_is_identity() {
        let (c, tok) = toy_corpus();
        let p = SketchPolicy::sft(&c.categories);
        let q = &c.questions[0];
        let sk = p.sketch(q, tok.specials.semicolon);
        assert_eq!(sk, q.sketch_tokens(tok.specials.semicolon));
    }

    #[test]
    fn compressed_policy_is_shorter() {
        let (c, tok) = toy_corpus();
        let mut p = SketchPolicy::sft(&c.categories);
        p.keep_frac.insert("generic".into(), 0.5);
        let q = &c.questions[0];
        let sk = p.sketch(q, tok.specials.semicolon);
        assert!(sk.len() < q.sketch_tokens(tok.specials.semicolon).len());
    }

    #[test]
    fn pipeline_runs_on_surrogate() {
        let (c, tok) = toy_corpus();
        let c = Arc::new(c);
        let reg = Registry::builtin();
        let mut backend = SurrogateBackend::new(c.clone(), &tok, &reg, 3);
        let trainer = Trainer {
            cfg: TrainerCfg { pairs_per_category: 1, rl_steps: 5, ..Default::default() },
            corpus: c.clone(),
            tok: &tok,
        };
        let out = trainer.run(&mut backend).unwrap();
        assert!(out.n_pairs >= 1);
        let f = out.policy.frac("generic");
        assert!((0.3..=1.25).contains(&f));
    }

    #[test]
    fn mean_lengths_reported_per_category() {
        let (c, tok) = toy_corpus();
        let p = SketchPolicy::sft(&c.categories);
        let m = p.mean_lengths(&c, tok.specials.semicolon);
        assert!(m.contains_key("generic"));
        assert!(m["generic"] > 0.0);
    }
}
