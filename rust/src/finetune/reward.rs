//! Preference labeling + reward model for sketch quality (paper §IV-D).

use crate::quality::rouge::rouge_l_f1;
use crate::util::rng::Rng;

/// β weights of the preference labeler:
/// score(r) = β1·(1/l_r) + β2·Rouge-L(ŷ(r), y).
pub const BETA1: f64 = 8.0;
pub const BETA2: f64 = 1.0;

/// Features the reward model sees for one (question, sketch) pair.
#[derive(Clone, Copy, Debug, Default)]
pub struct SketchFeatures {
    /// inverse sketch length (brevity)
    pub inv_len: f64,
    /// fraction of reference content words retained by the sketch
    pub coverage: f64,
    /// sketch length / reference answer length
    pub len_ratio: f64,
}

impl SketchFeatures {
    pub fn compute(sketch_len: usize, coverage: f64, ref_len: usize) -> Self {
        SketchFeatures {
            inv_len: 1.0 / sketch_len.max(1) as f64,
            coverage,
            len_ratio: sketch_len as f64 / ref_len.max(1) as f64,
        }
    }

    fn vec(&self) -> [f64; 4] {
        [self.inv_len, self.coverage, self.len_ratio, 1.0]
    }
}

/// The paper's preference-labeling criterion: shorter is better, but the
/// base-LLM expansion of the sketch must stay close to the SFT answer.
pub fn label_preference(
    len1: usize,
    expansion1: &[u32],
    len2: usize,
    expansion2: &[u32],
    reference: &[u32],
) -> bool {
    let s1 = BETA1 / len1.max(1) as f64 + BETA2 * rouge_l_f1(expansion1, reference);
    let s2 = BETA1 / len2.max(1) as f64 + BETA2 * rouge_l_f1(expansion2, reference);
    s1 >= s2
}

/// One labeled pair: winner features, loser features.
#[derive(Clone, Copy, Debug)]
pub struct PreferencePair {
    pub winner: SketchFeatures,
    pub loser: SketchFeatures,
}

/// Linear pairwise-logistic reward model, trained with the paper's loss
/// L_R(φ) = −E log σ(R_φ(x, r_w) − R_φ(x, r_l)).
#[derive(Clone, Debug)]
pub struct RewardModel {
    pub w: [f64; 4],
}

impl Default for RewardModel {
    fn default() -> Self {
        RewardModel { w: [0.0; 4] }
    }
}

fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

impl RewardModel {
    pub fn reward(&self, f: &SketchFeatures) -> f64 {
        let v = f.vec();
        self.w.iter().zip(v.iter()).map(|(a, b)| a * b).sum()
    }

    /// SGD on the pairwise logistic loss; returns the final mean loss.
    pub fn train(&mut self, pairs: &[PreferencePair], epochs: usize, lr: f64, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..pairs.len()).collect();
        let mut last = f64::INFINITY;
        for _ in 0..epochs {
            rng.shuffle(&mut order);
            let mut loss_sum = 0.0;
            for &i in &order {
                let p = &pairs[i];
                let d = self.reward(&p.winner) - self.reward(&p.loser);
                let s = sigmoid(d);
                loss_sum += -(s.max(1e-12)).ln();
                let g = 1.0 - s; // d/dd of -ln σ(d) is -(1-σ)
                let (wv, lv) = (p.winner.vec(), p.loser.vec());
                for k in 0..4 {
                    self.w[k] += lr * g * (wv[k] - lv[k]);
                }
            }
            last = loss_sum / pairs.len().max(1) as f64;
        }
        last
    }

    /// Pairwise accuracy on held-out pairs.
    pub fn accuracy(&self, pairs: &[PreferencePair]) -> f64 {
        if pairs.is_empty() {
            return 0.0;
        }
        let ok = pairs
            .iter()
            .filter(|p| self.reward(&p.winner) > self.reward(&p.loser))
            .count();
        ok as f64 / pairs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair(w_cov: f64, w_len: usize, l_cov: f64, l_len: usize) -> PreferencePair {
        PreferencePair {
            winner: SketchFeatures::compute(w_len, w_cov, 100),
            loser: SketchFeatures::compute(l_len, l_cov, 100),
        }
    }

    #[test]
    fn learns_separable_preferences() {
        // winners: short + high coverage; losers: long + low coverage
        let mut rng = Rng::new(5);
        let pairs: Vec<PreferencePair> = (0..200)
            .map(|_| {
                pair(
                    0.8 + rng.range(0.0, 0.2),
                    20 + rng.below(10),
                    0.2 + rng.range(0.0, 0.3),
                    60 + rng.below(30),
                )
            })
            .collect();
        let mut rm = RewardModel::default();
        let loss = rm.train(&pairs[..150], 50, 0.5, 1);
        assert!(loss < 0.4, "loss {loss}");
        assert!(rm.accuracy(&pairs[150..]) > 0.9);
    }

    #[test]
    fn labeler_prefers_short_when_equal_fidelity() {
        let expansion = [1u32, 2, 3, 4, 5];
        let reference = [1u32, 2, 3, 4, 5];
        assert!(label_preference(10, &expansion, 30, &expansion, &reference));
        assert!(!label_preference(30, &expansion, 10, &expansion, &reference));
    }

    #[test]
    fn labeler_rejects_lossy_over_short() {
        // extreme compression that destroys the expansion loses to a
        // moderately short sketch with a faithful expansion
        let faithful = [1u32, 2, 3, 4, 5, 6, 7, 8];
        let broken = [9u32, 9, 9];
        let reference = faithful;
        assert!(label_preference(20, &faithful, 8, &broken, &reference));
    }

    #[test]
    fn reward_monotone_in_trained_direction() {
        let mut rm = RewardModel::default();
        let pairs: Vec<PreferencePair> = (0..50).map(|_| pair(0.9, 20, 0.3, 70)).collect();
        rm.train(&pairs, 30, 0.5, 2);
        let good = SketchFeatures::compute(20, 0.9, 100);
        let bad = SketchFeatures::compute(70, 0.3, 100);
        assert!(rm.reward(&good) > rm.reward(&bad));
    }
}
