//! Autoregressive generation on top of the compiled picoLM executables.
//!
//! Hot path: prefill once, then one `decode` execution per token with the
//! KV cache held device-side as a `PjRtBuffer` (only a token id goes up and
//! a logits vector comes down per step).

use anyhow::{anyhow, bail, Result};

use super::loader::LoadedModel;
use crate::util::rng::Rng;

/// Sampling configuration for one generation call.
#[derive(Clone, Copy, Debug)]
pub struct SamplingParams {
    /// 0.0 = greedy; otherwise softmax temperature.
    pub temperature: f64,
    pub max_tokens: usize,
    /// Stop when this token is produced (besides <eos>). e.g. "." for
    /// single-sentence expansion tasks.
    pub stop_token: Option<u32>,
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { temperature: 0.0, max_tokens: 64, stop_token: None, seed: 0 }
    }
}

/// Result of one generation: tokens (without the prompt) + per-token
/// natural-log probabilities under the generating model (the ensemble's
/// perplexity input — Eq. 3 first term).
#[derive(Clone, Debug, Default)]
pub struct GenOutput {
    pub tokens: Vec<u32>,
    pub logps: Vec<f64>,
    /// true if generation ended on <eos>/stop rather than max_tokens
    pub finished: bool,
}

/// Stateless generation engine over a loaded model.
pub struct Generator<'m> {
    pub model: &'m LoadedModel,
    pub eos: u32,
}

impl<'m> Generator<'m> {
    pub fn new(model: &'m LoadedModel, eos: u32) -> Self {
        Generator { model, eos }
    }

    /// Run prefill over `prompt`, then decode until eos/stop/max_tokens.
    pub fn generate(&self, prompt: &[u32], sp: &SamplingParams) -> Result<GenOutput> {
        let m = self.model;
        let s_max = m.art.max_seq;
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if prompt.len() >= s_max {
            bail!("prompt len {} >= max_seq {}", prompt.len(), s_max);
        }
        // ---- prefill ----
        let mut padded = vec![0i32; s_max];
        for (i, &t) in prompt.iter().enumerate() {
            padded[i] = t as i32;
        }
        let tok_buf = m.i32_buffer(&padded, &[1, s_max])?;
        let len_buf = m.i32_buffer(&[prompt.len() as i32], &[1])?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf, &len_buf];
        args.extend(m.params.iter());
        let mut outs = m.prefill.execute_b(&args).map_err(|e| anyhow!("prefill: {e:?}"))?;
        // state = concat(kv.ravel(), logits). The state buffer STAYS on the
        // device and is fed back each step (execute_b); the host only reads
        // it to extract the logits tail (TFRT CPU lacks CopyRawToHost, so
        // the read is a full-state literal sync — download only, no upload;
        // see EXPERIMENTS.md §Perf).
        let mut state_buf = single_output(outs.remove(0))?;
        let logits_off = m.art.logits_offset();
        let mut state_host = vec![0f32; m.art.state_size];
        read_state(&state_buf, &mut state_host)?;

        // ---- decode loop ----
        let mut rng = Rng::new(sp.seed);
        let mut out = GenOutput::default();
        let mut pos = prompt.len();
        loop {
            let logits = &state_host[logits_off..];
            let (next, logp) = sample(logits, sp, &mut rng)?;
            out.tokens.push(next);
            out.logps.push(logp);
            if next == self.eos || Some(next) == sp.stop_token {
                out.finished = true;
                break;
            }
            if out.tokens.len() >= sp.max_tokens || pos + 1 >= s_max {
                break;
            }
            let tok_buf = m.i32_buffer(&[next as i32], &[1])?;
            let pos_buf = m.i32_buffer(&[pos as i32], &[1])?;
            let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf, &pos_buf, &state_buf];
            args.extend(m.params.iter());
            let mut outs =
                m.decode.execute_b(&args).map_err(|e| anyhow!("decode @pos {pos}: {e:?}"))?;
            state_buf = single_output(outs.remove(0))?;
            read_state(&state_buf, &mut state_host)?;
            pos += 1;
        }
        Ok(out)
    }

    /// Teacher-forcing log-probabilities of `tokens[1..]` given `tokens[..n-1]`
    /// (natural log) — perplexity of arbitrary text under this model.
    pub fn score_logps(&self, tokens: &[u32]) -> Result<Vec<f64>> {
        let m = self.model;
        let s_max = m.art.max_seq;
        if tokens.len() < 2 || tokens.len() > s_max {
            bail!("score needs 2..={s_max} tokens");
        }
        let mut padded = vec![0i32; s_max];
        for (i, &t) in tokens.iter().enumerate() {
            padded[i] = t as i32;
        }
        let tok_buf = m.i32_buffer(&padded, &[1, s_max])?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf];
        args.extend(m.params.iter());
        let mut outs = m.score.execute_b(&args).map_err(|e| anyhow!("score: {e:?}"))?;
        let buf = single_output(outs.remove(0))?;
        let lit = buf.to_literal_sync().map_err(|e| anyhow!("{e:?}"))?;
        let flat: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("{e:?}"))?;
        let v = m.art.vocab;
        if flat.len() != s_max * v {
            bail!("score output {} != {}x{}", flat.len(), s_max, v);
        }
        let mut logps = Vec::with_capacity(tokens.len() - 1);
        for i in 0..tokens.len() - 1 {
            let row = &flat[i * v..(i + 1) * v];
            logps.push(log_softmax_pick(row, tokens[i + 1] as usize));
        }
        Ok(logps)
    }
}

/// Every export returns a single flat array (return_tuple=False), so the
/// replica output must be exactly one plain buffer.
fn single_output(mut replica: Vec<xla::PjRtBuffer>) -> Result<xla::PjRtBuffer> {
    match replica.len() {
        1 => Ok(replica.remove(0)),
        n => bail!("expected 1 output buffer, got {n}"),
    }
}

/// Full host read of a device-side state buffer (dst must be exactly the
/// state size; Literal::copy_raw_to always copies the whole literal).
fn read_state(state: &xla::PjRtBuffer, dst: &mut [f32]) -> Result<()> {
    let lit = state.to_literal_sync().map_err(|e| anyhow!("read state: {e:?}"))?;
    if lit.element_count() != dst.len() {
        bail!("state size {} != {}", lit.element_count(), dst.len());
    }
    lit.copy_raw_to(dst).map_err(|e| anyhow!("copy state: {e:?}"))
}

/// Sample from logits (f32, unnormalized). Returns (token, ln p(token)).
fn sample(logits: &[f32], sp: &SamplingParams, rng: &mut Rng) -> Result<(u32, f64)> {
    if logits.is_empty() {
        bail!("empty logits");
    }
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    // log-softmax denominators at T=1 (for reported logp) and at T (sampling)
    let mut z1 = 0.0f64;
    for &l in logits {
        z1 += ((l as f64) - mx).exp();
    }
    let lnz1 = z1.ln();
    let pick = if sp.temperature <= 0.0 {
        logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    } else {
        let t = sp.temperature;
        let mut zt = 0.0f64;
        let mut probs = Vec::with_capacity(logits.len());
        for &l in logits {
            let p = (((l as f64) - mx) / t).exp();
            probs.push(p);
            zt += p;
        }
        let mut u = rng.f64() * zt;
        let mut idx = logits.len() - 1;
        for (i, p) in probs.iter().enumerate() {
            if u < *p {
                idx = i;
                break;
            }
            u -= p;
        }
        idx
    };
    let logp = (logits[pick] as f64) - mx - lnz1;
    Ok((pick as u32, logp))
}

fn log_softmax_pick(row: &[f32], idx: usize) -> f64 {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let mut z = 0.0f64;
    for &l in row {
        z += ((l as f64) - mx).exp();
    }
    (row[idx.min(row.len() - 1)] as f64) - mx - z.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_sample_argmax() {
        let logits = [0.1f32, 2.0, -1.0];
        let mut rng = Rng::new(1);
        let (t, lp) = sample(&logits, &SamplingParams::default(), &mut rng).unwrap();
        assert_eq!(t, 1);
        assert!(lp < 0.0);
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let logits = [1.0f32, 1.0, 1.0];
        let sp = SamplingParams { temperature: 1.0, seed: 3, ..Default::default() };
        let mut rng = Rng::new(3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            let (t, _) = sample(&logits, &sp, &mut rng).unwrap();
            seen.insert(t);
        }
        assert_eq!(seen.len(), 3);
    }

    #[test]
    fn logp_is_normalized() {
        let logits = [0.0f32, 0.0, 0.0, 0.0];
        let mut rng = Rng::new(1);
        let (_, lp) = sample(&logits, &SamplingParams::default(), &mut rng).unwrap();
        assert!((lp - (0.25f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn log_softmax_pick_uniform() {
        let row = [1.0f32; 10];
        assert!((log_softmax_pick(&row, 3) - (0.1f64).ln()).abs() < 1e-6);
    }
}
