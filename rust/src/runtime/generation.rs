//! Autoregressive generation on top of the compiled picoLM executables.
//!
//! Hot path: prefill once, then one `decode` execution per token with the
//! KV cache held device-side as a `PjRtBuffer` (only a token id goes up and
//! a logits vector comes down per step). Host-side buffers (padded prompt,
//! state mirror, sampling probabilities) live in a [`GenScratch`] that the
//! backend reuses across calls, so steady-state decoding allocates nothing
//! per token.

use anyhow::{anyhow, bail, Result};

use super::loader::LoadedModel;
use crate::util::rng::Rng;

/// Sampling configuration for one generation call.
#[derive(Clone, Copy, Debug)]
pub struct SamplingParams {
    /// 0.0 = greedy; otherwise softmax temperature.
    pub temperature: f64,
    pub max_tokens: usize,
    /// Stop when this token is produced (besides <eos>). e.g. "." for
    /// single-sentence expansion tasks.
    pub stop_token: Option<u32>,
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { temperature: 0.0, max_tokens: 64, stop_token: None, seed: 0 }
    }
}

/// Result of one generation: tokens (without the prompt) + per-token
/// natural-log probabilities under the generating model (the ensemble's
/// perplexity input — Eq. 3 first term).
#[derive(Clone, Debug, Default)]
pub struct GenOutput {
    pub tokens: Vec<u32>,
    pub logps: Vec<f64>,
    /// true if generation ended on <eos>/stop rather than max_tokens
    pub finished: bool,
}

/// Reusable host-side scratch for the generation hot path: the padded
/// prompt upload buffer, the full-state host mirror (TFRT CPU lacks
/// CopyRawToHost, so every step syncs the whole state down), and the
/// temperature-sampling probability buffer. One per backend worker; reuse
/// across calls removes the per-call (and per-sampled-token) allocations.
#[derive(Debug, Default)]
pub struct GenScratch {
    padded: Vec<i32>,
    state_host: Vec<f32>,
    probs: Vec<f64>,
}

/// Stateless generation engine over a loaded model.
pub struct Generator<'m> {
    pub model: &'m LoadedModel,
    pub eos: u32,
}

impl<'m> Generator<'m> {
    pub fn new(model: &'m LoadedModel, eos: u32) -> Self {
        Generator { model, eos }
    }

    /// Run prefill over `prompt`, then decode until eos/stop/max_tokens.
    /// Convenience wrapper around [`Generator::generate_with`] with a
    /// throwaway scratch; hot paths should hold a [`GenScratch`] instead.
    pub fn generate(&self, prompt: &[u32], sp: &SamplingParams) -> Result<GenOutput> {
        self.generate_with(prompt, sp, &mut GenScratch::default())
    }

    /// Prefill + decode reusing `scratch` across calls.
    pub fn generate_with(
        &self,
        prompt: &[u32],
        sp: &SamplingParams,
        scratch: &mut GenScratch,
    ) -> Result<GenOutput> {
        let m = self.model;
        let s_max = m.art.max_seq;
        let mut state_buf = self.prefill(prompt, scratch)?;
        let logits_off = m.art.logits_offset();
        scratch.state_host.resize(m.art.state_size, 0.0);
        read_state(&state_buf, &mut scratch.state_host)?;

        // ---- decode loop ----
        let mut rng = Rng::new(sp.seed);
        let mut out = GenOutput::default();
        let mut pos = prompt.len();
        loop {
            let (next, logp) =
                sample(&scratch.state_host[logits_off..], sp, &mut rng, &mut scratch.probs)?;
            out.tokens.push(next);
            out.logps.push(logp);
            if next == self.eos || Some(next) == sp.stop_token {
                out.finished = true;
                break;
            }
            if out.tokens.len() >= sp.max_tokens || pos + 1 >= s_max {
                break;
            }
            let tok_buf = m.i32_buffer(&[next as i32], &[1])?;
            let pos_buf = m.i32_buffer(&[pos as i32], &[1])?;
            let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf, &pos_buf, &state_buf];
            args.extend(m.params.iter());
            let mut outs =
                m.decode.execute_b(&args).map_err(|e| anyhow!("decode @pos {pos}: {e:?}"))?;
            state_buf = single_output(outs.remove(0))?;
            read_state(&state_buf, &mut scratch.state_host)?;
            pos += 1;
        }
        Ok(out)
    }

    /// Lockstep decoding of K independent sequences: prompts prefill as ONE
    /// padded batched execution when the artifact exports a batched entry
    /// point (falling back to per-prompt prefill otherwise — see
    /// [`Generator::prefill_many`]), then every round steps each
    /// still-active sequence once, so K decode executions are issued per
    /// token round-trip instead of running whole sequences back-to-back.
    /// Output i corresponds to `reqs[i]` and is bit-identical to a
    /// standalone [`Generator::generate`] call with the same parameters
    /// (per-sequence RNG streams, independent KV states).
    pub fn generate_many(
        &self,
        reqs: &[(&[u32], SamplingParams)],
        scratch: &mut GenScratch,
    ) -> Result<Vec<GenOutput>> {
        struct Seq {
            state: xla::PjRtBuffer,
            state_host: Vec<f32>,
            rng: Rng,
            out: GenOutput,
            pos: usize,
            done: bool,
        }
        let m = self.model;
        let s_max = m.art.max_seq;
        let mut seqs: Vec<Seq> = Vec::with_capacity(reqs.len());
        for ((state, state_host), (prompt, sp)) in
            self.prefill_many(reqs, scratch)?.into_iter().zip(reqs)
        {
            seqs.push(Seq {
                state,
                state_host,
                rng: Rng::new(sp.seed),
                out: GenOutput::default(),
                pos: prompt.len(),
                done: false,
            });
        }
        let logits_off = m.art.logits_offset();
        loop {
            let mut stepped = false;
            for (sq, (_, sp)) in seqs.iter_mut().zip(reqs) {
                if sq.done {
                    continue;
                }
                let (next, logp) =
                    sample(&sq.state_host[logits_off..], sp, &mut sq.rng, &mut scratch.probs)?;
                sq.out.tokens.push(next);
                sq.out.logps.push(logp);
                if next == self.eos || Some(next) == sp.stop_token {
                    sq.out.finished = true;
                    sq.done = true;
                    continue;
                }
                if sq.out.tokens.len() >= sp.max_tokens || sq.pos + 1 >= s_max {
                    sq.done = true;
                    continue;
                }
                let tok_buf = m.i32_buffer(&[next as i32], &[1])?;
                let pos_buf = m.i32_buffer(&[sq.pos as i32], &[1])?;
                let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf, &pos_buf, &sq.state];
                args.extend(m.params.iter());
                let mut outs = m
                    .decode
                    .execute_b(&args)
                    .map_err(|e| anyhow!("decode @pos {}: {e:?}", sq.pos))?;
                sq.state = single_output(outs.remove(0))?;
                read_state(&sq.state, &mut sq.state_host)?;
                sq.pos += 1;
                stepped = true;
            }
            if !stepped {
                break;
            }
        }
        Ok(seqs.into_iter().map(|s| s.out).collect())
    }

    /// Prefill every prompt of `reqs`, returning each sequence's device-side
    /// state buffer + host mirror. When the artifact ships a batched prefill
    /// entry point ([`LoadedModel::prefill_batch`]) and there is more than
    /// one prompt, all prompts go up as ONE padded `[K, max_seq]` execution;
    /// any failure on that path (stub runtime, shape drift in the export,
    /// mid-batch execution error) falls back to the per-prompt path, which
    /// stays the correctness reference.
    fn prefill_many(
        &self,
        reqs: &[(&[u32], SamplingParams)],
        scratch: &mut GenScratch,
    ) -> Result<Vec<(xla::PjRtBuffer, Vec<f32>)>> {
        if reqs.len() > 1 {
            if let Some(exec) = &self.model.prefill_batch {
                if let Ok(states) = self.prefill_batched(exec, reqs, scratch) {
                    return Ok(states);
                }
            }
        }
        let mut out = Vec::with_capacity(reqs.len());
        for (prompt, _) in reqs {
            let state = self.prefill(prompt, scratch)?;
            let mut state_host = vec![0f32; self.model.art.state_size];
            read_state(&state, &mut state_host)?;
            out.push((state, state_host));
        }
        Ok(out)
    }

    /// One padded batched prefill execution over K prompts: tokens
    /// `[K, max_seq]` + lens `[K]` -> flat `[K * state_size]` states, then
    /// each sequence's state slice is re-uploaded as its own device buffer
    /// so the (batch-1) decode loop sees exactly the buffer a solo prefill
    /// would have produced.
    fn prefill_batched(
        &self,
        exec: &xla::PjRtLoadedExecutable,
        reqs: &[(&[u32], SamplingParams)],
        scratch: &mut GenScratch,
    ) -> Result<Vec<(xla::PjRtBuffer, Vec<f32>)>> {
        let m = self.model;
        let s_max = m.art.max_seq;
        let k = reqs.len();
        scratch.padded.clear();
        scratch.padded.resize(k * s_max, 0);
        let mut lens = Vec::with_capacity(k);
        for (i, (prompt, _)) in reqs.iter().enumerate() {
            if prompt.is_empty() {
                bail!("empty prompt");
            }
            if prompt.len() >= s_max {
                bail!("prompt len {} >= max_seq {s_max}", prompt.len());
            }
            for (j, &t) in prompt.iter().enumerate() {
                scratch.padded[i * s_max + j] = t as i32;
            }
            lens.push(prompt.len() as i32);
        }
        let tok_buf = m.i32_buffer(&scratch.padded, &[k, s_max])?;
        let len_buf = m.i32_buffer(&lens, &[k])?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf, &len_buf];
        args.extend(m.params.iter());
        let mut outs = exec.execute_b(&args).map_err(|e| anyhow!("prefill_batch: {e:?}"))?;
        let buf = single_output(outs.remove(0))?;
        let lit = buf.to_literal_sync().map_err(|e| anyhow!("read batched states: {e:?}"))?;
        let state_size = m.art.state_size;
        if lit.element_count() != k * state_size {
            bail!("batched state size {} != {k}x{state_size}", lit.element_count());
        }
        let mut flat = vec![0f32; k * state_size];
        lit.copy_raw_to(&mut flat).map_err(|e| anyhow!("copy batched states: {e:?}"))?;
        let mut out = Vec::with_capacity(k);
        for (i, chunk) in flat.chunks_exact(state_size).enumerate() {
            let host = chunk.to_vec();
            let state = m
                .rt
                .client
                .buffer_from_host_buffer(&host, &[state_size], None)
                .map_err(|e| anyhow!("upload state {i}: {e:?}"))?;
            out.push((state, host));
        }
        Ok(out)
    }

    /// Upload the padded prompt and run the prefill executable; returns the
    /// device-side state buffer.
    fn prefill(&self, prompt: &[u32], scratch: &mut GenScratch) -> Result<xla::PjRtBuffer> {
        let m = self.model;
        let s_max = m.art.max_seq;
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if prompt.len() >= s_max {
            bail!("prompt len {} >= max_seq {}", prompt.len(), s_max);
        }
        scratch.padded.clear();
        scratch.padded.resize(s_max, 0);
        for (i, &t) in prompt.iter().enumerate() {
            scratch.padded[i] = t as i32;
        }
        let tok_buf = m.i32_buffer(&scratch.padded, &[1, s_max])?;
        let len_buf = m.i32_buffer(&[prompt.len() as i32], &[1])?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf, &len_buf];
        args.extend(m.params.iter());
        // state = concat(kv.ravel(), logits). The state buffer STAYS on the
        // device and is fed back each step (execute_b); the host only reads
        // it to extract the logits tail (TFRT CPU lacks CopyRawToHost, so
        // the read is a full-state literal sync — download only, no upload;
        // see EXPERIMENTS.md §Perf).
        let mut outs = m.prefill.execute_b(&args).map_err(|e| anyhow!("prefill: {e:?}"))?;
        single_output(outs.remove(0))
    }

    /// Teacher-forcing log-probabilities of `tokens[1..]` given `tokens[..n-1]`
    /// (natural log) — perplexity of arbitrary text under this model.
    pub fn score_logps(&self, tokens: &[u32]) -> Result<Vec<f64>> {
        let m = self.model;
        let s_max = m.art.max_seq;
        if tokens.len() < 2 || tokens.len() > s_max {
            bail!("score needs 2..={s_max} tokens");
        }
        let mut padded = vec![0i32; s_max];
        for (i, &t) in tokens.iter().enumerate() {
            padded[i] = t as i32;
        }
        let tok_buf = m.i32_buffer(&padded, &[1, s_max])?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf];
        args.extend(m.params.iter());
        let mut outs = m.score.execute_b(&args).map_err(|e| anyhow!("score: {e:?}"))?;
        let buf = single_output(outs.remove(0))?;
        let lit = buf.to_literal_sync().map_err(|e| anyhow!("{e:?}"))?;
        let flat: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("{e:?}"))?;
        let v = m.art.vocab;
        if flat.len() != s_max * v {
            bail!("score output {} != {}x{}", flat.len(), s_max, v);
        }
        let mut logps = Vec::with_capacity(tokens.len() - 1);
        for i in 0..tokens.len() - 1 {
            let row = &flat[i * v..(i + 1) * v];
            logps.push(log_softmax_pick(row, tokens[i + 1] as usize));
        }
        Ok(logps)
    }
}

/// Every export returns a single flat array (return_tuple=False), so the
/// replica output must be exactly one plain buffer.
fn single_output(mut replica: Vec<xla::PjRtBuffer>) -> Result<xla::PjRtBuffer> {
    match replica.len() {
        1 => Ok(replica.remove(0)),
        n => bail!("expected 1 output buffer, got {n}"),
    }
}

/// Full host read of a device-side state buffer (dst must be exactly the
/// state size; Literal::copy_raw_to always copies the whole literal).
fn read_state(state: &xla::PjRtBuffer, dst: &mut [f32]) -> Result<()> {
    let lit = state.to_literal_sync().map_err(|e| anyhow!("read state: {e:?}"))?;
    if lit.element_count() != dst.len() {
        bail!("state size {} != {}", lit.element_count(), dst.len());
    }
    lit.copy_raw_to(dst).map_err(|e| anyhow!("copy state: {e:?}"))
}

/// Sample from logits (f32, unnormalized). Returns (token, ln p(token)).
///
/// Greedy path: a single fused sweep computes the running max (with
/// on-the-fly partition rescaling), the T=1 log-partition for the reported
/// logp, and the argmax together. Temperature path: one cheap max sweep,
/// then one fused sweep filling `probs` — a scratch buffer reused across
/// decode steps — together with both partition sums.
fn sample(
    logits: &[f32],
    sp: &SamplingParams,
    rng: &mut Rng,
    probs: &mut Vec<f64>,
) -> Result<(u32, f64)> {
    if logits.is_empty() {
        bail!("empty logits");
    }
    if sp.temperature <= 0.0 {
        let mut mx = f64::NEG_INFINITY;
        let mut z1 = 0.0f64;
        let mut best = 0usize;
        let mut best_l = f32::NEG_INFINITY;
        for (i, &l) in logits.iter().enumerate() {
            let lf = l as f64;
            if lf > mx {
                // rescale the partial partition sum to the new reference max
                z1 = z1 * (mx - lf).exp() + 1.0;
                mx = lf;
            } else {
                z1 += (lf - mx).exp();
            }
            if l >= best_l {
                best_l = l;
                best = i;
            }
        }
        return Ok((best as u32, (logits[best] as f64) - mx - z1.ln()));
    }
    let t = sp.temperature;
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    probs.clear();
    probs.reserve(logits.len());
    // log-softmax denominators at T=1 (for reported logp) and at T (sampling)
    let mut z1 = 0.0f64;
    let mut zt = 0.0f64;
    for &l in logits {
        let d = (l as f64) - mx;
        z1 += d.exp();
        let p = (d / t).exp();
        probs.push(p);
        zt += p;
    }
    let mut u = rng.f64() * zt;
    let mut idx = logits.len() - 1;
    for (i, p) in probs.iter().enumerate() {
        if u < *p {
            idx = i;
            break;
        }
        u -= p;
    }
    Ok((idx as u32, (logits[idx] as f64) - mx - z1.ln()))
}

fn log_softmax_pick(row: &[f32], idx: usize) -> f64 {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let mut z = 0.0f64;
    for &l in row {
        z += ((l as f64) - mx).exp();
    }
    (row[idx.min(row.len() - 1)] as f64) - mx - z.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_sample_argmax() {
        let logits = [0.1f32, 2.0, -1.0];
        let mut rng = Rng::new(1);
        let mut probs = Vec::new();
        let (t, lp) = sample(&logits, &SamplingParams::default(), &mut rng, &mut probs).unwrap();
        assert_eq!(t, 1);
        assert!(lp < 0.0);
    }

    #[test]
    fn fused_greedy_matches_two_pass_log_softmax() {
        // the running-rescale partition must agree with the exact-max form
        let logits = [0.3f32, -1.2, 2.0, 0.7, 1.9, -4.0];
        let mut rng = Rng::new(1);
        let mut probs = Vec::new();
        let (t, lp) = sample(&logits, &SamplingParams::default(), &mut rng, &mut probs).unwrap();
        assert_eq!(t, 2);
        assert!((lp - log_softmax_pick(&logits, 2)).abs() < 1e-9, "{lp}");
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let logits = [1.0f32, 1.0, 1.0];
        let sp = SamplingParams { temperature: 1.0, seed: 3, ..Default::default() };
        let mut rng = Rng::new(3);
        let mut probs = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            let (t, _) = sample(&logits, &sp, &mut rng, &mut probs).unwrap();
            seen.insert(t);
        }
        assert_eq!(seen.len(), 3);
    }

    #[test]
    fn logp_is_normalized() {
        let logits = [0.0f32, 0.0, 0.0, 0.0];
        let mut rng = Rng::new(1);
        let mut probs = Vec::new();
        let (_, lp) = sample(&logits, &SamplingParams::default(), &mut rng, &mut probs).unwrap();
        assert!((lp - (0.25f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn scratch_probs_reused_across_calls() {
        let logits = [0.5f32; 8];
        let sp = SamplingParams { temperature: 0.7, seed: 1, ..Default::default() };
        let mut rng = Rng::new(1);
        let mut probs = Vec::new();
        sample(&logits, &sp, &mut rng, &mut probs).unwrap();
        let cap = probs.capacity();
        for _ in 0..10 {
            sample(&logits, &sp, &mut rng, &mut probs).unwrap();
        }
        assert_eq!(probs.capacity(), cap, "probs buffer must not reallocate");
    }

    #[test]
    fn log_softmax_pick_uniform() {
        let row = [1.0f32; 10];
        assert!((log_softmax_pick(&row, 3) - (0.1f64).ln()).abs() < 1e-6);
    }
}
