//! PJRT runtime: loads AOT artifacts and runs picoLM generation.
//!
//! Python is never on the request path — `make artifacts` lowered each model
//! variant to HLO text + a raw weight blob; this module compiles the HLO on
//! the PJRT CPU client once and keeps weights/KV caches device-side
//! (`PjRtBuffer`) so the decode loop only moves one token + one logits
//! vector per step.

pub mod generation;
pub mod loader;

pub use generation::{GenOutput, GenScratch, Generator, SamplingParams};
pub use loader::{LoadedModel, ModelArtifact, RuntimeHandle};
