//! Artifact loading: meta.json -> shapes, weights.bin -> device buffers,
//! *.hlo.txt -> compiled PJRT executables.

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Parsed meta.json for one model variant.
#[derive(Clone, Debug)]
pub struct ModelArtifact {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub n_params: usize,
    pub kv_shape: Vec<usize>,
    /// flat f32 state length = kv elements + vocab (logits tail)
    pub state_size: usize,
    /// (name, shape, byte offset, byte length) in weights.bin, PARAM_ORDER.
    pub weights: Vec<(String, Vec<usize>, usize, usize)>,
}

impl ModelArtifact {
    /// Offset of the logits within the flat state vector.
    pub fn logits_offset(&self) -> usize {
        self.state_size - self.vocab
    }
}

impl ModelArtifact {
    pub fn from_meta(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let us = |k: &str| -> Result<usize> {
            j.req(k).map_err(|e| anyhow!(e))?.as_usize().ok_or_else(|| anyhow!("bad {k}"))
        };
        let mut weights = Vec::new();
        for w in j.req("weights").map_err(|e| anyhow!(e))?.as_arr().unwrap_or(&[]) {
            let name = w.req("name").map_err(|e| anyhow!(e))?.as_str().unwrap().to_string();
            let shape: Vec<usize> = w
                .req("shape")
                .map_err(|e| anyhow!(e))?
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_usize().unwrap())
                .collect();
            let offset = w.req("offset").map_err(|e| anyhow!(e))?.as_usize().unwrap();
            let nbytes = w.req("nbytes").map_err(|e| anyhow!(e))?.as_usize().unwrap();
            weights.push((name, shape, offset, nbytes));
        }
        let kv_shape = j
            .req("kv_shape")
            .map_err(|e| anyhow!(e))?
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        Ok(ModelArtifact {
            name: j.req("name").map_err(|e| anyhow!(e))?.as_str().unwrap().to_string(),
            d_model: us("d_model")?,
            n_layers: us("n_layers")?,
            n_heads: us("n_heads")?,
            head_dim: us("head_dim")?,
            vocab: us("vocab")?,
            max_seq: us("max_seq")?,
            n_params: us("n_params")?,
            kv_shape,
            state_size: us("state_size")?,
            weights,
        })
    }
}

/// Shared PJRT client handle. One per process; models share it.
pub struct RuntimeHandle {
    pub client: xla::PjRtClient,
}

impl RuntimeHandle {
    pub fn cpu() -> Result<Arc<Self>> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Arc::new(RuntimeHandle { client }))
    }

    fn compile(&self, hlo_path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(|e| anyhow!("compile {}: {e:?}", hlo_path.display()))
    }
}

/// One model variant, compiled and resident: executables + device-side
/// weight buffers (uploaded once at load).
pub struct LoadedModel {
    pub art: ModelArtifact,
    pub prefill: xla::PjRtLoadedExecutable,
    /// optional batched prefill entry point (`prefill_batch.hlo.txt`):
    /// tokens [K, max_seq] + lens [K] -> flat [K * state_size] states.
    /// Absent from older artifact exports; `Generator::generate_many`
    /// falls back to per-prompt prefill when it's missing (or fails).
    pub prefill_batch: Option<xla::PjRtLoadedExecutable>,
    pub decode: xla::PjRtLoadedExecutable,
    pub score: xla::PjRtLoadedExecutable,
    pub params: Vec<xla::PjRtBuffer>,
    pub rt: Arc<RuntimeHandle>,
}

impl LoadedModel {
    /// Load `<dir>/{meta.json,weights.bin,prefill.hlo.txt,decode.hlo.txt,
    /// score.hlo.txt}` (+ optional `prefill_batch.hlo.txt`) and upload
    /// weights to the device.
    pub fn load(rt: Arc<RuntimeHandle>, dir: &Path) -> Result<Self> {
        let art = ModelArtifact::from_meta(&dir.join("meta.json"))?;
        let blob = std::fs::read(dir.join("weights.bin"))
            .with_context(|| format!("read {}/weights.bin", dir.display()))?;
        let mut params = Vec::with_capacity(art.weights.len());
        for (name, shape, offset, nbytes) in &art.weights {
            let end = offset + nbytes;
            if end > blob.len() {
                bail!("weights.bin too short for {name}");
            }
            let bytes = &blob[*offset..end];
            let floats: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let expect: usize = shape.iter().product();
            if floats.len() != expect {
                bail!("{name}: {} floats, shape wants {expect}", floats.len());
            }
            let buf = rt
                .client
                .buffer_from_host_buffer(&floats, shape, None)
                .map_err(|e| anyhow!("upload {name}: {e:?}"))?;
            params.push(buf);
        }
        let prefill = rt.compile(&dir.join("prefill.hlo.txt"))?;
        let pb_path = dir.join("prefill_batch.hlo.txt");
        // a broken batched export should not take the model down — the
        // runtime still has the per-prompt path
        let prefill_batch =
            if pb_path.exists() { rt.compile(&pb_path).ok() } else { None };
        let decode = rt.compile(&dir.join("decode.hlo.txt"))?;
        let score = rt.compile(&dir.join("score.hlo.txt"))?;
        Ok(LoadedModel { art, prefill, prefill_batch, decode, score, params, rt })
    }

    /// Upload an i32 tensor.
    pub fn i32_buffer(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.rt
            .client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32: {e:?}"))
    }
}
