//! Ensemble learning over SLM outputs (paper §IV-C).
//!
//! Multiple edge SLMs expand the same sketch; the system returns the
//! candidate with the highest *confidence* (Eq. 3):
//!
//!   con(ŷ) = α1·2^( (1/N) Σ log2 p(w_i) )            (geometric-mean prob,
//!                                                      = 1/perplexity)
//!          + α2·Norm(|ŷ|)                             (length score)
//!          + (1 − α1 − α2)·Rouge-1(r, ŷ)              (sketch faithfulness)
//!
//! Perplexity alone is "overly dependent on the model itself" (the paper's
//! Llama-vs-Qwen observation), hence the text-score terms.

use std::collections::HashMap;
use std::sync::Arc;

#[derive(Clone, Copy, Debug)]
pub struct ConfidenceWeights {
    pub alpha1: f64,
    pub alpha2: f64,
}

impl Default for ConfidenceWeights {
    fn default() -> Self {
        // paper does not publish α; chosen so all three terms matter and the
        // sensitivity bench (fig9/ablations) can sweep them.
        ConfidenceWeights { alpha1: 0.4, alpha2: 0.2 }
    }
}

/// One ensemble candidate: an SLM's expansion of a sketch. The model name
/// is the engine's interned `Arc<str>`, so replica fan-out never copies it.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub model: Arc<str>,
    pub tokens: Vec<u32>,
    /// per-generated-token natural-log probabilities under the generator
    pub logps: Vec<f64>,
}

/// Normalized length score: ramps 0→1 as the answer approaches the expected
/// length, flat beyond (more detail is better, but unboundedly long answers
/// should not dominate).
pub fn norm_len(answer_len: usize, expected_len: usize) -> f64 {
    if expected_len == 0 {
        return 0.0;
    }
    (answer_len as f64 / expected_len as f64).min(1.0)
}

/// Sketch-recall variant of Rouge-1: fraction of sketch unigrams covered by
/// the answer. Recall (not F1) so added detail — the whole point of the
/// expansion — is never penalized, while dropped sketch points are.
pub fn sketch_recall(sketch: &[u32], answer: &[u32]) -> f64 {
    if sketch.is_empty() {
        return 0.0;
    }
    let mut have: HashMap<u32, usize> = HashMap::new();
    for &t in answer {
        *have.entry(t).or_insert(0) += 1;
    }
    let mut hit = 0usize;
    for &t in sketch {
        if let Some(c) = have.get_mut(&t) {
            if *c > 0 {
                *c -= 1;
                hit += 1;
            }
        }
    }
    hit as f64 / sketch.len() as f64
}

/// Eq. 3 confidence of one candidate against the sketch `r`.
pub fn confidence(
    cand: &Candidate,
    sketch: &[u32],
    expected_len: usize,
    w: ConfidenceWeights,
) -> f64 {
    let geo_prob = if cand.logps.is_empty() {
        0.0
    } else {
        // 2^(mean log2 p) == e^(mean ln p)
        (cand.logps.iter().sum::<f64>() / cand.logps.len() as f64).exp()
    };
    let len_score = norm_len(cand.tokens.len(), expected_len);
    let rouge = sketch_recall(sketch, &cand.tokens);
    w.alpha1 * geo_prob + w.alpha2 * len_score + (1.0 - w.alpha1 - w.alpha2) * rouge
}

/// Pick the highest-confidence candidate; returns (index, confidence).
pub fn select(
    candidates: &[Candidate],
    sketch: &[u32],
    expected_len: usize,
    w: ConfidenceWeights,
) -> Option<(usize, f64)> {
    candidates
        .iter()
        .enumerate()
        .map(|(i, c)| (i, confidence(c, sketch, expected_len, w)))
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(model: &str, tokens: Vec<u32>, logp: f64) -> Candidate {
        let n = tokens.len();
        Candidate { model: model.into(), tokens, logps: vec![logp; n] }
    }

    #[test]
    fn faithful_beats_unfaithful() {
        let sketch = vec![1, 2, 3, 4];
        let good = cand("a", vec![9, 1, 2, 3, 4, 9], -0.5);
        let bad = cand("b", vec![7, 8, 9, 10, 11, 12], -0.5);
        let (i, _) = select(&[bad, good], &sketch, 6, ConfidenceWeights::default()).unwrap();
        assert_eq!(i, 1);
    }

    #[test]
    fn confident_model_wins_when_text_equal() {
        let sketch = vec![1, 2];
        let sure = cand("a", vec![1, 2, 3], -0.1);
        let unsure = cand("b", vec![1, 2, 3], -3.0);
        let (i, _) = select(&[unsure, sure], &sketch, 3, ConfidenceWeights::default()).unwrap();
        assert_eq!(i, 1);
    }

    #[test]
    fn longer_detail_preferred_up_to_expected() {
        let w = ConfidenceWeights::default();
        let sketch = vec![1, 2, 3];
        let short = cand("a", vec![1, 2, 3], -1.0);
        let detailed = cand("b", vec![1, 2, 3, 10, 11, 12], -1.0);
        let cs = confidence(&short, &sketch, 6, w);
        let cd = confidence(&detailed, &sketch, 6, w);
        assert!(cd > cs, "{cd} <= {cs}");
    }

    #[test]
    fn confidence_bounded() {
        let w = ConfidenceWeights::default();
        let c = cand("a", vec![1, 2, 3], 0.0); // p = 1
        let v = confidence(&c, &[1, 2, 3], 3, w);
        assert!(v <= 1.0 + 1e-9 && v >= 0.0);
    }

    #[test]
    fn empty_candidates_none() {
        assert!(select(&[], &[1], 1, ConfidenceWeights::default()).is_none());
    }

    #[test]
    fn perplexity_dependence_mitigated() {
        // the paper's motivation: a model with systematically worse ppl can
        // still win on text quality. α weights keep rouge dominant.
        let w = ConfidenceWeights::default();
        let sketch = vec![1, 2, 3, 4, 5];
        let high_ppl_good = cand("llama", vec![1, 2, 3, 4, 5, 9], -2.0);
        let low_ppl_bad = cand("qwen", vec![9, 9, 8, 8, 7, 7], -0.3);
        let (i, _) = select(&[low_ppl_bad, high_ppl_good], &sketch, 6, w).unwrap();
        assert_eq!(i, 1, "text terms must outweigh raw perplexity");
    }
}
