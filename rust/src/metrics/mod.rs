//! Serving metrics: request traces + throughput/latency/cost aggregation.
//!
//! The paper's efficiency metrics (§V-A): throughput = queries/min, average
//! end-to-end latency (cloud + waiting + transfer + edge). Cost metrics
//! (server/edge token counts) feed the lexicographic SLO optimizer.

use crate::simclock::SimTime;
use crate::util::stats;

/// How a request was served.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// full answer from the cloud LLM
    CloudFull,
    /// progressive: cloud sketch + edge expansion
    Progressive,
    /// full answer from an edge SLM (edge-only / routed-easy)
    EdgeFull,
}

/// Per-request lifecycle record.
#[derive(Clone, Debug)]
pub struct RequestTrace {
    pub rid: usize,
    pub question_id: usize,
    pub category: String,
    pub mode: Mode,
    pub sketch_level: usize,
    pub predicted_len: usize,
    /// tokens generated on the cloud (server cost)
    pub cloud_tokens: usize,
    /// tokens generated on edges, summed over ensemble members (edge cost)
    pub edge_tokens: usize,
    /// final answer token ids
    pub answer: Vec<u32>,
    pub arrival: SimTime,
    pub cloud_start: SimTime,
    pub cloud_done: SimTime,
    pub edge_start: SimTime,
    /// when the cloud sketch became client-visible — the streamed
    /// `SketchReady` instant (progressive requests only)
    pub sketch_ready: Option<SimTime>,
    /// when the first edge expansion chunk was delivered — the streamed
    /// first `ExpansionChunk` instant (progressive requests only)
    pub first_expansion: Option<SimTime>,
    pub done: SimTime,
    /// ensemble winner (empty when not progressive)
    pub winner_model: String,
    pub confidence: f64,
    /// edge expansion parallelism degree chosen by the execution optimizer
    pub parallelism: usize,
    /// failure-triggered re-dispatches this request survived (edge crashes
    /// killing its in-flight or queued work — dynamics subsystem)
    pub failovers: usize,
    /// expansion sentence-slots re-queued by those failovers
    pub retried_slots: usize,
    /// sentence-slots whose completed expansion was salvaged across an
    /// edge crash instead of re-queued (partial-result salvage)
    pub salvaged_slots: usize,
    /// "queue full: retry shortly" deferrals this request ate before its
    /// expansion job entered the dispatch queue (queue-pressure signal:
    /// saturation degrades answers, it never silently drops a request)
    pub requeue_retries: usize,
    /// hedged-dispatch watchdog firings this request survived (tail
    /// tolerance: a straggling pull was speculatively duplicated)
    pub hedges: usize,
    /// expansion sentence-slots speculatively re-dispatched by those hedges
    pub hedged_slots: usize,
}

impl RequestTrace {
    pub fn latency(&self) -> f64 {
        self.done - self.arrival
    }

    /// Time-to-first-sketch: arrival until the streamed sketch (the early
    /// partial response). None for non-progressive requests.
    pub fn ttfs(&self) -> Option<f64> {
        self.sketch_ready.map(|t| t - self.arrival)
    }

    /// Time-to-first-expansion: arrival until the first streamed expansion
    /// chunk. None when no expansion was delivered.
    pub fn ttfe(&self) -> Option<f64> {
        self.first_expansion.map(|t| t - self.arrival)
    }
}

/// Aggregated results for one serving run.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub throughput_qpm: f64,
    pub avg_latency_s: f64,
    pub p50_latency_s: f64,
    pub p95_latency_s: f64,
    pub p99_latency_s: f64,
    /// extreme-tail latency — the metric hedged dispatch exists to protect
    /// (Edge-First: tail percentiles, not means, decide edge serving)
    pub p999_latency_s: f64,
    /// time-to-first-sketch percentiles over progressive requests — the
    /// paper's "early response" metric, fed from the streaming event
    /// timestamps (0.0 when nothing went progressive)
    pub p50_ttfs_s: f64,
    pub p99_ttfs_s: f64,
    /// time-to-first-expansion percentiles over requests that received at
    /// least one streamed expansion chunk (0.0 when none did)
    pub p50_ttfe_s: f64,
    pub p99_ttfe_s: f64,
    pub p999_ttfe_s: f64,
    pub server_tokens: usize,
    pub edge_tokens: usize,
    pub n_requests: usize,
    pub n_progressive: usize,
    pub makespan_s: f64,
    /// total failure-triggered re-dispatches across the run (0 in a static
    /// world — the dynamics subsystem's failover counter)
    pub failovers: usize,
    /// total expansion slots re-queued by those failovers
    pub retried_slots: usize,
    /// total expansion slots salvaged across edge crashes (work the
    /// failover path did NOT have to redo)
    pub salvaged_slots: usize,
    /// degraded-mode latency: percentiles over only the requests that
    /// survived at least one failover (0.0 when none did)
    pub p50_degraded_latency_s: f64,
    pub p99_degraded_latency_s: f64,
    /// total "queue full" re-queue deferrals across the run
    pub requeue_retries: usize,
    /// total hedged-dispatch watchdog firings across the run (tail
    /// tolerance; 0 with hedging off)
    pub hedges: usize,
    /// total expansion slots speculatively re-dispatched by those hedges
    pub hedged_slots: usize,
    /// per-phase latency breakdown (queueing vs cloud vs transfer vs edge
    /// vs tail waits) from telemetry request spans — `None` when telemetry
    /// was off or the caller never attached one; [`aggregate`] does not
    /// fill it because traces alone carry no span data
    pub phases: Option<crate::telemetry::PhaseBreakdown>,
}

pub fn aggregate(traces: &[RequestTrace]) -> RunMetrics {
    let refs: Vec<&RequestTrace> = traces.iter().collect();
    aggregate_refs(&refs)
}

fn aggregate_refs(traces: &[&RequestTrace]) -> RunMetrics {
    if traces.is_empty() {
        return RunMetrics::default();
    }
    let lat: Vec<f64> = traces.iter().map(|t| t.latency()).collect();
    let ttfs: Vec<f64> = traces.iter().filter_map(|t| t.ttfs()).collect();
    let ttfe: Vec<f64> = traces.iter().filter_map(|t| t.ttfe()).collect();
    let degraded: Vec<f64> =
        traces.iter().filter(|t| t.failovers > 0).map(|t| t.latency()).collect();
    let first_arrival = traces.iter().map(|t| t.arrival).fold(f64::INFINITY, f64::min);
    let last_done = traces.iter().map(|t| t.done).fold(0.0, f64::max);
    let makespan = (last_done - first_arrival).max(1e-9);
    RunMetrics {
        throughput_qpm: traces.len() as f64 / makespan * 60.0,
        avg_latency_s: stats::mean(&lat),
        p50_latency_s: stats::percentile(&lat, 50.0),
        p95_latency_s: stats::percentile(&lat, 95.0),
        p99_latency_s: stats::percentile(&lat, 99.0),
        p999_latency_s: stats::percentile(&lat, 99.9),
        p50_ttfs_s: stats::percentile(&ttfs, 50.0),
        p99_ttfs_s: stats::percentile(&ttfs, 99.0),
        p50_ttfe_s: stats::percentile(&ttfe, 50.0),
        p99_ttfe_s: stats::percentile(&ttfe, 99.0),
        p999_ttfe_s: stats::percentile(&ttfe, 99.9),
        server_tokens: traces.iter().map(|t| t.cloud_tokens).sum(),
        edge_tokens: traces.iter().map(|t| t.edge_tokens).sum(),
        n_requests: traces.len(),
        n_progressive: traces.iter().filter(|t| t.mode == Mode::Progressive).count(),
        makespan_s: makespan,
        failovers: traces.iter().map(|t| t.failovers).sum(),
        retried_slots: traces.iter().map(|t| t.retried_slots).sum(),
        salvaged_slots: traces.iter().map(|t| t.salvaged_slots).sum(),
        p50_degraded_latency_s: stats::percentile(&degraded, 50.0),
        p99_degraded_latency_s: stats::percentile(&degraded, 99.0),
        requeue_retries: traces.iter().map(|t| t.requeue_retries).sum(),
        hedges: traces.iter().map(|t| t.hedges).sum(),
        hedged_slots: traces.iter().map(|t| t.hedged_slots).sum(),
        phases: None,
    }
}

/// Aggregation over a fleet's disjoint per-shard trace streams: one
/// `RunMetrics` per shard plus the fleet-wide view.
#[derive(Clone, Debug, Default)]
pub struct FleetMetrics {
    /// the whole fleet, every request counted exactly once
    pub fleet: RunMetrics,
    /// `per_shard[i]` aggregates shard i's own stream only
    pub per_shard: Vec<RunMetrics>,
}

/// Merge N disjoint per-shard trace streams into per-shard and fleet-wide
/// metrics without double-counting. Shards share one simulated time axis,
/// so fleet percentiles/totals are computed over the **union** of the
/// streams and fleet throughput uses the **global** makespan (max done −
/// min arrival across every shard). Summing per-shard `throughput_qpm`
/// instead would count overlapping wall-clock N times — the bug this merge
/// path exists to prevent.
pub fn aggregate_shards(shards: &[Vec<RequestTrace>]) -> FleetMetrics {
    let per_shard: Vec<RunMetrics> = shards.iter().map(|s| aggregate(s)).collect();
    let flat: Vec<&RequestTrace> = shards.iter().flatten().collect();
    FleetMetrics { fleet: aggregate_refs(&flat), per_shard }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(arrival: f64, done: f64) -> RequestTrace {
        RequestTrace {
            rid: 0,
            question_id: 0,
            category: "generic".into(),
            mode: Mode::CloudFull,
            sketch_level: 0,
            predicted_len: 0,
            cloud_tokens: 10,
            edge_tokens: 5,
            answer: vec![],
            arrival,
            cloud_start: arrival,
            cloud_done: done,
            edge_start: done,
            sketch_ready: None,
            first_expansion: None,
            done,
            winner_model: String::new(),
            confidence: 0.0,
            parallelism: 0,
            failovers: 0,
            retried_slots: 0,
            salvaged_slots: 0,
            requeue_retries: 0,
            hedges: 0,
            hedged_slots: 0,
        }
    }

    #[test]
    fn throughput_and_latency() {
        let traces: Vec<_> = (0..60).map(|i| trace(i as f64, i as f64 + 2.0)).collect();
        let m = aggregate(&traces);
        // 60 requests over 61 s makespan -> ~59 qpm
        assert!((m.throughput_qpm - 60.0 / 61.0 * 60.0).abs() < 1e-6);
        assert!((m.avg_latency_s - 2.0).abs() < 1e-9);
        assert_eq!(m.server_tokens, 600);
    }

    #[test]
    fn empty_is_zero() {
        let m = aggregate(&[]);
        assert_eq!(m.n_requests, 0);
        assert_eq!(m.throughput_qpm, 0.0);
    }

    #[test]
    fn ttfs_ttfe_percentiles_from_streaming_timestamps() {
        let traces: Vec<_> = (0..40)
            .map(|i| {
                let mut t = trace(i as f64, i as f64 + 10.0);
                t.mode = Mode::Progressive;
                // sketch ready 1..40 s after arrival, first expansion 2x that
                t.sketch_ready = Some(t.arrival + (i + 1) as f64);
                t.first_expansion = Some(t.arrival + 2.0 * (i + 1) as f64);
                t
            })
            .collect();
        let m = aggregate(&traces);
        assert!(m.p50_ttfs_s > 0.0 && m.p50_ttfs_s <= m.p99_ttfs_s);
        assert!(m.p50_ttfe_s > m.p50_ttfs_s, "{} vs {}", m.p50_ttfe_s, m.p50_ttfs_s);
        assert!(m.p99_ttfs_s <= 40.0 + 1e-9);
    }

    #[test]
    fn failover_totals_and_degraded_percentiles() {
        let mut traces: Vec<_> = (0..10).map(|i| trace(i as f64, i as f64 + 2.0)).collect();
        traces[3].failovers = 2;
        traces[3].retried_slots = 5;
        traces[3].done = traces[3].arrival + 9.0;
        traces[7].failovers = 1;
        traces[7].done = traces[7].arrival + 7.0;
        let m = aggregate(&traces);
        assert_eq!(m.failovers, 3);
        assert_eq!(m.retried_slots, 5);
        // degraded percentiles see only the two failover-survivor latencies
        assert!(m.p50_degraded_latency_s >= 7.0);
        assert!(m.p99_degraded_latency_s >= m.p50_degraded_latency_s);
        assert!(m.p99_latency_s >= m.p95_latency_s);
        // static world: no failovers, degraded percentiles stay 0
        let m0 = aggregate(&traces[..3]);
        assert_eq!(m0.failovers, 0);
        assert_eq!(m0.p99_degraded_latency_s, 0.0);
    }

    #[test]
    fn tail_counters_aggregate_and_p999_orders() {
        let mut traces: Vec<_> = (0..8).map(|i| trace(i as f64, i as f64 + 2.0)).collect();
        traces[1].hedges = 1;
        traces[1].hedged_slots = 3;
        traces[4].requeue_retries = 2;
        traces[6].done = traces[6].arrival + 30.0; // one extreme straggler
        let m = aggregate(&traces);
        assert_eq!(m.hedges, 1);
        assert_eq!(m.hedged_slots, 3);
        assert_eq!(m.requeue_retries, 2);
        assert!(m.p999_latency_s >= m.p99_latency_s);
        assert!(m.p999_latency_s <= 30.0 + 1e-9);
        // static world defaults stay zero
        let m0 = aggregate(&traces[2..4]);
        assert_eq!(m0.hedges, 0);
        assert_eq!(m0.requeue_retries, 0);
    }

    #[test]
    fn fleet_merge_matches_flat_aggregate() {
        // fleet-wide view == aggregating the flattened union: every
        // request counted once, percentiles over the union, throughput on
        // the global makespan
        let all: Vec<_> = (0..24)
            .map(|i| {
                let mut t = trace(i as f64, i as f64 + 2.0 + (i % 5) as f64);
                t.failovers = i % 3;
                t.retried_slots = i % 2;
                t.salvaged_slots = i % 4;
                t
            })
            .collect();
        let shards: Vec<Vec<RequestTrace>> = vec![
            all.iter().step_by(2).cloned().collect(),
            all.iter().skip(1).step_by(2).cloned().collect(),
        ];
        let fm = aggregate_shards(&shards);
        let flat = aggregate(&all);
        assert_eq!(fm.fleet.n_requests, flat.n_requests);
        assert_eq!(fm.fleet.failovers, flat.failovers);
        assert_eq!(fm.fleet.retried_slots, flat.retried_slots);
        assert_eq!(fm.fleet.salvaged_slots, flat.salvaged_slots);
        assert!((fm.fleet.throughput_qpm - flat.throughput_qpm).abs() < 1e-9);
        assert!((fm.fleet.p99_latency_s - flat.p99_latency_s).abs() < 1e-9);
        assert!((fm.fleet.p50_ttfs_s - flat.p50_ttfs_s).abs() < 1e-9);
        // per-shard rows partition the fleet totals exactly
        assert_eq!(fm.per_shard.len(), 2);
        assert_eq!(
            fm.per_shard.iter().map(|m| m.n_requests).sum::<usize>(),
            fm.fleet.n_requests
        );
        assert_eq!(
            fm.per_shard.iter().map(|m| m.failovers).sum::<usize>(),
            fm.fleet.failovers
        );
    }

    #[test]
    fn fleet_merge_throughput_is_not_a_shard_sum() {
        // two shards serving concurrently over the SAME wall-clock window:
        // fleet throughput must reflect the union over the global makespan
        // (~2x one shard), not the sum of per-shard rates computed on
        // overlapping windows (which here would equal it) — and crucially
        // not N x when one shard is idle most of the window
        let busy: Vec<_> = (0..30).map(|i| trace(i as f64 * 2.0, i as f64 * 2.0 + 1.0)).collect();
        let brief: Vec<_> = (0..3).map(|i| trace(i as f64, i as f64 + 1.0)).collect();
        let fm = aggregate_shards(&[busy.clone(), brief.clone()]);
        let shard_sum = fm.per_shard[0].throughput_qpm + fm.per_shard[1].throughput_qpm;
        // the brief shard's 3 requests over ~4 s inflate its own rate; the
        // honest fleet rate is 33 requests over the ~59 s global window
        assert!((fm.fleet.throughput_qpm - 33.0 / fm.fleet.makespan_s * 60.0).abs() < 1e-9);
        assert!(fm.fleet.throughput_qpm < shard_sum);
        // empty shard set degrades to defaults
        let empty = aggregate_shards(&[]);
        assert_eq!(empty.fleet.n_requests, 0);
        assert!(empty.per_shard.is_empty());
    }

    #[test]
    fn ttfs_skips_non_progressive() {
        // cloud-full traces carry no streaming timestamps; percentiles
        // must not be polluted by zeros
        let traces: Vec<_> = (0..10).map(|i| trace(i as f64, i as f64 + 2.0)).collect();
        let m = aggregate(&traces);
        assert_eq!(m.p50_ttfs_s, 0.0);
        assert_eq!(m.p99_ttfe_s, 0.0);
        assert!(traces.iter().all(|t| t.ttfs().is_none() && t.ttfe().is_none()));
    }
}
