//! Model registry: the Table-I model ladder and its simulation calibration.
//!
//! Each entry pairs (a) the *real* picoLM artifact (HLO + weights, loaded by
//! `runtime/`) with (b) the *simulated* identity it plays on the testbed
//! (Qwen2.5-72B, ..., Qwen2.5-1.5B) — speed, GPU memory and MMLU from the
//! paper's Table I, plus behavioural notes from §V-B (the 32B model's poor
//! response-length prediction).

use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    Qwen,
    Llama,
}

/// Static + artifact-derived description of one model variant.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub family: Family,
    /// Table I calibration (simulated identity)
    pub speed_tps: f64,
    pub memory_gb: f64,
    pub mmlu: f64,
    /// §V-B behavioural note: multiplicative bias of length predictions
    /// (1.0 = accurate; <1 = systematic underestimation).
    pub length_pred_bias: f64,
    /// picoLM reality (from artifacts meta.json; zero if registry is builtin)
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_params: usize,
    pub eval_accuracy: f64,
    pub artifact_dir: Option<PathBuf>,
}

impl ModelInfo {
    /// Simulated parameter count in billions (from the name, for sizing rules).
    pub fn sim_params_b(&self) -> f64 {
        match self.name.as_str() {
            "qwen72b-sim" => 72.0,
            "llama70b-sim" => 70.0,
            "qwen32b-sim" => 32.0,
            "llama8b-sim" => 8.0,
            "qwen7b-sim" => 7.0,
            "qwen1.5b-sim" => 1.5,
            _ => 1.0,
        }
    }

    /// Is this a small-enough model for edge deployment (paper: < 8B class)?
    pub fn edge_class(&self) -> bool {
        self.sim_params_b() <= 8.0
    }
}

/// The six Table-I entries, largest first.
pub fn builtin_table() -> Vec<ModelInfo> {
    let mk = |name: &str, family, speed, mem, mmlu, bias| ModelInfo {
        name: name.to_string(),
        family,
        speed_tps: speed,
        memory_gb: mem,
        mmlu,
        length_pred_bias: bias,
        d_model: 0,
        n_layers: 0,
        n_heads: 0,
        n_params: 0,
        eval_accuracy: 0.0,
        artifact_dir: None,
    };
    vec![
        mk("qwen72b-sim", Family::Qwen, 18.19, 134.74, 86.1, 1.0),
        mk("llama70b-sim", Family::Llama, 18.82, 130.64, 79.5, 1.0),
        mk("qwen32b-sim", Family::Qwen, 22.13, 60.11, 83.3, 0.55),
        mk("llama8b-sim", Family::Llama, 76.5, 15.83, 66.6, 1.0),
        mk("qwen7b-sim", Family::Qwen, 84.28, 14.92, 74.2, 1.0),
        mk("qwen1.5b-sim", Family::Qwen, 183.33, 3.44, 60.9, 0.9),
    ]
}

#[derive(Clone, Debug)]
pub struct Registry {
    pub models: Vec<ModelInfo>,
}

impl Registry {
    /// Simulation-only registry (no artifacts needed) — used by pure
    /// scheduling/efficiency experiments and unit tests.
    pub fn builtin() -> Self {
        Registry { models: builtin_table() }
    }

    /// Registry backed by `make artifacts` output; enriches the builtin
    /// table with picoLM dims + measured eval accuracy.
    pub fn from_artifacts(dir: &Path) -> Result<Self, String> {
        let mut models = builtin_table();
        for m in &mut models {
            let mdir = dir.join("models").join(&m.name);
            let meta_path = mdir.join("meta.json");
            let text = std::fs::read_to_string(&meta_path)
                .map_err(|e| format!("read {}: {e} (run `make artifacts`)", meta_path.display()))?;
            let meta = Json::parse(&text)?;
            m.d_model = meta.req("d_model")?.as_usize().ok_or("bad d_model")?;
            m.n_layers = meta.req("n_layers")?.as_usize().ok_or("bad n_layers")?;
            m.n_heads = meta.req("n_heads")?.as_usize().ok_or("bad n_heads")?;
            m.n_params = meta.req("n_params")?.as_usize().ok_or("bad n_params")?;
            if let Some(metrics) = meta.get("metrics") {
                m.eval_accuracy =
                    metrics.get("eval_accuracy").and_then(Json::as_f64).unwrap_or(0.0);
            }
            m.artifact_dir = Some(mdir);
        }
        Ok(Registry { models })
    }

    pub fn get(&self, name: &str) -> Option<&ModelInfo> {
        self.models.iter().find(|m| m.name == name)
    }

    /// Edge-deployable models smaller than `cloud` (paper: "the SLM at edge
    /// is any model with fewer parameters than the cloud model").
    pub fn slms_for(&self, cloud: &str) -> Vec<&ModelInfo> {
        let cb = self.get(cloud).map(|m| m.sim_params_b()).unwrap_or(f64::MAX);
        self.models
            .iter()
            .filter(|m| m.sim_params_b() < cb && m.edge_class())
            .collect()
    }

    pub fn names(&self) -> Vec<&str> {
        self.models.iter().map(|m| m.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_ladder_ordered() {
        let r = Registry::builtin();
        assert_eq!(r.models.len(), 6);
        // speed increases as size decreases
        assert!(r.get("qwen1.5b-sim").unwrap().speed_tps > r.get("qwen72b-sim").unwrap().speed_tps);
    }

    #[test]
    fn slm_selection_matches_paper() {
        let r = Registry::builtin();
        let slms = r.slms_for("qwen72b-sim");
        let names: Vec<_> = slms.iter().map(|m| m.name.as_str()).collect();
        assert_eq!(names, vec!["llama8b-sim", "qwen7b-sim", "qwen1.5b-sim"]);
        // for a small cloud model, only smaller SLMs remain
        let slms = r.slms_for("qwen7b-sim");
        assert_eq!(slms.len(), 1);
        assert_eq!(slms[0].name, "qwen1.5b-sim");
    }

    #[test]
    fn edge_class_cutoff() {
        let r = Registry::builtin();
        assert!(!r.get("qwen32b-sim").unwrap().edge_class());
        assert!(r.get("llama8b-sim").unwrap().edge_class());
    }
}
