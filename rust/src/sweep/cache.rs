//! The shared in-process generation cache — now a façade over the paged
//! buffer pool in [`crate::store`].
//!
//! [`SharedMemoCache`] keeps the API every call site was built against
//! (`get`/`insert`/`stats`, owner ids, cross-variant hit accounting,
//! `Arc`-shared across N concurrent engines), but the storage underneath is
//! [`BufferPool`]: fixed-size pages under a hard budget (the legacy
//! `PICE_MEMO_CAP` entry cap or the `PICE_CACHE_BUDGET` byte budget), clock
//! eviction with pin-while-reading, and cold pages spilled to a paged
//! on-disk store instead of silently discarded.
//!
//! Soundness is unchanged: every entry is keyed by the full generation
//! request ([`MemoKey`]: model, prompt tokens, sampling params) and both
//! shipped backends are pure functions of that key, so a hit — whichever
//! scenario inserted the entry, whatever got evicted, spilled, or faulted
//! back in between — returns exactly the bytes a live generation would.
//! Eviction and spill may change hit rates and load times, never traces.
//!
//! Each handle is tagged with an `owner` id (one per sweep scenario); a hit
//! on an entry inserted under a different owner is a **cross-variant hit**
//! (`cross_variant_hit_rate` in the perf bench). Entries faulted in from a
//! prior process's pages carry [`SNAPSHOT_OWNER`], so warm-start hits also
//! count as cross hits.
//!
//! Cross-process persistence is [`load_snapshot`]/[`SnapshotState::save`],
//! same names as the old monolithic-JSON layer — but `load` now only reads
//! the store's **manifest** (pages fault in on demand, killing the
//! per-process snapshot load spike) and `save` writes dirty pages + the
//! manifest. A v1 monolithic snapshot found at the path is imported once
//! and converted in place; see [`crate::store::spill`].

use std::path::{Path, PathBuf};

use crate::runtime::GenOutput;
use crate::store::{BufferPool, PoolCfg, PoolCounters};

pub use crate::store::{MemoKey, SNAPSHOT_OWNER};

/// The monolithic-snapshot format version this layer can still *import*
/// (one-time migration); the paged store writes
/// [`crate::store::STORE_VERSION`].
pub const CACHE_VERSION: usize = 1;

/// Counters of a [`SharedMemoCache`] since construction.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// hits served by an entry inserted under a *different* owner id than
    /// the requester's — cross-variant (or cross-process, for restored
    /// entries) sharing
    pub cross_hits: u64,
    /// pages whose payload was evicted from memory (spilled or discarded)
    pub evictions: u64,
    /// page files written by the evictor (budget pressure, not saves)
    pub spilled_pages: u64,
    /// pages read back from disk on demand
    pub faulted_pages: u64,
    /// entries with non-finite logps dropped by page writes — they have no
    /// JSON representation, so the store shrinks by this many entries
    /// (previously a silent drop in the snapshot writer)
    pub skipped_nonfinite: u64,
    /// current resident payload byte estimate
    pub resident_bytes: u64,
    /// current resident entry count
    pub resident_entries: u64,
}

impl CacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }

    /// Fraction of ALL lookups served by another variant's entry.
    pub fn cross_hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.cross_hits as f64 / self.lookups() as f64
        }
    }
}

impl From<PoolCounters> for CacheStats {
    fn from(c: PoolCounters) -> CacheStats {
        CacheStats {
            hits: c.hits,
            misses: c.misses,
            cross_hits: c.cross_hits,
            evictions: c.evictions,
            spilled_pages: c.spilled_pages,
            faulted_pages: c.faulted_pages,
            skipped_nonfinite: c.skipped_nonfinite,
            resident_bytes: c.resident_bytes,
            resident_entries: c.resident_entries,
        }
    }
}

/// The process-wide generation cache, shared via `Arc` across every engine.
/// All methods take `&self`. A façade over [`BufferPool`].
pub struct SharedMemoCache {
    pool: BufferPool,
}

impl SharedMemoCache {
    /// Legacy constructor: an entry-count bound (`PICE_MEMO_CAP`
    /// semantics — a cap of N keeps the N newest entries resident).
    pub fn new(capacity: usize) -> Self {
        SharedMemoCache::with_cfg(PoolCfg::entry_capped(capacity))
    }

    /// Construct with an explicit pool budget (entry cap or byte budget).
    pub fn with_cfg(cfg: PoolCfg) -> Self {
        SharedMemoCache { pool: BufferPool::new(cfg) }
    }

    /// The pool underneath, for store attachment and pool-level counters.
    pub fn pool(&self) -> &BufferPool {
        &self.pool
    }

    /// Look up `key` on behalf of scenario `owner`; counts hit/miss and
    /// cross-variant provenance. May fault a spilled page in from disk.
    pub fn get(&self, key: &MemoKey, owner: u32) -> Option<GenOutput> {
        self.pool.get(key, owner)
    }

    /// Insert an entry produced by scenario `owner`; evicts (spilling when
    /// a store is attached) beyond the pool budget.
    pub fn insert(&self, key: MemoKey, out: GenOutput, owner: u32) {
        self.pool.insert(key, out, owner)
    }

    pub fn stats(&self) -> CacheStats {
        self.pool.counters().into()
    }

    /// Total distinct keys ever inserted (monotone; drives dirty checks for
    /// the persistence layer).
    pub fn insertions(&self) -> u64 {
        self.pool.insertions()
    }

    /// Entries available: resident plus spilled-on-disk.
    pub fn len(&self) -> usize {
        self.pool.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pool.is_empty()
    }

    /// All resident entries in page/append order — deterministic for a
    /// deterministic fill sequence. Spilled pages are not faulted in.
    pub fn export(&self) -> Vec<(MemoKey, GenOutput)> {
        self.pool.export()
    }
}

// ---------------------------------------------------------------------------
// Cross-process persistence (the paged store behind the old snapshot API)
// ---------------------------------------------------------------------------

/// One process-wide binding of a [`SharedMemoCache`] to its on-disk store.
/// Produced by [`load_snapshot`]; call [`SnapshotState::save`] (typically
/// once, at process exit) to write dirty pages + the manifest back.
pub struct SnapshotState {
    path: PathBuf,
    restored: usize,
}

impl SnapshotState {
    /// Entries available from disk at attach time (0 on a cold start).
    /// These are *not* read into memory — pages fault in on first use.
    pub fn restored_entries(&self) -> usize {
        self.restored
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Have entries been inserted since the last flush? (Eviction-spilled
    /// pages are already durable; this tracks unsaved insertions.)
    pub fn dirty(&self, cache: &SharedMemoCache) -> bool {
        cache.pool().dirty()
    }

    /// Write all dirty resident pages and the manifest. Pages the evictor
    /// already spilled are not rewritten.
    pub fn save(&mut self, cache: &SharedMemoCache) -> Result<(), String> {
        cache.pool().flush()
    }
}

/// Attach `cache` to the paged store at `path` (a directory; one stamp
/// subdirectory per invalidation stamp). Only the manifest is read —
/// entries become *available* and fault in page-at-a-time on demand,
/// landing under [`SNAPSHOT_OWNER`]. A v1 monolithic snapshot file found at
/// `path` is imported once and converted to the paged layout. A missing,
/// unreadable, or stale store just means a cold start — never an error.
pub fn load_snapshot(
    cache: &SharedMemoCache,
    path: impl Into<PathBuf>,
    stamp: &str,
) -> SnapshotState {
    let path = path.into();
    let restored = cache.pool().attach_store(&path, stamp);
    SnapshotState { path, restored }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::SamplingParams;

    fn key(model: &str, seed: u64) -> MemoKey {
        MemoKey::new(model, &[seed as u32, 7], &SamplingParams { seed, ..Default::default() })
    }

    fn out(t: u32) -> GenOutput {
        GenOutput { tokens: vec![t], logps: vec![-0.25], finished: true }
    }

    #[test]
    fn capacity_bounded() {
        // page-granular eviction still respects the nominal entry cap
        let c = SharedMemoCache::new(256);
        for i in 0..1000u64 {
            c.insert(key("m", i), out(i as u32), 0);
        }
        assert!(c.len() <= 256, "cache grew to {}", c.len());
        assert_eq!(c.insertions(), 1000);
        assert!(c.stats().evictions > 0);
    }

    #[test]
    fn tiny_capacity_exact_fifo() {
        // caps below one page shrink the page size, so a cap of 2 holds
        // exactly the 2 newest entries (old global-FIFO semantics) — not
        // whatever survives page-granular eviction
        let c = SharedMemoCache::new(2);
        for i in 0..10u64 {
            c.insert(key("m", i), out(i as u32), 0);
        }
        assert_eq!(c.len(), 2, "tiny cap must be exact");
        assert!(c.get(&key("m", 8), 0).is_some());
        assert!(c.get(&key("m", 9), 0).is_some());
        assert!(c.get(&key("m", 0), 0).is_none());
    }

    #[test]
    fn cross_variant_hits_accounted() {
        let c = SharedMemoCache::new(64);
        let k = key("m", 1);
        assert!(c.get(&k, 0).is_none());
        c.insert(k.clone(), out(9), 0);
        // same owner: plain hit
        assert_eq!(c.get(&k, 0).unwrap().tokens, vec![9]);
        // different owner: cross-variant hit
        assert_eq!(c.get(&k, 1).unwrap().tokens, vec![9]);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.cross_hits), (2, 1, 1));
        assert!(s.hit_rate() > 0.6 && s.hit_rate() < 0.7);
        assert!(s.cross_hit_rate() > 0.3 && s.cross_hit_rate() < 0.4);
    }

    #[test]
    fn export_is_deterministic() {
        let fill = || {
            let c = SharedMemoCache::new(256);
            for i in 0..40u64 {
                c.insert(key("m", i), out(i as u32), 0);
            }
            c.export()
        };
        let a = fill();
        let b = fill();
        assert_eq!(a.len(), 40);
        let ka: Vec<_> = a.iter().map(|(k, _)| k.seed).collect();
        let kb: Vec<_> = b.iter().map(|(k, _)| k.seed).collect();
        assert_eq!(ka, kb);
    }

    #[test]
    fn snapshot_round_trip() {
        let path =
            std::env::temp_dir().join(format!("pice_sweep_cache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&path);
        let c = SharedMemoCache::new(256);
        for i in 0..10u64 {
            c.insert(key("m", i), out(i as u32), 3);
        }
        let mut st = load_snapshot(&c, &path, "stamp-x");
        assert_eq!(st.restored_entries(), 0);
        assert!(st.dirty(&c), "fresh inserts must mark the snapshot dirty");
        st.save(&c).unwrap();
        assert!(!st.dirty(&c));

        let c2 = SharedMemoCache::new(256);
        let st2 = load_snapshot(&c2, &path, "stamp-x");
        assert_eq!(st2.restored_entries(), 10);
        // nothing resident until a lookup faults the page in
        assert_eq!(c2.stats().resident_entries, 0);
        // restored entries carry the snapshot owner, so any scenario's hit
        // on them counts as a cross hit
        assert_eq!(c2.get(&key("m", 4), 3).unwrap().tokens, vec![4]);
        assert_eq!(c2.stats().cross_hits, 1);
        assert_eq!(c2.stats().faulted_pages, 1);
        let _ = std::fs::remove_dir_all(&path);
    }
}
