//! The shared in-process generation cache: the bounded memo store factored
//! out of `MemoBackend`/`PersistentMemoBackend` into a lock-sharded,
//! `Arc`-shared structure, so N concurrent engines (sweep scenarios, worker
//! pools, the `Env` sequential path) all hit ONE cache.
//!
//! Soundness is unchanged from the single-owner memo cache: every entry is
//! keyed by the full generation request ([`MemoKey`]: model, prompt tokens,
//! sampling params) and both shipped backends are pure functions of that
//! key, so a hit — no matter which scenario inserted the entry or in which
//! order threads interleave — returns exactly the bytes a live generation
//! would. That purity is what makes the cache *transparent*: parallel sweep
//! results stay bit-identical to the sequential loop with the cache on,
//! off, or shared.
//!
//! Each handle is tagged with an `owner` id (one per sweep scenario); a hit
//! on an entry inserted under a different owner is a **cross-variant hit**
//! — the Fig. 6 variants replay the same questions with the same derived
//! seeds, so cross-variant hits are the common case and are reported as
//! `cross_variant_hit_rate` in the perf bench.
//!
//! The on-disk snapshot (previously private to `PersistentMemoBackend`)
//! also lives here, as [`load_snapshot`]/[`SnapshotState::save`] over a
//! cache — so a process loads the snapshot ONCE into the shared cache and
//! saves ONCE at exit, instead of one round-trip per run.

use std::collections::{HashMap, VecDeque};
use std::hash::{Hash, Hasher};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::runtime::{GenOutput, SamplingParams};
use crate::util::json::{self, Json};

/// Full generation-request identity: the memo key. f64 sampling fields are
/// stored as exact bit patterns so keys hash/compare exactly.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct MemoKey {
    pub model: String,
    pub prompt: Vec<u32>,
    pub temperature_bits: u64,
    pub max_tokens: usize,
    pub stop_token: Option<u32>,
    pub seed: u64,
}

impl MemoKey {
    pub fn new(model: &str, prompt: &[u32], sp: &SamplingParams) -> MemoKey {
        MemoKey {
            model: model.to_string(),
            prompt: prompt.to_vec(),
            temperature_bits: sp.temperature.to_bits(),
            max_tokens: sp.max_tokens,
            stop_token: sp.stop_token,
            seed: sp.seed,
        }
    }
}

/// Owner id recorded on entries restored from a snapshot — distinct from
/// every live scenario id, so warm-start hits also count as cross hits
/// (they were produced outside the requesting scenario).
pub const SNAPSHOT_OWNER: u32 = u32::MAX;

/// Lookup counters of a [`SharedMemoCache`] since construction.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// hits served by an entry inserted under a *different* owner id than
    /// the requester's — cross-variant (or cross-process, for restored
    /// entries) sharing
    pub cross_hits: u64,
}

impl CacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }

    /// Fraction of ALL lookups served by another variant's entry.
    pub fn cross_hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.cross_hits as f64 / self.lookups() as f64
        }
    }
}

struct Entry {
    out: GenOutput,
    owner: u32,
}

/// One lock domain: a bounded FIFO map, exactly the old `MemoBackend`
/// store. Keys are `Arc`-shared between the map and the eviction queue so
/// prompt token vectors are stored once.
struct Shard {
    map: HashMap<Arc<MemoKey>, Entry>,
    order: VecDeque<Arc<MemoKey>>,
}

/// Shard scaling: one lock domain per [`SHARD_GRAIN`] entries of capacity,
/// capped at [`MAX_SHARDS`]. Small caches collapse to a single shard —
/// exact global-FIFO semantics, matching the old single-owner memo store
/// (a per-shard bound of 1-2 entries would let same-shard keys evict each
/// other far below nominal capacity) — while large ones spread contention.
/// Each shard holds `capacity / shards` entries, so the resident total
/// never exceeds `capacity`.
const SHARD_GRAIN: usize = 64;
const MAX_SHARDS: usize = 16;

/// Lock-sharded bounded generation cache, shared via `Arc` across every
/// engine in the process. All methods take `&self`; contention is bounded
/// to one shard per lookup.
pub struct SharedMemoCache {
    shards: Vec<Mutex<Shard>>,
    per_shard_cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    cross_hits: AtomicU64,
    insertions: AtomicU64,
}

impl SharedMemoCache {
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(1);
        let n = (cap / SHARD_GRAIN).clamp(1, MAX_SHARDS);
        SharedMemoCache {
            shards: (0..n)
                .map(|_| Mutex::new(Shard { map: HashMap::new(), order: VecDeque::new() }))
                .collect(),
            per_shard_cap: cap / n,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            cross_hits: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
        }
    }

    fn shard_of(&self, key: &MemoKey) -> usize {
        // DefaultHasher::new() uses fixed keys — deterministic within a
        // process, which keeps export order reproducible
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) % self.shards.len()
    }

    /// Look up `key` on behalf of scenario `owner`; counts hit/miss and
    /// cross-variant provenance.
    pub fn get(&self, key: &MemoKey, owner: u32) -> Option<GenOutput> {
        let shard = self.shards[self.shard_of(key)].lock().unwrap();
        match shard.map.get(key) {
            Some(e) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                if e.owner != owner {
                    self.cross_hits.fetch_add(1, Ordering::Relaxed);
                }
                Some(e.out.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert an entry produced by scenario `owner`; FIFO-evicts within the
    /// key's shard beyond the per-shard bound.
    pub fn insert(&self, key: MemoKey, out: GenOutput, owner: u32) {
        let si = self.shard_of(&key);
        let mut shard = self.shards[si].lock().unwrap();
        let key = Arc::new(key);
        if shard.map.insert(key.clone(), Entry { out, owner }).is_none() {
            shard.order.push_back(key);
            self.insertions.fetch_add(1, Ordering::Relaxed);
        }
        while shard.map.len() > self.per_shard_cap {
            let Some(old) = shard.order.pop_front() else { break };
            shard.map.remove(&old);
        }
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            cross_hits: self.cross_hits.load(Ordering::Relaxed),
        }
    }

    /// Total distinct keys ever inserted (monotone; drives dirty checks for
    /// the snapshot layer).
    pub fn insertions(&self) -> u64 {
        self.insertions.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All resident entries, shard-major in per-shard FIFO order — the
    /// snapshot serialization order. Deterministic for a deterministic fill
    /// sequence.
    pub fn export(&self) -> Vec<(MemoKey, GenOutput)> {
        let mut out = Vec::new();
        for s in &self.shards {
            let shard = s.lock().unwrap();
            for key in &shard.order {
                if let Some(e) = shard.map.get(key) {
                    out.push(((**key).clone(), e.out.clone()));
                }
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// On-disk snapshot (cross-process persistence)
// ---------------------------------------------------------------------------

/// On-disk snapshot format version; bump when the entry layout changes.
pub const CACHE_VERSION: usize = 1;

/// Foreign-stamp sections retained in a snapshot file — bounds file growth
/// when many differently-stamped runs share one path.
const FOREIGN_STAMP_LIMIT: usize = 8;

/// One process-wide binding of a [`SharedMemoCache`] to a snapshot file:
/// where to save, which stamp section is ours, the other stamps' sections
/// to re-emit verbatim, and the insertion watermark for dirty checks.
/// Produced by [`load_snapshot`]; call [`SnapshotState::save`] (typically
/// once, at process exit) to write back.
pub struct SnapshotState {
    path: PathBuf,
    stamp: String,
    /// entry sections of OTHER stamps found in the snapshot, preserved
    /// across save (bounded at [`FOREIGN_STAMP_LIMIT`])
    foreign: Vec<(String, Json)>,
    restored: usize,
    /// cache insertion count at load / after the last save
    clean_insertions: u64,
}

impl SnapshotState {
    /// Entries restored from disk at construction (0 on a cold start).
    pub fn restored_entries(&self) -> usize {
        self.restored
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Has the cache gained entries since load / the last save?
    pub fn dirty(&self, cache: &SharedMemoCache) -> bool {
        cache.insertions() != self.clean_insertions
    }

    /// Snapshot `cache` to `self.path` (shard-major FIFO order, so a
    /// restored cache evicts in the same order a live one would); other
    /// stamps' sections are written back untouched. Temp-file + rename, so
    /// a crashed process never leaves a torn snapshot.
    pub fn save(&mut self, cache: &SharedMemoCache) -> Result<(), String> {
        let insertions = cache.insertions();
        let mut entries = Vec::new();
        for (key, out) in cache.export() {
            // a non-finite logp (e.g. -inf from a zero-probability token)
            // has no JSON representation — skip the entry rather than write
            // an unparseable file
            if out.logps.iter().all(|x| x.is_finite()) {
                entries.push(entry_json(&key, &out));
            }
        }
        let mut caches = std::collections::BTreeMap::new();
        for (st, ent) in &self.foreign {
            caches.insert(st.clone(), ent.clone());
        }
        caches.insert(self.stamp.clone(), Json::Arr(entries));
        let snap = json::obj(vec![
            ("version", json::num(CACHE_VERSION as f64)),
            ("caches", Json::Obj(caches)),
        ]);
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                let _ = std::fs::create_dir_all(dir);
            }
        }
        let tmp = self.path.with_extension(format!("tmp{}", std::process::id()));
        std::fs::write(&tmp, snap.to_string())
            .map_err(|e| format!("write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &self.path)
            .map_err(|e| format!("rename to {}: {e}", self.path.display()))?;
        self.clean_insertions = insertions;
        Ok(())
    }
}

/// Restore `stamp`'s section of any matching-version snapshot at `path`
/// into `cache` (entries land under [`SNAPSHOT_OWNER`]); other stamps'
/// sections are retained for re-emission on save. A missing, unreadable,
/// or stale snapshot just means a cold start — never an error.
pub fn load_snapshot(
    cache: &SharedMemoCache,
    path: impl Into<PathBuf>,
    stamp: &str,
) -> SnapshotState {
    let path = path.into();
    let mut restored = 0usize;
    let mut foreign: Vec<(String, Json)> = Vec::new();
    if let Ok(text) = std::fs::read_to_string(&path) {
        if let Ok(snap) = Json::parse(&text) {
            if snap.get("version").and_then(Json::as_usize) == Some(CACHE_VERSION) {
                if let Some(Json::Obj(caches)) = snap.get("caches") {
                    for (st, entries) in caches {
                        if st == stamp {
                            for e in entries.as_arr().unwrap_or(&[]) {
                                if let Some((key, out)) = entry_from_json(e) {
                                    cache.insert(key, out, SNAPSHOT_OWNER);
                                    restored += 1;
                                }
                            }
                        } else if foreign.len() < FOREIGN_STAMP_LIMIT {
                            foreign.push((st.clone(), entries.clone()));
                        }
                    }
                }
            }
        }
    }
    SnapshotState {
        path,
        stamp: stamp.to_string(),
        foreign,
        restored,
        clean_insertions: cache.insertions(),
    }
}

fn u64_hex(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

fn parse_u64_hex(j: &Json) -> Option<u64> {
    u64::from_str_radix(j.as_str()?, 16).ok()
}

fn u32s_json(v: &[u32]) -> Json {
    Json::Arr(v.iter().map(|&t| Json::Num(t as f64)).collect())
}

fn parse_u32s(j: &Json) -> Option<Vec<u32>> {
    j.as_arr()?.iter().map(|x| x.as_f64().map(|f| f as u32)).collect()
}

/// One snapshot entry: the full memo key + the cached output. u64 fields
/// (seed, temperature bit pattern) are hex strings — JSON numbers are f64
/// and can't represent all 64-bit patterns exactly.
fn entry_json(key: &MemoKey, out: &GenOutput) -> Json {
    json::obj(vec![
        ("model", json::s(&key.model)),
        ("prompt", u32s_json(&key.prompt)),
        ("t_bits", u64_hex(key.temperature_bits)),
        ("max_tokens", json::num(key.max_tokens as f64)),
        (
            "stop",
            match key.stop_token {
                Some(t) => json::num(t as f64),
                None => Json::Null,
            },
        ),
        ("seed", u64_hex(key.seed)),
        ("tokens", u32s_json(&out.tokens)),
        ("logps", Json::Arr(out.logps.iter().map(|&x| Json::Num(x)).collect())),
        ("finished", Json::Bool(out.finished)),
    ])
}

fn entry_from_json(j: &Json) -> Option<(MemoKey, GenOutput)> {
    let key = MemoKey {
        model: j.get("model")?.as_str()?.to_string(),
        prompt: parse_u32s(j.get("prompt")?)?,
        temperature_bits: parse_u64_hex(j.get("t_bits")?)?,
        max_tokens: j.get("max_tokens")?.as_usize()?,
        stop_token: match j.get("stop")? {
            Json::Null => None,
            x => Some(x.as_f64()? as u32),
        },
        seed: parse_u64_hex(j.get("seed")?)?,
    };
    let out = GenOutput {
        tokens: parse_u32s(j.get("tokens")?)?,
        logps: j.get("logps")?.as_arr()?.iter().map(Json::as_f64).collect::<Option<Vec<f64>>>()?,
        finished: j.get("finished")?.as_bool()?,
    };
    Some((key, out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(model: &str, seed: u64) -> MemoKey {
        MemoKey::new(model, &[seed as u32, 7], &SamplingParams { seed, ..Default::default() })
    }

    fn out(t: u32) -> GenOutput {
        GenOutput { tokens: vec![t], logps: vec![-0.25], finished: true }
    }

    #[test]
    fn capacity_bounded_across_shards() {
        // 256 -> 4 shards x 64: the resident total stays under the nominal
        // capacity no matter how keys hash
        let c = SharedMemoCache::new(256);
        for i in 0..1000u64 {
            c.insert(key("m", i), out(i as u32), 0);
        }
        assert!(c.len() <= 256, "cache grew to {}", c.len());
        assert_eq!(c.insertions(), 1000);
    }

    #[test]
    fn tiny_capacity_single_shard_exact_fifo() {
        // caps below the shard grain collapse to ONE shard, so a cap of 2
        // holds exactly the 2 newest entries (old global-FIFO semantics) —
        // not one entry per shard with hash-dependent thrashing
        let c = SharedMemoCache::new(2);
        for i in 0..10u64 {
            c.insert(key("m", i), out(i as u32), 0);
        }
        assert_eq!(c.len(), 2, "single-shard cap must be exact");
        assert!(c.get(&key("m", 8), 0).is_some());
        assert!(c.get(&key("m", 9), 0).is_some());
        assert!(c.get(&key("m", 0), 0).is_none());
    }

    #[test]
    fn cross_variant_hits_accounted() {
        let c = SharedMemoCache::new(64);
        let k = key("m", 1);
        assert!(c.get(&k, 0).is_none());
        c.insert(k.clone(), out(9), 0);
        // same owner: plain hit
        assert_eq!(c.get(&k, 0).unwrap().tokens, vec![9]);
        // different owner: cross-variant hit
        assert_eq!(c.get(&k, 1).unwrap().tokens, vec![9]);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.cross_hits), (2, 1, 1));
        assert!(s.hit_rate() > 0.6 && s.hit_rate() < 0.7);
        assert!(s.cross_hit_rate() > 0.3 && s.cross_hit_rate() < 0.4);
    }

    #[test]
    fn export_is_deterministic() {
        let fill = || {
            let c = SharedMemoCache::new(256);
            for i in 0..40u64 {
                c.insert(key("m", i), out(i as u32), 0);
            }
            c.export()
        };
        let a = fill();
        let b = fill();
        assert_eq!(a.len(), 40);
        let ka: Vec<_> = a.iter().map(|(k, _)| k.seed).collect();
        let kb: Vec<_> = b.iter().map(|(k, _)| k.seed).collect();
        assert_eq!(ka, kb);
    }

    #[test]
    fn snapshot_round_trip() {
        let path =
            std::env::temp_dir().join(format!("pice_sweep_cache_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let c = SharedMemoCache::new(256);
        for i in 0..10u64 {
            c.insert(key("m", i), out(i as u32), 3);
        }
        let mut st = load_snapshot(&c, &path, "stamp-x");
        assert_eq!(st.restored_entries(), 0);
        assert!(st.dirty(&c), "fresh inserts must mark the snapshot dirty");
        st.save(&c).unwrap();
        assert!(!st.dirty(&c));

        let c2 = SharedMemoCache::new(256);
        let st2 = load_snapshot(&c2, &path, "stamp-x");
        assert_eq!(st2.restored_entries(), 10);
        // restored entries carry the snapshot owner, so any scenario's hit
        // on them counts as a cross hit
        assert_eq!(c2.get(&key("m", 4), 3).unwrap().tokens, vec![4]);
        assert_eq!(c2.stats().cross_hits, 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn entry_json_round_trip_exact() {
        // direct serde check, including u64 bit patterns beyond 2^53 and
        // negative fractional logps
        let key = MemoKey {
            model: "m".to_string(),
            prompt: vec![1, 2, 4_000_000_000],
            temperature_bits: 0.7f64.to_bits(),
            max_tokens: 24,
            stop_token: Some(7),
            seed: u64::MAX - 12345,
        };
        let out = GenOutput {
            tokens: vec![9, 8, 7],
            logps: vec![-0.123456789012345, -3.5e-7, 0.0],
            finished: true,
        };
        let j = entry_json(&key, &out);
        let reparsed = Json::parse(&j.to_string()).unwrap();
        let (k2, o2) = entry_from_json(&reparsed).unwrap();
        assert_eq!(k2, key);
        assert_eq!(o2.tokens, out.tokens);
        assert_eq!(o2.logps, out.logps);
        assert_eq!(o2.finished, out.finished);
    }
}
