//! The scenario-sweep runner: executes independent `(EngineCfg, Workload)`
//! scenarios across an OS-thread pool with deterministic, submission-order
//! result collection.
//!
//! Each scenario is a pure function of `(cfg, workload, seed)` — every
//! random draw inside the engine flows from `cfg.seed`, and every backend
//! generation is a pure function of its `(model, prompt, sampling-params)`
//! key — so the parallel sweep is **bit-identical** to the sequential
//! `for` loop regardless of thread count, scheduling order, or whether the
//! scenarios share a [`SharedMemoCache`](super::cache::SharedMemoCache)
//! (enforced by `rust/tests/sweep_determinism.rs`).
//!
//! Work distribution is a single atomic cursor over the scenario list
//! (dynamic load balancing: a thread that finishes a cheap scenario
//! immediately pulls the next one); results are written into their
//! submission slot, so `results[i]` always corresponds to `scenarios[i]`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::backend::TextBackend;
use crate::coordinator::{Engine, EngineCfg, RunError};
use crate::corpus::workload::Workload;
use crate::corpus::Corpus;
use crate::metrics::{aggregate, RequestTrace, RunMetrics};
use crate::models::Registry;
use crate::telemetry::{phase_breakdown, Span};
use crate::tokenizer::Tokenizer;

/// One cell of a sweep grid. Workloads are `Arc`-shared: a grid typically
/// replays one workload across many `EngineCfg` variants.
#[derive(Clone)]
pub struct SweepScenario {
    pub label: String,
    pub cfg: EngineCfg,
    pub workload: Arc<Workload>,
    /// enable the engine's telemetry sink for this cell (spans come back
    /// via [`SweepRunner::run_traced`]; `run` drops them)
    pub telemetry: bool,
}

impl SweepScenario {
    pub fn new(label: impl Into<String>, cfg: EngineCfg, workload: Arc<Workload>) -> Self {
        SweepScenario { label: label.into(), cfg, workload, telemetry: false }
    }

    /// Record request spans and metrics while this cell runs. Telemetry is
    /// pure in `(cfg, workload, seed)`, so the sweep stays bit-identical
    /// to the sequential loop at any thread count.
    pub fn with_telemetry(mut self) -> Self {
        self.telemetry = true;
        self
    }
}

pub type ScenarioResult = Result<(RunMetrics, Vec<RequestTrace>), RunError>;

/// [`ScenarioResult`] plus the scenario's telemetry span log (empty unless
/// the cell asked for telemetry via [`SweepScenario::with_telemetry`]).
pub type TracedResult = Result<(RunMetrics, Vec<RequestTrace>, Vec<Span>), RunError>;

/// Sweep-pool size: `PICE_SWEEP_THREADS` when set and parsable (min 1),
/// else auto-sized from the host like the backend worker pool
/// ([`crate::scenario::auto_workers`]). Orthogonal to `PICE_WORKERS`: that
/// knob shards one engine's generation batches, this one runs whole
/// scenarios concurrently. `Env::run_sweep` stacks the two when
/// `PICE_WORKERS` is set explicitly (each scenario gets its own pool).
pub fn sweep_threads() -> usize {
    std::env::var("PICE_SWEEP_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .map(|n| n.max(1))
        .unwrap_or_else(crate::scenario::auto_workers)
}

/// Executes scenario grids over a fixed-size OS-thread pool.
pub struct SweepRunner {
    threads: usize,
}

impl SweepRunner {
    pub fn new(threads: usize) -> Self {
        SweepRunner { threads: threads.max(1) }
    }

    pub fn from_env() -> Self {
        SweepRunner::new(sweep_threads())
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run every scenario; `results[i]` corresponds to `scenarios[i]`.
    ///
    /// `factory(i)` builds scenario i's backend stack *inside the worker
    /// thread that runs it* — typically a memo wrapper sharing one
    /// [`SharedMemoCache`](super::cache::SharedMemoCache) with owner id
    /// `i`, over a fresh replica of the substrate backend. One backend per
    /// scenario (not per thread) keeps owner attribution per-variant, which
    /// is what the cross-variant hit metric counts.
    pub fn run<F>(
        &self,
        scenarios: &[SweepScenario],
        corpus: &Arc<Corpus>,
        tok: &Tokenizer,
        registry: &Registry,
        factory: F,
    ) -> Vec<ScenarioResult>
    where
        F: Fn(usize) -> Box<dyn TextBackend> + Sync,
    {
        self.run_traced(scenarios, corpus, tok, registry, factory)
            .into_iter()
            .map(|r| r.map(|(m, t, _)| (m, t)))
            .collect()
    }

    /// [`SweepRunner::run`] but keeping each cell's telemetry span log
    /// (empty for cells without [`SweepScenario::with_telemetry`]).
    pub fn run_traced<F>(
        &self,
        scenarios: &[SweepScenario],
        corpus: &Arc<Corpus>,
        tok: &Tokenizer,
        registry: &Registry,
        factory: F,
    ) -> Vec<TracedResult>
    where
        F: Fn(usize) -> Box<dyn TextBackend> + Sync,
    {
        let n = scenarios.len();
        if self.threads <= 1 || n <= 1 {
            return scenarios
                .iter()
                .enumerate()
                .map(|(i, sc)| run_one(sc, corpus, tok, registry, factory(i).as_mut()))
                .collect();
        }
        let cursor = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<TracedResult>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|s| {
            for _ in 0..self.threads.min(n) {
                s.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::SeqCst);
                    if i >= n {
                        break;
                    }
                    let mut backend = factory(i);
                    let res = run_one(&scenarios[i], corpus, tok, registry, backend.as_mut());
                    *slots[i].lock().unwrap() = Some(res);
                });
            }
        });
        slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("every scenario slot filled"))
            .collect()
    }
}

fn run_one(
    sc: &SweepScenario,
    corpus: &Arc<Corpus>,
    tok: &Tokenizer,
    registry: &Registry,
    backend: &mut dyn TextBackend,
) -> TracedResult {
    let mut engine = Engine::new(sc.cfg.clone(), corpus.clone(), tok, registry, backend)?;
    if sc.telemetry {
        engine.enable_telemetry(0);
    }
    let traces = engine.run(&sc.workload)?;
    let spans = if sc.telemetry { engine.take_spans() } else { Vec::new() };
    let mut m = aggregate(&traces);
    if sc.telemetry {
        m.phases = phase_breakdown(&spans);
    }
    Ok((m, traces, spans))
}
