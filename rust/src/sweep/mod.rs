//! Scenario-sweep execution layer: PICE's evaluation is a *grid* of
//! scenarios (policies × loads × queue caps × model registries — Fig. 6,
//! Fig. 13, Table I), and this module makes the grid itself a first-class
//! parallel subsystem instead of a `for` loop:
//!
//! * [`cache::SharedMemoCache`] — the generation memo store, an
//!   `Arc`-shared façade over the paged buffer pool in [`crate::store`]
//!   (budgeted residency, clock eviction, disk spill), so N concurrent
//!   engines hit ONE in-process cache and its paged on-disk store is
//!   attached once per process, with pages faulting in on demand.
//! * [`SweepRunner`] — runs independent `(EngineCfg, Workload)` scenarios
//!   over an OS-thread pool with submission-order result collection;
//!   results are bit-identical to the sequential loop at any thread count.
//!
//! `scenario::Env::run_sweep` wires both together for benches; see PERF.md
//! §Scenario-sweep layer.

pub mod cache;
pub mod runner;

pub use cache::{CacheStats, SharedMemoCache};
pub use runner::{sweep_threads, ScenarioResult, SweepRunner, SweepScenario, TracedResult};
