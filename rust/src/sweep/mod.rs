//! Scenario-sweep execution layer: PICE's evaluation is a *grid* of
//! scenarios (policies × loads × queue caps × model registries — Fig. 6,
//! Fig. 13, Table I), and this module makes the grid itself a first-class
//! parallel subsystem instead of a `for` loop:
//!
//! * [`cache::SharedMemoCache`] — the bounded generation memo store,
//!   factored out of the backend wrappers into a lock-sharded `Arc`-shared
//!   structure, so N concurrent engines hit ONE in-process cache (and the
//!   on-disk snapshot is loaded/saved once per process, not per run).
//! * [`SweepRunner`] — runs independent `(EngineCfg, Workload)` scenarios
//!   over an OS-thread pool with submission-order result collection;
//!   results are bit-identical to the sequential loop at any thread count.
//!
//! `scenario::Env::run_sweep` wires both together for benches; see PERF.md
//! §Scenario-sweep layer.

pub mod cache;
pub mod runner;

pub use cache::{CacheStats, SharedMemoCache};
pub use runner::{sweep_threads, ScenarioResult, SweepRunner, SweepScenario};
