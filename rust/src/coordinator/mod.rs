//! The PICE coordinator — the paper's system contribution.
//!
//! * [`scheduler`] — cloud-side dynamic sketch-level scheduling (Eq. 2)
//! * [`dispatch`]  — multi-list job dispatching (Algorithm 1)
//! * [`selection`] — edge-side online SLM selection (Algorithm 2)
//! * [`slo`]       — lexicographic multi-objective SLO policy
//! * [`engine`]    — the serving event loop over the simulated testbed
//! * [`backend`]   — pluggable text generation (PJRT real / surrogate)

pub mod backend;
pub mod dispatch;
pub mod engine;
pub mod scheduler;
pub mod selection;
pub mod slo;

pub use engine::{Engine, EngineCfg, Policy, RunError, TailCfg};
