//! The PICE serving engine: a discrete-event simulation of the cloud-edge
//! testbed in which *text is generated for real* (via the pluggable
//! [`TextBackend`]) while *time advances virtually* per the calibrated
//! device/network models (DESIGN.md §2).
//!
//! One engine runs one scenario (cloud model, N edges, workload, policy) and
//! produces per-request traces. The baselines (cloud-only / edge-only /
//! routing) reuse the same event loop with different admission policies —
//! exactly how the paper runs its comparisons on a fixed testbed.

use std::collections::VecDeque;
use std::sync::Arc;

use super::backend::{GenRequest, TextBackend};
use super::dispatch::{Job, MultiListQueue};
use super::scheduler::{CloudScheduler, Mode as SchedMode, SchedInput};
use super::selection::select_model;
use crate::cluster::Cluster;
use crate::corpus::workload::Workload;
use crate::corpus::Corpus;
use crate::ensemble::{select as ensemble_select, Candidate, ConfidenceWeights};
use crate::metrics::{Mode, RequestTrace};
use crate::models::{ModelInfo, Registry};
use crate::network::Link;
use crate::parallel::{batch_wall, plan_batch, EdgeCostModel};
use crate::profiler::OfflineProfile;
use crate::runtime::SamplingParams;
use crate::simclock::{EventQueue, SimTime};
use crate::sketch::{compress, split_sketch, Prompts};
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

/// Serving policy: PICE or one of the paper's baselines (§V-A).
#[derive(Clone, Debug)]
pub enum Policy {
    Pice,
    CloudOnly,
    EdgeOnly,
    /// Hybrid-LLM-style difficulty router: queries with predicted length
    /// above the threshold go to the cloud, the rest to edge SLMs.
    Routing { difficulty_threshold: f64 },
}

#[derive(Clone, Debug)]
pub struct EngineCfg {
    pub cloud_model: String,
    pub n_edges: usize,
    pub link: Link,
    pub policy: Policy,
    /// max ensemble replicas per expansion job (1 = ensemble off)
    pub ensemble_k: usize,
    /// job-queue capacity (Fig. 13)
    pub queue_cap: usize,
    /// cap on cloud full-answer length, in SIM tokens (Fig. 3's knob)
    pub cloud_max_tokens: usize,
    /// simulated tokens per real picoLM token. The picoLM corpus answers are
    /// ~50 real tokens; the paper's serving regime is ~500-token answers, so
    /// scale 10 puts the simulated testbed in the paper's operating point
    /// (cloud batch ~20 saturating at ~1.5x-batch RPM) while text stays real.
    pub sim_token_scale: f64,
    pub seed: u64,
    pub scheduler: CloudScheduler,
    pub confidence: ConfidenceWeights,
    /// apply the RLAIF-fine-tuned sketch policy (per-category keep-fraction
    /// learned by `finetune`); None = base sketching
    pub sketch_keep_frac_override: Option<std::collections::BTreeMap<String, f64>>,
}

impl EngineCfg {
    pub fn pice(cloud_model: &str) -> Self {
        let mut scheduler = CloudScheduler::default();
        scheduler.min_progressive_len = 250; // sim tokens (25 real words)
        EngineCfg {
            cloud_model: cloud_model.to_string(),
            n_edges: 4,
            link: Link::default_wan(),
            policy: Policy::Pice,
            ensemble_k: 3,
            queue_cap: 8,
            cloud_max_tokens: 1000,
            sim_token_scale: 12.0,
            seed: 17,
            scheduler,
            confidence: ConfidenceWeights::default(),
            sketch_keep_frac_override: None,
        }
    }

    pub fn with_policy(mut self, p: Policy) -> Self {
        self.policy = p;
        self
    }
}

#[derive(Debug)]
pub enum RunError {
    /// the placement is infeasible (Table III's "OOM" cells)
    Oom(String),
    Backend(String),
}

impl std::fmt::Display for RunError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RunError::Oom(m) => write!(f, "OOM: {m}"),
            RunError::Backend(m) => write!(f, "backend: {m}"),
        }
    }
}

// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
enum Ev {
    Arrive(usize),
    CloudAdmit,
    CloudDone { rid: usize, kind: CloudJobKind },
    JobArriveAtQueue { rid: usize },
    EdgePull { eid: usize },
    EdgeDone { eid: usize, work: EdgeWork },
}

#[derive(Clone, Debug, PartialEq)]
enum CloudJobKind {
    Full,
    Sketch { level: usize },
}

/// Work a single edge completed: (rid, candidate) pairs.
#[derive(Clone, Debug)]
struct EdgeWork {
    items: Vec<(usize, Candidate, usize /* edge tokens */)>,
}

struct EdgeState {
    spec: crate::cluster::DeviceSpec,
    /// interned model name — reassignment and every per-event read are
    /// refcount bumps, never String allocations
    current_model: Arc<str>,
    busy: bool,
}

struct Pending {
    question_id: usize,
    /// question tokens, shared with every prompt/job built for this request
    question_toks: Arc<[u32]>,
    category: String,
    arrival: SimTime,
    predicted_len: usize,
    mode: Mode,
    sketch_level: usize,
    cloud_start: SimTime,
    cloud_done: SimTime,
    /// first time an edge began serving this request; None until then (a
    /// plain 0.0 sentinel would let a later replica pull overwrite a
    /// legitimate t=0 start)
    edge_start: Option<SimTime>,
    cloud_tokens: usize,
    edge_tokens: usize,
    sketch: Arc<[u32]>,
    expected_sketch_len: usize,
    candidates: Vec<Candidate>,
    replicas_out: usize,
    parallelism: usize,
    done: bool,
}

pub struct Engine<'a> {
    pub cfg: EngineCfg,
    pub corpus: Arc<Corpus>,
    pub tok: &'a Tokenizer,
    pub registry: &'a Registry,
    backend: &'a mut dyn TextBackend,
    cluster: Cluster,
    profile: OfflineProfile,
    cost_coeff: f64,
}

impl<'a> Engine<'a> {
    pub fn new(
        cfg: EngineCfg,
        corpus: Arc<Corpus>,
        tok: &'a Tokenizer,
        registry: &'a Registry,
        backend: &'a mut dyn TextBackend,
    ) -> Result<Self, RunError> {
        let cluster = Cluster::testbed(cfg.n_edges);
        let cloud_info = registry
            .get(&cfg.cloud_model)
            .ok_or_else(|| RunError::Backend(format!("unknown model {}", cfg.cloud_model)))?;
        if !cluster.cloud.fits(cloud_info) {
            return Err(RunError::Oom(format!("{} does not fit the cloud node", cfg.cloud_model)));
        }
        let devices: Vec<&crate::cluster::DeviceSpec> =
            std::iter::once(&cluster.cloud).chain(cluster.edges.iter()).collect();
        let model_refs: Vec<&ModelInfo> = registry.models.iter().collect();
        // profile the cloud at its serving batch so Eq. 2 compares against
        // per-sequence latency under load (vLLM continuous batching)
        let profile = OfflineProfile::profile_batched(&devices, &model_refs, 16);
        // cost coefficient vs the strongest edge SLM (conservative default)
        let slms = registry.slms_for(&cfg.cloud_model);
        let cost_coeff = slms
            .iter()
            .filter_map(|s| {
                profile.cost_coefficient(
                    &cluster.cloud.name,
                    &cfg.cloud_model,
                    &cluster.edges.first().map(|e| e.name.clone()).unwrap_or_default(),
                    &s.name,
                )
            })
            .fold(f64::INFINITY, f64::min)
            .min(10.0);
        Ok(Engine { cfg, corpus, tok, registry, backend, cluster, profile, cost_coeff })
    }

    /// SLMs deployable for this scenario, ascending capability.
    fn slms(&self) -> Vec<&ModelInfo> {
        let mut v = self.registry.slms_for(&self.cfg.cloud_model);
        // total_cmp: a degenerate fit (NaN params) must order, not panic
        v.sort_by(|a, b| a.sim_params_b().total_cmp(&b.sim_params_b()));
        v
    }

    fn f_cloud(&self) -> crate::profiler::LatencyFit {
        self.profile
            .f(&self.cluster.cloud.name, &self.cfg.cloud_model)
            .expect("cloud model profiled")
    }

    /// The LLM's response-length perception: reference length x the model's
    /// Table-I bias x noise (the 32B model underestimates — §V-B).
    fn predict_len(&self, qid: usize, rng: &mut Rng) -> usize {
        let q = self.corpus.get(qid).expect("qid");
        let info = self.registry.get(&self.cfg.cloud_model).unwrap();
        let noise = (rng.normal() * 0.08).exp();
        ((q.answer_len() as f64) * self.cfg.sim_token_scale * info.length_pred_bias * noise)
            .round()
            .max(1.0) as usize
    }

    /// Run the workload to completion; returns per-request traces.
    pub fn run(&mut self, workload: &Workload) -> Result<Vec<RequestTrace>, RunError> {
        // Edge-only feasibility: the paper places the *cloud* model on edges.
        if matches!(self.cfg.policy, Policy::EdgeOnly) {
            let info = self.registry.get(&self.cfg.cloud_model).unwrap();
            let fits = self.cluster.edges.first().map(|e| e.fits(info)).unwrap_or(false);
            if !fits {
                return Err(RunError::Oom(format!(
                    "{} does not fit a Jetson edge",
                    self.cfg.cloud_model
                )));
            }
        }

        let mut rng = Rng::new(self.cfg.seed);
        // Interned model names, hoisted out of the event loop: per-arrival
        // and per-sentence GenRequest/Candidate construction clones an
        // Arc<str> (refcount bump) instead of allocating a String.
        let cloud_model: Arc<str> = Arc::from(self.cfg.cloud_model.as_str());
        let slm_names: Vec<Arc<str>> =
            self.slms().iter().map(|m| Arc::from(m.name.as_str())).collect();
        // map a selection outcome back onto its interned name
        let intern = |name: &str| -> Arc<str> {
            slm_names
                .iter()
                .find(|n| ***n == *name)
                .cloned()
                .unwrap_or_else(|| {
                    if *cloud_model == *name {
                        cloud_model.clone()
                    } else {
                        Arc::from(name)
                    }
                })
        };
        let mut edges: Vec<EdgeState> = self
            .cluster
            .edges
            .iter()
            .map(|spec| EdgeState {
                spec: spec.clone(),
                // round-robin initial SLM placement (paper: one model per device)
                current_model: if matches!(self.cfg.policy, Policy::EdgeOnly)
                    || slm_names.is_empty()
                {
                    cloud_model.clone()
                } else {
                    slm_names[0].clone()
                },
                busy: false,
            })
            .collect();
        for (i, e) in edges.iter_mut().enumerate() {
            if !matches!(self.cfg.policy, Policy::EdgeOnly) && !slm_names.is_empty() {
                e.current_model = slm_names[i % slm_names.len()].clone();
            }
        }

        let cloud_info = self.registry.get(&self.cfg.cloud_model).unwrap();
        let cloud_slots = self.cluster.cloud.max_batch(cloud_info, 1000).max(1);
        let f_cloud = self.f_cloud();

        let mut q: EventQueue<Ev> = EventQueue::new();
        let mut pend: Vec<Pending> = Vec::with_capacity(workload.requests.len());
        for r in &workload.requests {
            let qq = self.corpus.get(r.question_id).expect("qid");
            pend.push(Pending {
                question_id: r.question_id,
                question_toks: Arc::from(qq.question.as_slice()),
                category: qq.category.clone(),
                arrival: r.arrival_s,
                predicted_len: 0,
                mode: Mode::CloudFull,
                sketch_level: 0,
                cloud_start: 0.0,
                cloud_done: 0.0,
                edge_start: None,
                cloud_tokens: 0,
                edge_tokens: 0,
                sketch: Vec::new().into(),
                expected_sketch_len: 0,
                candidates: Vec::new(),
                replicas_out: 0,
                parallelism: 0,
                done: false,
            });
            q.schedule(r.arrival_s, Ev::Arrive(r.rid));
        }

        // runtime monitor: EWMA of achieved edge expansion parallelism,
        // fed back into the dynamic scheduler's Eq. 2 estimate
        let mut ewma_parallelism: f64 = 1.0;
        let mut cloud_pending: VecDeque<(usize, CloudJobKind)> = VecDeque::new();
        let mut cloud_inflight: usize = 0;
        let scale = self.cfg.sim_token_scale;
        // PICE_SINGLE_FIFO=1 ablates Algorithm 1 into one FIFO list
        let bounds: Vec<usize> = if std::env::var("PICE_SINGLE_FIFO").as_deref() == Ok("1") {
            vec![]
        } else {
            [40.0, 80.0, 120.0].iter().map(|b| (b * scale) as usize).collect()
        };
        let mut jobq = MultiListQueue::new(bounds, self.cfg.queue_cap);
        let mut enqueue_attempts: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::new();
        let mut traces: Vec<Option<RequestTrace>> = (0..pend.len()).map(|_| None).collect();
        // edge-only/routing: per-edge FIFO of full-answer jobs
        let mut edge_fifo: Vec<VecDeque<usize>> = (0..edges.len()).map(|_| VecDeque::new()).collect();

        while let Some((now, ev)) = q.pop() {
            match ev {
                Ev::Arrive(rid) => {
                    let predicted = self.predict_len(pend[rid].question_id, &mut rng);
                    pend[rid].predicted_len = predicted;
                    match &self.cfg.policy {
                        Policy::CloudOnly => {
                            cloud_pending.push_back((rid, CloudJobKind::Full));
                            q.schedule(now, Ev::CloudAdmit);
                        }
                        Policy::EdgeOnly => {
                            pend[rid].mode = Mode::EdgeFull;
                            let eid = (0..edges.len())
                                .min_by_key(|&i| edge_fifo[i].len())
                                .unwrap_or(0);
                            edge_fifo[eid].push_back(rid);
                            q.schedule(now, Ev::EdgePull { eid });
                        }
                        Policy::Routing { difficulty_threshold } => {
                            // difficulty proxy: predicted length + jitter (an
                            // imperfect router, as in the paper's critique).
                            // The multiplier is clamped at 0 to keep the
                            // proxy in its valid non-negative domain — an
                            // extreme draw still misroutes to the edge
                            // (that inaccuracy is the router's modeled flaw),
                            // but it can no longer go *negative*.
                            let difficulty =
                                predicted as f64 * (1.0 + rng.normal() * 0.25).max(0.0);
                            if difficulty > *difficulty_threshold {
                                cloud_pending.push_back((rid, CloudJobKind::Full));
                                q.schedule(now, Ev::CloudAdmit);
                            } else {
                                pend[rid].mode = Mode::EdgeFull;
                                let eid = (0..edges.len())
                                    .min_by_key(|&i| edge_fifo[i].len())
                                    .unwrap_or(0);
                                edge_fifo[eid].push_back(rid);
                                q.schedule(now, Ev::EdgePull { eid });
                            }
                        }
                        Policy::Pice => {
                            let slms = self.slms();
                            let best_cap =
                                slms.iter().map(|m| m.mmlu).fold(0.0, f64::max);
                            // Eq. 2 backlog: Σ_j c·f(l_j) over queued jobs —
                            // the affine fit is summed per job, so each queued
                            // job carries its own intercept
                            let backlog_s = self.cost_coeff * jobq.backlog_cost(&f_cloud);
                            let inp = SchedInput {
                                predicted_len: predicted,
                                f_cloud,
                                cost_coeff: self.cost_coeff,
                                transfer_s: |n| 0.02 + n as f64 * 5e-7,
                                backlog_s,
                                n_edges: edges.len(),
                                best_slm_capability: best_cap,
                                parallel_hint: ewma_parallelism,
                            };
                            let d = self.cfg.scheduler.decide(&inp);
                            if d.mode == SchedMode::Full && predicted >= self.cfg.scheduler.min_progressive_len {
                                crate::debug!(
                                    "rid={rid} FULL pred={predicted} backlog={backlog_s:.1} hint={ewma_parallelism:.1} e2e_l3={:.1} budget={:.1}",
                                    self.cfg.scheduler.e2e_estimate(&inp, self.cfg.scheduler.levels[3]),
                                    f_cloud.eval(predicted)
                                );
                            }
                            if d.mode == SchedMode::Progressive && !slms.is_empty() {
                                pend[rid].mode = Mode::Progressive;
                                pend[rid].sketch_level = d.level.level;
                                pend[rid].expected_sketch_len = d.expected_sketch_len;
                                cloud_pending
                                    .push_back((rid, CloudJobKind::Sketch { level: d.level.level }));
                            } else {
                                cloud_pending.push_back((rid, CloudJobKind::Full));
                            }
                            q.schedule(now, Ev::CloudAdmit);
                        }
                    }
                }

                Ev::CloudAdmit => {
                    // Drain every job admissible at this timestamp, then issue
                    // all of their generations as ONE backend batch — the
                    // parallel/lockstep backends shard it across workers while
                    // results stay index-aligned with the admission order.
                    let mut admitted: Vec<(usize, CloudJobKind)> = Vec::new();
                    while cloud_inflight + admitted.len() < cloud_slots {
                        let Some(j) = cloud_pending.pop_front() else { break };
                        admitted.push(j);
                    }
                    if admitted.is_empty() {
                        continue;
                    }
                    let real_cap =
                        ((self.cfg.cloud_max_tokens as f64 / scale).round() as usize).max(4);
                    let reqs: Vec<GenRequest> = admitted
                        .iter()
                        .map(|(rid, kind)| {
                            let question = &pend[*rid].question_toks;
                            let (prompt, max_tokens) = match kind {
                                CloudJobKind::Full => {
                                    (Prompts::full_answer(self.tok, question), real_cap)
                                }
                                CloudJobKind::Sketch { .. } => {
                                    (Prompts::sketch(self.tok, question), 60)
                                }
                            };
                            GenRequest {
                                model: cloud_model.clone(),
                                prompt: prompt.into(),
                                sp: SamplingParams {
                                    max_tokens,
                                    seed: self.cfg.seed ^ *rid as u64,
                                    ..Default::default()
                                },
                            }
                        })
                        .collect();
                    let outs = self.backend.generate_batch(&reqs);
                    // every member of this admission batch runs concurrently
                    // with the jobs already in flight AND with each other, so
                    // all are priced at the final concurrent batch size — not
                    // the ascending sizes an in-loop `inflight + 1` would see
                    let b = cloud_inflight + admitted.len();
                    for (k, ((rid, kind), out)) in
                        admitted.into_iter().zip(outs).enumerate()
                    {
                        let out = out.map_err(RunError::Backend)?;
                        pend[rid].cloud_start = now;
                        let prompt_sim = (reqs[k].prompt.len() as f64 * scale) as usize;
                        let dur = match &kind {
                            CloudJobKind::Full => {
                                let n_sim = (out.tokens.len() as f64 * scale) as usize;
                                pend[rid].cloud_tokens = n_sim;
                                // final answer = cloud output minus <eos>
                                let mut ans = out.tokens;
                                if ans.last() == Some(&self.tok.specials.eos) {
                                    ans.pop();
                                }
                                pend[rid].candidates = vec![Candidate {
                                    model: cloud_model.clone(),
                                    tokens: ans,
                                    logps: out.logps,
                                }];
                                self.cluster.cloud.prefill_time_s(cloud_info, prompt_sim, b)
                                    + self.cluster.cloud.gen_time_s(cloud_info, n_sim, b)
                            }
                            CloudJobKind::Sketch { level } => {
                                let mut sk = out.tokens;
                                if sk.last() == Some(&self.tok.specials.eos) {
                                    sk.pop();
                                }
                                // apply the level compression per sentence
                                let lv = self
                                    .cfg
                                    .scheduler
                                    .levels
                                    .iter()
                                    .copied()
                                    .find(|l| l.level == *level)
                                    .unwrap_or(self.cfg.scheduler.levels[1]);
                                let keep = self
                                    .cfg
                                    .sketch_keep_frac_override
                                    .as_ref()
                                    .and_then(|m| m.get(&pend[rid].category).copied());
                                let sents = split_sketch(&sk, self.tok.specials.semicolon);
                                let mut out_sk: Vec<u32> = Vec::new();
                                for (i, s) in sents.iter().enumerate() {
                                    if i > 0 {
                                        out_sk.push(self.tok.specials.semicolon);
                                    }
                                    let lvl = match keep {
                                        Some(kf) => crate::sketch::SketchLevel { level: lv.level, keep_frac: kf },
                                        None => lv,
                                    };
                                    out_sk.extend(compress(s, lvl));
                                }
                                let n_sim = (out_sk.len() as f64 * scale) as usize;
                                pend[rid].cloud_tokens = n_sim;
                                pend[rid].sketch = out_sk.into();
                                self.cluster.cloud.prefill_time_s(cloud_info, prompt_sim, b)
                                    + self.cluster.cloud.gen_time_s(cloud_info, n_sim, b)
                            }
                        };
                        cloud_inflight += 1;
                        q.schedule(now + dur, Ev::CloudDone { rid, kind });
                    }
                }

                Ev::CloudDone { rid, kind } => {
                    cloud_inflight = cloud_inflight.saturating_sub(1);
                    pend[rid].cloud_done = now;
                    q.schedule(now, Ev::CloudAdmit);
                    match kind {
                        CloudJobKind::Full => {
                            self.finalize(rid, now, &mut pend, &mut traces);
                        }
                        CloudJobKind::Sketch { .. } => {
                            let delta = self
                                .cfg
                                .link
                                .transfer_tokens_s((pend[rid].sketch.len() as f64 * scale) as usize);
                            q.schedule(now + delta, Ev::JobArriveAtQueue { rid });
                        }
                    }
                }

                Ev::JobArriveAtQueue { rid } => {
                    let attempts = enqueue_attempts.entry(rid).or_insert(0usize);
                    if jobq.len() >= self.cfg.queue_cap && *attempts < 5 {
                        // queue full: retry shortly instead of degrading
                        // (bounded so latency can't grow unboundedly)
                        *attempts += 1;
                        q.schedule_in(2.0, Ev::JobArriveAtQueue { rid });
                        continue;
                    }
                    let sents: Vec<Arc<[u32]>> =
                        split_sketch(&pend[rid].sketch, self.tok.specials.semicolon)
                            .into_iter()
                            .map(Arc::from)
                            .collect();
                    let replicas = self.cfg.ensemble_k.max(1);
                    pend[rid].replicas_out = replicas;
                    let job = Job {
                        rid,
                        expected_len: pend[rid].predicted_len,
                        sentences: sents,
                        full_sketch: pend[rid].sketch.clone(),
                        question: pend[rid].question_toks.clone(),
                        enqueued_at: now,
                        replicas_left: replicas,
                    };
                    if !jobq.push(job) {
                        // queue full: fall back — answer is the sketch itself
                        // (degenerate; counted against PICE's quality)
                        pend[rid].candidates = vec![Candidate {
                            model: cloud_model.clone(),
                            tokens: pend[rid].sketch.to_vec(),
                            logps: vec![-1.0; pend[rid].sketch.len()],
                        }];
                        self.finalize(rid, now, &mut pend, &mut traces);
                        continue;
                    }
                    for eid in 0..edges.len() {
                        if !edges[eid].busy {
                            q.schedule(now, Ev::EdgePull { eid });
                        }
                    }
                }

                Ev::EdgePull { eid } => {
                    if edges[eid].busy {
                        continue;
                    }
                    // Edge-only / routed-easy full answers first.
                    if let Some(rid) = edge_fifo[eid].pop_front() {
                        edges[eid].busy = true;
                        pend[rid].edge_start.get_or_insert(now);
                        let model_name = edges[eid].current_model.clone();
                        let info = self.registry.get(&model_name).unwrap();
                        let prompt = Prompts::full_answer(self.tok, &pend[rid].question_toks);
                        let real_cap =
                            ((self.cfg.cloud_max_tokens as f64 / scale).round() as usize).max(4);
                        let out = self
                            .backend
                            .generate(
                                &model_name,
                                &prompt,
                                &SamplingParams {
                                    max_tokens: real_cap,
                                    seed: self.cfg.seed ^ (rid as u64) << 1,
                                    ..Default::default()
                                },
                            )
                            .map_err(RunError::Backend)?;
                        let mut ans = out.tokens;
                        if ans.last() == Some(&self.tok.specials.eos) {
                            ans.pop();
                        }
                        let n_sim = (ans.len() as f64 * scale) as usize;
                        let dur = edges[eid]
                            .spec
                            .prefill_time_s(info, (prompt.len() as f64 * scale) as usize, 1)
                            + edges[eid].spec.gen_time_s(info, n_sim, 1);
                        let work = EdgeWork {
                            items: vec![(
                                rid,
                                Candidate { model: model_name, tokens: ans, logps: out.logps },
                                n_sim,
                            )],
                        };
                        q.schedule(now + dur, Ev::EdgeDone { eid, work });
                        continue;
                    }
                    if jobq.is_empty() {
                        continue;
                    }
                    // Algorithm 1: pull a batch from the longest list.
                    let info0 = self.registry.get(&edges[eid].current_model).unwrap();
                    let cap = edges[eid].spec.max_batch(info0, 600).clamp(1, 4);
                    let mut batch = jobq.pull_batch(cap);
                    if batch.is_empty() {
                        continue;
                    }
                    edges[eid].busy = true;
                    // Ensemble replication: each queue entry carries the number
                    // of pending candidate executions (replicas_left). This pull
                    // runs ONE execution per job; surplus replicas are re-queued
                    // only if *idle* edges can absorb them (never delaying the
                    // primary expansion), and discarded otherwise.
                    let idle_others: Vec<usize> =
                        (0..edges.len()).filter(|&e2| e2 != eid && !edges[e2].busy).collect();
                    let mut spare = idle_others.len();
                    for job in batch.iter_mut() {
                        let surplus = job.replicas_left.saturating_sub(1);
                        let extra = surplus.min(spare);
                        let mut discarded = surplus - extra;
                        if extra > 0 {
                            let mut rep = job.clone();
                            rep.replicas_left = extra;
                            // the replica enters the queue NOW — keeping the
                            // original enqueue time would misattribute the
                            // primary's queue delay to the replica
                            rep.enqueued_at = now;
                            if jobq.push(rep) {
                                spare -= extra;
                                for &e2 in &idle_others {
                                    q.schedule(now, Ev::EdgePull { eid: e2 });
                                }
                            } else {
                                discarded += extra;
                            }
                        }
                        pend[job.rid].replicas_out =
                            pend[job.rid].replicas_out.saturating_sub(discarded);
                        job.replicas_left = 1;
                        pend[job.rid].edge_start.get_or_insert(now);
                    }

                    // Algorithm 2 on the first job's budget (batch-shared model)
                    let slm_refs = self.slms();
                    let j0 = &batch[0];
                    let budget = (f_cloud.eval(j0.expected_len)
                        - f_cloud.eval((j0.full_sketch.len() as f64 * scale) as usize))
                    .max(0.05);
                    let sel = if slm_refs.is_empty() {
                        super::selection::SelectionOutcome {
                            model: edges[eid].current_model.to_string(),
                            switched: false,
                            switch_cost_s: 0.0,
                        }
                    } else {
                        select_model(
                            &edges[eid].spec,
                            &slm_refs,
                            &edges[eid].current_model,
                            j0.expected_len,
                            ((j0.full_sketch.len() + j0.question.len()) as f64 * scale) as usize,
                            budget,
                            jobq.len(),
                            self.cfg.queue_cap,
                        )
                    };
                    let sel_model = intern(&sel.model);
                    edges[eid].current_model = sel_model.clone();
                    let info = self.registry.get(&sel.model).unwrap();

                    // Execution optimizer: batch-level lane planning. All
                    // jobs' lanes run concurrently on this device; the
                    // binary-tree merge balances per-job parallelism against
                    // global token-rate contention + prompt overhead (Fig. 7a).
                    let info_cost = EdgeCostModel {
                        token_s: edges[eid].spec.token_latency_s(info, 1),
                        batch_slowdown: crate::cluster::BATCH_TOKEN_SLOWDOWN,
                        prompt_tokens: batch
                            .iter()
                            .map(|j| ((j.question.len() + j.full_sketch.len() + 4) as f64 * scale) as usize)
                            .max()
                            .unwrap_or(0),
                        prefill_speedup: 8.0,
                    };
                    let est_lens: Vec<Vec<usize>> = batch
                        .iter()
                        .map(|job| {
                            job.sentences
                                .iter()
                                .map(|s| (((s.len() as f64 * 2.2).ceil() + 2.0) * scale) as usize)
                                .collect()
                        })
                        .collect();
                    let est_refs: Vec<&[usize]> = est_lens.iter().map(|v| v.as_slice()).collect();
                    let p_mem = edges[eid]
                        .spec
                        .max_batch(info, info_cost.prompt_tokens + (40.0 * scale) as usize)
                        .max(1);
                    let (plans, _) = plan_batch(&est_refs, p_mem, &info_cost);

                    // Generate the real expansions — every sentence of every
                    // job in the pulled batch goes out as ONE backend batch
                    // (sharded across workers by ParallelBackend), then charge
                    // simulated time using the chosen plans over the *actual*
                    // lengths. Flattened order is job-major, sentence-minor,
                    // so results realign positionally.
                    let reqs: Vec<GenRequest> = batch
                        .iter()
                        .flat_map(|job| {
                            job.sentences.iter().enumerate().map(|(si, sent)| GenRequest {
                                model: sel_model.clone(),
                                prompt: Prompts::expand(
                                    self.tok,
                                    &job.question,
                                    &job.full_sketch,
                                    sent,
                                )
                                .into(),
                                sp: SamplingParams {
                                    max_tokens: 24,
                                    stop_token: Some(self.tok.specials.period),
                                    seed: self.cfg.seed ^ ((job.rid as u64) << 8) ^ si as u64,
                                    ..Default::default()
                                },
                            })
                        })
                        .collect();
                    let mut outs = self.backend.generate_batch(&reqs).into_iter();
                    let mut items = Vec::new();
                    let mut real_lens_per_job: Vec<Vec<usize>> = Vec::with_capacity(batch.len());
                    for job in &batch {
                        let mut expansion: Vec<u32> = Vec::new();
                        let mut logps: Vec<f64> = Vec::new();
                        let mut real_lens = vec![0usize; job.sentences.len()];
                        for si in 0..job.sentences.len() {
                            let out = outs
                                .next()
                                .expect("batch result per sentence")
                                .map_err(RunError::Backend)?;
                            let mut toks = out.tokens;
                            if toks.last() == Some(&self.tok.specials.eos) {
                                toks.pop();
                            }
                            real_lens[si] = (toks.len() as f64 * scale) as usize;
                            expansion.extend_from_slice(&toks);
                            logps.extend_from_slice(&out.logps);
                        }
                        let n_edge_tokens: usize = real_lens.iter().sum();
                        items.push((
                            job.rid,
                            Candidate { model: sel_model.clone(), tokens: expansion, logps },
                            n_edge_tokens,
                        ));
                        real_lens_per_job.push(real_lens);
                    }
                    let mean_lanes = plans.iter().map(Vec::len).sum::<usize>() as f64
                        / plans.len().max(1) as f64;
                    ewma_parallelism = 0.8 * ewma_parallelism + 0.2 * mean_lanes;
                    for (job, plan) in batch.iter().zip(&plans) {
                        pend[job.rid].parallelism = pend[job.rid].parallelism.max(plan.len());
                    }
                    let real_refs: Vec<&[usize]> =
                        real_lens_per_job.iter().map(|v| v.as_slice()).collect();
                    let wall = batch_wall(&plans, &real_refs, &info_cost);
                    let total_dur = sel.switch_cost_s + wall;
                    crate::debug!(
                        "edge{eid} t={now:.1} batch={} model={} lanes={:?} switch={:.1} wall={wall:.1}",
                        batch.len(), sel.model,
                        plans.iter().map(Vec::len).collect::<Vec<_>>(), sel.switch_cost_s
                    );
                    q.schedule(now + total_dur, Ev::EdgeDone { eid, work: EdgeWork { items } });
                }

                Ev::EdgeDone { eid, work } => {
                    edges[eid].busy = false;
                    for (rid, cand, edge_tokens) in work.items {
                        pend[rid].edge_tokens += edge_tokens;
                        pend[rid].candidates.push(cand);
                        pend[rid].replicas_out = pend[rid].replicas_out.saturating_sub(1);
                        if pend[rid].replicas_out == 0 && !pend[rid].done {
                            self.finalize(rid, now, &mut pend, &mut traces);
                        }
                    }
                    q.schedule(now, Ev::EdgePull { eid });
                }
            }
        }

        Ok(traces.into_iter().flatten().collect())
    }

    /// Ensemble-select and close out a request.
    fn finalize(
        &self,
        rid: usize,
        now: SimTime,
        pend: &mut [Pending],
        traces: &mut [Option<RequestTrace>],
    ) {
        let p = &mut pend[rid];
        p.done = true;
        let expected_real =
            ((p.predicted_len as f64 / self.cfg.sim_token_scale).round() as usize).max(1);
        let (winner, confidence) = if p.candidates.len() > 1 {
            let (i, c) = ensemble_select(
                &p.candidates,
                &p.sketch,
                expected_real,
                self.cfg.confidence,
            )
            .unwrap_or((0, 0.0));
            (i, c)
        } else {
            (0, 1.0)
        };
        let cand = p.candidates.get(winner).cloned().unwrap_or(Candidate {
            model: Arc::from(""),
            tokens: Vec::new(),
            logps: Vec::new(),
        });
        traces[rid] = Some(RequestTrace {
            rid,
            question_id: p.question_id,
            category: p.category.clone(),
            mode: p.mode,
            sketch_level: p.sketch_level,
            predicted_len: p.predicted_len,
            cloud_tokens: p.cloud_tokens,
            edge_tokens: p.edge_tokens,
            answer: cand.tokens,
            arrival: p.arrival,
            cloud_start: p.cloud_start,
            cloud_done: p.cloud_done,
            edge_start: p.edge_start.unwrap_or(0.0),
            done: now,
            winner_model: cand.model.to_string(),
            confidence,
            parallelism: p.parallelism,
        });
    }
}
