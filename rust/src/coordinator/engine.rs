//! The PICE serving engine: a discrete-event simulation of the cloud-edge
//! testbed in which *text is generated for real* (via the pluggable
//! [`TextBackend`]) while *time advances virtually* per the calibrated
//! device/network models (DESIGN.md §2).
//!
//! One engine runs one scenario (cloud model, N edges, policy) and produces
//! per-request traces. The baselines (cloud-only / edge-only / routing)
//! reuse the same event loop with different admission policies — exactly
//! how the paper runs its comparisons on a fixed testbed.
//!
//! ## Step-driven core
//!
//! The engine is **re-entrant**: requests enter via [`Engine::submit`] while
//! earlier ones are still in flight, and the event queue drains under caller
//! control ([`Engine::pump_one`] / [`Engine::pump_until`] /
//! [`Engine::pump_all`]). [`Engine::run`] is the thin closed-loop driver
//! (submit every workload arrival, drain to quiescence) and is bit-identical
//! to the pre-refactor monolithic loop. Submissions injected mid-run order
//! ahead of same-instant internal events ([`crate::simclock::FIRST_CLASS`]),
//! so open-loop driving through [`crate::serve::PiceService`] reproduces the
//! closed-loop traces byte for byte.
//!
//! With streaming enabled ([`Engine::enable_events`]) the core additionally
//! emits per-request [`ResponseEvent`]s — `Admitted`, `SketchReady`,
//! `ExpansionChunk`, `Final` — at the simulated instant each becomes client
//! visible; the sink is off by default so batch runs pay nothing for it.
//!
//! ## Environment dynamics + failover
//!
//! With a non-default [`crate::dynamics::DynamicsSpec`] in [`EngineCfg`],
//! the world moves while the engine runs: the WAN link is re-evaluated per
//! event (Eq. 2 consumes the *current* transfer model, sketch transfers pay
//! the *current* link), and edge fault events (crash / recover / slowdown)
//! are scheduled up-front from the spec's deterministic timeline. A crash
//! bumps the edge's epoch so its in-flight completion events are discarded
//! as stale, and every lost slot re-enters dispatch (`enqueued_at` reset,
//! sketch context preserved) toward surviving edges — or parks until a
//! scheduled recover, or falls back to the cloud when no help is coming.
//! Invariant: **no request is ever silently lost** — every submission still
//! ends in exactly one terminal serve event. The static default schedules
//! no fault events, tracks no in-flight state and pins the legacy transfer
//! constants, so it stays bit-identical to the pre-dynamics engine.
//!
//! ## Cost model
//!
//! Every Eq. 2 quantity the engine consumes — cloud latency line, cost
//! coefficient, transfer correction, backlog, achieved parallelism — comes
//! from ONE [`crate::costmodel::CostModel`] instance owned by the core
//! (`cfg.calib` picks static vs calibrated). The engine feeds it
//! observations from its own event stream: cloud service times at
//! admission, edge pull walls, sketch transfer times. Because the instance
//! is per-engine and fed only from that engine's deterministic events,
//! calibrated traces stay bit-identical across sweep thread counts,
//! open/closed-loop driving, and fleet shard layouts — and the static
//! default reproduces the pre-costmodel arithmetic bit for bit.

use std::collections::VecDeque;
use std::sync::Arc;

use super::backend::{GenRequest, TextBackend};
use super::dispatch::{Job, MultiListQueue, SalvagedSlot};
use super::scheduler::{CloudScheduler, Mode as SchedMode, SchedInput};
use super::selection::select_model;
use crate::cluster::Cluster;
use crate::corpus::workload::Workload;
use crate::corpus::Corpus;
use crate::costmodel::{self, CalibCfg, CalibState, CalibSummary, CostModel};
use crate::dynamics::{DynamicsSpec, EdgeFault};
use crate::ensemble::{select as ensemble_select, Candidate, ConfidenceWeights};
use crate::metrics::{Mode, RequestTrace};
use crate::models::{ModelInfo, Registry};
use crate::network::{Link, TransferModel};
use crate::parallel::{batch_wall, plan_batch, EdgeCostModel};
use crate::profiler::OfflineProfile;
use crate::runtime::SamplingParams;
use crate::serve::{ResponseEvent, ResponseEventKind};
use crate::simclock::{EventQueue, FIRST_CLASS, SimTime};
use crate::sketch::{compress, split_sketch, Prompts};
use crate::telemetry::{MetricsRegistry, Span, SpanKind, Telemetry};
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

/// Serving policy: PICE or one of the paper's baselines (§V-A).
#[derive(Clone, Debug)]
pub enum Policy {
    Pice,
    CloudOnly,
    EdgeOnly,
    /// Hybrid-LLM-style difficulty router: queries with predicted length
    /// above the threshold go to the cloud, the rest to edge SLMs.
    Routing { difficulty_threshold: f64 },
}

#[derive(Clone, Debug)]
pub struct EngineCfg {
    pub cloud_model: String,
    pub n_edges: usize,
    pub link: Link,
    pub policy: Policy,
    /// max ensemble replicas per expansion job (1 = ensemble off)
    pub ensemble_k: usize,
    /// job-queue capacity (Fig. 13)
    pub queue_cap: usize,
    /// cap on cloud full-answer length, in SIM tokens (Fig. 3's knob)
    pub cloud_max_tokens: usize,
    /// simulated tokens per real picoLM token. The picoLM corpus answers are
    /// ~50 real tokens; the paper's serving regime is ~500-token answers, so
    /// scale 10 puts the simulated testbed in the paper's operating point
    /// (cloud batch ~20 saturating at ~1.5x-batch RPM) while text stays real.
    pub sim_token_scale: f64,
    pub seed: u64,
    pub scheduler: CloudScheduler,
    pub confidence: ConfidenceWeights,
    /// apply the RLAIF-fine-tuned sketch policy (per-category keep-fraction
    /// learned by `finetune`); None = base sketching
    pub sketch_keep_frac_override: Option<std::collections::BTreeMap<String, f64>>,
    /// environment dynamics: time-varying link + edge churn/failure
    /// injection. Default = static world, zero-cost when off.
    pub dynamics: DynamicsSpec,
    /// cost-model calibration: off (the static offline fit — bit-identical
    /// default), on (online re-fit from this run's event stream), or warm
    /// (on + seeded from persisted state). See [`crate::costmodel`].
    pub calib: CalibCfg,
    /// tail tolerance: hedged expansion dispatch + backoff retries.
    /// Default = off, bit-identical to an engine without the machinery.
    pub tail: TailCfg,
}

/// Tail-tolerance knobs: the hedged-dispatch watchdog and the blackout
/// backoff-retry policy. All timers are pure sim time, so hedge decisions
/// stay bit-identical across sweep threads and open vs closed loop.
#[derive(Clone, Debug)]
pub struct TailCfg {
    /// Hedge quantile `q` in (0,1): a dispatched expansion pull arms a
    /// watchdog at `slot_timeout_mult x (-ln(1-q)) x (Eq. 2 edge estimate)`
    /// — the q-th quantile of an exponential service tail with the cost
    /// model's estimate as its mean. On expiry the pull is hedged: slots
    /// already past their estimated completion are salvaged (the original
    /// dispatch won them), the straggler's remaining in-flight work is
    /// discarded via the per-edge epoch bump, and the unfinished slots are
    /// speculatively re-dispatched to another up edge or the cloud.
    /// `None` = hedging off (the default).
    pub hedge_quantile: Option<f64>,
    /// multiplier on the quantile-scaled timeout (tuning headroom)
    pub slot_timeout_mult: f64,
    /// max watchdog firings per request — bounds duplicated work
    pub hedge_budget: usize,
    /// base delay of the capped exponential backoff a transiently-displaced
    /// job waits through when every edge is down but recovers are pending
    pub backoff_base_s: f64,
    /// retry attempts before the backoff escalates to a cloud rescue
    /// (bounding how long a request can wait out a blackout)
    pub backoff_max_retries: usize,
}

impl Default for TailCfg {
    fn default() -> Self {
        TailCfg {
            hedge_quantile: None,
            slot_timeout_mult: 1.0,
            hedge_budget: 2,
            backoff_base_s: 2.0,
            backoff_max_retries: 3,
        }
    }
}

impl TailCfg {
    /// Hedging (and with it the whole tail-tolerance layer) enabled?
    pub fn on(&self) -> bool {
        self.hedge_quantile.is_some()
    }

    /// Strict validation, mirroring [`CalibCfg::validate`].
    pub fn validate(&self) -> Result<(), String> {
        if let Some(q) = self.hedge_quantile {
            if !(q.is_finite() && q > 0.0 && q < 1.0) {
                return Err(format!("hedge quantile must be in (0, 1), got {q}"));
            }
        }
        if !(self.slot_timeout_mult.is_finite() && self.slot_timeout_mult > 0.0) {
            return Err(format!(
                "slot-timeout-mult must be positive and finite, got {}",
                self.slot_timeout_mult
            ));
        }
        if self.hedge_budget == 0 {
            return Err("hedge budget must be >= 1".into());
        }
        if !(self.backoff_base_s.is_finite() && self.backoff_base_s > 0.0) {
            return Err(format!("backoff base must be positive, got {}", self.backoff_base_s));
        }
        if self.backoff_max_retries == 0 {
            return Err("backoff retries must be >= 1".into());
        }
        Ok(())
    }
}

impl EngineCfg {
    pub fn pice(cloud_model: &str) -> Self {
        let mut scheduler = CloudScheduler::default();
        scheduler.min_progressive_len = 250; // sim tokens (25 real words)
        EngineCfg {
            cloud_model: cloud_model.to_string(),
            n_edges: 4,
            link: Link::default_wan(),
            policy: Policy::Pice,
            ensemble_k: 3,
            queue_cap: 8,
            cloud_max_tokens: 1000,
            sim_token_scale: 12.0,
            seed: 17,
            scheduler,
            confidence: ConfidenceWeights::default(),
            sketch_keep_frac_override: None,
            dynamics: DynamicsSpec::default(),
            calib: CalibCfg::default(),
            tail: TailCfg::default(),
        }
    }

    pub fn with_policy(mut self, p: Policy) -> Self {
        self.policy = p;
        self
    }

    /// The persistence key this config's calibration is stored under (see
    /// [`crate::costmodel::calib_key`]): cloud model + edge count + policy
    /// shape, so persisted state never warms a differently-shaped engine.
    pub fn calib_key(&self) -> String {
        let policy = match self.policy {
            Policy::Pice => "pice",
            Policy::CloudOnly => "cloud-only",
            Policy::EdgeOnly => "edge-only",
            Policy::Routing { .. } => "routing",
        };
        costmodel::calib_key(&self.cloud_model, self.n_edges, policy, self.scheduler.static_mode)
    }

    pub fn with_dynamics(mut self, d: DynamicsSpec) -> Self {
        self.dynamics = d;
        self
    }
}

#[derive(Debug)]
pub enum RunError {
    /// the placement is infeasible (Table III's "OOM" cells)
    Oom(String),
    Backend(String),
}

impl std::fmt::Display for RunError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RunError::Oom(m) => write!(f, "OOM: {m}"),
            RunError::Backend(m) => write!(f, "backend: {m}"),
        }
    }
}

// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
enum Ev {
    Arrive(usize),
    CloudAdmit,
    CloudDone { rid: usize, kind: CloudJobKind },
    JobArriveAtQueue { rid: usize },
    EdgePull { eid: usize },
    /// `epoch` is the launching edge incarnation: a crash bumps the edge's
    /// epoch, so completions of work that died with the node arrive stale
    /// and are discarded (their slots were already re-dispatched).
    EdgeDone { eid: usize, epoch: u64, work: EdgeWork },
    /// environment-dynamics fault event (scheduled up-front from the
    /// deterministic [`crate::dynamics::FaultSpec`] timeline)
    Fault { eid: usize, fault: EdgeFault },
    /// hedged-dispatch watchdog: armed when an expansion pull's realized
    /// duration exceeds the tail-quantile of its Eq. 2 estimate. Carries
    /// the launching epoch — a crash before expiry makes it lapse stale.
    HedgeFire { eid: usize, epoch: u64 },
    /// capped exponential backoff retry of a job displaced by a transient
    /// all-edges-down window; the job itself waits in `Core::backoff_jobs`
    BackoffRetry { rid: usize, attempt: usize },
}

#[derive(Clone, Debug, PartialEq)]
enum CloudJobKind {
    Full,
    Sketch { level: usize },
}

/// Work a single edge completed: (rid, candidate) pairs.
#[derive(Clone, Debug)]
struct EdgeWork {
    items: Vec<(usize, Candidate, usize /* edge tokens */)>,
}

/// An in-flight expansion job plus the per-slot outputs this pull
/// generated, each with its estimated completion instant (the pull's wall
/// time apportioned by cumulative sim-token share). A crash salvages every
/// slot whose estimate is already past — those expansions survived the
/// node — and re-queues only the rest.
#[derive(Clone, Debug)]
struct InflightJob {
    job: Job,
    /// freshly generated slots: (sentence index, estimated done, output)
    outs: Vec<(usize, SimTime, SalvagedSlot)>,
}

/// What an edge is executing right now — retained (only when fault
/// injection is on) so a crash can re-dispatch the lost work.
#[derive(Clone, Debug, Default)]
enum EdgeInflight {
    #[default]
    Idle,
    /// expansion jobs of the current pull (replicas collapsed to 1)
    Expand(Vec<InflightJob>),
    /// full-answer request (edge-only / routed-easy)
    Full(usize),
}

struct EdgeState {
    spec: crate::cluster::DeviceSpec,
    /// interned model name — reassignment and every per-event read are
    /// refcount bumps, never String allocations
    current_model: Arc<str>,
    busy: bool,
    /// false while crashed (dynamics) — a down edge pulls nothing
    up: bool,
    /// compute-duration multiplier (>1 = straggler; dynamics slowdown)
    speed_mult: f64,
    /// incarnation counter; bumped on crash to invalidate in-flight work
    epoch: u64,
    /// current work, tracked only when fault injection is on
    inflight: EdgeInflight,
}

struct Pending {
    question_id: usize,
    /// question tokens, shared with every prompt/job built for this request
    question_toks: Arc<[u32]>,
    category: String,
    arrival: SimTime,
    predicted_len: usize,
    mode: Mode,
    sketch_level: usize,
    /// sim time this request last entered the cloud queue — start of the
    /// telemetry `QueueWait` span closed at admission
    cloud_enq: SimTime,
    cloud_start: SimTime,
    cloud_done: SimTime,
    /// first time an edge began serving this request; None until then (a
    /// plain 0.0 sentinel would let a later replica pull overwrite a
    /// legitimate t=0 start)
    edge_start: Option<SimTime>,
    /// sim time the sketch finished on the cloud (progressive only) —
    /// the client-visible time-to-first-sketch instant
    sketch_ready: Option<SimTime>,
    /// sim time the first edge expansion chunk was delivered
    first_expansion: Option<SimTime>,
    cloud_tokens: usize,
    edge_tokens: usize,
    sketch: Arc<[u32]>,
    expected_sketch_len: usize,
    candidates: Vec<Candidate>,
    /// decision-time transfer model (calibrating models only — compared
    /// against the observed sketch transfer to learn WAN drift)
    transfer_pred: Option<TransferModel>,
    replicas_out: usize,
    parallelism: usize,
    /// failure-triggered re-dispatches (dynamics failover counter)
    failovers: usize,
    /// expansion sentence-slots re-queued by those failovers
    retried_slots: usize,
    /// sentence-slots whose completed expansion was salvaged across a
    /// crash instead of re-queued
    salvaged_slots: usize,
    /// a cloud-fallback regeneration is already pending for this request
    /// (dedups the rescue when a primary job and its ensemble replicas are
    /// drained to the cloud in one blackout sweep)
    cloud_rescue: bool,
    /// watchdog firings that hedged this request's pulls (tail tolerance;
    /// capped by `TailCfg::hedge_budget`)
    hedges: usize,
    /// expansion sentence-slots speculatively re-dispatched by those hedges
    hedged_slots: usize,
    /// "queue full: retry shortly" deferrals this request ate before its
    /// expansion job entered the dispatch queue (bounded — see
    /// `ev_job_arrive`; surfaces queue-pressure starvation in traces)
    requeue_retries: usize,
    done: bool,
}

/// The step-driven loop state: everything the monolithic `run()` used to
/// keep in locals, lifted so the event queue can drain incrementally while
/// new requests keep arriving.
struct Core {
    rng: Rng,
    q: EventQueue<Ev>,
    pend: Vec<Pending>,
    traces: Vec<Option<RequestTrace>>,
    /// interned cloud-model name (refcount bumps instead of String allocs)
    cloud_model: Arc<str>,
    /// interned SLM names, ascending capability
    slm_names: Vec<Arc<str>>,
    edges: Vec<EdgeState>,
    /// edge-only/routing: per-edge FIFO of full-answer jobs
    edge_fifo: Vec<VecDeque<usize>>,
    cloud_pending: VecDeque<(usize, CloudJobKind)>,
    cloud_inflight: usize,
    cloud_slots: usize,
    /// THE world model: every Eq. 2 quantity (cloud latency line, cost
    /// coefficient, transfer, backlog, achieved parallelism) comes from
    /// here — [`crate::costmodel::StaticFit`] by default (bit-identical to
    /// the pre-costmodel inline arithmetic), or the online-calibrated model
    /// when `cfg.calib.mode` asks for it. Observations are fed only from
    /// this core's own event handlers, keeping traces deterministic.
    cost_model: Box<dyn CostModel>,
    /// `backlog_estimate_s` memo keyed on `events_processed`: the admission
    /// estimate is pure between events, so router polls and repeated
    /// deadline checks re-run Eq. 2 only when the loop actually moved
    backlog_memo: Option<(u64, SimTime)>,
    jobq: MultiListQueue,
    /// edge-only feasibility verdict, precomputed (the paper places the
    /// *cloud* model on edges); Some(msg) = every submit/run fails with OOM
    edge_oom: Option<String>,
    /// streaming sink: Some = emit client-visible [`ResponseEvent`]s
    /// (enabled by [`Engine::enable_events`]); None = zero-cost
    events: Option<Vec<ResponseEvent>>,
    /// telemetry sink: Some = stamp request spans + registry metrics from
    /// the event stream (enabled by [`Engine::enable_telemetry`]); None
    /// (default) = zero-cost, bit-identical to an engine without the
    /// subsystem. Stamps reuse already-computed sim-time values only — no
    /// extra scheduled events, no RNG draws.
    telem: Option<Box<Telemetry>>,
    /// fault injection configured (gates the in-flight tracking so the
    /// static world stays allocation-free on the pull path)
    faults_on: bool,
    /// tail tolerance configured (`cfg.tail.on()`): arms hedge watchdogs
    /// and routes transient displacements through backoff retries
    tail_on: bool,
    /// per-pull in-flight slot tracking needed — by crash salvage
    /// (`faults_on`) or by the hedge watchdog (`tail_on`)
    track_inflight: bool,
    /// jobs waiting out a backoff delay (tail tolerance; the paired
    /// `Ev::BackoffRetry` re-attempts dispatch). A plain Vec: entries are
    /// matched by rid in schedule order, deterministically.
    backoff_jobs: Vec<Job>,
    /// requests closed on THIS engine without a terminal event because the
    /// fleet re-dispatched them to a healthy shard (see `evict_displaced`)
    evicted: usize,
    /// edges currently alive
    up_edges: usize,
    /// Recover events still unprocessed in the timeline — the "is help
    /// coming" signal deciding park-vs-cloud-fallback when all edges die
    pending_recovers: usize,
    /// expansion jobs waiting out an all-edges-down window
    parked_jobs: Vec<Job>,
    /// full-answer requests waiting out an all-edges-down window
    parked_full: VecDeque<usize>,
    /// monotone count of processed events — advances exactly when the loop
    /// makes progress, so derived state (the fleet router's backlog memo)
    /// can be invalidated without polling queue internals
    events_processed: u64,
    /// requests finalized (terminal event emitted)
    completed: usize,
    /// resumable bandwidth-walk state: the event clock is monotone, so the
    /// walk advances incrementally instead of replaying from t=0 per event
    walk_cache: crate::dynamics::link::WalkCache,
    /// true until anything is submitted or pumped — lets [`Engine::run`]
    /// skip rebuilding a core that is still exactly what `reset()` would
    /// produce (a fault timeline pre-schedules events, so "queue empty" is
    /// no longer a usable pristine test)
    virgin: bool,
}

impl Core {
    /// Map a selection outcome back onto its interned name.
    fn intern(&self, name: &str) -> Arc<str> {
        self.slm_names.iter().find(|n| ***n == *name).cloned().unwrap_or_else(|| {
            if *self.cloud_model == *name {
                self.cloud_model.clone()
            } else {
                Arc::from(name)
            }
        })
    }
}

fn make_core(
    cfg: &EngineCfg,
    registry: &Registry,
    cluster: &Cluster,
    profile: &OfflineProfile,
    cost_coeff: f64,
) -> Core {
    // Interned model names, hoisted out of the event loop: per-arrival and
    // per-sentence GenRequest/Candidate construction clones an Arc<str>
    // (refcount bump) instead of allocating a String.
    let cloud_model: Arc<str> = Arc::from(cfg.cloud_model.as_str());
    let mut slms = registry.slms_for(&cfg.cloud_model);
    // total_cmp: a degenerate fit (NaN params) must order, not panic
    slms.sort_by(|a, b| a.sim_params_b().total_cmp(&b.sim_params_b()));
    let slm_names: Vec<Arc<str>> = slms.iter().map(|m| Arc::from(m.name.as_str())).collect();
    let edges: Vec<EdgeState> = cluster
        .edges
        .iter()
        .enumerate()
        .map(|(i, spec)| EdgeState {
            spec: spec.clone(),
            // round-robin initial SLM placement (paper: one model per device)
            current_model: if matches!(cfg.policy, Policy::EdgeOnly) || slm_names.is_empty() {
                cloud_model.clone()
            } else {
                slm_names[i % slm_names.len()].clone()
            },
            busy: false,
            up: true,
            speed_mult: 1.0,
            epoch: 0,
            inflight: EdgeInflight::Idle,
        })
        .collect();

    let cloud_info = registry.get(&cfg.cloud_model).expect("cloud model in registry");
    let cloud_slots = cluster.cloud.max_batch(cloud_info, 1000).max(1);
    let f_cloud = profile.f(&cluster.cloud.name, &cfg.cloud_model).expect("cloud model profiled");

    let scale = cfg.sim_token_scale;
    // PICE_SINGLE_FIFO=1 ablates Algorithm 1 into one FIFO list
    let bounds: Vec<usize> = if std::env::var("PICE_SINGLE_FIFO").as_deref() == Ok("1") {
        vec![]
    } else {
        [40.0, 80.0, 120.0].iter().map(|b| (b * scale) as usize).collect()
    };
    let edge_oom = if matches!(cfg.policy, Policy::EdgeOnly) {
        let fits = cluster.edges.first().map(|e| e.fits(cloud_info)).unwrap_or(false);
        (!fits).then(|| format!("{} does not fit a Jetson edge", cfg.cloud_model))
    } else {
        None
    };
    let n_edges = edges.len();
    // Environment dynamics: the WHOLE fault timeline is generated here,
    // pure in (n_edges, dynamics.seed), and scheduled up-front — open-loop
    // submission then sees the exact internal event set the closed loop
    // does, and sweeps replay the identical environment at any thread
    // count. The static default generates nothing.
    let fault_timeline = cfg.dynamics.faults.timeline(n_edges, cfg.dynamics.seed);
    let pending_recovers = crate::dynamics::FaultSpec::recover_count(&fault_timeline);
    let mut q = EventQueue::new();
    for ev in &fault_timeline {
        q.schedule(ev.t, Ev::Fault { eid: ev.eid, fault: ev.fault });
    }
    Core {
        rng: Rng::new(cfg.seed),
        q,
        pend: Vec::new(),
        traces: Vec::new(),
        cloud_model,
        slm_names,
        edges,
        edge_fifo: (0..n_edges).map(|_| VecDeque::new()).collect(),
        cloud_pending: VecDeque::new(),
        cloud_inflight: 0,
        cloud_slots,
        cost_model: costmodel::build(&cfg.calib, f_cloud, cost_coeff),
        backlog_memo: None,
        jobq: MultiListQueue::new(bounds, cfg.queue_cap),
        edge_oom,
        events: None,
        telem: None,
        faults_on: cfg.dynamics.faults.any(),
        tail_on: cfg.tail.on(),
        track_inflight: cfg.dynamics.faults.any() || cfg.tail.on(),
        backoff_jobs: Vec::new(),
        evicted: 0,
        up_edges: n_edges,
        pending_recovers,
        parked_jobs: Vec::new(),
        parked_full: VecDeque::new(),
        events_processed: 0,
        completed: 0,
        walk_cache: None,
        virgin: true,
    }
}

/// How an engine holds its backend: borrowed (the original single-engine
/// contract — callers keep ownership) or boxed (a [`crate::fleet::Fleet`]
/// owns N engines, so each must own its backend stack too). Dispatch is one
/// match per generation call — noise next to a backend invocation.
enum BackendSlot<'a> {
    Borrowed(&'a mut dyn TextBackend),
    Owned(Box<dyn TextBackend>),
}

impl BackendSlot<'_> {
    fn as_mut(&mut self) -> &mut dyn TextBackend {
        match self {
            BackendSlot::Borrowed(b) => &mut **b,
            BackendSlot::Owned(b) => b.as_mut(),
        }
    }
}

pub struct Engine<'a> {
    pub cfg: EngineCfg,
    pub corpus: Arc<Corpus>,
    pub tok: &'a Tokenizer,
    pub registry: &'a Registry,
    backend: BackendSlot<'a>,
    cluster: Cluster,
    profile: OfflineProfile,
    /// offline cost coefficient (the profile's output) — the base value the
    /// core's cost model is (re)built from; the *live* coefficient lives on
    /// the model, which may correct it online
    cost_coeff: f64,
    core: Core,
}

impl<'a> Engine<'a> {
    pub fn new(
        cfg: EngineCfg,
        corpus: Arc<Corpus>,
        tok: &'a Tokenizer,
        registry: &'a Registry,
        backend: &'a mut dyn TextBackend,
    ) -> Result<Self, RunError> {
        Engine::build(cfg, corpus, tok, registry, BackendSlot::Borrowed(backend))
    }

    /// Like [`Engine::new`] but taking ownership of the backend stack —
    /// the constructor fleet shards use, since a [`crate::fleet::Fleet`]
    /// must own N engines (and therefore N backends) at once.
    pub fn new_owned(
        cfg: EngineCfg,
        corpus: Arc<Corpus>,
        tok: &'a Tokenizer,
        registry: &'a Registry,
        backend: Box<dyn TextBackend>,
    ) -> Result<Self, RunError> {
        Engine::build(cfg, corpus, tok, registry, BackendSlot::Owned(backend))
    }

    fn build(
        cfg: EngineCfg,
        corpus: Arc<Corpus>,
        tok: &'a Tokenizer,
        registry: &'a Registry,
        backend: BackendSlot<'a>,
    ) -> Result<Self, RunError> {
        cfg.calib.validate().map_err(RunError::Backend)?;
        cfg.tail.validate().map_err(RunError::Backend)?;
        let cluster = Cluster::testbed(cfg.n_edges);
        let cloud_info = registry
            .get(&cfg.cloud_model)
            .ok_or_else(|| RunError::Backend(format!("unknown model {}", cfg.cloud_model)))?;
        if !cluster.cloud.fits(cloud_info) {
            return Err(RunError::Oom(format!("{} does not fit the cloud node", cfg.cloud_model)));
        }
        let devices: Vec<&crate::cluster::DeviceSpec> =
            std::iter::once(&cluster.cloud).chain(cluster.edges.iter()).collect();
        let model_refs: Vec<&ModelInfo> = registry.models.iter().collect();
        // profile the cloud at its serving batch so Eq. 2 compares against
        // per-sequence latency under load (vLLM continuous batching)
        let profile = OfflineProfile::profile_batched(&devices, &model_refs, 16);
        // cost coefficient vs the strongest edge SLM (conservative default)
        let slms = registry.slms_for(&cfg.cloud_model);
        let cost_coeff = slms
            .iter()
            .filter_map(|s| {
                profile.cost_coefficient(
                    &cluster.cloud.name,
                    &cfg.cloud_model,
                    &cluster.edges.first().map(|e| e.name.clone()).unwrap_or_default(),
                    &s.name,
                )
            })
            .fold(f64::INFINITY, f64::min)
            .min(10.0);
        let core = make_core(&cfg, registry, &cluster, &profile, cost_coeff);
        Ok(Engine { cfg, corpus, tok, registry, backend, cluster, profile, cost_coeff, core })
    }

    /// SLMs deployable for this scenario, ascending capability.
    fn slms(&self) -> Vec<&'a ModelInfo> {
        let reg: &'a Registry = self.registry;
        let mut v = reg.slms_for(&self.cfg.cloud_model);
        // total_cmp: a degenerate fit (NaN params) must order, not panic
        v.sort_by(|a, b| a.sim_params_b().total_cmp(&b.sim_params_b()));
        v
    }

    fn cloud_info(&self) -> &'a ModelInfo {
        let reg: &'a Registry = self.registry;
        reg.get(&self.cfg.cloud_model).expect("cloud model in registry")
    }

    fn model_info(&self, name: &str) -> &'a ModelInfo {
        let reg: &'a Registry = self.registry;
        reg.get(name).expect("model in registry")
    }

    /// The LLM's response-length perception: reference length x the model's
    /// Table-I bias x noise (the 32B model underestimates — §V-B).
    fn predict_len(&mut self, qid: usize) -> usize {
        let answer_len = self.corpus.get(qid).expect("qid").answer_len() as f64;
        let bias = self.cloud_info().length_pred_bias;
        let noise = (self.core.rng.normal() * 0.08).exp();
        (answer_len * self.cfg.sim_token_scale * bias * noise).round().max(1.0) as usize
    }

    // -- step-driven serving API --------------------------------------------

    /// Current simulated time.
    pub fn now(&self) -> SimTime {
        self.core.q.now()
    }

    /// True when no scheduled work remains.
    pub fn is_idle(&self) -> bool {
        self.core.q.is_empty()
    }

    /// Requests submitted so far (accepted submissions only).
    pub fn submitted(&self) -> usize {
        self.core.pend.len()
    }

    /// Requests finalized so far (terminal event emitted). `submitted() -
    /// completed()` is the engine's in-flight depth — the fleet router's
    /// least-loaded tiebreak.
    pub fn completed(&self) -> usize {
        self.core.completed
    }

    /// Requests closed on this engine without a terminal event because the
    /// fleet moved them to another shard (see [`Engine::evict_displaced`]).
    /// The router's in-flight depth is `submitted() - completed() -
    /// evicted()`.
    pub fn evicted(&self) -> usize {
        self.core.evicted
    }

    /// Monotone count of events processed by [`Engine::pump_one`]. Advances
    /// exactly when the loop makes progress, so callers can memoize derived
    /// state against it — the fleet router caches `backlog_estimate_s` per
    /// shard keyed on this counter instead of re-running Eq. 2 per
    /// submission.
    pub fn events_processed(&self) -> u64 {
        self.core.events_processed
    }

    /// Edges currently alive (dynamics: crashes decrement, recovers
    /// restore). In a static world this is constant `cfg.n_edges`.
    pub fn up_edges(&self) -> usize {
        self.core.up_edges
    }

    /// Recover events still unprocessed in the dynamics timeline — the
    /// "is help coming" signal (a shard with zero live edges and zero
    /// pending recovers can only serve via cloud fallback).
    pub fn pending_recovers(&self) -> usize {
        self.core.pending_recovers
    }

    /// Turn on the streaming [`ResponseEvent`] sink (off by default — batch
    /// drivers pay nothing for the serving-event machinery).
    pub fn enable_events(&mut self) {
        if self.core.events.is_none() {
            self.core.events = Some(Vec::new());
        }
    }

    /// Drain every event emitted since the last call (empty when the sink
    /// is disabled). Events are in emission order: per request, timestamps
    /// are monotone and the terminal `Final` comes last.
    pub fn take_events(&mut self) -> Vec<ResponseEvent> {
        self.core.events.as_mut().map(std::mem::take).unwrap_or_default()
    }

    /// Turn on the telemetry sink (request spans + metrics registry) with
    /// this engine tagged as `shard` (0 for a standalone engine; the fleet
    /// passes the shard index so exported traces carry per-shard `pid`s).
    /// Off by default — the off path is bit-identical to a build without
    /// the subsystem.
    pub fn enable_telemetry(&mut self, shard: usize) {
        if self.core.telem.is_none() {
            self.core.telem = Some(Box::new(Telemetry::new(shard)));
        }
    }

    /// Telemetry sink enabled?
    pub fn telemetry_on(&self) -> bool {
        self.core.telem.is_some()
    }

    /// Drain every span stamped since the last call (empty when telemetry
    /// is off). Spans are in emission order: pure in `(cfg, workload,
    /// seed)`, so the log is bit-identical across sweep thread counts and
    /// open vs closed loop.
    pub fn take_spans(&mut self) -> Vec<Span> {
        self.core.telem.as_mut().map(|t| std::mem::take(&mut t.spans)).unwrap_or_default()
    }

    /// The engine's metrics registry (None when telemetry is off).
    pub fn metrics_registry(&self) -> Option<&MetricsRegistry> {
        self.core.telem.as_deref().map(|t| &t.registry)
    }

    /// Stamp a durationful span, if telemetry is on.
    fn tspan(&mut self, rid: usize, kind: SpanKind, start: SimTime, end: SimTime) {
        if let Some(t) = self.core.telem.as_mut() {
            t.span(rid, kind, start, end);
        }
    }

    /// Stamp an instant mark, if telemetry is on.
    fn tmark(&mut self, rid: usize, kind: SpanKind, t: SimTime) {
        if let Some(tl) = self.core.telem.as_mut() {
            tl.mark(rid, kind, t);
        }
    }

    /// Bump a registry counter, if telemetry is on.
    fn tcount(&mut self, name: &str, by: u64) {
        if let Some(t) = self.core.telem.as_mut() {
            t.registry.inc(name, by);
        }
    }

    /// Submit one request arriving at simulated time `arrival` (clamped to
    /// `now()` if in the past) and return its request id. Re-entrant: call
    /// while earlier requests are mid-flight. A submission at time t orders
    /// ahead of every already-scheduled internal event at t, so interleaved
    /// submit/pump driving is bit-identical to scheduling all arrivals
    /// up-front (the open-loop determinism guarantee).
    pub fn submit(&mut self, question_id: usize, arrival: SimTime) -> Result<usize, RunError> {
        if let Some(msg) = &self.core.edge_oom {
            return Err(RunError::Oom(msg.clone()));
        }
        // the trace must record the *effective* arrival: a past timestamp
        // enters the system now, not retroactively (latency/TTFS would
        // otherwise count phantom wait)
        let arrival = arrival.max(self.core.q.now());
        let qq = self
            .corpus
            .get(question_id)
            .ok_or_else(|| RunError::Backend(format!("unknown question id {question_id}")))?;
        let question_toks: Arc<[u32]> = Arc::from(qq.question.as_slice());
        let category = qq.category.clone();
        let rid = self.core.pend.len();
        self.core.pend.push(Pending {
            question_id,
            question_toks,
            category,
            arrival,
            predicted_len: 0,
            mode: Mode::CloudFull,
            sketch_level: 0,
            cloud_enq: 0.0,
            cloud_start: 0.0,
            cloud_done: 0.0,
            edge_start: None,
            sketch_ready: None,
            first_expansion: None,
            cloud_tokens: 0,
            edge_tokens: 0,
            sketch: Vec::new().into(),
            expected_sketch_len: 0,
            candidates: Vec::new(),
            transfer_pred: None,
            replicas_out: 0,
            parallelism: 0,
            failovers: 0,
            retried_slots: 0,
            salvaged_slots: 0,
            cloud_rescue: false,
            hedges: 0,
            hedged_slots: 0,
            requeue_retries: 0,
            done: false,
        });
        self.core.traces.push(None);
        self.core.q.schedule_class(arrival, FIRST_CLASS, Ev::Arrive(rid));
        self.core.virgin = false;
        self.tcount("submitted", 1);
        Ok(rid)
    }

    /// Process the next scheduled event; `Ok(false)` when the queue is idle.
    pub fn pump_one(&mut self) -> Result<bool, RunError> {
        let Some((now, ev)) = self.core.q.pop() else {
            return Ok(false);
        };
        self.core.virgin = false;
        self.core.events_processed += 1;
        match ev {
            Ev::Arrive(rid) => self.ev_arrive(now, rid),
            Ev::CloudAdmit => self.ev_cloud_admit(now)?,
            Ev::CloudDone { rid, kind } => self.ev_cloud_done(now, rid, kind),
            Ev::JobArriveAtQueue { rid } => self.ev_job_arrive(now, rid),
            Ev::EdgePull { eid } => self.ev_edge_pull(now, eid)?,
            Ev::EdgeDone { eid, epoch, work } => self.ev_edge_done(now, eid, epoch, work),
            Ev::Fault { eid, fault } => self.ev_fault(now, eid, fault),
            Ev::HedgeFire { eid, epoch } => self.ev_hedge_fire(now, eid, epoch),
            Ev::BackoffRetry { rid, attempt } => self.ev_backoff_retry(now, rid, attempt),
        }
        Ok(true)
    }

    /// Drain every event scheduled *strictly before* `horizon` (the clock
    /// ends at the last processed event, not at `horizon`). Strict so a
    /// caller can submit an arrival at `horizon` *before* pumping past it —
    /// the order the closed-loop driver would have produced.
    pub fn pump_until(&mut self, horizon: SimTime) -> Result<(), RunError> {
        while let Some(t) = self.core.q.next_time() {
            if t >= horizon {
                break;
            }
            self.pump_one()?;
        }
        Ok(())
    }

    /// Drain the event queue to quiescence.
    pub fn pump_all(&mut self) -> Result<(), RunError> {
        while self.pump_one()? {}
        Ok(())
    }

    /// Take the completed traces (request-id order), leaving slots for any
    /// still-in-flight requests untouched.
    pub fn take_traces(&mut self) -> Vec<RequestTrace> {
        self.core.traces.iter_mut().filter_map(Option::take).collect()
    }

    /// Reset the loop state (fresh RNG, queues, placements) while keeping
    /// the profile/cluster. [`Engine::run`] calls this so repeated runs are
    /// independent, exactly like the pre-refactor per-run locals.
    pub fn reset(&mut self) {
        let events_on = self.core.events.is_some();
        let telem_shard = self.core.telem.as_deref().map(|t| t.shard);
        self.core =
            make_core(&self.cfg, self.registry, &self.cluster, &self.profile, self.cost_coeff);
        if events_on {
            self.core.events = Some(Vec::new());
        }
        if let Some(shard) = telem_shard {
            self.core.telem = Some(Box::new(Telemetry::new(shard)));
        }
    }

    /// Run the workload to completion; returns per-request traces. This is
    /// the closed-loop driver over the step core: submit every arrival,
    /// drain the queue.
    pub fn run(&mut self, workload: &Workload) -> Result<Vec<RequestTrace>, RunError> {
        // a pristine core (no submissions, nothing pumped) is already the
        // state reset() would rebuild — don't construct it twice per run.
        // Tracked with an explicit flag: a dynamics fault timeline
        // pre-schedules events, so an empty queue is not a usable test.
        if !self.core.virgin {
            self.reset();
        }
        // infeasible placements fail up front, even for empty workloads
        if let Some(msg) = &self.core.edge_oom {
            return Err(RunError::Oom(msg.clone()));
        }
        for r in &workload.requests {
            self.submit(r.question_id, r.arrival_s)?;
        }
        self.pump_all()?;
        Ok(self.take_traces())
    }

    // -- event handlers ------------------------------------------------------

    fn emit(&mut self, t: SimTime, rid: usize, kind: ResponseEventKind) {
        if let Some(events) = self.core.events.as_mut() {
            events.push(ResponseEvent { rid, t, kind });
        }
    }

    fn ev_arrive(&mut self, now: SimTime, rid: usize) {
        let qid = self.core.pend[rid].question_id;
        let predicted = self.predict_len(qid);
        self.core.pend[rid].predicted_len = predicted;
        // every ev_arrive cloud enqueue happens at `now` — stamp once here
        // (maintained unconditionally: a plain f64 store, no telemetry gate)
        self.core.pend[rid].cloud_enq = now;
        self.tcount("arrivals", 1);
        let policy = self.cfg.policy.clone();
        match &policy {
            Policy::CloudOnly => {
                self.core.cloud_pending.push_back((rid, CloudJobKind::Full));
                self.core.q.schedule(now, Ev::CloudAdmit);
            }
            Policy::EdgeOnly => {
                self.dispatch_full(now, rid);
            }
            Policy::Routing { difficulty_threshold } => {
                // difficulty proxy: predicted length + jitter (an imperfect
                // router, as in the paper's critique). The multiplier is
                // clamped at 0 to keep the proxy in its valid non-negative
                // domain — an extreme draw still misroutes to the edge (that
                // inaccuracy is the router's modeled flaw), but it can no
                // longer go *negative*.
                let difficulty =
                    predicted as f64 * (1.0 + self.core.rng.normal() * 0.25).max(0.0);
                if difficulty > *difficulty_threshold {
                    self.core.cloud_pending.push_back((rid, CloudJobKind::Full));
                    self.core.q.schedule(now, Ev::CloudAdmit);
                } else {
                    self.dispatch_full(now, rid);
                }
            }
            Policy::Pice => {
                let slms = self.slms();
                let best_cap = slms.iter().map(|m| m.mmlu).fold(0.0, f64::max);
                // Δ(r): the static world pins the legacy calibrated
                // constants bit-for-bit; with dynamics on, the cost model
                // sees the CURRENT link, so routing adapts mid-run
                let live = if self.cfg.dynamics.link.is_static() {
                    TransferModel { base_s: 0.02, per_token_s: 5e-7 }
                } else {
                    self.link_now_mut(now).transfer_model()
                };
                // every Eq. 2 world quantity — f(l), c, Δ correction,
                // backlog Σ_j c·f(l_j), achieved parallelism — in one
                // snapshot from THE model instance
                let est = self.core.cost_model.estimates(live, &self.core.jobq);
                let inp = SchedInput {
                    predicted_len: predicted,
                    n_edges: self.core.edges.len(),
                    best_slm_capability: best_cap,
                };
                let d = self.cfg.scheduler.decide(&inp, &est);
                if d.mode == SchedMode::Full && predicted >= self.cfg.scheduler.min_progressive_len
                {
                    crate::debug!(
                        "rid={rid} FULL pred={predicted} backlog={:.1} hint={:.1} e2e_l3={:.1} budget={:.1}",
                        est.backlog_s,
                        est.parallel_hint,
                        self.cfg.scheduler.e2e_estimate(&inp, &est, self.cfg.scheduler.levels[3]),
                        est.f_cloud.eval(predicted)
                    );
                }
                if d.mode == SchedMode::Progressive && !slms.is_empty() {
                    self.core.pend[rid].mode = Mode::Progressive;
                    self.core.pend[rid].sketch_level = d.level.level;
                    self.core.pend[rid].expected_sketch_len = d.expected_sketch_len;
                    if self.core.cost_model.learning() {
                        // remember what the model *promised* for the sketch
                        // transfer; the observed transfer grades it later
                        self.core.pend[rid].transfer_pred = Some(est.transfer);
                    }
                    self.core
                        .cloud_pending
                        .push_back((rid, CloudJobKind::Sketch { level: d.level.level }));
                } else {
                    self.core.cloud_pending.push_back((rid, CloudJobKind::Full));
                }
                self.core.q.schedule(now, Ev::CloudAdmit);
            }
        }
        if self.core.events.is_some() {
            let mode = self.core.pend[rid].mode;
            self.emit(now, rid, ResponseEventKind::Admitted { mode });
        }
    }

    fn ev_cloud_admit(&mut self, now: SimTime) -> Result<(), RunError> {
        // Drain every job admissible at this timestamp, then issue all of
        // their generations as ONE backend batch — the parallel/lockstep
        // backends shard it across workers while results stay index-aligned
        // with the admission order.
        let mut admitted: Vec<(usize, CloudJobKind)> = Vec::new();
        while self.core.cloud_inflight + admitted.len() < self.core.cloud_slots {
            let Some(j) = self.core.cloud_pending.pop_front() else { break };
            admitted.push(j);
        }
        if admitted.is_empty() {
            return Ok(());
        }
        let scale = self.cfg.sim_token_scale;
        let real_cap = ((self.cfg.cloud_max_tokens as f64 / scale).round() as usize).max(4);
        let cloud_model = self.core.cloud_model.clone();
        let reqs: Vec<GenRequest> = admitted
            .iter()
            .map(|(rid, kind)| {
                let question = &self.core.pend[*rid].question_toks;
                let (prompt, max_tokens) = match kind {
                    CloudJobKind::Full => (Prompts::full_answer(self.tok, question), real_cap),
                    CloudJobKind::Sketch { .. } => (Prompts::sketch(self.tok, question), 60),
                };
                GenRequest {
                    model: cloud_model.clone(),
                    prompt: prompt.into(),
                    sp: SamplingParams {
                        max_tokens,
                        seed: self.cfg.seed ^ *rid as u64,
                        ..Default::default()
                    },
                }
            })
            .collect();
        let outs = self.backend.as_mut().generate_batch(&reqs);
        // every member of this admission batch runs concurrently with the
        // jobs already in flight AND with each other, so all are priced at
        // the final concurrent batch size — not the ascending sizes an
        // in-loop `inflight + 1` would see
        let b = self.core.cloud_inflight + admitted.len();
        let cloud_info = self.cloud_info();
        for (k, ((rid, kind), out)) in admitted.into_iter().zip(outs).enumerate() {
            let out = out.map_err(RunError::Backend)?;
            // A cloud RESCUE of a progressive request must not overwrite the
            // sketch phase's cloud_start/cloud_done in the trace — rescues
            // only originate post-sketch (from displaced expansion jobs), so
            // an unguarded stamp here reported the rescue window and silently
            // folded sketch+transfer+edge time into apparent queue wait.
            if !self.core.pend[rid].cloud_rescue {
                self.core.pend[rid].cloud_start = now;
            }
            let prompt_sim = (reqs[k].prompt.len() as f64 * scale) as usize;
            let dur = match &kind {
                CloudJobKind::Full => {
                    let n_sim = (out.tokens.len() as f64 * scale) as usize;
                    self.core.pend[rid].cloud_tokens = n_sim;
                    // final answer = cloud output minus <eos>
                    let mut ans = out.tokens;
                    if ans.last() == Some(&self.tok.specials.eos) {
                        ans.pop();
                    }
                    // push, don't replace: a plain Full request reaches
                    // admission with no candidates (so this is the old
                    // `vec![..]` bit-for-bit), while a failover cloud
                    // rescue joins any already-streamed edge expansions in
                    // ensemble selection instead of silently erasing them
                    self.core.pend[rid].candidates.push(Candidate {
                        model: cloud_model.clone(),
                        tokens: ans,
                        logps: out.logps,
                    });
                    self.cluster.cloud.prefill_time_s(cloud_info, prompt_sim, b)
                        + self.cluster.cloud.gen_time_s(cloud_info, n_sim, b)
                }
                CloudJobKind::Sketch { level } => {
                    let mut sk = out.tokens;
                    if sk.last() == Some(&self.tok.specials.eos) {
                        sk.pop();
                    }
                    // apply the level compression per sentence
                    let lv = self
                        .cfg
                        .scheduler
                        .levels
                        .iter()
                        .copied()
                        .find(|l| l.level == *level)
                        .unwrap_or(self.cfg.scheduler.levels[1]);
                    let keep = self
                        .cfg
                        .sketch_keep_frac_override
                        .as_ref()
                        .and_then(|m| m.get(&self.core.pend[rid].category).copied());
                    let sents = split_sketch(&sk, self.tok.specials.semicolon);
                    let mut out_sk: Vec<u32> = Vec::new();
                    for (i, s) in sents.iter().enumerate() {
                        if i > 0 {
                            out_sk.push(self.tok.specials.semicolon);
                        }
                        let lvl = match keep {
                            Some(kf) => {
                                crate::sketch::SketchLevel { level: lv.level, keep_frac: kf }
                            }
                            None => lv,
                        };
                        out_sk.extend(compress(s, lvl));
                    }
                    let n_sim = (out_sk.len() as f64 * scale) as usize;
                    self.core.pend[rid].cloud_tokens = n_sim;
                    self.core.pend[rid].sketch = out_sk.into();
                    self.cluster.cloud.prefill_time_s(cloud_info, prompt_sim, b)
                        + self.cluster.cloud.gen_time_s(cloud_info, n_sim, b)
                }
            };
            if self.core.cost_model.learning() {
                // both kinds are (response sim-length, service time) points
                // on the same cloud line at the live batch size — sketches
                // anchor the short end, full answers the long end
                let n_sim = self.core.pend[rid].cloud_tokens;
                self.core.cost_model.observe_cloud(n_sim, dur);
            }
            if self.core.telem.is_some() {
                let enq = self.core.pend[rid].cloud_enq;
                let skind = match &kind {
                    CloudJobKind::Full => SpanKind::CloudFull,
                    CloudJobKind::Sketch { .. } => SpanKind::CloudSketch,
                };
                let t = self.core.telem.as_mut().unwrap();
                t.span(rid, SpanKind::QueueWait, enq, now);
                t.span(rid, skind, now, now + dur);
                t.registry.inc("cloud_jobs", 1);
            }
            self.core.cloud_inflight += 1;
            self.core.q.schedule(now + dur, Ev::CloudDone { rid, kind });
        }
        Ok(())
    }

    fn ev_cloud_done(&mut self, now: SimTime, rid: usize, kind: CloudJobKind) {
        self.core.cloud_inflight = self.core.cloud_inflight.saturating_sub(1);
        // see ev_cloud_admit: a rescue regeneration keeps the sketch phase's
        // trace timestamps; `sketch_ready == cloud_done` stays invariant for
        // every progressive request
        if !self.core.pend[rid].cloud_rescue {
            self.core.pend[rid].cloud_done = now;
        }
        self.core.q.schedule(now, Ev::CloudAdmit);
        match kind {
            CloudJobKind::Full => {
                self.finalize(rid, now);
            }
            CloudJobKind::Sketch { .. } => {
                // the sketch is the early partial response: client-visible now
                self.core.pend[rid].sketch_ready = Some(now);
                if self.core.events.is_some() {
                    let text = self.tok.decode_content(&self.core.pend[rid].sketch);
                    self.emit(now, rid, ResponseEventKind::SketchReady { text });
                }
                // the sketch pays the CURRENT link (dynamics may have
                // retimed it); static worlds see cfg.link untouched
                let sim_len =
                    (self.core.pend[rid].sketch.len() as f64 * self.cfg.sim_token_scale) as usize;
                let delta = self.link_now_mut(now).transfer_tokens_s(sim_len);
                if let Some(tm) = self.core.pend[rid].transfer_pred.take() {
                    // decision-time promise vs observed transfer: the gap is
                    // WAN drift between scheduling and the sketch landing
                    self.core.cost_model.observe_transfer(tm.eval(sim_len), delta);
                }
                self.tspan(rid, SpanKind::Transfer, now, now + delta);
                self.core.q.schedule(now + delta, Ev::JobArriveAtQueue { rid });
            }
        }
    }

    fn ev_job_arrive(&mut self, now: SimTime, rid: usize) {
        // a fleet may have evicted this request to another shard while its
        // deferral was pending — it must not re-enter here
        if self.core.pend[rid].done {
            return;
        }
        let attempts = self.core.pend[rid].requeue_retries;
        if self.core.jobq.len() >= self.cfg.queue_cap && attempts < 5 {
            // queue full: retry shortly instead of degrading. Bounded so
            // latency can't grow unboundedly: after 5 deferrals the request
            // proceeds regardless and, if the queue is still full, takes
            // the sketch-fallback terminal below — saturation degrades
            // answers, it never silently drops a request.
            self.core.pend[rid].requeue_retries = attempts + 1;
            self.tspan(rid, SpanKind::RequeueWait, now, now + 2.0);
            self.tcount("requeue_deferrals", 1);
            self.core.q.schedule_in(2.0, Ev::JobArriveAtQueue { rid });
            return;
        }
        let sents: Vec<Arc<[u32]>> =
            split_sketch(&self.core.pend[rid].sketch, self.tok.specials.semicolon)
                .into_iter()
                .map(Arc::from)
                .collect();
        let replicas = self.cfg.ensemble_k.max(1);
        self.core.pend[rid].replicas_out = replicas;
        let job = Job {
            rid,
            expected_len: self.core.pend[rid].predicted_len,
            salvaged: vec![None; sents.len()],
            sentences: sents,
            full_sketch: self.core.pend[rid].sketch.clone(),
            question: self.core.pend[rid].question_toks.clone(),
            enqueued_at: now,
            replicas_left: replicas,
        };
        if self.core.up_edges == 0 {
            // every edge is down: park for a scheduled recover, or fall
            // back to the cloud when the timeline promises none. Either
            // way the request was displaced by the blackout — count it, so
            // the degraded-mode percentiles see park-then-recover
            // survivors too, not only cloud rescues.
            self.core.pend[rid].failovers += 1;
            self.tmark(rid, SpanKind::Failover, now);
            self.tcount("failovers", 1);
            if self.core.pending_recovers > 0 {
                if self.core.tail_on {
                    self.backoff_displaced(now, job, 0);
                } else {
                    self.core.parked_jobs.push(job);
                }
            } else {
                self.fail_to_cloud(now, rid);
            }
            return;
        }
        if !self.core.jobq.push(job) {
            // queue full: fall back — answer is the sketch itself
            // (degenerate; counted against PICE's quality)
            self.fallback_finalize_with_sketch(rid, now);
            return;
        }
        for eid in 0..self.core.edges.len() {
            if self.core.edges[eid].up && !self.core.edges[eid].busy {
                self.core.q.schedule(now, Ev::EdgePull { eid });
            }
        }
    }

    fn ev_edge_pull(&mut self, now: SimTime, eid: usize) -> Result<(), RunError> {
        if self.core.edges[eid].busy || !self.core.edges[eid].up {
            return Ok(());
        }
        let scale = self.cfg.sim_token_scale;
        // Edge-only / routed-easy full answers first.
        if let Some(rid) = self.core.edge_fifo[eid].pop_front() {
            self.core.edges[eid].busy = true;
            self.core.pend[rid].edge_start.get_or_insert(now);
            let model_name = self.core.edges[eid].current_model.clone();
            let info = self.model_info(&model_name);
            let prompt = Prompts::full_answer(self.tok, &self.core.pend[rid].question_toks);
            let real_cap = ((self.cfg.cloud_max_tokens as f64 / scale).round() as usize).max(4);
            let out = self
                .backend
                .as_mut()
                .generate(
                    &model_name,
                    &prompt,
                    &SamplingParams {
                        max_tokens: real_cap,
                        seed: self.cfg.seed ^ (rid as u64) << 1,
                        ..Default::default()
                    },
                )
                .map_err(RunError::Backend)?;
            let mut ans = out.tokens;
            if ans.last() == Some(&self.tok.specials.eos) {
                ans.pop();
            }
            let n_sim = (ans.len() as f64 * scale) as usize;
            // straggler mode (dynamics slowdown) stretches compute; the
            // static multiplier is exactly 1.0 (bit-neutral)
            let dur = (self.core.edges[eid].spec.prefill_time_s(
                info,
                (prompt.len() as f64 * scale) as usize,
                1,
            ) + self.core.edges[eid].spec.gen_time_s(info, n_sim, 1))
                * self.core.edges[eid].speed_mult;
            let work = EdgeWork {
                items: vec![(
                    rid,
                    Candidate { model: model_name, tokens: ans, logps: out.logps },
                    n_sim,
                )],
            };
            if self.core.track_inflight {
                self.core.edges[eid].inflight = EdgeInflight::Full(rid);
            }
            if let Some(t) = self.core.telem.as_mut() {
                t.span(rid, SpanKind::EdgeFull { eid }, now, now + dur);
                t.registry.inc("edge_full_jobs", 1);
                t.registry.gauge_add(&format!("edge{eid}_busy_s"), dur);
            }
            let epoch = self.core.edges[eid].epoch;
            self.core.q.schedule(now + dur, Ev::EdgeDone { eid, epoch, work });
            return Ok(());
        }
        if self.core.jobq.is_empty() {
            return Ok(());
        }
        // Algorithm 1: pull a batch from the longest list.
        let model0 = self.core.edges[eid].current_model.clone();
        let info0 = self.model_info(&model0);
        let cap = self.core.edges[eid].spec.max_batch(info0, 600).clamp(1, 4);
        let mut batch = self.core.jobq.pull_batch(cap);
        if batch.is_empty() {
            return Ok(());
        }
        self.core.edges[eid].busy = true;
        // Ensemble replication: each queue entry carries the number of
        // pending candidate executions (replicas_left). This pull runs ONE
        // execution per job; surplus replicas are re-queued only if *idle*
        // edges can absorb them (never delaying the primary expansion), and
        // discarded otherwise.
        let idle_others: Vec<usize> = (0..self.core.edges.len())
            .filter(|&e2| e2 != eid && !self.core.edges[e2].busy && self.core.edges[e2].up)
            .collect();
        let mut spare = idle_others.len();
        for job in batch.iter_mut() {
            let surplus = job.replicas_left.saturating_sub(1);
            let extra = surplus.min(spare);
            let mut discarded = surplus - extra;
            if extra > 0 {
                let mut rep = job.clone();
                rep.replicas_left = extra;
                // the replica enters the queue NOW — keeping the original
                // enqueue time would misattribute the primary's queue delay
                // to the replica
                rep.enqueued_at = now;
                if self.core.jobq.push(rep) {
                    spare -= extra;
                    for &e2 in &idle_others {
                        self.core.q.schedule(now, Ev::EdgePull { eid: e2 });
                    }
                } else {
                    discarded += extra;
                }
            }
            let p = &mut self.core.pend[job.rid];
            p.replicas_out = p.replicas_out.saturating_sub(discarded);
            job.replicas_left = 1;
            p.edge_start.get_or_insert(now);
        }

        // Algorithm 2 on the first job's budget (batch-shared model) — the
        // cost model's current cloud line (offline, or the online re-fit)
        let slm_refs = self.slms();
        let f_cloud = self.core.cost_model.f_cloud();
        let j0 = &batch[0];
        let budget = (f_cloud.eval(j0.expected_len)
            - f_cloud.eval((j0.full_sketch.len() as f64 * scale) as usize))
        .max(0.05);
        let sel = if slm_refs.is_empty() {
            super::selection::SelectionOutcome {
                model: self.core.edges[eid].current_model.to_string(),
                switched: false,
                switch_cost_s: 0.0,
            }
        } else {
            select_model(
                &self.core.edges[eid].spec,
                &slm_refs,
                &self.core.edges[eid].current_model,
                j0.expected_len,
                ((j0.full_sketch.len() + j0.question.len()) as f64 * scale) as usize,
                budget,
                self.core.jobq.len(),
                self.cfg.queue_cap,
            )
        };
        let sel_model = self.core.intern(&sel.model);
        self.core.edges[eid].current_model = sel_model.clone();
        let info = self.model_info(&sel.model);

        // Execution optimizer: batch-level lane planning. All jobs' lanes
        // run concurrently on this device; the binary-tree merge balances
        // per-job parallelism against global token-rate contention + prompt
        // overhead (Fig. 7a).
        let info_cost = EdgeCostModel {
            token_s: self.core.edges[eid].spec.token_latency_s(info, 1),
            batch_slowdown: crate::cluster::BATCH_TOKEN_SLOWDOWN,
            prompt_tokens: batch
                .iter()
                .map(|j| ((j.question.len() + j.full_sketch.len() + 4) as f64 * scale) as usize)
                .max()
                .unwrap_or(0),
            prefill_speedup: 8.0,
        };
        // Sentence slots still needing generation. A first dispatch has
        // every slot fresh; after a crash-salvage re-dispatch only the
        // unfinished ones are regenerated (planned, priced and prompted) —
        // the salvaged expansions ride along for free.
        let fresh_idx: Vec<Vec<usize>> = batch
            .iter()
            .map(|job| {
                (0..job.sentences.len())
                    .filter(|&si| job.salvaged.get(si).and_then(Option::as_ref).is_none())
                    .collect()
            })
            .collect();
        let est_lens: Vec<Vec<usize>> = batch
            .iter()
            .zip(&fresh_idx)
            .map(|(job, fresh)| {
                fresh
                    .iter()
                    .map(|&si| {
                        (((job.sentences[si].len() as f64 * 2.2).ceil() + 2.0) * scale) as usize
                    })
                    .collect()
            })
            .collect();
        let est_refs: Vec<&[usize]> = est_lens.iter().map(|v| v.as_slice()).collect();
        let p_mem = self.core.edges[eid]
            .spec
            .max_batch(info, info_cost.prompt_tokens + (40.0 * scale) as usize)
            .max(1);
        let (plans, _) = plan_batch(&est_refs, p_mem, &info_cost);

        // Generate the real expansions — every sentence of every job in the
        // pulled batch goes out as ONE backend batch (sharded across workers
        // by ParallelBackend), then charge simulated time using the chosen
        // plans over the *actual* lengths. Flattened order is job-major,
        // sentence-minor, so results realign positionally.
        let reqs: Vec<GenRequest> = batch
            .iter()
            .zip(&fresh_idx)
            .flat_map(|(job, fresh)| {
                // the regenerated slot keeps its original sentence-index
                // seed, so a salvage re-dispatch replays the identical
                // sampling key (and hits the memo cache)
                fresh.iter().map(move |&si| GenRequest {
                    model: sel_model.clone(),
                    prompt: Prompts::expand(
                        self.tok,
                        &job.question,
                        &job.full_sketch,
                        &job.sentences[si],
                    )
                    .into(),
                    sp: SamplingParams {
                        max_tokens: 24,
                        stop_token: Some(self.tok.specials.period),
                        seed: self.cfg.seed ^ ((job.rid as u64) << 8) ^ si as u64,
                        ..Default::default()
                    },
                })
            })
            .collect();
        let mut outs = self.backend.as_mut().generate_batch(&reqs).into_iter();
        let mut items = Vec::new();
        let mut real_lens_per_job: Vec<Vec<usize>> = Vec::with_capacity(batch.len());
        // fresh outputs per job, kept for crash/hedge salvage (tracked only
        // when fault injection or hedging needs them)
        let mut fresh_outs_per_job: Vec<Vec<(usize, SalvagedSlot)>> =
            Vec::with_capacity(batch.len());
        for (job, fresh) in batch.iter().zip(&fresh_idx) {
            let mut slot_out: Vec<Option<SalvagedSlot>> = (0..job.sentences.len())
                .map(|si| job.salvaged.get(si).cloned().flatten())
                .collect();
            let mut real_lens = vec![0usize; fresh.len()];
            let mut fresh_outs = Vec::new();
            for (k, &si) in fresh.iter().enumerate() {
                let out = outs
                    .next()
                    .expect("batch result per sentence")
                    .map_err(RunError::Backend)?;
                let mut toks = out.tokens;
                if toks.last() == Some(&self.tok.specials.eos) {
                    toks.pop();
                }
                let n_sim = (toks.len() as f64 * scale) as usize;
                real_lens[k] = n_sim;
                let slot = SalvagedSlot { tokens: toks, logps: out.logps, sim_tokens: n_sim };
                if self.core.track_inflight {
                    fresh_outs.push((si, slot.clone()));
                }
                slot_out[si] = Some(slot);
            }
            // assemble in sentence order — salvaged and fresh interleave
            // exactly where the sketch put them
            let mut expansion: Vec<u32> = Vec::new();
            let mut logps: Vec<f64> = Vec::new();
            let mut n_edge_tokens = 0usize;
            for s in slot_out.into_iter().flatten() {
                expansion.extend_from_slice(&s.tokens);
                logps.extend_from_slice(&s.logps);
                n_edge_tokens += s.sim_tokens;
            }
            items.push((
                job.rid,
                Candidate { model: sel_model.clone(), tokens: expansion, logps },
                n_edge_tokens,
            ));
            real_lens_per_job.push(real_lens);
            fresh_outs_per_job.push(fresh_outs);
        }
        let mean_lanes =
            plans.iter().map(Vec::len).sum::<usize>() as f64 / plans.len().max(1) as f64;
        self.core.cost_model.observe_parallelism(mean_lanes);
        for (job, plan) in batch.iter().zip(&plans) {
            let p = &mut self.core.pend[job.rid];
            p.parallelism = p.parallelism.max(plan.len());
        }
        let real_refs: Vec<&[usize]> = real_lens_per_job.iter().map(|v| v.as_slice()).collect();
        let wall = batch_wall(&plans, &real_refs, &info_cost);
        // straggler multiplier is exactly 1.0 in the static world
        let total_dur = (sel.switch_cost_s + wall) * self.core.edges[eid].speed_mult;
        if self.core.cost_model.learning() {
            // grade Eq. 2's edge term in its decision shape — c·f(l)/p for
            // the batch's lead job at the achieved lane count — against the
            // wall this pull actually took
            let pred = self.core.cost_model.cost_coeff()
                * self.core.cost_model.f_cloud().eval(batch[0].expected_len)
                / mean_lanes.max(1.0);
            self.core.cost_model.observe_edge(pred, total_dur);
        }
        crate::debug!(
            "edge{eid} t={now:.1} batch={} model={} lanes={:?} switch={:.1} wall={wall:.1}",
            batch.len(),
            sel.model,
            plans.iter().map(Vec::len).collect::<Vec<_>>(),
            sel.switch_cost_s
        );
        if let Some(t) = self.core.telem.as_mut() {
            for (job, fresh) in batch.iter().zip(&fresh_idx) {
                t.span(
                    job.rid,
                    SpanKind::EdgeExpand { eid, slots: fresh.len() },
                    now,
                    now + total_dur,
                );
            }
            t.registry.inc("edge_pulls", 1);
            t.registry.gauge_add(&format!("edge{eid}_busy_s"), total_dur);
        }
        if self.core.track_inflight {
            // Retained so a crash can re-enter these slots into dispatch
            // with their sketch context intact (Job clones are Arc bumps).
            // Each fresh slot gets an estimated completion instant — the
            // pull's total duration apportioned by cumulative sim-token
            // share within its job (the last slot lands exactly on the
            // EdgeDone instant) — so a mid-pull crash can salvage the
            // slots that were already finished.
            let mut infl = Vec::with_capacity(batch.len());
            for ((job, fresh_outs), real_lens) in
                batch.iter().zip(fresh_outs_per_job).zip(&real_lens_per_job)
            {
                let total: usize = real_lens.iter().sum();
                let mut cum = 0usize;
                let mut outs = Vec::with_capacity(fresh_outs.len());
                for ((si, slot), &len) in fresh_outs.into_iter().zip(real_lens) {
                    cum += len;
                    let frac = if total == 0 { 1.0 } else { cum as f64 / total as f64 };
                    outs.push((si, now + total_dur * frac, slot));
                }
                infl.push(InflightJob { job: job.clone(), outs });
            }
            self.core.edges[eid].inflight = EdgeInflight::Expand(infl);
        }
        let epoch = self.core.edges[eid].epoch;
        let done = Ev::EdgeDone { eid, epoch, work: EdgeWork { items } };
        self.core.q.schedule(now + total_dur, done);
        if self.core.tail_on {
            // Tail-tolerance watchdog: arm a timer at the configured quantile
            // of Eq. 2's *edge-term estimate* for this pull (the same decision
            // shape observe_edge grades — c·f(l)/p with the calibrated lane
            // hint). Modelling pull duration as exponential with that mean,
            // the q-quantile is −ln(1−q)·est; slot_timeout_mult tightens or
            // relaxes it. Armed only when this pull will actually overrun the
            // threshold, so a well-behaved world schedules zero extra events.
            let est = self.core.cost_model.cost_coeff()
                * self.core.cost_model.f_cloud().eval(batch[0].expected_len)
                / self.core.cost_model.parallel_hint().max(1.0);
            let q = self.cfg.tail.hedge_quantile.unwrap_or(1.0);
            let timeout = self.cfg.tail.slot_timeout_mult * -(1.0 - q).ln() * est;
            if timeout.is_finite() && timeout > 0.0 && total_dur > timeout {
                self.core.q.schedule(now + timeout, Ev::HedgeFire { eid, epoch });
            }
        }
        Ok(())
    }

    fn ev_edge_done(&mut self, now: SimTime, eid: usize, epoch: u64, work: EdgeWork) {
        if epoch != self.core.edges[eid].epoch {
            // completion of work that died with a crashed incarnation: the
            // slots were re-dispatched at crash time — drop it entirely
            // (touching busy/pull state here would race the new incarnation)
            self.tcount("stale_edge_completions", 1);
            return;
        }
        self.core.edges[eid].busy = false;
        if self.core.track_inflight {
            self.core.edges[eid].inflight = EdgeInflight::Idle;
        }
        for (rid, cand, edge_tokens) in work.items {
            // streaming: the expansion chunk becomes client-visible now,
            // before terminal bookkeeping (SketchReady always precedes it).
            // A defensively-possible late completion for an already-final
            // request must not stream after its terminal event.
            if self.core.pend[rid].mode == Mode::Progressive && !self.core.pend[rid].done {
                self.core.pend[rid].first_expansion.get_or_insert(now);
                if self.core.events.is_some() {
                    let slot = self.core.pend[rid].candidates.len();
                    let text = self.tok.decode_content(&cand.tokens);
                    self.emit(now, rid, ResponseEventKind::ExpansionChunk { slot, text });
                }
            }
            let p = &mut self.core.pend[rid];
            p.edge_tokens += edge_tokens;
            p.candidates.push(cand);
            p.replicas_out = p.replicas_out.saturating_sub(1);
            let ready = p.replicas_out == 0 && !p.done;
            if ready {
                self.finalize(rid, now);
            }
        }
        self.core.q.schedule(now, Ev::EdgePull { eid });
    }

    // -- environment dynamics + failover -------------------------------------

    /// The cloud<->edge link as of simulated time `t` — `cfg.link` itself in
    /// a static world, the dynamics-retimed state otherwise. All engine
    /// callers see a monotone clock, so the bandwidth walk advances through
    /// the resumable cache instead of replaying from t=0 per event.
    fn link_now_mut(&mut self, t: SimTime) -> Link {
        self.cfg.dynamics.link.link_at_cached(
            &self.cfg.link,
            t,
            self.cfg.dynamics.seed,
            &mut self.core.walk_cache,
        )
    }

    /// Conservative estimate of the latency a request admitted *now* would
    /// inherit before its own work even starts: the cost model's Eq. 2
    /// backlog over every queued expansion job plus one sketch transfer on
    /// the current link. The SLO-aware admission gate
    /// ([`crate::serve::ServeCfg::deadline_s`]) tests deadlines against it,
    /// and fleet least-loaded placement polls it per shard.
    ///
    /// Memoized on [`Engine::events_processed`]: the estimate is a pure
    /// function of state that only moves when the event loop does, so
    /// repeated polls between events are a counter compare — and every
    /// caller (router, admission, tests) reads the *same* value by
    /// construction.
    pub fn backlog_estimate_s(&mut self) -> SimTime {
        let stamp = self.core.events_processed;
        if let Some((at, est)) = self.core.backlog_memo {
            if at == stamp {
                return est;
            }
        }
        let raw = self
            .link_now_mut(self.now())
            .transfer_tokens_s(self.cfg.scheduler.min_progressive_len);
        let est = self.core.cost_model.admission_backlog_s(&self.core.jobq, raw);
        self.core.backlog_memo = Some((stamp, est));
        est
    }

    /// Live calibration snapshot for metrics dumps (the static model
    /// reports the offline fit with identity corrections).
    pub fn calib_summary(&self) -> CalibSummary {
        self.core.cost_model.summary()
    }

    /// Persistable calibration state — None when the model is static.
    pub fn calib_state(&self) -> Option<CalibState> {
        self.core.cost_model.state()
    }

    /// The persistence key this engine's calibration is stored under —
    /// [`EngineCfg::calib_key`] of its config.
    pub fn calib_key(&self) -> String {
        self.cfg.calib_key()
    }

    /// Process one fault event from the dynamics timeline.
    fn ev_fault(&mut self, now: SimTime, eid: usize, fault: EdgeFault) {
        match fault {
            EdgeFault::Crash => {
                if !self.core.edges[eid].up {
                    return;
                }
                self.tcount("edge_crashes", 1);
                self.core.edges[eid].up = false;
                self.core.edges[eid].busy = false;
                self.core.edges[eid].speed_mult = 1.0;
                // invalidate the incarnation: in-flight EdgeDone events of
                // this edge now arrive stale and are dropped
                self.core.edges[eid].epoch += 1;
                self.core.up_edges -= 1;
                // the work that died with the node re-enters dispatch
                match std::mem::take(&mut self.core.edges[eid].inflight) {
                    EdgeInflight::Idle => {}
                    EdgeInflight::Expand(jobs) => {
                        for InflightJob { mut job, outs } in jobs {
                            // partial-result salvage: slots whose estimated
                            // completion is already past survived the node —
                            // carry them, re-queue only the unfinished rest
                            debug_assert_eq!(job.salvaged.len(), job.sentences.len());
                            let mut newly = 0usize;
                            for (si, done_at, slot) in outs {
                                if done_at <= now && job.salvaged[si].is_none() {
                                    job.salvaged[si] = Some(slot);
                                    newly += 1;
                                }
                            }
                            if newly > 0 && !self.core.pend[job.rid].done {
                                self.core.pend[job.rid].salvaged_slots += newly;
                                self.tcount("salvaged_slots", newly as u64);
                            }
                            self.redispatch_job(now, job);
                        }
                    }
                    EdgeInflight::Full(rid) => {
                        if !self.core.pend[rid].done {
                            self.core.pend[rid].failovers += 1;
                            self.tmark(rid, SpanKind::Failover, now);
                            self.tcount("failovers", 1);
                            self.dispatch_full(now, rid);
                        }
                    }
                }
                // queued-but-unstarted full-answer jobs move off the dead node
                let waiting = std::mem::take(&mut self.core.edge_fifo[eid]);
                for rid in waiting {
                    if !self.core.pend[rid].done {
                        self.core.pend[rid].failovers += 1;
                        self.tmark(rid, SpanKind::Failover, now);
                        self.tcount("failovers", 1);
                        self.dispatch_full(now, rid);
                    }
                }
                // nobody left alive and no recover scheduled: everything
                // still queued for the edges must terminate via the cloud
                if self.core.up_edges == 0 && self.core.pending_recovers == 0 {
                    loop {
                        let batch = self.core.jobq.pull_batch(usize::MAX);
                        if batch.is_empty() {
                            break;
                        }
                        for job in batch {
                            // one failover per request here, even when its
                            // primary and replicas all drain in this sweep
                            let p = &self.core.pend[job.rid];
                            if !p.done && !p.cloud_rescue {
                                self.core.pend[job.rid].failovers += 1;
                                self.tmark(job.rid, SpanKind::Failover, now);
                                self.tcount("failovers", 1);
                                self.fail_to_cloud(now, job.rid);
                            }
                        }
                    }
                    let parked: Vec<Job> = std::mem::take(&mut self.core.parked_jobs);
                    for job in parked {
                        self.fail_to_cloud(now, job.rid);
                    }
                    // backed-off jobs too: their retry timers will find the
                    // pool empty and no-op
                    let backoff: Vec<Job> = std::mem::take(&mut self.core.backoff_jobs);
                    for job in backoff {
                        self.fail_to_cloud(now, job.rid);
                    }
                    let parked_full = std::mem::take(&mut self.core.parked_full);
                    for rid in parked_full {
                        if !self.core.pend[rid].done {
                            self.dispatch_full(now, rid);
                        }
                    }
                }
            }
            EdgeFault::Recover => {
                // every Recover in the timeline is consumed exactly once,
                // whether or not the edge was actually down
                self.tcount("edge_recovers", 1);
                self.core.pending_recovers = self.core.pending_recovers.saturating_sub(1);
                if !self.core.edges[eid].up {
                    self.core.edges[eid].up = true;
                    self.core.edges[eid].busy = false;
                    self.core.edges[eid].speed_mult = 1.0;
                    self.core.edges[eid].inflight = EdgeInflight::Idle;
                    self.core.up_edges += 1;
                }
                // drain work parked during an all-edges-down window
                let parked: Vec<Job> = std::mem::take(&mut self.core.parked_jobs);
                for mut job in parked {
                    let rid = job.rid;
                    job.enqueued_at = now;
                    if !self.core.jobq.push(job) {
                        self.fallback_finalize_with_sketch(rid, now);
                    }
                }
                let parked_full = std::mem::take(&mut self.core.parked_full);
                for rid in parked_full {
                    if !self.core.pend[rid].done {
                        self.dispatch_full(now, rid);
                    }
                }
                self.core.q.schedule(now, Ev::EdgePull { eid });
            }
            EdgeFault::Slowdown { mult } => {
                self.tcount("edge_slowdowns", 1);
                if self.core.edges[eid].up {
                    // applies to work STARTED after this instant; in-flight
                    // work keeps the duration it was scheduled with
                    self.core.edges[eid].speed_mult = mult.max(0.05);
                }
            }
        }
    }

    /// Route a full-answer request to the least-loaded *live* edge; with
    /// every edge down, park it for a scheduled recover or serve it from
    /// the cloud when the timeline promises none. (In a static world every
    /// edge is up and this is exactly the old least-loaded FIFO pick.)
    fn dispatch_full(&mut self, now: SimTime, rid: usize) {
        let pick = (0..self.core.edges.len())
            .filter(|&i| self.core.edges[i].up)
            .min_by_key(|&i| self.core.edge_fifo[i].len());
        if let Some(eid) = pick {
            self.core.pend[rid].mode = Mode::EdgeFull;
            self.core.edge_fifo[eid].push_back(rid);
            self.core.q.schedule(now, Ev::EdgePull { eid });
        } else if self.core.pending_recovers > 0 {
            self.core.pend[rid].mode = Mode::EdgeFull;
            self.core.parked_full.push_back(rid);
        } else {
            // no edge will ever come back: the cloud is the answer of last
            // resort (degrades the edge-only baseline honestly)
            self.core.pend[rid].mode = Mode::CloudFull;
            self.core.pend[rid].cloud_enq = now;
            self.core.cloud_pending.push_back((rid, CloudJobKind::Full));
            self.core.q.schedule(now, Ev::CloudAdmit);
        }
    }

    /// Re-enter a failed expansion job into dispatch: fresh queue clock,
    /// sketch context preserved, counted on the request's failover tally.
    fn redispatch_job(&mut self, now: SimTime, mut job: Job) {
        let rid = job.rid;
        if self.core.pend[rid].done {
            return;
        }
        self.core.pend[rid].failovers += 1;
        self.tmark(rid, SpanKind::Failover, now);
        // salvaged slots ride along — only genuinely lost work is a retry
        self.core.pend[rid].retried_slots += job.unsalvaged();
        if let Some(t) = self.core.telem.as_mut() {
            t.registry.inc("failovers", 1);
            t.registry.inc("retried_slots", job.unsalvaged() as u64);
        }
        job.enqueued_at = now;
        if self.core.up_edges > 0 {
            if self.core.jobq.push(job) {
                for eid in 0..self.core.edges.len() {
                    if self.core.edges[eid].up && !self.core.edges[eid].busy {
                        self.core.q.schedule(now, Ev::EdgePull { eid });
                    }
                }
            } else {
                self.fallback_finalize_with_sketch(rid, now);
            }
        } else if self.core.pending_recovers > 0 {
            if self.core.tail_on {
                // tail tolerance: capped exponential backoff instead of an
                // open-ended park — see [`Engine::ev_backoff_retry`]
                self.backoff_displaced(now, job, 0);
            } else {
                self.core.parked_jobs.push(job);
            }
        } else {
            self.fail_to_cloud(now, rid);
        }
    }

    /// Tail-tolerance alternative to parking a displaced expansion job while
    /// every edge is down: hold it in the backoff pool and schedule a capped
    /// exponential retry. A transient blackout then costs roughly one backoff
    /// step instead of a full wait-for-recover, and an over-long blackout is
    /// bounded: once the retry cap is hit the cloud answers instead.
    fn backoff_displaced(&mut self, now: SimTime, job: Job, attempt: usize) {
        let rid = job.rid;
        let delay = self.cfg.tail.backoff_base_s * (1u64 << attempt.min(32)) as f64;
        self.tspan(rid, SpanKind::BackoffWait { attempt: attempt as u32 }, now, now + delay);
        self.tcount("backoff_waits", 1);
        self.core.backoff_jobs.push(job);
        self.core.q.schedule(now + delay, Ev::BackoffRetry { rid, attempt });
    }

    /// A backoff timer fired: retry dispatch of the pooled job. The pool is
    /// scanned by rid (first match — insertion order is deterministic); an
    /// absent rid means the job was already drained elsewhere (fleet
    /// re-dispatch eviction, or a no-recover-coming cloud sweep) and the
    /// timer is simply stale.
    fn ev_backoff_retry(&mut self, now: SimTime, rid: usize, attempt: usize) {
        let Some(pos) = self.core.backoff_jobs.iter().position(|j| j.rid == rid) else {
            return;
        };
        if self.core.pend[rid].done || self.core.pend[rid].cloud_rescue {
            self.core.backoff_jobs.remove(pos);
            return;
        }
        if self.core.up_edges == 0 {
            if self.core.pending_recovers > 0 && attempt + 1 < self.cfg.tail.backoff_max_retries {
                // still blacked out: double the delay, job stays pooled
                let delay =
                    self.cfg.tail.backoff_base_s * (1u64 << (attempt + 1).min(32)) as f64;
                self.tspan(
                    rid,
                    SpanKind::BackoffWait { attempt: attempt as u32 + 1 },
                    now,
                    now + delay,
                );
                self.tcount("backoff_waits", 1);
                self.core.q.schedule(now + delay, Ev::BackoffRetry { rid, attempt: attempt + 1 });
            } else {
                // retry cap hit (or no recover is ever coming): bound the
                // blackout wait — the cloud serves the full answer
                self.core.backoff_jobs.remove(pos);
                self.fail_to_cloud(now, rid);
            }
            return;
        }
        let mut job = self.core.backoff_jobs.remove(pos);
        job.enqueued_at = now;
        if self.core.jobq.push(job) {
            for eid in 0..self.core.edges.len() {
                if self.core.edges[eid].up && !self.core.edges[eid].busy {
                    self.core.q.schedule(now, Ev::EdgePull { eid });
                }
            }
        } else {
            self.fallback_finalize_with_sketch(rid, now);
        }
    }

    /// Tail-tolerance watchdog expiry: a pull armed at dispatch time has
    /// outrun its quantile timeout. Hedge it — re-enter the still-pending
    /// slots into dispatch (another up edge picks them up, or the cloud when
    /// re-queueing is impossible) and invalidate this edge's incarnation so
    /// the straggling completion is discarded on arrival. First completion
    /// wins at *slot* granularity: slots the straggler already finished are
    /// salvaged verbatim, exactly like the crash path, so hedging never
    /// regenerates done work and never double-counts `salvaged_slots`.
    fn ev_hedge_fire(&mut self, now: SimTime, eid: usize, epoch: u64) {
        if epoch != self.core.edges[eid].epoch {
            // the pull completed (or the edge crashed) before the timer fired
            return;
        }
        let jobs = match &self.core.edges[eid].inflight {
            EdgeInflight::Expand(jobs) => jobs,
            _ => return,
        };
        // hedge budget: the pull's EdgeDone is indivisible, so duplicate it
        // only if EVERY live job in the batch still has budget — otherwise
        // let the straggler finish on its own
        if !jobs.iter().all(|ij| {
            let p = &self.core.pend[ij.job.rid];
            p.done || p.hedges < self.cfg.tail.hedge_budget
        }) {
            return;
        }
        let jobs = match std::mem::take(&mut self.core.edges[eid].inflight) {
            EdgeInflight::Expand(jobs) => jobs,
            _ => unreachable!("checked above"),
        };
        // invalidate the incarnation: the straggler's EdgeDone now arrives
        // stale and is dropped wholesale (same mechanism as a crash)
        self.core.edges[eid].epoch += 1;
        self.core.edges[eid].busy = false;
        for InflightJob { mut job, outs } in jobs {
            debug_assert_eq!(job.salvaged.len(), job.sentences.len());
            let mut newly = 0usize;
            for (si, done_at, slot) in outs {
                if done_at <= now && job.salvaged[si].is_none() {
                    job.salvaged[si] = Some(slot);
                    newly += 1;
                }
            }
            let rid = job.rid;
            if self.core.pend[rid].done {
                continue;
            }
            if newly > 0 {
                self.core.pend[rid].salvaged_slots += newly;
                self.tcount("salvaged_slots", newly as u64);
            }
            self.core.pend[rid].hedges += 1;
            self.core.pend[rid].hedged_slots += job.unsalvaged();
            self.tmark(rid, SpanKind::HedgeDup { eid }, now);
            if let Some(t) = self.core.telem.as_mut() {
                t.registry.inc("hedges", 1);
                t.registry.inc("hedged_slots", job.unsalvaged() as u64);
            }
            job.enqueued_at = now;
            if self.core.jobq.push(job) {
                for e2 in 0..self.core.edges.len() {
                    if e2 != eid && self.core.edges[e2].up && !self.core.edges[e2].busy {
                        self.core.q.schedule(now, Ev::EdgePull { eid: e2 });
                    }
                }
            } else {
                // queue full: the cloud is the hedge target of last resort
                self.fail_to_cloud(now, rid);
            }
        }
        // the straggler edge goes back to pulling LAST, so an idle peer gets
        // first claim on the hedged job (the whole point of the hedge)
        self.core.q.schedule(now, Ev::EdgePull { eid });
    }

    /// Fleet failover support: drain every request this engine holds in a
    /// *displaced* state — parked, in backoff, or queued-but-unstarted — so
    /// a healthy shard can adopt it. Intended for a dead shard (all edges
    /// down): work already in the cloud path is left alone, it completes
    /// regardless. Each drained request is closed WITHOUT a terminal event
    /// (`done` is set, so any late local completion is ignored) and counted
    /// in [`Engine::evicted`], keeping `submitted − completed − evicted` an
    /// honest in-flight figure for the fleet router. Returns
    /// `(local rid, question_id, original arrival)` per evicted request.
    pub fn evict_displaced(&mut self) -> Vec<(usize, usize, SimTime)> {
        let mut rids: Vec<usize> = Vec::new();
        for job in std::mem::take(&mut self.core.parked_jobs) {
            rids.push(job.rid);
        }
        for job in std::mem::take(&mut self.core.backoff_jobs) {
            rids.push(job.rid);
        }
        rids.extend(std::mem::take(&mut self.core.parked_full));
        loop {
            let batch = self.core.jobq.pull_batch(usize::MAX);
            if batch.is_empty() {
                break;
            }
            rids.extend(batch.into_iter().map(|j| j.rid));
        }
        let mut out = Vec::new();
        for rid in rids {
            let p = &mut self.core.pend[rid];
            if p.done {
                // ensemble replicas share a rid — evict a request once
                continue;
            }
            p.done = true;
            self.core.evicted += 1;
            out.push((rid, p.question_id, p.arrival));
        }
        self.tcount("evicted", out.len() as u64);
        out
    }

    /// Last-resort failover: have the cloud produce the full answer (the
    /// request keeps its identity; whichever completion lands first wins —
    /// `finalize` is idempotent). One rescue per request: a primary job and
    /// its ensemble replicas drained in the same blackout collapse into a
    /// single cloud regeneration.
    fn fail_to_cloud(&mut self, now: SimTime, rid: usize) {
        if self.core.pend[rid].done || self.core.pend[rid].cloud_rescue {
            return;
        }
        self.core.pend[rid].cloud_rescue = true;
        self.core.pend[rid].cloud_enq = now;
        self.tmark(rid, SpanKind::CloudRescue, now);
        self.tcount("cloud_rescues", 1);
        self.core.cloud_pending.push_back((rid, CloudJobKind::Full));
        self.core.q.schedule(now, Ev::CloudAdmit);
    }

    /// Degenerate close-out: the sketch itself (or any candidate already
    /// delivered) becomes the answer — the pre-dynamics queue-full path,
    /// shared by failover when re-queueing is impossible.
    fn fallback_finalize_with_sketch(&mut self, rid: usize, now: SimTime) {
        if self.core.pend[rid].done {
            return;
        }
        if self.core.pend[rid].candidates.is_empty() {
            let sketch_cand = Candidate {
                model: self.core.cloud_model.clone(),
                tokens: self.core.pend[rid].sketch.to_vec(),
                logps: vec![-1.0; self.core.pend[rid].sketch.len()],
            };
            self.core.pend[rid].candidates = vec![sketch_cand];
        }
        self.tcount("sketch_fallbacks", 1);
        self.finalize(rid, now);
    }

    /// Ensemble-select and close out a request. Idempotent: under failover
    /// a request can race two completion paths (e.g. a surviving ensemble
    /// replica vs the cloud fallback); only the first closes the request,
    /// so exactly one terminal event is ever emitted.
    fn finalize(&mut self, rid: usize, now: SimTime) {
        if self.core.pend[rid].done {
            return;
        }
        let scale = self.cfg.sim_token_scale;
        let conf_w = self.cfg.confidence;
        let trace = {
            let p = &mut self.core.pend[rid];
            p.done = true;
            let expected_real = ((p.predicted_len as f64 / scale).round() as usize).max(1);
            let (winner, confidence) = if p.candidates.len() > 1 {
                ensemble_select(&p.candidates, &p.sketch, expected_real, conf_w)
                    .unwrap_or((0, 0.0))
            } else {
                (0, 1.0)
            };
            let cand = p.candidates.get(winner).cloned().unwrap_or(Candidate {
                model: Arc::from(""),
                tokens: Vec::new(),
                logps: Vec::new(),
            });
            RequestTrace {
                rid,
                question_id: p.question_id,
                category: p.category.clone(),
                mode: p.mode,
                sketch_level: p.sketch_level,
                predicted_len: p.predicted_len,
                cloud_tokens: p.cloud_tokens,
                edge_tokens: p.edge_tokens,
                answer: cand.tokens,
                arrival: p.arrival,
                cloud_start: p.cloud_start,
                cloud_done: p.cloud_done,
                edge_start: p.edge_start.unwrap_or(0.0),
                sketch_ready: p.sketch_ready,
                first_expansion: p.first_expansion,
                done: now,
                winner_model: cand.model.to_string(),
                confidence,
                parallelism: p.parallelism,
                failovers: p.failovers,
                retried_slots: p.retried_slots,
                salvaged_slots: p.salvaged_slots,
                requeue_retries: p.requeue_retries,
                hedges: p.hedges,
                hedged_slots: p.hedged_slots,
            }
        };
        if let Some(t) = self.core.telem.as_mut() {
            // exactly one root span per completed request — finalize is
            // idempotent and fleet-evicted requests never reach it locally
            t.span(rid, SpanKind::Request, trace.arrival, now);
            t.registry.inc("completed", 1);
            t.registry.observe("latency_s", now - trace.arrival);
        }
        self.core.traces[rid] = Some(trace);
        self.core.completed += 1;
        if self.core.events.is_some() {
            let tr = self.core.traces[rid].as_ref().unwrap().clone();
            self.emit(now, rid, ResponseEventKind::Final { trace: tr });
        }
    }
}
