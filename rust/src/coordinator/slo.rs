//! Multi-objective SLO policy (paper §IV-A1).
//!
//! Metrics M = {error, throughput, latency, server cost, edge cost} are
//! split into hard constraints (latency) and soft objectives ranked by a
//! *lexicographic* ordering: minimize M_i subject to M_j ≤ M_j(σ*_j) for all
//! higher-ranked j (within a tolerance band, as is standard for
//! lexicographic relaxation).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    Error,
    Throughput, // stored negated in vectors (all metrics minimized)
    Latency,
    ServerCost,
    EdgeCost,
}

pub const ALL_METRICS: [Metric; 5] =
    [Metric::Error, Metric::Throughput, Metric::Latency, Metric::ServerCost, Metric::EdgeCost];

#[derive(Clone, Debug)]
pub struct SloPolicy {
    /// soft-objective importance order, most important first
    pub order: Vec<Metric>,
    /// hard end-to-end latency bound multiplier relative to f(l) (Eq. 2's
    /// right-hand side); 1.0 = paper's "not slower than cloud-only".
    pub latency_slack: f64,
    /// lexicographic tolerance band (fraction of the stage optimum)
    pub tolerance: f64,
}

impl Default for SloPolicy {
    fn default() -> Self {
        // paper's implied default: efficiency-led (its evaluation accepts a
        // small quality cost on math/coding for the 1.5-2x throughput win),
        // with error next and raw costs last
        SloPolicy {
            order: vec![
                Metric::Throughput,
                Metric::Error,
                Metric::Latency,
                Metric::ServerCost,
                Metric::EdgeCost,
            ],
            latency_slack: 1.0,
            tolerance: 0.15,
        }
    }
}

impl SloPolicy {
    pub fn metric_index(&self, m: Metric) -> usize {
        ALL_METRICS.iter().position(|&x| x == m).unwrap()
    }

    /// Lexicographic selection over candidate metric vectors (indexed by
    /// ALL_METRICS; every entry is minimized — negate throughput upstream).
    /// Returns the index of the chosen candidate.
    pub fn lex_select(&self, candidates: &[[f64; 5]]) -> Option<usize> {
        if candidates.is_empty() {
            return None;
        }
        let mut alive: Vec<usize> = (0..candidates.len()).collect();
        for &m in &self.order {
            let mi = self.metric_index(m);
            let best = alive
                .iter()
                .map(|&i| candidates[i][mi])
                .fold(f64::INFINITY, f64::min);
            let band = best.abs().max(1e-9) * self.tolerance;
            let next: Vec<usize> =
                alive.iter().copied().filter(|&i| candidates[i][mi] <= best + band).collect();
            if next.len() == 1 {
                return Some(next[0]);
            }
            alive = next;
        }
        alive.first().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_primary_metric_winner() {
        let p = SloPolicy { order: vec![Metric::Latency], ..Default::default() };
        // candidate 1 has the lowest latency (index 2 of the vector)
        let c = [[0.5, -10.0, 9.0, 1.0, 1.0], [0.5, -10.0, 2.0, 1.0, 1.0]];
        assert_eq!(p.lex_select(&c), Some(1));
    }

    #[test]
    fn tie_broken_by_secondary() {
        let p = SloPolicy {
            order: vec![Metric::Error, Metric::ServerCost],
            tolerance: 0.05,
            ..Default::default()
        };
        // equal error; candidate 0 cheaper on the server
        let c = [[0.3, -5.0, 2.0, 10.0, 3.0], [0.3, -5.0, 2.0, 90.0, 3.0]];
        assert_eq!(p.lex_select(&c), Some(0));
    }

    #[test]
    fn ordering_changes_choice() {
        // A: low error, high cost. B: higher error, low cost.
        let a = [0.1, -5.0, 2.0, 100.0, 1.0];
        let b = [0.4, -5.0, 2.0, 5.0, 1.0];
        let error_first =
            SloPolicy { order: vec![Metric::Error, Metric::ServerCost], tolerance: 0.05, ..Default::default() };
        let cost_first =
            SloPolicy { order: vec![Metric::ServerCost, Metric::Error], tolerance: 0.05, ..Default::default() };
        assert_eq!(error_first.lex_select(&[a, b]), Some(0));
        assert_eq!(cost_first.lex_select(&[a, b]), Some(1));
    }

    #[test]
    fn empty_none() {
        assert_eq!(SloPolicy::default().lex_select(&[]), None);
    }
}
