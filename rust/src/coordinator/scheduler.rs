//! Cloud-side dynamic scheduling (paper §IV-A2).
//!
//! Per query, decide *whether* to run progressive inference and at *which*
//! sketch level, using the end-to-end latency constraint (Eq. 2):
//!
//!   f(|r_i|) + Δ(r_i) + c·f(l_i) + Σ_{r_j∈Q} c·f(l_j) / (p·N)  ≤  f(l_i)
//!
//! with f(.) the offline-profiled cloud latency line, c the cost
//! coefficient, Δ the network transfer, and the sum the job-queue backlog.
//! Edge latency is estimated conservatively with p = 1 (paper). Among
//! feasible levels the lexicographic SLO policy picks the operating point;
//! more capable SLMs admit shorter sketches.

use super::slo::SloPolicy;
use crate::network::TransferModel;
use crate::profiler::LatencyFit;
use crate::simclock::SimTime;
use crate::sketch::{expected_sketch_len, SketchLevel};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Full,
    Progressive,
}

#[derive(Clone, Debug)]
pub struct Decision {
    pub mode: Mode,
    pub level: SketchLevel,
    pub expected_sketch_len: usize,
}

/// Runtime inputs to one scheduling decision.
#[derive(Clone, Debug)]
pub struct SchedInput {
    /// predicted response length l_i (the LLM's length perception)
    pub predicted_len: usize,
    /// offline fit of the cloud LLM latency f(l)
    pub f_cloud: LatencyFit,
    /// cost coefficient c for the *current* best SLM/edge pair
    pub cost_coeff: f64,
    /// network transfer model for a sketch of the candidate size — derived
    /// from the *current* link state by the engine (the dynamics subsystem
    /// retimes it mid-run), so Eq. 2 routing genuinely adapts to the WAN
    pub transfer: TransferModel,
    /// backlog: Σ c·f(l_j) over queued jobs
    pub backlog_s: SimTime,
    /// number of edge devices N
    pub n_edges: usize,
    /// MMLU-like capability of the strongest available SLM (0-100)
    pub best_slm_capability: f64,
    /// runtime-observed edge expansion parallelism (EWMA from the profiler's
    /// monitor). 1.0 = the paper's conservative default; the *dynamic*
    /// scheduler feeds the achieved degree back in (Fig. 6's gap over
    /// static scheduling comes largely from this).
    pub parallel_hint: f64,
}

#[derive(Clone, Debug)]
pub struct CloudScheduler {
    pub levels: Vec<SketchLevel>,
    pub policy: SloPolicy,
    /// static mode (Fig. 6 ablation): fixed level-1 sketching by predicted
    /// length only, ignoring runtime conditions.
    pub static_mode: bool,
    /// minimum predicted length for progressive inference to be worthwhile
    /// (short answers are answered directly — paper workflow step 2a).
    pub min_progressive_len: usize,
}

impl Default for CloudScheduler {
    fn default() -> Self {
        CloudScheduler {
            levels: crate::sketch::levels(),
            policy: SloPolicy::default(),
            static_mode: false,
            min_progressive_len: 25,
        }
    }
}

impl CloudScheduler {
    /// Eq. 2 left-hand side for a candidate level.
    pub fn e2e_estimate(&self, inp: &SchedInput, level: SketchLevel) -> SimTime {
        let sk_len = expected_sketch_len(inp.predicted_len, level);
        let f_sketch = inp.f_cloud.eval(sk_len);
        let delta = inp.transfer.eval(sk_len);
        let p = inp.parallel_hint.max(1.0);
        // edge pass at the observed parallelism (p = 1 when no data yet —
        // the paper's conservative default)
        let edge = inp.cost_coeff * inp.f_cloud.eval(inp.predicted_len) / p;
        let wait = inp.backlog_s / (p * inp.n_edges.max(1) as f64);
        f_sketch + delta + edge + wait
    }

    pub fn decide(&self, inp: &SchedInput) -> Decision {
        let full = Decision {
            mode: Mode::Full,
            level: self.levels[0],
            expected_sketch_len: inp.predicted_len,
        };
        if inp.predicted_len < self.min_progressive_len || inp.n_edges == 0 {
            return full;
        }
        if self.static_mode {
            // fixed rule: always level-1 sketch for long answers
            let level = self.levels[1];
            return Decision {
                mode: Mode::Progressive,
                level,
                expected_sketch_len: expected_sketch_len(inp.predicted_len, level),
            };
        }

        let budget = inp.f_cloud.eval(inp.predicted_len) * self.policy.latency_slack;
        let feasible: Vec<SketchLevel> = self
            .levels
            .iter()
            .copied()
            .filter(|lv| lv.level > 0 && self.e2e_estimate(inp, *lv) <= budget)
            .collect();
        if feasible.is_empty() {
            // "If no level above 0 meets inequality (2), forgo progressive
            // inference and request a complete response from the LLM."
            return full;
        }
        // Lexicographic choice among feasible levels. Estimated metric
        // vectors [error, -throughput, latency, server, edge]:
        //  error       — shorter sketches leave less signal for the SLM;
        //                stronger SLMs (capability) dampen the effect.
        //  throughput  — server tokens saved per request.
        //  latency     — Eq. 2 estimate.
        let cap = (inp.best_slm_capability / 100.0).clamp(0.0, 1.0);
        let vecs: Vec<[f64; 5]> = feasible
            .iter()
            .map(|lv| {
                let sk = expected_sketch_len(inp.predicted_len, *lv) as f64;
                let err = (1.0 - lv.keep_frac * 0.7) * (1.0 - 0.6 * cap);
                let served_rate = 1.0 / sk.max(1.0); // queries/server-token
                [err, -served_rate, self.e2e_estimate(inp, *lv), sk, inp.predicted_len as f64]
            })
            .collect();
        let pick = self.policy.lex_select(&vecs).unwrap_or(0);
        let level = feasible[pick];
        Decision {
            mode: Mode::Progressive,
            level,
            expected_sketch_len: expected_sketch_len(inp.predicted_len, level),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_input() -> SchedInput {
        SchedInput {
            predicted_len: 100,
            f_cloud: LatencyFit { a: 0.2, b: 0.055 }, // ~18 tok/s cloud
            cost_coeff: 0.35,
            transfer: TransferModel { base_s: 0.02, per_token_s: 1e-5 },
            backlog_s: 0.0,
            n_edges: 4,
            best_slm_capability: 74.0,
            parallel_hint: 1.0,
        }
    }

    #[test]
    fn long_answers_go_progressive() {
        let s = CloudScheduler::default();
        let d = s.decide(&base_input());
        assert_eq!(d.mode, Mode::Progressive);
        assert!(d.level.level >= 1);
        assert!(d.expected_sketch_len < 100);
    }

    #[test]
    fn short_answers_stay_full() {
        let s = CloudScheduler::default();
        let d = s.decide(&SchedInput { predicted_len: 10, ..base_input() });
        assert_eq!(d.mode, Mode::Full);
    }

    #[test]
    fn slow_edge_forgoes_progressive() {
        let s = CloudScheduler::default();
        // c = 3: edge pass alone is 3x the cloud budget
        let d = s.decide(&SchedInput { cost_coeff: 3.0, ..base_input() });
        assert_eq!(d.mode, Mode::Full);
    }

    #[test]
    fn deep_backlog_forgoes_progressive() {
        let s = CloudScheduler::default();
        let d = s.decide(&SchedInput { backlog_s: 500.0, ..base_input() });
        assert_eq!(d.mode, Mode::Full);
    }

    #[test]
    fn degraded_link_forgoes_progressive() {
        // Eq. 2 consumes the live transfer model (dynamics subsystem): a
        // WAN bad enough that the sketch transfer alone blows the latency
        // budget must flip the decision to Full
        let s = CloudScheduler::default();
        assert_eq!(s.decide(&base_input()).mode, Mode::Progressive);
        let bad = SchedInput {
            transfer: TransferModel { base_s: 20.0, per_token_s: 1e-2 },
            ..base_input()
        };
        assert_eq!(s.decide(&bad).mode, Mode::Full);
    }

    #[test]
    fn no_edges_full() {
        let s = CloudScheduler::default();
        let d = s.decide(&SchedInput { n_edges: 0, ..base_input() });
        assert_eq!(d.mode, Mode::Full);
    }

    #[test]
    fn static_mode_ignores_backlog() {
        let s = CloudScheduler { static_mode: true, ..Default::default() };
        let d = s.decide(&SchedInput { backlog_s: 500.0, ..base_input() });
        assert_eq!(d.mode, Mode::Progressive);
        assert_eq!(d.level.level, 1);
    }

    #[test]
    fn capable_slm_gets_shorter_sketch() {
        // with server-cost prioritized, a capable SLM admits a shorter sketch
        let mut s = CloudScheduler::default();
        s.policy.order = vec![
            super::super::slo::Metric::ServerCost,
            super::super::slo::Metric::Error,
        ];
        let weak = s.decide(&SchedInput { best_slm_capability: 40.0, ..base_input() });
        let strong = s.decide(&SchedInput { best_slm_capability: 95.0, ..base_input() });
        assert!(strong.expected_sketch_len <= weak.expected_sketch_len);
    }

    #[test]
    fn parallel_hint_enables_progressive() {
        // a backlog that forgoes progressive at p=1 becomes feasible once
        // the monitor reports real parallelism
        let s = CloudScheduler::default();
        let slow = SchedInput { backlog_s: 40.0, cost_coeff: 0.9, ..base_input() };
        assert_eq!(s.decide(&slow).mode, Mode::Full);
        let fast = SchedInput { parallel_hint: 5.0, ..slow };
        assert_eq!(s.decide(&fast).mode, Mode::Progressive);
    }

    #[test]
    fn e2e_monotone_in_backlog() {
        let s = CloudScheduler::default();
        let lv = s.levels[1];
        let a = s.e2e_estimate(&base_input(), lv);
        let b = s.e2e_estimate(&SchedInput { backlog_s: 10.0, ..base_input() }, lv);
        assert!(b > a);
    }
}
