//! Cloud-side dynamic scheduling (paper §IV-A2).
//!
//! Per query, decide *whether* to run progressive inference and at *which*
//! sketch level, using the end-to-end latency constraint (Eq. 2):
//!
//!   f(|r_i|) + Δ(r_i) + c·f(l_i) + Σ_{r_j∈Q} c·f(l_j) / (p·N)  ≤  f(l_i)
//!
//! with f(.) the cloud latency line, c the cost coefficient, Δ the network
//! transfer, and the sum the job-queue backlog. The scheduler itself is a
//! pure decision rule: [`SchedInput`] describes the *query* (predicted
//! length, edge count, SLM capability) and [`Estimates`] carries the
//! *world model* — produced by the engine's [`crate::costmodel::CostModel`]
//! instance, which is either the offline fit (f, c static, p = 1 until
//! observed — the paper's conservative default) or the online-calibrated
//! re-fit. Among feasible levels the lexicographic SLO policy picks the
//! operating point; more capable SLMs admit shorter sketches.

use super::slo::SloPolicy;
use crate::costmodel::Estimates;
use crate::simclock::SimTime;
use crate::sketch::{expected_sketch_len, SketchLevel};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Full,
    Progressive,
}

#[derive(Clone, Debug)]
pub struct Decision {
    pub mode: Mode,
    pub level: SketchLevel,
    pub expected_sketch_len: usize,
}

/// The query descriptor of one scheduling decision — what varies per
/// request. Everything Eq. 2 knows about the *world* (latency fits, cost
/// coefficient, transfer, backlog, parallelism) arrives separately as
/// [`Estimates`] from the engine's cost model.
#[derive(Clone, Copy, Debug)]
pub struct SchedInput {
    /// predicted response length l_i (the LLM's length perception)
    pub predicted_len: usize,
    /// number of edge devices N
    pub n_edges: usize,
    /// MMLU-like capability of the strongest available SLM (0-100)
    pub best_slm_capability: f64,
}

#[derive(Clone, Debug)]
pub struct CloudScheduler {
    pub levels: Vec<SketchLevel>,
    pub policy: SloPolicy,
    /// static mode (Fig. 6 ablation): fixed level-1 sketching by predicted
    /// length only, ignoring runtime conditions.
    pub static_mode: bool,
    /// minimum predicted length for progressive inference to be worthwhile
    /// (short answers are answered directly — paper workflow step 2a).
    pub min_progressive_len: usize,
}

impl Default for CloudScheduler {
    fn default() -> Self {
        CloudScheduler {
            levels: crate::sketch::levels(),
            policy: SloPolicy::default(),
            static_mode: false,
            min_progressive_len: 25,
        }
    }
}

impl CloudScheduler {
    /// Eq. 2 left-hand side for a candidate level.
    pub fn e2e_estimate(&self, inp: &SchedInput, est: &Estimates, level: SketchLevel) -> SimTime {
        let sk_len = expected_sketch_len(inp.predicted_len, level);
        let f_sketch = est.f_cloud.eval(sk_len);
        let delta = est.transfer.eval(sk_len);
        let p = est.parallel_hint.max(1.0);
        // edge pass at the observed parallelism (p = 1 when no data yet —
        // the paper's conservative default)
        let edge = est.cost_coeff * est.f_cloud.eval(inp.predicted_len) / p;
        let wait = est.backlog_s / (p * inp.n_edges.max(1) as f64);
        f_sketch + delta + edge + wait
    }

    pub fn decide(&self, inp: &SchedInput, est: &Estimates) -> Decision {
        let full = Decision {
            mode: Mode::Full,
            level: self.levels[0],
            expected_sketch_len: inp.predicted_len,
        };
        if inp.predicted_len < self.min_progressive_len || inp.n_edges == 0 {
            return full;
        }
        if self.static_mode {
            // fixed rule: always level-1 sketch for long answers
            let level = self.levels[1];
            return Decision {
                mode: Mode::Progressive,
                level,
                expected_sketch_len: expected_sketch_len(inp.predicted_len, level),
            };
        }

        let budget = est.f_cloud.eval(inp.predicted_len) * self.policy.latency_slack;
        let feasible: Vec<SketchLevel> = self
            .levels
            .iter()
            .copied()
            .filter(|lv| lv.level > 0 && self.e2e_estimate(inp, est, *lv) <= budget)
            .collect();
        if feasible.is_empty() {
            // "If no level above 0 meets inequality (2), forgo progressive
            // inference and request a complete response from the LLM."
            return full;
        }
        // Lexicographic choice among feasible levels. Estimated metric
        // vectors [error, -throughput, latency, server, edge]:
        //  error       — shorter sketches leave less signal for the SLM;
        //                stronger SLMs (capability) dampen the effect.
        //  throughput  — server tokens saved per request.
        //  latency     — Eq. 2 estimate.
        let cap = (inp.best_slm_capability / 100.0).clamp(0.0, 1.0);
        let vecs: Vec<[f64; 5]> = feasible
            .iter()
            .map(|lv| {
                let sk = expected_sketch_len(inp.predicted_len, *lv) as f64;
                let err = (1.0 - lv.keep_frac * 0.7) * (1.0 - 0.6 * cap);
                let served_rate = 1.0 / sk.max(1.0); // queries/server-token
                [
                    err,
                    -served_rate,
                    self.e2e_estimate(inp, est, *lv),
                    sk,
                    inp.predicted_len as f64,
                ]
            })
            .collect();
        let pick = self.policy.lex_select(&vecs).unwrap_or(0);
        let level = feasible[pick];
        Decision {
            mode: Mode::Progressive,
            level,
            expected_sketch_len: expected_sketch_len(inp.predicted_len, level),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::TransferModel;
    use crate::profiler::LatencyFit;

    fn base_input() -> SchedInput {
        SchedInput { predicted_len: 100, n_edges: 4, best_slm_capability: 74.0 }
    }

    fn base_est() -> Estimates {
        Estimates {
            f_cloud: LatencyFit { a: 0.2, b: 0.055 }, // ~18 tok/s cloud
            cost_coeff: 0.35,
            transfer: TransferModel { base_s: 0.02, per_token_s: 1e-5 },
            backlog_s: 0.0,
            parallel_hint: 1.0,
        }
    }

    #[test]
    fn long_answers_go_progressive() {
        let s = CloudScheduler::default();
        let d = s.decide(&base_input(), &base_est());
        assert_eq!(d.mode, Mode::Progressive);
        assert!(d.level.level >= 1);
        assert!(d.expected_sketch_len < 100);
    }

    #[test]
    fn short_answers_stay_full() {
        let s = CloudScheduler::default();
        let d = s.decide(&SchedInput { predicted_len: 10, ..base_input() }, &base_est());
        assert_eq!(d.mode, Mode::Full);
    }

    #[test]
    fn slow_edge_forgoes_progressive() {
        let s = CloudScheduler::default();
        // c = 3: edge pass alone is 3x the cloud budget
        let d = s.decide(&base_input(), &Estimates { cost_coeff: 3.0, ..base_est() });
        assert_eq!(d.mode, Mode::Full);
    }

    #[test]
    fn deep_backlog_forgoes_progressive() {
        let s = CloudScheduler::default();
        let d = s.decide(&base_input(), &Estimates { backlog_s: 500.0, ..base_est() });
        assert_eq!(d.mode, Mode::Full);
    }

    #[test]
    fn degraded_link_forgoes_progressive() {
        // Eq. 2 consumes the live transfer model (dynamics subsystem): a
        // WAN bad enough that the sketch transfer alone blows the latency
        // budget must flip the decision to Full
        let s = CloudScheduler::default();
        assert_eq!(s.decide(&base_input(), &base_est()).mode, Mode::Progressive);
        let bad = Estimates {
            transfer: TransferModel { base_s: 20.0, per_token_s: 1e-2 },
            ..base_est()
        };
        assert_eq!(s.decide(&base_input(), &bad).mode, Mode::Full);
    }

    #[test]
    fn no_edges_full() {
        let s = CloudScheduler::default();
        let d = s.decide(&SchedInput { n_edges: 0, ..base_input() }, &base_est());
        assert_eq!(d.mode, Mode::Full);
    }

    #[test]
    fn static_mode_ignores_backlog() {
        let s = CloudScheduler { static_mode: true, ..Default::default() };
        let d = s.decide(&base_input(), &Estimates { backlog_s: 500.0, ..base_est() });
        assert_eq!(d.mode, Mode::Progressive);
        assert_eq!(d.level.level, 1);
    }

    #[test]
    fn capable_slm_gets_shorter_sketch() {
        // with server-cost prioritized, a capable SLM admits a shorter sketch
        let mut s = CloudScheduler::default();
        s.policy.order = vec![
            super::super::slo::Metric::ServerCost,
            super::super::slo::Metric::Error,
        ];
        let weak =
            s.decide(&SchedInput { best_slm_capability: 40.0, ..base_input() }, &base_est());
        let strong =
            s.decide(&SchedInput { best_slm_capability: 95.0, ..base_input() }, &base_est());
        assert!(strong.expected_sketch_len <= weak.expected_sketch_len);
    }

    #[test]
    fn parallel_hint_enables_progressive() {
        // a backlog that forgoes progressive at p=1 becomes feasible once
        // the cost model reports real achieved parallelism
        let s = CloudScheduler::default();
        let slow = Estimates { backlog_s: 40.0, cost_coeff: 0.9, ..base_est() };
        assert_eq!(s.decide(&base_input(), &slow).mode, Mode::Full);
        let fast = Estimates { parallel_hint: 5.0, ..slow };
        assert_eq!(s.decide(&base_input(), &fast).mode, Mode::Progressive);
    }

    #[test]
    fn e2e_monotone_in_backlog() {
        let s = CloudScheduler::default();
        let lv = s.levels[1];
        let a = s.e2e_estimate(&base_input(), &base_est(), lv);
        let b =
            s.e2e_estimate(&base_input(), &Estimates { backlog_s: 10.0, ..base_est() }, lv);
        assert!(b > a);
    }
}
