//! Job dispatching (paper Algorithm 1): multi-list scheduling by expected
//! answer length.
//!
//! Expansion jobs enter length-bucketed lists; an idle edge device pulls a
//! *batch* from the currently longest list, so co-scheduled sequences have
//! similar lengths (mitigating straggler waste — the paper's motivation for
//! multi-list over a single FIFO).

use std::collections::VecDeque;
use std::sync::Arc;

use crate::profiler::LatencyFit;
use crate::simclock::SimTime;

/// A sentence expansion completed before its edge crashed, carried across
/// the failover re-dispatch so the next edge regenerates only the slots
/// that were genuinely lost (PERF.md §Dynamics: partial-result salvage).
#[derive(Clone, Debug)]
pub struct SalvagedSlot {
    pub tokens: Vec<u32>,
    pub logps: Vec<f64>,
    /// simulated token count the slot was charged when generated
    pub sim_tokens: usize,
}

/// One queued expansion job. Token payloads are shared `Arc<[u32]>` slices:
/// jobs are cloned on every ensemble re-queue and embedded in events, so
/// sharing turns those clones into reference bumps instead of token copies.
#[derive(Clone, Debug)]
pub struct Job {
    pub rid: usize,
    /// expected full-answer length l_i (the bucketing key)
    pub expected_len: usize,
    /// sketch sentences to expand (token ids per sentence)
    pub sentences: Vec<Arc<[u32]>>,
    /// slots rescued from a crashed edge, index-aligned with `sentences`
    /// (`None` = still needs generation). Empty only in unit fixtures.
    pub salvaged: Vec<Option<SalvagedSlot>>,
    /// full sketch (context for the expansion prompt)
    pub full_sketch: Arc<[u32]>,
    pub question: Arc<[u32]>,
    pub enqueued_at: SimTime,
    /// how many ensemble replicas of this job remain to be launched
    pub replicas_left: usize,
}

impl Job {
    /// Sentence slots that still need generation (not salvaged).
    pub fn unsalvaged(&self) -> usize {
        self.sentences.len() - self.salvaged.iter().filter(|s| s.is_some()).count()
    }
}

/// Length-bucketed multi-list queue.
#[derive(Clone, Debug)]
pub struct MultiListQueue {
    /// ascending upper bounds; last bucket is unbounded
    bounds: Vec<usize>,
    lists: Vec<VecDeque<Job>>,
    /// optional total-capacity cap (Fig. 13's job-queue length knob);
    /// pushes beyond it are rejected so the scheduler falls back to Full.
    pub capacity: usize,
}

impl MultiListQueue {
    pub fn new(bounds: Vec<usize>, capacity: usize) -> Self {
        let n = bounds.len() + 1;
        MultiListQueue { bounds, lists: (0..n).map(|_| VecDeque::new()).collect(), capacity }
    }

    /// Paper defaults: buckets at 40/80/120 tokens, queue cap 4-8.
    pub fn standard(capacity: usize) -> Self {
        MultiListQueue::new(vec![40, 80, 120], capacity)
    }

    pub fn bucket_of(&self, expected_len: usize) -> usize {
        self.bounds.iter().position(|&b| expected_len < b).unwrap_or(self.bounds.len())
    }

    /// Lines 3-6 of Algorithm 1. Returns false (rejecting the job) when the
    /// queue is at capacity.
    pub fn push(&mut self, job: Job) -> bool {
        if self.len() >= self.capacity {
            return false;
        }
        let b = self.bucket_of(job.expected_len);
        self.lists[b].push_back(job);
        true
    }

    pub fn len(&self) -> usize {
        self.lists.iter().map(VecDeque::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Σ over queued jobs of expected length (for the Eq. 2 backlog term).
    pub fn backlog_tokens(&self) -> usize {
        self.lists.iter().flatten().map(|j| j.expected_len).sum()
    }

    /// Eq. 2 backlog cost: Σ over queued jobs of f(l_j), the affine latency
    /// fit evaluated *per job* (times the caller's constant c). Evaluating
    /// f(Σ l_j) instead would drop one intercept `a` per queued job and
    /// undercount backlog at deep queues. Empty queue costs exactly 0.
    pub fn backlog_cost(&self, fit: &LatencyFit) -> SimTime {
        self.lists.iter().flatten().map(|j| fit.eval(j.expected_len)).sum()
    }

    /// Lines 9-10 of Algorithm 1: take up to `max_n` jobs from the longest
    /// list (FIFO within the list).
    pub fn pull_batch(&mut self, max_n: usize) -> Vec<Job> {
        if max_n == 0 {
            return Vec::new();
        }
        let Some(li) = (0..self.lists.len()).max_by_key(|&i| self.lists[i].len()) else {
            return Vec::new();
        };
        if self.lists[li].is_empty() {
            return Vec::new();
        }
        let n = max_n.min(self.lists[li].len());
        self.lists[li].drain(..n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(rid: usize, len: usize) -> Job {
        Job {
            rid,
            expected_len: len,
            sentences: vec![],
            salvaged: vec![],
            full_sketch: Vec::new().into(),
            question: Vec::new().into(),
            enqueued_at: 0.0,
            replicas_left: 1,
        }
    }

    #[test]
    fn buckets_by_length() {
        let q = MultiListQueue::standard(100);
        assert_eq!(q.bucket_of(10), 0);
        assert_eq!(q.bucket_of(40), 1);
        assert_eq!(q.bucket_of(100), 2);
        assert_eq!(q.bucket_of(500), 3);
    }

    #[test]
    fn pulls_from_longest_list() {
        let mut q = MultiListQueue::standard(100);
        q.push(job(1, 10));
        q.push(job(2, 100));
        q.push(job(3, 101));
        q.push(job(4, 102));
        let batch = q.pull_batch(8);
        // bucket [80,120) has 3 jobs -> pulled first
        assert_eq!(batch.len(), 3);
        assert!(batch.iter().all(|j| (80..120).contains(&j.expected_len)));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn batch_is_fifo_within_list() {
        let mut q = MultiListQueue::standard(100);
        for rid in 0..5 {
            q.push(job(rid, 50));
        }
        let batch = q.pull_batch(3);
        assert_eq!(batch.iter().map(|j| j.rid).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn capacity_rejects() {
        let mut q = MultiListQueue::standard(2);
        assert!(q.push(job(1, 10)));
        assert!(q.push(job(2, 10)));
        assert!(!q.push(job(3, 10)));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn backlog_sums_lengths() {
        let mut q = MultiListQueue::standard(10);
        q.push(job(1, 30));
        q.push(job(2, 90));
        assert_eq!(q.backlog_tokens(), 120);
    }

    #[test]
    fn backlog_cost_is_per_job_sum() {
        // regression: backlog must be Σ f(l_j), not f(Σ l_j) — the latter
        // drops one intercept per queued job
        let fit = LatencyFit { a: 0.5, b: 0.01 };
        let mut q = MultiListQueue::standard(10);
        assert_eq!(q.backlog_cost(&fit), 0.0);
        q.push(job(1, 30));
        q.push(job(2, 90));
        q.push(job(3, 200));
        let per_job = fit.eval(30) + fit.eval(90) + fit.eval(200);
        assert!((q.backlog_cost(&fit) - per_job).abs() < 1e-12);
        let summed_tokens = fit.eval(q.backlog_tokens());
        assert!(
            q.backlog_cost(&fit) > summed_tokens + 2.0 * fit.a - 1e-9,
            "per-job sum must carry one intercept per job"
        );
    }

    #[test]
    fn pull_empty_is_empty() {
        let mut q = MultiListQueue::standard(10);
        assert!(q.pull_batch(4).is_empty());
    }
}
