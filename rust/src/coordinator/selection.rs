//! Edge-side online model selection (paper Algorithm 2).
//!
//! Each edge device keeps a *current* SLM. Before executing a task it
//! estimates the remaining processing time τ with the current model:
//!   * τ over budget  -> switch DOWN to a smaller SLM (hard constraint);
//!   * τ under budget and the job queue is short -> consider upgrading to a
//!     larger (higher-quality) SLM, accounting for the switch cost.
//! Switch churn is bounded by only upgrading when |JobQueue| < maximum.

use crate::cluster::DeviceSpec;
use crate::models::ModelInfo;
use crate::simclock::SimTime;

#[derive(Clone, Debug)]
pub struct SelectionOutcome {
    pub model: String,
    pub switched: bool,
    /// model-loading time paid when switching
    pub switch_cost_s: SimTime,
}

/// Estimated time for `model` on `dev` to expand a task of `tokens` output
/// tokens (parallelism-1 conservative estimate, matching the scheduler).
pub fn task_time_s(dev: &DeviceSpec, model: &ModelInfo, tokens: usize, prompt: usize) -> SimTime {
    dev.prefill_time_s(model, prompt, 1) + dev.gen_time_s(model, tokens, 1)
}

/// Algorithm 2. `candidates` must be edge-deployable SLMs sorted by
/// ascending capability (size). `budget_s` = f(l_i) − f(|r_i|).
pub fn select_model(
    dev: &DeviceSpec,
    candidates: &[&ModelInfo],
    current: &str,
    task_tokens: usize,
    prompt_tokens: usize,
    budget_s: SimTime,
    queue_len: usize,
    queue_max: usize,
) -> SelectionOutcome {
    let cur_idx = candidates.iter().position(|m| m.name == current).unwrap_or(0);
    let cur = candidates[cur_idx];
    let tau = task_time_s(dev, cur, task_tokens, prompt_tokens);

    if tau > budget_s {
        // lines 3-4: must switch to a smaller SLM; take the largest one that
        // meets the budget including its load cost, else the smallest.
        for i in (0..cur_idx).rev() {
            let m = candidates[i];
            let cost = dev.model_load_s(m);
            if task_time_s(dev, m, task_tokens, prompt_tokens) + cost <= budget_s {
                return SelectionOutcome { model: m.name.clone(), switched: true, switch_cost_s: cost };
            }
        }
        if cur_idx == 0 {
            return SelectionOutcome { model: cur.name.clone(), switched: false, switch_cost_s: 0.0 };
        }
        let m = candidates[0];
        return SelectionOutcome {
            model: m.name.clone(),
            switched: true,
            switch_cost_s: dev.model_load_s(m),
        };
    }

    // lines 6-12: consider upgrading only when the queue is short.
    if queue_len < queue_max {
        for i in (cur_idx + 1..candidates.len()).rev() {
            let m = candidates[i];
            if !dev.fits(m) {
                continue;
            }
            let cost = dev.model_load_s(m);
            if task_time_s(dev, m, task_tokens, prompt_tokens) + cost < budget_s {
                return SelectionOutcome { model: m.name.clone(), switched: true, switch_cost_s: cost };
            }
        }
    }
    SelectionOutcome { model: cur.name.clone(), switched: false, switch_cost_s: 0.0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Registry;

    fn slms(r: &Registry) -> Vec<&ModelInfo> {
        // ascending capability: 1.5b, 7b, 8b
        vec![
            r.get("qwen1.5b-sim").unwrap(),
            r.get("qwen7b-sim").unwrap(),
            r.get("llama8b-sim").unwrap(),
        ]
    }

    #[test]
    fn tight_budget_downgrades() {
        let r = Registry::builtin();
        let dev = DeviceSpec::jetson_orin("e");
        let c = slms(&r);
        // 8B on a Jetson ~ 8.4 tok/s; 120 tokens ~ 14 s. Budget 9 s forces a
        // downgrade (1.5B does it in ~6 s).
        let out = select_model(&dev, &c, "llama8b-sim", 120, 30, 9.0, 3, 8);
        assert!(out.switched);
        assert_ne!(out.model, "llama8b-sim");
    }

    #[test]
    fn loose_budget_and_short_queue_upgrades() {
        let r = Registry::builtin();
        let dev = DeviceSpec::jetson_orin("e");
        let c = slms(&r);
        let out = select_model(&dev, &c, "qwen1.5b-sim", 60, 30, 500.0, 1, 8);
        assert!(out.switched);
        assert_eq!(out.model, "llama8b-sim");
        assert!(out.switch_cost_s > 0.0);
    }

    #[test]
    fn full_queue_blocks_upgrades() {
        let r = Registry::builtin();
        let dev = DeviceSpec::jetson_orin("e");
        let c = slms(&r);
        let out = select_model(&dev, &c, "qwen1.5b-sim", 60, 30, 500.0, 8, 8);
        assert!(!out.switched);
        assert_eq!(out.model, "qwen1.5b-sim");
    }

    #[test]
    fn impossible_budget_keeps_smallest() {
        let r = Registry::builtin();
        let dev = DeviceSpec::jetson_orin("e");
        let c = slms(&r);
        let out = select_model(&dev, &c, "qwen1.5b-sim", 500, 30, 0.001, 3, 8);
        assert!(!out.switched);
        assert_eq!(out.model, "qwen1.5b-sim");
    }

    #[test]
    fn switch_cost_counted() {
        let r = Registry::builtin();
        let dev = DeviceSpec::jetson_orin("e");
        let c = slms(&r);
        // budget fits the 7B's compute but not compute+load -> settle for a
        // model whose total (compute + switch) meets the budget
        let m7 = r.get("qwen7b-sim").unwrap();
        let load = dev.model_load_s(m7);
        let compute = task_time_s(&dev, m7, 60, 30);
        let budget = compute + load * 0.5;
        let out = select_model(&dev, &c, "qwen1.5b-sim", 60, 30, budget, 1, 8);
        // upgrading to 7B would blow the budget due to load time
        assert_ne!(out.model, "qwen7b-sim");
    }
}
