//! Text-generation backend abstraction + the batched/parallel execution
//! layer.
//!
//! The serving engine is generic over *how* tokens are produced:
//!  * [`RealBackend`] — the production path: PJRT picoLM inference
//!    (artifacts required; used by examples/benches).
//!  * [`SurrogateBackend`] — a deterministic corpus-driven mock with
//!    capacity-calibrated corruption, used by unit/property tests so the
//!    full coordinator logic is testable without artifacts and in O(μs).
//!
//! Every backend speaks the batch protocol ([`TextBackend::generate_batch`])
//! so the engine can hand all jobs co-scheduled at one sim timestamp to the
//! substrate in one call. Two composable wrappers exploit that:
//!  * [`ParallelBackend`] shards a batch across a fixed pool of OS threads,
//!    each owning its own backend replica; results merge by request index,
//!    so output is bit-identical to the sequential path.
//!  * [`MemoBackend`] adds a bounded memo-cache keyed by
//!    (model, prompt, sampling params) — bench workloads replay the same
//!    questions across figures, so repeated generations become lookups.
//!    The store itself is an `Arc`-shareable
//!    [`SharedMemoCache`](crate::sweep::cache::SharedMemoCache) — a façade
//!    over the paged buffer pool in [`crate::store`] (budgeted residency,
//!    clock eviction, disk spill): N concurrent engines (sweep scenarios)
//!    can hit ONE in-process cache.
//!  * [`PersistentMemoBackend`] extends the memo-cache across *processes*:
//!    the cache binds to a versioned, stamp-guarded paged store directory
//!    at construction (only the manifest is read; pages fault in on
//!    demand) and flushes dirty pages on save/drop, so separate bench runs
//!    share one cache.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use crate::corpus::Corpus;
use crate::models::Registry;
use crate::runtime::{GenOutput, GenScratch, Generator, LoadedModel, RuntimeHandle, SamplingParams};
use crate::sweep::cache::{load_snapshot, MemoKey, SharedMemoCache, SnapshotState};
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

/// One generation request inside a batch. Prompts are shared slices so a
/// request can be fanned out (replicas, retries) without copying tokens;
/// model names are interned `Arc<str>` so per-request fan-out (one request
/// per sentence per job) bumps a refcount instead of allocating a String.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub model: Arc<str>,
    pub prompt: Arc<[u32]>,
    pub sp: SamplingParams,
}

impl GenRequest {
    pub fn new(model: &str, prompt: &[u32], sp: SamplingParams) -> GenRequest {
        GenRequest { model: Arc::from(model), prompt: Arc::from(prompt), sp }
    }
}

pub trait TextBackend {
    /// Generate a continuation of `prompt` with `model`.
    fn generate(
        &mut self,
        model: &str,
        prompt: &[u32],
        sp: &SamplingParams,
    ) -> Result<GenOutput, String>;

    /// Execute a batch of independent generation requests; the result at
    /// index i corresponds to `reqs[i]`. The default implementation is the
    /// sequential loop, so every backend keeps working unchanged;
    /// batch-aware backends override it to exploit parallel hardware or
    /// lockstep decoding.
    fn generate_batch(&mut self, reqs: &[GenRequest]) -> Vec<Result<GenOutput, String>> {
        reqs.iter().map(|r| self.generate(&r.model, &r.prompt, &r.sp)).collect()
    }

    /// (hits, misses) of the outermost memo-cache layer, if any — lets
    /// callers holding a `Box<dyn TextBackend>` report cache effectiveness
    /// without knowing the concrete wrapper stack.
    fn memo_stats(&self) -> Option<(u64, u64)> {
        None
    }
}

/// Boxed backends are backends, so wrapper stacks can be composed from
/// trait objects (e.g. `MemoBackend<Box<dyn TextBackend + Send>>` over
/// whichever substrate `Env::load` picked).
impl<T: TextBackend + ?Sized> TextBackend for Box<T> {
    fn generate(
        &mut self,
        model: &str,
        prompt: &[u32],
        sp: &SamplingParams,
    ) -> Result<GenOutput, String> {
        (**self).generate(model, prompt, sp)
    }

    fn generate_batch(&mut self, reqs: &[GenRequest]) -> Vec<Result<GenOutput, String>> {
        (**self).generate_batch(reqs)
    }

    fn memo_stats(&self) -> Option<(u64, u64)> {
        (**self).memo_stats()
    }
}

// ---------------------------------------------------------------------------
// Real backend (PJRT)
// ---------------------------------------------------------------------------

pub struct RealBackend {
    rt: Arc<RuntimeHandle>,
    models_dir: PathBuf,
    eos: u32,
    loaded: HashMap<String, LoadedModel>,
    /// host-side buffers reused across every generate call (padded prompt,
    /// state mirror, sampling probs) — no per-call allocation churn
    scratch: GenScratch,
}

impl RealBackend {
    pub fn new(artifacts: &std::path::Path, eos: u32) -> Result<Self, String> {
        let rt = RuntimeHandle::cpu().map_err(|e| e.to_string())?;
        Ok(RealBackend {
            rt,
            models_dir: artifacts.join("models"),
            eos,
            loaded: HashMap::new(),
            scratch: GenScratch::default(),
        })
    }

    fn model(&mut self, name: &str) -> Result<&LoadedModel, String> {
        if !self.loaded.contains_key(name) {
            let m = LoadedModel::load(self.rt.clone(), &self.models_dir.join(name))
                .map_err(|e| format!("load {name}: {e}"))?;
            self.loaded.insert(name.to_string(), m);
        }
        Ok(&self.loaded[name])
    }
}

impl TextBackend for RealBackend {
    fn generate(
        &mut self,
        model: &str,
        prompt: &[u32],
        sp: &SamplingParams,
    ) -> Result<GenOutput, String> {
        let eos = self.eos;
        let mut scratch = std::mem::take(&mut self.scratch);
        let res = match self.model(model) {
            Ok(m) => Generator::new(m, eos)
                .generate_with(prompt, sp, &mut scratch)
                .map_err(|e| e.to_string()),
            Err(e) => Err(e),
        };
        self.scratch = scratch;
        res
    }

    /// Runs of consecutive same-model requests decode in lockstep via
    /// [`Generator::generate_many`]: K sequences advance one token per
    /// round, sharing the scratch buffers, instead of K full back-to-back
    /// generations. Lockstep width is capped at [`MAX_LOCKSTEP`] — every
    /// in-flight sequence holds a full device-side state buffer (KV +
    /// logits), so an uncapped batch would multiply device memory by the
    /// batch width.
    fn generate_batch(&mut self, reqs: &[GenRequest]) -> Vec<Result<GenOutput, String>> {
        let eos = self.eos;
        let mut scratch = std::mem::take(&mut self.scratch);
        let mut out: Vec<Result<GenOutput, String>> = Vec::with_capacity(reqs.len());
        let mut i = 0;
        while i < reqs.len() {
            let mut j = i + 1;
            while j < reqs.len() && reqs[j].model == reqs[i].model {
                j += 1;
            }
            match self.model(&reqs[i].model) {
                Err(e) => out.extend((i..j).map(|_| Err(e.clone()))),
                Ok(m) => {
                    let gen = Generator::new(m, eos);
                    let mut k = i;
                    while k < j {
                        let kk = (k + MAX_LOCKSTEP).min(j);
                        let run: Vec<(&[u32], SamplingParams)> =
                            reqs[k..kk].iter().map(|r| (r.prompt.as_ref(), r.sp)).collect();
                        match gen.generate_many(&run, &mut scratch) {
                            Ok(v) => out.extend(v.into_iter().map(Ok)),
                            // a run-level failure (one bad prompt poisons the
                            // whole generate_many call) falls back to
                            // per-request generation, so result i maps to
                            // request i exactly like the sequential path
                            Err(_) => {
                                for (prompt, sp) in &run {
                                    out.push(
                                        gen.generate_with(prompt, sp, &mut scratch)
                                            .map_err(|e| e.to_string()),
                                    );
                                }
                            }
                        }
                        k = kk;
                    }
                }
            }
            i = j;
        }
        self.scratch = scratch;
        out
    }
}

/// Max sequences decoded in lockstep per [`RealBackend::generate_batch`]
/// run — bounds the number of simultaneously-resident device state buffers.
const MAX_LOCKSTEP: usize = 8;

// ---------------------------------------------------------------------------
// Parallel backend (thread-pool sharding)
// ---------------------------------------------------------------------------

/// Shards [`TextBackend::generate_batch`] across a fixed pool of OS
/// threads. Each worker owns its own backend replica built by the factory
/// at construction (its own `LoadedModel` handles / surrogate state), and a
/// batch is split into contiguous chunks merged back by request index —
/// so as long as each replica is a pure function of
/// (model, prompt, sampling params), which both shipped backends are (the
/// per-request RNG seed arrives inside [`SamplingParams`]), output is
/// **bit-identical** to the sequential path regardless of worker count or
/// completion order.
pub struct ParallelBackend<B: TextBackend + Send + 'static> {
    txs: Vec<mpsc::Sender<(usize, Vec<GenRequest>)>>,
    rx: mpsc::Receiver<(usize, Vec<Result<GenOutput, String>>)>,
    handles: Vec<thread::JoinHandle<()>>,
    next: usize,
    _marker: std::marker::PhantomData<B>,
}

impl<B: TextBackend + Send + 'static> ParallelBackend<B> {
    /// Spawn `n_workers` threads; `factory(w)` builds worker w's replica.
    pub fn new<F: FnMut(usize) -> B>(n_workers: usize, mut factory: F) -> Self {
        let n = n_workers.max(1);
        let (res_tx, rx) = mpsc::channel();
        let mut txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for w in 0..n {
            let (tx, wrx) = mpsc::channel::<(usize, Vec<GenRequest>)>();
            let res_tx = res_tx.clone();
            let mut backend = factory(w);
            handles.push(thread::spawn(move || {
                while let Ok((offset, chunk)) = wrx.recv() {
                    // a panicking replica must still answer its chunk, or the
                    // merge loop would wait forever for the missing offset
                    let n = chunk.len();
                    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        backend.generate_batch(&chunk)
                    }))
                    .unwrap_or_else(|_| {
                        (0..n)
                            .map(|_| Err("parallel backend: worker panicked".to_string()))
                            .collect()
                    });
                    if res_tx.send((offset, res)).is_err() {
                        break;
                    }
                }
            }));
            txs.push(tx);
        }
        ParallelBackend { txs, rx, handles, next: 0, _marker: std::marker::PhantomData }
    }

    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    fn run_chunk(&mut self, worker: usize, reqs: Vec<GenRequest>) -> Vec<Result<GenOutput, String>> {
        let n = reqs.len();
        if self.txs[worker].send((0, reqs)).is_err() {
            return (0..n).map(|_| Err("parallel backend: worker died".to_string())).collect();
        }
        match self.rx.recv() {
            Ok((_, res)) => res,
            Err(_) => (0..n).map(|_| Err("parallel backend: worker died".to_string())).collect(),
        }
    }
}

impl<B: TextBackend + Send + 'static> TextBackend for ParallelBackend<B> {
    fn generate(
        &mut self,
        model: &str,
        prompt: &[u32],
        sp: &SamplingParams,
    ) -> Result<GenOutput, String> {
        let w = self.next % self.txs.len();
        self.next += 1;
        self.run_chunk(w, vec![GenRequest::new(model, prompt, *sp)])
            .pop()
            .unwrap_or_else(|| Err("parallel backend: empty result".to_string()))
    }

    fn generate_batch(&mut self, reqs: &[GenRequest]) -> Vec<Result<GenOutput, String>> {
        if reqs.is_empty() {
            return Vec::new();
        }
        if reqs.len() == 1 || self.txs.len() == 1 {
            let w = self.next % self.txs.len();
            self.next += 1;
            return self.run_chunk(w, reqs.to_vec());
        }
        // contiguous chunks (one per worker) keep messaging overhead at
        // O(workers) per batch rather than O(requests)
        let per = reqs.len().div_ceil(self.txs.len());
        let mut sent = 0usize;
        for (ci, chunk) in reqs.chunks(per).enumerate() {
            // a closed channel means the worker is gone; its indices stay
            // None and surface below as per-request errors
            if self.txs[ci % self.txs.len()].send((ci * per, chunk.to_vec())).is_ok() {
                sent += 1;
            }
        }
        let mut out: Vec<Option<Result<GenOutput, String>>> =
            std::iter::repeat_with(|| None).take(reqs.len()).collect();
        for _ in 0..sent {
            let Ok((offset, res)) = self.rx.recv() else { break };
            for (k, r) in res.into_iter().enumerate() {
                out[offset + k] = Some(r);
            }
        }
        out.into_iter()
            .map(|o| o.unwrap_or_else(|| Err("parallel backend: missing result".to_string())))
            .collect()
    }
}

impl<B: TextBackend + Send + 'static> Drop for ParallelBackend<B> {
    fn drop(&mut self) {
        self.txs.clear(); // closing the channels ends the worker loops
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Memoizing backend (bounded generation cache)
// ---------------------------------------------------------------------------

/// Bounded FIFO memo-cache over any backend, keyed by the full generation
/// request (model, prompt tokens, sampling params). Sound because both
/// shipped backends are deterministic functions of that key; errors are
/// never cached. Batch misses are forwarded to the inner backend as one
/// batch, so the cache composes with [`ParallelBackend`] sharding.
///
/// The store is a [`SharedMemoCache`]: [`MemoBackend::new`] makes a
/// private one (classic single-engine memoization), while
/// [`MemoBackend::shared`] attaches to an existing `Arc`-shared cache with
/// an `owner` id — the sweep layer gives each concurrent scenario its own
/// owner so hits across scenarios are counted as cross-variant hits.
pub struct MemoBackend<B: TextBackend> {
    inner: B,
    cache: Arc<SharedMemoCache>,
    owner: u32,
}

impl<B: TextBackend> MemoBackend<B> {
    pub fn new(inner: B, capacity: usize) -> Self {
        MemoBackend { inner, cache: Arc::new(SharedMemoCache::new(capacity)), owner: 0 }
    }

    /// Wrap `inner` over an existing shared cache; `owner` tags this
    /// handle's insertions for cross-variant hit accounting.
    pub fn shared(inner: B, cache: Arc<SharedMemoCache>, owner: u32) -> Self {
        MemoBackend { inner, cache, owner }
    }

    /// (hits, misses) of the underlying cache — process-global when the
    /// cache is shared.
    pub fn stats(&self) -> (u64, u64) {
        let s = self.cache.stats();
        (s.hits, s.misses)
    }

    pub fn hit_rate(&self) -> f64 {
        self.cache.stats().hit_rate()
    }

    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }

    pub fn inner_mut(&mut self) -> &mut B {
        &mut self.inner
    }

    pub fn cache(&self) -> &Arc<SharedMemoCache> {
        &self.cache
    }

    fn insert(&mut self, key: MemoKey, out: GenOutput) {
        self.cache.insert(key, out, self.owner);
    }
}

impl<B: TextBackend> TextBackend for MemoBackend<B> {
    fn generate(
        &mut self,
        model: &str,
        prompt: &[u32],
        sp: &SamplingParams,
    ) -> Result<GenOutput, String> {
        let key = MemoKey::new(model, prompt, sp);
        if let Some(hit) = self.cache.get(&key, self.owner) {
            return Ok(hit);
        }
        let out = self.inner.generate(model, prompt, sp)?;
        self.insert(key, out.clone());
        Ok(out)
    }

    fn generate_batch(&mut self, reqs: &[GenRequest]) -> Vec<Result<GenOutput, String>> {
        let mut out: Vec<Option<Result<GenOutput, String>>> =
            std::iter::repeat_with(|| None).take(reqs.len()).collect();
        let mut miss_idx: Vec<usize> = Vec::new();
        let mut misses: Vec<GenRequest> = Vec::new();
        for (i, r) in reqs.iter().enumerate() {
            let key = MemoKey::new(&r.model, &r.prompt, &r.sp);
            if let Some(hit) = self.cache.get(&key, self.owner) {
                out[i] = Some(Ok(hit));
            } else {
                miss_idx.push(i);
                misses.push(r.clone());
            }
        }
        let results = self.inner.generate_batch(&misses);
        for (i, res) in miss_idx.into_iter().zip(results) {
            if let Ok(o) = &res {
                let r = &reqs[i];
                self.insert(MemoKey::new(&r.model, &r.prompt, &r.sp), o.clone());
            }
            out[i] = Some(res);
        }
        out.into_iter()
            .map(|o| o.unwrap_or_else(|| Err("memo backend: missing result".to_string())))
            .collect()
    }

    fn memo_stats(&self) -> Option<(u64, u64)> {
        Some(self.stats())
    }
}

// ---------------------------------------------------------------------------
// Persistent memo backend (cross-run generation cache)
// ---------------------------------------------------------------------------

/// A [`MemoBackend`] whose contents survive the process: the cache is
/// attached to a paged on-disk store at construction (only the manifest is
/// read — pages fault in on demand) and dirty pages are written back on
/// [`PersistentMemoBackend::save`] (or drop). Figure benches replay the
/// same questions across separate processes, so one bench warms the cache
/// for the next.
///
/// The store machinery (paged files, versioned stamped headers, temp+rename
/// writes, one-time v1 snapshot migration) lives in [`crate::store`] behind
/// [`crate::sweep::cache`] — this type is the standalone wrapper binding
/// one private cache to one store directory. `Env::load` instead binds its
/// process-wide [`SharedMemoCache`] to the store directly, so a whole sweep
/// costs ONE attach and ONE save.
pub struct PersistentMemoBackend<B: TextBackend> {
    memo: MemoBackend<B>,
    snapshot: SnapshotState,
}

impl<B: TextBackend> PersistentMemoBackend<B> {
    /// Wrap `inner` in a memo-cache of `capacity`, restoring this `stamp`'s
    /// section of any matching-version snapshot at `path`. A missing,
    /// unreadable, or stale snapshot just means a cold start — never an
    /// error.
    pub fn load(inner: B, capacity: usize, path: impl Into<PathBuf>, stamp: &str) -> Self {
        let memo = MemoBackend::new(inner, capacity);
        let snapshot = load_snapshot(memo.cache(), path, stamp);
        PersistentMemoBackend { memo, snapshot }
    }

    /// Snapshot the cache to its bound path; other stamps' sections are
    /// written back untouched.
    pub fn save(&mut self) -> Result<(), String> {
        self.snapshot.save(self.memo.cache())
    }

    /// Entries restored from disk at construction (0 on a cold start).
    pub fn restored_entries(&self) -> usize {
        self.snapshot.restored_entries()
    }

    /// (hits, misses) since construction — hits against restored entries
    /// are cross-process hits.
    pub fn stats(&self) -> (u64, u64) {
        self.memo.stats()
    }

    pub fn hit_rate(&self) -> f64 {
        self.memo.hit_rate()
    }

    /// Full pool-level counter snapshot (evictions, spilled pages, resident
    /// bytes, non-finite skips, …) — superset of [`Self::stats`].
    pub fn cache_stats(&self) -> crate::sweep::CacheStats {
        self.memo.cache().stats()
    }

    pub fn len(&self) -> usize {
        self.memo.len()
    }

    pub fn is_empty(&self) -> bool {
        self.memo.is_empty()
    }

    pub fn path(&self) -> &std::path::Path {
        self.snapshot.path()
    }
}

impl<B: TextBackend> TextBackend for PersistentMemoBackend<B> {
    fn generate(
        &mut self,
        model: &str,
        prompt: &[u32],
        sp: &SamplingParams,
    ) -> Result<GenOutput, String> {
        self.memo.generate(model, prompt, sp)
    }

    fn generate_batch(&mut self, reqs: &[GenRequest]) -> Vec<Result<GenOutput, String>> {
        self.memo.generate_batch(reqs)
    }

    fn memo_stats(&self) -> Option<(u64, u64)> {
        Some(self.memo.stats())
    }
}

impl<B: TextBackend> Drop for PersistentMemoBackend<B> {
    fn drop(&mut self) {
        if self.snapshot.dirty(self.memo.cache()) {
            let _ = self.save();
        }
    }
}

// ---------------------------------------------------------------------------
// Surrogate backend (corpus-driven, deterministic)
// ---------------------------------------------------------------------------

/// Produces reference-derived text with a per-model corruption rate tied to
/// the Table-I MMLU ladder, so bigger models give measurably better answers
/// — the same *shape* the real picoLM ladder exhibits. Cloning yields an
/// exact replica (all state is read-only after construction), which is what
/// [`ParallelBackend`] workers rely on.
#[derive(Clone)]
pub struct SurrogateBackend {
    by_question: HashMap<Vec<u32>, usize>,
    corpus: Arc<Corpus>,
    specials: crate::tokenizer::Specials,
    /// model name -> per-token corruption probability
    err: HashMap<String, f64>,
    /// content-word id range for corruption draws
    vocab_lo: u32,
    vocab_hi: u32,
    seed: u64,
}

impl SurrogateBackend {
    pub fn new(corpus: Arc<Corpus>, tok: &Tokenizer, registry: &Registry, seed: u64) -> Self {
        let mut by_question = HashMap::new();
        for q in &corpus.questions {
            by_question.insert(q.question.clone(), q.id);
        }
        let err = registry
            .models
            .iter()
            .map(|m| (m.name.clone(), ((88.0 - m.mmlu) * 0.008).clamp(0.01, 0.5)))
            .collect();
        SurrogateBackend {
            by_question,
            corpus,
            specials: tok.specials,
            err,
            vocab_lo: 10,
            vocab_hi: tok.vocab_size() as u32,
            seed,
        }
    }

    fn corrupt(&self, tokens: &[u32], err: f64, rng: &mut Rng, keep: &[u32]) -> Vec<u32> {
        tokens
            .iter()
            .map(|&t| {
                if keep.contains(&t) || !rng.bool(err) {
                    t
                } else {
                    self.vocab_lo + (rng.next_u64() % (self.vocab_hi - self.vocab_lo) as u64) as u32
                }
            })
            .collect()
    }
}

impl TextBackend for SurrogateBackend {
    fn generate(
        &mut self,
        model: &str,
        prompt: &[u32],
        sp: &SamplingParams,
    ) -> Result<GenOutput, String> {
        let spx = self.specials;
        let err = *self.err.get(model).ok_or_else(|| format!("unknown model {model}"))?;
        // locate the question span: <q> ... (<a> | <sk>)
        let qpos = prompt.iter().position(|&t| t == spx.q).ok_or("no <q> in prompt")?;
        let qend = prompt
            .iter()
            .position(|&t| t == spx.a || t == spx.sk)
            .ok_or("no <a>/<sk> in prompt")?;
        let question: Vec<u32> = prompt[qpos + 1..qend].to_vec();
        let qid = *self.by_question.get(&question).ok_or("unknown question")?;
        let q = self.corpus.get(qid).ok_or("bad qid")?;

        let mut rng = Rng::new(
            self.seed
                ^ prompt.iter().fold(0u64, |h, &t| h.wrapping_mul(131).wrapping_add(t as u64)),
        );
        let structural = [spx.period, spx.semicolon];
        let has_ex = prompt.contains(&spx.ex);
        let last = *prompt.last().ok_or("empty prompt")?;

        let mut tokens = if has_ex && last == spx.a {
            // expansion: sentence-sketch sits between <ex> and trailing <a>
            let ex_pos = prompt.iter().rposition(|&t| t == spx.ex).unwrap();
            let sent_sketch = &prompt[ex_pos + 1..prompt.len() - 1];
            let sent = q
                .sentences
                .iter()
                .find(|s| s.sketch.starts_with(sent_sketch) || sent_sketch.starts_with(&s.sketch[..s.sketch.len().min(sent_sketch.len())]))
                .or_else(|| q.sentences.first())
                .ok_or("no sentences")?;
            self.corrupt(&sent.full, err, &mut rng, &structural)
        } else if last == spx.sk {
            // sketch generation
            let sk = q.sketch_tokens(spx.semicolon);
            self.corrupt(&sk, err * 0.5, &mut rng, &structural)
        } else {
            // full answer
            self.corrupt(&q.answer_tokens(), err, &mut rng, &structural)
        };
        tokens.truncate(sp.max_tokens.max(1).saturating_sub(1));
        if let Some(stop) = sp.stop_token {
            if let Some(i) = tokens.iter().position(|&t| t == stop) {
                tokens.truncate(i + 1);
            }
        } else {
            tokens.push(spx.eos);
        }
        // logp model: confident in proportion to (1 - err), with jitter
        let logps = tokens
            .iter()
            .map(|_| ((1.0 - err) as f64).ln() - 0.35 + rng.range(-0.05, 0.05))
            .collect();
        Ok(GenOutput { tokens, logps, finished: true })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::tests_support::toy_corpus;
    use crate::sketch::Prompts;

    fn setup() -> (SurrogateBackend, Tokenizer, Arc<Corpus>) {
        let (c, tok) = toy_corpus();
        let c = Arc::new(c);
        let b = SurrogateBackend::new(c.clone(), &tok, &Registry::builtin(), 1);
        (b, tok, c)
    }

    #[test]
    fn full_answer_resembles_reference() {
        let (mut b, tok, c) = setup();
        let q = &c.questions[0];
        let p = Prompts::full_answer(&tok, &q.question);
        let out = b
            .generate("qwen72b-sim", &p, &SamplingParams { max_tokens: 64, ..Default::default() })
            .unwrap();
        let reference = q.answer_tokens();
        let overlap = crate::quality::rouge::rouge1_f1(
            &out.tokens[..out.tokens.len() - 1],
            &reference,
        );
        assert!(overlap > 0.8, "overlap {overlap}");
    }

    #[test]
    fn small_model_more_corrupted() {
        let (mut b, tok, c) = setup();
        let q = &c.questions[0];
        let p = Prompts::full_answer(&tok, &q.question);
        let sp = SamplingParams { max_tokens: 64, ..Default::default() };
        let reference = q.answer_tokens();
        let big = b.generate("qwen72b-sim", &p, &sp).unwrap();
        let small = b.generate("qwen1.5b-sim", &p, &sp).unwrap();
        let r_big = crate::quality::rouge::rouge1_f1(&big.tokens, &reference);
        let r_small = crate::quality::rouge::rouge1_f1(&small.tokens, &reference);
        assert!(r_big >= r_small, "{r_big} < {r_small}");
    }

    #[test]
    fn expansion_stops_at_period() {
        let (mut b, tok, c) = setup();
        let q = &c.questions[0];
        let full_sk = q.sketch_tokens(tok.specials.semicolon);
        let p = Prompts::expand(&tok, &q.question, &full_sk, &q.sentences[1].sketch);
        let out = b
            .generate(
                "qwen72b-sim",
                &p,
                &SamplingParams {
                    max_tokens: 32,
                    stop_token: Some(tok.specials.period),
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(*out.tokens.last().unwrap(), tok.specials.period);
    }

    #[test]
    fn deterministic() {
        let (mut b, tok, c) = setup();
        let q = &c.questions[0];
        let p = Prompts::full_answer(&tok, &q.question);
        let sp = SamplingParams { max_tokens: 64, ..Default::default() };
        let a = b.generate("qwen7b-sim", &p, &sp).unwrap();
        let bb = b.generate("qwen7b-sim", &p, &sp).unwrap();
        assert_eq!(a.tokens, bb.tokens);
    }

    fn batch_of_prompts(b: &SurrogateBackend, tok: &Tokenizer, c: &Corpus) -> Vec<GenRequest> {
        let _ = b;
        let mut reqs = Vec::new();
        for q in &c.questions {
            let p = Prompts::full_answer(tok, &q.question);
            reqs.push(GenRequest::new(
                "qwen7b-sim",
                &p,
                SamplingParams { max_tokens: 64, seed: q.id as u64, ..Default::default() },
            ));
            let sk = Prompts::sketch(tok, &q.question);
            reqs.push(GenRequest::new(
                "qwen72b-sim",
                &sk,
                SamplingParams { max_tokens: 60, seed: q.id as u64, ..Default::default() },
            ));
        }
        reqs
    }

    #[test]
    fn default_batch_matches_sequential_calls() {
        let (mut b, tok, c) = setup();
        let reqs = batch_of_prompts(&b, &tok, &c);
        let batch = b.generate_batch(&reqs);
        for (r, out) in reqs.iter().zip(&batch) {
            let solo = b.generate(&r.model, &r.prompt, &r.sp).unwrap();
            assert_eq!(solo.tokens, out.as_ref().unwrap().tokens);
        }
    }

    #[test]
    fn parallel_backend_bit_identical_and_index_ordered() {
        let (b, tok, c) = setup();
        let reqs = batch_of_prompts(&b, &tok, &c);
        let mut seq = b.clone();
        let expect = seq.generate_batch(&reqs);
        for workers in [1usize, 2, 3, 4] {
            let mut par = ParallelBackend::new(workers, |_| b.clone());
            let got = par.generate_batch(&reqs);
            assert_eq!(got.len(), expect.len());
            for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
                let (g, e) = (g.as_ref().unwrap(), e.as_ref().unwrap());
                assert_eq!(g.tokens, e.tokens, "workers={workers} idx={i}");
                assert_eq!(g.logps, e.logps, "workers={workers} idx={i}");
            }
        }
    }

    #[test]
    fn parallel_backend_single_generate_works() {
        let (b, tok, c) = setup();
        let q = &c.questions[0];
        let p = Prompts::full_answer(&tok, &q.question);
        let sp = SamplingParams { max_tokens: 64, ..Default::default() };
        let mut seq = b.clone();
        let mut par = ParallelBackend::new(2, |_| b.clone());
        let a = seq.generate("qwen7b-sim", &p, &sp).unwrap();
        let bb = par.generate("qwen7b-sim", &p, &sp).unwrap();
        assert_eq!(a.tokens, bb.tokens);
        assert_eq!(par.workers(), 2);
    }

    #[test]
    fn parallel_backend_reports_backend_errors() {
        let (b, _tok, _c) = setup();
        let mut par = ParallelBackend::new(2, |_| b.clone());
        let reqs = vec![GenRequest::new("no-such-model", &[1, 2, 3], SamplingParams::default())];
        let out = par.generate_batch(&reqs);
        assert!(out[0].is_err());
    }

    #[test]
    fn memo_backend_hits_and_is_transparent() {
        let (b, tok, c) = setup();
        let reqs = batch_of_prompts(&b, &tok, &c);
        let mut plain = b.clone();
        let expect = plain.generate_batch(&reqs);
        let mut memo = MemoBackend::new(b.clone(), 1024);
        let first = memo.generate_batch(&reqs);
        let second = memo.generate_batch(&reqs);
        let (hits, misses) = memo.stats();
        assert_eq!(misses, reqs.len() as u64);
        assert_eq!(hits, reqs.len() as u64);
        assert!(memo.hit_rate() > 0.49 && memo.hit_rate() < 0.51);
        for ((a, bb), e) in first.iter().zip(&second).zip(&expect) {
            assert_eq!(a.as_ref().unwrap().tokens, e.as_ref().unwrap().tokens);
            assert_eq!(bb.as_ref().unwrap().tokens, e.as_ref().unwrap().tokens);
        }
    }

    #[test]
    fn memo_backend_capacity_bounded() {
        let (b, tok, c) = setup();
        let mut memo = MemoBackend::new(b, 2);
        let q = &c.questions[0];
        let p = Prompts::full_answer(&tok, &q.question);
        for seed in 0..10u64 {
            let sp = SamplingParams { max_tokens: 64, seed, ..Default::default() };
            memo.generate("qwen7b-sim", &p, &sp).unwrap();
        }
        assert!(memo.len() <= 2, "cache grew to {}", memo.len());
        let (hits, misses) = memo.stats();
        assert_eq!(hits, 0);
        assert_eq!(misses, 10);
    }

    #[test]
    fn memo_backend_does_not_cache_errors() {
        let (b, _tok, _c) = setup();
        let mut memo = MemoBackend::new(b, 8);
        let sp = SamplingParams::default();
        assert!(memo.generate("no-such-model", &[1, 2], &sp).is_err());
        assert!(memo.is_empty());
        let (hits, misses) = memo.stats();
        assert_eq!((hits, misses), (0, 1));
    }

    fn tmp_cache(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("pice_backend_cache_{}_{name}.json", std::process::id()))
    }

    #[test]
    fn persistent_memo_round_trips_across_instances() {
        let (b, tok, c) = setup();
        let reqs = batch_of_prompts(&b, &tok, &c);
        let path = tmp_cache("roundtrip");
        let _ = std::fs::remove_file(&path);

        let mut plain = b.clone();
        let expect = plain.generate_batch(&reqs);

        // first "process": cold cache, populate + save
        let first = {
            let mut pm = PersistentMemoBackend::load(b.clone(), 1024, &path, "stamp-a");
            assert_eq!(pm.restored_entries(), 0);
            let out = pm.generate_batch(&reqs);
            pm.save().unwrap();
            out
        };
        // second "process": everything restored, zero misses, bit-identical
        let mut pm = PersistentMemoBackend::load(b.clone(), 1024, &path, "stamp-a");
        assert_eq!(pm.restored_entries(), reqs.len());
        let second = pm.generate_batch(&reqs);
        let (hits, misses) = pm.stats();
        assert_eq!(misses, 0, "warm snapshot must serve every request");
        assert_eq!(hits, reqs.len() as u64);
        assert!(pm.hit_rate() > 0.99);
        for ((a, bb), e) in first.iter().zip(&second).zip(&expect) {
            let (a, bb, e) = (a.as_ref().unwrap(), bb.as_ref().unwrap(), e.as_ref().unwrap());
            assert_eq!(a.tokens, e.tokens);
            assert_eq!(bb.tokens, e.tokens);
            // logps must survive the JSON round trip bit-exactly
            assert_eq!(bb.logps, e.logps);
            assert_eq!(bb.finished, e.finished);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn persistent_memo_stale_stamp_starts_cold_and_preserves_sections() {
        let (b, tok, c) = setup();
        let reqs = batch_of_prompts(&b, &tok, &c);
        let path = tmp_cache("stale");
        let _ = std::fs::remove_file(&path);
        {
            let mut pm = PersistentMemoBackend::load(b.clone(), 1024, &path, "artifacts-v1");
            pm.generate_batch(&reqs);
            pm.save().unwrap();
        }
        // a different artifact fingerprint restores nothing...
        {
            let mut pm = PersistentMemoBackend::load(b.clone(), 1024, &path, "artifacts-v2");
            assert_eq!(pm.restored_entries(), 0, "stale stamp must not restore entries");
            pm.generate_batch(&reqs[..1]);
            pm.save().unwrap();
        }
        // ...and its save leaves the other stamp's section intact
        let pm = PersistentMemoBackend::load(b.clone(), 1024, &path, "artifacts-v1");
        assert_eq!(pm.restored_entries(), reqs.len(), "foreign section must survive a save");
        let pm2 = PersistentMemoBackend::load(b, 1024, &path, "artifacts-v2");
        assert_eq!(pm2.restored_entries(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn persistent_memo_skips_non_finite_logps() {
        let (b, _tok, _c) = setup();
        let path = tmp_cache("nonfinite");
        let _ = std::fs::remove_file(&path);
        let mut pm = PersistentMemoBackend::load(b.clone(), 8, &path, "stamp");
        let bad = GenOutput { tokens: vec![1], logps: vec![f64::NEG_INFINITY], finished: true };
        let good = GenOutput { tokens: vec![2], logps: vec![-0.5], finished: true };
        pm.memo.insert(MemoKey::new("m", &[1], &SamplingParams::default()), bad);
        pm.memo.insert(MemoKey::new("m", &[2], &SamplingParams::default()), good);
        pm.save().unwrap();
        let pm2 = PersistentMemoBackend::load(b, 8, &path, "stamp");
        assert_eq!(pm2.restored_entries(), 1, "only the finite-logp entry survives");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn persistent_memo_tolerates_corrupt_snapshot() {
        let (b, _tok, _c) = setup();
        let path = tmp_cache("corrupt");
        std::fs::write(&path, "{not json at all").unwrap();
        let pm = PersistentMemoBackend::load(b, 1024, &path, "stamp");
        assert_eq!(pm.restored_entries(), 0);
        assert!(pm.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn persistent_memo_saves_on_drop() {
        let (b, tok, c) = setup();
        let q = &c.questions[0];
        let p = Prompts::full_answer(&tok, &q.question);
        let sp = SamplingParams { max_tokens: 64, ..Default::default() };
        let path = tmp_cache("drop");
        let _ = std::fs::remove_file(&path);
        {
            let mut pm = PersistentMemoBackend::load(b.clone(), 64, &path, "stamp");
            pm.generate("qwen7b-sim", &p, &sp).unwrap();
            // no explicit save — Drop must flush the dirty cache
        }
        let pm = PersistentMemoBackend::load(b, 64, &path, "stamp");
        assert_eq!(pm.restored_entries(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn shared_cache_counts_cross_variant_hits_through_memo_handles() {
        // two memo handles over one shared cache: variant 1 replays what
        // variant 0 generated, entirely as cross-variant hits
        let (b, tok, c) = setup();
        let reqs = batch_of_prompts(&b, &tok, &c);
        let cache = Arc::new(SharedMemoCache::new(4096));
        let mut v0 = MemoBackend::shared(b.clone(), cache.clone(), 0);
        let mut v1 = MemoBackend::shared(b.clone(), cache.clone(), 1);
        let first = v0.generate_batch(&reqs);
        let second = v1.generate_batch(&reqs);
        for (a, bb) in first.iter().zip(&second) {
            assert_eq!(a.as_ref().unwrap().tokens, bb.as_ref().unwrap().tokens);
        }
        let s = cache.stats();
        assert_eq!(s.misses, reqs.len() as u64);
        assert_eq!(s.hits, reqs.len() as u64);
        assert_eq!(s.cross_hits, reqs.len() as u64, "all of variant 1's hits are cross-variant");
        assert!(s.cross_hit_rate() > 0.49 && s.cross_hit_rate() < 0.51);
    }
}
