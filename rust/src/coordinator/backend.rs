//! Text-generation backend abstraction.
//!
//! The serving engine is generic over *how* tokens are produced:
//!  * [`RealBackend`] — the production path: PJRT picoLM inference
//!    (artifacts required; used by examples/benches).
//!  * [`SurrogateBackend`] — a deterministic corpus-driven mock with
//!    capacity-calibrated corruption, used by unit/property tests so the
//!    full coordinator logic is testable without artifacts and in O(μs).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use crate::corpus::Corpus;
use crate::models::Registry;
use crate::runtime::{GenOutput, Generator, LoadedModel, RuntimeHandle, SamplingParams};
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

pub trait TextBackend {
    /// Generate a continuation of `prompt` with `model`.
    fn generate(
        &mut self,
        model: &str,
        prompt: &[u32],
        sp: &SamplingParams,
    ) -> Result<GenOutput, String>;
}

// ---------------------------------------------------------------------------
// Real backend (PJRT)
// ---------------------------------------------------------------------------

pub struct RealBackend {
    rt: Arc<RuntimeHandle>,
    models_dir: PathBuf,
    eos: u32,
    loaded: HashMap<String, LoadedModel>,
}

impl RealBackend {
    pub fn new(artifacts: &std::path::Path, eos: u32) -> Result<Self, String> {
        let rt = RuntimeHandle::cpu().map_err(|e| e.to_string())?;
        Ok(RealBackend { rt, models_dir: artifacts.join("models"), eos, loaded: HashMap::new() })
    }

    fn model(&mut self, name: &str) -> Result<&LoadedModel, String> {
        if !self.loaded.contains_key(name) {
            let m = LoadedModel::load(self.rt.clone(), &self.models_dir.join(name))
                .map_err(|e| format!("load {name}: {e}"))?;
            self.loaded.insert(name.to_string(), m);
        }
        Ok(&self.loaded[name])
    }
}

impl TextBackend for RealBackend {
    fn generate(
        &mut self,
        model: &str,
        prompt: &[u32],
        sp: &SamplingParams,
    ) -> Result<GenOutput, String> {
        let eos = self.eos;
        let m = self.model(model)?;
        Generator::new(m, eos).generate(prompt, sp).map_err(|e| e.to_string())
    }
}

// ---------------------------------------------------------------------------
// Surrogate backend (corpus-driven, deterministic)
// ---------------------------------------------------------------------------

/// Produces reference-derived text with a per-model corruption rate tied to
/// the Table-I MMLU ladder, so bigger models give measurably better answers
/// — the same *shape* the real picoLM ladder exhibits.
pub struct SurrogateBackend {
    by_question: HashMap<Vec<u32>, usize>,
    corpus: Arc<Corpus>,
    specials: crate::tokenizer::Specials,
    /// model name -> per-token corruption probability
    err: HashMap<String, f64>,
    /// content-word id range for corruption draws
    vocab_lo: u32,
    vocab_hi: u32,
    seed: u64,
}

impl SurrogateBackend {
    pub fn new(corpus: Arc<Corpus>, tok: &Tokenizer, registry: &Registry, seed: u64) -> Self {
        let mut by_question = HashMap::new();
        for q in &corpus.questions {
            by_question.insert(q.question.clone(), q.id);
        }
        let err = registry
            .models
            .iter()
            .map(|m| (m.name.clone(), ((88.0 - m.mmlu) * 0.008).clamp(0.01, 0.5)))
            .collect();
        SurrogateBackend {
            by_question,
            corpus,
            specials: tok.specials,
            err,
            vocab_lo: 10,
            vocab_hi: tok.vocab_size() as u32,
            seed,
        }
    }

    fn corrupt(&self, tokens: &[u32], err: f64, rng: &mut Rng, keep: &[u32]) -> Vec<u32> {
        tokens
            .iter()
            .map(|&t| {
                if keep.contains(&t) || !rng.bool(err) {
                    t
                } else {
                    self.vocab_lo + (rng.next_u64() % (self.vocab_hi - self.vocab_lo) as u64) as u32
                }
            })
            .collect()
    }
}

impl TextBackend for SurrogateBackend {
    fn generate(
        &mut self,
        model: &str,
        prompt: &[u32],
        sp: &SamplingParams,
    ) -> Result<GenOutput, String> {
        let spx = self.specials;
        let err = *self.err.get(model).ok_or_else(|| format!("unknown model {model}"))?;
        // locate the question span: <q> ... (<a> | <sk>)
        let qpos = prompt.iter().position(|&t| t == spx.q).ok_or("no <q> in prompt")?;
        let qend = prompt
            .iter()
            .position(|&t| t == spx.a || t == spx.sk)
            .ok_or("no <a>/<sk> in prompt")?;
        let question: Vec<u32> = prompt[qpos + 1..qend].to_vec();
        let qid = *self.by_question.get(&question).ok_or("unknown question")?;
        let q = self.corpus.get(qid).ok_or("bad qid")?;

        let mut rng = Rng::new(
            self.seed
                ^ prompt.iter().fold(0u64, |h, &t| h.wrapping_mul(131).wrapping_add(t as u64)),
        );
        let structural = [spx.period, spx.semicolon];
        let has_ex = prompt.contains(&spx.ex);
        let last = *prompt.last().ok_or("empty prompt")?;

        let mut tokens = if has_ex && last == spx.a {
            // expansion: sentence-sketch sits between <ex> and trailing <a>
            let ex_pos = prompt.iter().rposition(|&t| t == spx.ex).unwrap();
            let sent_sketch = &prompt[ex_pos + 1..prompt.len() - 1];
            let sent = q
                .sentences
                .iter()
                .find(|s| s.sketch.starts_with(sent_sketch) || sent_sketch.starts_with(&s.sketch[..s.sketch.len().min(sent_sketch.len())]))
                .or_else(|| q.sentences.first())
                .ok_or("no sentences")?;
            self.corrupt(&sent.full, err, &mut rng, &structural)
        } else if last == spx.sk {
            // sketch generation
            let sk = q.sketch_tokens(spx.semicolon);
            self.corrupt(&sk, err * 0.5, &mut rng, &structural)
        } else {
            // full answer
            self.corrupt(&q.answer_tokens(), err, &mut rng, &structural)
        };
        tokens.truncate(sp.max_tokens.max(1).saturating_sub(1));
        if let Some(stop) = sp.stop_token {
            if let Some(i) = tokens.iter().position(|&t| t == stop) {
                tokens.truncate(i + 1);
            }
        } else {
            tokens.push(spx.eos);
        }
        // logp model: confident in proportion to (1 - err), with jitter
        let logps = tokens
            .iter()
            .map(|_| ((1.0 - err) as f64).ln() - 0.35 + rng.range(-0.05, 0.05))
            .collect();
        Ok(GenOutput { tokens, logps, finished: true })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::tests_support::toy_corpus;
    use crate::sketch::Prompts;

    fn setup() -> (SurrogateBackend, Tokenizer, Arc<Corpus>) {
        let (c, tok) = toy_corpus();
        let c = Arc::new(c);
        let b = SurrogateBackend::new(c.clone(), &tok, &Registry::builtin(), 1);
        (b, tok, c)
    }

    #[test]
    fn full_answer_resembles_reference() {
        let (mut b, tok, c) = setup();
        let q = &c.questions[0];
        let p = Prompts::full_answer(&tok, &q.question);
        let out = b
            .generate("qwen72b-sim", &p, &SamplingParams { max_tokens: 64, ..Default::default() })
            .unwrap();
        let reference = q.answer_tokens();
        let overlap = crate::quality::rouge::rouge1_f1(
            &out.tokens[..out.tokens.len() - 1],
            &reference,
        );
        assert!(overlap > 0.8, "overlap {overlap}");
    }

    #[test]
    fn small_model_more_corrupted() {
        let (mut b, tok, c) = setup();
        let q = &c.questions[0];
        let p = Prompts::full_answer(&tok, &q.question);
        let sp = SamplingParams { max_tokens: 64, ..Default::default() };
        let reference = q.answer_tokens();
        let big = b.generate("qwen72b-sim", &p, &sp).unwrap();
        let small = b.generate("qwen1.5b-sim", &p, &sp).unwrap();
        let r_big = crate::quality::rouge::rouge1_f1(&big.tokens, &reference);
        let r_small = crate::quality::rouge::rouge1_f1(&small.tokens, &reference);
        assert!(r_big >= r_small, "{r_big} < {r_small}");
    }

    #[test]
    fn expansion_stops_at_period() {
        let (mut b, tok, c) = setup();
        let q = &c.questions[0];
        let full_sk = q.sketch_tokens(tok.specials.semicolon);
        let p = Prompts::expand(&tok, &q.question, &full_sk, &q.sentences[1].sketch);
        let out = b
            .generate(
                "qwen72b-sim",
                &p,
                &SamplingParams {
                    max_tokens: 32,
                    stop_token: Some(tok.specials.period),
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(*out.tokens.last().unwrap(), tok.specials.period);
    }

    #[test]
    fn deterministic() {
        let (mut b, tok, c) = setup();
        let q = &c.questions[0];
        let p = Prompts::full_answer(&tok, &q.question);
        let sp = SamplingParams { max_tokens: 64, ..Default::default() };
        let a = b.generate("qwen7b-sim", &p, &sp).unwrap();
        let bb = b.generate("qwen7b-sim", &p, &sp).unwrap();
        assert_eq!(a.tokens, bb.tokens);
    }
}
