//! Cloud<->edge network model.
//!
//! PICE transfers only *text* (queries + sketches); the paper observes this
//! keeps transfer to "a few tens of milliseconds even at lower bandwidths"
//! (Fig. 14). The model: transfer_s = RTT/2 + payload_bits / bandwidth, with
//! an optional congestion multiplier the runtime profiler can update.

use crate::simclock::SimTime;

pub const BYTES_PER_TOKEN: f64 = 6.0; // avg word + separator, UTF-8
pub const PROTOCOL_OVERHEAD_BYTES: f64 = 220.0; // headers/framing per message

#[derive(Clone, Debug)]
pub struct Link {
    pub bandwidth_mbps: f64,
    pub rtt_ms: f64,
    /// Runtime congestion factor (1.0 = uncongested), set by the profiler.
    pub congestion: f64,
}

impl Link {
    pub fn new(bandwidth_mbps: f64, rtt_ms: f64) -> Self {
        Link { bandwidth_mbps, rtt_ms, congestion: 1.0 }
    }

    /// Typical cloud-edge WAN for the paper's testbed experiments.
    pub fn default_wan() -> Self {
        Link::new(100.0, 20.0)
    }

    /// One-way transfer time for a token payload, seconds.
    pub fn transfer_tokens_s(&self, n_tokens: usize) -> SimTime {
        self.transfer_bytes_s(n_tokens as f64 * BYTES_PER_TOKEN)
    }

    pub fn transfer_bytes_s(&self, bytes: f64) -> SimTime {
        let bits = (bytes + PROTOCOL_OVERHEAD_BYTES) * 8.0;
        let bw = (self.bandwidth_mbps * 1e6 / self.congestion).max(1e3);
        self.rtt_ms / 2.0 / 1e3 + bits / bw
    }

    /// Round trip for request + response payloads (the Δ(r) of Eq. 2).
    pub fn round_trip_s(&self, tokens_out: usize, tokens_back: usize) -> SimTime {
        self.transfer_tokens_s(tokens_out) + self.transfer_tokens_s(tokens_back)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sketch_transfer_is_tens_of_ms() {
        // paper §V-D: sketches transfer in a few tens of ms even at low bw
        let slow = Link::new(10.0, 30.0);
        let t = slow.transfer_tokens_s(200);
        assert!(t < 0.1, "200-token sketch at 10 Mbps took {t}s");
        assert!(t > 0.01);
    }

    #[test]
    fn bandwidth_monotone() {
        let a = Link::new(10.0, 20.0).transfer_tokens_s(500);
        let b = Link::new(100.0, 20.0).transfer_tokens_s(500);
        let c = Link::new(1000.0, 20.0).transfer_tokens_s(500);
        assert!(a > b && b > c);
    }

    #[test]
    fn congestion_slows() {
        let mut l = Link::new(100.0, 20.0);
        let fast = l.transfer_tokens_s(1000);
        l.congestion = 4.0;
        assert!(l.transfer_tokens_s(1000) > fast);
    }

    #[test]
    fn rtt_floor() {
        let l = Link::new(10_000.0, 40.0);
        assert!(l.transfer_tokens_s(1) >= 0.02);
    }
}
