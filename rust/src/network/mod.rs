//! Cloud<->edge network model.
//!
//! PICE transfers only *text* (queries + sketches); the paper observes this
//! keeps transfer to "a few tens of milliseconds even at lower bandwidths"
//! (Fig. 14). The model: transfer_s = RTT·congestion/2 + payload_bits /
//! (bandwidth/congestion) — congestion both thins the per-flow bandwidth
//! and inflates the RTT (queueing delay at the bottleneck), and is driven
//! at runtime by the profiler / the dynamics subsystem
//! ([`crate::dynamics::CongestionSpikes`]).

use crate::simclock::SimTime;

pub const BYTES_PER_TOKEN: f64 = 6.0; // avg word + separator, UTF-8
pub const PROTOCOL_OVERHEAD_BYTES: f64 = 220.0; // headers/framing per message

#[derive(Clone, Debug)]
pub struct Link {
    pub bandwidth_mbps: f64,
    pub rtt_ms: f64,
    /// Runtime congestion factor (1.0 = uncongested), set by the profiler.
    pub congestion: f64,
}

impl Link {
    pub fn new(bandwidth_mbps: f64, rtt_ms: f64) -> Self {
        Link { bandwidth_mbps, rtt_ms, congestion: 1.0 }
    }

    /// Typical cloud-edge WAN for the paper's testbed experiments.
    pub fn default_wan() -> Self {
        Link::new(100.0, 20.0)
    }

    /// One-way transfer time for a token payload, seconds.
    pub fn transfer_tokens_s(&self, n_tokens: usize) -> SimTime {
        self.transfer_bytes_s(n_tokens as f64 * BYTES_PER_TOKEN)
    }

    pub fn transfer_bytes_s(&self, bytes: f64) -> SimTime {
        let bits = (bytes + PROTOCOL_OVERHEAD_BYTES) * 8.0;
        let bw = (self.bandwidth_mbps * 1e6 / self.congestion).max(1e3);
        // congestion inflates BOTH terms: a congested path queues packets
        // (RTT grows), it doesn't just thin per-flow bandwidth
        self.rtt_ms * self.congestion / 2.0 / 1e3 + bits / bw
    }

    /// Round trip for request + response payloads (the Δ(r) of Eq. 2).
    pub fn round_trip_s(&self, tokens_out: usize, tokens_back: usize) -> SimTime {
        self.transfer_tokens_s(tokens_out) + self.transfer_tokens_s(tokens_back)
    }

    /// Affine view (base + per-token seconds) of this link's one-way
    /// transfer — the Δ(r) form the Eq. 2 scheduler consumes, recomputed
    /// from the *current* link state when dynamics are on.
    pub fn transfer_model(&self) -> TransferModel {
        let bw = (self.bandwidth_mbps * 1e6 / self.congestion).max(1e3);
        TransferModel {
            base_s: self.rtt_ms * self.congestion / 2.0 / 1e3
                + PROTOCOL_OVERHEAD_BYTES * 8.0 / bw,
            per_token_s: BYTES_PER_TOKEN * 8.0 / bw,
        }
    }
}

/// Affine one-way transfer-time model `base_s + n_tokens * per_token_s` —
/// what one scheduling decision sees of the network. A plain value (not a
/// closure) so [`crate::costmodel::Estimates`] stays `Copy` and the static
/// world can pin its legacy calibrated constants bit-for-bit.
#[derive(Clone, Copy, Debug)]
pub struct TransferModel {
    pub base_s: f64,
    pub per_token_s: f64,
}

impl TransferModel {
    pub fn eval(&self, n_tokens: usize) -> SimTime {
        self.base_s + n_tokens as f64 * self.per_token_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sketch_transfer_is_tens_of_ms() {
        // paper §V-D: sketches transfer in a few tens of ms even at low bw
        let slow = Link::new(10.0, 30.0);
        let t = slow.transfer_tokens_s(200);
        assert!(t < 0.1, "200-token sketch at 10 Mbps took {t}s");
        assert!(t > 0.01);
        // recalibrated for the congestion-RTT fix: a 3x-congested slow link
        // pays queueing delay on the RTT term too, but a sketch still lands
        // well under a second — text transfer never dominates inference
        let mut congested = Link::new(10.0, 30.0);
        congested.congestion = 3.0;
        let tc = congested.transfer_tokens_s(200);
        assert!(tc > 3.0 * 30.0 / 2.0 / 1e3, "congestion must inflate the RTT term: {tc}s");
        assert!(tc < 0.5, "200-token sketch at 10 Mbps x3 congestion took {tc}s");
    }

    #[test]
    fn bandwidth_monotone() {
        let a = Link::new(10.0, 20.0).transfer_tokens_s(500);
        let b = Link::new(100.0, 20.0).transfer_tokens_s(500);
        let c = Link::new(1000.0, 20.0).transfer_tokens_s(500);
        assert!(a > b && b > c);
    }

    #[test]
    fn congestion_slows() {
        let mut l = Link::new(100.0, 20.0);
        let fast = l.transfer_tokens_s(1000);
        l.congestion = 4.0;
        let slow = l.transfer_tokens_s(1000);
        assert!(slow > fast);
        // regression (queueing-delay fix): congestion applies to the RTT
        // term as well as bandwidth, so the slowdown must exceed what
        // thinning bandwidth alone would produce
        let bits = (1000.0 * BYTES_PER_TOKEN + PROTOCOL_OVERHEAD_BYTES) * 8.0;
        let bw_only = 20.0 / 2.0 / 1e3 + bits / (100.0 * 1e6 / 4.0);
        assert!(slow > bw_only + 1e-12, "RTT term not inflated: {slow} vs {bw_only}");
    }

    #[test]
    fn transfer_model_matches_closed_form() {
        let mut l = Link::new(37.0, 28.0);
        l.congestion = 2.5;
        let m = l.transfer_model();
        for n in [0usize, 1, 64, 500, 4096] {
            let direct = l.transfer_tokens_s(n);
            assert!(
                (m.eval(n) - direct).abs() < 1e-12,
                "affine model diverges at n={n}: {} vs {direct}",
                m.eval(n)
            );
        }
    }

    #[test]
    fn rtt_floor() {
        let l = Link::new(10_000.0, 40.0);
        assert!(l.transfer_tokens_s(1) >= 0.02);
    }
}
