//! Time-varying cloud<->edge links.
//!
//! The paper's central implementation challenge is "increased latency caused
//! by network transmission and edge inference" (§I, Fig. 14) — but a WAN is
//! not a constant. This module retimes a base [`Link`] as a **pure function
//! of `(SimTime, seed)`**: no mutable state, no wall clock, so concurrent
//! sweeps replay the exact same network no matter how scenarios interleave
//! (the same determinism rule the sweep layer lives by — PERF.md).
//!
//! Three composable processes, all opt-in:
//! * [`LinkPhase`] — piecewise base overrides (scheduled outages/degradation
//!   windows, e.g. "bandwidth drops to 10 Mbps from t=60 to t=120");
//! * [`BandwidthWalk`] — a bounded random walk on log-bandwidth (slow WAN
//!   drift between a floor and a ceiling);
//! * [`CongestionSpikes`] — periodic congestion windows (cross-traffic
//!   bursts) driving the [`Link::congestion`] factor, which since the
//!   queueing-delay fix inflates RTT as well as thinning bandwidth.

use crate::network::Link;
use crate::simclock::SimTime;
use crate::util::rng::Rng;

/// Base-link override active from `start_s` until the next phase (the last
/// phase holds to the end of time). Phases must be sorted by `start_s`.
#[derive(Clone, Copy, Debug)]
pub struct LinkPhase {
    pub start_s: SimTime,
    pub bandwidth_mbps: f64,
    pub rtt_ms: f64,
}

/// Bounded random walk on log-bandwidth: every `step_s` the multiplier takes
/// a uniform step of at most `rel_step` in log space, clamped to
/// `[min_frac, max_frac]` of the base bandwidth. Evaluated by replaying the
/// walk from t=0 at every call — a pure function of `(t, seed)`, O(t/step_s)
/// with cheap xoshiro draws (hundreds of steps per call at sim scale).
#[derive(Clone, Copy, Debug)]
pub struct BandwidthWalk {
    pub step_s: f64,
    pub rel_step: f64,
    pub min_frac: f64,
    pub max_frac: f64,
}

/// Resumable walk state: `(steps_replayed, clamped log-factor, rng)`. The
/// walk is a function of the step count alone, so carrying this forward
/// between calls with nondecreasing `t` (the engine's event clock) yields
/// bit-identical factors while only drawing the *new* steps — without it,
/// per-event evaluation is O(t/step_s) and total cost quadratic in sim
/// length. `None` (or a cache ahead of `t`) falls back to a fresh replay.
pub type WalkCache = Option<(u64, f64, Rng)>;

impl BandwidthWalk {
    pub fn factor_at(&self, t: SimTime, seed: u64) -> f64 {
        self.factor_at_cached(t, seed, &mut None)
    }

    pub fn factor_at_cached(&self, t: SimTime, seed: u64, cache: &mut WalkCache) -> f64 {
        let step = self.step_s.max(1e-3);
        // cap the replay length so a pathological timestamp can't spin
        let steps = (t / step).floor().clamp(0.0, 1e6) as u64;
        let (lo, hi) = (self.min_frac.max(1e-6).ln(), self.max_frac.max(1e-6).ln());
        let (mut done, mut logf, mut rng) = match cache.take() {
            Some(c) if c.0 <= steps => c,
            _ => (0, 0.0f64, Rng::new(seed ^ 0x9e37_79b9_7f4a_7c15)),
        };
        while done < steps {
            logf = (logf + self.rel_step * (2.0 * rng.f64() - 1.0)).clamp(lo, hi);
            done += 1;
        }
        *cache = Some((done, logf, rng));
        logf.exp()
    }
}

/// Periodic congestion: for the first `duty` fraction of every `period_s`
/// window the link's congestion factor is `factor`, else 1.0. The window
/// phase is jittered per seed so grids don't all spike in lockstep.
#[derive(Clone, Copy, Debug)]
pub struct CongestionSpikes {
    pub period_s: f64,
    pub duty: f64,
    pub factor: f64,
}

impl CongestionSpikes {
    pub fn factor_at(&self, t: SimTime, seed: u64) -> f64 {
        let period = self.period_s.max(1e-3);
        let phase = Rng::new(seed ^ 0x5bf0_3635_c0ff_ee01).f64() * period;
        let pos = ((t + phase) / period).fract();
        if pos < self.duty.clamp(0.0, 1.0) {
            self.factor.max(1.0)
        } else {
            1.0
        }
    }
}

/// The link-dynamics schedule of a scenario. Default = static world: every
/// component off, [`LinkDynamics::link_at`] returns the base link untouched
/// and the engine keeps its calibrated static transfer model bit-for-bit.
#[derive(Clone, Debug, Default)]
pub struct LinkDynamics {
    pub phases: Vec<LinkPhase>,
    pub bw_walk: Option<BandwidthWalk>,
    pub spikes: Option<CongestionSpikes>,
}

impl LinkDynamics {
    pub fn is_static(&self) -> bool {
        self.phases.is_empty() && self.bw_walk.is_none() && self.spikes.is_none()
    }

    /// The link state at simulated time `t` — pure in `(t, seed)`.
    pub fn link_at(&self, base: &Link, t: SimTime, seed: u64) -> Link {
        self.link_at_cached(base, t, seed, &mut None)
    }

    /// [`LinkDynamics::link_at`] with a resumable [`WalkCache`] — what the
    /// engine's monotone event clock uses, so the bandwidth walk advances
    /// incrementally instead of replaying from t=0 per event. Results are
    /// bit-identical to the pure form.
    pub fn link_at_cached(
        &self,
        base: &Link,
        t: SimTime,
        seed: u64,
        cache: &mut WalkCache,
    ) -> Link {
        if self.is_static() {
            return base.clone();
        }
        let mut link = base.clone();
        if let Some(ph) = self.phases.iter().rev().find(|p| p.start_s <= t) {
            link.bandwidth_mbps = ph.bandwidth_mbps;
            link.rtt_ms = ph.rtt_ms;
        }
        if let Some(w) = &self.bw_walk {
            let f = w.factor_at_cached(t, seed, cache);
            link.bandwidth_mbps = (link.bandwidth_mbps * f).max(0.001);
        }
        if let Some(s) = &self.spikes {
            link.congestion *= s.factor_at(t, seed);
        }
        link
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn walk() -> BandwidthWalk {
        BandwidthWalk { step_s: 5.0, rel_step: 0.3, min_frac: 0.2, max_frac: 1.5 }
    }

    #[test]
    fn static_schedule_is_identity() {
        let d = LinkDynamics::default();
        assert!(d.is_static());
        let base = Link::new(100.0, 20.0);
        for t in [0.0, 17.3, 900.0] {
            let l = d.link_at(&base, t, 7);
            assert_eq!(l.bandwidth_mbps, base.bandwidth_mbps);
            assert_eq!(l.rtt_ms, base.rtt_ms);
            assert_eq!(l.congestion, base.congestion);
        }
    }

    #[test]
    fn walk_is_pure_and_bounded() {
        let w = walk();
        for t in [0.0, 3.0, 50.0, 777.7] {
            let a = w.factor_at(t, 42);
            let b = w.factor_at(t, 42);
            assert_eq!(a.to_bits(), b.to_bits(), "factor not pure at t={t}");
            assert!((0.2..=1.5).contains(&a), "factor {a} out of bounds at t={t}");
        }
        // different seeds give different walks (overwhelmingly likely)
        assert_ne!(w.factor_at(500.0, 1), w.factor_at(500.0, 2));
    }

    #[test]
    fn cached_replay_matches_pure_replay() {
        // the resumable cache must be invisible in the results, for any
        // monotone sequence of query times
        let w = walk();
        let mut cache = None;
        for k in 0..60 {
            let t = k as f64 * 3.7;
            let pure = w.factor_at(t, 99);
            let cached = w.factor_at_cached(t, 99, &mut cache);
            assert_eq!(pure.to_bits(), cached.to_bits(), "cache diverged at t={t}");
        }
        // a cache ahead of t falls back to a fresh replay, not stale state
        let early = w.factor_at_cached(2.0, 99, &mut cache);
        assert_eq!(early.to_bits(), w.factor_at(2.0, 99).to_bits());
    }

    #[test]
    fn walk_actually_moves() {
        let w = walk();
        let early = w.factor_at(0.0, 9);
        let late = w.factor_at(400.0, 9);
        assert_eq!(early, 1.0, "no steps before the first boundary");
        assert_ne!(early, late);
    }

    #[test]
    fn phases_override_in_order() {
        let d = LinkDynamics {
            phases: vec![
                LinkPhase { start_s: 60.0, bandwidth_mbps: 10.0, rtt_ms: 80.0 },
                LinkPhase { start_s: 120.0, bandwidth_mbps: 50.0, rtt_ms: 40.0 },
            ],
            ..Default::default()
        };
        let base = Link::new(100.0, 20.0);
        assert_eq!(d.link_at(&base, 10.0, 0).bandwidth_mbps, 100.0);
        assert_eq!(d.link_at(&base, 60.0, 0).bandwidth_mbps, 10.0);
        assert_eq!(d.link_at(&base, 61.0, 0).rtt_ms, 80.0);
        assert_eq!(d.link_at(&base, 500.0, 0).bandwidth_mbps, 50.0);
    }

    #[test]
    fn spikes_toggle_congestion() {
        let s = CongestionSpikes { period_s: 10.0, duty: 0.5, factor: 4.0 };
        let (mut hi, mut lo) = (0, 0);
        for k in 0..100 {
            match s.factor_at(k as f64 * 0.37, 3) {
                f if f > 1.0 => hi += 1,
                _ => lo += 1,
            }
        }
        assert!(hi > 10 && lo > 10, "spikes never toggled: hi={hi} lo={lo}");
        // pure
        assert_eq!(s.factor_at(7.7, 3).to_bits(), s.factor_at(7.7, 3).to_bits());
    }

    #[test]
    fn degraded_link_slows_transfer() {
        let d = LinkDynamics {
            bw_walk: Some(BandwidthWalk {
                step_s: 5.0,
                rel_step: 0.4,
                min_frac: 0.1,
                max_frac: 0.5, // strictly degrading ceiling
            }),
            ..Default::default()
        };
        let base = Link::new(100.0, 20.0);
        let t_base = base.transfer_tokens_s(2000);
        let degraded = d.link_at(&base, 300.0, 11);
        assert!(degraded.bandwidth_mbps < base.bandwidth_mbps);
        assert!(degraded.transfer_tokens_s(2000) > t_base);
    }
}
