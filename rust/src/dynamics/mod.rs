//! Environment dynamics: the subsystem that makes the simulated world move.
//!
//! PICE's scheduler is *dynamic* — Eq. 2 re-routes every query under the
//! network and edge conditions of the moment — but a frozen testbed never
//! exercises that. [`DynamicsSpec`] perturbs the world while the engine
//! runs: time-varying links ([`link`]), edge churn / failure injection
//! ([`fault`]), and the engine-side failover re-dispatch that survives it
//! (see `coordinator::engine`).
//!
//! Determinism contract (same rules as the sweep layer, PERF.md):
//! * link state is a pure function of `(SimTime, seed)`;
//! * the fault timeline is generated in full at engine construction, pure
//!   in `(n_edges, seed)` — open-loop serving, closed-loop runs and
//!   N-thread sweeps all see the identical environment;
//! * `DynamicsSpec::default()` is the static world: no events are
//!   scheduled, no per-pull state is tracked, and traces are bit-identical
//!   to an engine that predates this module.

pub mod fault;
pub mod link;

pub use fault::{BlackoutSpec, EdgeEvent, EdgeFault, FaultSpec, SlowdownSpec};
pub use link::{BandwidthWalk, CongestionSpikes, LinkDynamics, LinkPhase};

/// A scenario's environment-dynamics schedule. Carried by
/// [`crate::coordinator::EngineCfg`]; default = static world (zero-cost).
#[derive(Clone, Debug, Default)]
pub struct DynamicsSpec {
    pub link: LinkDynamics,
    pub faults: FaultSpec,
    /// dynamics seed — deliberately separate from `EngineCfg::seed`, so a
    /// grid of policy variants faces the *same* environment timeline
    pub seed: u64,
}

impl DynamicsSpec {
    /// Fully static world (the default): no link variation, no faults.
    pub fn is_static(&self) -> bool {
        self.link.is_static() && !self.faults.any()
    }

    /// Named presets for the CLI / benches / sweep grids.
    ///
    /// * `stable`     — the static world (identical to `default()`; runs
    ///   through the same preset plumbing so CI can assert it changes
    ///   nothing);
    /// * `flaky-wan`  — bounded bandwidth walk + periodic congestion
    ///   spikes, no edge faults;
    /// * `edge-churn` — a deterministic front-loaded churn pattern (edges
    ///   0-2 crash and recover inside the first minute, so even short smoke
    ///   runs exercise the failover path) followed by a stochastic
    ///   MTBF/MTTR tail plus straggler windows, on a stable WAN;
    /// * `shard-blackout` — whole-node-set blackout windows (every edge of
    ///   the engine crashes together, recovers together): the shard-level
    ///   failure mode for fleet failover and the backoff-retry path. Window
    ///   times are pure in the dynamics seed, so fleet shards (seeded
    ///   `seed + shard`) black out at different times and healthy peers
    ///   exist to steal the displaced sessions.
    pub fn preset(name: &str) -> Option<DynamicsSpec> {
        match name {
            "stable" => Some(DynamicsSpec::default()),
            "flaky-wan" => Some(DynamicsSpec {
                link: LinkDynamics {
                    bw_walk: Some(BandwidthWalk {
                        step_s: 5.0,
                        rel_step: 0.3,
                        min_frac: 0.2,
                        max_frac: 1.25,
                    }),
                    spikes: Some(CongestionSpikes { period_s: 40.0, duty: 0.25, factor: 4.0 }),
                    phases: Vec::new(),
                },
                faults: FaultSpec::default(),
                seed: 29,
            }),
            "edge-churn" => Some(DynamicsSpec {
                link: LinkDynamics::default(),
                faults: FaultSpec {
                    mtbf_s: Some(75.0),
                    mttr_s: 15.0,
                    slowdown: Some(SlowdownSpec { mtbs_s: 120.0, mean_dur_s: 25.0, mult: 2.5 }),
                    horizon_s: 1800.0,
                    events: vec![
                        EdgeEvent { t: 10.0, eid: 0, fault: EdgeFault::Crash },
                        EdgeEvent { t: 16.0, eid: 1, fault: EdgeFault::Crash },
                        EdgeEvent { t: 25.0, eid: 0, fault: EdgeFault::Recover },
                        EdgeEvent { t: 31.0, eid: 1, fault: EdgeFault::Recover },
                        EdgeEvent { t: 38.0, eid: 2, fault: EdgeFault::Crash },
                        EdgeEvent { t: 53.0, eid: 2, fault: EdgeFault::Recover },
                    ],
                },
                seed: 23,
            }),
            "shard-blackout" => Some(DynamicsSpec {
                link: LinkDynamics::default(),
                faults: FaultSpec {
                    blackout: Some(fault::BlackoutSpec { mtbb_s: 90.0, dur_s: 20.0 }),
                    horizon_s: 900.0,
                    ..Default::default()
                },
                seed: 31,
            }),
            _ => None,
        }
    }

    pub fn preset_names() -> &'static [&'static str] {
        &["stable", "flaky-wan", "edge-churn", "shard-blackout"]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_static() {
        assert!(DynamicsSpec::default().is_static());
    }

    #[test]
    fn presets_resolve_and_unknown_rejects() {
        for name in DynamicsSpec::preset_names() {
            assert!(DynamicsSpec::preset(name).is_some(), "missing preset {name}");
        }
        assert!(DynamicsSpec::preset("chaos-monkey").is_none());
    }

    #[test]
    fn stable_preset_is_the_static_world() {
        assert!(DynamicsSpec::preset("stable").unwrap().is_static());
    }

    #[test]
    fn churn_preset_generates_faults() {
        let d = DynamicsSpec::preset("edge-churn").unwrap();
        assert!(!d.is_static());
        let tl = d.faults.timeline(4, d.seed);
        assert!(
            tl.iter().any(|e| e.fault == EdgeFault::Crash),
            "edge-churn must crash at least one edge within its horizon"
        );
    }

    #[test]
    fn shard_blackout_preset_blacks_out_within_the_horizon() {
        let d = DynamicsSpec::preset("shard-blackout").unwrap();
        assert!(!d.is_static());
        // the preset seed and the fleet-derived seeds (seed + shard) must
        // all hit at least one window, or smoke runs would test nothing
        for shard in 0..4u64 {
            let tl = d.faults.timeline(4, d.seed + shard);
            let crashes = tl.iter().filter(|e| e.fault == EdgeFault::Crash).count();
            assert!(crashes >= 4, "shard {shard}: no blackout window in the horizon");
            assert_eq!(FaultSpec::recover_count(&tl), crashes, "unpaired blackout");
        }
    }

    #[test]
    fn flaky_wan_perturbs_the_link_but_not_the_cluster() {
        let d = DynamicsSpec::preset("flaky-wan").unwrap();
        assert!(!d.link.is_static());
        assert!(!d.faults.any());
    }
}
