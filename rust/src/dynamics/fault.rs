//! Edge churn and failure injection.
//!
//! EdgeShard-class deployments must tolerate heterogeneous, unreliable edge
//! devices; this module generates the *entire* fault timeline of a scenario
//! up-front as a pure function of `(n_edges, seed)`, so the engine schedules
//! every event at construction and open-loop driving stays bit-identical to
//! the closed loop (an on-demand process would observe submission timing).
//!
//! Event kinds:
//! * `Crash` — the node dies instantly: in-flight expansion slots are lost
//!   and re-enter dispatch (the engine's failover path);
//! * `Recover` — the node rejoins with a cold queue and nominal speed;
//! * `Slowdown { mult }` — straggler mode: compute takes `mult`x as long
//!   (`mult: 1.0` restores nominal speed).
//!
//! Stochastic processes (MTBF/MTTR crashes, straggler windows) are bounded
//! by `horizon_s`; every stochastically injected crash is **paired with a
//! recover**, even one past the horizon, so work parked during an all-edges
//! -down window always drains. Explicit event lists may model permanent
//! loss (crash with no recover) — the engine then falls back to the cloud.

use crate::simclock::SimTime;
use crate::util::rng::Rng;

/// One edge-node fault event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EdgeFault {
    Crash,
    Recover,
    Slowdown { mult: f64 },
}

#[derive(Clone, Copy, Debug)]
pub struct EdgeEvent {
    pub t: SimTime,
    pub eid: usize,
    pub fault: EdgeFault,
}

/// Stochastic straggler windows: on average every `mtbs_s` an edge slows to
/// `mult`x compute time for an (exponential) `mean_dur_s` window.
#[derive(Clone, Copy, Debug)]
pub struct SlowdownSpec {
    pub mtbs_s: f64,
    pub mean_dur_s: f64,
    pub mult: f64,
}

/// Whole-node-set blackout windows: every edge of the engine crashes at the
/// window start and recovers `dur_s` later — the shard-level failure mode a
/// fleet must survive by re-dispatching to healthy shards. Window starts are
/// an exponential renewal process with mean gap `mtbb_s` (minimum gap
/// `0.25 x mtbb_s` so consecutive windows never pile on top of each other),
/// drawn from its own RNG stream so it composes with MTBF churn without
/// perturbing it. Pure in `seed`: fleet shards (whose dynamics seeds differ
/// by shard index) black out at *different* times, leaving healthy peers.
#[derive(Clone, Copy, Debug)]
pub struct BlackoutSpec {
    /// mean time between blackout-window starts (exponential, min gap 25%)
    pub mtbb_s: f64,
    /// window length: paired per-edge recovers land at `start + dur_s`
    pub dur_s: f64,
}

/// The failure-injection schedule of a scenario. Default = no faults.
#[derive(Clone, Debug)]
pub struct FaultSpec {
    /// explicit scheduled events (reproduced incidents, targeted tests)
    pub events: Vec<EdgeEvent>,
    /// mean time between failures per edge (exponential); None = no crashes
    pub mtbf_s: Option<f64>,
    /// mean time to repair after a stochastic crash (exponential)
    pub mttr_s: f64,
    /// stochastic straggler process; None = no slowdowns
    pub slowdown: Option<SlowdownSpec>,
    /// stochastic whole-node-set blackout windows; None = no blackouts
    pub blackout: Option<BlackoutSpec>,
    /// stochastic injections stop at this sim time (recovers may land past
    /// it); bounds the timeline so `Engine::run` always reaches quiescence
    pub horizon_s: f64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            events: Vec::new(),
            mtbf_s: None,
            mttr_s: 30.0,
            slowdown: None,
            blackout: None,
            horizon_s: 3600.0,
        }
    }
}

impl FaultSpec {
    /// Any fault source configured? (Gates the engine's in-flight tracking
    /// so the static world pays nothing for the failover machinery.)
    pub fn any(&self) -> bool {
        !self.events.is_empty()
            || self.mtbf_s.is_some()
            || self.slowdown.is_some()
            || self.blackout.is_some()
    }

    /// The full deterministic event timeline, sorted by `(t, eid)` with
    /// stable insertion order on ties. Pure in `(n_edges, seed)`.
    pub fn timeline(&self, n_edges: usize, seed: u64) -> Vec<EdgeEvent> {
        let mut evs: Vec<EdgeEvent> =
            self.events.iter().filter(|e| e.eid < n_edges).copied().collect();
        if let Some(mtbf) = self.mtbf_s {
            let mtbf = mtbf.max(1e-3);
            let mttr = self.mttr_s.max(1e-3);
            for eid in 0..n_edges {
                let mut rng = Rng::new(seed ^ (eid as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let mut t = 0.0;
                loop {
                    t += rng.exp(1.0 / mtbf);
                    if t >= self.horizon_s {
                        break;
                    }
                    evs.push(EdgeEvent { t, eid, fault: EdgeFault::Crash });
                    t += rng.exp(1.0 / mttr);
                    // paired recover, even past the horizon: a stochastic
                    // crash never strands parked work forever
                    evs.push(EdgeEvent { t, eid, fault: EdgeFault::Recover });
                }
            }
        }
        if let Some(sl) = self.slowdown {
            let mtbs = sl.mtbs_s.max(1e-3);
            let dur = sl.mean_dur_s.max(1e-3);
            for eid in 0..n_edges {
                let mut rng = Rng::new(seed ^ (eid as u64 + 1).wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
                let mut t = 0.0;
                loop {
                    t += rng.exp(1.0 / mtbs);
                    if t >= self.horizon_s {
                        break;
                    }
                    evs.push(EdgeEvent { t, eid, fault: EdgeFault::Slowdown { mult: sl.mult } });
                    t += rng.exp(1.0 / dur);
                    evs.push(EdgeEvent { t, eid, fault: EdgeFault::Slowdown { mult: 1.0 } });
                }
            }
        }
        if let Some(bl) = self.blackout {
            let mtbb = bl.mtbb_s.max(1e-3);
            let dur = bl.dur_s.max(1e-3);
            let mut rng = Rng::new(seed ^ 0xA076_1D64_78BD_642F);
            let mut t = 0.0;
            loop {
                t += 0.25 * mtbb + rng.exp(1.0 / (0.75 * mtbb));
                if t >= self.horizon_s {
                    break;
                }
                for eid in 0..n_edges {
                    evs.push(EdgeEvent { t, eid, fault: EdgeFault::Crash });
                    // paired recover: a blackout is always transient, so the
                    // engine sees pending_recovers > 0 and parks/backs off
                    // instead of declaring the world dead
                    evs.push(EdgeEvent { t: t + dur, eid, fault: EdgeFault::Recover });
                }
                t += dur;
            }
        }
        // stable sort: equal (t, eid) keep generation order, so the
        // timeline (and thus the engine's event-queue seq numbers) is a
        // deterministic function of the spec alone
        evs.sort_by(|a, b| a.t.total_cmp(&b.t).then(a.eid.cmp(&b.eid)));
        evs
    }

    /// Recover events in the timeline — the engine's "is help coming"
    /// signal deciding park-vs-cloud-fallback when every edge is down.
    pub fn recover_count(timeline: &[EdgeEvent]) -> usize {
        timeline.iter().filter(|e| e.fault == EdgeFault::Recover).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn churny() -> FaultSpec {
        FaultSpec {
            mtbf_s: Some(60.0),
            mttr_s: 15.0,
            horizon_s: 600.0,
            slowdown: Some(SlowdownSpec { mtbs_s: 120.0, mean_dur_s: 20.0, mult: 2.5 }),
            ..Default::default()
        }
    }

    #[test]
    fn default_is_empty() {
        let f = FaultSpec::default();
        assert!(!f.any());
        assert!(f.timeline(4, 7).is_empty());
    }

    #[test]
    fn timeline_is_pure_and_sorted() {
        let f = churny();
        let a = f.timeline(4, 21);
        let b = f.timeline(4, 21);
        assert!(!a.is_empty());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.t.to_bits(), y.t.to_bits());
            assert_eq!(x.eid, y.eid);
            assert_eq!(x.fault, y.fault);
        }
        for w in a.windows(2) {
            assert!(w[0].t <= w[1].t, "timeline out of order");
        }
        // a different seed perturbs the timeline
        let c = f.timeline(4, 22);
        assert!(a.iter().zip(&c).any(|(x, y)| x.t != y.t));
    }

    #[test]
    fn every_stochastic_crash_is_paired_with_a_recover() {
        let f =
            FaultSpec { mtbf_s: Some(40.0), mttr_s: 10.0, horizon_s: 500.0, ..Default::default() };
        let tl = f.timeline(3, 5);
        let crashes = tl.iter().filter(|e| e.fault == EdgeFault::Crash).count();
        assert!(crashes > 0, "horizon 500 / mtbf 40 x 3 edges must crash");
        assert_eq!(FaultSpec::recover_count(&tl), crashes);
        // per edge, crash/recover strictly alternate
        for eid in 0..3 {
            let mut expect_crash = true;
            for e in tl.iter().filter(|e| e.eid == eid) {
                match e.fault {
                    EdgeFault::Crash => {
                        assert!(expect_crash, "double crash on edge {eid}");
                        expect_crash = false;
                    }
                    EdgeFault::Recover => {
                        assert!(!expect_crash, "recover before crash on edge {eid}");
                        expect_crash = true;
                    }
                    _ => {}
                }
            }
        }
    }

    #[test]
    fn blackout_windows_crash_every_edge_and_pair_recovers() {
        let f = FaultSpec {
            blackout: Some(BlackoutSpec { mtbb_s: 120.0, dur_s: 20.0 }),
            horizon_s: 900.0,
            ..Default::default()
        };
        assert!(f.any());
        for seed in [31u64, 32, 33, 34] {
            let tl = f.timeline(4, seed);
            let crashes: Vec<&EdgeEvent> =
                tl.iter().filter(|e| e.fault == EdgeFault::Crash).collect();
            assert!(!crashes.is_empty(), "seed {seed}: horizon 900 / mtbb 120 must black out");
            assert_eq!(crashes.len() % 4, 0, "seed {seed}: partial blackout");
            assert_eq!(FaultSpec::recover_count(&tl), crashes.len());
            // each window takes all 4 edges down at the same instant and the
            // paired recovers land exactly dur_s later
            for w in crashes.chunks(4) {
                assert!(w.iter().all(|e| e.t.to_bits() == w[0].t.to_bits()));
                let eids: Vec<usize> = w.iter().map(|e| e.eid).collect();
                assert_eq!(eids, vec![0, 1, 2, 3]);
                assert!(tl.iter().any(|e| {
                    e.fault == EdgeFault::Recover && e.eid == 0 && e.t == w[0].t + 20.0
                }));
            }
        }
        // different seeds stagger the windows — the fleet's healthy-peer story
        let a = f.timeline(4, 31);
        let b = f.timeline(4, 32);
        let first = |tl: &[EdgeEvent]| tl.iter().find(|e| e.fault == EdgeFault::Crash).map(|e| e.t);
        assert_ne!(first(&a), first(&b), "blackout windows must differ across shard seeds");
    }

    #[test]
    fn explicit_events_pass_through_and_filter_bad_eids() {
        let f = FaultSpec {
            events: vec![
                EdgeEvent { t: 5.0, eid: 1, fault: EdgeFault::Crash },
                EdgeEvent { t: 9.0, eid: 99, fault: EdgeFault::Crash }, // dropped
                EdgeEvent { t: 8.0, eid: 1, fault: EdgeFault::Recover },
            ],
            ..Default::default()
        };
        let tl = f.timeline(2, 0);
        assert_eq!(tl.len(), 2);
        assert_eq!(tl[0].t, 5.0);
        assert_eq!(tl[1].t, 8.0);
    }
}
