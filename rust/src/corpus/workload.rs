//! Request workload generation: arrival processes + category mixes.
//!
//! The paper drives its testbed at a configured RPM (requests per minute,
//! §V-B: "RPM is 1.5x the maximum batch size") with MT-bench/Vicuna-bench
//! questions. This generator reproduces that: Poisson (or uniform) arrivals
//! over the eval split, optionally restricted to a category mix.

use super::{Corpus, Question};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arrival {
    /// Poisson process (exponential inter-arrival).
    Poisson,
    /// Evenly spaced arrivals.
    Uniform,
    /// All requests arrive at t=0 (closed-loop batch).
    Burst,
    /// Markov-modulated on/off Poisson: alternating phases of `burst_len`
    /// arrivals — ON at `burst_factor` x the nominal rate, OFF at the
    /// complementary rate — so the long-run mean rate still equals `rpm`
    /// while load spikes can coincide with link degradation / edge churn
    /// (the dynamics-subsystem pairing). `burst_factor` is clamped to >= 1.
    BurstyPoisson { burst_factor: f64, burst_len: usize },
}

#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub rpm: f64,
    pub n_requests: usize,
    pub arrival: Arrival,
    /// Empty = all categories.
    pub categories: Vec<String>,
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            rpm: 30.0,
            n_requests: 60,
            arrival: Arrival::Poisson,
            categories: Vec::new(),
            seed: 7,
        }
    }
}

/// One incoming request: a question arriving at a (simulated) time.
#[derive(Clone, Debug)]
pub struct Request {
    pub rid: usize,
    pub question_id: usize,
    pub arrival_s: f64,
}

pub struct Workload {
    pub spec: WorkloadSpec,
    pub requests: Vec<Request>,
}

impl Workload {
    pub fn generate(corpus: &Corpus, spec: WorkloadSpec) -> Workload {
        let mut rng = Rng::new(spec.seed);
        let pool: Vec<&Question> = corpus
            .eval_questions()
            .into_iter()
            .filter(|q| spec.categories.is_empty() || spec.categories.contains(&q.category))
            .collect();
        assert!(!pool.is_empty(), "workload: empty question pool");

        let rate_per_s = spec.rpm / 60.0;
        let mut t = 0.0;
        let mut requests = Vec::with_capacity(spec.n_requests);
        for rid in 0..spec.n_requests {
            let q = pool[rng.below(pool.len())];
            let arrival_s = match spec.arrival {
                Arrival::Poisson => {
                    t += rng.exp(rate_per_s);
                    t
                }
                Arrival::Uniform => {
                    t += 1.0 / rate_per_s;
                    t
                }
                Arrival::Burst => 0.0,
                Arrival::BurstyPoisson { burst_factor, burst_len } => {
                    let bf = burst_factor.max(1.0);
                    let on = rate_per_s * bf;
                    // equal-length (in arrivals) on/off phases keep the
                    // mean inter-arrival at exactly 1/rate:
                    // (1/on + 1/off) / 2 = 1/rate  =>  off = rate/(2 - 1/bf)
                    let off = rate_per_s / (2.0 - 1.0 / bf);
                    let phase_on = (rid / burst_len.max(1)) % 2 == 0;
                    t += rng.exp(if phase_on { on } else { off });
                    t
                }
            };
            requests.push(Request { rid, question_id: q.id, arrival_s });
        }
        Workload { spec, requests }
    }

    /// Duration over which requests arrive (for throughput accounting).
    pub fn span_s(&self) -> f64 {
        self.requests.last().map(|r| r.arrival_s).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::tests::{toy_corpus_json, toy_tokenizer};
    use crate::util::json::Json;

    fn toy_corpus() -> Corpus {
        let tok = toy_tokenizer();
        Corpus::from_json(&Json::parse(toy_corpus_json()).unwrap(), &tok).unwrap()
    }

    #[test]
    fn poisson_rate_approx() {
        let c = toy_corpus();
        let spec = WorkloadSpec { rpm: 60.0, n_requests: 2000, ..Default::default() };
        let w = Workload::generate(&c, spec);
        // 60 rpm = 1/s; 2000 arrivals should span ~2000s +- 10%
        let span = w.span_s();
        assert!((1700.0..2300.0).contains(&span), "span {span}");
    }

    #[test]
    fn bursty_mean_rate_matches_rpm() {
        // property: across factors/phase lengths, the modulated process
        // keeps the nominal long-run rate (the phase algebra is exact; the
        // tolerance only absorbs sampling noise)
        let c = toy_corpus();
        for (bf, bl, seed) in [(2.0, 10, 7u64), (4.0, 25, 11), (8.0, 5, 13), (1.0, 50, 17)] {
            let spec = WorkloadSpec {
                rpm: 60.0,
                n_requests: 4000,
                arrival: Arrival::BurstyPoisson { burst_factor: bf, burst_len: bl },
                categories: vec![],
                seed,
            };
            let w = Workload::generate(&c, spec);
            let span = w.span_s();
            assert!(
                (3400.0..4600.0).contains(&span),
                "bf={bf} bl={bl}: span {span} vs nominal 4000s"
            );
        }
    }

    #[test]
    fn bursty_actually_bursts() {
        // ON-phase gaps must be visibly tighter than OFF-phase gaps
        let c = toy_corpus();
        let bl = 50;
        let spec = WorkloadSpec {
            rpm: 60.0,
            n_requests: 1000,
            arrival: Arrival::BurstyPoisson { burst_factor: 6.0, burst_len: bl },
            categories: vec![],
            seed: 3,
        };
        let w = Workload::generate(&c, spec);
        let gap = |i: usize| w.requests[i].arrival_s - w.requests[i - 1].arrival_s;
        let (mut on_sum, mut on_n, mut off_sum, mut off_n) = (0.0, 0, 0.0, 0);
        for i in 1..w.requests.len() {
            if (i / bl) % 2 == 0 {
                on_sum += gap(i);
                on_n += 1;
            } else {
                off_sum += gap(i);
                off_n += 1;
            }
        }
        let (on_mean, off_mean) = (on_sum / on_n as f64, off_sum / off_n as f64);
        assert!(
            on_mean * 2.0 < off_mean,
            "on-phase mean gap {on_mean:.3}s not clearly tighter than off {off_mean:.3}s"
        );
    }

    #[test]
    fn arrivals_monotone() {
        let c = toy_corpus();
        let w = Workload::generate(&c, WorkloadSpec::default());
        for pair in w.requests.windows(2) {
            assert!(pair[0].arrival_s <= pair[1].arrival_s);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let c = toy_corpus();
        let w1 = Workload::generate(&c, WorkloadSpec::default());
        let w2 = Workload::generate(&c, WorkloadSpec::default());
        assert_eq!(w1.requests.len(), w2.requests.len());
        for (a, b) in w1.requests.iter().zip(&w2.requests) {
            assert_eq!(a.question_id, b.question_id);
            assert!((a.arrival_s - b.arrival_s).abs() < 1e-12);
        }
    }
}
