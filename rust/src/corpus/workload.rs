//! Request workload generation: arrival processes + category mixes.
//!
//! The paper drives its testbed at a configured RPM (requests per minute,
//! §V-B: "RPM is 1.5x the maximum batch size") with MT-bench/Vicuna-bench
//! questions. This generator reproduces that: Poisson (or uniform) arrivals
//! over the eval split, optionally restricted to a category mix.

use super::{Corpus, Question};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arrival {
    /// Poisson process (exponential inter-arrival).
    Poisson,
    /// Evenly spaced arrivals.
    Uniform,
    /// All requests arrive at t=0 (closed-loop batch).
    Burst,
}

#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub rpm: f64,
    pub n_requests: usize,
    pub arrival: Arrival,
    /// Empty = all categories.
    pub categories: Vec<String>,
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            rpm: 30.0,
            n_requests: 60,
            arrival: Arrival::Poisson,
            categories: Vec::new(),
            seed: 7,
        }
    }
}

/// One incoming request: a question arriving at a (simulated) time.
#[derive(Clone, Debug)]
pub struct Request {
    pub rid: usize,
    pub question_id: usize,
    pub arrival_s: f64,
}

pub struct Workload {
    pub spec: WorkloadSpec,
    pub requests: Vec<Request>,
}

impl Workload {
    pub fn generate(corpus: &Corpus, spec: WorkloadSpec) -> Workload {
        let mut rng = Rng::new(spec.seed);
        let pool: Vec<&Question> = corpus
            .eval_questions()
            .into_iter()
            .filter(|q| spec.categories.is_empty() || spec.categories.contains(&q.category))
            .collect();
        assert!(!pool.is_empty(), "workload: empty question pool");

        let rate_per_s = spec.rpm / 60.0;
        let mut t = 0.0;
        let mut requests = Vec::with_capacity(spec.n_requests);
        for rid in 0..spec.n_requests {
            let q = pool[rng.below(pool.len())];
            let arrival_s = match spec.arrival {
                Arrival::Poisson => {
                    t += rng.exp(rate_per_s);
                    t
                }
                Arrival::Uniform => {
                    t += 1.0 / rate_per_s;
                    t
                }
                Arrival::Burst => 0.0,
            };
            requests.push(Request { rid, question_id: q.id, arrival_s });
        }
        Workload { spec, requests }
    }

    /// Duration over which requests arrive (for throughput accounting).
    pub fn span_s(&self) -> f64 {
        self.requests.last().map(|r| r.arrival_s).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::tests::{toy_corpus_json, toy_tokenizer};
    use crate::util::json::Json;

    fn toy_corpus() -> Corpus {
        let tok = toy_tokenizer();
        Corpus::from_json(&Json::parse(toy_corpus_json()).unwrap(), &tok).unwrap()
    }

    #[test]
    fn poisson_rate_approx() {
        let c = toy_corpus();
        let spec = WorkloadSpec { rpm: 60.0, n_requests: 2000, ..Default::default() };
        let w = Workload::generate(&c, spec);
        // 60 rpm = 1/s; 2000 arrivals should span ~2000s +- 10%
        let span = w.span_s();
        assert!((1700.0..2300.0).contains(&span), "span {span}");
    }

    #[test]
    fn arrivals_monotone() {
        let c = toy_corpus();
        let w = Workload::generate(&c, WorkloadSpec::default());
        for pair in w.requests.windows(2) {
            assert!(pair[0].arrival_s <= pair[1].arrival_s);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let c = toy_corpus();
        let w1 = Workload::generate(&c, WorkloadSpec::default());
        let w2 = Workload::generate(&c, WorkloadSpec::default());
        assert_eq!(w1.requests.len(), w2.requests.len());
        for (a, b) in w1.requests.iter().zip(&w2.requests) {
            assert_eq!(a.question_id, b.question_id);
            assert!((a.arrival_s - b.arrival_s).abs() < 1e-12);
        }
    }
}
