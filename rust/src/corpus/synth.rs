//! In-process synthetic corpus generator — a Rust mirror of
//! `python/compile/corpus.py`, used by integration/property tests and
//! simulation-only benches so the full coordinator stack runs without
//! artifacts. (The artifact corpus remains the source of truth for
//! everything involving real picoLM generation.)

use super::{Corpus, Question, Sentence, Split};
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;
use std::collections::BTreeMap;

const SPECIALS: [&str; 10] =
    ["<pad>", "<bos>", "<eos>", "<q>", "<a>", "<sk>", "<ex>", ".", ";", "?"];

const FILLERS: [&str; 14] =
    ["the", "a", "of", "in", "to", "and", "is", "are", "with", "that", "can", "because", "many", "it"];

const CATEGORIES: [&str; 12] = [
    "generic", "knowledge", "roleplay", "fermi", "coding", "math", "writing",
    "reasoning", "stem", "humanities", "counterfactual", "common-sense",
];

const SENTS: [usize; 12] = [4, 5, 6, 3, 5, 2, 8, 4, 5, 6, 3, 2];

const VERBS: [&str; 8] = ["moves", "shapes", "guides", "builds", "breaks", "holds", "turns", "links"];
const ADJS: [&str; 8] = ["bright", "steady", "hidden", "simple", "complex", "ancient", "rapid", "dense"];
const ADVS: [&str; 4] = ["slowly", "quickly", "carefully", "boldly"];
const PLACES: [&str; 4] = ["garden", "valley", "market", "library"];

fn nouns(cat: usize) -> Vec<String> {
    (0..6).map(|i| format!("n{cat}x{i}")).collect()
}

/// Build the mirrored tokenizer.
pub fn synth_tokenizer() -> Tokenizer {
    let mut toks: Vec<String> = SPECIALS.iter().map(|s| s.to_string()).collect();
    toks.extend(FILLERS.iter().map(|s| s.to_string()));
    for c in 0..CATEGORIES.len() {
        toks.extend(nouns(c));
    }
    toks.extend(VERBS.iter().map(|s| s.to_string()));
    toks.extend(ADJS.iter().map(|s| s.to_string()));
    toks.extend(ADVS.iter().map(|s| s.to_string()));
    toks.extend(PLACES.iter().map(|s| s.to_string()));
    Tokenizer::from_tokens(toks).expect("synth vocab")
}

fn sentence(tok: &Tokenizer, cat: usize, rng: &mut Rng) -> Sentence {
    let ns = nouns(cat);
    let n = ns[rng.below(ns.len())].clone();
    let n2 = ns[rng.below(ns.len())].clone();
    let v = VERBS[rng.below(VERBS.len())];
    let j = ADJS[rng.below(ADJS.len())];
    let d = ADVS[rng.below(ADVS.len())];
    let p = PLACES[rng.below(PLACES.len())];
    let tid = rng.below(4);
    let (full, sketch): (Vec<String>, Vec<String>) = match tid {
        0 => (
            ["the", j, &n, v, "the", &n2, "in", "the", p, "."].iter().map(|s| s.to_string()).collect(),
            [j, &n, v, &n2, p].iter().map(|s| s.to_string()).collect(),
        ),
        1 => (
            ["a", &n, "can", v, d, "with", "the", &n2, "."].iter().map(|s| s.to_string()).collect(),
            [&n, v, d, &n2].iter().map(|s| s.to_string()).collect(),
        ),
        2 => (
            ["the", &n, "is", j, "because", "it", v, "the", &n2, "."].iter().map(|s| s.to_string()).collect(),
            [&n, j, v, &n2].iter().map(|s| s.to_string()).collect(),
        ),
        _ => (
            ["many", &n, v, "to", "holds", "the", j, &n2, "."].iter().map(|s| s.to_string()).collect(),
            [&n, v, "holds", j, &n2].iter().map(|s| s.to_string()).collect(),
        ),
    };
    let enc = |ws: &[String]| ws.iter().map(|w| tok.id(w).expect("synth token")).collect();
    Sentence { template: tid, full: enc(&full), sketch: enc(&sketch) }
}

/// Generate `per_category` questions per category (30% eval split).
pub fn synth_corpus(tok: &Tokenizer, per_category: usize, seed: u64) -> Corpus {
    let mut rng = Rng::new(seed);
    let mut questions = Vec::new();
    let mut qid = 0;
    for (ci, cat) in CATEGORIES.iter().enumerate() {
        let n_eval = (per_category * 3) / 10;
        for i in 0..per_category {
            let split = if i >= per_category - n_eval { Split::Eval } else { Split::Train };
            let ns = nouns(ci);
            let qtext: Vec<String> = vec![
                "the".into(),
                ns[rng.below(ns.len())].clone(),
                "in".into(),
                "the".into(),
                PLACES[rng.below(PLACES.len())].into(),
                "?".into(),
            ];
            let question = qtext.iter().map(|w| tok.id(w).unwrap()).collect();
            let k = (SENTS[ci] as i64 + [-1, 0, 0, 1][rng.below(4)]).max(1) as usize;
            let sentences = (0..k).map(|_| sentence(tok, ci, &mut rng)).collect();
            questions.push(Question {
                id: qid,
                category: cat.to_string(),
                split,
                question,
                sentences,
            });
            qid += 1;
        }
    }
    let sentences_per_category: BTreeMap<String, usize> = CATEGORIES
        .iter()
        .zip(SENTS.iter())
        .map(|(c, &s)| (c.to_string(), s))
        .collect();
    Corpus {
        categories: CATEGORIES.iter().map(|s| s.to_string()).collect(),
        questions,
        sentences_per_category,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_well_formed() {
        let tok = synth_tokenizer();
        let c = synth_corpus(&tok, 10, 1);
        assert_eq!(c.questions.len(), 120);
        assert!(!c.eval_questions().is_empty());
        for q in &c.questions {
            assert!(!q.sentences.is_empty());
            for s in &q.sentences {
                assert!(!s.sketch.is_empty());
                assert!(s.full.len() > s.sketch.len());
            }
        }
    }

    #[test]
    fn questions_unique_enough() {
        let tok = synth_tokenizer();
        let c = synth_corpus(&tok, 20, 2);
        // surrogate backend keys on the question token sequence; near-total
        // uniqueness is enough (duplicates map to an equivalent question)
        let set: std::collections::HashSet<Vec<u32>> =
            c.questions.iter().map(|q| q.question.clone()).collect();
        assert!(set.len() > c.questions.len() / 2);
    }

    #[test]
    fn category_lengths_ladder() {
        let tok = synth_tokenizer();
        let c = synth_corpus(&tok, 20, 3);
        let avg = |cat: &str| {
            let qs = c.by_category(cat);
            qs.iter().map(|q| q.answer_len()).sum::<usize>() as f64 / qs.len() as f64
        };
        assert!(avg("writing") > avg("math"));
        assert!(avg("roleplay") > avg("common-sense"));
    }
}
