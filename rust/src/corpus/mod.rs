//! Corpus loading + workload generation (the MT-bench / Vicuna-bench
//! substitute — see DESIGN.md §2).
//!
//! `artifacts/corpus.json` is produced by the Python compile path; this
//! module parses it into typed questions with reference answers/sketches,
//! and generates request workloads (arrival processes, category mixes)
//! for the serving experiments.

pub mod synth;
pub mod workload;

use std::collections::BTreeMap;
use std::path::Path;

use crate::tokenizer::Tokenizer;
use crate::util::json::Json;

/// One reference-answer sentence: full form + its semantic sketch.
#[derive(Clone, Debug)]
pub struct Sentence {
    pub template: usize,
    pub full: Vec<u32>,
    pub sketch: Vec<u32>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Eval,
}

/// A benchmark question with its category and reference answer.
#[derive(Clone, Debug)]
pub struct Question {
    pub id: usize,
    pub category: String,
    pub split: Split,
    pub question: Vec<u32>,
    pub sentences: Vec<Sentence>,
}

impl Question {
    /// Reference answer tokens (sentences concatenated, "." terminated).
    pub fn answer_tokens(&self) -> Vec<u32> {
        self.sentences.iter().flat_map(|s| s.full.iter().copied()).collect()
    }

    /// Full sketch tokens (";"-separated sentence sketches).
    pub fn sketch_tokens(&self, semicolon: u32) -> Vec<u32> {
        let mut out = Vec::new();
        for (i, s) in self.sentences.iter().enumerate() {
            if i > 0 {
                out.push(semicolon);
            }
            out.extend_from_slice(&s.sketch);
        }
        out
    }

    /// Expected (reference) answer length in tokens — what the paper's
    /// length-aware LLM would predict perfectly.
    pub fn answer_len(&self) -> usize {
        self.sentences.iter().map(|s| s.full.len()).sum()
    }
}

#[derive(Clone, Debug)]
pub struct Corpus {
    pub categories: Vec<String>,
    pub questions: Vec<Question>,
    /// paper's per-category expected sentence counts (scheduler heuristics)
    pub sentences_per_category: BTreeMap<String, usize>,
}

impl Corpus {
    pub fn from_file(path: &Path, tok: &Tokenizer) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let json = Json::parse(&text)?;
        Self::from_json(&json, tok)
    }

    pub fn from_json(json: &Json, tok: &Tokenizer) -> Result<Self, String> {
        let categories = json
            .req("categories")?
            .str_vec()
            .ok_or("corpus.json: bad 'categories'")?;
        let mut sentences_per_category = BTreeMap::new();
        if let Some(Json::Obj(m)) = json.get("sentences_per_category") {
            for (k, v) in m {
                sentences_per_category
                    .insert(k.clone(), v.as_usize().ok_or("bad sentence count")?);
            }
        }
        let enc_list = |j: &Json| -> Result<Vec<u32>, String> {
            j.str_vec()
                .ok_or("expected token array".to_string())?
                .iter()
                .map(|t| tok.id(t).ok_or(format!("token '{t}' not in vocab")))
                .collect()
        };
        let mut questions = Vec::new();
        for qj in json.req("questions")?.as_arr().ok_or("bad 'questions'")? {
            let split = match qj.req("split")?.as_str() {
                Some("train") => Split::Train,
                Some("eval") => Split::Eval,
                other => return Err(format!("bad split {other:?}")),
            };
            let mut sentences = Vec::new();
            for sj in qj.req("sentences")?.as_arr().ok_or("bad 'sentences'")? {
                sentences.push(Sentence {
                    template: sj.req("template")?.as_usize().ok_or("bad template id")?,
                    full: enc_list(sj.req("full")?)?,
                    sketch: enc_list(sj.req("sketch")?)?,
                });
            }
            questions.push(Question {
                id: qj.req("id")?.as_usize().ok_or("bad id")?,
                category: qj.req("category")?.as_str().ok_or("bad category")?.to_string(),
                split,
                question: enc_list(qj.req("question")?)?,
                sentences,
            });
        }
        Ok(Corpus { categories, questions, sentences_per_category })
    }

    pub fn eval_questions(&self) -> Vec<&Question> {
        self.questions.iter().filter(|q| q.split == Split::Eval).collect()
    }

    pub fn by_category<'a>(&'a self, cat: &str) -> Vec<&'a Question> {
        self.questions.iter().filter(|q| q.category == cat).collect()
    }

    pub fn get(&self, id: usize) -> Option<&Question> {
        self.questions.iter().find(|q| q.id == id)
    }
}

/// Shared fixtures for unit tests across modules.
#[cfg(test)]
pub mod tests_support {
    use super::*;

    pub fn toy_corpus() -> (Corpus, Tokenizer) {
        let tok = tests::toy_tokenizer();
        let c = Corpus::from_json(&Json::parse(tests::toy_corpus_json()).unwrap(), &tok).unwrap();
        (c, tok)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn toy_tokenizer() -> Tokenizer {
        let toks = ["<pad>", "<bos>", "<eos>", "<q>", "<a>", "<sk>", "<ex>", ".", ";", "?",
            "the", "cat", "sat", "mat", "big"];
        Tokenizer::from_tokens(toks.iter().map(|s| s.to_string()).collect()).unwrap()
    }

    pub(crate) fn toy_corpus_json() -> &'static str {
        r#"{
          "categories": ["generic"],
          "sentences_per_category": {"generic": 2},
          "questions": [
            {"id": 0, "category": "generic", "split": "eval",
             "question": ["the", "cat", "?"],
             "sentences": [
               {"template": 0, "full": ["the", "big", "cat", "sat", "."],
                "sketch": ["big", "cat", "sat"]},
               {"template": 1, "full": ["the", "cat", "sat", "mat", "."],
                "sketch": ["cat", "mat"]}
             ]}
          ]
        }"#
    }

    #[test]
    fn parse_toy() {
        let tok = toy_tokenizer();
        let j = Json::parse(toy_corpus_json()).unwrap();
        let c = Corpus::from_json(&j, &tok).unwrap();
        assert_eq!(c.questions.len(), 1);
        let q = &c.questions[0];
        assert_eq!(q.answer_len(), 10);
        let sk = q.sketch_tokens(tok.specials.semicolon);
        assert_eq!(tok.decode(&sk), "big cat sat ; cat mat");
    }

    #[test]
    fn unknown_token_fails() {
        let tok = toy_tokenizer();
        let j = Json::parse(
            r#"{"categories": [], "questions": [{"id":0,"category":"x","split":"eval",
              "question":["zebra"],"sentences":[]}]}"#,
        )
        .unwrap();
        assert!(Corpus::from_json(&j, &tok).is_err());
    }
}
