//! Minimal JSON parser/writer (no serde in the offline image).
//!
//! Parses the build-time artifacts (corpus.json, vocab.json, meta.json) and
//! serializes bench results. Supports the full JSON grammar except exotic
//! number forms; strings handle the standard escapes + \uXXXX.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj.req("key")?` with a decent error message.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key '{key}'"))
    }

    /// Array of strings helper (vocab files, token lists).
    pub fn str_vec(&self) -> Option<Vec<String>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|x| x.as_str().map(str::to_string)).collect())
    }

    // ---- writer ----------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building result objects in benches.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or("unexpected end")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.i += 1; // {
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            if self.peek() != Some(b':') {
                return Err(format!("expected ':' at byte {}", self.i));
            }
            self.i += 1;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.i += 1; // [
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        if self.peek() != Some(b'"') {
            return Err(format!("expected string at byte {}", self.i));
        }
        self.i += 1;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or("unterminated string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                c => {
                    // UTF-8 passthrough: collect continuation bytes.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = (start + len).min(self.b.len());
                        if let Ok(s) = std::str::from_utf8(&self.b[start..end]) {
                            out.push_str(s);
                        }
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad number")?;
        s.parse::<f64>().map(Json::Num).map_err(|_| format!("bad number '{s}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": {"d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().as_str(), Some("hi\nthere"));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_bool(), Some(true));
        // write + reparse
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""A""#).unwrap();
        assert_eq!(v.as_str(), Some("A"));
    }

    #[test]
    fn nested_deep() {
        let v = Json::parse("[[[[[1]]]]]").unwrap();
        assert_eq!(
            v.idx(0).unwrap().idx(0).unwrap().idx(0).unwrap().idx(0).unwrap().idx(0).unwrap().as_f64(),
            Some(1.0)
        );
    }
}
