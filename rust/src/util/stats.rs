//! Small statistics helpers used by the metrics/bench layers.

/// Mean of a slice; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile with linear interpolation; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Ordinary least squares fit y = a + b*x; returns (a, b).
/// This is what the profiler uses to fit the latency function f(l).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
    }
    if sxx.abs() < 1e-12 {
        return (my, 0.0);
    }
    let b = sxy / sxx;
    (my - b * mx, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_var() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn linfit_exact() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b) = linfit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
