//! Deterministic PRNG (xoshiro256**) — no external crates in this image,
//! and the whole evaluation must be reproducible from seeds anyway.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Independent child stream (for per-component seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate lambda (inter-arrival times for Poisson loads).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Bernoulli with probability p.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
