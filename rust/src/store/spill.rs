//! The paged on-disk store: where cold pages go instead of dying.
//!
//! Layout under one store root (the `PICE_MEMO_PATH` directory):
//!
//! ```text
//! <root>/
//!   <stamp>/                  one directory per invalidation stamp
//!     manifest.json           {version, stamp, pages:[{file, n, bytes, hashes}]}
//!     page-000000.json        {version, stamp, entries:[...]}   (temp+rename)
//!     page-000001.json
//!   <other-stamp>/...         foreign stamps' stores, never touched
//! ```
//!
//! A process **attaches** by reading only the manifest — a few hundred
//! bytes per page of key hashes — and registers each on-disk page with the
//! buffer pool as a non-resident frame. Page payloads fault in one file at
//! a time on first use, so there is no monolithic snapshot load spike and
//! the cross-run cache is bounded by disk, not RAM.
//!
//! Every page and manifest write is temp-file + rename, so a crashed
//! process never leaves a torn file in place of a good one; a file that IS
//! torn (partial JSON, wrong stamp, wrong version) parses to "page lost" —
//! a cold page, never an error.
//!
//! **v1 migration:** if the store path holds a monolithic v1 JSON snapshot
//! (the pre-buffer-pool format), [`SpillStore::attach`] imports the
//! matching stamp's entries once, converts foreign stamps' sections into
//! their own paged directories, and replaces the file with the directory
//! layout. Any failure along the way degrades to a cold start.

use std::path::{Path, PathBuf};

use super::page::{self, PageData};
use super::{stable_key_hash, MemoKey};
use crate::runtime::GenOutput;
use crate::util::json::{self, Json};

/// On-disk store format version; bump when the page/manifest layout
/// changes. Version 1 was the monolithic JSON snapshot (import-only).
pub const STORE_VERSION: usize = 2;

/// Foreign-stamp directories retained under one store root — bounds disk
/// growth when many differently-stamped runs share one path (the v1
/// snapshot kept the same bound on foreign sections).
const FOREIGN_STAMP_LIMIT: usize = 8;

/// Manifest record of one on-disk page: its file name, entry count, byte
/// estimate, and the stable hash of every key it holds — enough to route
/// lookups to the page without reading it.
#[derive(Clone, Debug)]
pub struct DiskPage {
    pub file: String,
    pub n: usize,
    pub bytes: usize,
    pub hashes: Vec<u64>,
}

/// Result of [`SpillStore::attach`]: the store handle, the on-disk pages to
/// register with the pool (v2 layout), and entries imported from a v1
/// monolithic snapshot (at most one of `pages`/`imported` is non-empty).
pub struct Attached {
    pub store: SpillStore,
    pub pages: Vec<DiskPage>,
    pub imported: Vec<(MemoKey, GenOutput, u32)>,
}

/// One stamp's paged directory under a store root.
pub struct SpillStore {
    root: PathBuf,
    dir: PathBuf,
    stamp: String,
    next_file: u64,
}

impl SpillStore {
    /// Open (or create lazily) the store at `root` for `stamp`. A missing
    /// root, a stale stamp, or an unreadable manifest is a cold start; a v1
    /// snapshot file at `root` is imported once and converted in place.
    /// Never an error.
    pub fn attach(root: impl Into<PathBuf>, stamp: &str) -> Attached {
        let root = root.into();
        let dir = root.join(stamp);
        let mut store =
            SpillStore { root: root.clone(), dir, stamp: stamp.to_string(), next_file: 0 };
        if root.is_file() {
            let imported = store.import_v1();
            return Attached { store, pages: Vec::new(), imported };
        }
        let pages = store.read_manifest();
        store.next_file = pages
            .iter()
            .filter_map(|p| parse_page_index(&p.file))
            .max()
            .map(|i| i + 1)
            .unwrap_or(0);
        Attached { store, pages, imported: Vec::new() }
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn stamp(&self) -> &str {
        &self.stamp
    }

    pub fn page_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Reserve the next on-disk page file name.
    pub fn alloc_file(&mut self) -> String {
        let f = format!("page-{:06}.json", self.next_file);
        self.next_file += 1;
        f
    }

    /// Write one page to `file` (temp+rename). Returns the manifest record
    /// and how many non-finite-logp entries were skipped.
    pub fn write_page(&self, file: &str, data: &PageData) -> Result<(DiskPage, u64), String> {
        let skipped = write_page_file(&self.page_path(file), &self.stamp, data)?;
        let mut hashes = Vec::with_capacity(data.entries.len());
        let mut n = 0usize;
        for e in &data.entries {
            if e.out.logps.iter().all(|x| x.is_finite()) {
                hashes.push(stable_key_hash(&e.key));
                n += 1;
            }
        }
        Ok((DiskPage { file: file.to_string(), n, bytes: data.bytes, hashes }, skipped))
    }

    /// Write the manifest over `pages` (temp+rename), delete page files the
    /// manifest no longer references, and prune foreign stamp directories
    /// beyond [`FOREIGN_STAMP_LIMIT`] (oldest-modified first).
    pub fn write_manifest(&self, pages: &[DiskPage]) -> Result<(), String> {
        let rows: Vec<Json> = pages
            .iter()
            .map(|p| {
                json::obj(vec![
                    ("file", json::s(&p.file)),
                    ("n", json::num(p.n as f64)),
                    ("bytes", json::num(p.bytes as f64)),
                    (
                        "hashes",
                        Json::Arr(
                            p.hashes.iter().map(|h| Json::Str(format!("{h:016x}"))).collect(),
                        ),
                    ),
                ])
            })
            .collect();
        let j = json::obj(vec![
            ("version", json::num(STORE_VERSION as f64)),
            ("stamp", json::s(&self.stamp)),
            ("pages", Json::Arr(rows)),
        ]);
        write_atomic(&self.dir, &self.dir.join("manifest.json"), &j.to_string())?;
        self.gc_orphans(pages);
        self.prune_foreign();
        Ok(())
    }

    /// Read our stamp's manifest; empty on any miss (cold start).
    fn read_manifest(&self) -> Vec<DiskPage> {
        let Ok(text) = std::fs::read_to_string(self.dir.join("manifest.json")) else {
            return Vec::new();
        };
        let Ok(j) = Json::parse(&text) else { return Vec::new() };
        if j.get("version").and_then(Json::as_usize) != Some(STORE_VERSION)
            || j.get("stamp").and_then(Json::as_str) != Some(self.stamp.as_str())
        {
            return Vec::new();
        }
        let mut out = Vec::new();
        for p in j.get("pages").and_then(Json::as_arr).unwrap_or(&[]) {
            let (Some(file), Some(n), Some(bytes), Some(hj)) = (
                p.get("file").and_then(Json::as_str),
                p.get("n").and_then(Json::as_usize),
                p.get("bytes").and_then(Json::as_usize),
                p.get("hashes").and_then(Json::as_arr),
            ) else {
                continue;
            };
            let hashes: Option<Vec<u64>> =
                hj.iter().map(|h| u64::from_str_radix(h.as_str()?, 16).ok()).collect();
            let Some(hashes) = hashes else { continue };
            out.push(DiskPage { file: file.to_string(), n, bytes, hashes });
        }
        out
    }

    /// Delete `page-*.json` files the manifest no longer references —
    /// rewritten stores (two handles bound to one root, last save wins)
    /// would otherwise leak dead page files forever.
    fn gc_orphans(&self, pages: &[DiskPage]) {
        let live: std::collections::HashSet<&str> = pages.iter().map(|p| p.file.as_str()).collect();
        let Ok(rd) = std::fs::read_dir(&self.dir) else { return };
        for e in rd.flatten() {
            let name = e.file_name();
            let Some(name) = name.to_str() else { continue };
            if name.starts_with("page-") && name.ends_with(".json") && !live.contains(name) {
                let _ = std::fs::remove_file(e.path());
            }
        }
    }

    /// Keep at most [`FOREIGN_STAMP_LIMIT`] other stamps' directories under
    /// the root, dropping the oldest-modified beyond it.
    fn prune_foreign(&self) {
        let Ok(rd) = std::fs::read_dir(&self.root) else { return };
        let mut foreign: Vec<(std::time::SystemTime, PathBuf)> = Vec::new();
        for e in rd.flatten() {
            let p = e.path();
            if !p.is_dir() || p == self.dir {
                continue;
            }
            let t = e
                .metadata()
                .and_then(|m| m.modified())
                .unwrap_or(std::time::SystemTime::UNIX_EPOCH);
            foreign.push((t, p));
        }
        if foreign.len() <= FOREIGN_STAMP_LIMIT {
            return;
        }
        foreign.sort_by_key(|(t, _)| *t);
        for (_, p) in foreign.iter().take(foreign.len() - FOREIGN_STAMP_LIMIT) {
            let _ = std::fs::remove_dir_all(p);
        }
    }

    /// One-time import of a v1 monolithic snapshot found at the store root:
    /// parse it fully, replace the file with the directory layout, write
    /// foreign stamps' sections as their own paged stores, and hand our
    /// stamp's entries back for insertion into the pool (the caller flushes
    /// them to pages, completing the conversion). Any failure → cold start.
    fn import_v1(&mut self) -> Vec<(MemoKey, GenOutput, u32)> {
        let Ok(text) = std::fs::read_to_string(&self.root) else { return Vec::new() };
        let Ok(snap) = Json::parse(&text) else { return Vec::new() };
        if snap.get("version").and_then(Json::as_usize) != Some(1) {
            return Vec::new();
        }
        let Some(Json::Obj(caches)) = snap.get("caches") else { return Vec::new() };
        let mut mine = Vec::new();
        let mut foreign: Vec<(String, Vec<(MemoKey, GenOutput, u32)>)> = Vec::new();
        for (st, entries) in caches {
            let parsed: Vec<(MemoKey, GenOutput, u32)> = entries
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(page::entry_from_json)
                .collect();
            if st == &self.stamp {
                mine = parsed;
            } else if foreign.len() < FOREIGN_STAMP_LIMIT {
                foreign.push((st.clone(), parsed));
            }
        }
        // the parse is complete and in memory — now (and only now) swap the
        // file for the directory layout
        if std::fs::remove_file(&self.root).is_err() {
            return Vec::new();
        }
        for (st, entries) in foreign {
            let fstore = SpillStore {
                root: self.root.clone(),
                dir: self.root.join(&st),
                stamp: st,
                next_file: 0,
            };
            let _ = fstore.write_entry_chunks(&entries);
        }
        mine
    }

    /// Write `entries` as sealed pages + a manifest (the foreign-stamp
    /// conversion path).
    fn write_entry_chunks(&self, entries: &[(MemoKey, GenOutput, u32)]) -> Result<(), String> {
        let mut pages = Vec::new();
        let mut next = 0u64;
        for chunk in entries.chunks(page::PAGE_ENTRIES.max(1)) {
            let mut data = PageData::default();
            for (k, o, owner) in chunk {
                data.push(std::sync::Arc::new(k.clone()), o.clone(), *owner);
            }
            let file = format!("page-{next:06}.json");
            next += 1;
            let (dp, _) = self.write_page(&file, &data)?;
            pages.push(dp);
        }
        self.write_manifest(&pages)
    }
}

/// Parse the numeric index out of a `page-NNNNNN.json` file name.
fn parse_page_index(file: &str) -> Option<u64> {
    file.strip_prefix("page-")?.strip_suffix(".json")?.parse().ok()
}

/// Serialize one page to `path` (temp+rename). A free function so the
/// pool's evictor can write outside its lock with just a cloned path and
/// stamp. Returns the count of non-finite-logp entries skipped.
pub fn write_page_file(path: &Path, stamp: &str, data: &PageData) -> Result<u64, String> {
    let (j, skipped) = page::page_json(stamp, data);
    let dir = path.parent().unwrap_or(Path::new("")).to_path_buf();
    write_atomic(&dir, path, &j.to_string())?;
    Ok(skipped)
}

/// Read one page file and parse it against `stamp`. A free function (not a
/// method) so the pool can read outside its lock with just a cloned path.
pub fn read_page_file(path: &Path, stamp: &str) -> Result<PageData, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    page::parse_page(&text, stamp)
        .ok_or_else(|| format!("torn or foreign page file {}", path.display()))
}

/// Temp-file + rename write, creating `dir` on demand. Temp names carry the
/// pid AND a process-wide counter: two threads writing the same page (an
/// evictor racing a flush) must not share a temp file.
fn write_atomic(dir: &Path, path: &Path, text: &str) -> Result<(), String> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
    if !dir.as_os_str().is_empty() {
        let _ = std::fs::create_dir_all(dir);
    }
    let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp = path.with_extension(format!("tmp{}-{seq}", std::process::id()));
    std::fs::write(&tmp, text).map_err(|e| format!("write {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path).map_err(|e| format!("rename to {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn tmp_root(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("pice_spill_{}_{name}", std::process::id()))
    }

    fn entry(seed: u64) -> (MemoKey, GenOutput) {
        (
            MemoKey {
                model: "m".into(),
                prompt: vec![seed as u32, 7],
                temperature_bits: 0.7f64.to_bits(),
                max_tokens: 16,
                stop_token: None,
                seed,
            },
            GenOutput { tokens: vec![seed as u32], logps: vec![-0.25], finished: true },
        )
    }

    #[test]
    fn page_and_manifest_round_trip() {
        let root = tmp_root("roundtrip");
        let _ = std::fs::remove_dir_all(&root);
        let att = SpillStore::attach(&root, "st");
        assert!(att.pages.is_empty() && att.imported.is_empty());
        let mut store = att.store;
        let mut data = PageData::default();
        for i in 0..5u64 {
            let (k, o) = entry(i);
            data.push(Arc::new(k), o, 2);
        }
        let f = store.alloc_file();
        let (dp, skipped) = store.write_page(&f, &data).unwrap();
        assert_eq!((dp.n, skipped), (5, 0));
        store.write_manifest(&[dp.clone()]).unwrap();

        // fresh attach sees the page without reading it; fault-in matches
        let att2 = SpillStore::attach(&root, "st");
        assert_eq!(att2.pages.len(), 1);
        assert_eq!(att2.pages[0].n, 5);
        assert_eq!(att2.pages[0].hashes, dp.hashes);
        let back = read_page_file(&att2.store.page_path(&att2.pages[0].file), "st").unwrap();
        assert_eq!(back.entries.len(), 5);
        assert_eq!(*back.entries[0].key, entry(0).0);
        assert_eq!(back.entries[0].owner, 2);
        // next_file skips past existing pages
        let mut s2 = att2.store;
        assert_eq!(s2.alloc_file(), "page-000001.json");

        // stale stamp: attach under another stamp sees nothing
        let att3 = SpillStore::attach(&root, "other");
        assert!(att3.pages.is_empty());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn orphan_pages_are_garbage_collected() {
        let root = tmp_root("gc");
        let _ = std::fs::remove_dir_all(&root);
        let mut store = SpillStore::attach(&root, "st").store;
        let mut data = PageData::default();
        let (k, o) = entry(1);
        data.push(Arc::new(k), o, 0);
        let f0 = store.alloc_file();
        let (dp0, _) = store.write_page(&f0, &data).unwrap();
        let f1 = store.alloc_file();
        let (_dp1, _) = store.write_page(&f1, &data).unwrap();
        // manifest references only page 0 -> page 1 is deleted
        store.write_manifest(&[dp0]).unwrap();
        assert!(store.page_path(&f0).exists());
        assert!(!store.page_path(&f1).exists());
        let _ = std::fs::remove_dir_all(&root);
    }
}
