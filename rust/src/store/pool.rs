//! The buffer pool: resident pages under a hard budget, clock eviction,
//! pin-while-reading, and demand fault-in from the spill store.
//!
//! One `Mutex<Inner>` guards all frame state; **no disk I/O ever happens
//! under that lock**. The two slow paths both pin their frame, release the
//! lock, do the I/O, and re-lock to publish:
//!
//! * **fault-in** (`Spilled` → `Resident`): the frame moves to `Faulting`
//!   so concurrent readers of the same page wait on a condvar instead of
//!   issuing duplicate reads, and concurrent evictors skip it.
//! * **spill** (`Resident` → `Spilled`): the victim page stays fully
//!   readable while its bytes are serialized — pages are append-only and
//!   sealed once full, so the pinned snapshot the writer serializes can
//!   only go stale in the harmless direction (it IS the page).
//!
//! Eviction is clock (second-chance): every hit sets the frame's ref bit,
//! the clock hand clears it on first pass and evicts on second, skipping
//! the tail page (still accepting appends), pinned frames, and non-resident
//! frames. A victim with a clean disk copy just drops its payload; a dirty
//! victim spills first (or, with no store attached, is dropped — cache
//! semantics allow it: eviction changes hit rates, never traces).
//!
//! Budgets come in two shapes ([`PoolCfg`]): the legacy entry cap
//! (`PICE_MEMO_CAP`, where caps below one page shrink the page size so
//! tiny caches keep exact FIFO retention) and the byte budget
//! (`PICE_CACHE_BUDGET`) that this PR adds.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use super::page::{PageData, PAGE_ENTRIES};
use super::spill::{self, DiskPage, SpillStore};
use super::{stable_key_hash, MemoKey, SNAPSHOT_OWNER};
use crate::runtime::GenOutput;

/// Residency budget for a [`BufferPool`]. Exactly one of the two limits is
/// finite in the stock configurations, but both are enforced.
#[derive(Clone, Copy, Debug)]
pub struct PoolCfg {
    /// Max resident entries (the legacy `PICE_MEMO_CAP` semantics).
    pub max_entries: usize,
    /// Max resident payload bytes (`PICE_CACHE_BUDGET`).
    pub byte_budget: usize,
    /// Entries per page before the tail seals and a new one is allocated.
    pub page_entries: usize,
}

impl PoolCfg {
    /// The legacy entry-count bound. Caps below one full page shrink the
    /// page to the cap so retention is exact (a cap of 2 keeps exactly the
    /// 2 newest entries, not "whatever survives page-granular eviction").
    pub fn entry_capped(capacity: usize) -> PoolCfg {
        let cap = capacity.max(1);
        PoolCfg { max_entries: cap, byte_budget: usize::MAX, page_entries: cap.min(PAGE_ENTRIES) }
    }

    /// A hard byte budget on resident payload; entry count unbounded.
    pub fn byte_budget(bytes: usize) -> PoolCfg {
        PoolCfg { max_entries: usize::MAX, byte_budget: bytes.max(1), page_entries: PAGE_ENTRIES }
    }
}

/// Monotone pool counters, snapshot by [`BufferPool::counters`].
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolCounters {
    pub hits: u64,
    pub misses: u64,
    pub cross_hits: u64,
    pub insertions: u64,
    /// pages whose payload was dropped from memory (spilled or discarded)
    pub evictions: u64,
    /// page files written by the evictor (flush writes are not evictions)
    pub spilled_pages: u64,
    /// pages read back from disk on demand
    pub faulted_pages: u64,
    /// entries with non-finite logps skipped by page writes (no JSON
    /// representation; the store shrinks by this many entries)
    pub skipped_nonfinite: u64,
    /// current resident payload byte estimate
    pub resident_bytes: u64,
    /// current resident entry count
    pub resident_entries: u64,
}

enum FrameState {
    /// Payload in memory. `Arc` so a spill writer can serialize the sealed
    /// page outside the lock while readers keep hitting it.
    Resident(Arc<PageData>),
    /// Payload only on disk (`disk`/`hashes`/`n` describe the file).
    Spilled,
    /// A fault-in is reading the file; readers wait on the pool condvar.
    Faulting,
    /// Gone entirely (evicted with no store, or the file was torn).
    Dropped,
}

struct Frame {
    state: FrameState,
    /// page file name under the spill dir, if a disk copy exists
    disk: Option<String>,
    /// key hashes of the DISK copy (manifest data) — maintained only while
    /// the payload is off-memory; recomputed from the payload on spill
    hashes: Vec<u64>,
    /// entry count: payload len while resident, disk count while spilled
    n: usize,
    /// payload byte estimate (kept across spill as the disk estimate)
    bytes: usize,
    /// resident payload differs from (or doesn't have) a disk copy
    dirty: bool,
    /// attached from a prior process: owners rewritten to
    /// [`SNAPSHOT_OWNER`] at fault-in so warm hits count as cross hits
    foreign: bool,
    ref_bit: bool,
    pins: u32,
}

impl Frame {
    fn fresh() -> Frame {
        Frame {
            state: FrameState::Resident(Arc::new(PageData::default())),
            disk: None,
            hashes: Vec::new(),
            n: 0,
            bytes: 0,
            dirty: false,
            foreign: false,
            ref_bit: false,
            pins: 0,
        }
    }

    fn attached(dp: &DiskPage) -> Frame {
        Frame {
            state: FrameState::Spilled,
            disk: Some(dp.file.clone()),
            hashes: dp.hashes.clone(),
            n: dp.n,
            bytes: dp.bytes,
            dirty: false,
            foreign: true,
            ref_bit: false,
            pins: 0,
        }
    }
}

struct Inner {
    frames: Vec<Frame>,
    /// stable key hash -> frames that (may) hold the key; exact match is
    /// re-checked inside the page, so collisions and stale slots only cost
    /// a probe, never a wrong answer
    index: HashMap<u64, Vec<u32>>,
    /// frame currently accepting appends (always resident, never evicted)
    tail: Option<u32>,
    /// clock hand (frame index, wrapping)
    hand: usize,
    resident_entries: usize,
    resident_bytes: usize,
    spill: Option<SpillStore>,
    evictions: u64,
    spilled_pages: u64,
    faulted_pages: u64,
    skipped_nonfinite: u64,
}

/// The paged, budgeted, spill-backed generation store. All methods take
/// `&self`; share it via `Arc`.
pub struct BufferPool {
    cfg: PoolCfg,
    inner: Mutex<Inner>,
    cond: Condvar,
    hits: AtomicU64,
    misses: AtomicU64,
    cross_hits: AtomicU64,
    insertions: AtomicU64,
    /// insertion watermark at the last successful flush — the dirty check
    flushed: AtomicU64,
}

impl BufferPool {
    pub fn new(cfg: PoolCfg) -> BufferPool {
        BufferPool {
            cfg,
            inner: Mutex::new(Inner {
                frames: Vec::new(),
                index: HashMap::new(),
                tail: None,
                hand: 0,
                resident_entries: 0,
                resident_bytes: 0,
                spill: None,
                evictions: 0,
                spilled_pages: 0,
                faulted_pages: 0,
                skipped_nonfinite: 0,
            }),
            cond: Condvar::new(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            cross_hits: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            flushed: AtomicU64::new(0),
        }
    }

    pub fn cfg(&self) -> PoolCfg {
        self.cfg
    }

    /// Look up `key` on behalf of `owner`, faulting the page in from disk
    /// if needed; counts hit/miss and cross-owner provenance.
    pub fn get(&self, key: &MemoKey, owner: u32) -> Option<GenOutput> {
        let h = stable_key_hash(key);
        let mut inner = self.inner.lock().unwrap();
        loop {
            let cands: Vec<u32> = inner.index.get(&h).cloned().unwrap_or_default();
            let mut fault_target: Option<usize> = None;
            let mut waiting = false;
            for fid in cands {
                let fid = fid as usize;
                let found = match &inner.frames[fid].state {
                    FrameState::Resident(data) => {
                        data.find(key).map(|e| (e.out.clone(), e.owner))
                    }
                    FrameState::Spilled => {
                        if fault_target.is_none() {
                            fault_target = Some(fid);
                        }
                        None
                    }
                    FrameState::Faulting => {
                        waiting = true;
                        None
                    }
                    FrameState::Dropped => None,
                };
                if let Some((out, e_owner)) = found {
                    inner.frames[fid].ref_bit = true;
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    if e_owner != owner {
                        self.cross_hits.fetch_add(1, Ordering::Relaxed);
                    }
                    return Some(out);
                }
            }
            if let Some(fid) = fault_target {
                inner = self.fault_in(inner, fid);
                continue; // re-probe: the page is resident (or dropped) now
            }
            if waiting {
                inner = self.cond.wait(inner).unwrap();
                continue;
            }
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
    }

    /// Insert an entry produced by `owner`, appending to the tail page and
    /// enforcing the budget. Duplicate keys (already resident) are no-ops —
    /// entries are pure in the key, so the resident copy is the same bytes.
    pub fn insert(&self, key: MemoKey, out: GenOutput, owner: u32) {
        let h = stable_key_hash(&key);
        let mut inner = self.inner.lock().unwrap();
        if let Some(cands) = inner.index.get(&h) {
            let cands = cands.clone();
            for fid in cands {
                if let FrameState::Resident(data) = &inner.frames[fid as usize].state {
                    if data.find(&key).is_some() {
                        return;
                    }
                }
            }
        }
        let open_tail = inner.tail.filter(|&t| match &inner.frames[t as usize].state {
            FrameState::Resident(d) => d.entries.len() < self.cfg.page_entries,
            _ => false,
        });
        let t = match open_tail {
            Some(t) => t,
            None => {
                let t = inner.frames.len() as u32;
                inner.frames.push(Frame::fresh());
                inner.tail = Some(t);
                t
            }
        };
        let eb;
        {
            let f = &mut inner.frames[t as usize];
            let FrameState::Resident(arc) = &mut f.state else {
                unreachable!("tail page is always resident")
            };
            // the tail Arc is never cloned (spill skips the tail), so this
            // never deep-copies
            let data = Arc::make_mut(arc);
            eb = data.push(Arc::new(key), out, owner);
            f.n = data.entries.len();
            f.bytes = data.bytes;
            f.dirty = true;
        }
        inner.resident_entries += 1;
        inner.resident_bytes += eb;
        inner.index.entry(h).or_default().push(t);
        self.insertions.fetch_add(1, Ordering::Relaxed);
        let _ = self.enforce_budget(inner, None);
    }

    /// Bind the pool to the paged on-disk store at `root` for `stamp`:
    /// register each on-disk page as a non-resident frame (nothing is read
    /// beyond the manifest), or — if `root` holds a v1 monolithic snapshot
    /// — import it once and convert it to the paged layout. Returns how
    /// many entries became available. Never an error.
    pub fn attach_store(&self, root: impl Into<PathBuf>, stamp: &str) -> usize {
        let att = SpillStore::attach(root, stamp);
        let mut restored = 0usize;
        {
            let mut inner = self.inner.lock().unwrap();
            for dp in &att.pages {
                let fid = inner.frames.len() as u32;
                for &h in &dp.hashes {
                    inner.index.entry(h).or_default().push(fid);
                }
                restored += dp.n;
                inner.frames.push(Frame::attached(dp));
            }
            inner.spill = Some(att.store);
        }
        if !att.imported.is_empty() {
            // v1 migration: the old file is already gone — flush right away
            // so the imported entries exist in the new layout even if this
            // process never saves
            for (key, out, owner) in att.imported {
                restored += 1;
                self.insert(key, out, owner);
            }
            let _ = self.flush();
        }
        restored
    }

    /// Write every dirty resident page and a manifest covering all
    /// disk-backed frames; prior page files no longer referenced are
    /// removed. No-op without an attached store. This is the end-of-process
    /// save path (the old monolithic snapshot write), so it runs under the
    /// pool lock.
    pub fn flush(&self) -> Result<(), String> {
        let watermark = self.insertions.load(Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        let Some(mut spill) = inner.spill.take() else { return Ok(()) };
        let mut result = Ok(());
        let mut manifest: Vec<DiskPage> = Vec::new();
        for fid in 0..inner.frames.len() {
            let write_needed = {
                let f = &inner.frames[fid];
                matches!(f.state, FrameState::Resident(_)) && f.dirty && f.n > 0
            };
            if write_needed {
                let file = match inner.frames[fid].disk.clone() {
                    Some(f) => f,
                    None => {
                        let f = spill.alloc_file();
                        inner.frames[fid].disk = Some(f.clone());
                        f
                    }
                };
                let wrote = {
                    let FrameState::Resident(data) = &inner.frames[fid].state else {
                        unreachable!()
                    };
                    spill.write_page(&file, data)
                };
                match wrote {
                    Ok((dp, skipped)) => {
                        inner.skipped_nonfinite += skipped;
                        inner.frames[fid].dirty = false;
                        manifest.push(dp);
                    }
                    Err(e) => {
                        result = Err(e);
                        break;
                    }
                }
                continue;
            }
            // clean frames with a disk copy still belong in the manifest
            let f = &inner.frames[fid];
            if f.disk.is_none() {
                continue;
            }
            match &f.state {
                FrameState::Resident(data) => {
                    // clean resident page: disk content == finite subset of
                    // the payload; rebuild its manifest row from the payload
                    let mut hashes = Vec::with_capacity(data.entries.len());
                    for e in &data.entries {
                        if e.out.logps.iter().all(|x| x.is_finite()) {
                            hashes.push(stable_key_hash(&e.key));
                        }
                    }
                    manifest.push(DiskPage {
                        file: f.disk.clone().unwrap(),
                        n: hashes.len(),
                        bytes: data.bytes,
                        hashes,
                    });
                }
                FrameState::Spilled => {
                    manifest.push(DiskPage {
                        file: f.disk.clone().unwrap(),
                        n: f.n,
                        bytes: f.bytes,
                        hashes: f.hashes.clone(),
                    });
                }
                // Faulting can't coexist with flush's lock hold beyond the
                // I/O window; its frame keeps its manifest row next flush.
                // Dropped/torn frames fall out of the manifest (and their
                // files are GC'd by write_manifest).
                _ => {}
            }
        }
        if result.is_ok() {
            result = spill.write_manifest(&manifest);
        }
        inner.spill = Some(spill);
        if result.is_ok() {
            self.flushed.store(watermark, Ordering::Relaxed);
        }
        result
    }

    /// Have entries been inserted since the last successful flush?
    pub fn dirty(&self) -> bool {
        self.insertions.load(Ordering::Relaxed) != self.flushed.load(Ordering::Relaxed)
    }

    /// Total distinct keys ever inserted (monotone).
    pub fn insertions(&self) -> u64 {
        self.insertions.load(Ordering::Relaxed)
    }

    /// Entries available (resident + on disk). Excludes dropped pages.
    pub fn len(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner
            .frames
            .iter()
            .map(|f| match &f.state {
                FrameState::Resident(data) => data.entries.len(),
                FrameState::Spilled | FrameState::Faulting => f.n,
                FrameState::Dropped => 0,
            })
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn counters(&self) -> PoolCounters {
        let inner = self.inner.lock().unwrap();
        PoolCounters {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            cross_hits: self.cross_hits.load(Ordering::Relaxed),
            insertions: self.insertions.load(Ordering::Relaxed),
            evictions: inner.evictions,
            spilled_pages: inner.spilled_pages,
            faulted_pages: inner.faulted_pages,
            skipped_nonfinite: inner.skipped_nonfinite,
            resident_bytes: inner.resident_bytes as u64,
            resident_entries: inner.resident_entries as u64,
        }
    }

    /// All resident entries in page/append order (deterministic for a
    /// deterministic fill sequence). Diagnostics and tests; spilled pages
    /// are not faulted in.
    pub fn export(&self) -> Vec<(MemoKey, GenOutput)> {
        let inner = self.inner.lock().unwrap();
        let mut out = Vec::new();
        for f in &inner.frames {
            if let FrameState::Resident(data) = &f.state {
                for e in &data.entries {
                    out.push(((*e.key).clone(), e.out.clone()));
                }
            }
        }
        out
    }

    /// Read a spilled page back in. Called with the pool locked; the I/O
    /// itself runs unlocked with the frame in `Faulting` (readers wait,
    /// evictors skip). Returns the re-acquired guard.
    fn fault_in<'a>(
        &'a self,
        mut inner: MutexGuard<'a, Inner>,
        fid: usize,
    ) -> MutexGuard<'a, Inner> {
        let (path, stamp) = {
            let spill = inner.spill.as_ref().expect("spilled frame without a store");
            let file = inner.frames[fid].disk.as_ref().expect("spilled frame without a file");
            (spill.page_path(file), spill.stamp().to_string())
        };
        inner.frames[fid].state = FrameState::Faulting;
        inner.frames[fid].pins += 1;
        drop(inner);
        let read = spill::read_page_file(&path, &stamp);
        let mut inner = self.inner.lock().unwrap();
        inner.frames[fid].pins -= 1;
        match read {
            Ok(mut data) => {
                if inner.frames[fid].foreign {
                    // entries written by a prior process: any hit on them is
                    // cross-provenance, exactly like the old snapshot restore
                    for e in &mut data.entries {
                        e.owner = SNAPSHOT_OWNER;
                    }
                    inner.frames[fid].foreign = false;
                }
                let (n, bytes) = (data.entries.len(), data.bytes);
                inner.resident_entries += n;
                inner.resident_bytes += bytes;
                inner.faulted_pages += 1;
                let f = &mut inner.frames[fid];
                f.n = n;
                f.bytes = bytes;
                f.dirty = false;
                f.ref_bit = true;
                f.state = FrameState::Resident(Arc::new(data));
            }
            Err(_) => {
                // torn or vanished file: the page is lost, not an error;
                // the next manifest write garbage-collects the file
                let f = &mut inner.frames[fid];
                f.state = FrameState::Dropped;
                f.disk = None;
                f.hashes = Vec::new();
                f.n = 0;
                f.bytes = 0;
            }
        }
        self.cond.notify_all();
        // the faulted page is exempt: the caller is about to read it
        self.enforce_budget(inner, Some(fid))
    }

    fn over_budget(&self, inner: &Inner) -> bool {
        inner.resident_entries > self.cfg.max_entries || inner.resident_bytes > self.cfg.byte_budget
    }

    /// Evict until within budget (or nothing evictable remains). Spill
    /// writes drop the lock with the victim pinned; see the module docs.
    fn enforce_budget<'a>(
        &'a self,
        mut inner: MutexGuard<'a, Inner>,
        exempt: Option<usize>,
    ) -> MutexGuard<'a, Inner> {
        while self.over_budget(&inner) {
            let n = inner.frames.len();
            if n == 0 {
                break;
            }
            // clock scan: first pass clears ref bits, second evicts; bound
            // the scan so an all-pinned/all-exempt pool terminates
            let mut victim = None;
            let mut scanned = 0;
            while scanned < 2 * n + 2 {
                let i = inner.hand % n;
                inner.hand = inner.hand.wrapping_add(1);
                scanned += 1;
                if exempt == Some(i) || inner.tail == Some(i as u32) {
                    continue;
                }
                let f = &mut inner.frames[i];
                if f.pins > 0 || !matches!(f.state, FrameState::Resident(_)) {
                    continue;
                }
                if f.ref_bit {
                    f.ref_bit = false;
                    continue;
                }
                victim = Some(i);
                break;
            }
            let Some(v) = victim else { break };
            if !inner.frames[v].dirty && inner.frames[v].disk.is_some() {
                // clean with a disk copy: just drop the payload
                drop_payload(&mut inner, v, true);
                continue;
            }
            if inner.spill.is_none() {
                // no store: discard (hit rates change, traces can't)
                drop_payload(&mut inner, v, false);
                continue;
            }
            // dirty + store: spill outside the lock with a pin held; the
            // page stays resident and readable until the write lands
            let data = match &inner.frames[v].state {
                FrameState::Resident(d) => d.clone(),
                _ => continue,
            };
            let file = match inner.frames[v].disk.clone() {
                Some(f) => f,
                None => {
                    let f = inner.spill.as_mut().unwrap().alloc_file();
                    inner.frames[v].disk = Some(f.clone());
                    f
                }
            };
            let (path, stamp) = {
                let sp = inner.spill.as_ref().unwrap();
                (sp.page_path(&file), sp.stamp().to_string())
            };
            inner.frames[v].pins += 1;
            drop(inner);
            let wrote = spill::write_page_file(&path, &stamp, &data);
            inner = self.inner.lock().unwrap();
            inner.frames[v].pins -= 1;
            match wrote {
                Ok(skipped) => {
                    inner.skipped_nonfinite += skipped;
                    inner.spilled_pages += 1;
                    inner.frames[v].dirty = false;
                    drop_payload(&mut inner, v, true);
                }
                Err(_) => {
                    // disk refused the page: discard it rather than retry
                    // forever against a full disk
                    inner.frames[v].disk = None;
                    drop_payload(&mut inner, v, false);
                }
            }
        }
        inner
    }
}

/// Drop frame `v`'s resident payload: to `Spilled` (disk copy exists; the
/// manifest hashes are recomputed from the payload's finite subset, which
/// is exactly what the disk file holds) or to `Dropped` (gone). No-op on
/// non-resident frames.
fn drop_payload(inner: &mut Inner, v: usize, to_spilled: bool) {
    let (n_res, b_res, disk_hashes) = match &inner.frames[v].state {
        FrameState::Resident(data) => {
            let mut hashes = Vec::new();
            if to_spilled {
                hashes.reserve(data.entries.len());
                for e in &data.entries {
                    if e.out.logps.iter().all(|x| x.is_finite()) {
                        hashes.push(stable_key_hash(&e.key));
                    }
                }
            }
            (data.entries.len(), data.bytes, hashes)
        }
        _ => return,
    };
    let f = &mut inner.frames[v];
    if to_spilled {
        f.n = disk_hashes.len();
        f.hashes = disk_hashes;
        f.dirty = false;
        f.state = FrameState::Spilled;
    } else {
        f.n = 0;
        f.bytes = 0;
        f.hashes = Vec::new();
        f.disk = None;
        f.dirty = false;
        f.state = FrameState::Dropped;
    }
    inner.resident_entries -= n_res;
    inner.resident_bytes -= b_res;
    inner.evictions += 1;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(seed: u64) -> MemoKey {
        MemoKey {
            model: "m".into(),
            prompt: vec![seed as u32, 7],
            temperature_bits: 0.7f64.to_bits(),
            max_tokens: 16,
            stop_token: None,
            seed,
        }
    }

    fn out(t: u32) -> GenOutput {
        GenOutput { tokens: vec![t], logps: vec![-0.25], finished: true }
    }

    #[test]
    fn entry_cap_without_store_discards_oldest() {
        let pool = BufferPool::new(PoolCfg::entry_capped(4));
        for i in 0..10u64 {
            pool.insert(key(i), out(i as u32), 0);
        }
        let c = pool.counters();
        assert!(c.resident_entries <= 4, "resident {}", c.resident_entries);
        assert!(c.evictions > 0 && c.spilled_pages == 0);
        // newest survive (pages of 4, clock walks oldest-first on cold bits)
        assert!(pool.get(&key(9), 0).is_some());
        assert!(pool.get(&key(0), 0).is_none());
    }

    #[test]
    fn byte_budget_spills_and_faults_back() {
        let root =
            std::env::temp_dir().join(format!("pice_pool_spill_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        // budget below two pages' worth with 64-entry pages: force spill
        let mut cfg = PoolCfg::byte_budget(4 * 1024);
        cfg.page_entries = 8;
        let pool = BufferPool::new(cfg);
        assert_eq!(pool.attach_store(&root, "st"), 0);
        for i in 0..64u64 {
            pool.insert(key(i), out(i as u32), 0);
        }
        let c = pool.counters();
        assert!(c.spilled_pages > 0, "expected spills, got {c:?}");
        assert!(c.resident_bytes <= 4 * 1024 + 512);
        // an evicted early key faults back in from disk — and counts as a
        // SAME-owner hit (same process wrote it)
        assert_eq!(pool.get(&key(0), 0).unwrap().tokens, vec![0u32]);
        let c = pool.counters();
        assert!(c.faulted_pages > 0);
        assert_eq!(c.cross_hits, 0);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn flush_attach_round_trip_is_cross_process_warm() {
        let root =
            std::env::temp_dir().join(format!("pice_pool_warm_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        {
            let pool = BufferPool::new(PoolCfg::entry_capped(256));
            pool.attach_store(&root, "st");
            for i in 0..10u64 {
                pool.insert(key(i), out(i as u32), 3);
            }
            assert!(pool.dirty());
            pool.flush().unwrap();
            assert!(!pool.dirty());
        }
        // "next process": attach reads only the manifest, then faults
        let pool = BufferPool::new(PoolCfg::entry_capped(256));
        let restored = pool.attach_store(&root, "st");
        assert_eq!(restored, 10);
        assert_eq!(pool.len(), 10);
        assert_eq!(pool.counters().resident_entries, 0, "attach must not read pages");
        // hits on prior-process entries are cross hits, whoever asks
        assert_eq!(pool.get(&key(4), 3).unwrap().tokens, vec![4u32]);
        assert_eq!(pool.counters().cross_hits, 1);
        let _ = std::fs::remove_dir_all(&root);
    }
}
