//! The paged buffer-pool generation store — the storage subsystem under the
//! serving fleet (ROADMAP item 3).
//!
//! The shared memo cache used to be a bounded in-memory FIFO plus a
//! monolithic load-once/save-once JSON snapshot; neither survives a working
//! set larger than RAM. This module rebuilds that tier as a database-style
//! buffer pool:
//!
//! * [`page`] — entries are grouped into fixed-size **pages** (append-ordered,
//!   sealed at [`page::PAGE_ENTRIES`] entries), the unit of eviction and I/O.
//! * [`pool`] — the **buffer pool**: resident pages under a hard byte budget
//!   (`PICE_CACHE_BUDGET`) or an entry cap (the legacy `PICE_MEMO_CAP`
//!   behavior), with clock (second-chance) eviction and pin-while-reading so
//!   concurrent readers, faulters, and evictors never tear a page.
//! * [`spill`] — the **paged on-disk store** replacing the JSON snapshot:
//!   one directory per invalidation stamp, one file per page (temp+rename,
//!   versioned header), plus a small manifest so a process attaches by
//!   reading the manifest alone — pages fault in page-at-a-time on demand,
//!   killing the per-process snapshot load spike. A v1 monolithic snapshot
//!   found at the store path is imported once and converted in place.
//!
//! Determinism: eviction, spill, and fault-in may change hit rates and load
//! times but can never change traces — every entry is a pure function of its
//! [`MemoKey`], so a hit returns exactly the bytes a live generation would
//! (asserted across budgets, sweep threads, and open/closed loop by
//! `rust/tests/cache_budget_determinism.rs`). All on-disk placement and
//! export order flow from the repo's own splitmix64/FNV hashing
//! ([`stable_key_hash`]) and append order — never from `DefaultHasher`,
//! which is only deterministic within a process.
//!
//! The public cache API lives in [`crate::sweep::cache`]: `SharedMemoCache`
//! is a façade over [`pool::BufferPool`], so every call site (engine memo
//! backends, sweep scenarios, fleet shards, the serve CLI) is unchanged.

pub mod page;
pub mod pool;
pub mod spill;

pub use page::{entry_bytes, PageData, PageEntry, PAGE_ENTRIES};
pub use pool::{BufferPool, PoolCfg, PoolCounters};
pub use spill::{SpillStore, STORE_VERSION};

use crate::runtime::SamplingParams;

/// Full generation-request identity: the memo key. f64 sampling fields are
/// stored as exact bit patterns so keys hash/compare exactly.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct MemoKey {
    pub model: String,
    pub prompt: Vec<u32>,
    pub temperature_bits: u64,
    pub max_tokens: usize,
    pub stop_token: Option<u32>,
    pub seed: u64,
}

impl MemoKey {
    pub fn new(model: &str, prompt: &[u32], sp: &SamplingParams) -> MemoKey {
        MemoKey {
            model: model.to_string(),
            prompt: prompt.to_vec(),
            temperature_bits: sp.temperature.to_bits(),
            max_tokens: sp.max_tokens,
            stop_token: sp.stop_token,
            seed: sp.seed,
        }
    }
}

/// Owner id recorded on entries restored from the on-disk store — distinct
/// from every live scenario id, so warm-start hits also count as cross hits
/// (they were produced outside the requesting scenario).
pub const SNAPSHOT_OWNER: u32 = u32::MAX;

/// Stable 64-bit key hash: FNV-1a over the key's length-delimited fields,
/// finished with a splitmix64 avalanche mix. Drives the pool's key index
/// and the on-disk page manifests, so it must be identical across
/// processes, architectures, and Rust releases — `DefaultHasher` guarantees
/// none of those.
pub fn stable_key_hash(key: &MemoKey) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let eat = |h: &mut u64, bytes: &[u8]| {
        for &b in bytes {
            *h ^= b as u64;
            *h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    eat(&mut h, &(key.model.len() as u64).to_le_bytes());
    eat(&mut h, key.model.as_bytes());
    eat(&mut h, &(key.prompt.len() as u64).to_le_bytes());
    for &t in &key.prompt {
        eat(&mut h, &t.to_le_bytes());
    }
    eat(&mut h, &key.temperature_bits.to_le_bytes());
    eat(&mut h, &(key.max_tokens as u64).to_le_bytes());
    match key.stop_token {
        Some(t) => {
            eat(&mut h, &[1]);
            eat(&mut h, &t.to_le_bytes());
        }
        None => eat(&mut h, &[0]),
    }
    eat(&mut h, &key.seed.to_le_bytes());
    // splitmix64 finalizer: bijective avalanche, same mix the fleet's
    // session placement uses
    let mut z = h.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Parse a byte-size knob: a plain integer with an optional binary
/// `k`/`m`/`g` suffix (case-insensitive). `None` on anything else.
pub fn parse_byte_size(s: &str) -> Option<usize> {
    let t = s.trim();
    let (digits, mult) = match t.char_indices().last()? {
        (i, 'k') | (i, 'K') => (&t[..i], 1usize << 10),
        (i, 'm') | (i, 'M') => (&t[..i], 1usize << 20),
        (i, 'g') | (i, 'G') => (&t[..i], 1usize << 30),
        _ => (t, 1usize),
    };
    let n: usize = digits.parse().ok()?;
    n.checked_mul(mult)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(seed: u64) -> MemoKey {
        MemoKey {
            model: "m".into(),
            prompt: vec![1, 2, 3],
            temperature_bits: 0.7f64.to_bits(),
            max_tokens: 24,
            stop_token: Some(7),
            seed,
        }
    }

    #[test]
    fn stable_hash_is_pinned() {
        // the hash feeds on-disk manifests: a silent change would orphan
        // every existing store, so pin one value for the canonical key
        let h = stable_key_hash(&key(42));
        assert_eq!(h, stable_key_hash(&key(42)));
        assert_ne!(h, stable_key_hash(&key(43)));
        let mut k2 = key(42);
        k2.stop_token = None;
        assert_ne!(h, stable_key_hash(&k2));
    }

    #[test]
    fn byte_size_suffixes() {
        assert_eq!(parse_byte_size("4096"), Some(4096));
        assert_eq!(parse_byte_size("64k"), Some(64 << 10));
        assert_eq!(parse_byte_size("3M"), Some(3 << 20));
        assert_eq!(parse_byte_size("1g"), Some(1 << 30));
        assert_eq!(parse_byte_size("0"), Some(0));
        assert_eq!(parse_byte_size(""), None);
        assert_eq!(parse_byte_size("64kb"), None);
        assert_eq!(parse_byte_size("-3"), None);
    }
}
