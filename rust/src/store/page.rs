//! Pages: the buffer pool's unit of residency, eviction, and disk I/O.
//!
//! A page is an append-ordered group of up to [`PAGE_ENTRIES`] memo entries.
//! Pages are immutable once sealed (the pool only ever appends to its
//! current tail page and entries themselves never mutate), which is what
//! makes spill-while-readable safe: an evictor can serialize a sealed page
//! to disk while readers keep hitting it, then drop the payload under the
//! pool lock.
//!
//! The on-disk page format is a versioned JSON object (`version`, `stamp`,
//! `entries`) reusing the v1 snapshot's exact-bit entry serde: u64 fields
//! (seed, temperature bit pattern) are hex strings because JSON numbers are
//! f64 and cannot represent all 64-bit patterns; f64 logps round-trip
//! exactly through Rust's shortest-representation formatter. Entries whose
//! logps are non-finite (e.g. `-inf` from a zero-probability token) have no
//! JSON representation and are **skipped at write time** — the skip is
//! counted and surfaced as `CacheStats::skipped_nonfinite` so a shrinking
//! store is diagnosable rather than silent.

use std::sync::Arc;

use super::{MemoKey, SNAPSHOT_OWNER};
use crate::runtime::GenOutput;
use crate::util::json::{self, Json};

/// Entries per page. Small enough that a fault-in reads a few KiB, large
/// enough that the per-page file and index overheads amortize. The default
/// 4096-entry cache is 64 pages.
pub const PAGE_ENTRIES: usize = 64;

/// One resident entry: the full key, the cached output, and the cache-owner
/// id that produced it (cross-variant hit accounting).
#[derive(Clone)]
pub struct PageEntry {
    pub key: Arc<MemoKey>,
    pub out: GenOutput,
    pub owner: u32,
}

/// A page's in-memory payload: entries in insertion order plus the running
/// byte estimate the pool's budget accounting uses.
#[derive(Clone, Default)]
pub struct PageData {
    pub entries: Vec<PageEntry>,
    pub bytes: usize,
}

impl PageData {
    pub fn find(&self, key: &MemoKey) -> Option<&PageEntry> {
        // pages are small (<= PAGE_ENTRIES); a linear exact-key scan beats
        // a per-page map and is collision-proof where the hash index isn't
        self.entries.iter().find(|e| *e.key == *key)
    }

    /// Append an entry; returns its byte estimate (already added to
    /// `self.bytes`).
    pub fn push(&mut self, key: Arc<MemoKey>, out: GenOutput, owner: u32) -> usize {
        let eb = entry_bytes(&key, &out);
        self.bytes += eb;
        self.entries.push(PageEntry { key, out, owner });
        eb
    }
}

/// Heap-byte estimate of one entry: key payload (model string, prompt
/// tokens) + output payload (tokens, logps) + fixed per-entry overhead for
/// the structs, `Arc` header, and index slot. An estimate, not an exact
/// allocator measurement — the budget is a target, not an audit.
pub fn entry_bytes(key: &MemoKey, out: &GenOutput) -> usize {
    key.model.len() + key.prompt.len() * 4 + out.tokens.len() * 4 + out.logps.len() * 8 + 96
}

fn u64_hex(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

fn parse_u64_hex(j: &Json) -> Option<u64> {
    u64::from_str_radix(j.as_str()?, 16).ok()
}

fn u32s_json(v: &[u32]) -> Json {
    Json::Arr(v.iter().map(|&t| Json::Num(t as f64)).collect())
}

fn parse_u32s(j: &Json) -> Option<Vec<u32>> {
    j.as_arr()?.iter().map(|x| x.as_f64().map(|f| f as u32)).collect()
}

/// One on-disk entry: the full memo key + the cached output + the owner id.
/// u64 fields (seed, temperature bit pattern) are hex strings — JSON
/// numbers are f64 and can't represent all 64-bit patterns exactly.
pub fn entry_json(key: &MemoKey, out: &GenOutput, owner: u32) -> Json {
    json::obj(vec![
        ("model", json::s(&key.model)),
        ("prompt", u32s_json(&key.prompt)),
        ("t_bits", u64_hex(key.temperature_bits)),
        ("max_tokens", json::num(key.max_tokens as f64)),
        (
            "stop",
            match key.stop_token {
                Some(t) => json::num(t as f64),
                None => Json::Null,
            },
        ),
        ("seed", u64_hex(key.seed)),
        ("tokens", u32s_json(&out.tokens)),
        ("logps", Json::Arr(out.logps.iter().map(|&x| Json::Num(x)).collect())),
        ("finished", Json::Bool(out.finished)),
        ("owner", json::num(owner as f64)),
    ])
}

/// Parse one entry. The `owner` field is absent in v1 snapshot entries;
/// those default to [`SNAPSHOT_OWNER`] (they were produced by some earlier
/// process, which is exactly what the snapshot owner means).
pub fn entry_from_json(j: &Json) -> Option<(MemoKey, GenOutput, u32)> {
    let key = MemoKey {
        model: j.get("model")?.as_str()?.to_string(),
        prompt: parse_u32s(j.get("prompt")?)?,
        temperature_bits: parse_u64_hex(j.get("t_bits")?)?,
        max_tokens: j.get("max_tokens")?.as_usize()?,
        stop_token: match j.get("stop")? {
            Json::Null => None,
            x => Some(x.as_f64()? as u32),
        },
        seed: parse_u64_hex(j.get("seed")?)?,
    };
    let out = GenOutput {
        tokens: parse_u32s(j.get("tokens")?)?,
        logps: j.get("logps")?.as_arr()?.iter().map(Json::as_f64).collect::<Option<Vec<f64>>>()?,
        finished: j.get("finished")?.as_bool()?,
    };
    let owner = match j.get("owner") {
        Some(o) => o.as_f64()? as u32,
        None => SNAPSHOT_OWNER,
    };
    Some((key, out, owner))
}

/// Serialize a page for disk. Entries with non-finite logps are skipped
/// (second element of the return: how many); the page header carries the
/// store version and the invalidation stamp so a reader can reject foreign
/// or torn files outright.
pub fn page_json(stamp: &str, data: &PageData) -> (Json, u64) {
    let mut skipped = 0u64;
    let mut entries = Vec::with_capacity(data.entries.len());
    for e in &data.entries {
        if e.out.logps.iter().all(|x| x.is_finite()) {
            entries.push(entry_json(&e.key, &e.out, e.owner));
        } else {
            skipped += 1;
        }
    }
    let j = json::obj(vec![
        ("version", json::num(super::STORE_VERSION as f64)),
        ("stamp", json::s(stamp)),
        ("entries", Json::Arr(entries)),
    ]);
    (j, skipped)
}

/// Parse a page file's text back into a [`PageData`]. `None` on any
/// mismatch — wrong version, wrong stamp, torn/corrupt JSON, malformed
/// entry — the caller treats the page as lost (a cold page, never an
/// error).
pub fn parse_page(text: &str, stamp: &str) -> Option<PageData> {
    let j = Json::parse(text).ok()?;
    if j.get("version").and_then(Json::as_usize) != Some(super::STORE_VERSION) {
        return None;
    }
    if j.get("stamp").and_then(Json::as_str) != Some(stamp) {
        return None;
    }
    let mut data = PageData::default();
    for e in j.get("entries")?.as_arr()? {
        let (key, out, owner) = entry_from_json(e)?;
        data.push(Arc::new(key), out, owner);
    }
    Some(data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_json_round_trip_exact() {
        // direct serde check, including u64 bit patterns beyond 2^53 and
        // negative fractional logps
        let key = MemoKey {
            model: "m".to_string(),
            prompt: vec![1, 2, 4_000_000_000],
            temperature_bits: 0.7f64.to_bits(),
            max_tokens: 24,
            stop_token: Some(7),
            seed: u64::MAX - 12345,
        };
        let out = GenOutput {
            tokens: vec![9, 8, 7],
            logps: vec![-0.123456789012345, -3.5e-7, 0.0],
            finished: true,
        };
        let j = entry_json(&key, &out, 3);
        let reparsed = Json::parse(&j.to_string()).unwrap();
        let (k2, o2, owner) = entry_from_json(&reparsed).unwrap();
        assert_eq!(k2, key);
        assert_eq!(o2.tokens, out.tokens);
        assert_eq!(o2.logps, out.logps);
        assert_eq!(o2.finished, out.finished);
        assert_eq!(owner, 3);
    }

    #[test]
    fn v1_entry_without_owner_defaults_to_snapshot_owner() {
        let key = MemoKey {
            model: "m".into(),
            prompt: vec![4],
            temperature_bits: 0,
            max_tokens: 8,
            stop_token: None,
            seed: 5,
        };
        let out = GenOutput { tokens: vec![1], logps: vec![-0.5], finished: true };
        let mut j = entry_json(&key, &out, 9);
        if let Json::Obj(m) = &mut j {
            m.remove("owner");
        }
        let (_, _, owner) = entry_from_json(&j).unwrap();
        assert_eq!(owner, SNAPSHOT_OWNER);
    }

    #[test]
    fn page_write_skips_nonfinite_and_counts() {
        let mk = |seed: u64, logp: f64| {
            (
                MemoKey {
                    model: "m".into(),
                    prompt: vec![seed as u32],
                    temperature_bits: 0,
                    max_tokens: 8,
                    stop_token: None,
                    seed,
                },
                GenOutput { tokens: vec![seed as u32], logps: vec![logp], finished: true },
            )
        };
        let mut data = PageData::default();
        let (k1, o1) = mk(1, -0.25);
        let (k2, o2) = mk(2, f64::NEG_INFINITY);
        let (k3, o3) = mk(3, f64::NAN);
        data.push(Arc::new(k1.clone()), o1, 0);
        data.push(Arc::new(k2), o2, 0);
        data.push(Arc::new(k3), o3, 0);
        let (j, skipped) = page_json("st", &data);
        assert_eq!(skipped, 2);
        let back = parse_page(&j.to_string(), "st").unwrap();
        assert_eq!(back.entries.len(), 1);
        assert_eq!(*back.entries[0].key, k1);
        // stamp / version mismatches reject the whole page
        assert!(parse_page(&j.to_string(), "other").is_none());
        assert!(parse_page("{\"version\":99}", "st").is_none());
        assert!(parse_page("torn{", "st").is_none());
    }
}
