//! Deterministic observability: request spans, a typed metrics registry,
//! and exporters (Chrome-trace JSONL, periodic snapshot JSONL).
//!
//! House rules (enforced by `tests/telemetry_determinism.rs`):
//!
//! - **Zero-cost when off.** The engine carries telemetry as
//!   `Option<Telemetry>` (same pattern as its `events` stream); with the
//!   option `None` no span is allocated, no counter bumped, no event
//!   scheduled — runs are bit-identical to a build without this module.
//! - **Pure when on.** Spans are stamped from the engine's existing
//!   sim-time event stream only: no wall clocks, no RNG draws, no
//!   allocation that feeds back into scheduling. Span logs are therefore
//!   bit-identical across 1/2/4 sweep threads and open vs closed loop.
//!
//! The span vocabulary mirrors the request lifecycle: `QueueWait`
//! (cloud-queue admission wait), `CloudSketch`/`CloudFull` (LLM service
//! window), `Transfer` (sketch shipping over the WAN), `EdgeExpand`
//! (per-dispatch SLM expansion window, one span per batched job),
//! `EdgeFull` (edge-only full answers), plus the tail machinery:
//! `RequeueWait`, `BackoffWait`, and instant marks for `Failover`,
//! `HedgeDup`, and `CloudRescue`. Every completed request closes with one
//! `Request` root span covering arrival→done.

use std::collections::BTreeMap;
use std::io;
use std::path::{Path, PathBuf};

use crate::util::json::{num, obj, s, Json};
use crate::util::stats;

/// Sim-time seconds (same convention as the coordinator's `SimTime`).
pub type SimTime = f64;

/// Latency phase a span's duration is attributed to in the per-request
/// breakdown. Instant marks carry no duration and attribute to nothing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Queue,
    Cloud,
    Transfer,
    Edge,
    Tail,
    None,
}

/// What a span measures. Durationful kinds cover `[start, end]`; mark
/// kinds are instants (`start == end`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SpanKind {
    /// Root span: arrival → terminal, exactly one per completed request.
    Request,
    /// Waiting in the cloud queue for an LLM service slot.
    QueueWait,
    /// Cloud LLM producing a semantic sketch (progressive path).
    CloudSketch,
    /// Cloud LLM producing a full answer (cloud-only / fallback path).
    CloudFull,
    /// Sketch bits on the WAN, cloud → edge job queue.
    Transfer,
    /// One edge dispatch expanding `slots` sketch slots on edge `eid`.
    EdgeExpand { eid: usize, slots: usize },
    /// Edge-only full answer on edge `eid`.
    EdgeFull { eid: usize },
    /// Job deferred because every edge was down; waiting to re-probe.
    RequeueWait,
    /// Displaced job in exponential backoff before re-dispatch.
    BackoffWait { attempt: u32 },
    /// Mark: displaced work re-entered the queue (crash/blackout/evict).
    Failover,
    /// Mark: hedge watchdog duplicated a straggler onto edge `eid`.
    HedgeDup { eid: usize },
    /// Mark: request gave up on the edges and was rescued by the cloud.
    CloudRescue,
}

impl SpanKind {
    /// Stable event name (Chrome-trace `name` field, snapshot keys).
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Request => "request",
            SpanKind::QueueWait => "queue_wait",
            SpanKind::CloudSketch => "cloud_sketch",
            SpanKind::CloudFull => "cloud_full",
            SpanKind::Transfer => "transfer",
            SpanKind::EdgeExpand { .. } => "edge_expand",
            SpanKind::EdgeFull { .. } => "edge_full",
            SpanKind::RequeueWait => "requeue_wait",
            SpanKind::BackoffWait { .. } => "backoff_wait",
            SpanKind::Failover => "failover",
            SpanKind::HedgeDup { .. } => "hedge_dup",
            SpanKind::CloudRescue => "cloud_rescue",
        }
    }

    /// Which latency phase the span's duration belongs to.
    pub fn phase(&self) -> Phase {
        match self {
            SpanKind::QueueWait => Phase::Queue,
            SpanKind::CloudSketch | SpanKind::CloudFull => Phase::Cloud,
            SpanKind::Transfer => Phase::Transfer,
            SpanKind::EdgeExpand { .. } | SpanKind::EdgeFull { .. } => Phase::Edge,
            SpanKind::RequeueWait | SpanKind::BackoffWait { .. } => Phase::Tail,
            _ => Phase::None,
        }
    }

    /// True for instant marks (rendered as Chrome-trace `ph: "i"`).
    pub fn is_mark(&self) -> bool {
        matches!(self, SpanKind::Failover | SpanKind::HedgeDup { .. } | SpanKind::CloudRescue)
    }
}

/// One timed interval (or instant mark) in a request's lifecycle, stamped
/// in sim time. `shard` is the engine shard that emitted it (0 for a
/// single engine); `rid` is shard-local until the fleet rewrites it.
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    pub rid: usize,
    pub shard: usize,
    pub kind: SpanKind,
    pub start: SimTime,
    pub end: SimTime,
}

impl Span {
    pub fn dur(&self) -> SimTime {
        (self.end - self.start).max(0.0)
    }
}

// ---------------------------------------------------------------------------
// Metrics registry
// ---------------------------------------------------------------------------

/// Fixed-bucket histogram: `counts[i]` holds observations `<= bounds[i]`,
/// with one overflow bucket past the last bound. Fixed bounds make the
/// shard merge a plain element-wise sum.
#[derive(Clone, Debug, PartialEq)]
pub struct Hist {
    pub bounds: Vec<f64>,
    pub counts: Vec<u64>,
    pub sum: f64,
    pub n: u64,
}

impl Hist {
    pub fn new(bounds: Vec<f64>) -> Self {
        let n_buckets = bounds.len() + 1;
        Hist { bounds, counts: vec![0; n_buckets], sum: 0.0, n: 0 }
    }

    /// Default latency buckets (seconds): 0.25 → 512 doubling.
    pub fn latency() -> Self {
        Hist::new((0..12).map(|i| 0.25 * (1u64 << i) as f64).collect())
    }

    pub fn observe(&mut self, v: f64) {
        let idx = self.bounds.iter().position(|b| v <= *b).unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.n += 1;
    }

    fn merge(&mut self, other: &Hist) {
        debug_assert_eq!(self.bounds, other.bounds, "histogram bounds must match to merge");
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.sum += other.sum;
        self.n += other.n;
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("bounds", Json::Arr(self.bounds.iter().map(|b| num(*b)).collect())),
            ("counts", Json::Arr(self.counts.iter().map(|c| num(*c as f64)).collect())),
            ("sum", num(self.sum)),
            ("n", num(self.n as f64)),
        ])
    }
}

/// Typed counters/gauges/histograms for one engine shard. All maps are
/// `BTreeMap` so iteration (and therefore every exported snapshot) is
/// deterministic; `merge` mirrors `metrics::aggregate_shards` — counters
/// and histogram buckets sum, gauges sum (they are extensive quantities:
/// backlog seconds, busy seconds, up-edge counts).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsRegistry {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub hists: BTreeMap<String, Hist>,
}

impl MetricsRegistry {
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn gauge_set(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn gauge_add(&mut self, name: &str, v: f64) {
        *self.gauges.entry(name.to_string()).or_insert(0.0) += v;
    }

    pub fn observe(&mut self, name: &str, v: f64) {
        self.hists.entry(name.to_string()).or_insert_with(Hist::latency).observe(v);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Element-wise deterministic merge (shard 0..N order in the fleet).
    pub fn merge(&mut self, other: &MetricsRegistry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            *self.gauges.entry(k.clone()).or_insert(0.0) += v;
        }
        for (k, h) in &other.hists {
            self.hists.entry(k.clone()).or_insert_with(|| Hist::new(h.bounds.clone())).merge(h);
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            (
                "counters",
                Json::Obj(
                    self.counters.iter().map(|(k, v)| (k.clone(), num(*v as f64))).collect(),
                ),
            ),
            ("gauges", Json::Obj(self.gauges.iter().map(|(k, v)| (k.clone(), num(*v))).collect())),
            (
                "hists",
                Json::Obj(self.hists.iter().map(|(k, h)| (k.clone(), h.to_json())).collect()),
            ),
        ])
    }
}

// ---------------------------------------------------------------------------
// Per-engine telemetry sink
// ---------------------------------------------------------------------------

/// The per-engine sink: a span log plus a metrics registry. Lives inside
/// the engine core as `Option<Box<Telemetry>>` — `None` (the default) is
/// the zero-cost off state.
#[derive(Clone, Debug, Default)]
pub struct Telemetry {
    pub shard: usize,
    pub spans: Vec<Span>,
    pub registry: MetricsRegistry,
}

impl Telemetry {
    pub fn new(shard: usize) -> Self {
        Telemetry { shard, ..Default::default() }
    }

    pub fn span(&mut self, rid: usize, kind: SpanKind, start: SimTime, end: SimTime) {
        self.spans.push(Span { rid, shard: self.shard, kind, start, end });
    }

    pub fn mark(&mut self, rid: usize, kind: SpanKind, t: SimTime) {
        self.span(rid, kind, t, t);
    }
}

// ---------------------------------------------------------------------------
// Per-phase latency breakdown
// ---------------------------------------------------------------------------

/// p50/p99/mean of one phase's per-request time (interval-union seconds).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseStats {
    pub p50_s: f64,
    pub p99_s: f64,
    pub mean_s: f64,
}

/// Where completed requests' time goes: per-phase percentiles over the
/// union of that phase's span intervals per request (parallel slot
/// expansions on one request count wall-clock coverage, not slot-seconds).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseBreakdown {
    pub queue: PhaseStats,
    pub cloud: PhaseStats,
    pub transfer: PhaseStats,
    pub edge: PhaseStats,
    pub tail: PhaseStats,
    pub n_requests: usize,
}

/// Total covered seconds of a set of (possibly overlapping) intervals.
fn union_seconds(ivs: &mut Vec<(f64, f64)>) -> f64 {
    if ivs.is_empty() {
        return 0.0;
    }
    ivs.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
    let (mut lo, mut hi) = ivs[0];
    let mut total = 0.0;
    for &(s0, e0) in ivs.iter().skip(1) {
        if s0 > hi {
            total += hi - lo;
            lo = s0;
            hi = e0;
        } else if e0 > hi {
            hi = e0;
        }
    }
    total + (hi - lo)
}

/// Compute the per-phase breakdown from a span log. Requests are keyed by
/// `(shard, rid)` of their `Request` root span; spans of requests that
/// never completed are ignored. Returns `None` for an empty log.
pub fn phase_breakdown(spans: &[Span]) -> Option<PhaseBreakdown> {
    let mut per_req: BTreeMap<(usize, usize), [Vec<(f64, f64)>; 5]> = BTreeMap::new();
    for sp in spans {
        if sp.kind == SpanKind::Request {
            per_req.entry((sp.shard, sp.rid)).or_default();
        }
    }
    if per_req.is_empty() {
        return None;
    }
    for sp in spans {
        let idx = match sp.kind.phase() {
            Phase::Queue => 0,
            Phase::Cloud => 1,
            Phase::Transfer => 2,
            Phase::Edge => 3,
            Phase::Tail => 4,
            Phase::None => continue,
        };
        if let Some(phases) = per_req.get_mut(&(sp.shard, sp.rid)) {
            phases[idx].push((sp.start, sp.end));
        }
    }
    let mut cols: [Vec<f64>; 5] = Default::default();
    for (_, mut phases) in per_req.iter_mut().map(|(k, v)| (*k, std::mem::take(v))) {
        for (i, col) in cols.iter_mut().enumerate() {
            col.push(union_seconds(&mut phases[i]));
        }
    }
    let stat = |xs: &[f64]| PhaseStats {
        p50_s: stats::percentile(xs, 50.0),
        p99_s: stats::percentile(xs, 99.0),
        mean_s: stats::mean(xs),
    };
    Some(PhaseBreakdown {
        queue: stat(&cols[0]),
        cloud: stat(&cols[1]),
        transfer: stat(&cols[2]),
        edge: stat(&cols[3]),
        tail: stat(&cols[4]),
        n_requests: cols[0].len(),
    })
}

impl PhaseStats {
    pub fn to_json(&self) -> Json {
        obj(vec![("p50_s", num(self.p50_s)), ("p99_s", num(self.p99_s)), ("mean_s", num(self.mean_s))])
    }
}

impl PhaseBreakdown {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("queue", self.queue.to_json()),
            ("cloud", self.cloud.to_json()),
            ("transfer", self.transfer.to_json()),
            ("edge", self.edge.to_json()),
            ("tail", self.tail.to_json()),
            ("n_requests", num(self.n_requests as f64)),
        ])
    }
}

// ---------------------------------------------------------------------------
// Chrome-trace export
// ---------------------------------------------------------------------------

/// Render one span as a Chrome-trace/Perfetto event object (`ph:"X"`
/// complete events, `ph:"i"` instants; µs timestamps; `pid` = shard,
/// `tid` = request id).
pub fn chrome_trace_event(sp: &Span) -> Json {
    let us = |t: f64| (t * 1e6).round();
    let mut fields = vec![
        ("name", s(sp.kind.name())),
        ("cat", s(phase_name(sp.kind.phase()))),
        ("ph", s(if sp.kind.is_mark() { "i" } else { "X" })),
        ("ts", num(us(sp.start))),
        ("pid", num(sp.shard as f64)),
        ("tid", num(sp.rid as f64)),
    ];
    if sp.kind.is_mark() {
        fields.push(("s", s("t")));
    } else {
        fields.push(("dur", num(us(sp.end) - us(sp.start))));
    }
    let args = match sp.kind {
        SpanKind::EdgeExpand { eid, slots } => {
            vec![("eid", num(eid as f64)), ("slots", num(slots as f64))]
        }
        SpanKind::EdgeFull { eid } | SpanKind::HedgeDup { eid } => vec![("eid", num(eid as f64))],
        SpanKind::BackoffWait { attempt } => vec![("attempt", num(attempt as f64))],
        _ => vec![],
    };
    if !args.is_empty() {
        fields.push(("args", obj(args)));
    }
    obj(fields)
}

fn phase_name(p: Phase) -> &'static str {
    match p {
        Phase::Queue => "queue",
        Phase::Cloud => "cloud",
        Phase::Transfer => "transfer",
        Phase::Edge => "edge",
        Phase::Tail => "tail",
        Phase::None => "mark",
    }
}

/// Write a span log as Chrome-trace JSONL (one event object per line —
/// Perfetto ingests this directly; wrap in `[...]` for legacy
/// `chrome://tracing`). Atomic temp+rename, same pattern as `CalibStore`.
pub fn write_chrome_trace(path: &Path, spans: &[Span]) -> io::Result<()> {
    let mut out = String::new();
    for sp in spans {
        out.push_str(&chrome_trace_event(sp).to_string());
        out.push('\n');
    }
    atomic_write(path, &out)
}

fn atomic_write(path: &Path, contents: &str) -> io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let tmp = path.with_extension(format!("tmp{}", std::process::id()));
    std::fs::write(&tmp, contents)?;
    std::fs::rename(&tmp, path)
}

// ---------------------------------------------------------------------------
// Snapshot exporter
// ---------------------------------------------------------------------------

/// Periodic snapshot sink: accumulates JSONL lines and rewrites the whole
/// file via temp+rename on every push, so a crashed or interrupted run
/// still leaves the last snapshot on disk (satellite of ISSUE 10).
#[derive(Debug)]
pub struct SnapshotWriter {
    path: PathBuf,
    lines: Vec<String>,
}

impl SnapshotWriter {
    pub fn new(path: impl Into<PathBuf>) -> Self {
        SnapshotWriter { path: path.into(), lines: Vec::new() }
    }

    /// Append one snapshot object and flush the full file atomically.
    pub fn push(&mut self, snapshot: Json) -> io::Result<()> {
        self.lines.push(snapshot.to_string());
        let mut out = self.lines.join("\n");
        out.push('\n');
        atomic_write(&self.path, &out)
    }

    pub fn len(&self) -> usize {
        self.lines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lines.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_buckets_and_merge() {
        let mut a = Hist::latency();
        a.observe(0.1); // first bucket (<= 0.25)
        a.observe(3.0); // <= 4.0
        a.observe(1e9); // overflow
        assert_eq!(a.n, 3);
        assert_eq!(a.counts[0], 1);
        assert_eq!(*a.counts.last().unwrap(), 1);
        let b = a.clone();
        a.merge(&b);
        assert_eq!(a.n, 6);
        assert_eq!(a.counts[0], 2);
        assert!((a.sum - 2.0 * b.sum).abs() < 1e-9);
    }

    #[test]
    fn registry_merge_is_elementwise() {
        let mut a = MetricsRegistry::default();
        a.inc("completed", 3);
        a.gauge_set("backlog_s", 1.5);
        a.observe("latency_s", 2.0);
        let mut b = MetricsRegistry::default();
        b.inc("completed", 4);
        b.inc("failovers", 1);
        b.gauge_set("backlog_s", 0.5);
        b.observe("latency_s", 8.0);
        a.merge(&b);
        assert_eq!(a.counter("completed"), 7);
        assert_eq!(a.counter("failovers"), 1);
        assert!((a.gauges["backlog_s"] - 2.0).abs() < 1e-12);
        assert_eq!(a.hists["latency_s"].n, 2);
    }

    #[test]
    fn union_seconds_merges_overlaps() {
        let mut ivs = vec![(0.0, 2.0), (1.0, 3.0), (5.0, 6.0)];
        assert!((union_seconds(&mut ivs) - 4.0).abs() < 1e-12);
        let mut nested = vec![(0.0, 10.0), (2.0, 3.0)];
        assert!((union_seconds(&mut nested) - 10.0).abs() < 1e-12);
        let mut empty: Vec<(f64, f64)> = vec![];
        assert_eq!(union_seconds(&mut empty), 0.0);
    }

    #[test]
    fn breakdown_unions_parallel_edge_slots() {
        let spans = vec![
            Span { rid: 0, shard: 0, kind: SpanKind::Request, start: 0.0, end: 10.0 },
            Span { rid: 0, shard: 0, kind: SpanKind::QueueWait, start: 0.0, end: 1.0 },
            Span { rid: 0, shard: 0, kind: SpanKind::CloudSketch, start: 1.0, end: 3.0 },
            // two overlapping expansions: edge coverage is 4s, not 6s
            Span {
                rid: 0,
                shard: 0,
                kind: SpanKind::EdgeExpand { eid: 0, slots: 2 },
                start: 4.0,
                end: 8.0,
            },
            Span {
                rid: 0,
                shard: 0,
                kind: SpanKind::EdgeExpand { eid: 1, slots: 1 },
                start: 5.0,
                end: 7.0,
            },
            // span of a request with no root: ignored
            Span { rid: 9, shard: 0, kind: SpanKind::CloudFull, start: 0.0, end: 50.0 },
        ];
        let b = phase_breakdown(&spans).expect("breakdown");
        assert_eq!(b.n_requests, 1);
        assert!((b.queue.p50_s - 1.0).abs() < 1e-12);
        assert!((b.cloud.p50_s - 2.0).abs() < 1e-12);
        assert!((b.edge.p50_s - 4.0).abs() < 1e-12);
        assert_eq!(b.transfer.p50_s, 0.0);
    }

    #[test]
    fn chrome_trace_event_shapes() {
        let x = chrome_trace_event(&Span {
            rid: 3,
            shard: 1,
            kind: SpanKind::EdgeExpand { eid: 2, slots: 4 },
            start: 1.0,
            end: 1.5,
        })
        .to_string();
        assert!(x.contains("\"ph\":\"X\""), "{x}");
        assert!(x.contains("\"ts\":1000000"), "{x}");
        assert!(x.contains("\"dur\":500000"), "{x}");
        assert!(x.contains("\"pid\":1"), "{x}");
        assert!(x.contains("\"tid\":3"), "{x}");
        assert!(x.contains("\"slots\":4"), "{x}");
        let m = chrome_trace_event(&Span {
            rid: 0,
            shard: 0,
            kind: SpanKind::Failover,
            start: 2.0,
            end: 2.0,
        })
        .to_string();
        assert!(m.contains("\"ph\":\"i\""), "{m}");
        assert!(!m.contains("dur"), "{m}");
    }

    #[test]
    fn snapshot_writer_survives_interruption() {
        let dir = std::env::temp_dir().join(format!("pice_telem_test_{}", std::process::id()));
        let path = dir.join("snap.jsonl");
        let mut w = SnapshotWriter::new(&path);
        w.push(obj(vec![("t", num(0.0))])).expect("push");
        w.push(obj(vec![("t", num(5.0))])).expect("push");
        // every push leaves a complete, parseable file on disk
        let body = std::fs::read_to_string(&path).expect("read");
        assert_eq!(body.lines().count(), 2);
        for line in body.lines() {
            Json::parse(line).expect("valid json line");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
