//! The serving fleet: N independent [`Engine`] shards behind one router.
//!
//! One engine is one event loop — its saturation point is bounded by a
//! single core's worth of simulated cluster. The fleet scales the serving
//! tier *horizontally*: each shard owns a full cloud+edges cluster replica,
//! its own simclock, and its own dynamics timeline (shard i's fault seed is
//! `base + i`, so shards fail independently — and shard 0's world is
//! bit-identical to the single-engine world). A router in front places each
//! session on a shard ([`Placement`]): deterministic session-hash, or
//! backlog-aware least-loaded.
//!
//! ## Determinism contract (hash placement)
//!
//! Extends the SweepRunner playbook to the serving tier:
//!
//! 1. **Shard isolation.** Shards never interact — the only shared state
//!    is the generation memo cache, which is semantically transparent. A
//!    session's trace is therefore a pure function of its *own shard's*
//!    `(cfg, sub-workload, seed)`: a fleet run equals N independent
//!    single-engine runs over the hash partition of the workload,
//!    bit-for-bit, under any pump interleaving.
//! 2. **Pump-order independence.** [`Fleet::pump_until`] advances every
//!    shard to the same horizon and [`Fleet::take_events`] k-way-merges the
//!    per-shard streams by `(t, shard)`. Per-shard streams are monotone and
//!    a horizon never splits same-instant events across calls, so the
//!    merged global order is identical however the caller chunks its pumps.
//! 3. **Shard-count transparency for pinned sessions.** Hash placement
//!    nests across power-of-two fleet sizes (see
//!    [`placement::session_shard`]): a session whose key lands on shard 0
//!    of an 8-wide fleet lands on shard 0 of every smaller power-of-two
//!    fleet, where (by 1) it replays the identical world. The
//!    `fleet_determinism` tests and the `fig_saturation` hash-identity
//!    guard drive pinned cohorts and assert their traces are bit-identical
//!    at 1/2/4/8 shards.
//!
//! [`Placement::LeastLoaded`] is deliberately outside the contract: it
//! reads live backlog, so the route depends on when the caller pumps. Its
//! guarantees are weaker and load-shaped: no session routes to a
//! crashed-and-unrecovering shard while a healthy one exists. Backlog polls
//! go straight to each shard's [`Engine::backlog_estimate_s`] — the engine
//! memoizes the estimate against its own event-loop progress, so the router
//! and the shard's admission path always read the *same* number and the
//! router's hot path is a counter compare, not a queue walk.

pub mod placement;

pub use placement::{session_shard, Placement};

use crate::coordinator::{Engine, EngineCfg, RunError};
use crate::metrics::RequestTrace;
use crate::serve::{ResponseEvent, ResponseEventKind};
use crate::simclock::SimTime;
use crate::telemetry::{MetricsRegistry, Span};
use std::collections::HashMap;

/// Fleet shape: how many engine shards, and how sessions are placed.
#[derive(Clone, Copy, Debug)]
pub struct FleetCfg {
    pub shards: usize,
    pub placement: Placement,
}

impl Default for FleetCfg {
    fn default() -> Self {
        FleetCfg { shards: 1, placement: Placement::Hash }
    }
}

/// Derive shard `i`'s engine config from the fleet's base config: identical
/// in every respect except the dynamics seed (`base + i`), so shards face
/// independent fault timelines while shard 0 stays bit-identical to the
/// single-engine world. `cfg.seed` is deliberately shared — identical
/// questions derive identical sampling keys on every shard, which is what
/// makes cross-shard memo-cache hits possible.
pub fn shard_cfg(base: &EngineCfg, shard: usize) -> EngineCfg {
    let mut cfg = base.clone();
    cfg.dynamics.seed = base.dynamics.seed.wrapping_add(shard as u64);
    cfg
}

/// A fleet of independent engine shards behind a placement router.
///
/// Global request ids are allocated sequentially across the fleet in
/// submission order (the [`crate::serve::PiceService`] contract); traces
/// and events surface with global ids, shard-local ids stay internal.
pub struct Fleet<'a> {
    shards: Vec<Engine<'a>>,
    placement: Placement,
    /// global rid -> (shard, shard-local rid)
    routes: Vec<(usize, usize)>,
    /// per shard: shard-local rid -> global rid
    global_of: Vec<Vec<usize>>,
    /// cross-shard re-dispatch (work stealing) enabled — opt-in, because a
    /// steal's timing depends on when the caller pumps, which is outside
    /// the strict hash-placement determinism contract (same carve-out as
    /// [`Placement::LeastLoaded`]). Enabled by the serve layer when tail
    /// tolerance is on.
    rebalance_on: bool,
    /// global rid -> (original arrival, re-dispatch count) for every
    /// session a [`Fleet::rebalance`] moved off a dead shard. The adopting
    /// shard records its *resubmission* time as the arrival; surfaced
    /// traces/events are rewritten back to the client-true arrival and the
    /// moves are counted as failovers.
    redispatched: HashMap<usize, (SimTime, usize)>,
}

impl<'a> Fleet<'a> {
    /// Assemble a fleet from pre-built shards (typically via
    /// [`Engine::new_owned`] over [`shard_cfg`] variants — see
    /// [`crate::scenario::Env::fleet_service`]).
    pub fn new(shards: Vec<Engine<'a>>, placement: Placement) -> Self {
        assert!(!shards.is_empty(), "a fleet needs at least one shard");
        let n = shards.len();
        Fleet {
            shards,
            placement,
            routes: Vec::new(),
            global_of: vec![Vec::new(); n],
            rebalance_on: false,
            redispatched: HashMap::new(),
        }
    }

    /// Opt in to cross-shard re-dispatch: after every pump, sessions a dead
    /// shard (zero live edges) holds in a displaced state — parked, in
    /// backoff, or queued-but-unstarted — are evicted and resubmitted to
    /// the healthiest live shard. Off by default: stealing timing depends
    /// on the caller's pump cadence (see `rebalance_on`).
    pub fn enable_rebalance(&mut self) {
        self.rebalance_on = true;
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn placement(&self) -> Placement {
        self.placement
    }

    /// Latest simulated time across the shards (each shard's clock advances
    /// only as far as its own events go).
    pub fn now(&self) -> SimTime {
        self.shards.iter().map(Engine::now).fold(0.0, f64::max)
    }

    /// True when no shard has scheduled work left.
    pub fn is_idle(&self) -> bool {
        self.shards.iter().all(Engine::is_idle)
    }

    /// Total accepted submissions across the fleet.
    pub fn submitted(&self) -> usize {
        self.routes.len()
    }

    /// Total finalized requests across the fleet.
    pub fn completed(&self) -> usize {
        self.shards.iter().map(Engine::completed).sum()
    }

    /// Enable the streaming event sink on every shard.
    pub fn enable_events(&mut self) {
        for e in &mut self.shards {
            e.enable_events();
        }
    }

    /// Enable the telemetry sink on every shard, each tagged with its shard
    /// index (exported Chrome traces get per-shard `pid`s).
    pub fn enable_telemetry(&mut self) {
        for (s, e) in self.shards.iter_mut().enumerate() {
            e.enable_telemetry(s);
        }
    }

    /// Drain the shards' span logs, rids rewritten to fleet-global ids,
    /// sorted by `(start, shard, rid)` into one global timeline. Shards are
    /// drained in shard order and the sort key is total, so the result is
    /// identical at any sweep thread count and under any pump chunking that
    /// drains at the same instants. A session moved by [`Fleet::rebalance`]
    /// contributes spans from both shards but exactly ONE `Request` root
    /// span — the donor evicted it without finalizing.
    pub fn take_spans(&mut self) -> Vec<Span> {
        let mut out: Vec<Span> = Vec::new();
        for s in 0..self.shards.len() {
            for mut sp in self.shards[s].take_spans() {
                sp.rid = self.global_of[s][sp.rid];
                out.push(sp);
            }
        }
        out.sort_by(|a, b| {
            a.start.total_cmp(&b.start).then(a.shard.cmp(&b.shard)).then(a.rid.cmp(&b.rid))
        });
        out
    }

    /// Fleet-level metrics: the deterministic element-wise merge of every
    /// shard's registry (shard 0..N order — mirrors
    /// [`crate::metrics::aggregate_shards`]), plus the per-shard registries.
    /// `None` when telemetry is off.
    pub fn metrics_registries(&self) -> Option<(MetricsRegistry, Vec<MetricsRegistry>)> {
        let per_shard: Vec<MetricsRegistry> =
            self.shards.iter().filter_map(|e| e.metrics_registry().cloned()).collect();
        if per_shard.len() != self.shards.len() {
            return None;
        }
        let mut fleet = MetricsRegistry::default();
        for r in &per_shard {
            fleet.merge(r);
        }
        Some((fleet, per_shard))
    }

    /// The shard a submission with this session key would land on *now*
    /// (for hash placement, ever): admission control peeks here to test a
    /// deadline against the backlog the request would actually inherit.
    pub fn shard_for(&mut self, session_key: u64) -> usize {
        match self.placement {
            Placement::Hash => session_shard(session_key, self.shards.len()),
            Placement::LeastLoaded => self.least_loaded_shard(),
        }
    }

    /// Submit one request and return its fleet-global request id. The
    /// session key drives placement: requests of one session (same key)
    /// always co-locate under hash placement.
    pub fn submit(
        &mut self,
        question_id: usize,
        arrival: SimTime,
        session_key: u64,
    ) -> Result<usize, RunError> {
        let s = self.shard_for(session_key);
        let local = self.shards[s].submit(question_id, arrival)?;
        debug_assert_eq!(local, self.global_of[s].len(), "shard rids are sequential");
        let global = self.routes.len();
        self.routes.push((s, local));
        self.global_of[s].push(global);
        Ok(global)
    }

    /// The shard a (successfully submitted) global request id was routed to.
    pub fn route_of(&self, global_rid: usize) -> usize {
        self.routes[global_rid].0
    }

    /// Eq. 2 backlog estimate of the shard this session key would land on —
    /// the fleet-level [`Engine::backlog_estimate_s`]. The shard memoizes
    /// the estimate itself, so repeated polls between pumps are free and
    /// identical to what the shard's own admission path computes.
    pub fn backlog_estimate_for(&mut self, session_key: u64) -> SimTime {
        let s = self.shard_for(session_key);
        self.shards[s].backlog_estimate_s()
    }

    /// One calibration summary per shard (shard order). Every shard owns an
    /// independent [`crate::costmodel::CostModel`] fed only by its own event
    /// stream, so summaries diverge exactly as the shards' worlds do.
    pub fn calib_summaries(&self) -> Vec<crate::costmodel::CalibSummary> {
        self.shards.iter().map(Engine::calib_summary).collect()
    }

    /// Direct shard access (tests and the serve layer's calibration dump).
    pub fn shard(&self, s: usize) -> &Engine<'a> {
        &self.shards[s]
    }

    /// Mutable shard access (tests poll shard-level estimates directly).
    pub fn shard_mut(&mut self, s: usize) -> &mut Engine<'a> {
        &mut self.shards[s]
    }

    /// Least-loaded pick: smallest shard backlog estimate, ties broken by
    /// in-flight depth then shard index. Shards with zero live edges and
    /// zero pending recovers are skipped — they can only serve via cloud
    /// fallback, so routing *new* sessions there would turn every placement
    /// into a degraded one — unless the whole fleet is in that state.
    fn least_loaded_shard(&mut self) -> usize {
        let n = self.shards.len();
        let healthy = |e: &Engine<'_>| e.up_edges() > 0 || e.pending_recovers() > 0;
        let any_healthy = self.shards.iter().any(healthy);
        let mut best: Option<(f64, usize, usize)> = None;
        for s in 0..n {
            if any_healthy && !healthy(&self.shards[s]) {
                continue;
            }
            let inflight =
                self.shards[s].submitted() - self.shards[s].completed() - self.shards[s].evicted();
            let key = (self.shards[s].backlog_estimate_s(), inflight, s);
            let better = match &best {
                None => true,
                Some(b) => match key.0.total_cmp(&b.0) {
                    std::cmp::Ordering::Less => true,
                    std::cmp::Ordering::Greater => false,
                    std::cmp::Ordering::Equal => key.1 < b.1,
                },
            };
            if better {
                best = Some(key);
            }
        }
        best.expect("non-empty fleet").2
    }

    /// Advance every shard strictly past all events before `horizon` (the
    /// open-loop driving primitive, same semantics as
    /// [`Engine::pump_until`] per shard).
    pub fn pump_until(&mut self, horizon: SimTime) -> Result<(), RunError> {
        for e in &mut self.shards {
            e.pump_until(horizon)?;
        }
        if self.rebalance_on {
            // a stolen session enters its adopter before the horizon; pump
            // again so the caller observes the post-steal state, and repeat
            // until no shard is both dead and holding displaced work
            while self.rebalance()? > 0 {
                for e in &mut self.shards {
                    e.pump_until(horizon)?;
                }
            }
        }
        Ok(())
    }

    /// Drain every shard to quiescence.
    pub fn pump_all(&mut self) -> Result<(), RunError> {
        loop {
            for e in &mut self.shards {
                e.pump_all()?;
            }
            if !self.rebalance_on || self.rebalance()? == 0 {
                return Ok(());
            }
        }
    }

    /// One work-stealing sweep (no-op unless [`Fleet::enable_rebalance`]):
    /// every *dead* shard — zero live edges right now — donates the
    /// sessions it cannot make progress on to the live shard with the
    /// smallest in-flight depth. The donor closes each moved request
    /// without a terminal event ([`Engine::evict_displaced`]), the adopter
    /// issues a fresh local rid, and the global routing tables are
    /// remapped — so the fleet still emits exactly one terminal event per
    /// request and global ids never change. Returns the number of sessions
    /// moved. Work already escalated to a donor's cloud path is not moved:
    /// it completes regardless of edge health.
    fn rebalance(&mut self) -> Result<usize, RunError> {
        let n = self.shards.len();
        let live: Vec<usize> = (0..n).filter(|&s| self.shards[s].up_edges() > 0).collect();
        if live.is_empty() {
            return Ok(0);
        }
        let mut moved = 0usize;
        for d in 0..n {
            if self.shards[d].up_edges() > 0 {
                continue;
            }
            let displaced = self.shards[d].evict_displaced();
            if displaced.is_empty() {
                continue;
            }
            // the steal is observed fleet-wide at the latest shard clock;
            // each adopter clamps to its own (Engine::submit semantics)
            let t_steal = self.now();
            for (local, question_id, arrival) in displaced {
                let global = self.global_of[d][local];
                // record the client-true arrival once (the first eviction
                // still carries it); count every subsequent move
                let entry = self.redispatched.entry(global).or_insert((arrival, 0));
                entry.1 += 1;
                let target = *live
                    .iter()
                    .min_by_key(|&&s| {
                        self.shards[s].submitted()
                            - self.shards[s].completed()
                            - self.shards[s].evicted()
                    })
                    .expect("non-empty live set");
                let new_local = self.shards[target].submit(question_id, t_steal)?;
                debug_assert_eq!(new_local, self.global_of[target].len());
                self.routes[global] = (target, new_local);
                self.global_of[target].push(global);
                moved += 1;
            }
        }
        Ok(moved)
    }

    /// Rewrite a surfaced trace of a re-dispatched session: the arrival
    /// reverts to the client-true instant (the adopting shard only saw the
    /// steal time) and each move counts as a failover.
    fn rewrite_redispatched(&self, t: &mut RequestTrace) {
        if let Some(&(arrival, moves)) = self.redispatched.get(&t.rid) {
            t.arrival = arrival;
            t.failovers += moves;
        }
    }

    /// Drain and merge the shards' streaming events into one globally
    /// time-ordered stream (ties resolve to the lower shard index; ids are
    /// rewritten to fleet-global rids). Chunked draining is safe: a pump
    /// horizon never splits events across calls out of time order, so
    /// concatenating successive merges reproduces the full-run merge.
    pub fn take_events(&mut self) -> Vec<ResponseEvent> {
        let mut streams: Vec<std::iter::Peekable<std::vec::IntoIter<ResponseEvent>>> =
            self.shards.iter_mut().map(|e| e.take_events().into_iter().peekable()).collect();
        let mut out = Vec::new();
        loop {
            let mut best: Option<(usize, SimTime)> = None;
            for (s, st) in streams.iter_mut().enumerate() {
                if let Some(ev) = st.peek() {
                    let better = match best {
                        None => true,
                        Some((_, bt)) => ev.t < bt,
                    };
                    if better {
                        best = Some((s, ev.t));
                    }
                }
            }
            let Some((s, _)) = best else { break };
            let mut ev = streams[s].next().expect("peeked event");
            ev.rid = self.global_of[s][ev.rid];
            if let ResponseEventKind::Final { trace } = &mut ev.kind {
                trace.rid = ev.rid;
                self.rewrite_redispatched(trace);
            }
            out.push(ev);
        }
        out
    }

    /// Take completed traces across the fleet, rids rewritten to global ids,
    /// sorted by global id (fleet submission order).
    pub fn take_traces(&mut self) -> Vec<RequestTrace> {
        let mut out: Vec<RequestTrace> = Vec::new();
        for s in 0..self.shards.len() {
            for mut t in self.shards[s].take_traces() {
                t.rid = self.global_of[s][t.rid];
                self.rewrite_redispatched(&mut t);
                out.push(t);
            }
        }
        out.sort_by_key(|t| t.rid);
        out
    }

    /// Like [`Fleet::take_traces`], but keeping the per-shard grouping
    /// (rids still rewritten to global ids) — the
    /// [`crate::metrics::aggregate_shards`] input.
    pub fn take_shard_traces(&mut self) -> Vec<Vec<RequestTrace>> {
        let mut out: Vec<Vec<RequestTrace>> = Vec::with_capacity(self.shards.len());
        for s in 0..self.shards.len() {
            let mut traces = self.shards[s].take_traces();
            for t in &mut traces {
                t.rid = self.global_of[s][t.rid];
                self.rewrite_redispatched(t);
            }
            out.push(traces);
        }
        out
    }
}
