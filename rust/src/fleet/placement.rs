//! Placement policies for the serving fleet: which engine shard a new
//! session lands on.
//!
//! * [`Placement::Hash`] — deterministic session-hash placement. A session
//!   key is mixed through a splitmix64 finalizer and reduced mod the shard
//!   count; the choice is a pure function of `(key, n_shards)`, independent
//!   of fleet state, pump interleaving, or submission order. This is the
//!   policy the fleet determinism contract is stated under.
//! * [`Placement::LeastLoaded`] — backlog-aware placement: route to the
//!   shard with the smallest Eq. 2 backlog estimate
//!   ([`crate::coordinator::Engine::backlog_estimate_s`]), breaking ties by
//!   in-flight depth, then by shard index. Each shard memoizes its estimate
//!   against its own event-loop progress, so routing never re-runs Eq. 2
//!   for a shard whose loop hasn't moved — and the router reads the *same*
//!   number the shard's admission path computes. Load-adaptive, therefore
//!   *not* part of the bit-identity contract: the route depends on when the
//!   caller pumps.

/// Shard-placement policy of a [`crate::fleet::Fleet`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// deterministic session-hash placement (the default)
    Hash,
    /// backlog-aware least-loaded placement
    LeastLoaded,
}

impl Placement {
    /// Parse a CLI spelling (`hash` | `least-loaded`).
    pub fn parse(s: &str) -> Option<Placement> {
        match s {
            "hash" => Some(Placement::Hash),
            "least-loaded" => Some(Placement::LeastLoaded),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Placement::Hash => "hash",
            Placement::LeastLoaded => "least-loaded",
        }
    }
}

/// splitmix64 finalizer: a bijective avalanche mix, so consecutive session
/// keys (0, 1, 2, …) spread uniformly across shards instead of striping.
pub fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Hash placement: the shard a session key lands on in an `n_shards`-wide
/// fleet. Pure in `(key, n_shards)` — the determinism contract's anchor.
///
/// Power-of-two fleets nest: `session_shard(k, m) ≡ session_shard(k, n)
/// (mod m)` whenever `m` divides `n`, because both reduce the same mixed
/// hash. A key whose mixed hash is ≡ j (mod 8) therefore lands on shard
/// `j % n` for every fleet size n ∈ {1, 2, 4, 8} — the property the
/// cross-shard-count bit-identity guard pins sessions with.
pub fn session_shard(key: u64, n_shards: usize) -> usize {
    debug_assert!(n_shards > 0);
    (mix64(key) % n_shards.max(1) as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for p in [Placement::Hash, Placement::LeastLoaded] {
            assert_eq!(Placement::parse(p.name()), Some(p));
        }
        assert_eq!(Placement::parse("random"), None);
    }

    #[test]
    fn hash_placement_is_stable_and_spread() {
        // pure: same key, same shard
        for key in 0..64u64 {
            assert_eq!(session_shard(key, 4), session_shard(key, 4));
        }
        // consecutive keys must not stripe onto one shard
        let mut counts = [0usize; 4];
        for key in 0..400u64 {
            counts[session_shard(key, 4)] += 1;
        }
        assert!(counts.iter().all(|&c| c >= 50), "skewed spread: {counts:?}");
    }

    #[test]
    fn power_of_two_fleets_nest() {
        // h % m == (h % n) % m when m | n: a session pinned to shard j of
        // an 8-wide fleet lands on shard j % n for every n in {1,2,4,8}
        for key in 0..512u64 {
            let s8 = session_shard(key, 8);
            for n in [1usize, 2, 4] {
                assert_eq!(session_shard(key, n), s8 % n);
            }
        }
    }
}
