//! Profiler: offline latency estimation.
//!
//! Paper §III: "In the offline phase, it conducts device-specific latency
//! estimation."
//!
//! Fits the latency function f(l) (cloud LLM time to produce an l-token
//! response) and the cost coefficient c per (SLM, edge device) — the
//! quantities Eq. 2's admission test needs. The fit is an OLS line over
//! sampled generation lengths, mirroring how the paper profiles a real
//! testbed rather than reading the model's closed form.
//!
//! The paper's *runtime* half ("during runtime, it continuously monitors
//! device and server loads, as well as network conditions") lives in
//! [`crate::costmodel`]: the engine's `CostModel` instance consumes these
//! offline fits as its baseline and — when calibration is on — corrects
//! them from the live event stream.

use std::collections::BTreeMap;

use crate::cluster::DeviceSpec;
use crate::models::ModelInfo;
use crate::simclock::SimTime;
use crate::util::stats::linfit;

/// Fitted latency line f(l) = a + b*l, seconds for an l-token response.
#[derive(Clone, Copy, Debug)]
pub struct LatencyFit {
    pub a: f64,
    pub b: f64,
}

impl LatencyFit {
    pub fn eval(&self, l: usize) -> SimTime {
        (self.a + self.b * l as f64).max(0.0)
    }
}

/// Offline profile: f(l) per (device, model) + cost coefficients.
#[derive(Clone, Debug, Default)]
pub struct OfflineProfile {
    fits: BTreeMap<(String, String), LatencyFit>,
}

impl OfflineProfile {
    /// Sample the device latency model at several lengths and fit a line —
    /// the offline phase of the paper's profiler. Batch-1 everywhere.
    pub fn profile(devices: &[&DeviceSpec], models: &[&ModelInfo]) -> Self {
        Self::profile_batched(devices, models, 1)
    }

    /// Profile with the cloud measured at its *typical serving batch* (vLLM
    /// runs continuously batched, so per-sequence cloud latency under load
    /// is what Eq. 2 must compare against). Edge devices profile at batch 1.
    pub fn profile_batched(
        devices: &[&DeviceSpec],
        models: &[&ModelInfo],
        cloud_batch: usize,
    ) -> Self {
        let lengths = [32usize, 64, 128, 256, 512, 768];
        let mut fits = BTreeMap::new();
        for d in devices {
            let b = match d.kind {
                crate::cluster::DeviceKind::Cloud => cloud_batch.max(1),
                crate::cluster::DeviceKind::Edge => 1,
            };
            for m in models {
                if !d.fits(m) {
                    continue;
                }
                let xs: Vec<f64> = lengths.iter().map(|&l| l as f64).collect();
                let ys: Vec<f64> = lengths
                    .iter()
                    .map(|&l| d.prefill_time_s(m, 24, b) + d.gen_time_s(m, l, b))
                    .collect();
                let (a, bb) = linfit(&xs, &ys);
                fits.insert((d.name.clone(), m.name.clone()), LatencyFit { a, b: bb });
            }
        }
        OfflineProfile { fits }
    }

    pub fn f(&self, device: &str, model: &str) -> Option<LatencyFit> {
        self.fits.get(&(device.to_string(), model.to_string())).copied()
    }

    /// Cost coefficient c: time ratio of a single execution on (edge, SLM)
    /// vs (cloud, LLM) — the paper's c in Eq. 2.
    pub fn cost_coefficient(&self, cloud_dev: &str, llm: &str, edge_dev: &str, slm: &str) -> Option<f64> {
        let fc = self.f(cloud_dev, llm)?;
        let fe = self.f(edge_dev, slm)?;
        // ratio of marginal per-token costs (robust to intercepts)
        Some(fe.b / fc.b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::DeviceSpec;
    use crate::models::Registry;

    #[test]
    fn fit_recovers_linear_model() {
        let r = Registry::builtin();
        let cloud = DeviceSpec::a100_cloud("c");
        let m = r.get("qwen72b-sim").unwrap();
        let prof = OfflineProfile::profile(&[&cloud], &[m]);
        let fit = prof.f("c", "qwen72b-sim").unwrap();
        // slope should match the device token latency closely
        let expect = cloud.token_latency_s(m, 1);
        assert!((fit.b - expect).abs() / expect < 0.05, "slope {} vs {}", fit.b, expect);
    }

    #[test]
    fn oom_pairs_not_profiled() {
        let r = Registry::builtin();
        let edge = DeviceSpec::jetson_orin("e");
        let m = r.get("qwen72b-sim").unwrap();
        let prof = OfflineProfile::profile(&[&edge], &[m]);
        assert!(prof.f("e", "qwen72b-sim").is_none());
    }

    #[test]
    fn cost_coefficient_sane() {
        let r = Registry::builtin();
        let cloud = DeviceSpec::a100_cloud("c");
        let edge = DeviceSpec::jetson_orin("e");
        let llm = r.get("qwen72b-sim").unwrap();
        let slm = r.get("qwen7b-sim").unwrap();
        let prof = OfflineProfile::profile(&[&cloud, &edge], &[llm, slm]);
        let c = prof.cost_coefficient("c", "qwen72b-sim", "e", "qwen7b-sim").unwrap();
        // a 7B SLM on a Jetson is slower per token than a 72B on 4xA100+vLLM,
        // but within ~2x (the regime where progressive inference pays off).
        assert!(c > 0.3 && c < 10.0, "c = {c}");
    }
}
