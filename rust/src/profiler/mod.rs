//! Profiler: offline latency estimation + runtime condition monitoring.
//!
//! Paper §III: "In the offline phase, it conducts device-specific latency
//! estimation. During runtime, it continuously monitors device and server
//! loads, as well as network conditions."
//!
//! Offline: fits the latency function f(l) (cloud LLM time to produce an
//! l-token response) and the cost coefficient c per (SLM, edge device) —
//! the quantities Eq. 2's admission test needs. The fit is an OLS line over
//! sampled generation lengths, mirroring how the paper profiles a real
//! testbed rather than reading the model's closed form.

use std::collections::BTreeMap;

use crate::cluster::DeviceSpec;
use crate::models::ModelInfo;
use crate::simclock::SimTime;
use crate::util::stats::linfit;

/// Fitted latency line f(l) = a + b*l, seconds for an l-token response.
#[derive(Clone, Copy, Debug)]
pub struct LatencyFit {
    pub a: f64,
    pub b: f64,
}

impl LatencyFit {
    pub fn eval(&self, l: usize) -> SimTime {
        (self.a + self.b * l as f64).max(0.0)
    }
}

/// Offline profile: f(l) per (device, model) + cost coefficients.
#[derive(Clone, Debug, Default)]
pub struct OfflineProfile {
    fits: BTreeMap<(String, String), LatencyFit>,
}

impl OfflineProfile {
    /// Sample the device latency model at several lengths and fit a line —
    /// the offline phase of the paper's profiler. Batch-1 everywhere.
    pub fn profile(devices: &[&DeviceSpec], models: &[&ModelInfo]) -> Self {
        Self::profile_batched(devices, models, 1)
    }

    /// Profile with the cloud measured at its *typical serving batch* (vLLM
    /// runs continuously batched, so per-sequence cloud latency under load
    /// is what Eq. 2 must compare against). Edge devices profile at batch 1.
    pub fn profile_batched(
        devices: &[&DeviceSpec],
        models: &[&ModelInfo],
        cloud_batch: usize,
    ) -> Self {
        let lengths = [32usize, 64, 128, 256, 512, 768];
        let mut fits = BTreeMap::new();
        for d in devices {
            let b = match d.kind {
                crate::cluster::DeviceKind::Cloud => cloud_batch.max(1),
                crate::cluster::DeviceKind::Edge => 1,
            };
            for m in models {
                if !d.fits(m) {
                    continue;
                }
                let xs: Vec<f64> = lengths.iter().map(|&l| l as f64).collect();
                let ys: Vec<f64> = lengths
                    .iter()
                    .map(|&l| d.prefill_time_s(m, 24, b) + d.gen_time_s(m, l, b))
                    .collect();
                let (a, bb) = linfit(&xs, &ys);
                fits.insert((d.name.clone(), m.name.clone()), LatencyFit { a, b: bb });
            }
        }
        OfflineProfile { fits }
    }

    pub fn f(&self, device: &str, model: &str) -> Option<LatencyFit> {
        self.fits.get(&(device.to_string(), model.to_string())).copied()
    }

    /// Cost coefficient c: time ratio of a single execution on (edge, SLM)
    /// vs (cloud, LLM) — the paper's c in Eq. 2.
    pub fn cost_coefficient(&self, cloud_dev: &str, llm: &str, edge_dev: &str, slm: &str) -> Option<f64> {
        let fc = self.f(cloud_dev, llm)?;
        let fe = self.f(edge_dev, slm)?;
        // ratio of marginal per-token costs (robust to intercepts)
        Some(fe.b / fc.b)
    }
}

/// Runtime monitor: rolling view of queue depths, device busy state and
/// network condition that the dynamic scheduler consults per-query.
#[derive(Clone, Debug, Default)]
pub struct RuntimeMonitor {
    pub cloud_inflight: usize,
    pub cloud_queue: usize,
    pub edge_busy_until: Vec<SimTime>,
    pub job_queue_len: usize,
    pub congestion: f64,
    /// exponentially-weighted observed edge token rate error (observed /
    /// predicted), used to correct offline fits online.
    pub edge_rate_correction: f64,
}

impl RuntimeMonitor {
    pub fn new(n_edges: usize) -> Self {
        RuntimeMonitor {
            cloud_inflight: 0,
            cloud_queue: 0,
            edge_busy_until: vec![0.0; n_edges],
            job_queue_len: 0,
            congestion: 1.0,
            edge_rate_correction: 1.0,
        }
    }

    /// Earliest time any edge device becomes idle.
    pub fn next_idle_edge(&self, now: SimTime) -> SimTime {
        self.edge_busy_until
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min)
            .max(now)
    }

    pub fn idle_edges(&self, now: SimTime) -> usize {
        self.edge_busy_until.iter().filter(|&&t| t <= now).count()
    }

    /// Update the EWMA rate correction with an observed/predicted ratio.
    pub fn observe_edge_rate(&mut self, ratio: f64) {
        const ALPHA: f64 = 0.2;
        self.edge_rate_correction =
            (1.0 - ALPHA) * self.edge_rate_correction + ALPHA * ratio.clamp(0.25, 4.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::DeviceSpec;
    use crate::models::Registry;

    #[test]
    fn fit_recovers_linear_model() {
        let r = Registry::builtin();
        let cloud = DeviceSpec::a100_cloud("c");
        let m = r.get("qwen72b-sim").unwrap();
        let prof = OfflineProfile::profile(&[&cloud], &[m]);
        let fit = prof.f("c", "qwen72b-sim").unwrap();
        // slope should match the device token latency closely
        let expect = cloud.token_latency_s(m, 1);
        assert!((fit.b - expect).abs() / expect < 0.05, "slope {} vs {}", fit.b, expect);
    }

    #[test]
    fn oom_pairs_not_profiled() {
        let r = Registry::builtin();
        let edge = DeviceSpec::jetson_orin("e");
        let m = r.get("qwen72b-sim").unwrap();
        let prof = OfflineProfile::profile(&[&edge], &[m]);
        assert!(prof.f("e", "qwen72b-sim").is_none());
    }

    #[test]
    fn cost_coefficient_sane() {
        let r = Registry::builtin();
        let cloud = DeviceSpec::a100_cloud("c");
        let edge = DeviceSpec::jetson_orin("e");
        let llm = r.get("qwen72b-sim").unwrap();
        let slm = r.get("qwen7b-sim").unwrap();
        let prof = OfflineProfile::profile(&[&cloud, &edge], &[llm, slm]);
        let c = prof.cost_coefficient("c", "qwen72b-sim", "e", "qwen7b-sim").unwrap();
        // a 7B SLM on a Jetson is slower per token than a 72B on 4xA100+vLLM,
        // but within ~2x (the regime where progressive inference pays off).
        assert!(c > 0.3 && c < 10.0, "c = {c}");
    }

    #[test]
    fn monitor_idle_tracking() {
        let mut mon = RuntimeMonitor::new(3);
        mon.edge_busy_until = vec![5.0, 1.0, 9.0];
        assert_eq!(mon.idle_edges(2.0), 1);
        assert_eq!(mon.next_idle_edge(0.0), 1.0);
        assert_eq!(mon.next_idle_edge(6.0), 6.0);
    }

    #[test]
    fn ewma_bounded() {
        let mut mon = RuntimeMonitor::new(1);
        for _ in 0..100 {
            mon.observe_edge_rate(100.0); // clamped to 4.0
        }
        assert!(mon.edge_rate_correction <= 4.0);
    }
}
