//! Scenario plumbing shared by the CLI, examples and benches: artifact
//! loading, backend choice (real PJRT vs surrogate), workload construction,
//! and one-call experiment runs.

use std::sync::Arc;

use crate::baselines;
use crate::coordinator::backend::{
    MemoBackend, ParallelBackend, RealBackend, SurrogateBackend, TextBackend,
};
use crate::coordinator::{Engine, EngineCfg, RunError};
use crate::corpus::workload::{Arrival, Workload, WorkloadSpec};
use crate::corpus::Corpus;
use crate::metrics::{aggregate, RequestTrace, RunMetrics};
use crate::models::Registry;
use crate::quality::judge::Judge;
use crate::tokenizer::Tokenizer;

/// Everything a scenario needs, loaded once.
pub struct Env {
    pub tok: Tokenizer,
    pub corpus: Arc<Corpus>,
    pub registry: Registry,
    pub backend: Box<dyn TextBackend>,
    pub judge: Judge,
    pub real: bool,
}

impl Env {
    /// Load artifacts + the real PJRT backend; fall back to the Rust synth
    /// corpus + surrogate backend when artifacts are missing or
    /// `PICE_BACKEND=surrogate`.
    ///
    /// Execution-layer knobs (both preserve bit-identical outputs):
    /// * `PICE_WORKERS=N` (default 1) — shard backend batches over N OS
    ///   threads via [`ParallelBackend`], each worker owning its own backend
    ///   replica (surrogate clone / separately-loaded PJRT models).
    /// * `PICE_MEMO_CAP=N` (default 4096; 0 disables) — bound of the
    ///   generation memo-cache wrapped around the stack.
    pub fn load() -> Result<Env, String> {
        let art = crate::artifacts_dir();
        let force_surrogate = std::env::var("PICE_BACKEND").as_deref() == Ok("surrogate");
        let have_artifacts = art.join("manifest.json").exists();
        let env_usize = |key: &str, default: usize| {
            std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
        };
        let workers = env_usize("PICE_WORKERS", 1);
        let memo_cap = env_usize("PICE_MEMO_CAP", 4096);
        if have_artifacts && !force_surrogate {
            let tok = Tokenizer::from_file(&art.join("vocab.json"))?;
            let corpus = Arc::new(Corpus::from_file(&art.join("corpus.json"), &tok)?);
            let registry = Registry::from_artifacts(&art)?;
            let backend = if workers > 1 {
                let art2 = art.clone();
                let eos = tok.specials.eos;
                // probe once so a broken setup fails here, not inside a worker
                RealBackend::new(&art, eos)?;
                wrap_memo(
                    ParallelBackend::new(workers, move |_| {
                        RealBackend::new(&art2, eos).expect("worker backend")
                    }),
                    memo_cap,
                )
            } else {
                wrap_memo(RealBackend::new(&art, tok.specials.eos)?, memo_cap)
            };
            let judge = Judge::fit(&corpus);
            Ok(Env { tok, corpus, registry, backend, judge, real: true })
        } else {
            let tok = crate::corpus::synth::synth_tokenizer();
            let corpus = Arc::new(crate::corpus::synth::synth_corpus(&tok, 30, 42));
            let registry = Registry::builtin();
            let base = SurrogateBackend::new(corpus.clone(), &tok, &registry, 9);
            let backend = if workers > 1 {
                wrap_memo(ParallelBackend::new(workers, move |_| base.clone()), memo_cap)
            } else {
                wrap_memo(base, memo_cap)
            };
            let judge = Judge::fit(&corpus);
            Ok(Env { tok, corpus, registry, backend, judge, real: false })
        }
    }

    /// Paper §V-B workload: RPM = 1.5 x the cloud model's max batch.
    pub fn paper_rpm(&self, cloud_model: &str) -> f64 {
        let info = self.registry.get(cloud_model).expect("model");
        let cloud = crate::cluster::DeviceSpec::a100_cloud("c");
        1.5 * cloud.max_batch(info, 1000) as f64
    }

    pub fn workload(&self, rpm: f64, n: usize, seed: u64) -> Workload {
        Workload::generate(
            &self.corpus,
            WorkloadSpec {
                rpm,
                n_requests: n,
                arrival: Arrival::Poisson,
                categories: vec![],
                seed,
            },
        )
    }

    /// Run one engine configuration over a workload.
    pub fn run(
        &mut self,
        cfg: EngineCfg,
        wl: &Workload,
    ) -> Result<(RunMetrics, Vec<RequestTrace>), RunError> {
        let mut engine =
            Engine::new(cfg, self.corpus.clone(), &self.tok, &self.registry, self.backend.as_mut())?;
        let traces = engine.run(wl)?;
        Ok((aggregate(&traces), traces))
    }

    /// Run all four systems (Table III/IV composition) for one cloud model.
    #[allow(clippy::type_complexity)]
    pub fn run_all_systems(
        &mut self,
        cloud_model: &str,
        rpm: f64,
        n: usize,
        seed: u64,
    ) -> Vec<(&'static str, Result<(RunMetrics, Vec<RequestTrace>), RunError>)> {
        let wl = self.workload(rpm, n, seed);
        baselines::all(cloud_model)
            .into_iter()
            .map(|(name, cfg)| (name, self.run(cfg, &wl)))
            .collect()
    }
}

/// Wrap a backend in the bounded memo-cache unless `memo_cap` is 0.
fn wrap_memo<B: TextBackend + 'static>(backend: B, memo_cap: usize) -> Box<dyn TextBackend> {
    if memo_cap > 0 {
        Box::new(MemoBackend::new(backend, memo_cap))
    } else {
        Box::new(backend)
    }
}

/// Bench sizing from the environment: `PICE_BENCH_N` (requests per scenario,
/// default 60), `PICE_BENCH_SMOKE=1` (tiny smoke sizing for CI).
pub fn bench_n() -> usize {
    if std::env::var("PICE_BENCH_SMOKE").as_deref() == Ok("1") {
        return 12;
    }
    std::env::var("PICE_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(60)
}
