//! Scenario plumbing shared by the CLI, examples and benches: artifact
//! loading, backend choice (real PJRT vs surrogate), workload construction,
//! and one-call experiment runs.

use std::sync::Arc;

use crate::baselines;
use crate::coordinator::backend::{
    MemoBackend, ParallelBackend, PersistentMemoBackend, RealBackend, SurrogateBackend,
    TextBackend,
};
use crate::coordinator::{Engine, EngineCfg, RunError};
use crate::corpus::workload::{Arrival, Workload, WorkloadSpec};
use crate::corpus::Corpus;
use crate::metrics::{aggregate, RequestTrace, RunMetrics};
use crate::models::Registry;
use crate::quality::judge::Judge;
use crate::tokenizer::Tokenizer;

/// Everything a scenario needs, loaded once.
pub struct Env {
    pub tok: Tokenizer,
    pub corpus: Arc<Corpus>,
    pub registry: Registry,
    pub backend: Box<dyn TextBackend>,
    pub judge: Judge,
    pub real: bool,
}

impl Env {
    /// Load artifacts + the real PJRT backend; fall back to the Rust synth
    /// corpus + surrogate backend when artifacts are missing or
    /// `PICE_BACKEND=surrogate`.
    ///
    /// Execution-layer knobs (all preserve bit-identical outputs):
    /// * `PICE_WORKERS=N` — shard backend batches over N OS threads via
    ///   [`ParallelBackend`], each worker owning its own backend replica
    ///   (surrogate clone / separately-loaded PJRT models). Unset (or
    ///   unparsable) auto-sizes from the host — see [`auto_workers`].
    /// * `PICE_MEMO_CAP=N` (default 4096; 0 disables) — bound of the
    ///   generation memo-cache wrapped around the stack.
    /// * `PICE_MEMO_PATH=path` — persist the memo-cache to a stamp-guarded
    ///   snapshot at `path` via [`PersistentMemoBackend`], so separate
    ///   bench processes share one cache (see PERF.md §Persistent cache).
    pub fn load() -> Result<Env, String> {
        let art = crate::artifacts_dir();
        let force_surrogate = std::env::var("PICE_BACKEND").as_deref() == Ok("surrogate");
        let have_artifacts = art.join("manifest.json").exists();
        let env_usize = |key: &str, default: usize| {
            std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
        };
        let workers = std::env::var("PICE_WORKERS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(auto_workers);
        let memo_cap = env_usize("PICE_MEMO_CAP", 4096);
        let memo_path = std::env::var("PICE_MEMO_PATH").ok().filter(|p| !p.is_empty());
        if have_artifacts && !force_surrogate {
            let tok = Tokenizer::from_file(&art.join("vocab.json"))?;
            let corpus = Arc::new(Corpus::from_file(&art.join("corpus.json"), &tok)?);
            let registry = Registry::from_artifacts(&art)?;
            let stamp = real_cache_stamp(&art);
            let persist = memo_path.map(|p| (p, stamp));
            let backend = if workers > 1 {
                let art2 = art.clone();
                let eos = tok.specials.eos;
                // probe once so a broken setup fails here, not inside a worker
                RealBackend::new(&art, eos)?;
                wrap_memo(
                    ParallelBackend::new(workers, move |_| {
                        RealBackend::new(&art2, eos).expect("worker backend")
                    }),
                    memo_cap,
                    persist,
                )
            } else {
                wrap_memo(RealBackend::new(&art, tok.specials.eos)?, memo_cap, persist)
            };
            let judge = Judge::fit(&corpus);
            Ok(Env { tok, corpus, registry, backend, judge, real: true })
        } else {
            let tok = crate::corpus::synth::synth_tokenizer();
            let corpus = Arc::new(crate::corpus::synth::synth_corpus(&tok, 30, 42));
            let registry = Registry::builtin();
            let base = SurrogateBackend::new(corpus.clone(), &tok, &registry, SURROGATE_SEED);
            let stamp = surrogate_cache_stamp(&tok, &corpus, &registry, SURROGATE_SEED);
            let persist = memo_path.map(|p| (p, stamp));
            let backend = if workers > 1 {
                wrap_memo(ParallelBackend::new(workers, move |_| base.clone()), memo_cap, persist)
            } else {
                wrap_memo(base, memo_cap, persist)
            };
            let judge = Judge::fit(&corpus);
            Ok(Env { tok, corpus, registry, backend, judge, real: false })
        }
    }

    /// (hits, misses) of the memo-cache layer, if one wraps the backend.
    pub fn memo_stats(&self) -> Option<(u64, u64)> {
        self.backend.memo_stats()
    }

    /// Paper §V-B workload: RPM = 1.5 x the cloud model's max batch.
    pub fn paper_rpm(&self, cloud_model: &str) -> f64 {
        let info = self.registry.get(cloud_model).expect("model");
        let cloud = crate::cluster::DeviceSpec::a100_cloud("c");
        1.5 * cloud.max_batch(info, 1000) as f64
    }

    pub fn workload(&self, rpm: f64, n: usize, seed: u64) -> Workload {
        Workload::generate(
            &self.corpus,
            WorkloadSpec {
                rpm,
                n_requests: n,
                arrival: Arrival::Poisson,
                categories: vec![],
                seed,
            },
        )
    }

    /// Run one engine configuration over a workload.
    pub fn run(
        &mut self,
        cfg: EngineCfg,
        wl: &Workload,
    ) -> Result<(RunMetrics, Vec<RequestTrace>), RunError> {
        let mut engine =
            Engine::new(cfg, self.corpus.clone(), &self.tok, &self.registry, self.backend.as_mut())?;
        let traces = engine.run(wl)?;
        Ok((aggregate(&traces), traces))
    }

    /// Run all four systems (Table III/IV composition) for one cloud model.
    #[allow(clippy::type_complexity)]
    pub fn run_all_systems(
        &mut self,
        cloud_model: &str,
        rpm: f64,
        n: usize,
        seed: u64,
    ) -> Vec<(&'static str, Result<(RunMetrics, Vec<RequestTrace>), RunError>)> {
        let wl = self.workload(rpm, n, seed);
        baselines::all(cloud_model)
            .into_iter()
            .map(|(name, cfg)| (name, self.run(cfg, &wl)))
            .collect()
    }
}

/// Seed of the surrogate backend built by [`Env::load`]. Exported so
/// benches/tests constructing their own [`SurrogateBackend`] can share the
/// persistent cache with `Env`-driven runs — the seed shapes every
/// surrogate output, so it is part of the cache stamp.
pub const SURROGATE_SEED: u64 = 9;

/// Bump to invalidate every persistent generation cache (e.g. when backend
/// output semantics change without the artifacts changing).
pub const CACHE_STAMP_SALT: &str = "pice-gen-v1";

/// Auto-sized [`ParallelBackend`] pool: one worker per available hardware
/// thread, capped at 8 — each worker owns a full backend replica (its own
/// `LoadedModel` device buffers on the real path), so the cap bounds
/// resident memory. Determinism is unaffected by the count: the
/// index-ordered merge keeps output bit-identical at any size (PERF.md
/// §Worker-pool determinism rules).
pub fn auto_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, 8)
}

/// FNV-1a over length-delimited byte chunks -> printable stamp.
fn fnv_stamp(parts: &[&[u8]]) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    for p in parts {
        eat(&(p.len() as u64).to_le_bytes());
        eat(p);
    }
    format!("{CACHE_STAMP_SALT}-{h:016x}")
}

/// Invalidation stamp for the real-backend cache: fingerprints the artifact
/// manifest, vocab, and every model's meta/weights/HLO files, so
/// regenerated artifacts orphan old cache sections. The manifest alone is
/// NOT enough — `aot.py` writes only shapes and model names there, so a
/// retrain leaves it byte-identical while changing every generation.
pub fn real_cache_stamp(art: &std::path::Path) -> String {
    // length + head/tail sample per file rather than a full hash: cheap at
    // bench startup, and any regeneration perturbs the sampled regions
    fn eat_sampled(content: &mut Vec<u8>, path: &std::path::Path) {
        use std::io::{Read, Seek, SeekFrom};
        let Ok(mut f) = std::fs::File::open(path) else { return };
        let len = f.metadata().map(|m| m.len()).unwrap_or(0);
        content.extend_from_slice(&len.to_le_bytes());
        let k = (len as usize).min(4096);
        let mut head = vec![0u8; k];
        if f.read_exact(&mut head).is_ok() {
            content.extend_from_slice(&head);
        }
        if len > 4096 {
            let mut tail = vec![0u8; 4096];
            if f.seek(SeekFrom::End(-4096)).is_ok() && f.read_exact(&mut tail).is_ok() {
                content.extend_from_slice(&tail);
            }
        }
    }
    let mut content: Vec<u8> = Vec::new();
    eat_sampled(&mut content, &art.join("manifest.json"));
    eat_sampled(&mut content, &art.join("vocab.json"));
    let mut model_dirs: Vec<std::path::PathBuf> = std::fs::read_dir(art.join("models"))
        .map(|rd| rd.filter_map(|e| e.ok().map(|e| e.path())).collect())
        .unwrap_or_default();
    model_dirs.sort();
    for dir in model_dirs {
        let name = dir.file_name().and_then(|n| n.to_str()).unwrap_or("");
        content.extend_from_slice(name.as_bytes());
        for f in [
            "meta.json",
            "weights.bin",
            "prefill.hlo.txt",
            "prefill_batch.hlo.txt",
            "decode.hlo.txt",
            "score.hlo.txt",
        ] {
            eat_sampled(&mut content, &dir.join(f));
        }
    }
    fnv_stamp(&[b"real", &content])
}

/// Invalidation stamp for the surrogate cache: fingerprints everything the
/// surrogate's outputs are a function of — the tokenizer size, the backend
/// `seed`, the registry's model names + MMLU values (they set each model's
/// corruption rate), and the full question/answer token content. Pass the
/// same registry and seed the [`SurrogateBackend`] was constructed with —
/// a mismatch would serve another backend's outputs as cache hits.
pub fn surrogate_cache_stamp(
    tok: &Tokenizer,
    corpus: &Corpus,
    registry: &Registry,
    seed: u64,
) -> String {
    let mut content: Vec<u8> = Vec::new();
    content.extend_from_slice(&(tok.vocab_size() as u64).to_le_bytes());
    content.extend_from_slice(&seed.to_le_bytes());
    for m in &registry.models {
        content.extend_from_slice(m.name.as_bytes());
        content.extend_from_slice(&m.mmlu.to_bits().to_le_bytes());
    }
    for q in &corpus.questions {
        content.extend_from_slice(&(q.id as u64).to_le_bytes());
        for &t in &q.question {
            content.extend_from_slice(&t.to_le_bytes());
        }
        for sent in &q.sentences {
            for &t in &sent.full {
                content.extend_from_slice(&t.to_le_bytes());
            }
            for &t in &sent.sketch {
                content.extend_from_slice(&t.to_le_bytes());
            }
        }
    }
    fnv_stamp(&[b"surrogate", &content])
}

/// Wrap a backend in the bounded memo-cache unless `memo_cap` is 0; with a
/// `(path, stamp)` the cache is the persistent cross-run variant.
fn wrap_memo<B: TextBackend + 'static>(
    backend: B,
    memo_cap: usize,
    persist: Option<(String, String)>,
) -> Box<dyn TextBackend> {
    match (memo_cap > 0, persist) {
        (true, Some((path, stamp))) => {
            Box::new(PersistentMemoBackend::load(backend, memo_cap, path, &stamp))
        }
        (true, None) => Box::new(MemoBackend::new(backend, memo_cap)),
        (false, _) => Box::new(backend),
    }
}

/// Bench sizing from the environment: `PICE_BENCH_N` (requests per scenario,
/// default 60), `PICE_BENCH_SMOKE=1` (tiny smoke sizing for CI).
pub fn bench_n() -> usize {
    if std::env::var("PICE_BENCH_SMOKE").as_deref() == Ok("1") {
        return 12;
    }
    std::env::var("PICE_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(60)
}
